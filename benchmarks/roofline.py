"""Roofline-term computation from dry-run artifacts (assignment §Roofline).

Hardware constants (TPU v5e per chip):
  peak bf16 compute  197 TFLOP/s
  HBM bandwidth      819 GB/s
  ICI link bandwidth ~50 GB/s (per link; collective payload / link BW)

Terms (seconds, per step, per chip -- all dry-run numbers are per-device):
  compute    = HLO_FLOPs_per_device / 197e12      (trip-count-aware walker)
  memory     = analytic_bytes_per_device / 819e9  (documented model; the
               CPU-backend HLO's byte counts over-estimate TPU HBM traffic,
               see EXPERIMENTS.md §Dry-run)
  collective = collective_bytes_per_device / 50e9 (walker, payload x trips)

bottleneck = argmax term; roofline_fraction = compute / max(all terms) --
the fraction of peak the step would reach if perfectly overlapped, i.e.
compute-bound cells score ~1 x useful_ratio.
"""
from __future__ import annotations

import json
from typing import Dict, List, Optional

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9


def load_cells(path: str) -> List[dict]:
    out = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                out.append(json.loads(line))
    return out


def roofline_terms(cell: dict) -> Optional[Dict[str, float]]:
    if not cell.get("ok"):
        return None
    compute = cell["flops_per_device"] / PEAK_FLOPS
    memory = cell["analytic_bytes_per_device"]["total"] / HBM_BW
    coll = sum(cell["collective_bytes_per_device"].values()) / LINK_BW
    terms = {"compute": compute, "memory": memory, "collective": coll}
    bottleneck = max(terms, key=terms.get)
    step = max(terms.values())
    model_flops_dev = cell["model_flops"] / cell["n_chips"]
    useful = model_flops_dev / max(cell["flops_per_device"], 1e-30)
    return {
        "compute_ms": compute * 1e3,
        "memory_ms": memory * 1e3,
        "collective_ms": coll * 1e3,
        "bottleneck": bottleneck,
        "step_us": step * 1e6,
        "useful_ratio": min(useful, 9.99),
        # fraction of the compute roofline actually achieved given the
        # dominating term (counting only model-useful flops as progress)
        "roofline_fraction": model_flops_dev / PEAK_FLOPS / step,
    }


def markdown_table(cells: List[dict]) -> str:
    lines = [
        "| arch | shape | mesh | compute ms | memory ms | collective ms "
        "| bound | useful | roofline |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for c in cells:
        t = roofline_terms(c)
        if t is None:
            lines.append(f"| {c['arch']} | {c['shape']} | {c['mesh']} | "
                         f"FAIL: {c.get('error', '')[:40]} | | | | | |")
            continue
        lines.append(
            f"| {c['arch']} | {c['shape']} | {c['mesh']} "
            f"| {t['compute_ms']:.2f} | {t['memory_ms']:.2f} "
            f"| {t['collective_ms']:.2f} | {t['bottleneck']} "
            f"| {t['useful_ratio']:.2f} | {t['roofline_fraction']:.3f} |")
    return "\n".join(lines)


def main(argv=None) -> int:
    import argparse
    ap = argparse.ArgumentParser(
        prog="roofline.py",
        description="Render the roofline markdown table from a dry-run "
                    "JSONL artifact.")
    ap.add_argument("path", nargs="?",
                    default="benchmarks/dryrun_results.jsonl",
                    help="dry-run results JSONL (merged or raw)")
    ap.add_argument("--out", default=None,
                    help="write the markdown table here instead of stdout")
    args = ap.parse_args(argv)
    table = markdown_table(load_cells(args.path))
    if args.out:
        with open(args.out, "w") as f:
            f.write(table + "\n")
    else:
        print(table)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
