"""Benchmark harness -- one section per paper table/figure.

  B1 (Fig. 2, amended): workload x queue x thread count x **memory model**
      x **contention** -> simulated throughput (the B1' sweep; `eadr` /
      `cxl` columns show how the paper's ranking shifts on other
      persistence platforms, and the contended column restores the CAS
      retry + helping costs the op-granularity executor cannot observe)
  B2 (§5/§6 accounting): fences/op + post-flush accesses/op per queue,
      per memory model -- uncontended at 1 thread (the paper's per-op
      schedule) and contended at 4 threads (retry-inflated per-op costs)
  B3 (§2.1): ONLL upper-bound construction accounting
  B4 (assignment): roofline terms per (arch x shape x mesh) from the
      dry-run artifacts (benchmarks/dryrun_results.jsonl if present)

Prints ``name,us_per_call,derived`` CSV lines per the harness contract, and
(with ``--out``) writes the full row set to a CSV file (the CI artifact)
plus a versioned run manifest (git sha, config, env, phase timings,
headline metrics -- see docs/observability.md) alongside it.

Examples::

  PYTHONPATH=src python benchmarks/run.py --smoke     # CI smoke run
  PYTHONPATH=src python benchmarks/run.py --ops 1000 --threads 1,2,4,8,16,32,64
  PYTHONPATH=src python benchmarks/run.py --models eadr --workloads mixed5050
  PYTHONPATH=src python benchmarks/run.py --contention learned --threads 8,16
  PYTHONPATH=src python benchmarks/run.py --engine exact --trace-out traces/
  PYTHONPATH=src python benchmarks/run.py fit-profiles   # refit learned.json
  PYTHONPATH=src python benchmarks/run.py crash-sweep --out crash.csv
  PYTHONPATH=src python benchmarks/run.py fastpath-smoke --out fp.csv
  PYTHONPATH=src python benchmarks/run.py fleet --instances 100000 --check 8
  PYTHONPATH=src python benchmarks/run.py profile --out profile.csv

``repro`` comes from the pyproject / ``PYTHONPATH=src`` convention (under
pytest the pythonpath is configured for you); there is no ``sys.path``
mutation here.
"""
from __future__ import annotations

import argparse
import csv
import os
import sys
import time

from repro.core import ALL_QUEUES, DURABLE_QUEUES, NVRAM, ONLL, QueueHarness
from repro.obs import (Heartbeat, PhaseProfiler, build_manifest,
                       manifest_path_for, write_manifest)

try:        # package import (pytest / `python -m benchmarks.run`)
    from benchmarks.workloads import (contention_label, make_plans,
                                      run_workload)
except ModuleNotFoundError:   # script mode: sibling module on sys.path[0]
    from workloads import contention_label, make_plans, run_workload

# The queue axis is owned by repro.core.DURABLE_QUEUES (the crash sweep
# shards over the same registry); tests/test_benchmark_queues.py asserts
# this stays true so new queues cannot silently drop out of benchmarks.
DURABLE = list(DURABLE_QUEUES)
WORKLOADS = ["mixed5050", "pairs", "producers", "consumers", "prodcons"]
MODELS = ["optane-clwb", "eadr", "cxl"]


def _emit_manifest(subcommand: str, args, rows, headline,
                   phases=None, wall_s=None, extra=None):
    """Write the versioned run manifest for a subcommand.

    The path follows the ``--out`` CSV convention (``x.csv`` ->
    ``x.manifest.json`` in the same directory); ``--manifest`` overrides
    it (and works without a CSV).  No-op when neither is given."""
    path = getattr(args, "manifest", None)
    if not path and getattr(args, "out", None):
        path = manifest_path_for(args.out)
    if not path:
        return None
    man = build_manifest(subcommand=subcommand, config=vars(args),
                         metrics=rows, headline=headline, phases=phases,
                         wall_s=wall_s, extra=extra)
    path = write_manifest(man, path)
    print(f"# wrote manifest {path}")
    return path


def _trace_attribution(trace_out):
    """Fold every captured trace's paper-§8 post-flush attribution into a
    manifest section: which sites re-read flushed content, how often."""
    import glob

    from repro.trace import load_trace
    from repro.trace.analyze import post_flush_per_op, post_flush_sites
    out = {}
    for path in sorted(glob.glob(os.path.join(trace_out, "*.trace.npz"))):
        tr = load_trace(path)
        name = os.path.basename(path)[:-len(".trace.npz")]
        out[name] = {
            "post_flush_per_op": {k: round(v, 4) for k, v in
                                  post_flush_per_op(tr).items()},
            "sites": [{"op_kind": s.op_kind, "region": s.region,
                       "prim": s.prim, "count": s.count,
                       "per_op": round(s.per_op, 4)}
                      for s in post_flush_sites(tr)[:16]],
        }
    return out or None


def _trace_path(trace_out, *parts) -> str:
    if not trace_out:
        return None
    os.makedirs(trace_out, exist_ok=True)
    return os.path.join(trace_out,
                        "_".join(str(p) for p in parts) + ".trace.npz")


def bench_fig2(ops_per_thread: int, threads: list, models: list,
               workloads: list, queues: list, engine: str,
               contention: list, trace_out: str = None) -> list:
    rows = []
    print("# B1: Fig.2 workloads x memory models x contention "
          "(simulated latency model)")
    print("name,us_per_call,derived")
    for wl in workloads:
        # full thread sweep on the headline workload, endpoints elsewhere
        tlist = threads if wl == "mixed5050" else \
            sorted({threads[0], threads[-1]})
        for model in models:
            for cont in contention:
                for nt in tlist:
                    for q in queues:
                        r = run_workload(q, wl, nt, ops_per_thread,
                                         model=model, engine=engine,
                                         contention=cont,
                                         trace_path=_trace_path(
                                             trace_out, "b1", wl, model, q,
                                             f"t{nt}"))
                        rows.append(r)
                        print(f"fig2/{wl}/{model}/{r['contention']}/t{nt}/{q},"
                              f"{r['us_per_op']:.3f},"
                              f"mops={r['mops_per_s']:.3f};"
                              f"retries_per_op={r['retries_per_op']:.2f}")
    return rows


# B2's contended column runs at this thread count: enough co-scheduled ops
# to exercise retries while keeping per-op accounting comparable.
B2_CONTENDED_THREADS = 4


def bench_persist_counts(ops: int, models: list, queues: list,
                         engine: str, contention: list,
                         trace_out: str = None) -> list:
    # 'native' (exact engine) keeps the paper's 1-thread per-op schedule:
    # its contention axis is collapsed to that single column
    cells = []   # (setting, label, thread count) actually run
    for cont in contention:
        label = contention_label(cont) if engine == "batched" else "native"
        nt = 1 if label in ("off", "native") else B2_CONTENDED_THREADS
        cells.append((cont, label, nt))
    columns = ", ".join(f"{label} = {nt} thread{'s' if nt > 1 else ''}"
                        for _, label, nt in cells)
    print(f"\n# B2: persist-op accounting ({ops} ops, per memory model; "
          f"{columns})")
    print("name,us_per_call,derived")
    rows = []
    for model in models:
        for cont, label, nt in cells:
            for q in queues:
                r = run_workload(q, "pairs", nt, ops, model=model,
                                 engine=engine, contention=cont,
                                 trace_path=_trace_path(trace_out, "b2",
                                                        model, q, f"t{nt}"))
                rows.append(r)
                print(f"counts/{model}/{r['contention']}/{q},"
                      f"{r['us_per_op']:.3f},"
                      f"fences_per_op={r['fences_per_op']:.2f};"
                      f"post_flush_per_op={r['post_flush_per_op']:.2f};"
                      f"retries_per_op={r['retries_per_op']:.2f}")
    return rows


def bench_onll(n: int = 200) -> None:
    print("\n# B3: ONLL universal construction (upper bound, §2.1)")
    print("name,us_per_call,derived")
    nv = NVRAM(1)
    obj = ONLL(nv, 1, lambda s, o: (s + o, s + o), 0)
    base = nv.total_stats()
    for _ in range(n):
        obj.update(0, 1)
    d = nv.total_stats().minus(base)
    print(f"onll/update,{d.time_ns / n / 1e3:.3f},"
          f"fences_per_op={d.fences / n:.2f};"
          f"post_flush_per_op={d.post_flush_accesses / n:.2f}")


def bench_roofline(path: str = None) -> None:
    base = os.path.dirname(__file__)
    merged = os.path.join(base, "dryrun_merged.jsonl")
    path = path or (merged if os.path.exists(merged)
                    else os.path.join(base, "dryrun_results.jsonl"))
    print("\n# B4: roofline terms from the multi-pod dry-run")
    if not os.path.exists(path):
        print(f"(no dry-run artifacts at {path}; run "
              "`python -m repro.launch.dryrun` first)")
        return
    print("name,us_per_call,derived")
    try:
        from benchmarks.roofline import load_cells, roofline_terms
    except ModuleNotFoundError:
        from roofline import load_cells, roofline_terms
    for cell in load_cells(path):
        t = roofline_terms(cell)
        if t is None:
            print(f"roofline/{cell['arch']}/{cell['shape']}/{cell['mesh']},"
                  f"nan,error={cell.get('error', '?')[:60]}")
            continue
        dom = t["bottleneck"]
        print(f"roofline/{cell['arch']}/{cell['shape']}/{cell['mesh']},"
              f"{t['step_us']:.1f},"
              f"compute_ms={t['compute_ms']:.2f};mem_ms={t['memory_ms']:.2f};"
              f"coll_ms={t['collective_ms']:.2f};bound={dom};"
              f"useful={t['useful_ratio']:.2f};"
              f"roofline_frac={t['roofline_fraction']:.3f}")


def parse_args(argv=None) -> argparse.Namespace:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--ops", type=int, default=200,
                    help="ops per thread (default 200; seed engine capped "
                         "at ~60)")
    ap.add_argument("--threads", default="1,2,4,8,16",
                    help="comma-separated thread counts, 1..64")
    ap.add_argument("--models", default=",".join(MODELS),
                    help="comma-separated memory models "
                         f"(default {','.join(MODELS)})")
    ap.add_argument("--workloads", default=",".join(WORKLOADS))
    ap.add_argument("--queues", default=",".join(DURABLE))
    ap.add_argument("--engine", choices=["batched", "exact"],
                    default="batched")
    ap.add_argument("--contention", default="off,on",
                    help="comma-separated contention axis values: off, on "
                         "(calibrated default model), learned "
                         "(trace-fitted profiles from "
                         "benchmarks/profiles/learned.json), or a float "
                         "retry_scale (batched engine only; the exact "
                         "engine's contention is native)")
    ap.add_argument("--trace-out", default=None,
                    help="directory for captured traces (*.trace.npz); "
                         "exact-engine runs only -- the trace subsystem "
                         "records real interleavings")
    ap.add_argument("--out", default=None,
                    help="write all B1/B2 rows to this CSV file")
    ap.add_argument("--manifest", default=None,
                    help="run-manifest destination (default: alongside "
                         "--out as <stem>.manifest.json)")
    ap.add_argument("--sections", default="b1,b2,b3,b4")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI run: 30 ops/thread, threads 1,4")
    args = ap.parse_args(argv)
    for tok in args.contention.split(","):
        try:
            contention_label(tok)
        except ValueError:
            ap.error(f"--contention: {tok!r} is not off, on, learned, or "
                     "a float retry_scale")
    if args.trace_out and args.engine != "exact":
        ap.error("--trace-out needs --engine exact: the trace subsystem "
                 "records real per-primitive interleavings")
    if args.smoke:
        args.ops = 30
        args.threads = "1,4"
    return args


def fastpath_smoke_main(argv) -> None:
    """`run.py fastpath-smoke`: the schedule-compiler acceptance smoke.

    Four runs of the same workload per queue:

    * ``per-op@cap``   -- the pre-compiler stack (per-primitive replay,
      per-primitive allocator-area zeroing, collector running) at that
      stack's practical scale cap (``--cap-ops``, default 6400 total ops
      at 64 threads -- "a few thousand ops" per the pre-compiler docs);
    * ``per-op``       -- the same stack pushed to the full ``--ops``
      scale (areas amortize; the steady per-op cost);
    * ``per-op+bulk-alloc`` -- per-op ops with this PR's vectorized
      allocator seam + GC pause, isolating those two contributions;
    * ``compiled``     -- the full fast path at full scale.

    Three gates, all enforced: the compiled path must be ``--min-speedup``
    (default 30x) cheaper per op than the per-op stack at its practical
    cap, ``--min-speedup-same-scale`` (default 4x) cheaper than the
    per-op stack at the identical full scale, and absolutely cheaper than
    ``--max-us-per-op`` (default 10 us -- the columnar engine measures
    ~4.5-8 us/op run to run on the reference container; the margin
    absorbs CI-runner noise), inside ``--budget-s`` wall clock.  All four
    us/op figures are printed and written to the CSV, so no ratio hides
    another.

    The cap baseline keeps the pre-compiler stack's stock allocator
    config (4096-node areas) -- it is a historical reference point, not a
    tunable.  The three full-scale modes share ``--area-nodes`` so the
    same-scale ratio compares like for like.

    ``--differential`` reruns the compiled workload on the legacy record
    path (``QueueHarness(records="legacy")``) and requires every
    per-thread Stats field to be bit-identical to the columnar run -- the
    CI columnar-vs-legacy differential smoke, at full smoke scale rather
    than the equivalence suite's test sizes.

    ``--burst`` adds the burst-executor rows: ``--burst-workload``
    (default ``producers``) at the full ``--ops`` scale, once on the
    merged columnar runner and once with the vectorized burst executor
    (``run_batched(burst=...)``, window ``--burst-window``).  Two gates:
    per-thread Stats must be bit-identical between the two runs, and the
    burst run must be ``--min-speedup-burst`` (default 3x) cheaper per
    op at the identical scale -- the PR-10 sub-microsecond cell the
    trajectory snapshot tracks as
    ``fastpath-burst/<queue>/burst_us_per_op``.
    """
    ap = argparse.ArgumentParser(
        prog="run.py fastpath-smoke",
        description=fastpath_smoke_main.__doc__.splitlines()[0])
    ap.add_argument("--threads", type=int, default=64)
    ap.add_argument("--ops", type=int, default=100_000,
                    help="total ops across all threads (default 100k)")
    ap.add_argument("--cap-ops", type=int, default=6400,
                    help="total ops for the per-op stack's practical-cap "
                         "baseline (default 6400: the pre-compiler reach)")
    ap.add_argument("--queues", default="DurableMSQ,OptUnlinkedQ")
    ap.add_argument("--workload", default="mixed5050")
    ap.add_argument("--model", default="optane-clwb")
    ap.add_argument("--area-nodes", type=int, default=1024,
                    help="designated-area size (nodes/area) for the three "
                         "full-scale modes (default 1024: right-sized for "
                         "this workload's ~800 allocs/thread -- the stock "
                         "4096 spends most of an area's zeroing cost on "
                         "nodes the smoke never allocates); the per-op@cap "
                         "baseline keeps the pre-compiler stock 4096")
    ap.add_argument("--min-speedup", type=float, default=30.0,
                    help="required compiled (at --ops) vs per-op (at "
                         "--cap-ops) per-op speedup (default 30x; measured "
                         "~43-75x against the stock-config cap baseline)")
    ap.add_argument("--min-speedup-same-scale", type=float, default=4.0,
                    help="required compiled vs per-op speedup at the "
                         "identical --ops scale (default 4x; measured "
                         "~5-9x, the margin absorbs CI-runner noise)")
    ap.add_argument("--max-us-per-op", type=float, default=10.0,
                    help="absolute ceiling on compiled us/op (default 10; "
                         "measured ~4.5-8 on the reference container)")
    ap.add_argument("--budget-s", type=float, default=60.0,
                    help="wall-clock budget per compiled run")
    ap.add_argument("--differential", action="store_true",
                    help="rerun the compiled workload with records='legacy' "
                         "and require bit-identical per-thread Stats")
    ap.add_argument("--burst", action="store_true",
                    help="add the burst-executor rows: run --burst-workload "
                         "at full scale on the columnar runner and again "
                         "with run_batched(burst=...), require bit-identical "
                         "per-thread Stats and >= --min-speedup-burst")
    ap.add_argument("--burst-queues", default="MSQ",
                    help="comma-separated queues for the burst rows "
                         "(default MSQ: the queue whose op programs the "
                         "whole-burst vector fast paths fully collapse)")
    ap.add_argument("--burst-workload", default="producers",
                    help="workload for the burst rows (default producers: "
                         "the uncontended enqueue-only shape burst "
                         "prediction targets)")
    ap.add_argument("--burst-window", type=int, default=32768,
                    help="burst window in ops (default 32768)")
    ap.add_argument("--min-speedup-burst", type=float, default=3.0,
                    help="required burst vs columnar speedup at identical "
                         "scale (default 3x; measured ~3.3-3.6x on the "
                         "reference container)")
    ap.add_argument("--out", default=None, help="CSV destination")
    ap.add_argument("--manifest", default=None,
                    help="run-manifest destination (default: alongside "
                         "--out as <stem>.manifest.json)")
    args = ap.parse_args(argv)
    ops_per_thread = max(1, -(-args.ops // args.threads))
    total = ops_per_thread * args.threads
    cap_per_thread = max(1, -(-args.cap_ops // args.threads))
    cap_total = cap_per_thread * args.threads
    t_run0 = time.perf_counter()
    headline = {}
    modes = [
        # (label, ops/thread, compiled?, vectorized allocator seam?,
        #  pause GC?, area nodes) -- the first two reproduce the stack as
        # it stood before the schedule compiler: every primitive and
        # every allocator-area zeroing replayed one Python call at a
        # time, with the collector running.  The cap baseline keeps the
        # pre-compiler stock area size; the full-scale modes share
        # --area-nodes.
        ("per-op@cap", cap_per_thread, False, False, False, 4096),
        ("per-op", ops_per_thread, False, False, False, args.area_nodes),
        ("per-op+bulk-alloc", ops_per_thread, False, True, True,
         args.area_nodes),
        ("compiled", ops_per_thread, True, True, True, args.area_nodes),
    ]
    rows, failures = [], []
    print(f"# fastpath-smoke: {args.workload} x {args.threads} threads x "
          f"{total} ops ({args.model}; per-op cap baseline {cap_total} ops)")
    print("name,us_per_call,derived")
    for qname in args.queues.split(","):
        cell = {}
        for label, opt, compiled, bulk, pause_gc, area_nodes in modes:
            h = QueueHarness(ALL_QUEUES[qname], nthreads=args.threads,
                             model=args.model, area_nodes=area_nodes)
            h.nvram.enable_bulk_init = bulk
            plans, prefill = make_plans(args.workload, args.threads,
                                        opt, seed=0)
            for i in range(prefill):
                h.queue.enqueue(0, ("pre", i))
            base_stats = h.nvram.total_stats()
            t0 = time.perf_counter()
            res = h.run_batched(plans, compiled=compiled, pause_gc=pause_gc)
            wall = time.perf_counter() - t0
            n = opt * args.threads
            assert res.ops_completed == n
            us = wall * 1e6 / n
            cell[label] = us
            d = h.nvram.total_stats().minus(base_stats)
            if compiled:
                columnar_stats = {t: h.nvram.stats[t].snapshot()
                                  for t in range(args.threads)}
            rows.append({
                "queue": qname, "workload": args.workload,
                "model": args.model, "threads": args.threads, "mode": label,
                "ops": n, "wall_s": round(wall, 3),
                "us_per_op": round(us, 3),
                "post_flush_per_op": round(d.post_flush_accesses / n, 3),
                "fast_ops": h.fast.fast_ops if h.fast else 0,
                "bailed_ops": h.fast.bailed_ops if h.fast else 0,
                "speedup_vs_cap": "", "speedup_same_scale": "",
                "speedup_burst": "",
            })
        speedup_cap = cell["per-op@cap"] / cell["compiled"]
        speedup_same = cell["per-op"] / cell["compiled"]
        rows[-1]["speedup_vs_cap"] = round(speedup_cap, 2)
        rows[-1]["speedup_same_scale"] = round(speedup_same, 2)
        headline[f"fastpath/{qname}/compiled_us_per_op"] = \
            round(cell["compiled"], 4)
        headline[f"fastpath/{qname}/speedup_vs_cap"] = round(speedup_cap, 2)
        headline[f"fastpath/{qname}/speedup_same_scale"] = \
            round(speedup_same, 2)
        print(f"fastpath/{qname}/compiled,{cell['compiled']:.3f},"
              f"perop_cap_us={cell['per-op@cap']:.1f};"
              f"perop_us={cell['per-op']:.1f};"
              f"perop_bulk_us={cell['per-op+bulk-alloc']:.1f};"
              f"speedup_vs_cap={speedup_cap:.1f}x;"
              f"speedup_same_scale={speedup_same:.1f}x")
        wall_compiled = rows[-1]["wall_s"]
        if speedup_cap < args.min_speedup:
            failures.append(
                f"{qname}: {speedup_cap:.1f}x vs per-op@cap < "
                f"{args.min_speedup:.0f}x required")
        if speedup_same < args.min_speedup_same_scale:
            failures.append(
                f"{qname}: {speedup_same:.1f}x at same scale < "
                f"{args.min_speedup_same_scale:.0f}x required")
        if cell["compiled"] > args.max_us_per_op:
            failures.append(
                f"{qname}: compiled {cell['compiled']:.2f} us/op > "
                f"{args.max_us_per_op:.1f} us ceiling")
        if args.differential:
            h = QueueHarness(ALL_QUEUES[qname], nthreads=args.threads,
                             model=args.model, area_nodes=args.area_nodes,
                             records="legacy")
            h.nvram.enable_bulk_init = True
            plans, prefill = make_plans(args.workload, args.threads,
                                        ops_per_thread, seed=0)
            for i in range(prefill):
                h.queue.enqueue(0, ("pre", i))
            base_stats = h.nvram.total_stats()
            t0 = time.perf_counter()
            res = h.run_batched(plans, compiled=True, pause_gc=True)
            wall = time.perf_counter() - t0
            assert res.ops_completed == total
            d = h.nvram.total_stats().minus(base_stats)
            mismatches = [
                (t, f)
                for t in range(args.threads)
                for f in columnar_stats[t].__dict__
                if getattr(h.nvram.stats[t], f) != getattr(
                    columnar_stats[t], f)
            ]
            rows.append({
                "queue": qname, "workload": args.workload,
                "model": args.model, "threads": args.threads,
                "mode": "compiled-legacy", "ops": total,
                "wall_s": round(wall, 3),
                "us_per_op": round(wall * 1e6 / total, 3),
                "post_flush_per_op": round(
                    d.post_flush_accesses / total, 3),
                "fast_ops": h.fast.fast_ops if h.fast else 0,
                "bailed_ops": h.fast.bailed_ops if h.fast else 0,
                "speedup_vs_cap": "", "speedup_same_scale": "",
                "speedup_burst": "",
            })
            print(f"fastpath/{qname}/differential,"
                  f"{wall * 1e6 / total:.3f},"
                  f"legacy_stats={'MISMATCH' if mismatches else 'identical'}")
            if mismatches:
                t, f = mismatches[0]
                failures.append(
                    f"{qname}: legacy records diverge from columnar on "
                    f"{len(mismatches)} Stats fields (first: thread {t} "
                    f"{f}: legacy={getattr(h.nvram.stats[t], f)} "
                    f"columnar={getattr(columnar_stats[t], f)})")
        if wall_compiled > args.budget_s:
            failures.append(f"{qname}: compiled run took {wall_compiled}s "
                            f"(> {args.budget_s}s budget)")
    if args.burst:
        bw = {"window": args.burst_window}
        for qname in args.burst_queues.split(","):
            burst_cell, burst_stats = {}, {}
            for label, burst in (("columnar@burst-wl", None), ("burst", bw)):
                # warm codegen caches outside timing, like `profile` cells
                hw = QueueHarness(ALL_QUEUES[qname], nthreads=args.threads,
                                  model=args.model,
                                  area_nodes=args.area_nodes)
                hw.nvram.enable_bulk_init = True
                wplans, wprefill = make_plans(args.burst_workload,
                                              args.threads, 8, seed=0)
                for i in range(wprefill):
                    hw.queue.enqueue(0, ("pre", i))
                hw.run_batched(wplans, compiled=True, pause_gc=True,
                               burst=burst)
                h = QueueHarness(ALL_QUEUES[qname], nthreads=args.threads,
                                 model=args.model,
                                 area_nodes=args.area_nodes)
                h.nvram.enable_bulk_init = True
                plans, prefill = make_plans(args.burst_workload,
                                            args.threads, ops_per_thread,
                                            seed=0)
                for i in range(prefill):
                    h.queue.enqueue(0, ("pre", i))
                base_stats = h.nvram.total_stats()
                t0 = time.perf_counter()
                res = h.run_batched(plans, compiled=True, pause_gc=True,
                                    burst=burst)
                wall = time.perf_counter() - t0
                assert res.ops_completed == total
                us = wall * 1e6 / total
                burst_cell[label] = us
                burst_stats[label] = {t: h.nvram.stats[t].snapshot()
                                      for t in range(args.threads)}
                d = h.nvram.total_stats().minus(base_stats)
                rows.append({
                    "queue": qname, "workload": args.burst_workload,
                    "model": args.model, "threads": args.threads,
                    "mode": label, "ops": total, "wall_s": round(wall, 3),
                    "us_per_op": round(us, 3),
                    "post_flush_per_op": round(
                        d.post_flush_accesses / total, 3),
                    "fast_ops": h.fast.fast_ops if h.fast else 0,
                    "bailed_ops": h.fast.bailed_ops if h.fast else 0,
                    "speedup_vs_cap": "", "speedup_same_scale": "",
                    "speedup_burst": "",
                })
                bstats = h.last_burst_stats or {}
            speedup_burst = burst_cell["columnar@burst-wl"] / \
                burst_cell["burst"]
            rows[-1]["speedup_burst"] = round(speedup_burst, 2)
            mismatches = [
                (t, f)
                for t in range(args.threads)
                for f in burst_stats["burst"][t].__dict__
                if getattr(burst_stats["burst"][t], f) != getattr(
                    burst_stats["columnar@burst-wl"][t], f)
            ]
            headline[f"fastpath-burst/{qname}/burst_us_per_op"] = \
                round(burst_cell["burst"], 4)
            headline[f"fastpath-burst/{qname}/columnar_us_per_op"] = \
                round(burst_cell["columnar@burst-wl"], 4)
            headline[f"fastpath-burst/{qname}/speedup_vs_columnar"] = \
                round(speedup_burst, 2)
            print(f"fastpath-burst/{qname}/burst,"
                  f"{burst_cell['burst']:.3f},"
                  f"columnar_us={burst_cell['columnar@burst-wl']:.3f};"
                  f"speedup_burst={speedup_burst:.2f}x;"
                  f"bursted={bstats.get('ops_bursted', 0)};"
                  f"mispredicts={bstats.get('mispredicts', 0)};"
                  f"stats={'MISMATCH' if mismatches else 'identical'}")
            if speedup_burst < args.min_speedup_burst:
                failures.append(
                    f"{qname}: burst {speedup_burst:.2f}x vs columnar < "
                    f"{args.min_speedup_burst:.1f}x required")
            if mismatches:
                t, f = mismatches[0]
                failures.append(
                    f"{qname}: burst run diverges from columnar on "
                    f"{len(mismatches)} Stats fields (first: thread {t} "
                    f"{f}: burst="
                    f"{getattr(burst_stats['burst'][t], f)} columnar="
                    f"{getattr(burst_stats['columnar@burst-wl'][t], f)})")
    if args.out:
        with open(args.out, "w", newline="") as f:
            w = csv.DictWriter(f, fieldnames=list(rows[0].keys()))
            w.writeheader()
            w.writerows(rows)
        print(f"# wrote {len(rows)} rows to {args.out}")
    _emit_manifest("fastpath-smoke", args, rows, headline,
                   wall_s=time.perf_counter() - t_run0)
    if failures:
        for msg in failures:
            print(f"# FASTPATH SMOKE FAILURE: {msg}", file=sys.stderr)
        sys.exit(1)


# `run.py fleet` CSV schema -- tests/test_docs_refs.py checks that the
# column list quoted in docs/fleet.md matches this constant.
FLEET_CSV_COLUMNS = [
    "queue", "model", "contention", "backend", "devices", "instances",
    "ops_per_instance", "total_ops", "chunk", "bails", "residents",
    "build_s", "run_s", "fleet_mops_per_s", "sim_ns_per_op",
    "fences_per_op", "post_flush_per_op", "checked", "check_ok",
]


def fleet_main(argv) -> None:
    """`run.py fleet`: queue-ops/sec across a simulated user fleet.

    Runs 10k-1M independent queue instances (one per simulated
    user/tenant, one thread each) as a single vectorized array program
    (repro.fleet): each queue x model compiled schedule is lowered to
    stacked event-count/effect arrays and driven by a vmapped lax.scan
    stepper sharded across forced XLA host devices; instances hitting a
    fast-path bail condition fall out to the real per-instance executor
    and rejoin at the next chunk boundary.  ``--check N`` re-runs N
    sampled instances per cell on independent ``run_batched`` harnesses
    and requires bit-identical Stats (every counter and ``time_ns``) --
    the fleet's correctness gate; failures exit nonzero.  One thread per
    instance means contended counts are bit-identical to uncontended
    ones (see docs/fleet.md), so ``--contention`` is a reporting axis.
    """
    ap = argparse.ArgumentParser(
        prog="run.py fleet",
        description=fleet_main.__doc__.splitlines()[0])
    ap.add_argument("--instances", type=int, default=100_000,
                    help="fleet size (default 100k; 1M is practical with "
                         "--batch)")
    ap.add_argument("--ops", type=int, default=96,
                    help="plan steps per instance (default 96)")
    ap.add_argument("--queues", default="DurableMSQ,OptUnlinkedQ,OptLinkedQ",
                    help=f"comma-separated, from {','.join(ALL_QUEUES)}")
    ap.add_argument("--models", default="optane-clwb",
                    help=f"comma-separated memory models ({','.join(MODELS)})")
    ap.add_argument("--contention", default="off",
                    help="comma-separated: off, on (reporting axis; "
                         "per-instance counts are bit-identical either way "
                         "at one thread per instance)")
    ap.add_argument("--backend",
                    choices=["auto", "numpy", "jax", "jax-opcode", "pallas"],
                    default="numpy",
                    help="numpy (default; fastest on host CPU), jax (the "
                         "sharded unrolled XLA path), jax-opcode (the "
                         "opcode-interpreting scan: depth-independent "
                         "compile), pallas (the opcode interpreter as a "
                         "Pallas chunk kernel; interpret mode off-TPU), or "
                         "auto (jax if importable)")
    ap.add_argument("--devices", type=int, default=8,
                    help="forced XLA host devices for the jax mesh")
    ap.add_argument("--chunk", type=int, default=48,
                    help="plan steps per vector chunk (bail/rejoin "
                         "granularity)")
    ap.add_argument("--batch", type=int, default=0,
                    help="instances per state batch (0 = whole fleet at "
                         "once; bound memory at 1M scale)")
    ap.add_argument("--prefill", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--check", type=int, default=0,
                    help="equivalence-check this many sampled instances per "
                         "cell against independent run_batched harnesses")
    ap.add_argument("--heartbeat", type=float, default=5.0,
                    help="seconds between fleet progress lines on stderr "
                         "(chunks done, bails, rejoins, residents, us/op "
                         "so far)")
    ap.add_argument("--quiet", action="store_true",
                    help="suppress the stderr heartbeat (tests/CI logs)")
    ap.add_argument("--out", default=None, help="CSV destination")
    ap.add_argument("--manifest", default=None,
                    help="run-manifest destination (default: alongside "
                         "--out as <stem>.manifest.json)")
    args = ap.parse_args(argv)
    from repro.fleet import (FleetConfig, check_instances,
                             ensure_host_devices, run_fleet)
    if args.backend != "numpy":
        ensure_host_devices(args.devices)
    rows, failures = [], []
    headline = {}
    t_run0 = time.perf_counter()
    print(f"# fleet: {args.instances} instances x {args.ops} ops "
          f"(backend {args.backend}, chunk {args.chunk})")
    print("name,us_per_call,derived")
    for model in args.models.split(","):
        for cont in args.contention.split(","):
            for qname in args.queues.split(","):
                cfg = FleetConfig(
                    queue=qname, model=model, instances=args.instances,
                    ops=args.ops, prefill=args.prefill, seed=args.seed,
                    chunk=args.chunk, backend=args.backend,
                    devices=args.devices, batch=args.batch, contention=cont)
                hb = None if args.quiet else Heartbeat(
                    interval_s=args.heartbeat,
                    label=f"fleet {model}/{cont}/{qname}")
                res = run_fleet(cfg, heartbeat=hb)
                agg = res.aggregate()
                total = res.total_ops
                sim_ns = agg.time_ns / total
                checked = check_ok = 0
                if args.check:
                    checks = check_instances(
                        res, sample=args.check,
                        contention=(True if cont == "on" else None))
                    checked = len(checks)
                    check_ok = sum(r["ok"] for r in checks)
                    for r in checks:
                        if not r["ok"]:
                            failures.append(
                                f"{qname}/{model}/{cont}: instance "
                                f"{r['instance']} fleet Stats != run_batched "
                                f"Stats")
                rows.append({
                    "queue": qname, "model": model, "contention": cont,
                    "backend": res.backend, "devices": res.devices,
                    "instances": args.instances,
                    "ops_per_instance": args.ops, "total_ops": total,
                    "chunk": args.chunk, "bails": res.bails,
                    "residents": res.residents,
                    "build_s": round(res.build_s, 3),
                    "run_s": round(res.run_s, 3),
                    "fleet_mops_per_s": round(res.ops_per_sec / 1e6, 3),
                    "sim_ns_per_op": round(sim_ns, 2),
                    "fences_per_op": round(agg.fences / total, 3),
                    "post_flush_per_op": round(
                        agg.post_flush_accesses / total, 3),
                    "checked": checked, "check_ok": check_ok,
                })
                print(f"fleet/{model}/{cont}/{qname},"
                      f"{res.run_s * 1e6 / total:.4f},"
                      f"mops={res.ops_per_sec / 1e6:.2f};"
                      f"sim_ns_per_op={sim_ns:.1f};"
                      f"fences_per_op={agg.fences / total:.2f};"
                      f"backend={res.backend};bails={res.bails};"
                      f"checked={check_ok}/{checked}")
                # the numpy reference keeps the legacy trajectory cell
                # name; other backends get backend-qualified cells so the
                # perf gate never compares across backends
                cell = ("wall_us_per_op" if res.backend == "numpy"
                        else f"{res.backend}_wall_us_per_op")
                headline[f"fleet/{model}/{cont}/{qname}/{cell}"] = \
                    round(res.run_s * 1e6 / total, 4)
    if args.out:
        with open(args.out, "w", newline="") as f:
            w = csv.DictWriter(f, fieldnames=FLEET_CSV_COLUMNS)
            w.writeheader()
            w.writerows(rows)
        print(f"# wrote {len(rows)} rows to {args.out}")
    _emit_manifest("fleet", args, rows, headline,
                   wall_s=time.perf_counter() - t_run0)
    if failures:
        for msg in failures:
            print(f"# FLEET CHECK FAILURE: {msg}", file=sys.stderr)
        sys.exit(1)


def fit_profiles_main(argv) -> None:
    """`run.py fit-profiles`: capture exact-scheduler traces and refit the
    learned contention profiles (benchmarks/profiles/learned.json)."""
    ap = argparse.ArgumentParser(
        prog="run.py fit-profiles",
        description="Trace the exact scheduler and fit per-queue contention "
                    "profiles (repro.trace.fit); writes the JSON the "
                    "--contention learned axis reads.")
    # all 8 queues, MSQ included: the volatile baseline gets a learned
    # profile too so every contention axis value covers every queue
    ap.add_argument("--queues", default=",".join(ALL_QUEUES))
    ap.add_argument("--threads", default="2,4,8,12",
                    help="thread counts to trace (default 2,4,8,12: the "
                         "12-thread sample anchors the extrapolation "
                         "region; exact runs get slow past 12)")
    ap.add_argument("--ops", type=int, default=24,
                    help="ops per thread per trace (default 24)")
    ap.add_argument("--seed", type=int, default=1)
    ap.add_argument("--model", default="optane-clwb")
    ap.add_argument("--trace-out", default=None,
                    help="also save the captured traces to this directory")
    ap.add_argument("--out",
                    default=os.path.join(os.path.dirname(__file__),
                                         "profiles", "learned.json"),
                    help="profile JSON destination (default: the checked-in "
                         "benchmarks/profiles/learned.json)")
    args = ap.parse_args(argv)
    from repro.trace.fit import fit_all, save_profiles
    profiles = fit_all(
        args.queues.split(","),
        thread_counts=[int(t) for t in args.threads.split(",")],
        ops_per_thread=args.ops, seed=args.seed, model=args.model,
        trace_dir=args.trace_out, log=print)
    os.makedirs(os.path.dirname(os.path.abspath(args.out)), exist_ok=True)
    save_profiles(args.out, profiles)
    print(f"# wrote learned profiles for {len(profiles)} queues "
          f"to {args.out}")


def crash_sweep_main(argv) -> None:
    """`run.py crash-sweep`: durable linearizability at every scheduler
    step, via the snapshot/restore crash engine (repro.crash).  Emits the
    coverage/recovery-cost CSV (--out) and, on violations, one repro
    artifact per failure (--artifacts-dir) before exiting nonzero."""
    from repro.crash.__main__ import sweep_main
    rc = sweep_main(argv)
    if rc:
        sys.exit(rc)


# Execution phases the `profile` subcommand reports for run_batched cells
# (see repro.obs.profiler); CSV columns replace '-' with '_'.
EXEC_PHASES = ("heap-loop", "interpreted-body", "record-charging",
               "bookkeeping", "bail-real-op")
BURST_PHASES = ("burst-predict", "burst-verify", "burst-vector-apply",
                "mispredict-replay")
FLEET_PHASES = ("lowering", "chunk-step", "poll", "bail-replay",
                "resident-replay")
CRASH_PHASES = ("capture", "restore", "recover", "check")


def _phase_cols(per, names):
    """{phase -> value} -> ordered (column, value) pairs for CSV rows."""
    return [(ph.replace("-", "_") + "_us", round(per.get(ph, 0.0), 4))
            for ph in names]


def profile_main(argv) -> None:
    """`run.py profile`: per-phase µs/op attribution across the layers.

    For every queue x model cell, runs the standard workload under an
    attached :class:`repro.obs.PhaseProfiler` and prints where each
    microsecond goes: ``heap-loop`` (dispatch + cursor bookkeeping),
    ``interpreted-body`` (the compiled per-op fns -- the interpreted
    Python the vectorized-burst roadmap item targets), ``record-charging``
    (the columnar store's staged-burst sync passes), ``bookkeeping``
    (setup/teardown) and ``bail-real-op`` (real per-primitive fallbacks).
    The phase sum is within 10% of wall time by construction (gap-free
    scoped timers); a coverage outside [0.9, 1.1] prints a warning.

    ``--sections burst`` reruns the cells with the vectorized burst
    executor attached (``run_batched(burst=...)``) and adds its phase
    group: ``burst-predict`` (heap simulation as segmented cumsums),
    ``burst-verify`` (key comparison against the prediction),
    ``burst-vector-apply`` (bulk memory effects + staged records) and
    ``mispredict-replay`` (bounded columnar replay of rejected
    stretches).  ``--sections fleet`` and ``--sections crash`` add the
    fleet runner (lowering / chunk-step / poll / bail-replay /
    resident-replay) and crash-sweep recovery (capture / restore /
    recover / check) phase breakdowns.  Each cell does a small warmup
    run first so codegen and cache fills are not attributed to the
    measured phases.
    """
    ap = argparse.ArgumentParser(
        prog="run.py profile",
        description=profile_main.__doc__.splitlines()[0])
    ap.add_argument("--queues", default=",".join(ALL_QUEUES),
                    help="comma-separated (default: all 8 queues)")
    ap.add_argument("--models", default="optane-clwb",
                    help=f"comma-separated memory models ({','.join(MODELS)})")
    ap.add_argument("--threads", type=int, default=4)
    ap.add_argument("--ops", type=int, default=2000, help="ops per thread")
    ap.add_argument("--workload", default="mixed5050")
    ap.add_argument("--area-nodes", type=int, default=1024)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--sections", default="exec",
                    help="comma-separated: exec (run_batched phases), "
                         "burst (run_batched with the burst executor: "
                         "predict/verify/vector-apply/mispredict-replay), "
                         "fleet (fleet-runner phases), crash (crash-sweep "
                         "recovery phases)")
    ap.add_argument("--burst-window", type=int, default=32768,
                    help="burst window for --sections burst cells")
    ap.add_argument("--fleet-instances", type=int, default=2000)
    ap.add_argument("--fleet-ops", type=int, default=48)
    ap.add_argument("--crash-ops", type=int, default=2,
                    help="enqueues per thread for the crash-profile cell")
    ap.add_argument("--out", default=None, help="CSV destination")
    ap.add_argument("--manifest", default=None,
                    help="run-manifest destination (default: alongside "
                         "--out as <stem>.manifest.json)")
    args = ap.parse_args(argv)
    sections = set(args.sections.split(","))
    unknown = sections - {"exec", "burst", "fleet", "crash"}
    if unknown:
        ap.error(f"unknown --sections {sorted(unknown)}")
    queues = args.queues.split(",")
    models = args.models.split(",")
    rows, headline = [], {}
    all_phases = PhaseProfiler()
    t_run0 = time.perf_counter()
    print(f"# profile: per-phase us/op ({args.workload} x {args.threads} "
          f"threads x {args.ops} ops/thread; sections "
          f"{','.join(sorted(sections))})")
    print("name,us_per_call,derived")
    if "exec" in sections:
        for model in models:
            for qname in queues:
                # warmup: executor codegen + numpy caches, outside timing
                hw = QueueHarness(ALL_QUEUES[qname], nthreads=args.threads,
                                  model=model, area_nodes=args.area_nodes)
                wplans, wprefill = make_plans(args.workload, args.threads,
                                              8, seed=args.seed)
                for i in range(wprefill):
                    hw.queue.enqueue(0, ("pre", i))
                hw.run_batched(wplans)
                h = QueueHarness(ALL_QUEUES[qname], nthreads=args.threads,
                                 model=model, area_nodes=args.area_nodes)
                plans, prefill = make_plans(args.workload, args.threads,
                                            args.ops, seed=args.seed)
                for i in range(prefill):
                    h.queue.enqueue(0, ("pre", i))
                prof = PhaseProfiler()
                t0 = time.perf_counter()
                res = h.run_batched(plans, profile=prof)
                wall = time.perf_counter() - t0
                n = res.ops_completed
                per = prof.us_per_op(n)
                cov = prof.coverage(wall)
                us = wall * 1e6 / max(n, 1)
                row = {"section": "exec", "queue": qname, "model": model,
                       "threads": args.threads, "ops": n,
                       "wall_s": round(wall, 4), "us_per_op": round(us, 4),
                       "coverage": round(cov, 4),
                       "fast_ops": h.fast.fast_ops if h.fast else 0,
                       "bailed_ops": h.fast.bailed_ops if h.fast else 0}
                row.update(_phase_cols(per, EXEC_PHASES))
                rows.append(row)
                derived = ";".join(
                    f"{c}={v}" for c, v in _phase_cols(per, EXEC_PHASES))
                print(f"profile/{model}/{qname},{us:.3f},"
                      f"{derived};coverage={cov:.3f}")
                if not 0.9 <= cov <= 1.1:
                    print(f"# profile WARNING: {model}/{qname} phase sum "
                          f"covers {cov:.2f}x of wall time "
                          f"(expected within 10%)", file=sys.stderr)
                headline[f"profile/{model}/{qname}/us_per_op"] = \
                    round(us, 4)
                all_phases.merge(prof)
    if "burst" in sections:
        bw = {"window": args.burst_window}
        for model in models:
            for qname in queues:
                hw = QueueHarness(ALL_QUEUES[qname], nthreads=args.threads,
                                  model=model, area_nodes=args.area_nodes)
                wplans, wprefill = make_plans(args.workload, args.threads,
                                              8, seed=args.seed)
                for i in range(wprefill):
                    hw.queue.enqueue(0, ("pre", i))
                hw.run_batched(wplans, burst=bw)
                h = QueueHarness(ALL_QUEUES[qname], nthreads=args.threads,
                                 model=model, area_nodes=args.area_nodes)
                plans, prefill = make_plans(args.workload, args.threads,
                                            args.ops, seed=args.seed)
                for i in range(prefill):
                    h.queue.enqueue(0, ("pre", i))
                prof = PhaseProfiler()
                t0 = time.perf_counter()
                res = h.run_batched(plans, profile=prof, burst=bw)
                wall = time.perf_counter() - t0
                n = res.ops_completed
                per = prof.us_per_op(n)
                cov = prof.coverage(wall)
                us = wall * 1e6 / max(n, 1)
                bs = h.last_burst_stats or {}
                row = {"section": "burst", "queue": qname, "model": model,
                       "threads": args.threads, "ops": n,
                       "wall_s": round(wall, 4), "us_per_op": round(us, 4),
                       "coverage": round(cov, 4),
                       "burst_commits": bs.get("commits", 0),
                       "burst_mispredicts": bs.get("mispredicts", 0),
                       "burst_rejects": bs.get("rejects", 0),
                       "ops_bursted": bs.get("ops_bursted", 0),
                       "replayed_ops": bs.get("replayed_ops", 0)}
                row.update(_phase_cols(per, EXEC_PHASES + BURST_PHASES))
                rows.append(row)
                derived = ";".join(
                    f"{c}={v}" for c, v in _phase_cols(per, BURST_PHASES))
                print(f"profile-burst/{model}/{qname},{us:.3f},"
                      f"{derived};bursted={bs.get('ops_bursted', 0)};"
                      f"coverage={cov:.3f}")
                if not 0.9 <= cov <= 1.1:
                    print(f"# profile WARNING: burst {model}/{qname} phase "
                          f"sum covers {cov:.2f}x of wall time "
                          f"(expected within 10%)", file=sys.stderr)
                headline[f"profile-burst/{model}/{qname}/us_per_op"] = \
                    round(us, 4)
                all_phases.merge(prof)
    if "fleet" in sections:
        from repro.fleet import FleetConfig, run_fleet
        for model in models:
            for qname in queues:
                cfg = FleetConfig(queue=qname, model=model,
                                  instances=args.fleet_instances,
                                  ops=args.fleet_ops, seed=args.seed,
                                  backend="numpy")
                prof = PhaseProfiler()
                t0 = time.perf_counter()
                res = run_fleet(cfg, profile=prof)
                wall = time.perf_counter() - t0
                n = res.total_ops
                per = prof.us_per_op(n)
                cov = prof.coverage(wall)
                us = res.run_s * 1e6 / n
                row = {"section": "fleet", "queue": qname, "model": model,
                       "threads": 1, "ops": n, "wall_s": round(wall, 4),
                       "us_per_op": round(us, 4), "coverage": round(cov, 4),
                       "fast_ops": 0, "bailed_ops": res.bails}
                row.update(_phase_cols(per, FLEET_PHASES))
                rows.append(row)
                derived = ";".join(
                    f"{c}={v}" for c, v in _phase_cols(per, FLEET_PHASES))
                print(f"profile-fleet/{model}/{qname},{us:.4f},"
                      f"{derived};coverage={cov:.3f}")
                headline[f"profile-fleet/{model}/{qname}/us_per_op"] = \
                    round(us, 4)
                all_phases.merge(prof)
    if "crash" in sections:
        from repro.crash.sweep import sweep_queue
        for model in models:
            for qname in queues:
                if qname not in DURABLE_QUEUES:
                    continue   # the volatile baseline has no recovery
                prof = PhaseProfiler()
                t0 = time.perf_counter()
                r = sweep_queue(qname, per_thread=args.crash_ops,
                                model=model, profile=prof)
                wall = time.perf_counter() - t0
                cov_info = r.coverage()
                checks = max(cov_info["crashes_checked"], 1)
                per = prof.us_per_op(checks)   # us per recovery check
                cov = prof.coverage(wall)
                us = cov_info["recovery_us_total"] / checks
                row = {"section": "crash", "queue": qname, "model": model,
                       "threads": 3, "ops": checks,
                       "wall_s": round(wall, 4), "us_per_op": round(us, 4),
                       "coverage": round(cov, 4),
                       "fast_ops": 0, "bailed_ops": 0}
                row.update(_phase_cols(per, CRASH_PHASES))
                rows.append(row)
                derived = ";".join(
                    f"{c}={v}" for c, v in _phase_cols(per, CRASH_PHASES))
                print(f"profile-crash/{model}/{qname},{us:.3f},"
                      f"{derived};coverage={cov:.3f}")
                headline[f"profile-crash/{model}/{qname}"
                         f"/recoveries_per_s"] = round(1e6 / max(us, 1e-9), 2)
                all_phases.merge(prof)
    if args.out and rows:
        fieldnames = []
        for r in rows:
            for k in r:
                if k not in fieldnames:
                    fieldnames.append(k)
        with open(args.out, "w", newline="") as f:
            w = csv.DictWriter(f, fieldnames=fieldnames, restval="")
            w.writeheader()
            w.writerows(rows)
        print(f"# wrote {len(rows)} rows to {args.out}")
    _emit_manifest("profile", args, rows, headline,
                   phases=all_phases.as_dict(),
                   wall_s=time.perf_counter() - t_run0)


def main(argv=None) -> None:
    argv = sys.argv[1:] if argv is None else list(argv)
    if argv and argv[0] == "fit-profiles":
        return fit_profiles_main(argv[1:])
    if argv and argv[0] == "crash-sweep":
        return crash_sweep_main(argv[1:])
    if argv and argv[0] == "fastpath-smoke":
        return fastpath_smoke_main(argv[1:])
    if argv and argv[0] == "fleet":
        return fleet_main(argv[1:])
    if argv and argv[0] == "profile":
        return profile_main(argv[1:])
    args = parse_args(argv)
    threads = sorted({int(t) for t in args.threads.split(",")})
    models = args.models.split(",")
    workloads = args.workloads.split(",")
    queues = args.queues.split(",")
    contention = args.contention.split(",")
    if args.engine == "exact":
        contention = ["off"]   # exact runs contend natively; one column
    sections = set(args.sections.split(","))
    rows = []
    t_run0 = time.perf_counter()
    if "b1" in sections:
        rows += bench_fig2(args.ops, threads, models, workloads, queues,
                           args.engine, contention,
                           trace_out=args.trace_out)
    if "b2" in sections:
        rows += bench_persist_counts(args.ops, models, queues, args.engine,
                                     contention, trace_out=args.trace_out)
    if "b3" in sections:
        bench_onll(args.ops)
    if "b4" in sections:
        bench_roofline()
    if args.out:
        if rows:
            with open(args.out, "w", newline="") as f:
                w = csv.DictWriter(f, fieldnames=list(rows[0].keys()))
                w.writeheader()
                w.writerows(rows)
            print(f"\n# wrote {len(rows)} rows to {args.out}")
        else:
            print(f"\n# warning: no CSV rows produced (sections "
                  f"{sorted(sections)} emit none); {args.out} not written")
    # simulated per-op latencies are deterministic, so headline cells
    # only move when the cost model (or a queue's schedule) changes --
    # exactly the drift the manifest trajectory should record
    headline = {}
    for r in rows:
        headline[f"{r['workload']}/{r['model']}/{r['contention']}"
                 f"/t{r['threads']}/{r['queue']}/us_per_op_sim"] = \
            round(r["us_per_op"], 4)
    extra = None
    if args.trace_out:
        attribution = _trace_attribution(args.trace_out)
        if attribution:
            extra = {"post_flush_attribution": attribution}
    _emit_manifest("bench", args, rows, headline,
                   wall_s=time.perf_counter() - t_run0, extra=extra)


if __name__ == "__main__":
    main()
