"""Benchmark harness -- one section per paper table/figure.

  B1 (Fig. 2): five workloads x queue x thread count -> simulated throughput
  B2 (§5/§6 accounting): fences/op + post-flush accesses/op per queue
  B3 (§2.1): ONLL upper-bound construction accounting
  B4 (assignment): roofline terms per (arch x shape x mesh) from the
      dry-run artifacts (benchmarks/dryrun_results.jsonl if present)

Prints ``name,us_per_call,derived`` CSV lines per the harness contract.
"""
from __future__ import annotations

import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core import ALL_QUEUES, NVRAM, ONLL  # noqa: E402
from benchmarks.workloads import run_workload   # noqa: E402

DURABLE = ["DurableMSQ", "IzraelevitzQ", "NVTraverseQ", "UnlinkedQ",
           "LinkedQ", "OptUnlinkedQ", "OptLinkedQ"]
WORKLOADS = ["mixed5050", "pairs", "producers", "consumers", "prodcons"]


def bench_fig2(ops_per_thread: int = 60) -> list:
    rows = []
    print("# B1: Fig.2 workloads (simulated Optane latency model)")
    print("name,us_per_call,derived")
    for wl in WORKLOADS:
        threads = [1, 2, 4, 8] if wl == "mixed5050" else [1, 8]
        for nt in threads:
            for q in DURABLE:
                r = run_workload(q, wl, nt, ops_per_thread)
                rows.append(r)
                print(f"fig2/{wl}/t{nt}/{q},{r['us_per_op']:.3f},"
                      f"mops={r['mops_per_s']:.3f}")
    return rows


def bench_persist_counts() -> list:
    print("\n# B2: persist-op accounting (200 ops, single thread)")
    print("name,us_per_call,derived")
    rows = []
    for q in DURABLE:
        r = run_workload(q, "pairs", 1, 200)
        rows.append(r)
        print(f"counts/{q},{r['us_per_op']:.3f},"
              f"fences_per_op={r['fences_per_op']:.2f};"
              f"post_flush_per_op={r['post_flush_per_op']:.2f}")
    return rows


def bench_onll() -> None:
    print("\n# B3: ONLL universal construction (upper bound, §2.1)")
    print("name,us_per_call,derived")
    nv = NVRAM(1)
    obj = ONLL(nv, 1, lambda s, o: (s + o, s + o), 0)
    base = nv.total_stats()
    n = 200
    for i in range(n):
        obj.update(0, 1)
    d = nv.total_stats().minus(base)
    print(f"onll/update,{d.time_ns / n / 1e3:.3f},"
          f"fences_per_op={d.fences / n:.2f};"
          f"post_flush_per_op={d.post_flush_accesses / n:.2f}")


def bench_roofline(path: str = None) -> None:
    base = os.path.dirname(__file__)
    merged = os.path.join(base, "dryrun_merged.jsonl")
    path = path or (merged if os.path.exists(merged)
                    else os.path.join(base, "dryrun_results.jsonl"))
    print("\n# B4: roofline terms from the multi-pod dry-run")
    if not os.path.exists(path):
        print(f"(no dry-run artifacts at {path}; run "
              "`python -m repro.launch.dryrun` first)")
        return
    print("name,us_per_call,derived")
    from benchmarks.roofline import load_cells, roofline_terms
    for cell in load_cells(path):
        t = roofline_terms(cell)
        if t is None:
            print(f"roofline/{cell['arch']}/{cell['shape']}/{cell['mesh']},"
                  f"nan,error={cell.get('error', '?')[:60]}")
            continue
        dom = t["bottleneck"]
        print(f"roofline/{cell['arch']}/{cell['shape']}/{cell['mesh']},"
              f"{t['step_us']:.1f},"
              f"compute_ms={t['compute_ms']:.2f};mem_ms={t['memory_ms']:.2f};"
              f"coll_ms={t['collective_ms']:.2f};bound={dom};"
              f"useful={t['useful_ratio']:.2f};"
              f"roofline_frac={t['roofline_fraction']:.3f}")


def main() -> None:
    bench_fig2()
    bench_persist_counts()
    bench_onll()
    bench_roofline()


if __name__ == "__main__":
    main()
