"""Merge dry-run artifact files: the LAST record per (arch, shape, mesh)
wins (later runs supersede earlier failures/retries)."""
from __future__ import annotations

import glob
import json


def merge(paths, out):
    best = {}
    order = []
    for p in paths:
        try:
            with open(p) as f:
                for line in f:
                    line = line.strip()
                    if not line:
                        continue
                    r = json.loads(line)
                    key = (r["arch"], r["shape"], r["mesh"])
                    if key not in best:
                        order.append(key)
                    # prefer ok records; otherwise latest
                    if key in best and best[key].get("ok") and not r.get("ok"):
                        continue
                    best[key] = r
        except FileNotFoundError:
            pass
    with open(out, "w") as f:
        for key in order:
            f.write(json.dumps(best[key]) + "\n")
    return best


if __name__ == "__main__":
    paths = sorted(glob.glob("benchmarks/dryrun_results*.jsonl"))
    out = "benchmarks/dryrun_merged.jsonl"
    best = merge(paths, out)
    ok = sum(1 for r in best.values() if r.get("ok"))
    print(f"merged {len(best)} cells ({ok} ok) from {paths} -> {out}")
