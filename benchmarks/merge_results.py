"""Merge dry-run artifact files: the LAST record per (arch, shape, mesh)
wins (later runs supersede earlier failures/retries)."""
from __future__ import annotations

import argparse
import glob
import json


def merge(paths, out):
    best = {}
    order = []
    for p in paths:
        try:
            with open(p) as f:
                for line in f:
                    line = line.strip()
                    if not line:
                        continue
                    r = json.loads(line)
                    key = (r["arch"], r["shape"], r["mesh"])
                    if key not in best:
                        order.append(key)
                    # prefer ok records; otherwise latest
                    if key in best and best[key].get("ok") and not r.get("ok"):
                        continue
                    best[key] = r
        except FileNotFoundError:
            pass
    with open(out, "w") as f:
        for key in order:
            f.write(json.dumps(best[key]) + "\n")
    return best


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="merge_results.py",
        description="Merge dry-run JSONL artifacts; last record per "
                    "(arch, shape, mesh) wins, ok records preferred.")
    ap.add_argument("inputs", nargs="*",
                    help="JSONL files to merge (default: glob "
                         "benchmarks/dryrun_results*.jsonl)")
    ap.add_argument("--out", default="benchmarks/dryrun_merged.jsonl",
                    help="merged JSONL destination")
    args = ap.parse_args(argv)
    paths = args.inputs or sorted(glob.glob("benchmarks/dryrun_results*.jsonl"))
    best = merge(paths, args.out)
    ok = sum(1 for r in best.values() if r.get("ok"))
    print(f"merged {len(best)} cells ({ok} ok) from {paths} -> {args.out}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
