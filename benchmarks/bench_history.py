"""Perf-trajectory gate: fold run manifests into BENCH_<pr>.json, compare.

Every ``benchmarks/run.py`` subcommand writes a run manifest
(``repro.obs.manifest``) whose flat ``headline`` dict holds the cells
worth tracking across PRs (``fastpath/<q>/compiled_us_per_op``,
``fleet/<model>/<cont>/<q>/wall_us_per_op``,
``crash-sweep/recoveries_per_s``, ...).  This tool maintains the
committed trajectory under ``benchmarks/history/``:

``fold``
    merge one or more manifests' headline cells into a snapshot::

        python benchmarks/bench_history.py fold --pr 8 \\
            --out benchmarks/history/BENCH_8.json fp.manifest.json ...

``compare``
    gate fresh manifests against a baseline snapshot: **fail** (exit 1)
    on a >25% per-op regression in any shared cell, **warn** on >10%
    (thresholds via ``--fail-pct`` / ``--warn-pct``; ``--baseline auto``
    picks the newest ``BENCH_*.json``).  Direction-aware: ``*_us_per_op``
    cells regress upward, ``*_per_s`` / ``*_speedup*`` cells regress
    downward.  Cells present on only one side are reported but never
    gate -- a retired queue or a new metric must not break CI.

CI runs ``compare`` in the fastpath-smoke and fleet-smoke jobs (the
baseline-relative replacement for hand-pinned thresholds); a PR that
intentionally shifts performance re-folds and commits a new snapshot.
Wall-clock cells measured on different hosts drift -- compare prints an
env note when baseline and current hostnames differ.
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import sys
import time
from typing import Dict, List, Optional, Tuple

from repro.obs.manifest import (ManifestError, collect_env, collect_git,
                                load_manifest)

SNAPSHOT_SCHEMA = "repro.obs.bench-history/v1"
HISTORY_DIR = os.path.join(os.path.dirname(__file__), "history")

# Cells where bigger is better (everything else regresses upward).
_HIGHER_BETTER_SUFFIXES = ("_per_s", "_mops", "speedup", "_speedup_vs_cap",
                           "_speedup_same_scale")


def is_higher_better(key: str) -> bool:
    tail = key.rsplit("/", 1)[-1]
    return any(tail.endswith(s) or s in tail
               for s in _HIGHER_BETTER_SUFFIXES)


def regression_pct(key: str, base: float, cur: float) -> float:
    """Signed regression percentage for a cell: positive = worse.

    Lower-is-better cells (``*_us_per_op``): (cur - base) / base.
    Higher-is-better cells (``*_per_s``, speedups): (base - cur) / base.
    """
    if base == 0:
        return 0.0
    if is_higher_better(key):
        return (base - cur) / abs(base) * 100.0
    return (cur - base) / abs(base) * 100.0


def validate_snapshot(snap) -> dict:
    problems = []
    if not isinstance(snap, dict):
        raise ManifestError("snapshot must be a dict")
    if snap.get("schema") != SNAPSHOT_SCHEMA:
        problems.append(f"schema must be {SNAPSHOT_SCHEMA!r}, "
                        f"got {snap.get('schema')!r}")
    if not isinstance(snap.get("pr"), int):
        problems.append("pr must be an int")
    cells = snap.get("cells")
    if not isinstance(cells, dict) or any(
            not isinstance(k, str) or isinstance(v, bool)
            or not isinstance(v, (int, float)) for k, v in (cells or {}).items()):
        problems.append("cells must be a dict of str -> number")
    if problems:
        raise ManifestError("invalid snapshot: " + "; ".join(problems))
    return snap


def load_snapshot(path: str) -> dict:
    with open(path) as fh:
        snap = json.load(fh)
    try:
        return validate_snapshot(snap)
    except ManifestError as e:
        raise ManifestError(f"{path}: {e}") from None


def latest_snapshot_path(history_dir: str = HISTORY_DIR) -> Optional[str]:
    """Newest committed BENCH_<pr>.json by PR number, or None."""
    best, best_pr = None, -1
    for path in glob.glob(os.path.join(history_dir, "BENCH_*.json")):
        stem = os.path.basename(path)[len("BENCH_"):-len(".json")]
        try:
            pr = int(stem)
        except ValueError:
            continue
        if pr > best_pr:
            best, best_pr = path, pr
    return best


def fold(manifest_paths: List[str], pr: int,
         note: str = "") -> Tuple[dict, List[str]]:
    """Merge manifests' headline cells into one snapshot.  Later manifests
    win on duplicate keys; returns (snapshot, duplicate-key warnings)."""
    cells: Dict[str, float] = {}
    sources, warnings = [], []
    for path in manifest_paths:
        man = load_manifest(path)
        for key, val in man["headline"].items():
            if key in cells and cells[key] != val:
                warnings.append(
                    f"duplicate cell {key!r}: {cells[key]} -> {val} "
                    f"(from {os.path.basename(path)})")
            cells[key] = float(val)
        sources.append({"path": os.path.basename(path),
                        "subcommand": man["subcommand"],
                        "created_unix": man["created_unix"]})
    snap = {
        "schema": SNAPSHOT_SCHEMA,
        "pr": pr,
        "created_unix": time.time(),
        "git": collect_git(),
        "env": collect_env(),
        "note": note,
        "sources": sources,
        "cells": dict(sorted(cells.items())),
    }
    return validate_snapshot(snap), warnings


def compare(baseline: dict, manifest_paths: List[str],
            fail_pct: float = 25.0, warn_pct: float = 10.0) -> dict:
    """Compare fresh manifests' headline cells against a baseline snapshot.

    Returns {"rows": [...], "fails": n, "warns": n, "only_base": [...],
    "only_current": [...]}; each row is (status, key, base, cur, pct)."""
    current: Dict[str, float] = {}
    for path in manifest_paths:
        for key, val in load_manifest(path)["headline"].items():
            current[key] = float(val)
    base_cells = baseline["cells"]
    rows, fails, warns = [], 0, 0
    for key in sorted(set(current) & set(base_cells)):
        pct = regression_pct(key, base_cells[key], current[key])
        if pct > fail_pct:
            status, fails = "FAIL", fails + 1
        elif pct > warn_pct:
            status, warns = "WARN", warns + 1
        else:
            status = "ok"
        rows.append((status, key, base_cells[key], current[key], pct))
    return {
        "rows": rows, "fails": fails, "warns": warns,
        "only_base": sorted(set(base_cells) - set(current)),
        "only_current": sorted(set(current) - set(base_cells)),
    }


def fold_main(argv) -> int:
    ap = argparse.ArgumentParser(
        prog="bench_history.py fold",
        description="Fold run manifests into a BENCH_<pr>.json snapshot.")
    ap.add_argument("manifests", nargs="+", help="*.manifest.json inputs")
    ap.add_argument("--pr", type=int, required=True,
                    help="PR number the snapshot captures")
    ap.add_argument("--out", default=None,
                    help="snapshot path (default: "
                         "benchmarks/history/BENCH_<pr>.json)")
    ap.add_argument("--note", default="",
                    help="free-form provenance note stored in the snapshot")
    args = ap.parse_args(argv)
    out = args.out or os.path.join(HISTORY_DIR, f"BENCH_{args.pr}.json")
    snap, warnings = fold(args.manifests, args.pr, note=args.note)
    for w in warnings:
        print(f"# fold warning: {w}", file=sys.stderr)
    os.makedirs(os.path.dirname(os.path.abspath(out)), exist_ok=True)
    with open(out, "w") as fh:
        json.dump(snap, fh, indent=2)
        fh.write("\n")
    print(f"# wrote {len(snap['cells'])} cells from "
          f"{len(args.manifests)} manifest(s) to {out}")
    return 0


def write_summary(path: str, baseline_name: str, baseline_pr,
                  res: dict) -> None:
    """Append the per-cell delta table as GitHub-flavored markdown (the
    format ``$GITHUB_STEP_SUMMARY`` renders in the job summary)."""
    lines = [
        f"### Perf trajectory vs `{baseline_name}` (PR {baseline_pr})",
        "",
        "| status | cell | baseline | current | Δ% |",
        "|---|---|---:|---:|---:|",
    ]
    for status, key, base, cur, pct in res["rows"]:
        mark = {"FAIL": "❌ FAIL", "WARN": "⚠️ WARN"}.get(status, "✅ ok")
        lines.append(f"| {mark} | `{key}` | {base:.4g} | {cur:.4g} "
                     f"| {pct:+.1f}% |")
    for key in res["only_base"]:
        lines.append(f"| gone | `{key}` | — | — | not gated |")
    for key in res["only_current"]:
        lines.append(f"| new | `{key}` | — | — | not gated |")
    lines.append("")
    lines.append(f"{len(res['rows'])} cells compared: {res['fails']} fail, "
                 f"{res['warns']} warn")
    lines.append("")
    with open(path, "a") as fh:
        fh.write("\n".join(lines) + "\n")


def compare_main(argv) -> int:
    ap = argparse.ArgumentParser(
        prog="bench_history.py compare",
        description="Gate fresh manifests against a BENCH_<pr>.json "
                    "baseline (fail >25%% per-op regression, warn >10%%).")
    ap.add_argument("manifests", nargs="+", help="*.manifest.json inputs")
    ap.add_argument("--baseline", default="auto",
                    help="baseline snapshot path, or 'auto' for the newest "
                         "benchmarks/history/BENCH_*.json")
    ap.add_argument("--fail-pct", type=float, default=25.0,
                    help="regression %% that fails the gate (default 25)")
    ap.add_argument("--warn-pct", type=float, default=10.0,
                    help="regression %% that warns (default 10)")
    ap.add_argument("--summary", default=os.environ.get(
                        "GITHUB_STEP_SUMMARY"),
                    help="append the delta table as markdown to this file "
                         "(default: $GITHUB_STEP_SUMMARY when set, so CI "
                         "shows it in the job summary)")
    args = ap.parse_args(argv)
    path = args.baseline
    if path == "auto":
        path = latest_snapshot_path()
        if path is None:
            print("# no BENCH_*.json under benchmarks/history/ -- "
                  "nothing to compare against", file=sys.stderr)
            return 0
    baseline = load_snapshot(path)
    res = compare(baseline, args.manifests,
                  fail_pct=args.fail_pct, warn_pct=args.warn_pct)
    print(f"# baseline {os.path.basename(path)} (PR {baseline['pr']}, "
          f"sha {str(baseline['git'].get('sha'))[:9]})")
    cur_host = collect_env()["hostname"]
    base_host = baseline.get("env", {}).get("hostname")
    if base_host and base_host != cur_host:
        print(f"# note: baseline measured on {base_host!r}, this run on "
              f"{cur_host!r} -- absolute wall-clock cells may drift")
    for status, key, base, cur, pct in res["rows"]:
        print(f"{status:<4} {key}  base={base:.4g} cur={cur:.4g} "
              f"({pct:+.1f}%)")
    for key in res["only_base"]:
        print(f"gone {key}  (in baseline only; not gated)")
    for key in res["only_current"]:
        print(f"new  {key}  (no baseline; not gated)")
    print(f"# {len(res['rows'])} cells compared: {res['fails']} fail, "
          f"{res['warns']} warn "
          f"(fail >{args.fail_pct:g}%, warn >{args.warn_pct:g}%)")
    if args.summary:
        write_summary(args.summary, os.path.basename(path), baseline["pr"],
                      res)
    return 1 if res["fails"] else 0


def show_main(argv) -> int:
    ap = argparse.ArgumentParser(
        prog="bench_history.py show",
        description="Print a snapshot's cells (default: the newest).")
    ap.add_argument("snapshot", nargs="?", default=None)
    args = ap.parse_args(argv)
    path = args.snapshot or latest_snapshot_path()
    if path is None:
        print("# no BENCH_*.json under benchmarks/history/", file=sys.stderr)
        return 2
    snap = load_snapshot(path)
    print(f"# {os.path.basename(path)}: PR {snap['pr']}, "
          f"sha {str(snap['git'].get('sha'))[:9]}, "
          f"{len(snap['cells'])} cells")
    for key, val in snap["cells"].items():
        print(f"{key} = {val:.4g}")
    return 0


def main(argv=None) -> int:
    argv = sys.argv[1:] if argv is None else list(argv)
    cmds = {"fold": fold_main, "compare": compare_main, "show": show_main}
    if not argv or argv[0] not in cmds:
        print(__doc__.strip(), file=sys.stderr)
        return 2
    return cmds[argv[0]](argv[1:])


if __name__ == "__main__":
    sys.exit(main())
