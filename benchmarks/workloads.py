"""Paper §10 workload definitions over the simulated-NVRAM queues.

Five workloads following Figure 2:
  * ``mixed5050``   -- each op uniformly enqueue/dequeue (initial size 10)
  * ``pairs``       -- each thread runs enqueue-dequeue pairs
  * ``producers``   -- enqueues only, starting from empty
  * ``consumers``   -- dequeues only, from a pre-filled queue
  * ``prodcons``    -- 1/4 of threads dequeue-then-enqueue blocks, the rest
                       enqueue-then-dequeue (queue never drains)

Each run is parameterized by a **memory model** (``optane-clwb`` / ``eadr``
/ ``cxl``; see :mod:`repro.core.memmodel`) and an **engine**:

  * ``batched`` (default) -- the clock-driven op-granularity executor over
    the array-backed cost engine; thousands of ops/thread across 1..64
    threads are practical;
  * ``exact``   -- the OS-thread, per-primitive interleaving scheduler the
    crash/linearizability tests use (slow; seed-era op counts only).

Batched runs take a **contention** setting (``off`` / ``on`` /
``learned`` / a float ``retry_scale``): ``on`` attaches the calibrated
:class:`repro.core.contention.ContentionModel`, charging CAS-retry and
helping-path costs for co-scheduled ops; ``learned`` swaps the hand-fit
per-queue retry profiles for the trace-fitted ones checked in at
``benchmarks/profiles/learned.json`` (see :mod:`repro.trace.fit`; refit
with ``python benchmarks/run.py fit-profiles``).  Exact runs report
``native`` -- their retries really execute, which is what the model is
calibrated against.

Throughput is simulated time (per-thread latency-model clocks; see
repro.core.nvram for constants + citations): ops / max(thread clock).  The
paper's claims are about *orderings and ratios*, which is what these
reproduce.
"""
from __future__ import annotations

import os
import random
from typing import Dict, List, Tuple

from repro.core import (ALL_QUEUES, ContentionModel, QueueHarness,
                        get_memory_model)

# the checked-in trace-fitted contention profiles (see repro.trace.fit)
LEARNED_PROFILES_PATH = os.path.join(os.path.dirname(__file__), "profiles",
                                     "learned.json")
_learned_cache: dict = {}


def _plan_5050(tid: int, n_ops: int, seed: int):
    rng = random.Random(seed * 7919 + tid)
    plan = []
    for i in range(n_ops):
        if rng.random() < 0.5:
            plan.append(("enq", (tid, i)))
        else:
            plan.append(("deq", None))
    return plan


def make_plans(workload: str, nthreads: int, ops_per_thread: int,
               seed: int = 0) -> Tuple[List[list], int]:
    """Returns (plans, prefill) -- prefill items are enqueued before timing."""
    if workload == "mixed5050":
        return [_plan_5050(t, ops_per_thread, seed)
                for t in range(nthreads)], 10
    if workload == "pairs":
        plans = []
        for t in range(nthreads):
            p = []
            for i in range(ops_per_thread // 2):
                p.append(("enq", (t, i)))
                p.append(("deq", None))
            plans.append(p)
        return plans, 10
    if workload == "producers":
        return [[("enq", (t, i)) for i in range(ops_per_thread)]
                for t in range(nthreads)], 0
    if workload == "consumers":
        return [[("deq", None)] * ops_per_thread
                for t in range(nthreads)], nthreads * ops_per_thread + 8
    if workload == "prodcons":
        plans = []
        half = ops_per_thread // 2
        for t in range(nthreads):
            if t % 4 == 0:
                p = [("deq", None)] * half + \
                    [("enq", (t, i)) for i in range(half)]
            else:
                p = [("enq", (t, i)) for i in range(half)] + \
                    [("deq", None)] * half
            plans.append(p)
        return plans, 10
    raise ValueError(workload)


def contention_label(setting) -> str:
    """Classify an axis value (off | on | learned | float retry_scale)
    without building a model.  Identity checks first: numeric 0/1 must
    resolve to their float scales, not to the False/True presets they
    compare equal to."""
    if setting is None or setting is False or setting == "off":
        return "off"
    if setting is True or setting == "on":
        return "on"
    if setting == "learned":
        return "learned"
    return f"{float(setting):g}"


def load_learned_profiles(path: str = None) -> dict:
    """Load (and cache) the trace-fitted per-queue contention profiles."""
    path = path or LEARNED_PROFILES_PATH
    if path not in _learned_cache:
        from repro.trace.fit import load_profiles
        _learned_cache[path] = load_profiles(path)
    return _learned_cache[path]


def resolve_contention(setting, queue_name: str = None
                       ) -> Tuple[str, "ContentionModel | None"]:
    """('label', model-or-None) from an axis value: off | on | learned |
    float scale.  ``learned`` needs `queue_name` to pick that queue's
    trace-fitted profile from ``benchmarks/profiles/learned.json``."""
    label = contention_label(setting)
    if label == "off":
        return label, None
    if label == "on":
        return label, ContentionModel()
    if label == "learned":
        if queue_name is None:
            raise ValueError("--contention learned needs a queue name")
        profiles = load_learned_profiles()
        if queue_name not in profiles:
            raise ValueError(
                f"no learned profile for {queue_name!r} in "
                f"{LEARNED_PROFILES_PATH}; re-run "
                "`python benchmarks/run.py fit-profiles`")
        return label, ContentionModel(profiles=profiles[queue_name])
    return label, ContentionModel(retry_scale=float(label))


def run_workload(queue_name: str, workload: str, nthreads: int,
                 ops_per_thread: int = 60, seed: int = 0,
                 model: str = "optane-clwb",
                 engine: str = "batched",
                 contention=None,
                 trace_path: str = None) -> Dict[str, float]:
    mm = get_memory_model(model)
    h = QueueHarness(ALL_QUEUES[queue_name], nthreads=nthreads,
                     area_nodes=4096, model=mm)
    plans, prefill = make_plans(workload, nthreads, ops_per_thread, seed)
    # prefill outside the measured window
    for i in range(prefill):
        h.queue.enqueue(0, ("pre", i))
    base = h.nvram.total_stats()
    base_time = h.nvram.sim_time_ns()
    if engine == "batched":
        clabel, cmodel = resolve_contention(contention, queue_name)
        res = h.run_batched(plans, contention=cmodel)
        retries_per_op = cmodel.retries_per_op() if cmodel else 0.0
    elif engine == "exact":
        # the exact scheduler's contention is native: retries really run;
        # trace capture (repro.trace) records the real interleaving
        clabel, retries_per_op = "native", 0.0
        rec = None
        if trace_path:
            from repro.trace import TraceRecorder, save_trace
            rec = TraceRecorder()
        res = h.run_scheduled(plans, seed=seed, trace=rec)
        if rec is not None:
            rec.trace.meta["workload"] = workload
            rec.trace.meta["ops_per_thread"] = ops_per_thread
            save_trace(trace_path, rec.trace)
    else:
        raise ValueError(f"unknown engine {engine!r}")
    d = h.nvram.total_stats().minus(base)
    ops = res.ops_completed
    span = h.nvram.sim_time_ns() - base_time
    return {
        "queue": queue_name, "workload": workload, "threads": nthreads,
        "model": mm.name, "engine": engine, "contention": clabel,
        "ops": ops,
        "mops_per_s": ops / max(span, 1) * 1e3,
        "us_per_op": span / max(ops, 1) / 1e3,
        "fences_per_op": d.fences / max(ops, 1),
        "flushes_per_op": d.flushes / max(ops, 1),
        "post_flush_per_op": d.post_flush_accesses / max(ops, 1),
        "retries_per_op": retries_per_op,
    }
