"""Paper §10 workload definitions over the simulated-NVRAM queues.

Five workloads following Figure 2:
  * ``mixed5050``   -- each op uniformly enqueue/dequeue (initial size 10)
  * ``pairs``       -- each thread runs enqueue-dequeue pairs
  * ``producers``   -- enqueues only, starting from empty
  * ``consumers``   -- dequeues only, from a pre-filled queue
  * ``prodcons``    -- 1/4 of threads dequeue-then-enqueue blocks, the rest
                       enqueue-then-dequeue (queue never drains)

Each run is parameterized by a **memory model** (``optane-clwb`` / ``eadr``
/ ``cxl``; see :mod:`repro.core.memmodel`) and an **engine**:

  * ``batched`` (default) -- the clock-driven op-granularity executor over
    the array-backed cost engine; thousands of ops/thread across 1..64
    threads are practical;
  * ``exact``   -- the OS-thread, per-primitive interleaving scheduler the
    crash/linearizability tests use (slow; seed-era op counts only).

Batched runs take a **contention** setting (``off`` / ``on`` / a float
``retry_scale``): ``on`` attaches the calibrated
:class:`repro.core.contention.ContentionModel`, charging CAS-retry and
helping-path costs for co-scheduled ops.  Exact runs report ``native`` --
their retries really execute, which is what the model is calibrated
against.

Throughput is simulated time (per-thread latency-model clocks; see
repro.core.nvram for constants + citations): ops / max(thread clock).  The
paper's claims are about *orderings and ratios*, which is what these
reproduce.
"""
from __future__ import annotations

import random
from typing import Dict, List, Tuple

from repro.core import (ALL_QUEUES, ContentionModel, QueueHarness,
                        get_memory_model)


def _plan_5050(tid: int, n_ops: int, seed: int):
    rng = random.Random(seed * 7919 + tid)
    plan = []
    for i in range(n_ops):
        if rng.random() < 0.5:
            plan.append(("enq", (tid, i)))
        else:
            plan.append(("deq", None))
    return plan


def make_plans(workload: str, nthreads: int, ops_per_thread: int,
               seed: int = 0) -> Tuple[List[list], int]:
    """Returns (plans, prefill) -- prefill items are enqueued before timing."""
    if workload == "mixed5050":
        return [_plan_5050(t, ops_per_thread, seed)
                for t in range(nthreads)], 10
    if workload == "pairs":
        plans = []
        for t in range(nthreads):
            p = []
            for i in range(ops_per_thread // 2):
                p.append(("enq", (t, i)))
                p.append(("deq", None))
            plans.append(p)
        return plans, 10
    if workload == "producers":
        return [[("enq", (t, i)) for i in range(ops_per_thread)]
                for t in range(nthreads)], 0
    if workload == "consumers":
        return [[("deq", None)] * ops_per_thread
                for t in range(nthreads)], nthreads * ops_per_thread + 8
    if workload == "prodcons":
        plans = []
        half = ops_per_thread // 2
        for t in range(nthreads):
            if t % 4 == 0:
                p = [("deq", None)] * half + \
                    [("enq", (t, i)) for i in range(half)]
            else:
                p = [("enq", (t, i)) for i in range(half)] + \
                    [("deq", None)] * half
            plans.append(p)
        return plans, 10
    raise ValueError(workload)


def contention_label(setting) -> str:
    """Classify an axis value (off | on | float retry_scale) without
    building a model.  Identity checks first: numeric 0/1 must resolve to
    their float scales, not to the False/True presets they compare equal
    to."""
    if setting is None or setting is False or setting == "off":
        return "off"
    if setting is True or setting == "on":
        return "on"
    return f"{float(setting):g}"


def resolve_contention(setting) -> Tuple[str, "ContentionModel | None"]:
    """('label', model-or-None) from an axis value: off | on | float scale."""
    label = contention_label(setting)
    if label == "off":
        return label, None
    if label == "on":
        return label, ContentionModel()
    return label, ContentionModel(retry_scale=float(label))


def run_workload(queue_name: str, workload: str, nthreads: int,
                 ops_per_thread: int = 60, seed: int = 0,
                 model: str = "optane-clwb",
                 engine: str = "batched",
                 contention=None) -> Dict[str, float]:
    mm = get_memory_model(model)
    h = QueueHarness(ALL_QUEUES[queue_name], nthreads=nthreads,
                     area_nodes=4096, model=mm)
    plans, prefill = make_plans(workload, nthreads, ops_per_thread, seed)
    # prefill outside the measured window
    for i in range(prefill):
        h.queue.enqueue(0, ("pre", i))
    base = h.nvram.total_stats()
    base_time = h.nvram.sim_time_ns()
    if engine == "batched":
        clabel, cmodel = resolve_contention(contention)
        res = h.run_batched(plans, contention=cmodel)
        retries_per_op = cmodel.retries_per_op() if cmodel else 0.0
    elif engine == "exact":
        # the exact scheduler's contention is native: retries really run
        clabel, retries_per_op = "native", 0.0
        res = h.run_scheduled(plans, seed=seed)
    else:
        raise ValueError(f"unknown engine {engine!r}")
    d = h.nvram.total_stats().minus(base)
    ops = res.ops_completed
    span = h.nvram.sim_time_ns() - base_time
    return {
        "queue": queue_name, "workload": workload, "threads": nthreads,
        "model": mm.name, "engine": engine, "contention": clabel,
        "ops": ops,
        "mops_per_s": ops / max(span, 1) * 1e3,
        "us_per_op": span / max(ops, 1) / 1e3,
        "fences_per_op": d.fences / max(ops, 1),
        "flushes_per_op": d.flushes / max(ops, 1),
        "post_flush_per_op": d.post_flush_accesses / max(ops, 1),
        "retries_per_op": retries_per_op,
    }
