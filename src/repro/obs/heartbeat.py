"""Rate-limited progress lines for long fleet runs.

A :class:`Heartbeat` accumulates fleet progress counters (chunks, ops,
bails, rejoins, residents) and emits a one-line summary to its stream at
most every ``interval_s`` seconds -- frequent enough to show a 1M-instance
run is alive, cheap enough to never shape the numbers.  Off by default:
`benchmarks/run.py fleet` only constructs one when stderr progress is
wanted (``--quiet`` suppresses it, tests never see one).
"""
import sys
import time
from typing import Optional, TextIO


class Heartbeat:
    """Periodic ``fleet-heartbeat:`` lines (chunks done, bails, rejoins,
    residents, µs/op so far) on ``stream`` (default stderr)."""

    def __init__(self, interval_s: float = 5.0,
                 stream: Optional[TextIO] = None,
                 label: str = "fleet") -> None:
        self.interval_s = float(interval_s)
        self.stream = stream if stream is not None else sys.stderr
        self.label = label
        self.total_chunks = 0
        self.total_ops = 0
        self.chunks_done = 0
        self.ops_done = 0
        self.bails = 0
        self.rejoins = 0
        self.residents = 0
        self.emitted = 0
        self._t0 = time.perf_counter()
        self._last_emit = self._t0

    def configure(self, total_chunks: int = 0, total_ops: int = 0) -> None:
        """Set (or extend) the denominators shown in progress lines."""
        self.total_chunks += int(total_chunks)
        self.total_ops += int(total_ops)

    def advance(self, chunks: int = 0, ops: int = 0, bails: int = 0,
                rejoins: int = 0, residents: int = 0) -> None:
        """Record progress; emits a line if ``interval_s`` has elapsed."""
        self.chunks_done += chunks
        self.ops_done += ops
        self.bails += bails
        self.rejoins += rejoins
        self.residents += residents
        now = time.perf_counter()
        if now - self._last_emit >= self.interval_s:
            self.emit(now=now)

    def emit(self, now: Optional[float] = None, final: bool = False) -> None:
        """Write one progress line unconditionally."""
        if now is None:
            now = time.perf_counter()
        elapsed = now - self._t0
        us_per_op = (elapsed * 1e6 / self.ops_done) if self.ops_done else 0.0
        pct = (f" ({100.0 * self.ops_done / self.total_ops:.1f}%)"
               if self.total_ops else "")
        tc = f"/{self.total_chunks}" if self.total_chunks else ""
        tag = "done" if final else "heartbeat"
        self.stream.write(
            f"# {self.label}-{tag}: chunks {self.chunks_done}{tc} "
            f"ops {self.ops_done}{pct} bails {self.bails} "
            f"rejoins {self.rejoins} residents {self.residents} "
            f"{us_per_op:.2f}us/op {elapsed:.1f}s elapsed\n")
        self.stream.flush()
        self._last_emit = now
        self.emitted += 1
