"""Scoped per-phase timers for the execution layers.

A :class:`PhaseProfiler` is a stack of named phases over a single
monotonic clock.  ``push(name)`` charges the elapsed time since the last
transition to the phase currently on top, then makes ``name`` the
current phase; ``pop()`` charges the top phase and resumes its parent at
the same timestamp.  Because every transition hands the clock from one
phase to the next with no gap, the sum over ``totals`` equals the wall
time between the outermost push and pop *exactly* -- the "phase sum
within 10% of wall" acceptance check holds by construction, with the
profiler's own overhead attributed to whichever phase was running when
the timer fired.

Phase names are plain strings so `repro.core` never imports this module:
the scheduler, record store, fleet runner and crash sweep take an
optional profiler object and call ``push``/``pop`` on it (duck-typed).
The canonical names used by the batched-execution layers are the
``PH_*`` constants below; `benchmarks/run.py profile` maps them to CSV
columns by replacing ``-`` with ``_``.
"""
from contextlib import contextmanager
from time import perf_counter_ns
from typing import Dict, Iterator, List, Optional

# Batched-execution phases (ClockScheduler / RecordStore / harness).
PH_HEAP = "heap-loop"               # heap pop/push + cursor advance
PH_INTERP_BODY = "interpreted-body" # compiled per-op fn (columnar/timed body)
PH_CHARGE = "record-charging"       # RecordStore.sync vector pass + flush_counts
PH_BOOKKEEPING = "bookkeeping"      # plan/thunk setup, store attach, teardown
PH_BAIL_REAL = "bail-real-op"       # fast-path bail: real per-primitive op

# Burst-execution phases (repro.core.burst; nested under heap-loop).
PH_BURST_PREDICT = "burst-predict"  # pool + duration/interleave prediction
PH_BURST_VERIFY = "burst-verify"    # plan + vector automaton + key compare
PH_BURST_APPLY = "burst-vector-apply"  # commit: staging, stores, splice
PH_BURST_REPLAY = "mispredict-replay"  # rejected bursts on the merged runner

# Fleet phases (repro.fleet.runner).
PH_FLEET_LOWER = "lowering"         # build_fleet: schedules -> stacked arrays
PH_FLEET_CHUNK = "chunk-step"       # backend.run_chunk
PH_FLEET_POLL = "poll"              # backend.poll: bail detection
PH_FLEET_BAIL = "bail-replay"       # per-instance replay + export + rejoin
PH_FLEET_RESIDENT = "resident-replay"  # instances finishing outside the fleet

# Crash-sweep phases (repro.crash.sweep).
PH_CRASH_CAPTURE = "capture"        # boundary capture run
PH_CRASH_RESTORE = "restore"        # snapshot restore + log truncation
PH_CRASH_RECOVER = "recover"        # crash_and_recover
PH_CRASH_CHECK = "check"            # drain + durable-linearizability check


class PhaseProfiler:
    """Accumulates wall nanoseconds and entry counts per named phase."""

    __slots__ = ("totals", "counts", "_stack")

    def __init__(self) -> None:
        self.totals: Dict[str, int] = {}   # phase -> ns
        self.counts: Dict[str, int] = {}   # phase -> entries
        self._stack: List[list] = []       # [name, resumed_at_ns]

    def push(self, name: str) -> None:
        now = perf_counter_ns()
        stack = self._stack
        if stack:
            top = stack[-1]
            totals = self.totals
            totals[top[0]] = totals.get(top[0], 0) + now - top[1]
        stack.append([name, now])
        counts = self.counts
        counts[name] = counts.get(name, 0) + 1

    def pop(self) -> None:
        now = perf_counter_ns()
        name, since = self._stack.pop()
        totals = self.totals
        totals[name] = totals.get(name, 0) + now - since
        if self._stack:
            self._stack[-1][1] = now

    @contextmanager
    def phase(self, name: str) -> Iterator["PhaseProfiler"]:
        self.push(name)
        try:
            yield self
        finally:
            self.pop()

    def total_ns(self) -> int:
        """Sum over all phases (open phases counted up to their last
        transition only; call with an empty stack for exact totals)."""
        return sum(self.totals.values())

    def us_per_op(self, ops: int) -> Dict[str, float]:
        """totals as microseconds per op (ops <= 0 yields raw µs)."""
        div = ops if ops > 0 else 1
        return {k: v / 1000.0 / div for k, v in self.totals.items()}

    def coverage(self, wall_s: float) -> float:
        """Fraction of a measured wall time the phase sum accounts for."""
        if wall_s <= 0:
            return 0.0
        return self.total_ns() / (wall_s * 1e9)

    def as_dict(self) -> Dict[str, Dict[str, int]]:
        """JSON-ready ``{phase: {"ns": ..., "count": ...}}`` (manifests)."""
        return {name: {"ns": ns, "count": self.counts.get(name, 0)}
                for name, ns in sorted(self.totals.items())}

    def merge(self, other: Optional["PhaseProfiler"]) -> "PhaseProfiler":
        """Fold another profiler's totals/counts into this one."""
        if other is not None:
            for name, ns in other.totals.items():
                self.totals[name] = self.totals.get(name, 0) + ns
            for name, n in other.counts.items():
                self.counts[name] = self.counts.get(name, 0) + n
        return self

    def report(self, ops: int = 0, indent: str = "  ") -> str:
        """Human-readable per-phase table (µs/op when ops given)."""
        lines = []
        total = self.total_ns() or 1
        for name, ns in sorted(self.totals.items(), key=lambda kv: -kv[1]):
            frac = 100.0 * ns / total
            if ops > 0:
                lines.append(f"{indent}{name:<18} {ns / 1000.0 / ops:8.3f} "
                             f"us/op  {frac:5.1f}%  x{self.counts.get(name, 0)}")
            else:
                lines.append(f"{indent}{name:<18} {ns / 1e6:10.3f} ms  "
                             f"{frac:5.1f}%  x{self.counts.get(name, 0)}")
        return "\n".join(lines)
