"""Versioned JSON run manifests written alongside benchmark CSVs.

Every ``benchmarks/run.py`` subcommand records *how* a number was
produced next to the number itself: git state, full CLI config, seed,
host environment, per-phase timings, and a flat ``headline`` dict of the
metrics worth tracking across PRs.  ``benchmarks/bench_history.py``
folds those headline cells into committed ``BENCH_<pr>.json`` snapshots
and gates CI on ratio-vs-baseline drift.

The schema is intentionally flat and versioned (``MANIFEST_SCHEMA``);
:func:`validate_manifest` collects *all* problems before raising so a
malformed manifest is diagnosable in one round trip.  Only stdlib is
used here -- the module must import in CI jobs that install nothing.
"""
import json
import os
import platform
import subprocess
import sys
import time
from pathlib import Path
from typing import Any, Dict, List, Optional, Union

MANIFEST_SCHEMA = "repro.obs.manifest/v1"
MANIFEST_VERSION = 1

_REPO_ROOT = Path(__file__).resolve().parents[3]


class ManifestError(ValueError):
    """A manifest (or snapshot) failed schema validation."""


def _git(args: List[str], cwd: Path) -> Optional[str]:
    try:
        out = subprocess.run(["git", *args], cwd=str(cwd), timeout=10,
                             capture_output=True, text=True)
    except (OSError, subprocess.SubprocessError):
        return None
    if out.returncode != 0:
        return None
    return out.stdout.strip()


def collect_git(cwd: Optional[Union[str, Path]] = None) -> Dict[str, Any]:
    """Best-effort git state: ``{sha, branch, dirty}`` (None/False when
    git or the repo is unavailable -- manifests must never fail a run)."""
    root = Path(cwd) if cwd is not None else _REPO_ROOT
    sha = _git(["rev-parse", "HEAD"], root)
    branch = _git(["rev-parse", "--abbrev-ref", "HEAD"], root)
    status = _git(["status", "--porcelain"], root)
    return {"sha": sha, "branch": branch,
            "dirty": bool(status) if status is not None else False}


def collect_env() -> Dict[str, Any]:
    """Host facts that make a perf number comparable (or explain why two
    numbers are not): interpreter, platform, CPU count, CI marker."""
    return {
        "python": sys.version.split()[0],
        "implementation": platform.python_implementation(),
        "platform": platform.platform(),
        "machine": platform.machine(),
        "cpu_count": os.cpu_count(),
        "hostname": platform.node(),
        "ci": bool(os.environ.get("CI")),
    }


def build_manifest(subcommand: str,
                   config: Dict[str, Any],
                   metrics: Optional[List[Dict[str, Any]]] = None,
                   headline: Optional[Dict[str, float]] = None,
                   phases: Optional[Dict[str, Dict[str, int]]] = None,
                   wall_s: Optional[float] = None,
                   extra: Optional[Dict[str, Any]] = None) -> Dict[str, Any]:
    """Assemble a schema-valid manifest dict.

    ``config`` is the resolved CLI namespace (seed included), ``metrics``
    the per-row measurements mirroring the CSV, ``headline`` the flat
    ``key -> number`` cells bench_history tracks, ``phases`` a
    ``PhaseProfiler.as_dict()``, ``extra`` free-form sections (e.g. the
    paper-§8 post-flush attribution from `repro.trace.analyze`).
    """
    man: Dict[str, Any] = {
        "schema": MANIFEST_SCHEMA,
        "version": MANIFEST_VERSION,
        "subcommand": subcommand,
        "created_unix": time.time(),
        "git": collect_git(),
        "env": collect_env(),
        "config": dict(config),
        "metrics": list(metrics) if metrics is not None else [],
        "headline": dict(headline) if headline is not None else {},
        "phases": dict(phases) if phases is not None else None,
        "wall_s": wall_s,
    }
    if extra:
        man.update(extra)
    return validate_manifest(man)


def validate_manifest(man: Any) -> Dict[str, Any]:
    """Check shape + types; raise :class:`ManifestError` listing every
    problem at once. Returns the manifest unchanged when valid."""
    problems: List[str] = []
    if not isinstance(man, dict):
        raise ManifestError(f"manifest must be a dict, got {type(man).__name__}")
    if man.get("schema") != MANIFEST_SCHEMA:
        problems.append(f"schema must be {MANIFEST_SCHEMA!r}, "
                        f"got {man.get('schema')!r}")
    if man.get("version") != MANIFEST_VERSION:
        problems.append(f"version must be {MANIFEST_VERSION}, "
                        f"got {man.get('version')!r}")
    if not isinstance(man.get("subcommand"), str) or not man.get("subcommand"):
        problems.append("subcommand must be a non-empty string")
    if not isinstance(man.get("created_unix"), (int, float)):
        problems.append("created_unix must be a number")
    for key in ("git", "env", "config", "headline"):
        if not isinstance(man.get(key), dict):
            problems.append(f"{key} must be a dict")
    if not isinstance(man.get("metrics"), list) or any(
            not isinstance(row, dict) for row in man.get("metrics") or []):
        problems.append("metrics must be a list of dicts")
    if isinstance(man.get("headline"), dict):
        for k, v in man["headline"].items():
            if not isinstance(k, str):
                problems.append(f"headline key {k!r} must be a string")
            if not isinstance(v, (int, float)) or isinstance(v, bool):
                problems.append(f"headline[{k!r}] must be a number, got {v!r}")
    phases = man.get("phases")
    if phases is not None:
        if not isinstance(phases, dict):
            problems.append("phases must be a dict or None")
        else:
            for name, cell in phases.items():
                if (not isinstance(cell, dict) or "ns" not in cell
                        or "count" not in cell):
                    problems.append(
                        f"phases[{name!r}] must be a dict with ns+count")
    wall = man.get("wall_s")
    if wall is not None and not isinstance(wall, (int, float)):
        problems.append("wall_s must be a number or None")
    if problems:
        raise ManifestError("invalid manifest: " + "; ".join(problems))
    return man


def manifest_path_for(out: Union[str, Path]) -> Path:
    """Sibling manifest path for a CSV output path: ``x.csv`` ->
    ``x.manifest.json`` (non-``.csv`` paths get ``.manifest.json``
    appended), honouring whatever output directory ``--out`` chose."""
    out = Path(out)
    if out.suffix == ".csv":
        return out.with_suffix(".manifest.json")
    return out.with_name(out.name + ".manifest.json")


def write_manifest(man: Dict[str, Any], path: Union[str, Path]) -> Path:
    """Validate + write (creating parent dirs); returns the path."""
    validate_manifest(man)
    path = Path(path)
    if path.parent and not path.parent.exists():
        path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(json.dumps(man, indent=2, sort_keys=False,
                               default=_json_default) + "\n")
    return path


def load_manifest(path: Union[str, Path]) -> Dict[str, Any]:
    """Read + validate a manifest file."""
    with open(path) as fh:
        man = json.load(fh)
    try:
        return validate_manifest(man)
    except ManifestError as e:
        raise ManifestError(f"{path}: {e}") from None


def _json_default(obj: Any) -> Any:
    """Serialize numpy scalars and Paths without importing numpy."""
    if isinstance(obj, Path):
        return str(obj)
    for attr in ("item",):   # numpy scalar protocol
        fn = getattr(obj, attr, None)
        if callable(fn):
            return fn()
    raise TypeError(f"not JSON serializable: {type(obj).__name__}")
