"""Observation-only telemetry: phase profilers, run manifests, heartbeats.

The subsystem follows the trace-tap contract (PR 3): attaching any of its
instruments must leave per-thread Stats, op records and simulated clocks
bit-identical (``tests/test_obs_bit_identity.py`` is the gate), and a
disabled instrument costs at most a ``None`` check on the hot path.

Three instruments:

* :class:`repro.obs.profiler.PhaseProfiler` -- scoped phase timers threaded
  through the batched scheduler loop, the columnar record store's
  staged-burst sync/charge passes, the fleet runner and the crash sweep;
  surfaced as ``benchmarks/run.py profile``.
* :mod:`repro.obs.manifest` -- versioned JSON run manifests (git sha,
  config, seed, env, phase timings, headline metrics) written alongside
  every benchmark CSV; folded into ``BENCH_<pr>.json`` snapshots by
  ``benchmarks/bench_history.py``.
* :class:`repro.obs.heartbeat.Heartbeat` -- periodic progress lines for
  long fleet runs (stderr, rate-limited, off by default).

Core modules never import this package: instruments are passed in and
duck-typed (``push``/``pop``), so ``repro.core`` stays dependency-free.
"""
from .heartbeat import Heartbeat
from .manifest import (MANIFEST_SCHEMA, ManifestError, build_manifest,
                       collect_env, collect_git, load_manifest,
                       manifest_path_for, validate_manifest, write_manifest)
from .profiler import (PH_BAIL_REAL, PH_BOOKKEEPING, PH_BURST_APPLY,
                       PH_BURST_PREDICT, PH_BURST_REPLAY, PH_BURST_VERIFY,
                       PH_CHARGE, PH_HEAP, PH_INTERP_BODY, PhaseProfiler)

__all__ = [
    "Heartbeat",
    "MANIFEST_SCHEMA", "ManifestError", "build_manifest", "collect_env",
    "collect_git", "load_manifest", "manifest_path_for", "validate_manifest",
    "write_manifest",
    "PH_BAIL_REAL", "PH_BOOKKEEPING", "PH_BURST_APPLY", "PH_BURST_PREDICT",
    "PH_BURST_REPLAY", "PH_BURST_VERIFY", "PH_CHARGE", "PH_HEAP",
    "PH_INTERP_BODY", "PhaseProfiler",
]
