from .pipeline import DurableShardQueue, TokenSource

__all__ = ["DurableShardQueue", "TokenSource"]
