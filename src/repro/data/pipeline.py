"""Durable data pipeline with exactly-once shard delivery.

The training data queue is a durable FIFO in the paper's mold:
* **producers** enqueue shard descriptors into a WAL -- a batch of enqueues
  shares ONE fence (group commit = the single blocking persist per update);
* **consumers** (trainer workers) read shards in order; consumption becomes
  durable when the per-worker cursor advances -- which happens at
  *checkpoint commit* time, so data state and model state move atomically:
  after a crash, training resumes from the last committed step and replays
  exactly the shards after its cursor (consumed-but-uncommitted shards are
  re-delivered; committed ones never -- the FIFO prefix rule,
  Observation 2);
* nothing on the fast path re-reads what it persisted (guideline 2): the
  shard WAL is only replayed at recovery, cursors are write-only.
"""
from __future__ import annotations

import json
import os
from typing import Dict, List, Optional

import numpy as np

from repro.persist.cursors import CursorFile
from repro.persist.wal import WriteAheadLog


class TokenSource:
    """Deterministic synthetic token stream (shard id -> tokens)."""

    def __init__(self, vocab: int, seq_len: int, batch: int):
        self.vocab = vocab
        self.seq_len = seq_len
        self.batch = batch

    def batch_for(self, shard_id: int) -> Dict[str, np.ndarray]:
        rng = np.random.RandomState(shard_id % (2 ** 31))
        toks = rng.randint(0, self.vocab,
                           (self.batch, self.seq_len)).astype(np.int32)
        return {"tokens": toks, "labels": np.roll(toks, -1, axis=1)}


class DurableShardQueue:
    def __init__(self, directory: str, worker_id: int = 0, n_workers: int = 1):
        self.dir = directory
        os.makedirs(directory, exist_ok=True)
        self.worker_id = worker_id
        self.n_workers = n_workers
        self.wal = WriteAheadLog(os.path.join(directory, "shards.wal"))
        self.cursor = CursorFile(self._cursor_path(worker_id))
        # volatile state rebuilt by recover()
        self._shards: List[dict] = []
        self._next = 0

    def _cursor_path(self, w: int) -> str:
        return os.path.join(self.dir, f"cursor_{w}.bin")

    # ---------------------------------------------------------------- produce
    def enqueue_shards(self, descriptors: List[dict]) -> None:
        """Durable enqueue: N appends + ONE fence (group commit)."""
        for d in descriptors:
            self.wal.append(json.dumps(d).encode())
        self.wal.fence()
        self._shards.extend(descriptors)

    # ---------------------------------------------------------------- consume
    def next_shard(self) -> Optional[dict]:
        """Volatile dequeue; durability comes from commit_consumed()."""
        mine = [i for i in range(self._next, len(self._shards))
                if i % self.n_workers == self.worker_id]
        if not mine:
            return None
        i = mine[0]
        self._next = i + 1
        d = dict(self._shards[i])
        d["_queue_index"] = i
        return d

    def commit_consumed(self, queue_index: int, fence: bool = True) -> None:
        """Advance the durable per-worker cursor (paper: movnti the
        per-thread head index + the one fence).  Called at checkpoint
        commit so data and model state stay atomic."""
        self.cursor.advance(queue_index + 1, fence=fence)

    # --------------------------------------------------------------- recovery
    def recover(self) -> int:
        """Rebuild volatile state: replay the WAL prefix, set the head to the
        max committed per-worker cursor.  Returns the resume index."""
        self._shards = [json.loads(p.decode())
                        for p in WriteAheadLog.replay(
                            os.path.join(self.dir, "shards.wal"))]
        paths = [self._cursor_path(w) for w in range(self.n_workers)]
        head = CursorFile.recover_max(paths) or 0
        self._next = head
        return head

    def close(self) -> None:
        self.wal.close()
        self.cursor.close()
