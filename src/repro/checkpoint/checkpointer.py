"""Durable distributed checkpointer following the paper's two guidelines.

1. **One blocking persist per checkpoint** (the fence lower bound): shard
   files stream out asynchronously (optionally on a background thread --
   compute/IO overlap); the only blocking barrier is the final commit-record
   fsync.  Shard fsyncs are issued before the commit (they are the
   "asynchronous flushes"; the commit is the SFENCE).
2. **Zero post-flush accesses**: nothing written is ever read back on the
   fast path -- no readback-verify, no manifest read-modify-write.  Recovery
   is an UnlinkedQ-style *directory scan*: every ``step_XXXX`` directory is a
   node in a designated area, the COMMIT record is its ``linked`` flag, the
   step number its ``index``; restore = the max-index committed entry,
   torn/uncommitted entries are ignored (and garbage-collected).

Works per-host on its own parameter shards: each host writes
``shard_{host}.npz`` independently; host 0 writes the commit record once all
shard writes have landed -- on a real cluster that "all landed" signal is a
cross-host barrier, here it is sequential completion in the save worker.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import struct
import threading
import zlib
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

PyTree = Any


def _flatten(tree: PyTree, prefix: str = "") -> Dict[str, np.ndarray]:
    out: Dict[str, np.ndarray] = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}{k}/"))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{i}/"))
    else:
        out[prefix[:-1]] = np.asarray(tree)
    return out


def _unflatten(flat: Dict[str, np.ndarray]) -> PyTree:
    root: Dict[str, Any] = {}
    for path, v in flat.items():
        parts = path.split("/")
        cur = root
        for p in parts[:-1]:
            cur = cur.setdefault(p, {})
        cur[parts[-1]] = v
    def fix(node):
        if isinstance(node, dict):
            keys = list(node.keys())
            if keys and all(re.fullmatch(r"\d+", k) for k in keys):
                return [fix(node[str(i)]) for i in range(len(keys))]
            return {k: fix(v) for k, v in node.items()}
        return node
    return fix(root)


class DurableCheckpointer:
    def __init__(self, directory: str, keep: int = 2,
                 background: bool = True):
        self.dir = directory
        self.keep = keep
        self.background = background
        os.makedirs(directory, exist_ok=True)
        self.commit_fences = 0
        self._inflight: Optional[threading.Thread] = None

    # ----------------------------------------------------------------- save
    def _step_dir(self, step: int) -> str:
        return os.path.join(self.dir, f"step_{step:08d}")

    def _write_shard(self, step: int, shard_id: int, tree: PyTree) -> None:
        d = self._step_dir(step)
        os.makedirs(d, exist_ok=True)
        path = os.path.join(d, f"shard_{shard_id}.npz")
        flat = _flatten(tree)
        with open(path, "wb") as f:
            np.savez(f, **flat)
            f.flush()
            os.fsync(f.fileno())     # asynchronous-flush analogue (per shard)

    def save(self, step: int, shards: Dict[int, PyTree],
             meta: Optional[dict] = None) -> None:
        """Write all shards, then ONE blocking commit."""
        if self._inflight is not None:
            self._inflight.join()    # previous async save must land first
            self._inflight = None

        def work():
            for sid, tree in shards.items():
                self._write_shard(step, sid, tree)
            self._commit(step, n_shards=len(shards), meta=meta or {})
            self._gc()

        if self.background:
            self._inflight = threading.Thread(target=work, daemon=True)
            self._inflight.start()
        else:
            work()

    def wait(self) -> None:
        if self._inflight is not None:
            self._inflight.join()
            self._inflight = None

    def _commit(self, step: int, n_shards: int, meta: dict) -> None:
        """The single blocking persist (the checkpoint's SFENCE)."""
        d = self._step_dir(step)
        body = json.dumps({"step": step, "n_shards": n_shards,
                           "meta": meta}).encode()
        crc = zlib.crc32(body) & 0xFFFFFFFF
        path = os.path.join(d, "COMMIT")
        with open(path, "wb") as f:
            f.write(struct.pack("<I", crc) + body)
            f.flush()
            os.fsync(f.fileno())
        # fsync the parent so the directory entry itself is durable
        fd = os.open(self.dir, os.O_RDONLY)
        try:
            os.fsync(fd)
        finally:
            os.close(fd)
        self.commit_fences += 1

    # ------------------------------------------------------------- recovery
    @staticmethod
    def _read_commit(path: str) -> Optional[dict]:
        try:
            with open(path, "rb") as f:
                raw = f.read()
            crc = struct.unpack("<I", raw[:4])[0]
            body = raw[4:]
            if (zlib.crc32(body) & 0xFFFFFFFF) != crc:
                return None
            return json.loads(body)
        except (OSError, ValueError, struct.error):
            return None

    def scan(self) -> List[Tuple[int, dict]]:
        """Designated-area scan: committed (step, meta) entries, ascending."""
        out = []
        for name in sorted(os.listdir(self.dir)):
            m = re.fullmatch(r"step_(\d+)", name)
            if not m:
                continue
            commit = self._read_commit(
                os.path.join(self.dir, name, "COMMIT"))
            if commit is not None:
                out.append((int(m.group(1)), commit))
        return out

    def restore_latest(self) -> Optional[Tuple[int, Dict[int, PyTree], dict]]:
        """Max-index committed checkpoint; torn/uncommitted ones ignored."""
        entries = self.scan()
        if not entries:
            return None
        step, commit = entries[-1]
        d = self._step_dir(step)
        shards: Dict[int, PyTree] = {}
        for sid in range(commit["n_shards"]):
            with np.load(os.path.join(d, f"shard_{sid}.npz")) as z:
                shards[sid] = _unflatten({k: z[k] for k in z.files})
        return step, shards, commit.get("meta", {})

    def _gc(self) -> None:
        """Reclaim old committed entries + any uncommitted garbage older
        than the newest commit (crash leftovers == unlinked nodes)."""
        committed = [s for s, _ in self.scan()]
        if not committed:
            return
        newest = committed[-1]
        for name in os.listdir(self.dir):
            m = re.fullmatch(r"step_(\d+)", name)
            if not m:
                continue
            step = int(m.group(1))
            keep_set = set(committed[-self.keep:])
            if step in keep_set:
                continue
            if step < newest or step in committed:
                shutil.rmtree(os.path.join(self.dir, name),
                              ignore_errors=True)
