from .checkpointer import DurableCheckpointer

__all__ = ["DurableCheckpointer"]
