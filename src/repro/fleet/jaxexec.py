"""JAX backend: per-instance step fn, ``lax.scan`` over the op stream,
``jax.vmap`` over the fleet, sharded across forced host devices.

The step function is a straight functional transcription of
:mod:`repro.fleet.stepper` for a *single* instance (scalars + small 1D
arrays); ``jax.vmap`` batches it over the instance axis and ``lax.scan``
drives it down a chunk of the op stream.  Both lowered programs run every
step as masked straight-line code (no ``lax.cond`` -- the fleet's whole
premise is that each op is a handful of gathers/scatters, so executing the
non-selected program under a False mask is cheaper than divergence).

Sharding uses the CPU-mesh trick: ``XLA_FLAGS=
--xla_force_host_platform_device_count=8`` (set by
:func:`repro.fleet.runner.ensure_host_devices` before jax's first import)
splits the host into 8 XLA devices; a 1D mesh over the instance axis then
gives device parallelism without any accelerator.  The instance axis is
padded to a device multiple; padding rows are born inactive.

All arrays are int32/uint8 -- volatile addresses are offsets, counts are
int32 deltas (converted back to int64 on the host) -- so the backend never
needs jax x64 mode.  Bit-identity with the numpy stepper (and hence with
``run_batched``) is asserted by ``tests/test_fleet_equivalence.py``.

Two steppers share the sections that don't depend on schedule depth
(:func:`_op_prologue`: tail record, bail detection, epoch machinery, env
binding + allocations):

* the original **unrolled** stepper (:func:`_apply_one` /
  :func:`make_chunk_fn`) traces every micro/aux entry inline -- fastest
  compiled steps, but the jit trace grows with schedule depth;
* the **opcode interpreter** (:func:`_apply_opcode_one` /
  :func:`make_opcode_chunk_fn`) drives a ``lax.fori_loop`` +
  ``lax.switch`` over the program's :class:`~repro.fleet.lowering.
  OpcodeProgram` table, so the trace size is independent of depth
  (asserted by ``tests/test_fleet_opcode.py``).  The same function is the
  body of the Pallas kernel in :mod:`repro.kernels.fleet_step`.
"""
from __future__ import annotations

import numpy as np

from ..core.nvram import (EV_COLD_DRAM, EV_COLD_NVM, EV_DRAM, EV_HIT,
                          EV_POSTFLUSH, LINE_WORDS)
from ..core.opsched import NULL, ST_EVERFL, ST_INVAL
from .lowering import (KIND_DEQ, KIND_ENQ, SYM, N_OPC, OPC_CLASS_P,
                       OPC_CLASS_V, OPC_LIMBO, OPC_PADD, OPC_PDISCARD,
                       OPC_RECACHE, OPC_SLOT, OPC_ST_EVERFL, OPC_ST_INVAL,
                       encode_program)
from .state import FleetState, Template
from .stepper import EPOCH_ADV_OPS

N_SYM = max(SYM.values()) + 1

E_NEW_P, E_NEW_V = SYM["new_p"], SYM["new_v"]
E_TAIL_P, E_TAIL_V = SYM["tail_p"], SYM["tail_v"]
E_HEAD_P, E_HEAD_V = SYM["head_p"], SYM["head_v"]
E_NEXT_P, E_NEXT_V = SYM["next_p"], SYM["next_v"]
E_PREV = SYM["prev"]

# FleetState fields carried on device (leading instance axis)
_ARRAY_FIELDS = ("cached", "finval", "everfl", "persisted", "vtouched",
                 "ring_p", "ring_v", "free_p", "vfree",
                 "limbo_a", "limbo_e", "limbo_k")
_SCALAR_FIELDS = ("head", "length", "dummy_p", "dummy_v", "nfree", "cursor",
                  "nvfree", "vcursor", "nlimbo", "epoch", "opsctr",
                  "active", "bail_at")


def _advance_one(jnp, dims, c):
    """Epoch advance for one instance (no-op when ``c['_adv']`` is False:
    the freed mask is empty and the epoch increment is masked)."""
    adv = c.pop("_adv")
    min_e = c["epoch"]
    c["epoch"] = jnp.where(adv, min_e + 1, min_e)
    j = jnp.arange(dims.lcap, dtype=jnp.int32)
    inl = j < c["nlimbo"]
    fr = inl & (c["limbo_e"] + 2 <= min_e) & adv
    is_p = c["limbo_k"] == 0
    for sel, stack, nkey, slen in ((fr & is_p, "free_p", "nfree", dims.fcap),
                                   (fr & ~is_p, "vfree", "nvfree",
                                    dims.vfcap)):
        cnt = jnp.cumsum(sel.astype(jnp.int32))
        dest = jnp.where(sel, c[nkey] + cnt - 1, slen)   # OOB -> dropped
        c[stack] = c[stack].at[dest].set(c["limbo_a"], mode="drop")
        c[nkey] = c[nkey] + cnt[-1]
    keep = inl & ~fr
    order = jnp.argsort(jnp.where(keep, 0, 1).astype(jnp.int32), stable=True)
    for key in ("limbo_a", "limbo_e", "limbo_k"):
        c[key] = c[key][order]
    c["nlimbo"] = c["nlimbo"] - fr.sum().astype(jnp.int32)
    return c


def _op_prologue(jnp, dims, prog, c, sel, oi):
    """The depth-independent front half of one lowered op on one
    instance's state dict: tail record, bail detection, op_begin epoch
    machinery, env binding + allocations.  Shared verbatim by the
    unrolled and opcode steppers; returns ``(c, m, env)``.

    Guard-slot values are read through ``slot()`` so the same code serves
    both state layouts: per-attr ``slot_<attr>`` keys (unrolled stepper)
    and the stacked ``slots`` vector the opcode stepper gathers from."""
    c = dict(c)
    m = c["active"] & sel
    cap = dims.cap
    length, head = c["length"], c["head"]
    has = length > 0
    tpos = (head + jnp.maximum(length - 1, 0)) % cap
    tail_p = jnp.where(has, c["ring_p"][tpos], c["dummy_p"])
    tail_v = jnp.where(has, c["ring_v"][tpos], c["dummy_v"])

    def slot(attr):
        if "slots" in c:
            return c["slots"][dims.slot_attrs.index(attr)]
        return c["slot_" + attr]

    # ---- bail detection --------------------------------------------------
    bail = jnp.asarray(False)
    if prog.code == KIND_DEQ:
        bail = bail | (length == 0)
    for g in prog.guards:
        if g[0] == "slot_nonnull":
            bail = bail | (slot(g[1]) == NULL)
        else:                               # tail_persisted
            bail = bail | (c["persisted"][tail_p // LINE_WORDS] == 0)
    if prog.allocs_p:
        bail = bail | ((c["nfree"] == 0) & (c["cursor"] >= dims.area_cap))
    if prog.allocs_v:
        bail = bail | ((c["nvfree"] == 0) & (c["vcursor"] >= dims.chunk_cap))
    newly = m & bail
    c["bail_at"] = jnp.where(newly, oi, c["bail_at"])
    c["active"] = c["active"] & ~newly
    m = m & ~newly
    # ---- op_begin --------------------------------------------------------
    if prog.uses_ssmem:
        ctr = c["opsctr"] + 1
        adv = m & (ctr >= EPOCH_ADV_OPS)
        c["opsctr"] = jnp.where(m, jnp.where(adv, 0, ctr), c["opsctr"])
        c["_adv"] = adv
        c = _advance_one(jnp, dims, c)
    # ---- env + allocations ----------------------------------------------
    env = {}
    if prog.code == KIND_ENQ:
        env[E_TAIL_P], env[E_TAIL_V] = tail_p, tail_v
    else:
        hpos = head % cap
        env[E_HEAD_P], env[E_HEAD_V] = c["dummy_p"], c["dummy_v"]
        env[E_NEXT_P] = c["ring_p"][hpos]
        env[E_NEXT_V] = c["ring_v"][hpos]
    for attr in prog.slot_attrs:
        env[E_PREV] = slot(attr)
    if prog.allocs_p:
        use = c["nfree"] > 0
        top = c["free_p"][jnp.maximum(c["nfree"] - 1, 0)]
        env[E_NEW_P] = jnp.where(
            use, top, dims.area_base + c["cursor"] * LINE_WORDS)
        c["nfree"] = jnp.where(m & use, c["nfree"] - 1, c["nfree"])
        c["cursor"] = jnp.where(m & ~use, c["cursor"] + 1, c["cursor"])
    if prog.allocs_v:
        use = c["nvfree"] > 0
        top = c["vfree"][jnp.maximum(c["nvfree"] - 1, 0)]
        env[E_NEW_V] = jnp.where(
            use, top, dims.chunk_base + c["vcursor"] * dims.node_words)
        c["nvfree"] = jnp.where(m & use, c["nvfree"] - 1, c["nvfree"])
        c["vcursor"] = jnp.where(m & ~use, c["vcursor"] + 1, c["vcursor"])
    return c, m, env


def _apply_one(jnp, dims, prog, c, sel, oi):
    """One lowered op on one instance's state dict, masked by ``sel``
    (unrolled form: every micro/aux entry traces inline)."""
    c, m, env = _op_prologue(jnp, dims, prog, c, sel, oi)
    # ---- micro-ops on local copies --------------------------------------
    cached, finval, everfl = c["cached"], c["finval"], c["everfl"]
    vtouched, persisted = c["vtouched"], c["persisted"]
    cdelta = jnp.asarray(prog.base_counts.astype(np.int32))
    one, zero = jnp.uint8(1), jnp.uint8(0)
    for ins in prog.micro:
        tag, ref = ins[0], ins[1]
        a = ref.const if ref.mode == "const" else env[ref.sym] + ref.off
        if tag == "class_p":
            ln = a // LINE_WORDS
            ev = jnp.where(cached[ln] == 1, EV_HIT,
                           jnp.where(finval[ln] == 1, EV_POSTFLUSH,
                                     jnp.where(everfl[ln] == 1, EV_COLD_NVM,
                                               EV_COLD_DRAM)))
            cdelta = cdelta.at[ev].add(1)
            cached = cached.at[ln].set(one)
            finval = finval.at[ln].set(zero)
        elif tag == "class_v":
            ev = jnp.where(vtouched[a] == 1, EV_HIT, EV_DRAM)
            cdelta = cdelta.at[ev].add(1)
            vtouched = vtouched.at[a].set(one)
        elif tag == "state":
            ln = a // LINE_WORDS
            mode = ins[2]
            if mode == ST_INVAL:
                cached = cached.at[ln].set(zero)
                finval = finval.at[ln].set(one)
                everfl = everfl.at[ln].set(one)
            elif mode == ST_EVERFL:
                everfl = everfl.at[ln].set(one)
            else:                           # ST_RECACHE
                cached = cached.at[ln].set(one)
                finval = finval.at[ln].set(zero)
        else:                               # "line"
            ln = a // LINE_WORDS
            cached = cached.at[ln].set(one)
            finval = finval.at[ln].set(zero)
    c["counts"] = jnp.where(m, c["counts"] + cdelta, c["counts"])
    # ---- logical FIFO ----------------------------------------------------
    cap = dims.cap
    length, head = c["length"], c["head"]
    if prog.code == KIND_ENQ:
        pos = (head + length) % cap
        new_p = env[E_NEW_P] if prog.allocs_p else jnp.int32(0)
        new_v = env[E_NEW_V] if prog.allocs_v else jnp.int32(0)
        c["ring_p"] = jnp.where(m, c["ring_p"].at[pos].set(new_p),
                                c["ring_p"])
        c["ring_v"] = jnp.where(m, c["ring_v"].at[pos].set(new_v),
                                c["ring_v"])
        c["length"] = jnp.where(m, length + 1, length)
    else:
        c["dummy_p"] = jnp.where(m, env[E_NEXT_P], c["dummy_p"])
        c["dummy_v"] = jnp.where(m, env[E_NEXT_V], c["dummy_v"])
        c["head"] = jnp.where(m, (head + 1) % cap, head)
        c["length"] = jnp.where(m, length - 1, length)
    # ---- aux effects on local copies ------------------------------------
    limbo_a, limbo_e, limbo_k = c["limbo_a"], c["limbo_e"], c["limbo_k"]
    nlimbo = c["nlimbo"]
    touched_limbo = False
    for ax in prog.aux:
        t0 = ax[0]
        if t0 == "limbo":
            limbo_a = limbo_a.at[nlimbo].set(env[ax[1]])
            limbo_e = limbo_e.at[nlimbo].set(c["epoch"])
            limbo_k = limbo_k.at[nlimbo].set(
                jnp.uint8(0 if ax[2] == "p" else 1))
            nlimbo = nlimbo + 1
            touched_limbo = True
        elif t0 == "slot":
            key = "slot_" + ax[1]
            c[key] = jnp.where(m, env[ax[2]], c[key])
        elif t0 == "pdiscard":
            persisted = persisted.at[env[ax[1]] // LINE_WORDS].set(zero)
        else:                               # padd
            for sym in ax[1]:
                persisted = persisted.at[env[sym] // LINE_WORDS].set(one)
    if touched_limbo:
        c["limbo_a"] = jnp.where(m, limbo_a, c["limbo_a"])
        c["limbo_e"] = jnp.where(m, limbo_e, c["limbo_e"])
        c["limbo_k"] = jnp.where(m, limbo_k, c["limbo_k"])
        c["nlimbo"] = jnp.where(m, nlimbo, c["nlimbo"])
    # commit the line/word-state locals
    c["cached"] = jnp.where(m, cached, c["cached"])
    c["finval"] = jnp.where(m, finval, c["finval"])
    c["everfl"] = jnp.where(m, everfl, c["everfl"])
    c["vtouched"] = jnp.where(m, vtouched, c["vtouched"])
    c["persisted"] = jnp.where(m, persisted, c["persisted"])
    return c


def make_chunk_fn(jax, programs, dims):
    """-> chunk(st, kcols, oi): vmap over instances of a lax.scan over the
    chunk's op stream.  ``kcols`` is (N, C) uint8, ``oi`` (C,) int32 global
    op indices (shared across instances)."""
    import jax.numpy as jnp
    from jax import lax

    def per_instance(c, kcol, oi):
        def step(carry, xs):
            k, o = xs
            for prog in programs:
                carry = _apply_one(jnp, dims, prog, carry, k == prog.code, o)
            return carry, None
        out, _ = lax.scan(step, c, (kcol, oi))
        return out

    def chunk(st, kcols, oi):
        return jax.vmap(per_instance, in_axes=(0, 0, None))(st, kcols, oi)

    return chunk


def _apply_opcode_one(jnp, lax, dims, prog, opc, c, sel, oi,
                      table=None, base_counts=None):
    """One lowered op on one instance's state dict, masked by ``sel`` --
    data-driven form: a ``fori_loop`` + ``switch`` interprets the int32
    opcode table instead of tracing each micro/aux entry, so the jaxpr
    does not grow with schedule depth.  Requires the stacked ``slots``
    state layout (see :func:`make_opcode_chunk_fn`).  Bit-identical to
    :func:`_apply_one`: same prologue, same effect order, same masked
    commits.

    ``table`` / ``base_counts`` default to trace constants from
    ``opc`` / ``prog``; the Pallas kernel passes them explicitly (a
    kernel cannot capture array constants)."""
    c, m, env = _op_prologue(jnp, dims, prog, c, sel, oi)
    # dense env vector for data-driven sym gathers (static keys)
    envv = jnp.zeros((N_SYM,), jnp.int32)
    for k, v in env.items():
        envv = envv.at[k].set(v)
    if table is None:
        table = jnp.asarray(opc.table)          # (R, 5) int32
    if base_counts is None:
        base_counts = jnp.asarray(prog.base_counts.astype(np.int32))
    epoch = c["epoch"]
    one, zero = jnp.uint8(1), jnp.uint8(0)

    def row_step(r, t):
        (cached, finval, everfl, vtouched, persisted,
         limbo_a, limbo_e, limbo_k, nlimbo, slots, cdelta) = t
        row = table[r]
        kind, amode, aval, off, imm = (row[0], row[1], row[2], row[3],
                                       row[4])
        bound = envv[jnp.clip(aval, 0, N_SYM - 1)] + off
        a = jnp.where(amode == 1, bound, aval)
        ln = a // LINE_WORDS

        def b_nop(t):
            return t

        def b_class_p(t):
            (ca, fi, ev_, vt, pe, la, le, lk, nl, sl, cd) = t
            ev = jnp.where(ca[ln] == 1, EV_HIT,
                           jnp.where(fi[ln] == 1, EV_POSTFLUSH,
                                     jnp.where(ev_[ln] == 1, EV_COLD_NVM,
                                               EV_COLD_DRAM)))
            return (ca.at[ln].set(one), fi.at[ln].set(zero), ev_, vt, pe,
                    la, le, lk, nl, sl, cd.at[ev].add(1))

        def b_class_v(t):
            (ca, fi, ev_, vt, pe, la, le, lk, nl, sl, cd) = t
            ev = jnp.where(vt[a] == 1, EV_HIT, EV_DRAM)
            return (ca, fi, ev_, vt.at[a].set(one), pe, la, le, lk, nl, sl,
                    cd.at[ev].add(1))

        def b_st_inval(t):
            (ca, fi, ev_, vt, pe, la, le, lk, nl, sl, cd) = t
            return (ca.at[ln].set(zero), fi.at[ln].set(one),
                    ev_.at[ln].set(one), vt, pe, la, le, lk, nl, sl, cd)

        def b_st_everfl(t):
            (ca, fi, ev_, vt, pe, la, le, lk, nl, sl, cd) = t
            return (ca, fi, ev_.at[ln].set(one), vt, pe, la, le, lk, nl,
                    sl, cd)

        def b_recache(t):
            (ca, fi, ev_, vt, pe, la, le, lk, nl, sl, cd) = t
            return (ca.at[ln].set(one), fi.at[ln].set(zero), ev_, vt, pe,
                    la, le, lk, nl, sl, cd)

        def b_limbo(t):
            (ca, fi, ev_, vt, pe, la, le, lk, nl, sl, cd) = t
            return (ca, fi, ev_, vt, pe, la.at[nl].set(a),
                    le.at[nl].set(epoch), lk.at[nl].set(imm.astype(lk.dtype)),
                    nl + 1, sl, cd)

        def b_slot(t):
            (ca, fi, ev_, vt, pe, la, le, lk, nl, sl, cd) = t
            return (ca, fi, ev_, vt, pe, la, le, lk, nl, sl.at[imm].set(a),
                    cd)

        def b_pdiscard(t):
            (ca, fi, ev_, vt, pe, la, le, lk, nl, sl, cd) = t
            return (ca, fi, ev_, vt, pe.at[ln].set(zero), la, le, lk, nl,
                    sl, cd)

        def b_padd(t):
            (ca, fi, ev_, vt, pe, la, le, lk, nl, sl, cd) = t
            return (ca, fi, ev_, vt, pe.at[ln].set(one), la, le, lk, nl,
                    sl, cd)

        branches = [b_nop] * N_OPC
        branches[OPC_CLASS_P] = b_class_p
        branches[OPC_CLASS_V] = b_class_v
        branches[OPC_ST_INVAL] = b_st_inval
        branches[OPC_ST_EVERFL] = b_st_everfl
        branches[OPC_RECACHE] = b_recache
        branches[OPC_LIMBO] = b_limbo
        branches[OPC_SLOT] = b_slot
        branches[OPC_PDISCARD] = b_pdiscard
        branches[OPC_PADD] = b_padd
        return lax.switch(kind, branches, t)

    t = (c["cached"], c["finval"], c["everfl"], c["vtouched"],
         c["persisted"], c["limbo_a"], c["limbo_e"], c["limbo_k"],
         c["nlimbo"], c["slots"], base_counts)
    # micro rows, then the logical FIFO update, then aux rows -- the same
    # effect order as _apply_one / the numpy stepper
    t = lax.fori_loop(0, opc.n_micro, row_step, t)
    cap = dims.cap
    length, head = c["length"], c["head"]
    if prog.code == KIND_ENQ:
        pos = (head + length) % cap
        new_p = env[E_NEW_P] if prog.allocs_p else jnp.int32(0)
        new_v = env[E_NEW_V] if prog.allocs_v else jnp.int32(0)
        c["ring_p"] = jnp.where(m, c["ring_p"].at[pos].set(new_p),
                                c["ring_p"])
        c["ring_v"] = jnp.where(m, c["ring_v"].at[pos].set(new_v),
                                c["ring_v"])
        c["length"] = jnp.where(m, length + 1, length)
    else:
        c["dummy_p"] = jnp.where(m, env[E_NEXT_P], c["dummy_p"])
        c["dummy_v"] = jnp.where(m, env[E_NEXT_V], c["dummy_v"])
        c["head"] = jnp.where(m, (head + 1) % cap, head)
        c["length"] = jnp.where(m, length - 1, length)
    t = lax.fori_loop(opc.n_micro, opc.n_rows, row_step, t)
    (cached, finval, everfl, vtouched, persisted,
     limbo_a, limbo_e, limbo_k, nlimbo, slots, cdelta) = t
    c["counts"] = jnp.where(m, c["counts"] + cdelta, c["counts"])
    for key, val in (("cached", cached), ("finval", finval),
                     ("everfl", everfl), ("vtouched", vtouched),
                     ("persisted", persisted), ("limbo_a", limbo_a),
                     ("limbo_e", limbo_e), ("limbo_k", limbo_k),
                     ("nlimbo", nlimbo), ("slots", slots)):
        c[key] = jnp.where(m, val, c[key])
    return c


def make_opcode_chunk_fn(jax, programs, dims):
    """Opcode-interpreting variant of :func:`make_chunk_fn` -- same
    ``chunk(st, kcols, oi)`` signature and the same state-dict layout
    outside the call (``slot_<attr>`` keys are stacked into a ``slots``
    matrix around the vmapped scan).  The jit trace holds one
    ``row_step`` body per op kind regardless of schedule depth."""
    import jax.numpy as jnp
    from jax import lax

    progs = [(p, encode_program(p, dims.slot_attrs)) for p in programs]

    def per_instance(c, kcol, oi):
        def step(carry, xs):
            k, o = xs
            for prog, opc in progs:
                carry = _apply_opcode_one(jnp, lax, dims, prog, opc, carry,
                                          k == prog.code, o)
            return carry, None
        out, _ = lax.scan(step, c, (kcol, oi))
        return out

    def chunk(st, kcols, oi):
        st = dict(st)
        if dims.slot_attrs:
            st["slots"] = jnp.stack(
                [st.pop("slot_" + a) for a in dims.slot_attrs], axis=-1)
        else:
            st["slots"] = jnp.zeros((kcols.shape[0], 1), jnp.int32)
        out = jax.vmap(per_instance, in_axes=(0, 0, None))(st, kcols, oi)
        slots = out.pop("slots")
        for i, a in enumerate(dims.slot_attrs):
            out["slot_" + a] = slots[:, i]
        return out

    return chunk


class JaxBackend:
    """Device-resident fleet state; same protocol as NumpyBackend."""
    name = "jax"

    def __init__(self, template: Template, state: FleetState,
                 devices: int = 8):
        import jax
        import jax.numpy as jnp
        self.jax, self.jnp = jax, jnp
        self.t = template
        self.n = state.n
        self._setup_layout(state)

        def put(a, pad_value=None):
            pad = self.npad - self.n
            if pad:
                tile = (np.repeat(a[:1], pad, axis=0) if pad_value is None
                        else np.full((pad,) + a.shape[1:], pad_value,
                                     dtype=a.dtype))
                a = np.concatenate([a, tile], axis=0)
            return self._put(a)

        st = {}
        for name in _ARRAY_FIELDS:
            st[name] = put(getattr(state, name))
        for name in _SCALAR_FIELDS:
            pad_value = False if name == "active" else None
            st[name] = put(getattr(state, name), pad_value)
        st["counts"] = put(state.counts.astype(np.int32))
        for attr, arr in state.slots.items():
            st["slot_" + attr] = put(arr)
        self.st = st
        self._fn = self._make_fn()

    def _setup_layout(self, state: FleetState) -> None:
        """Instance-axis padding + sharding.  The base backend shards a 1D
        mesh over every host device; subclasses override."""
        from jax.sharding import Mesh, NamedSharding, PartitionSpec
        ndev = len(self.jax.devices())
        self.npad = -(-state.n // ndev) * ndev
        mesh = Mesh(np.array(self.jax.devices()), ("i",))
        self.sharding = NamedSharding(mesh, PartitionSpec("i"))

    def _put(self, a):
        if self.sharding is None:
            return self.jax.device_put(a)
        return self.jax.device_put(a, self.sharding)

    def _make_fn(self):
        return self.jax.jit(make_chunk_fn(self.jax, self.t.programs,
                                          self.t.dims),
                            donate_argnums=(0,))

    def run_chunk(self, kinds: np.ndarray, start: int) -> None:
        C = kinds.shape[0]
        kc = np.zeros((self.npad, C), dtype=np.uint8)
        kc[:self.n] = kinds.T
        kc = self._put(kc)
        oi = self.jnp.arange(start, start + C, dtype=self.jnp.int32)
        self.st = self._fn(self.st, kc, oi)

    def poll(self):
        bail_at = np.asarray(self.st["bail_at"])[:self.n]
        active = np.asarray(self.st["active"])[:self.n]
        fresh = (~active) & (bail_at >= 0)
        return np.nonzero(fresh)[0], bail_at

    def rejoin(self, i: int, row: dict) -> None:
        st = dict(self.st)
        for name, val in row.items():
            if name == "slots":
                for attr, v in val.items():
                    st["slot_" + attr] = st["slot_" + attr].at[i].set(v)
            elif name == "counts":
                st["counts"] = st["counts"].at[i].set(
                    val.astype(np.int32))
            else:
                st[name] = st[name].at[i].set(val)
        st["active"] = st["active"].at[i].set(True)
        st["bail_at"] = st["bail_at"].at[i].set(-1)
        self.st = st

    def retire_resident(self, i: int) -> None:
        from .runner import RESIDENT
        st = dict(self.st)
        st["active"] = st["active"].at[i].set(False)
        st["bail_at"] = st["bail_at"].at[i].set(RESIDENT)
        self.st = st

    def counts(self) -> np.ndarray:
        return np.asarray(self.st["counts"])[:self.n].astype(np.int64)


class OpcodeJaxBackend(JaxBackend):
    """JaxBackend with the opcode-interpreting chunk fn: identical state
    layout and protocol, but the jit trace no longer scales with schedule
    depth -- the win is compile time on deep schedules, at some per-step
    cost (a ``switch`` per table row instead of straight-line code)."""
    name = "jax-opcode"

    def _make_fn(self):
        return self.jax.jit(make_opcode_chunk_fn(self.jax, self.t.programs,
                                                 self.t.dims),
                            donate_argnums=(0,))


class PallasBackend(JaxBackend):
    """Opcode interpreter as a Pallas kernel: instances map to the grid in
    blocks, each program id steps its block's state rows through the whole
    chunk.  On hosts without an accelerator the kernel runs in Pallas
    interpret mode (still the kernel's dataflow, evaluated by XLA:CPU) --
    that is what CI's ``fleet-pallas-smoke`` exercises; on TPU the same
    kernel compiles to Mosaic.  Single-device: the grid replaces the mesh
    sharding of the base backend."""
    name = "pallas"
    PHASE_COMPILED = "kernel-launch"
    PHASE_INTERPRET = "kernel-interpret"
    block = 128

    def _setup_layout(self, state: FleetState) -> None:
        self.sharding = None
        self.interpret = self.jax.default_backend() != "tpu"
        self.chunk_phase = (self.PHASE_INTERPRET if self.interpret
                            else self.PHASE_COMPILED)
        # shrink the block for tiny fleets (tests): padding 5 instances to
        # a 128-row block would cost 25x the interpret-mode work
        self.block = min(self.block, -(-state.n // 8) * 8)
        self.npad = -(-state.n // self.block) * self.block

    def _make_fn(self):
        from ..kernels.fleet_step import make_pallas_chunk_fn
        return make_pallas_chunk_fn(self.jax, self.t.programs, self.t.dims,
                                    block=self.block,
                                    interpret=self.interpret)
