"""Fleet executor: the schedule compiler's output, run as array data.

PR 5's :func:`repro.core.opsched.compile_schedule` reduces each queue's
steady-state enqueue/dequeue to one pre-reduced event-count vector plus a
short effect program.  This package lowers that program once more -- into a
**Stats-only vector micro-program** over integer state arrays -- and then
runs 10k-1M *independent queue instances* (one per simulated user/tenant,
one thread each) as a single batched array program:

* :mod:`repro.fleet.lowering` -- ``CompiledOp`` -> :class:`FleetProgram`
  (classification points, line-state updates, guards, allocator and
  epoch-reclamation effects; value stores drop out because per-instance
  ``Stats`` never depend on stored values);
* :mod:`repro.fleet.state` -- build one warmed template harness, export its
  integer state, replicate it across N instances (construction is
  deterministic, so every instance shares the template's address layout);
* :mod:`repro.fleet.stepper` -- the numpy reference stepper (mask-vectorized
  over instances; also the fallback when jax is unavailable);
* :mod:`repro.fleet.jaxexec` -- the jax backends: a per-instance step
  function, ``jax.vmap`` over the fleet, ``lax.scan`` over the op stream,
  sharded across forced host devices
  (``XLA_FLAGS=--xla_force_host_platform_device_count=8``).  Three
  flavors: ``jax`` (unrolled trace), ``jax-opcode`` (interprets the
  fixed-width opcode tables emitted by the lowering, so compile time is
  independent of schedule depth) and ``pallas`` (the same opcode
  interpreter as a Pallas chunk kernel,
  :mod:`repro.kernels.fleet_step`);
* :mod:`repro.fleet.runner` -- chunked execution with the bail/rejoin
  protocol: instances that hit a fast-path bail condition fall out of the
  vector program into a real per-instance harness (the existing
  :class:`repro.core.opsched.FastPathExecutor` path) and rejoin at the next
  chunk boundary.

The correctness gate is the same one every layer of this repo carries:
per-instance fleet Stats (every counter *and* ``time_ns``) are
**bit-identical** to N independent :meth:`repro.core.harness.QueueHarness.
run_batched` runs (``tests/test_fleet_equivalence.py``).  See docs/fleet.md.
"""
from .runner import (FleetConfig, FleetResult, build_fleet, check_instances,
                     ensure_host_devices, fleet_kinds, run_fleet)
from .state import build_template

__all__ = [
    "FleetConfig", "FleetResult", "build_fleet", "build_template",
    "check_instances", "ensure_host_devices", "fleet_kinds", "run_fleet",
]
