"""Fleet runner: chunked execution with the bail/rejoin protocol.

The fleet advances in chunks of ``FleetConfig.chunk`` plan steps.  Inside a
chunk every active instance runs purely as array data (numpy reference
stepper or the jax backend).  At each chunk boundary the runner polls for
instances that hit a bail condition; each one is **replayed** on a real
per-instance harness -- the same ``run_batched`` path every benchmark uses,
with the compiled fast path handling the steady-state prefix and real
per-primitive execution handling the bailing op -- up to the chunk
boundary, then **rejoined**: its integer state is exported back into the
fleet arrays (:func:`repro.fleet.state.export_instance`).  An instance
whose layout diverged from the template (grew an allocation area or a
volatile chunk) cannot rejoin; it finishes its plan on the Python path and
its final counts are merged at the end ("resident").

Replay-from-op-0 is exact, not approximate: instance plans are
deterministic (one seeded generator), construction is deterministic, and
splitting one plan across successive ``run_batched`` calls on one harness
is bit-identical to a single call -- so the replayed instance passes
through exactly the states the vector program retired, then crosses the
bail on the real path.

Plans are **length-clamped** by default (a dequeue is only scheduled while
the tracked queue is non-empty), so a well-sized fleet takes zero bails;
the bail machinery is exercised deliberately by the equivalence tests,
which inject unclamped plans via ``run_fleet(cfg, kinds=...)``.
"""
from __future__ import annotations

import os
import sys
import time
from dataclasses import dataclass, field, replace
from typing import List, Optional

import numpy as np

from ..core.harness import ALL_QUEUES
from ..core.nvram import N_EV, Stats
from .state import (DEFAULT_PREFILL, Template, area_nodes_for, build_template,
                    export_instance, make_instance_harness, replicate)
from .stepper import run_chunk_numpy

RESIDENT = -2      # bail_at marker: finished out-of-fleet, counts merged


@dataclass(frozen=True)
class FleetConfig:
    """One fleet cell: a queue x model x scale point."""
    queue: str = "DurableMSQ"
    model: str = "optane-clwb"
    instances: int = 10_000
    ops: int = 256                  # plan steps per instance
    prefill: int = DEFAULT_PREFILL
    seed: int = 0
    p_deq: float = 0.5
    chunk: int = 64                 # plan steps per vector chunk
    backend: str = "auto"           # auto | numpy | jax | jax-opcode | pallas
    devices: int = 8                # forced host devices for the jax mesh
    batch: int = 0                  # instances per state batch (0 = all)
    contention: str = "off"         # CSV label; one thread per instance, so
                                    # contended counts == uncontended ones


@dataclass
class Fleet:
    cfg: FleetConfig
    template: Template
    kinds: np.ndarray               # (ops, instances) uint8: 0 enq, 1 deq


@dataclass
class FleetResult:
    cfg: FleetConfig
    backend: str                    # backend actually used
    devices: int
    counts: np.ndarray              # (instances, N_EV) int64
    kinds: np.ndarray
    bails: int                      # bail events (replay+rejoin round trips)
    residents: int                  # instances that finished on Python path
    build_s: float
    run_s: float
    template: Template = field(repr=False, default=None)

    @property
    def total_ops(self) -> int:
        return self.cfg.instances * self.cfg.ops

    @property
    def ops_per_sec(self) -> float:
        return self.total_ops / self.run_s if self.run_s > 0 else 0.0

    def stats_of(self, i: int) -> Stats:
        return self.template.harness.nvram._stats_of(self.counts[i])

    def aggregate(self) -> Stats:
        """Fleet-aggregate Stats: the elementwise sum of every instance's
        counters (time_ns = total simulated nanoseconds across the fleet)."""
        return self.template.harness.nvram._stats_of(self.counts.sum(axis=0))


def ensure_host_devices(n: int = 8) -> bool:
    """Force n XLA host devices (the SNIPPETS.md CPU-mesh trick).  Only
    effective before jax's first import: returns False (and changes
    nothing) if jax is already loaded."""
    if "jax" in sys.modules:
        return False
    flags = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in flags:
        os.environ["XLA_FLAGS"] = (
            flags + f" --xla_force_host_platform_device_count={n}").strip()
    return True


def fleet_kinds(instances: int, ops: int, seed: int = 0,
                prefill: int = DEFAULT_PREFILL,
                p_deq: float = 0.5) -> np.ndarray:
    """Per-instance op plans as a (ops, instances) uint8 matrix
    (0 = enqueue, 1 = dequeue), drawn from one seeded generator and
    length-clamped so no instance dequeues an empty queue.  Deterministic
    in (instances, ops, seed, prefill, p_deq) -- the equivalence check
    regenerates the same plans independently."""
    rng = np.random.default_rng(seed)
    kinds = np.zeros((ops, instances), dtype=np.uint8)
    length = np.full(instances, prefill, dtype=np.int64)
    for c in range(ops):
        deq = (rng.random(instances) < p_deq) & (length > 0)
        kinds[c] = deq
        length += np.where(deq, -1, 1)
    return kinds


def plan_of(kinds: np.ndarray, i: int, start: int = 0,
            end: Optional[int] = None) -> List[tuple]:
    """Instance i's plan slice in run_batched format."""
    col = kinds[start:end, i]
    base = start
    return [("deq", None) if k else ("enq", ("fleet", int(i), base + t))
            for t, k in enumerate(col)]


def build_fleet(cfg: FleetConfig) -> Fleet:
    """Build the warmed template (one real harness), lower its schedules,
    and draw every instance's plan."""
    template = build_template(cfg.queue, cfg.model, cfg.ops, cfg.prefill)
    kinds = fleet_kinds(cfg.instances, cfg.ops, seed=cfg.seed,
                        prefill=cfg.prefill, p_deq=cfg.p_deq)
    return Fleet(cfg=cfg, template=template, kinds=kinds)


class NumpyBackend:
    """Mask-vectorized numpy stepper over one FleetState batch."""
    name = "numpy"

    def __init__(self, template: Template, state):
        self.t = template
        self.st = state

    def run_chunk(self, kinds: np.ndarray, start: int) -> None:
        run_chunk_numpy(self.t.programs, self.t.dims, self.st, kinds, start)

    def poll(self):
        st = self.st
        fresh = (~st.active) & (st.bail_at >= 0)
        return np.nonzero(fresh)[0], st.bail_at

    def rejoin(self, i: int, row: dict) -> None:
        self.st.set_row(i, row)
        self.st.active[i] = True
        self.st.bail_at[i] = -1

    def retire_resident(self, i: int) -> None:
        self.st.active[i] = False
        self.st.bail_at[i] = RESIDENT

    def counts(self) -> np.ndarray:
        return self.st.counts


def _resolve_backend(name: str, devices: int):
    """-> (backend_name, device_count).  'auto' prefers jax, falls back to
    numpy if jax is unavailable; the explicit jax-family names
    ('jax', 'jax-opcode', 'pallas') raise if jax is missing.  Forcing the
    host-device count only works if jax has not been imported yet
    (harmless otherwise)."""
    if name == "numpy":
        return "numpy", 1
    try:
        ensure_host_devices(devices)
        import jax
        if name == "pallas":
            return "pallas", 1          # grid-parallel, single device
        if name == "jax-opcode":
            return "jax-opcode", len(jax.devices())
        return "jax", len(jax.devices())
    except Exception:
        if name != "auto":
            raise
        return "numpy", 1


def _make_backend(name: str, template: Template, state, devices: int):
    if name == "jax":
        from .jaxexec import JaxBackend
        return JaxBackend(template, state, devices)
    if name == "jax-opcode":
        from .jaxexec import OpcodeJaxBackend
        return OpcodeJaxBackend(template, state, devices)
    if name == "pallas":
        from .jaxexec import PallasBackend
        return PallasBackend(template, state, devices)
    return NumpyBackend(template, state)


def _replay(template: Template, kinds: np.ndarray, i: int, upto: int):
    """Fresh real harness for instance i, run through plan ops [0, upto)."""
    h = make_instance_harness(
        ALL_QUEUES[template.queue_name], template.model_name,
        area_nodes_for(template.ops, template.prefill), template.prefill)
    plan = plan_of(kinds, i, 0, upto)
    if plan:
        h.run_batched([plan])
    return h


def _final_counts(h) -> np.ndarray:
    h.nvram._drain()
    return h.nvram._counts[0].astype(np.int64).copy()


class _NullScope:
    """No-op stand-ins so the runner's hot loop has one shape whether or
    not a profiler/heartbeat is attached (observation-only contract)."""

    def push(self, name):
        pass

    def pop(self):
        pass

    def configure(self, total_chunks=0, total_ops=0):
        pass

    def advance(self, chunks=0, ops=0, bails=0, rejoins=0, residents=0):
        pass

    def emit(self, now=None, final=False):
        pass


_NULL = _NullScope()


def _run_batch(template: Template, cfg: FleetConfig, kinds: np.ndarray,
               backend_name: str, devices: int, base: int,
               prof=_NULL, hb=_NULL):
    """Run one contiguous instance batch; kinds columns are the batch's
    plans, ``base`` the batch's first global instance id (labels only).
    ``prof``/``hb`` are an optional phase profiler and heartbeat (both
    observation-only; defaults are no-ops)."""
    n = kinds.shape[1]
    prof.push("lowering")
    state = replicate(template.row, template.dims, n)
    backend = _make_backend(backend_name, template, state, devices)
    prof.pop()
    resident_counts = {}
    bails = residents = 0
    chunk_phase = getattr(backend, "chunk_phase", "chunk-step")
    for start in range(0, cfg.ops, cfg.chunk):
        end = min(start + cfg.chunk, cfg.ops)
        prof.push(chunk_phase)
        backend.run_chunk(kinds[start:end], start)
        prof.pop()
        prof.push("poll")
        ids, _ = backend.poll()
        prof.pop()
        rejoins = 0
        for i in ids.tolist():
            bails += 1
            prof.push("bail-replay")
            h = _replay(template, kinds, i, end)
            row = export_instance(h, template.dims)
            if row is not None:
                backend.rejoin(i, row)
                rejoins += 1
                prof.pop()
            else:
                prof.pop()
                residents += 1
                prof.push("resident-replay")
                rest = plan_of(kinds, i, end, cfg.ops)
                if rest:
                    h.run_batched([rest])
                resident_counts[i] = _final_counts(h)
                backend.retire_resident(i)
                prof.pop()
        hb.advance(chunks=1, ops=n * (end - start), bails=len(ids),
                   rejoins=rejoins,
                   residents=len(ids) - rejoins)
    counts = np.asarray(backend.counts(), dtype=np.int64).copy()
    for i, c in resident_counts.items():
        counts[i] = c
    return counts, bails, residents


def run_fleet(cfg: FleetConfig, fleet: Optional[Fleet] = None,
              kinds: Optional[np.ndarray] = None,
              profile=None, heartbeat=None) -> FleetResult:
    """Build (unless given) and run one fleet cell.  ``kinds`` overrides
    the generated plans (the bail/rejoin tests inject unclamped plans).

    ``profile`` attaches an observation-only phase profiler (phases:
    ``lowering``, ``chunk-step``, ``poll``, ``bail-replay``,
    ``resident-replay``; the pallas backend replaces ``chunk-step`` with
    its ``chunk_phase`` -- ``kernel-launch`` or ``kernel-interpret``);
    ``heartbeat`` a :class:`repro.obs.Heartbeat`
    that emits periodic progress lines.  Neither changes counts."""
    prof = profile if profile is not None else _NULL
    hb = heartbeat if heartbeat is not None else _NULL
    t0 = time.perf_counter()
    prof.push("lowering")
    if fleet is None:
        fleet = build_fleet(cfg)
    if kinds is not None:
        kinds = np.asarray(kinds, dtype=np.uint8)
        if kinds.shape != (cfg.ops, cfg.instances):
            raise ValueError(
                f"kinds shape {kinds.shape} != {(cfg.ops, cfg.instances)}")
        fleet = replace(fleet, kinds=kinds)
    backend_name, devices = _resolve_backend(cfg.backend, cfg.devices)
    prof.pop()
    build_s = time.perf_counter() - t0

    t1 = time.perf_counter()
    bsz = cfg.batch or cfg.instances
    n_batches = (cfg.instances + bsz - 1) // bsz
    chunks_per_batch = (cfg.ops + cfg.chunk - 1) // cfg.chunk
    hb.configure(total_chunks=n_batches * chunks_per_batch,
                 total_ops=cfg.instances * cfg.ops)
    counts = np.zeros((cfg.instances, N_EV), dtype=np.int64)
    bails = residents = 0
    for s in range(0, cfg.instances, bsz):
        e = min(s + bsz, cfg.instances)
        c, b, r = _run_batch(fleet.template, cfg, fleet.kinds[:, s:e],
                             backend_name, devices, s, prof=prof, hb=hb)
        counts[s:e] = c
        bails += b
        residents += r
    run_s = time.perf_counter() - t1
    if heartbeat is not None:
        hb.emit(final=True)
    return FleetResult(cfg=cfg, backend=backend_name, devices=devices,
                       counts=counts, kinds=fleet.kinds, bails=bails,
                       residents=residents, build_s=build_s, run_s=run_s,
                       template=fleet.template)


def check_instances(result: FleetResult, sample: int = 8, seed: int = 1234,
                    contention=None) -> List[dict]:
    """The correctness gate: re-run sampled instances independently on real
    harnesses (``run_batched`` with the same plan) and compare full Stats
    -- every counter and the derived ``time_ns`` -- for bit-identity."""
    cfg, t = result.cfg, result.template
    k = min(sample, cfg.instances)
    rng = np.random.default_rng(seed)
    ids = sorted(rng.choice(cfg.instances, size=k, replace=False).tolist())
    nv = t.harness.nvram
    rows = []
    for i in ids:
        h = make_instance_harness(
            ALL_QUEUES[t.queue_name], t.model_name,
            area_nodes_for(cfg.ops, cfg.prefill), cfg.prefill)
        plan = plan_of(result.kinds, i, 0, cfg.ops)
        if plan:
            h.run_batched([plan], contention=contention)
        ref = _final_counts(h)
        got = result.counts[i]
        ok = bool(np.array_equal(ref, got)) \
            and nv._stats_of(got) == nv._stats_of(ref)
        rows.append({"instance": i, "ok": ok,
                     "fleet": nv._stats_of(got), "ref": nv._stats_of(ref)})
    return rows
