"""Numpy reference stepper: the fleet's semantics, mask-vectorized.

Executes one lowered op (:class:`repro.fleet.lowering.FleetProgram`) for
every active instance whose next plan step is that op kind, with boolean
masks standing in for control flow.  The order of effects per op mirrors
the generated fast-path function exactly (``repro.core.opsched.
generate_fast_fn``):

1. bail detection (empty dequeue, guard failures, allocator refills) --
   **before** any state change, so a bailing op leaves its instance
   untouched for the Python-path replay;
2. ``op_begin``: epoch announce + the 64-op advance cadence (limbo entries
   two epochs stale move to the free stacks, in retirement order);
3. env binding (FIFO tail/head records, guard slot) + allocations
   (free-stack pop, else cursor bump);
4. the classification/state micro-ops, charging dynamic outcomes;
5. the static base-count vector;
6. the logical FIFO update, then aux effects (retire -> limbo, slot
   stores, persisted-set bits) -- in that order, as in the fast path.

This backend is the semantic reference for :mod:`repro.fleet.jaxexec`
(cross-checked by ``tests/test_fleet_equivalence.py``) and the fallback
when jax is unavailable.  The effect order spelled out above is a
three-way contract: the unrolled jax stepper, the opcode interpreter
(``jax-opcode``) and the Pallas chunk kernel (``pallas``) all replay it
exactly -- anything reordered here must be reordered there, and the
opcode encoding in :mod:`repro.fleet.lowering` must keep round-tripping.
"""
from __future__ import annotations

import numpy as np

from ..core.nvram import (EV_COLD_DRAM, EV_COLD_NVM, EV_DRAM, EV_HIT,
                          EV_POSTFLUSH, LINE_WORDS)
from ..core.opsched import NULL, ST_EVERFL, ST_INVAL
from .lowering import KIND_DEQ, KIND_ENQ, SYM, FleetPrograms
from .state import FleetDims, FleetState

E_NEW_P, E_NEW_V = SYM["new_p"], SYM["new_v"]
E_TAIL_P, E_TAIL_V = SYM["tail_p"], SYM["tail_v"]
E_HEAD_P, E_HEAD_V = SYM["head_p"], SYM["head_v"]
E_NEXT_P, E_NEXT_V = SYM["next_p"], SYM["next_v"]
E_PREV = SYM["prev"]

EPOCH_ADV_OPS = 64     # SSMem.op_begin's advance cadence


def run_chunk_numpy(programs: FleetPrograms, dims: FleetDims, st: FleetState,
                    kinds: np.ndarray, start_op: int) -> None:
    """Run ``kinds.shape[0]`` plan steps for all instances, in place.
    ``kinds[c, i]`` is instance i's op at global index ``start_op + c``
    (0 = enq, 1 = deq).  Instances that hit a bail condition record
    ``bail_at`` and go inactive for the rest of the chunk."""
    rows = np.arange(st.n)
    for c in range(kinds.shape[0]):
        k = kinds[c]
        for prog in programs:
            m = st.active & (k == prog.code)
            if m.any():
                _apply_op(prog, dims, st, m, rows, start_op + c)


def _advance(dims: FleetDims, st: FleetState, adv: np.ndarray) -> None:
    """SSMem._try_advance at one thread: announced == epoch, so the epoch
    always advances; limbo entries with ``ep + 2 <= min_e`` (min_e = the
    pre-advance epoch) free in retirement order."""
    min_e = st.epoch.copy()
    st.epoch[adv] += 1
    j = np.arange(dims.lcap)[None, :]
    inlimbo = j < st.nlimbo[:, None]
    fr = inlimbo & (st.limbo_e + 2 <= min_e[:, None]) & adv[:, None]
    if not fr.any():
        return
    is_p = st.limbo_k == 0
    for sel, stack, nname in ((fr & is_p, st.free_p, "nfree"),
                              (fr & ~is_p, st.vfree, "nvfree")):
        if not sel.any():
            continue
        nfree = getattr(st, nname)
        cnt = np.cumsum(sel, axis=1)
        dest = nfree[:, None] + cnt - 1
        ii, jj = np.nonzero(sel)
        stack[ii, dest[ii, jj]] = st.limbo_a[ii, jj]
        nfree += cnt[:, -1].astype(nfree.dtype)
    # compact the kept entries, preserving order
    keep = inlimbo & ~fr
    order = np.argsort(~keep, axis=1, kind="stable")
    chg = fr.any(axis=1)
    for arr in (st.limbo_a, st.limbo_e, st.limbo_k):
        arr[chg] = np.take_along_axis(arr, order, axis=1)[chg]
    st.nlimbo -= fr.sum(axis=1).astype(st.nlimbo.dtype)


def _apply_op(prog, dims: FleetDims, st: FleetState, m: np.ndarray,
              rows: np.ndarray, op_idx: int) -> None:
    cap = dims.cap
    # ---- tail record (enq env and the tail_persisted guard) -------------
    tail_p = tail_v = None
    needs_tail = prog.code == KIND_ENQ or any(
        g[0] == "tail_persisted" for g in prog.guards)
    if needs_tail:
        has = st.length > 0
        tpos = (st.head + np.maximum(st.length - 1, 0)) % cap
        tail_p = np.where(has, st.ring_p[rows, tpos], st.dummy_p)
        tail_v = np.where(has, st.ring_v[rows, tpos], st.dummy_v)
    # ---- bail detection (no state changed yet) --------------------------
    bail = np.zeros(st.n, dtype=bool)
    if prog.code == KIND_DEQ:
        bail |= st.length == 0
    for g in prog.guards:
        if g[0] == "slot_nonnull":
            bail |= st.slots[g[1]] == NULL
        else:                               # tail_persisted
            bail |= st.persisted[rows, tail_p // LINE_WORDS] == 0
    if prog.allocs_p:
        bail |= (st.nfree == 0) & (st.cursor >= dims.area_cap)
    if prog.allocs_v:
        # conservative fleet-only bail: a chunk refill would change the
        # address layout, so such instances run on the Python path
        bail |= (st.nvfree == 0) & (st.vcursor >= dims.chunk_cap)
    newly = m & bail
    if newly.any():
        st.bail_at[newly] = op_idx
        st.active &= ~newly
        m = m & ~newly
        if not m.any():
            return
    # ---- op_begin: epoch machinery --------------------------------------
    if prog.uses_ssmem:
        st.opsctr[m] += 1
        adv = m & (st.opsctr >= EPOCH_ADV_OPS)
        if adv.any():
            st.opsctr[adv] = 0
            _advance(dims, st, adv)
    # ---- env + allocations ----------------------------------------------
    env = {}
    if prog.code == KIND_ENQ:
        env[E_TAIL_P], env[E_TAIL_V] = tail_p, tail_v
    else:
        hpos = st.head % cap
        env[E_HEAD_P] = st.dummy_p.copy()
        env[E_HEAD_V] = st.dummy_v.copy()
        env[E_NEXT_P] = st.ring_p[rows, hpos]
        env[E_NEXT_V] = st.ring_v[rows, hpos]
    for attr in prog.slot_attrs:
        env[E_PREV] = st.slots[attr].copy()
    if prog.allocs_p:
        use = m & (st.nfree > 0)
        top = st.free_p[rows, np.maximum(st.nfree - 1, 0)]
        env[E_NEW_P] = np.where(
            use, top,
            dims.area_base + st.cursor.astype(np.int64) * LINE_WORDS
        ).astype(np.int32)
        st.nfree[use] -= 1
        st.cursor[m & ~use] += 1
    if prog.allocs_v:
        use = m & (st.nvfree > 0)
        top = st.vfree[rows, np.maximum(st.nvfree - 1, 0)]
        env[E_NEW_V] = np.where(
            use, top,
            dims.chunk_base + st.vcursor.astype(np.int64) * dims.node_words
        ).astype(np.int32)
        st.nvfree[use] -= 1
        st.vcursor[m & ~use] += 1
    # ---- micro-ops -------------------------------------------------------
    im = rows[m]
    counts = st.counts
    for ins in prog.micro:
        tag, ref = ins[0], ins[1]
        if ref.mode == "const":
            a = ref.const
        else:
            a = env[ref.sym][im] + ref.off
        if tag == "class_p":
            ln = a // LINE_WORDS
            c = st.cached[im, ln]
            f = st.finval[im, ln]
            e = st.everfl[im, ln]
            ev = np.where(c == 1, EV_HIT,
                          np.where(f == 1, EV_POSTFLUSH,
                                   np.where(e == 1, EV_COLD_NVM,
                                            EV_COLD_DRAM)))
            counts[im, ev] += 1
            st.cached[im, ln] = 1
            st.finval[im, ln] = 0
        elif tag == "class_v":
            t = st.vtouched[im, a]
            counts[im, np.where(t == 1, EV_HIT, EV_DRAM)] += 1
            st.vtouched[im, a] = 1
        elif tag == "state":
            mode = ins[2]
            ln = a // LINE_WORDS
            if mode == ST_INVAL:
                st.cached[im, ln] = 0
                st.finval[im, ln] = 1
                st.everfl[im, ln] = 1
            elif mode == ST_EVERFL:
                st.everfl[im, ln] = 1
            else:                           # ST_RECACHE
                st.cached[im, ln] = 1
                st.finval[im, ln] = 0
        else:                               # "line"
            ln = a // LINE_WORDS
            st.cached[im, ln] = 1
            st.finval[im, ln] = 0
    # ---- static counts ---------------------------------------------------
    counts[im] += prog.base_counts
    # ---- logical FIFO ----------------------------------------------------
    if prog.code == KIND_ENQ:
        pos = (st.head + st.length) % cap
        st.ring_p[im, pos[im]] = env[E_NEW_P][im] if prog.allocs_p else 0
        st.ring_v[im, pos[im]] = env[E_NEW_V][im] if prog.allocs_v else 0
        st.length[m] += 1
    else:
        st.dummy_p[m] = env[E_NEXT_P][m]
        st.dummy_v[m] = env[E_NEXT_V][m]
        st.head[m] = (st.head[m] + 1) % cap
        st.length[m] -= 1
    # ---- aux effects -----------------------------------------------------
    for ax in prog.aux:
        t0 = ax[0]
        if t0 == "limbo":
            pos = st.nlimbo[im]
            st.limbo_a[im, pos] = env[ax[1]][im]
            st.limbo_e[im, pos] = st.epoch[im]
            st.limbo_k[im, pos] = 0 if ax[2] == "p" else 1
            st.nlimbo[m] += 1
        elif t0 == "slot":
            st.slots[ax[1]][m] = env[ax[2]][m]
        elif t0 == "pdiscard":
            st.persisted[im, env[ax[1]][im] // LINE_WORDS] = 0
        else:                               # padd
            for sym in ax[1]:
                st.persisted[im, env[sym][im] // LINE_WORDS] = 1
