"""Fleet state: one warmed template instance, replicated N times.

Queue construction is deterministic: building the same queue class on a
fresh engine always produces the same region layout, the same dummy node,
the same allocator cursors.  The fleet exploits this by building **one**
template harness (construction + prefill + warmup), exporting its integer
state, and replicating it across N instances -- every instance then shares
the template's address map, so the lowered programs' constant addresses are
valid fleet-wide.

What gets exported is exactly the state the Stats-only programs read or
write (see :mod:`repro.fleet.lowering`):

* per-line cached/finval/everfl bits and per-word volatile touched bits;
* the logical FIFO (pnode/vnode rings + dummy) -- the executor's
  ``(pnode, vnode, item, idx)`` records minus items/indices, which feed
  value stores only;
* ssmem state: free stack, area cursor, limbo ring, epoch, op counter
  (64-op advance cadence), and the VolatileAlloc twin;
* guard slots, the persisted set (as a line bitmap), per-thread counts.

``export_instance`` is also the **rejoin** path: after a bailed instance is
replayed on a real per-instance harness, its state is exported back into
the fleet arrays -- provided its layout still matches the template (an
instance that grew a new area/chunk mid-run stays resident on the Python
path; ``export_instance`` returns None for it).

The ``prefill + warmup`` protocol mirrors the benchmark harness: prefill
enqueues give dequeues something to consume, and one warmup
enqueue+dequeue pair retires the sentinel state that would otherwise make
every instance's first ops bail (NULL retire/flush slots, non-durable walk
anchors -- the fast path's documented warmup bails).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple

import numpy as np

from ..core.harness import ALL_QUEUES, QueueHarness
from ..core.nvram import LINE_WORDS, NVRAM
from ..core.opsched import NULL, FastPathExecutor
from .lowering import FleetPrograms, lower_queue

_VB = NVRAM._VOLATILE_BASE

DEFAULT_PREFILL = 10


@dataclass(frozen=True)
class FleetDims:
    """Template-wide constants every instance shares."""
    nl: int                  # persistent lines tracked
    nvw: int                 # volatile words tracked (>= 1)
    cap: int                 # FIFO ring capacity
    fcap: int                # persistent free-stack capacity
    vfcap: int               # volatile free-stack capacity
    lcap: int                # limbo ring capacity
    area_base: int           # the single ssmem area's base address
    area_cap: int            # area_nodes
    chunk_base: int          # volatile chunk base offset (-1: no valloc)
    chunk_cap: int           # usable chunk nodes (conservative)
    node_words: int          # valloc node width
    p_brk: int               # template persistent brk (layout fingerprint)
    v_brk: int               # template volatile brk
    slot_attrs: Tuple[str, ...]
    needs_persisted: bool
    uses_valloc: bool
    uses_ssmem: bool


@dataclass
class FleetState:
    """Struct-of-arrays over N instances (numpy, instance axis first)."""
    n: int
    dims: FleetDims
    cached: np.ndarray       # uint8 [N, nl]
    finval: np.ndarray
    everfl: np.ndarray
    persisted: np.ndarray    # uint8 [N, nl] (or [N, 1] when unused)
    vtouched: np.ndarray     # uint8 [N, nvw]
    ring_p: np.ndarray       # int32 [N, cap]
    ring_v: np.ndarray
    free_p: np.ndarray       # int32 [N, fcap]
    vfree: np.ndarray        # int32 [N, vfcap]
    limbo_a: np.ndarray      # int32 [N, lcap]
    limbo_e: np.ndarray      # int32 [N, lcap]
    limbo_k: np.ndarray      # uint8 [N, lcap]  (0 = p, 1 = v)
    counts: np.ndarray       # int64 [N, N_EV]
    head: np.ndarray         # int32 [N] -- ring read position
    length: np.ndarray       # int32 [N] -- logical FIFO length
    dummy_p: np.ndarray
    dummy_v: np.ndarray
    nfree: np.ndarray
    cursor: np.ndarray
    nvfree: np.ndarray
    vcursor: np.ndarray
    nlimbo: np.ndarray
    epoch: np.ndarray
    opsctr: np.ndarray
    active: np.ndarray       # bool [N]
    bail_at: np.ndarray      # int32 [N]: global op index of first bail, -1
    slots: Dict[str, np.ndarray] = field(default_factory=dict)

    def set_row(self, i: int, row: dict) -> None:
        for name, val in row.items():
            if name == "slots":
                for attr, v in val.items():
                    self.slots[attr][i] = v
            else:
                getattr(self, name)[i] = val

    def get_counts(self, i: int) -> np.ndarray:
        return self.counts[i]


def make_instance_harness(queue_cls, model, area_nodes: int,
                          prefill: int = DEFAULT_PREFILL) -> QueueHarness:
    """The shared builder: the fleet template, the per-instance
    equivalence-check harnesses and the bail-replay harnesses all come
    from here, so construction + prefill + warmup are identical."""
    h = QueueHarness(queue_cls, nthreads=1, area_nodes=area_nodes,
                     model=model)
    for i in range(prefill):
        h.queue.enqueue(0, ("pre", i))
    # warmup: one enq+deq pair populates the per-thread retire/flush slots
    # and durable-walk anchors so instance op #1 doesn't warmup-bail
    h.queue.enqueue(0, ("warm", 0))
    h.queue.dequeue(0)
    return h


def area_nodes_for(ops: int, prefill: int = DEFAULT_PREFILL) -> int:
    """An area large enough that no instance ever hits a refill bail:
    total persistent allocations are bounded by dummy + prefill + warmup +
    one per op (frees only shrink demand)."""
    return prefill + ops + 16


@dataclass
class Template:
    queue_name: str
    model_name: str
    prefill: int
    ops: int
    harness: QueueHarness
    programs: FleetPrograms
    dims: FleetDims
    row: dict                      # exported instance-0 state


def derive_dims(h: QueueHarness, programs: FleetPrograms,
                ops: int) -> FleetDims:
    nv, q, mem = h.nvram, h.queue, h.mem
    nl = -(-nv._brk // LINE_WORDS)
    uses_ssmem = programs.enq.uses_ssmem or programs.deq.uses_ssmem
    valloc = getattr(q, "valloc", None)
    uses_valloc = valloc is not None
    if uses_valloc:
        chunk_abs = valloc._base[0]
        assert chunk_abs is not None, "valloc chunk not allocated at warmup"
        chunk_base = chunk_abs - _VB
        node_words = valloc.node_words
        chunk_cap = min(valloc.chunk_nodes, valloc._cursor[0] + ops + 4)
        nvw = chunk_base + chunk_cap * node_words
        if chunk_base + valloc.chunk_nodes * node_words < nv._vbrk - _VB:
            # chunk is not the last volatile region: track the full span
            nvw = nv._vbrk - _VB
    else:
        chunk_base, chunk_cap, node_words = -1, 0, 1
        nvw = nv._vbrk - _VB
    areas = mem._areas[0]
    # MSQ never allocates persistent nodes: no ssmem area at all
    assert len(areas) <= 1, "template must have at most one ssmem area"
    area_base = areas[0] if areas else 0
    area_cap = mem.area_nodes if areas else 0
    fifo_len = _walk_fifo_len(h)
    free0 = len(mem._free[0])
    vfree0 = len(valloc._free[0]) if uses_valloc else 0
    limbo0 = len(mem._limbo[0])
    return FleetDims(
        nl=nl,
        nvw=max(nvw, 1),
        cap=fifo_len + ops + 2,
        fcap=free0 + limbo0 + ops + 6,
        vfcap=vfree0 + limbo0 + ops + 6,
        lcap=limbo0 + 2 * ops + 6,
        area_base=area_base,
        area_cap=area_cap,
        chunk_base=chunk_base,
        chunk_cap=chunk_cap,
        node_words=node_words,
        p_brk=nv._brk,
        v_brk=nv._vbrk,
        slot_attrs=programs.guard_slot_attrs,
        needs_persisted=programs.needs_persisted,
        uses_valloc=uses_valloc,
        uses_ssmem=uses_ssmem,
    )


def _walk_fifo_len(h: QueueHarness) -> int:
    ex = FastPathExecutor(h.queue, h.nvram)
    return len(ex.fifo)


def export_instance(h: QueueHarness, dims: FleetDims) -> Optional[dict]:
    """Harness -> one fleet state row (dict of scalars / padded arrays).

    Returns None when the harness no longer matches the template layout
    (grew an area or a chunk, or has leftover unfenced persists) -- the
    instance must then stay resident on the Python path.
    """
    nv, q, mem = h.nvram, h.queue, h.mem
    if nv._brk != dims.p_brk or nv._vbrk != dims.v_brk:
        return None
    if nv._pending.get(0):
        return None
    areas = mem._areas[0]
    if dims.area_cap:
        if len(areas) != 1 or areas[0] != dims.area_base:
            return None
    elif areas:
        return None
    valloc = getattr(q, "valloc", None)
    if dims.uses_valloc and valloc._base[0] - _VB != dims.chunk_base:
        return None
    ex = FastPathExecutor(h.queue, h.nvram)
    if len(ex.fifo) >= dims.cap:
        return None
    nv._drain()
    row: dict = {}
    # the engine packs line state into one byte array; the fleet lowering
    # keeps separate planes, so unpack through the export seam
    cached, finval, everfl = nv.line_state_arrays(dims.nl)
    row["cached"] = _pad_u8(cached, dims.nl)
    row["finval"] = _pad_u8(finval, dims.nl)
    row["everfl"] = _pad_u8(everfl, dims.nl)
    row["vtouched"] = _pad_u8(nv.vtouched_array(dims.nvw), dims.nvw)
    pers = np.zeros(dims.nl if dims.needs_persisted else 1, dtype=np.uint8)
    if dims.needs_persisted:
        for addr in getattr(q, "_persisted", ()):
            ln = addr // LINE_WORDS
            if ln >= dims.nl:
                return None
            pers[ln] = 1
    row["persisted"] = pers
    # logical FIFO
    ring_p = np.zeros(dims.cap, dtype=np.int32)
    ring_v = np.zeros(dims.cap, dtype=np.int32)
    for j, rec in enumerate(ex.fifo):
        ring_p[j] = rec[0] or 0
        ring_v[j] = (rec[1] - _VB) if rec[1] else 0
    row["ring_p"], row["ring_v"] = ring_p, ring_v
    row["head"], row["length"] = 0, len(ex.fifo)
    d = ex.dummy
    row["dummy_p"] = d[0] or 0
    row["dummy_v"] = (d[1] - _VB) if d[1] else 0
    # ssmem
    free0 = mem._free[0]
    if len(free0) > dims.fcap:
        return None
    fp = np.zeros(dims.fcap, dtype=np.int32)
    fp[:len(free0)] = free0
    row["free_p"], row["nfree"] = fp, len(free0)
    row["cursor"] = mem._cursor[0]
    limbo = mem._limbo[0]
    if len(limbo) > dims.lcap:
        return None
    la = np.zeros(dims.lcap, dtype=np.int32)
    le = np.zeros(dims.lcap, dtype=np.int32)
    lk = np.zeros(dims.lcap, dtype=np.uint8)
    for j, (addr, ep, kind) in enumerate(limbo):
        la[j] = addr - _VB if kind == "v" else addr
        le[j] = ep
        lk[j] = 1 if kind == "v" else 0
    row["limbo_a"], row["limbo_e"], row["limbo_k"] = la, le, lk
    row["nlimbo"] = len(limbo)
    row["epoch"], row["opsctr"] = mem._epoch, mem._ops_since_adv
    # valloc
    vf = np.zeros(dims.vfcap, dtype=np.int32)
    if dims.uses_valloc:
        vfree0 = valloc._free[0]
        if len(vfree0) > dims.vfcap:
            return None
        vf[:len(vfree0)] = [a - _VB for a in vfree0]
        row["nvfree"] = len(vfree0)
        row["vcursor"] = valloc._cursor[0]
    else:
        row["nvfree"] = 0
        row["vcursor"] = 0
    row["vfree"] = vf
    # guard slots
    slots = {}
    for attr in dims.slot_attrs:
        v = getattr(q, attr)[0]
        slots[attr] = int(v) if v else NULL
    row["slots"] = slots
    row["counts"] = nv._counts[0].astype(np.int64).copy()
    return row


def _pad_u8(a: np.ndarray, n: int) -> np.ndarray:
    out = np.zeros(n, dtype=np.uint8)
    out[:len(a)] = a[:n]
    return out


def replicate(row: dict, dims: FleetDims, n: int) -> FleetState:
    """Tile one exported instance row across N instances."""
    def tile(v, dtype):
        if np.isscalar(v):
            return np.full(n, v, dtype=dtype)
        return np.repeat(np.asarray(v, dtype=dtype)[None, :], n, axis=0)

    slots = {attr: np.full(n, val, dtype=np.int32)
             for attr, val in row["slots"].items()}
    return FleetState(
        n=n, dims=dims,
        cached=tile(row["cached"], np.uint8),
        finval=tile(row["finval"], np.uint8),
        everfl=tile(row["everfl"], np.uint8),
        persisted=tile(row["persisted"], np.uint8),
        vtouched=tile(row["vtouched"], np.uint8),
        ring_p=tile(row["ring_p"], np.int32),
        ring_v=tile(row["ring_v"], np.int32),
        free_p=tile(row["free_p"], np.int32),
        vfree=tile(row["vfree"], np.int32),
        limbo_a=tile(row["limbo_a"], np.int32),
        limbo_e=tile(row["limbo_e"], np.int32),
        limbo_k=tile(row["limbo_k"], np.uint8),
        counts=tile(row["counts"], np.int64),
        head=tile(row["head"], np.int32),
        length=tile(row["length"], np.int32),
        dummy_p=tile(row["dummy_p"], np.int32),
        dummy_v=tile(row["dummy_v"], np.int32),
        nfree=tile(row["nfree"], np.int32),
        cursor=tile(row["cursor"], np.int32),
        nvfree=tile(row["nvfree"], np.int32),
        vcursor=tile(row["vcursor"], np.int32),
        nlimbo=tile(row["nlimbo"], np.int32),
        epoch=tile(row["epoch"], np.int32),
        opsctr=tile(row["opsctr"], np.int32),
        active=np.ones(n, dtype=bool),
        bail_at=np.full(n, -1, dtype=np.int32),
        slots=slots,
    )


def build_template(queue_name: str, model, ops: int,
                   prefill: int = DEFAULT_PREFILL) -> Template:
    """Build + warm one template instance and lower its schedules."""
    queue_cls = ALL_QUEUES[queue_name]
    h = make_instance_harness(queue_cls, model, area_nodes_for(ops, prefill),
                              prefill)
    programs = lower_queue(h.queue, h.nvram.model)
    dims = derive_dims(h, programs, ops)
    row = export_instance(h, dims)
    assert row is not None, "template instance must export cleanly"
    return Template(queue_name=queue_name, model_name=h.nvram.model.name,
                    prefill=prefill, ops=ops, harness=h, programs=programs,
                    dims=dims, row=row)
