"""Lower a :class:`repro.core.opsched.CompiledOp` to a Stats-only program.

The compiled effect program mutates two kinds of engine state: *values*
(``_vis``/``_pmem``/``_vval``/store logs) and *cost state* (per-line
cached/finval/everfl bits, per-word volatile touched bits, per-thread event
counters).  On the steady-state fast path, control flow never reads values
back -- environment addresses come from the executor's logical FIFO and the
allocators, CASes always succeed, and the bail guards consult only slots,
the persisted set and allocator cursors.  Per-instance ``Stats`` therefore
depend *only* on the cost state, which is all-integer and tiny: that is the
whole reason a million queue instances fit in a few arrays.

``lower_op`` keeps exactly the opcodes that can change a count or feed a
later address:

* ``K_CLASS_P`` / ``K_CLASS_V`` -- the dynamic classification points
  (hit / post-flush / cold-NVM / cold-DRAM, hit / DRAM);
* ``K_STATE`` (flush invalidation / retaining-flush / re-cache) and the
  line-state half of ``K_LINE`` (a full-line store caches its line);
* guards, allocators, FIFO bindings, retire->limbo, slot stores and the
  persisted-set bookkeeping (they steer *which* addresses later ops
  classify);

and drops every pure value store (``K_VVAL``/``K_LOGW``/``K_PMEMW``/
``K_PENDW``/``K_DRAIN``/``K_DRAINF``/``K_NT``/``K_NTAPPLY``) and the
contention-tracking stamps (``K_CASTAG``/``K_STAMP`` -- the fleet runs one
thread per instance, where contended counts are bit-identical to
uncontended ones; see ``tests/test_contention_property.py``).

Addresses are lowered for ``tid == 0`` (one simulated tenant per
instance).  Volatile addresses are stored as offsets from
``NVRAM._VOLATILE_BASE`` so every array stays comfortably in int32.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Tuple

import numpy as np

from ..core.nvram import NVRAM
from ..core.opsched import (K_CASTAG, K_CLASS_P, K_CLASS_V, K_DRAIN, K_DRAINF,
                            K_LINE, K_LOGW, K_NT, K_NTAPPLY, K_PENDW, K_PMEMW,
                            K_STAMP, K_STATE, K_VVAL, _SYM_INDEX,
                            _VOLATILE_SYMS, CompiledOp, compile_schedule)

_VB = NVRAM._VOLATILE_BASE

# env slot indices, re-exported for the steppers
SYM = dict(_SYM_INDEX)
VOLATILE_SYM = frozenset(_SYM_INDEX[s] for s in _VOLATILE_SYMS)

KIND_ENQ, KIND_DEQ = 0, 1


class FleetLoweringError(ValueError):
    """A compiled op the fleet lowering cannot prove Stats-equivalent."""


@dataclass(frozen=True)
class Ref:
    """A lowered address: persistent line / volatile word, constant or
    env-relative.  ``const`` holds an absolute persistent address or a
    volatile offset (addr - _VOLATILE_BASE); ``sym`` indexes the op env.
    Mode ``"tid"`` (multi-thread lowering only, see ``pin_tid``) is a
    per-thread root: effective address ``const + tid * LINE_WORDS``."""
    space: str            # "p" | "v"
    mode: str             # "const" | "sym" | "tid"
    const: int = 0
    sym: int = -1
    off: int = 0


def _lower_addr(a, space: str, pin_tid: bool = True) -> Ref:
    """Compiler address descriptor -> Ref.

    ``pin_tid=True`` (the fleet default: one simulated tenant per
    instance) folds per-tid roots to their tid-0 constant; the burst
    executor lowers with ``pin_tid=False`` to keep them symbolic
    (mode ``"tid"``), resolved per grant against the granted thread."""
    mode = a[0]
    if mode == 0:
        addr = a[1]
        if addr >= _VB:
            if space == "p":
                raise FleetLoweringError(
                    f"volatile address {addr} in persistent context")
            return Ref("v", "const", const=addr - _VB)
        return Ref(space, "const", const=addr)
    if mode == 2:                       # per-tid root
        addr = a[1] + a[2]
        if addr >= _VB:
            if space == "p":
                raise FleetLoweringError(
                    f"volatile address {addr} in persistent context")
            addr -= _VB
        if pin_tid:
            return Ref(space, "const", const=addr)
        return Ref(space, "tid", const=addr)
    sym, off = a[1], a[2]
    sp = "v" if sym in VOLATILE_SYM else "p"
    if sp != space:
        raise FleetLoweringError(
            f"sym {_SYMS[sym]} is {sp}-space but used in {space} context")
    return Ref(sp, "sym", sym=sym, off=off)


def _lower_val_sym(val) -> int:
    """Aux value expressions the fleet tracks must be bare env symbols."""
    if not (isinstance(val, tuple) and val and val[0] == "sym"):
        raise FleetLoweringError(f"aux value {val!r} is not a bare symbol")
    return _SYM_INDEX[val[1]]


# opcodes the lowering drops outright: value stores and contention stamps
_DROPPED = {K_VVAL, K_LOGW, K_PMEMW, K_PENDW, K_DRAIN, K_DRAINF, K_NT,
            K_NTAPPLY, K_CASTAG, K_STAMP}


@dataclass
class FleetProgram:
    """One (queue, kind, model) op as Stats-only vector micro-ops.

    ``micro`` entries (applied in order):
      ("class_p", Ref)         dynamic persistent classification
      ("class_v", Ref)         dynamic volatile classification
      ("state", Ref, mode)     K_STATE: ST_INVAL / ST_EVERFL / ST_RECACHE
      ("line", Ref)            K_LINE line-state half: cached=1, finval=0

    ``aux`` entries (applied after the FIFO update, in order):
      ("limbo", sym, "p"|"v")  retire / retire_volatile -> limbo append
      ("slot", attr, sym)      q.attr[tid] = env[sym] (guard-relevant only)
      ("pdiscard", sym)        q._persisted.discard(env[sym])
      ("padd", (sym, ...))     q._persisted.add(env[sym]) each
    """
    kind: str
    code: int                                 # KIND_ENQ | KIND_DEQ
    base_counts: np.ndarray                   # (N_EV,) int64
    micro: Tuple[tuple, ...]
    aux: Tuple[tuple, ...]
    guards: Tuple[tuple, ...]                 # compiler guard_specs, verbatim
    uses_ssmem: bool = True
    allocs_p: bool = False
    allocs_v: bool = False
    n_class: int = 0
    slot_attrs: Tuple[str, ...] = field(default=())   # guard slot attrs


def lower_op(op: CompiledOp, guard_attrs: frozenset,
             pin_tid: bool = True) -> FleetProgram:
    """Lower one CompiledOp.  ``guard_attrs`` is the set of slot attributes
    any guard of this queue consults -- slot stores to other attrs carry no
    Stats information (their values feed dropped value stores only) and are
    elided; a tuple-valued store to a *guarded* slot is an error.
    ``pin_tid`` is forwarded to :func:`_lower_addr` (the burst executor
    lowers with ``pin_tid=False`` to keep per-tid roots symbolic)."""
    micro = []
    for ins in op.prog:
        code = ins[0]
        if code in _DROPPED:
            continue
        if code == K_CLASS_P:
            micro.append(("class_p", _lower_addr(ins[1], "p", pin_tid)))
        elif code == K_CLASS_V:
            micro.append(("class_v", _lower_addr(ins[1], "v", pin_tid)))
        elif code == K_STATE:
            micro.append(("state", _lower_addr(ins[1], "p", pin_tid), ins[2]))
        elif code == K_LINE:
            micro.append(("line", _lower_addr(ins[1], "p", pin_tid)))
        else:
            raise FleetLoweringError(f"unknown opcode {code} in {op.kind}")
    aux = []
    for spec in op.aux_specs:
        t0 = spec[0]
        if t0 == "retire":
            aux.append(("limbo", _lower_val_sym(spec[1]), "p"))
        elif t0 == "retire_v":
            aux.append(("limbo", _lower_val_sym(spec[1]), "v"))
        elif t0 == "slot":
            attr = spec[1]
            if attr not in guard_attrs:
                continue        # value-only slot (e.g. OptLinkedQ._last)
            aux.append(("slot", attr, _lower_val_sym(spec[2])))
        elif t0 == "pdiscard":
            aux.append(("pdiscard", _SYM_INDEX[spec[1]]))
        elif t0 == "padd":
            aux.append(("padd", tuple(_SYM_INDEX[s] for s in spec[1])))
        else:
            raise FleetLoweringError(f"unknown aux {t0!r} in {op.kind}")
    slot_attrs = tuple(g[1] for g in op.guard_specs if g[0] == "slot_nonnull")
    return FleetProgram(
        kind=op.kind,
        code=KIND_ENQ if op.kind == "enq" else KIND_DEQ,
        base_counts=op.base_counts.copy(),
        micro=tuple(micro),
        aux=tuple(aux),
        guards=tuple(op.guard_specs),
        uses_ssmem=op.uses_ssmem,
        allocs_p=op.allocs_p,
        allocs_v=op.allocs_v,
        n_class=op.n_class,
        slot_attrs=slot_attrs,
    )


@dataclass
class FleetPrograms:
    """Both op kinds of one queue x model, plus the layout facts the
    steppers need (shared by the numpy and jax backends)."""
    enq: FleetProgram
    deq: FleetProgram

    def __iter__(self):
        yield self.enq
        yield self.deq

    @property
    def guard_slot_attrs(self) -> Tuple[str, ...]:
        seen = []
        for p in self:
            for a in p.slot_attrs:
                if a not in seen:
                    seen.append(a)
        return tuple(seen)

    @property
    def needs_persisted(self) -> bool:
        return any(g[0] == "tail_persisted" for p in self for g in p.guards) \
            or any(ax[0] in ("pdiscard", "padd") for p in self for ax in p.aux)


def lower_queue(queue, model, pin_tid: bool = True) -> FleetPrograms:
    """Compile + lower both steady-state ops of one queue instance."""
    schedules = queue.op_schedule()
    if schedules is None:
        raise FleetLoweringError(
            f"{type(queue).__name__} declares no op_schedule()")
    ops = {k: compile_schedule(queue, schedules.of_kind(k), model)
           for k in ("enq", "deq")}
    guard_attrs = frozenset(
        g[1] for op in ops.values() for g in op.guard_specs
        if g[0] == "slot_nonnull")
    return FleetPrograms(enq=lower_op(ops["enq"], guard_attrs, pin_tid),
                         deq=lower_op(ops["deq"], guard_attrs, pin_tid))


# --------------------------------------------------------------------------
# Opcode-table encoding: FleetProgram micro/aux entries as fixed-width int32
# rows, so a stepper can interpret them with a data-driven loop instead of
# tracing one unrolled instruction sequence per program (the jit-trace size
# then no longer scales with schedule depth -- see repro.fleet.jaxexec).
# --------------------------------------------------------------------------

# row opcodes (column 0)
OPC_NOP = 0            # padding row: no effect
OPC_CLASS_P = 1        # dynamic persistent classification
OPC_CLASS_V = 2        # dynamic volatile classification
OPC_ST_INVAL = 3       # K_STATE ST_INVAL: cached=0, finval=1, everfl=1
OPC_ST_EVERFL = 4      # K_STATE ST_EVERFL: everfl=1
OPC_RECACHE = 5        # K_STATE ST_RECACHE and K_LINE: cached=1, finval=0
OPC_LIMBO = 6          # aux retire: limbo append (imm 0 = p, 1 = v)
OPC_SLOT = 7           # aux slot store: slots[imm] = addr (imm: slot index)
OPC_PDISCARD = 8       # aux persisted.discard(addr line)
OPC_PADD = 9           # aux persisted.add(addr line) -- one row per sym
N_OPC = 10

# columns: (kind, amode, a, off, imm).  amode 0 = const (a is an absolute
# persistent address / volatile offset), amode 1 = sym (a indexes the op
# env, off is added to the bound value), amode 2 = per-tid root (a is the
# tid-0 address; effective address a + tid * LINE_WORDS -- only emitted by
# the burst lowering's ``pin_tid=False`` tables, never by fleet programs).
# imm carries the per-kind immediate (limbo space, slot index); event
# charges are implied by kind (class_p consults cached/finval/everfl,
# class_v consults vtouched).
OPCODE_COLUMNS = 5

# kinds whose address operand lives in the volatile space
_OPC_VSPACE = frozenset((OPC_CLASS_V,))

_ST_TO_OPC = {0: OPC_ST_INVAL, 1: OPC_ST_EVERFL, 2: OPC_RECACHE}
_OPC_TO_ST = {v: k for k, v in _ST_TO_OPC.items()}


@dataclass(frozen=True)
class OpcodeProgram:
    """One FleetProgram's effect ops as a fixed-width int32 table.

    Rows ``[0, n_micro)`` encode ``micro`` (applied before the logical
    FIFO update), rows ``[n_micro, len(table))`` encode ``aux`` (applied
    after it).  ``table`` may be padded with trailing ``OPC_NOP`` rows --
    interpreters must treat them as no-ops."""
    table: np.ndarray            # (rows, OPCODE_COLUMNS) int32
    n_micro: int

    @property
    def n_rows(self) -> int:
        return int(self.table.shape[0])

    def padded(self, rows: int) -> "OpcodeProgram":
        """Same program with the table NOP-padded to ``rows`` rows."""
        if rows < self.n_rows:
            raise ValueError(f"cannot pad {self.n_rows} rows down to {rows}")
        out = np.zeros((rows, OPCODE_COLUMNS), dtype=np.int32)
        out[:self.n_rows] = self.table
        return OpcodeProgram(table=out, n_micro=self.n_micro)


def _encode_ref(kind: int, ref: Ref, imm: int = 0) -> tuple:
    if ref.mode == "const":
        return (kind, 0, ref.const, 0, imm)
    if ref.mode == "tid":
        return (kind, 2, ref.const, 0, imm)
    return (kind, 1, ref.sym, ref.off, imm)


def encode_program(prog: FleetProgram,
                   slot_attrs: Tuple[str, ...]) -> OpcodeProgram:
    """FleetProgram -> opcode table.  ``slot_attrs`` is the fleet-wide
    guard-slot layout (``FleetDims.slot_attrs``): aux slot stores encode
    the attribute as an index into it."""
    rows = []
    for ins in prog.micro:
        tag, ref = ins[0], ins[1]
        if tag == "class_p":
            rows.append(_encode_ref(OPC_CLASS_P, ref))
        elif tag == "class_v":
            rows.append(_encode_ref(OPC_CLASS_V, ref))
        elif tag == "state":
            rows.append(_encode_ref(_ST_TO_OPC[ins[2]], ref))
        elif tag == "line":
            rows.append(_encode_ref(OPC_RECACHE, ref))
        else:
            raise FleetLoweringError(f"unknown micro tag {tag!r}")
    n_micro = len(rows)
    for ax in prog.aux:
        t0 = ax[0]
        if t0 == "limbo":
            rows.append((OPC_LIMBO, 1, ax[1], 0, 0 if ax[2] == "p" else 1))
        elif t0 == "slot":
            if ax[1] not in slot_attrs:
                raise FleetLoweringError(
                    f"slot store to {ax[1]!r} outside the guard-slot "
                    f"layout {slot_attrs}")
            rows.append((OPC_SLOT, 1, ax[2], 0, slot_attrs.index(ax[1])))
        elif t0 == "pdiscard":
            rows.append((OPC_PDISCARD, 1, ax[1], 0, 0))
        elif t0 == "padd":
            for sym in ax[1]:
                rows.append((OPC_PADD, 1, sym, 0, 0))
        else:
            raise FleetLoweringError(f"unknown aux tag {t0!r}")
    table = np.asarray(rows, dtype=np.int32).reshape(-1, OPCODE_COLUMNS)
    opc = OpcodeProgram(table=table, n_micro=n_micro)
    validate_opcodes(prog, opc, slot_attrs)
    return opc


_SYM_NAMES = {v: k for k, v in _SYM_INDEX.items()}
_SYMS = _SYM_NAMES          # name used by _lower_addr's error message


def decode_opcodes(opc: OpcodeProgram,
                   slot_attrs: Tuple[str, ...]) -> Tuple[tuple, tuple]:
    """Opcode table -> (micro, aux) in FleetProgram's tuple form, with
    every ``padd`` group expanded to one entry per symbol (the encoding's
    normal form).  NOP padding rows are skipped."""
    micro, aux = [], []
    for r, row in enumerate(map(tuple, opc.table.tolist())):
        kind, amode, a, off, imm = row
        if kind == OPC_NOP:
            continue
        in_micro = r < opc.n_micro
        if kind in (OPC_CLASS_P, OPC_CLASS_V, OPC_ST_INVAL, OPC_ST_EVERFL,
                    OPC_RECACHE):
            space = "v" if kind in _OPC_VSPACE else "p"
            if amode == 0:
                ref = Ref(space, "const", const=a)
            elif amode == 2:
                ref = Ref(space, "tid", const=a)
            else:
                ref = Ref(space, "sym", sym=a, off=off)
            if not in_micro:
                raise FleetLoweringError(
                    f"row {r}: effect opcode {kind} in the aux region")
            if kind == OPC_CLASS_P:
                micro.append(("class_p", ref))
            elif kind == OPC_CLASS_V:
                micro.append(("class_v", ref))
            elif kind == OPC_RECACHE:
                micro.append(("state", ref, _OPC_TO_ST[OPC_RECACHE]))
            else:
                micro.append(("state", ref, _OPC_TO_ST[kind]))
        elif kind in (OPC_LIMBO, OPC_SLOT, OPC_PDISCARD, OPC_PADD):
            if in_micro:
                raise FleetLoweringError(
                    f"row {r}: aux opcode {kind} in the micro region")
            if kind == OPC_LIMBO:
                aux.append(("limbo", a, "p" if imm == 0 else "v"))
            elif kind == OPC_SLOT:
                aux.append(("slot", slot_attrs[imm], a))
            elif kind == OPC_PDISCARD:
                aux.append(("pdiscard", a))
            else:
                aux.append(("padd", (a,)))
        else:
            raise FleetLoweringError(f"row {r}: unknown opcode {kind}")
    return tuple(micro), tuple(aux)


def _normalize(prog: FleetProgram) -> Tuple[tuple, tuple]:
    """The program's micro/aux in the encoding's normal form: ``line``
    entries become ST_RECACHE state entries, ``padd`` groups expand."""
    micro = []
    for ins in prog.micro:
        if ins[0] == "line":
            micro.append(("state", ins[1], _OPC_TO_ST[OPC_RECACHE]))
        else:
            micro.append(ins)
    aux = []
    for ax in prog.aux:
        if ax[0] == "padd":
            aux.extend(("padd", (sym,)) for sym in ax[1])
        else:
            aux.append(ax)
    return tuple(micro), tuple(aux)


def validate_opcodes(prog: FleetProgram, opc: OpcodeProgram,
                     slot_attrs: Tuple[str, ...]) -> None:
    """Decode the table and require it to reproduce the source program's
    effect semantics exactly (up to the documented normal form).  Runs at
    every encode so a drifting encoder cannot silently ship wrong
    tables."""
    if opc.table.dtype != np.int32 or opc.table.ndim != 2 \
            or opc.table.shape[1] != OPCODE_COLUMNS:
        raise FleetLoweringError(
            f"opcode table must be (rows, {OPCODE_COLUMNS}) int32, got "
            f"{opc.table.dtype} {opc.table.shape}")
    got = decode_opcodes(opc, slot_attrs)
    want = _normalize(prog)
    if got != want:
        raise FleetLoweringError(
            f"opcode round-trip mismatch for {prog.kind}:\n"
            f"  decoded {got}\n  expected {want}")
