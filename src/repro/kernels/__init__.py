"""Pallas TPU kernels for the compute hot spots.

Each kernel ships as <name>/kernel.py (pl.pallas_call + explicit BlockSpec
VMEM tiling), <name>/ops.py (jit'd wrapper with XLA fallback) and
<name>/ref.py (pure-jnp oracle).  Kernels target TPU (MXU-aligned tiles);
on this CPU container they are validated with interpret=True.
"""
