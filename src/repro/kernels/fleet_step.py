"""Pallas chunk-stepper kernel for the fleet executor.

One ``pl.pallas_call`` advances every instance's Stats-only state through
a whole chunk of the op stream.  The grid tiles the (padded) instance
axis into blocks; each program id owns one block of rows from every state
array, loops over the chunk's ops with ``lax.fori_loop`` and applies the
*same* opcode interpreter the jax-opcode backend scans with
(:func:`repro.fleet.jaxexec._apply_opcode_one`, vmapped over the block).
Sharing the interpreter is the point: the kernel adds a memory layout
(explicit per-block refs, one launch per chunk instead of one dispatch
per op), not a second semantics to keep bit-identical.

Bail flags come back through the ``active`` / ``bail_at`` state outputs
-- the runner's poll/rejoin protocol is unchanged.  All state inputs are
aliased to the outputs, so the chunk steps in place.

On this container (CPU-only) the kernel runs with ``interpret=True``,
which is also what CI's ``fleet-pallas-smoke`` job exercises; the
``tests/test_fleet_equivalence.py`` backend matrix gates bit-identity
with ``run_batched`` either way.
"""
from __future__ import annotations

from functools import partial

from ..fleet.jaxexec import (_ARRAY_FIELDS, _SCALAR_FIELDS,
                             _apply_opcode_one)
from ..fleet.lowering import encode_program

# state-dict keys in ref order; "slots" is the stacked guard-slot matrix
STATE_KEYS = tuple(_ARRAY_FIELDS) + ("counts", "slots") + \
    tuple(_SCALAR_FIELDS)


def make_pallas_chunk_fn(jax, programs, dims, block: int = 128,
                         interpret: bool = True):
    """-> jit'd ``chunk(st, kcols, oi)`` with the same signature and
    state-dict layout as :func:`repro.fleet.jaxexec.make_chunk_fn`.
    ``kcols`` is (npad, C) uint8 with npad a multiple of ``block``."""
    import jax.numpy as jnp
    from jax import lax
    from jax.experimental import pallas as pl

    import numpy as np

    progs = [(p, encode_program(p, dims.slot_attrs)) for p in programs]
    # a kernel cannot capture array constants -- the opcode tables and
    # static count vectors ride in as (broadcast) inputs instead
    const_arrays = []
    for p, opc in progs:
        const_arrays.append(opc.table)
        const_arrays.append(p.base_counts.astype(np.int32))
    n_const = len(const_arrays)

    def apply_block(st, k, o, prog, opc, table, bc):
        return jax.vmap(
            partial(_apply_opcode_one, jnp, lax, dims, prog, opc),
            in_axes=(0, 0, None, None, None))(st, k == prog.code, o,
                                              table, bc)

    def kernel(kc_ref, oi_ref, *refs):
        consts = [r[...] for r in refs[:n_const]]
        state_in = refs[n_const:n_const + len(STATE_KEYS)]
        state_out = refs[n_const + len(STATE_KEYS):]
        st = {key: r[...] for key, r in zip(STATE_KEYS, state_in)}
        kc = kc_ref[...]                    # (block, C)
        oi = oi_ref[...]                    # (C,)

        def step_op(ci, st):
            k = lax.dynamic_index_in_dim(kc, ci, axis=1, keepdims=False)
            o = lax.dynamic_index_in_dim(oi, ci, keepdims=False)
            for j, (prog, opc) in enumerate(progs):
                st = apply_block(st, k, o, prog, opc,
                                 consts[2 * j], consts[2 * j + 1])
            return st

        st = lax.fori_loop(0, kc.shape[1], step_op, st)
        for key, r in zip(STATE_KEYS, state_out):
            r[...] = st[key]

    def full_spec(v):
        if v.ndim == 2:
            return pl.BlockSpec(v.shape, lambda i: (0, 0))
        return pl.BlockSpec(v.shape, lambda i: (0,))

    def chunk(st, kcols, oi):
        st = dict(st)
        if dims.slot_attrs:
            st["slots"] = jnp.stack(
                [st.pop("slot_" + a) for a in dims.slot_attrs], axis=-1)
        else:
            st["slots"] = jnp.zeros((kcols.shape[0], 1), jnp.int32)
        vals = [st[key] for key in STATE_KEYS]
        npad, C = kcols.shape

        def row_spec(v):
            if v.ndim == 2:
                return pl.BlockSpec((block, v.shape[1]), lambda i: (i, 0))
            return pl.BlockSpec((block,), lambda i: (i,))

        base = 2 + n_const
        out = pl.pallas_call(
            kernel,
            grid=(npad // block,),
            in_specs=[pl.BlockSpec((block, C), lambda i: (i, 0)),
                      pl.BlockSpec((C,), lambda i: (0,))] +
                     [full_spec(a) for a in const_arrays] +
                     [row_spec(v) for v in vals],
            out_specs=[row_spec(v) for v in vals],
            out_shape=[jax.ShapeDtypeStruct(v.shape, v.dtype)
                       for v in vals],
            input_output_aliases={base + j: j for j in range(len(vals))},
            interpret=interpret,
        )(kcols, oi, *const_arrays, *vals)
        res = dict(zip(STATE_KEYS, out))
        slots = res.pop("slots")
        for i, a in enumerate(dims.slot_attrs):
            res["slot_" + a] = slots[:, i]
        return res

    return jax.jit(chunk)
