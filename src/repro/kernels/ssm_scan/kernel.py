"""Mamba selective scan as a Pallas TPU kernel.

Adaptation notes (GPU selective-scan -> TPU, per DESIGN.md §3):
* the CUDA kernel parallelizes over (batch, channel) threads with a
  sequential time loop in registers; the TPU version tiles **channels onto
  the 128-lane VPU** -- each grid cell owns a (BLOCK_D channels x ds states)
  state matrix resident in VMEM and walks the sequence in TIME CHUNKS,
  so the (S, BLOCK_D) input tile streams HBM->VMEM once;
* the grid is (batch, d_inner/BLOCK_D, S/chunk); Pallas TPU executes the
  last grid dim sequentially on a core, so the running state h lives in a
  VMEM scratch carried across chunk cells (the TPU analogue of the GPU
  kernel's register-resident recurrence);
* within a chunk the recurrence is a fori_loop of fused multiply-adds on
  (BLOCK_D, ds) tiles -- elementwise VPU work, no MXU needed.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssm_kernel(dt_ref, b_ref, c_ref, x_ref, a_ref, y_ref, hout_ref, h_scr, *,
                chunk: int, n_chunks: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    A = a_ref[...].astype(jnp.float32)               # (block_d, ds)
    h = h_scr[...]

    def step(t, h):
        dti = dt_ref[t, :].astype(jnp.float32)       # (block_d,)
        xi = x_ref[t, :].astype(jnp.float32)         # (block_d,)
        Bi = b_ref[t, :].astype(jnp.float32)         # (ds,)
        Ci = c_ref[t, :].astype(jnp.float32)         # (ds,)
        a = jnp.exp(dti[:, None] * A)                # (block_d, ds)
        h = a * h + (dti * xi)[:, None] * Bi[None, :]
        y = h @ Ci                                   # (block_d,)
        y_ref[t, :] = y.astype(y_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, chunk, step, h)
    h_scr[...] = h

    @pl.when(ci == n_chunks - 1)
    def _fin():
        hout_ref[...] = h


@functools.partial(jax.jit, static_argnames=("block_d", "chunk", "interpret"))
def ssm_scan_kernel(dt: jax.Array, Bt: jax.Array, Ct: jax.Array,
                    x: jax.Array, A: jax.Array, block_d: int = 512,
                    chunk: int = 128,
                    interpret: bool = False) -> Tuple[jax.Array, jax.Array]:
    """dt, x: (B,S,din); Bt,Ct: (B,S,ds); A: (din,ds) ->
    (y (B,S,din) fp32, h_final (B,din,ds) fp32)."""
    B, S, din = x.shape
    ds = Bt.shape[-1]
    block_d = min(block_d, din)
    chunk = min(chunk, S)
    assert din % block_d == 0 and S % chunk == 0
    grid = (B, din // block_d, S // chunk)
    y, h = pl.pallas_call(
        functools.partial(_ssm_kernel, chunk=chunk, n_chunks=S // chunk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, chunk, block_d), lambda b, d, c: (b, c, d)),
            pl.BlockSpec((None, chunk, ds), lambda b, d, c: (b, c, 0)),
            pl.BlockSpec((None, chunk, ds), lambda b, d, c: (b, c, 0)),
            pl.BlockSpec((None, chunk, block_d), lambda b, d, c: (b, c, d)),
            pl.BlockSpec((block_d, ds), lambda b, d, c: (d, 0)),
        ],
        out_specs=[
            pl.BlockSpec((None, chunk, block_d), lambda b, d, c: (b, c, d)),
            pl.BlockSpec((None, block_d, ds), lambda b, d, c: (b, d, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, S, din), jnp.float32),
            jax.ShapeDtypeStruct((B, din, ds), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((block_d, ds), jnp.float32)],
        interpret=interpret,
    )(dt, Bt, Ct, x, A)
    return y, h
