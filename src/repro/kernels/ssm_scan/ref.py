"""Pure-jnp oracle for the Mamba selective scan (sequential over time)."""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def ssm_scan_ref(dt: jax.Array, Bt: jax.Array, Ct: jax.Array, x: jax.Array,
                 A: jax.Array, h0=None) -> Tuple[jax.Array, jax.Array]:
    """dt, x: (B, S, din); Bt, Ct: (B, S, ds); A: (din, ds).
    h_t = exp(dt_t A) h_{t-1} + (dt_t x_t) B_t ;  y_t = C_t . h_t
    Returns (y (B, S, din), h_final (B, din, ds)); fp32 math."""
    Bsz, S, din = x.shape
    ds = Bt.shape[-1]
    dt = dt.astype(jnp.float32)
    x = x.astype(jnp.float32)
    Btf = Bt.astype(jnp.float32)
    Ctf = Ct.astype(jnp.float32)
    if h0 is None:
        h0 = jnp.zeros((Bsz, din, ds), jnp.float32)

    def step(h, args):
        dti, xi, Bi, Ci = args
        a = jnp.exp(dti[..., None] * A)                   # (B, din, ds)
        h = a * h + (dti * xi)[..., None] * Bi[:, None, :]
        y = jnp.einsum("bds,bs->bd", h, Ci)
        return h, y

    h, ys = jax.lax.scan(step, h0,
                         (jnp.moveaxis(dt, 1, 0), jnp.moveaxis(x, 1, 0),
                          jnp.moveaxis(Btf, 1, 0), jnp.moveaxis(Ctf, 1, 0)))
    return jnp.moveaxis(ys, 0, 1), h
