"""jit'd public wrapper for the selective scan."""
from __future__ import annotations

from typing import Tuple

import jax

from .kernel import ssm_scan_kernel


def ssm_scan(dt: jax.Array, Bt: jax.Array, Ct: jax.Array, x: jax.Array,
             A: jax.Array) -> Tuple[jax.Array, jax.Array]:
    platform = jax.devices()[0].platform
    if platform == "tpu":
        return ssm_scan_kernel(dt, Bt, Ct, x, A)
    return ssm_scan_kernel(dt, Bt, Ct, x, A, interpret=True)
