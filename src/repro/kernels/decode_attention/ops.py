"""jit'd public wrapper for flash-decode."""
from __future__ import annotations

import jax

from .kernel import decode_attention_kernel


def decode_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                     lengths: jax.Array) -> jax.Array:
    platform = jax.devices()[0].platform
    if platform == "tpu":
        return decode_attention_kernel(q, k, v, lengths)
    return decode_attention_kernel(q, k, v, lengths, interpret=True)
