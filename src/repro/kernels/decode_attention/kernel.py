"""Flash-decode as a Pallas TPU kernel: split-K over the KV cache.

GPU flash-decoding splits the KV sequence across thread blocks and merges
partial softmax states; the TPU adaptation splits across *grid cells* --
each (batch, kv_head, split) cell reduces its S/n_splits slice of the cache
with an online softmax over VMEM tiles, emitting a partial
(out, max, sumexp) triple; a cheap renormalized merge in XLA combines the
splits.  This keeps every MXU op on (G x block_k x hd) tiles and the HBM
traffic at exactly one cache read -- decode is memory-bound, so the kernel's
job is to stream the cache at full bandwidth, not to save FLOPs.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, *,
                   block_k: int, split_len: int, scale: float):
    si = pl.program_id(2)
    length = len_ref[0]
    q = q_ref[...].astype(jnp.float32) * scale        # (G, hd)
    G, hd = q.shape
    m = jnp.full((G,), NEG_INF, jnp.float32)
    l = jnp.zeros((G,), jnp.float32)
    acc = jnp.zeros((G, hd), jnp.float32)
    base = si * split_len

    def kv_step(j, carry):
        m, l, acc = carry
        k = pl.load(k_ref, (pl.dslice(j * block_k, block_k),
                            slice(None))).astype(jnp.float32)
        v = pl.load(v_ref, (pl.dslice(j * block_k, block_k),
                            slice(None))).astype(jnp.float32)
        s = q @ k.T                                   # (G, block_k)
        pos = base + j * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (G, block_k), 1)
        s = jnp.where(pos < length, s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_new = l * alpha + p.sum(axis=-1)
        acc_new = acc * alpha[:, None] + p @ v
        return m_new, l_new, acc_new

    # only stream blocks that can contain valid positions
    nblocks = split_len // block_k
    valid_blocks = jnp.clip(
        (length - base + block_k - 1) // block_k, 0, nblocks)
    m, l, acc = jax.lax.fori_loop(0, valid_blocks, kv_step, (m, l, acc))
    o_ref[...] = acc.astype(o_ref.dtype)
    m_ref[...] = m
    l_ref[...] = l


@functools.partial(jax.jit, static_argnames=("n_splits", "block_k",
                                             "interpret"))
def decode_attention_kernel(q: jax.Array, k: jax.Array, v: jax.Array,
                            lengths: jax.Array, n_splits: int = 8,
                            block_k: int = 256,
                            interpret: bool = False) -> jax.Array:
    """q: (B, H, hd); k/v: (B, S, KV, hd); lengths: (B,). -> (B, H, hd)."""
    B, H, hd = q.shape
    S, KV = k.shape[1], k.shape[2]
    G = H // KV
    while S % (n_splits * block_k) and n_splits > 1:
        n_splits //= 2
    block_k = min(block_k, S)
    assert S % (n_splits * block_k) == 0, (S, n_splits, block_k)
    split_len = S // n_splits

    qr = q.reshape(B, KV, G, hd)
    kr = jnp.moveaxis(k, 1, 2)        # (B, KV, S, hd)
    vr = jnp.moveaxis(v, 1, 2)
    grid = (B, KV, n_splits)
    o, m, l = pl.pallas_call(
        functools.partial(_decode_kernel, block_k=block_k,
                          split_len=split_len, scale=1.0 / (hd ** 0.5)),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda b, kv, s: (b,)),
            pl.BlockSpec((None, None, G, hd), lambda b, kv, s: (b, kv, 0, 0)),
            pl.BlockSpec((None, None, split_len, hd),
                         lambda b, kv, s: (b, kv, s, 0)),
            pl.BlockSpec((None, None, split_len, hd),
                         lambda b, kv, s: (b, kv, s, 0)),
        ],
        out_specs=[
            pl.BlockSpec((None, None, None, G, hd),
                         lambda b, kv, s: (b, kv, s, 0, 0)),
            pl.BlockSpec((None, None, None, G),
                         lambda b, kv, s: (b, kv, s, 0)),
            pl.BlockSpec((None, None, None, G),
                         lambda b, kv, s: (b, kv, s, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, KV, n_splits, G, hd), jnp.float32),
            jax.ShapeDtypeStruct((B, KV, n_splits, G), jnp.float32),
            jax.ShapeDtypeStruct((B, KV, n_splits, G), jnp.float32),
        ],
        interpret=interpret,
    )(lengths, qr, kr, vr)
    # renormalized merge across splits (flash-decoding reduction)
    m_max = m.max(axis=2, keepdims=True)                  # (B,KV,1,G)
    alpha = jnp.exp(m - m_max)                            # (B,KV,ns,G)
    l_tot = (l * alpha).sum(axis=2)                       # (B,KV,G)
    o_tot = (o * alpha[..., None]).sum(axis=2)            # (B,KV,G,hd)
    out = o_tot / jnp.maximum(l_tot, 1e-30)[..., None]
    return out.reshape(B, H, hd).astype(q.dtype)
