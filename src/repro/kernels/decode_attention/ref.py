"""Pure-jnp oracle for single-token GQA decode attention."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def decode_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                         lengths: jax.Array) -> jax.Array:
    """q: (B, H, hd); k/v: (B, S, KV, hd); lengths: (B,) valid prefix sizes.
    Returns (B, H, hd)."""
    B, H, hd = q.shape
    S, KV = k.shape[1], k.shape[2]
    G = H // KV
    qg = q.reshape(B, KV, G, hd).astype(jnp.float32)
    s = jnp.einsum("bkgh,btkh->bkgt", qg, k.astype(jnp.float32))
    s = s / jnp.sqrt(hd)
    valid = jnp.arange(S)[None, :] < lengths[:, None]          # (B, S)
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgt,btkh->bkgh", w, v.astype(jnp.float32))
    return out.reshape(B, H, hd).astype(q.dtype)
