"""jit'd public wrapper: Pallas on TPU, chunked-XLA fallback elsewhere."""
from __future__ import annotations

import jax

from .kernel import flash_attention_kernel


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    causal: bool = True) -> jax.Array:
    """Dispatch: Pallas kernel on TPU backends; interpretable elsewhere for
    correctness (the model's XLA fallback lives in models/attention.py)."""
    platform = jax.devices()[0].platform
    if platform == "tpu":
        return flash_attention_kernel(q, k, v, causal=causal)
    return flash_attention_kernel(q, k, v, causal=causal, interpret=True)
