"""Pure-jnp oracle for causal GQA flash attention."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                        causal: bool = True) -> jax.Array:
    """q: (B, S, H, hd); k, v: (B, S, KV, hd); H % KV == 0.
    Returns (B, S, H, hd), accumulation in fp32."""
    B, S, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    qg = q.reshape(B, S, KV, G, hd).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    scores = jnp.einsum("bskgh,btkh->bkgst", qg, kf) / jnp.sqrt(hd)
    if causal:
        mask = jnp.tril(jnp.ones((S, S), bool))
        scores = jnp.where(mask, scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgst,btkh->bskgh", w, vf)
    return out.reshape(B, S, H, hd).astype(q.dtype)
