"""Causal GQA flash attention as a Pallas TPU kernel.

Adaptation notes (GPU FlashAttention -> TPU, per DESIGN.md §3):
* the online-softmax tiling maps to VMEM blocks instead of SM shared
  memory: each grid step owns a (BLOCK_Q, head_dim) query tile resident in
  VMEM and streams (BLOCK_K, head_dim) K/V tiles;
* tile sizes are MXU-aligned (multiples of 128 on the contracting and lane
  dims; head_dim is typically 128);
* the grid iterates (batch, kv_head, q_group, q_block); the innermost KV
  loop is a fori_loop *inside* the kernel so the running (m, l, acc) stay in
  registers/VMEM -- the TPU analogue of FA2's register accumulation;
* causal masking skips fully-masked KV tiles via the loop upper bound
  (block-level early exit -- no wasted MXU work past the diagonal).

q: (B, S, H, hd) -> kernel works on one (kv-head, group) slice at a time;
GQA means K/V tiles are shared across the G query heads of the group, which
is why the group dim lives INSIDE the q tile (better KV reuse in VMEM).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, *, block_q: int, block_k: int,
                 seq_len: int, scale: float, causal: bool):
    qi = pl.program_id(3)
    q = q_ref[...].astype(jnp.float32) * scale      # (block_q, hd)
    m = jnp.full((block_q,), NEG_INF, jnp.float32)
    l = jnp.zeros((block_q,), jnp.float32)
    acc = jnp.zeros((block_q, q.shape[-1]), jnp.float32)

    q_pos = qi * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0)

    def kv_step(j, carry):
        m, l, acc = carry
        k = pl.load(k_ref, (pl.dslice(j * block_k, block_k),
                            slice(None))).astype(jnp.float32)
        v = pl.load(v_ref, (pl.dslice(j * block_k, block_k),
                            slice(None))).astype(jnp.float32)
        s = q @ k.T                                  # (block_q, block_k)
        if causal:
            k_pos = j * block_k + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 1)
            s = jnp.where(q_pos >= k_pos, s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_new = l * alpha + p.sum(axis=-1)
        acc_new = acc * alpha[:, None] + p @ v
        return m_new, l_new, acc_new

    # causal block-level early exit: only blocks up to the diagonal
    if causal:
        upper = jnp.minimum((qi + 1) * block_q + block_k - 1,
                            seq_len) // block_k
    else:
        upper = seq_len // block_k
    m, l, acc = jax.lax.fori_loop(0, upper, kv_step, (m, l, acc))
    o_ref[...] = (acc / jnp.maximum(l, 1e-30)[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k",
                                             "interpret"))
def flash_attention_kernel(q: jax.Array, k: jax.Array, v: jax.Array,
                           causal: bool = True, block_q: int = 256,
                           block_k: int = 256,
                           interpret: bool = False) -> jax.Array:
    """q: (B, S, H, hd); k/v: (B, S, KV, hd) -> (B, S, H, hd)."""
    B, S, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    block_q = min(block_q, S)
    block_k = min(block_k, S)
    assert S % block_q == 0 and S % block_k == 0
    # regroup: (B, KV, G, S, hd) so one grid cell = one (b, kv, g, q-block)
    qr = jnp.moveaxis(q.reshape(B, S, KV, G, hd), 1, 3)
    kr = jnp.moveaxis(k, 1, 2)                       # (B, KV, S, hd)
    vr = jnp.moveaxis(v, 1, 2)

    grid = (B, KV, G, S // block_q)
    out = pl.pallas_call(
        functools.partial(_attn_kernel, block_q=block_q, block_k=block_k,
                          seq_len=S, scale=1.0 / (hd ** 0.5), causal=causal),
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, None, None, block_q, hd),
                         lambda b, kv, g, qi: (b, kv, g, qi, 0)),
            pl.BlockSpec((None, None, S, hd),
                         lambda b, kv, g, qi: (b, kv, 0, 0)),
            pl.BlockSpec((None, None, S, hd),
                         lambda b, kv, g, qi: (b, kv, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, None, None, block_q, hd),
                               lambda b, kv, g, qi: (b, kv, g, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B, KV, G, S // block_q * block_q, hd),
                                       q.dtype),
        interpret=interpret,
    )(qr, kr, vr)
    return jnp.moveaxis(out, 3, 1).reshape(B, S, H, hd)
