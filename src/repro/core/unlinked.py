"""UnlinkedQ -- first amendment, design #1 (paper §5.1, Figure 1).

One blocking fence per operation (meets the Cohen et al. lower bound).  Node
links are *not* persisted; recovery identifies queue nodes by scanning the
designated allocation areas for nodes with a set ``linked`` flag and an
``index`` larger than the persisted head index, then orders them by index.

The head is a double-width ``(ptr, index)`` word updated with DWCAS; a
dequeue persists the head's *index* (its whole line, of course) with one
flush+fence.  The enqueue persists the fully-initialized node with one
flush+fence after the link CAS succeeds; Assumption 1 (same-line store order
is preserved) makes ``linked=True`` reach NVRAM only after item/index.

This queue deliberately *does* access flushed content -- reading
``tail->index`` (flushed by the previous enqueuer), the dequeued node's
``item``/``index``, and the head line after its own flush -- which is exactly
the cost the second amendment removes.
"""
from __future__ import annotations

from typing import Any, List, Tuple

from .nvram import LINE_WORDS, NVRAM
from .opsched import (AllocP, Cas, Fence, FifoLayout, Flush, L, OpSchedule,
                      QueueSchedules, Read, Retire, SlotSet, Write,
                      WriteLine)
from .queue_base import NULL, QueueAlgorithm, alloc_root_lines
from .ssmem import SSMem

# persistent node layout (one cache line): Figure 1's class Node
ITEM, NEXT, LINKED, INDEX = 0, 1, 2, 3


class UnlinkedQueue(QueueAlgorithm):
    NAME = "UnlinkedQ"

    def __init__(self, nvram: NVRAM, mem: SSMem, nthreads: int, on_event=None,
                 _recovering: bool = False, roots=None):
        super().__init__(nvram, mem, nthreads, on_event)
        nv = self.nvram
        if roots is None:
            roots = alloc_root_lines(nv, 2, "unlinkedq:roots")
        self.HEAD, self.TAIL = roots       # HEAD holds a (ptr, index) tuple
        self.roots = roots
        self.node_to_retire = [NULL] * nthreads   # volatile, Figure 1
        if not _recovering:
            dummy = self.mem.alloc(0)
            # dummy: linked=0 so recovery never resurrects it; index=0
            nv.write_full_line(dummy, [None, NULL, 0, 0, 0, 0, 0, 0])
            nv.write(self.HEAD, (dummy, 0))
            nv.write(self.TAIL, dummy)
            self.pflush(self.HEAD)
            self.pfence()

    # ---------------------------------------- steady-state schedule facts
    # Retries issue no flushes of their own, so they add no NEW line
    # invalidations: the flushed tail/head node lines are re-fetched
    # once (charged to whichever op touches them first -- already in the
    # base accounting) and a retry re-reads them as plain hits.  The
    # exact scheduler confirms flushed-access totals stay flat here.
    RETRY_SHAPES = {
        "enq": dict(reads=3),
        "deq": dict(reads=4),
    }

    def op_schedule(self):
        """Steady state (Figure 1): one fence per op; the enqueue reads the
        flushed tail node's index (post-flush), the dequeue reads the
        flushed node content and its own flushed head line."""
        enq = OpSchedule("enq", steps=(
            AllocP(),                                          # Line 21
            WriteLine(L("new_p"), (None, NULL, 0, 0, 0, 0, 0, 0),
                      item_at=0),                              # Lines 22-24
            Read(L("TAIL")),                                   # Line 26
            Read(L("tail_p", NEXT)),                           # Line 27
            Read(L("tail_p", INDEX)),                          # Line 28 (rhs)
            Write(L("new_p", INDEX), ("idx",)),                # Line 28
            Cas(L("tail_p", NEXT), ("sym", "new_p"),
                event="enq"),                                  # Line 29
            Write(L("new_p", LINKED), ("c", 1)),               # Line 30
            Flush(L("new_p")), Fence(),                        # the ONE fence
            Cas(L("TAIL"), ("sym", "new_p"), root=True),       # Line 32
        ), retry_from=2)
        deq = OpSchedule("deq", steps=(
            Read(L("HEAD")),                                   # Line 8
            Read(L("head_p", NEXT)),                           # Line 9
            Read(L("TAIL")),                                   # MSQ guard
            Read(L("next_p", INDEX)),                          # Line 13
            Read(L("next_p", ITEM)),                           # Line 14
            Cas(L("HEAD"), ("tup", ("sym", "next_p"), ("idx",)),
                root=True, event="deq"),                       # DWCAS
            Flush(L("HEAD")), Fence(),                         # the ONE fence
            Retire(("sym", "prev")),                           # Lines 16-17
            SlotSet("node_to_retire", ("sym", "head_p")),      # Line 18
        ), guards=(("slot_nonnull", "node_to_retire"),))
        return QueueSchedules(enq=enq, deq=deq, layout=FifoLayout(
            head_root="HEAD", next_off=NEXT, item_off=ITEM, idx_off=INDEX,
            head_is_tuple=True))

    # --------------------------------------------------------------- enqueue
    def enqueue(self, tid: int, item: Any) -> None:
        nv = self.nvram
        self.mem.op_begin(tid)
        node = self.mem.alloc(tid)                        # Line 21
        # full-line init: item, next=NULL, linked=false (Lines 22-24)
        nv.write_full_line(node, [item, NULL, 0, 0, 0, 0, 0, 0])
        while True:
            tail = nv.read(self.TAIL)                     # Line 26
            if nv.read(tail + NEXT) == NULL:              # Line 27
                # Line 28: reads the flushed tail node's line (post-flush!)
                nv.write(node + INDEX, nv.read(tail + INDEX) + 1)
                if nv.cas(tail + NEXT, NULL, node):       # Line 29
                    self._ev("enq", item)
                    nv.write(node + LINKED, 1)            # Line 30
                    self.pflush(node)                        # Line 31
                    self.pfence()                            # the ONE fence
                    nv.cas(self.TAIL, tail, node)         # Line 32
                    return
            else:
                nv.cas(self.TAIL, tail, nv.read(tail + NEXT))   # Line 34

    # --------------------------------------------------------------- dequeue
    def dequeue(self, tid: int) -> Any:
        nv = self.nvram
        self.mem.op_begin(tid)
        while True:
            head = nv.read(self.HEAD)                     # Line 8: (ptr, idx)
            head_ptr, _head_idx = head
            head_next = nv.read(head_ptr + NEXT)          # Line 9
            if head_next == NULL:                         # Line 10
                self.pflush(self.HEAD)                       # Line 11
                self.pfence()
                self._ev("empty")
                return None                               # Line 12
            # MSQ guard: head must not overtake tail (reclamation safety)
            tail = nv.read(self.TAIL)
            if head_ptr == tail:
                nv.cas(self.TAIL, tail, head_next)
                continue
            # Line 13: DWCAS to (next, next->index) -- reads flushed node
            nidx = nv.read(head_next + INDEX)
            item = nv.read(head_next + ITEM)              # Line 14
            if nv.cas(self.HEAD, head, (head_next, nidx)):
                self._ev("deq", item)
                self.pflush(self.HEAD)                       # Line 15
                self.pfence()                                # the ONE fence
                if self.node_to_retire[tid] != NULL:      # Lines 16-17
                    self.mem.retire(tid, self.node_to_retire[tid])
                self.node_to_retire[tid] = head_ptr       # Line 18
                return item                               # Line 19

    # -------------------------------------------------------------- recovery
    @classmethod
    def recover(cls, nvram: NVRAM, mem: SSMem, nthreads: int, roots,
                on_event=None) -> "UnlinkedQueue":
        q = cls(nvram, mem, nthreads, on_event, _recovering=True, roots=roots)
        head_val = nvram.pread(q.HEAD)
        head_idx = head_val[1] if isinstance(head_val, tuple) else 0
        # scan designated areas for linked nodes with index > head_idx (§5.1.3)
        live: List[Tuple[int, int]] = []
        free: List[int] = []
        for base, nnodes in mem.area_addrs():
            for i in range(nnodes):
                a = base + i * LINE_WORDS
                linked = nvram.pread(a + LINKED)
                idx = nvram.pread(a + INDEX) or 0
                if linked and idx > head_idx:
                    live.append((idx, a))
                else:
                    free.append(a)
        live.sort()
        # fresh dummy with the head's index
        dummy = free.pop() if free else mem.alloc(0)
        nvram.pwrite(dummy + ITEM, None)
        nvram.pwrite(dummy + LINKED, 0)
        nvram.pwrite(dummy + INDEX, head_idx)
        nvram.pwrite(dummy + NEXT, NULL)
        # stitch next pointers in index order (links are volatile-only data,
        # but recovery writes them straight into the persistent image)
        prev = dummy
        for idx, a in live:
            nvram.pwrite(prev + NEXT, a)
            prev = a
        nvram.pwrite(prev + NEXT, NULL)
        nvram.pwrite(q.HEAD, (dummy, head_idx))
        nvram.pwrite(q.TAIL, prev)
        for a in free:
            mem.free_now(0, a)
        nvram.reset_after_recovery()
        return q
