"""DurableMSQ -- the thinned Friedman et al. (PPoPP'18) durable queue.

The paper's baseline (§10): the original durable queue minus the
returned-value recovery mechanism (which durable linearizability does not
require).  Persist schedule:

* enqueue: persist the node content before linking (flush+fence #1), link
  with CAS, persist the predecessor's ``next`` (flush+fence #2), advance tail
  -- **2 blocking fences per enqueue**;
* dequeue: CAS the head forward and persist it (flush+fence) -- **1 fence**;
  a failing dequeue also persists the head to make preceding dequeues
  durable.

Recovery walks the persisted ``next`` chain from the persisted head.  Note
the post-flush accesses this design incurs (and the paper measures): each
enqueue re-reads the flushed tail node's line, each dequeue re-reads the
flushed head line and the flushed node content.
"""
from __future__ import annotations

from typing import Any

from .nvram import LINE_WORDS, NVRAM
from .opsched import (AllocP, Cas, Fence, FifoLayout, Flush, L, OpSchedule,
                      QueueSchedules, Read, Retire, WriteLine)
from .queue_base import NULL, QueueAlgorithm, alloc_root_lines
from .ssmem import SSMem

# persistent node layout (one cache line)
ITEM, NEXT = 0, 1


class DurableMSQueue(QueueAlgorithm):
    NAME = "DurableMSQ"

    def __init__(self, nvram: NVRAM, mem: SSMem, nthreads: int, on_event=None,
                 _recovering: bool = False, roots=None):
        super().__init__(nvram, mem, nthreads, on_event)
        nv = self.nvram
        if roots is None:
            roots = alloc_root_lines(nv, 2, "durablemsq:roots")
        self.HEAD, self.TAIL = roots
        self.roots = roots
        if not _recovering:
            dummy = self.mem.alloc(0)
            nv.write_full_line(dummy, [None, NULL, 0, 0, 0, 0, 0, 0])
            nv.write(self.HEAD, dummy)
            nv.write(self.TAIL, dummy)
            self.pflush(dummy)
            self.pflush(self.HEAD)
            self.pfence()

    # ---------------------------------------- steady-state schedule facts
    # enq retry: re-read TAIL (hit) and the obstructing tail->next on a
    # line the winner flushed (post-flush), then take the helping path --
    # persist the obstruction (flush+fence) and CAS TAIL forward before
    # re-attempting the link CAS.  deq retry: pure re-reads -- the HEAD
    # and node lines were already re-fetched (and so re-cached) by
    # whichever op touched them first after the invalidating flush, so a
    # retry adds hits, not post-flush accesses.  (Roots come from the
    # op_schedule's root CAS; see queue_base.retry_profile.)
    RETRY_SHAPES = {
        "enq": dict(reads=1, flushed_reads=0.8, cas=2, flushes=1, fences=1,
                    weight=0.6),
        "deq": dict(reads=4),
    }

    def op_schedule(self):
        """Steady state (paper §10 baseline): 2 fences/enq, 1 fence/deq,
        post-flush re-reads of the tail link and head line."""
        enq = OpSchedule("enq", steps=(
            AllocP(),
            WriteLine(L("new_p"), (None, NULL, 0, 0, 0, 0, 0, 0), item_at=0),
            Flush(L("new_p")), Fence(),              # fence #1: node content
            Read(L("TAIL")),
            Read(L("tail_p", NEXT)),
            Cas(L("tail_p", NEXT), ("sym", "new_p"), event="enq"),
            Flush(L("tail_p", NEXT)), Fence(),       # fence #2: link durable
            Cas(L("TAIL"), ("sym", "new_p"), root=True),
        ), retry_from=4)
        deq = OpSchedule("deq", steps=(
            Read(L("HEAD")),
            Read(L("head_p", NEXT)),
            Read(L("TAIL")),                         # MSQ reclamation guard
            Read(L("next_p", ITEM)),
            Cas(L("HEAD"), ("sym", "next_p"), root=True, event="deq"),
            Flush(L("HEAD")), Fence(),               # 1 fence per dequeue
            Retire(("sym", "head_p")),
        ))
        return QueueSchedules(enq=enq, deq=deq, layout=FifoLayout(
            head_root="HEAD", next_off=NEXT, item_off=ITEM))

    # ------------------------------------------------------------------ ops
    def enqueue(self, tid: int, item: Any) -> None:
        nv = self.nvram
        self.mem.op_begin(tid)
        node = self.mem.alloc(tid)
        nv.write_full_line(node, [item, NULL, 0, 0, 0, 0, 0, 0])
        self.pflush(node)
        self.pfence()                       # fence #1: node content durable
        while True:
            tail = nv.read(self.TAIL)
            nxt = nv.read(tail + NEXT)
            if nxt == NULL:
                if nv.cas(tail + NEXT, NULL, node):
                    self._ev("enq", item)
                    self.pflush(tail + NEXT)
                    self.pfence()           # fence #2: link durable
                    nv.cas(self.TAIL, tail, node)
                    return
            else:
                # help: persist the obstructing link before advancing tail
                self.pflush(tail + NEXT)
                self.pfence()
                nv.cas(self.TAIL, tail, nxt)

    def dequeue(self, tid: int) -> Any:
        nv = self.nvram
        self.mem.op_begin(tid)
        while True:
            head = nv.read(self.HEAD)
            nxt = nv.read(head + NEXT)
            if nxt == NULL:
                self.pflush(self.HEAD)
                self.pfence()               # make prior dequeues durable
                self._ev("empty")
                return None
            # MSQ guard: head must not overtake tail (reclamation safety)
            tail = nv.read(self.TAIL)
            if head == tail:
                self.pflush(tail + NEXT)
                self.pfence()
                nv.cas(self.TAIL, tail, nxt)
                continue
            item = nv.read(nxt + ITEM)
            if nv.cas(self.HEAD, head, nxt):
                self._ev("deq", item)
                self.pflush(self.HEAD)
                self.pfence()               # 1 fence per dequeue
                self.mem.retire(tid, head)
                return item

    # ------------------------------------------------------------- recovery
    @classmethod
    def recover(cls, nvram: NVRAM, mem: SSMem, nthreads: int, roots,
                on_event=None) -> "DurableMSQueue":
        q = cls(nvram, mem, nthreads, on_event, _recovering=True, roots=roots)
        head = nvram.pread(q.HEAD) or NULL
        assert head != NULL, "initial head was persisted at construction"
        # the persisted chain from head is the queue
        cur = head
        while True:
            nxt = nvram.pread(cur + NEXT) or NULL
            if nxt == NULL:
                break
            cur = nxt
        nvram.pwrite(q.TAIL, cur)
        # reconstruct free lists: every area node not on the chain is free
        chain = set()
        c = head
        while c != NULL:
            chain.add(c)
            c = nvram.pread(c + NEXT) or NULL
        for base, nnodes in mem.area_addrs():
            for i in range(nnodes):
                a = base + i * LINE_WORDS
                if a not in chain:
                    mem.free_now(0, a)
        nvram.reset_after_recovery()
        return q
