"""The volatile Michael--Scott queue (MSQ, PODC'96) -- paper §3.1.

This is the non-durable substrate every queue in the paper extends, and our
linearizability oracle.  It lives entirely in the volatile address space:
after a crash nothing survives (which is exactly why the durable amendments
exist).  It issues no flushes or fences, so it is the one queue whose cost
is identical under every :class:`repro.core.memmodel.MemoryModel` -- the
benchmark sweep uses it as the memory-model-invariant baseline.
"""
from __future__ import annotations

from typing import Any

from .nvram import NVRAM
from .opsched import (AllocV, Cas, FifoLayout, L, OpSchedule, QueueSchedules,
                      Read, Write)
from .queue_base import NULL, QueueAlgorithm
from .ssmem import VolatileAlloc

# node layout (volatile words)
ITEM, NEXT = 0, 1
NODE_WORDS = 2


class MSQueue(QueueAlgorithm):
    NAME = "MSQ"

    def __init__(self, nvram: NVRAM, mem, nthreads: int, on_event=None):
        super().__init__(nvram, mem, nthreads, on_event)
        self.valloc = VolatileAlloc(nvram, nthreads, NODE_WORDS, name="msq")
        nv = self.nvram
        self.HEAD = nv.alloc_region(1, "msq:head", persistent=False)
        self.TAIL = nv.alloc_region(1, "msq:tail", persistent=False)
        dummy = self._new_node(0, None)
        nv.write(self.HEAD, dummy)
        nv.write(self.TAIL, dummy)

    def _new_node(self, tid: int, item: Any) -> int:
        nv = self.nvram
        n = self.valloc.alloc(tid)
        nv.write(n + ITEM, item)
        nv.write(n + NEXT, NULL)
        return n

    # everything is volatile: a retry re-reads cached words and re-CASes
    RETRY_SHAPES = {
        "enq": dict(reads=2),
        "deq": dict(reads=4),
    }

    def op_schedule(self):
        """Steady state: pure volatile pointer chasing, no persists -- the
        memory-model-invariant baseline."""
        enq = OpSchedule("enq", steps=(
            AllocV(),
            Write(L("new_v", ITEM), ("item",)),
            Write(L("new_v", NEXT), ("c", NULL)),
            Read(L("TAIL")),
            Read(L("tail_v", NEXT)),
            Cas(L("tail_v", NEXT), ("sym", "new_v"), event="enq"),
            Cas(L("TAIL"), ("sym", "new_v"), root=True),
        ), uses_ssmem=False, retry_from=3)
        deq = OpSchedule("deq", steps=(
            Read(L("HEAD")),
            Read(L("head_v", NEXT)),
            Read(L("TAIL")),                     # MSQ reclamation guard
            Read(L("next_v", ITEM)),
            Cas(L("HEAD"), ("sym", "next_v"), root=True, event="deq"),
        ), uses_ssmem=False)
        return QueueSchedules(enq=enq, deq=deq, layout=FifoLayout(
            head_root="HEAD", next_off=NEXT, item_off=ITEM, volatile=True))

    def enqueue(self, tid: int, item: Any) -> None:
        nv = self.nvram
        node = self._new_node(tid, item)
        while True:
            tail = nv.read(self.TAIL)
            nxt = nv.read(tail + NEXT)
            if nxt == NULL:
                if nv.cas(tail + NEXT, NULL, node):
                    self._ev("enq", item)
                    nv.cas(self.TAIL, tail, node)
                    return
            else:
                nv.cas(self.TAIL, tail, nxt)

    def dequeue(self, tid: int) -> Any:
        nv = self.nvram
        while True:
            head = nv.read(self.HEAD)
            nxt = nv.read(head + NEXT)
            if nxt == NULL:
                self._ev("empty")
                return None
            # MSQ guard: never let the head overtake the tail -- keeps TAIL
            # from pointing at a dequeued (reclaimable) node.
            tail = nv.read(self.TAIL)
            if head == tail:
                nv.cas(self.TAIL, tail, nxt)
                continue
            item = nv.read(nxt + ITEM)   # read before CAS: the event right
            if nv.cas(self.HEAD, head, nxt):   # after the CAS is then exact
                self._ev("deq", item)
                # no immediate reuse: MSQ needs safe memory reclamation to
                # avoid ABA; the durable queues use ssmem epochs for this.
                return item
