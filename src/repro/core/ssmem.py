"""ssmem -- epoch-based designated-area allocator (paper §9).

Mirrors the memory manager of Zuriel et al. used by all queues in the paper:

* nodes are allocated from *designated areas* in persistent memory; the list
  of areas is itself persistent, so recovery can scan them;
* a new area is zeroed and persisted with asynchronous flushes + a **single**
  SFENCE (paper §5.1.3) -- zeroed indices/flags make unused nodes invisible
  to recovery;
* each thread has its own allocator (area cursor + free list) to avoid
  synchronization;
* reclamation is epoch-based: ``retire`` defers reuse until every thread has
  passed an epoch boundary, so a node is never recycled while another thread
  may still dereference it;
* free lists are volatile -- after a crash they are reconstructed from the
  areas by the recovery procedure.

Node initialization writes the full line without read-for-ownership
(``write_full_line``): a freshly (re)allocated node's line is entirely
overwritten, which on x86 avoids fetching the (flushed, invalidated) line --
this is what lets the second-amendment queues truly reach **zero post-flush
accesses** on the fast path.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

from .nvram import LINE_WORDS, NVRAM


class SSMem:
    def __init__(self, nvram: NVRAM, nthreads: int, area_nodes: int = 4096,
                 name: str = "ssmem"):
        self.nvram = nvram
        self.nthreads = nthreads
        self.area_nodes = area_nodes
        self.name = name
        # per-thread allocation state (volatile)
        self._areas: Dict[int, List[int]] = {t: [] for t in range(nthreads)}
        self._cursor: Dict[int, int] = {t: 0 for t in range(nthreads)}
        self._free: Dict[int, List[int]] = {t: [] for t in range(nthreads)}
        # epoch-based reclamation (volatile)
        self._epoch = 0
        self._announced: Dict[int, int] = {t: 0 for t in range(nthreads)}
        self._limbo: Dict[int, List[Tuple[int, int, str]]] = {t: [] for t in range(nthreads)}
        self._ops_since_adv = 0
        self._valloc = None   # optional VolatileAlloc sharing the epochs

    # ----------------------------------------------------------------- areas
    def _new_area(self, tid: int) -> int:
        nv = self.nvram
        base = nv.alloc_region(self.area_nodes * LINE_WORDS,
                               name=f"{self.name}:area:t{tid}",
                               persistent=True)
        # zero + persist the whole area with one fence (paper §5.1.3);
        # persist-on-store platforms (eADR) need no flushes at all.
        # On the batched engine, with no per-primitive observers attached
        # (scheduler step hook, trace tap) and no outstanding persists to
        # coalesce into the fence, the whole schedule is applied through
        # the vectorized seam -- bit-identical accounting, ~100x faster.
        if (getattr(nv, "bulk_line_init", None) is not None
                and getattr(nv, "enable_bulk_init", False)
                and nv.step_hook is None and getattr(nv, "_tap", None) is None
                and not nv._pending.get(nv.tid)):
            nv.bulk_line_init(base, self.area_nodes)
        else:
            needs_flush = nv.model.needs_flush
            for i in range(self.area_nodes):
                a = base + i * LINE_WORDS
                nv.write_full_line(a, [0] * LINE_WORDS)
                if needs_flush:
                    nv.flush(a)
            nv.fence()
        self._areas[tid].append(base)
        self._cursor[tid] = 0
        return base

    def area_addrs(self) -> List[Tuple[int, int]]:
        """All designated-area (base, nnodes) pairs -- persistent metadata the
        recovery procedure scans."""
        return [(base, n // LINE_WORDS)
                for (name, base, n, pers) in self.nvram.regions
                if pers and name.startswith(f"{self.name}:area:")]

    # ------------------------------------------------------------ epoch / ebr
    def op_begin(self, tid: int) -> None:
        self._announced[tid] = self._epoch
        self._ops_since_adv += 1
        if self._ops_since_adv >= 64:
            self._ops_since_adv = 0
            self._try_advance()

    def attach_volatile(self, valloc: "VolatileAlloc") -> None:
        """Let a VolatileAlloc reuse this manager's epochs (the Volatile node
        halves of the second-amendment queues need safe reclamation too)."""
        self._valloc = valloc

    def _try_advance(self) -> None:
        min_e = min(self._announced.values())
        if min_e >= self._epoch:
            self._epoch += 1
        # limbo entries carry the epoch current at retire time, so each
        # per-thread list is sorted by epoch and the reclaimable entries
        # (ep + 2 <= min_e) form a prefix: scan it, free in list order
        # (same order the full rebuild produced), drop it in place.  The
        # common case -- nothing reclaimable yet -- is one comparison per
        # thread instead of rebuilding every keep-list.
        cut = min_e - 2
        for t, lst in self._limbo.items():
            if not lst or lst[0][1] > cut:
                continue
            free_t = self._free[t]
            i, n = 0, len(lst)
            while i < n and lst[i][1] <= cut:
                addr, _, kind = lst[i]
                if kind == "p":
                    free_t.append(addr)
                else:
                    self._valloc.free(t, addr)
                i += 1
            del lst[:i]

    # ------------------------------------------------------------ alloc/free
    def alloc(self, tid: int) -> int:
        if self._free[tid]:
            return self._free[tid].pop()
        if not self._areas[tid] or self._cursor[tid] >= self.area_nodes:
            self._new_area(tid)
        base = self._areas[tid][-1]
        addr = base + self._cursor[tid] * LINE_WORDS
        self._cursor[tid] += 1
        return addr

    def retire(self, tid: int, addr: int) -> None:
        self._limbo[tid].append((addr, self._epoch, "p"))

    def retire_volatile(self, tid: int, addr: int) -> None:
        self._limbo[tid].append((addr, self._epoch, "v"))

    def free_now(self, tid: int, addr: int) -> None:
        """Recovery-time reclamation (no concurrent readers exist)."""
        self._free[tid].append(addr)


class VolatileAlloc:
    """Bump/free-list allocator in the volatile address space (DRAM), used
    for the Volatile halves of the second-amendment queues' nodes."""

    def __init__(self, nvram: NVRAM, nthreads: int, node_words: int = LINE_WORDS,
                 chunk_nodes: int = 4096, name: str = "vol"):
        self.nvram = nvram
        self.node_words = node_words
        self.chunk_nodes = chunk_nodes
        self.name = name
        self._free: Dict[int, List[int]] = {t: [] for t in range(nthreads)}
        self._base: Dict[int, Optional[int]] = {t: None for t in range(nthreads)}
        self._cursor: Dict[int, int] = {t: 0 for t in range(nthreads)}

    def alloc(self, tid: int) -> int:
        if self._free[tid]:
            return self._free[tid].pop()
        if self._base[tid] is None or self._cursor[tid] >= self.chunk_nodes:
            self._base[tid] = self.nvram.alloc_region(
                self.chunk_nodes * self.node_words,
                name=f"{self.name}:chunk:t{tid}", persistent=False)
            self._cursor[tid] = 0
        addr = self._base[tid] + self._cursor[tid] * self.node_words
        self._cursor[tid] += 1
        return addr

    def free(self, tid: int, addr: int) -> None:
        self._free[tid].append(addr)
