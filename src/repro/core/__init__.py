"""Core reproduction of "Durable Queues: The Second Amendment" (SPAA'21).

Simulated NVRAM (cache-line model with CLWB-invalidation semantics and
Assumption-1 crash prefixes), a deterministic interleaving scheduler, the
ssmem designated-area allocator, and the seven queue algorithms:

========================  ======================  ==========================
queue                     fences / update op      post-flush accesses
========================  ======================  ==========================
MSQ (volatile)            0 (not durable)         --
IzraelevitzQ              many (per shared op)    yes
NVTraverseQ               several                 yes
DurableMSQ (Friedman'18)  2 enq / 1 deq           yes
UnlinkedQ   (1st amend.)  1                       yes
LinkedQ     (1st amend.)  1                       yes
OptUnlinkedQ (2nd amend.) 1                       **0**
OptLinkedQ   (2nd amend.) 1                       **0**
========================  ======================  ==========================
"""
from .memmodel import (MEMORY_MODELS, MemoryModel, OPTANE_CLWB, EADR,
                       CXL_MEM, get_memory_model)
from .contention import ContentionModel, LearnedRetryProfile, RetryProfile
from .nvram import (NVRAM, LINE_WORDS, CrashChoices, EngineSnapshot, Stats,
                    ThreadCrashed)
from .nvram_ref import ReferenceNVRAM
from .opsched import (FastPathExecutor, OpSchedule, QueueSchedules,
                      ScheduleError, compile_schedule, linearizing_root,
                      retry_touches_persistent)
from .scheduler import ClockScheduler, Scheduler
from .ssmem import SSMem, VolatileAlloc
from .queue_base import NULL, QueueAlgorithm
from .msq import MSQueue
from .durable_msq import DurableMSQueue
from .izraelevitz import IzraelevitzQueue, NVTraverseQueue
from .unlinked import UnlinkedQueue
from .linked import LinkedQueue
from .opt_unlinked import OptUnlinkedQueue
from .opt_linked import OptLinkedQueue
from .onll import ONLL
from .harness import (ALL_QUEUES, DURABLE_QUEUES, QueueHarness,
                      check_durable_linearizability, split_at_crash)

__all__ = [
    "ContentionModel", "LearnedRetryProfile", "RetryProfile",
    "NVRAM", "ReferenceNVRAM", "LINE_WORDS", "Stats", "ThreadCrashed",
    "CrashChoices", "EngineSnapshot",
    "Scheduler", "ClockScheduler", "SSMem", "VolatileAlloc", "NULL",
    "QueueAlgorithm", "MSQueue", "DurableMSQueue", "IzraelevitzQueue",
    "NVTraverseQueue", "UnlinkedQueue", "LinkedQueue", "OptUnlinkedQueue",
    "OptLinkedQueue", "ONLL", "ALL_QUEUES", "DURABLE_QUEUES", "QueueHarness",
    "check_durable_linearizability", "split_at_crash", "MemoryModel",
    "MEMORY_MODELS", "OPTANE_CLWB", "EADR", "CXL_MEM", "get_memory_model",
    "FastPathExecutor", "OpSchedule", "QueueSchedules", "ScheduleError",
    "compile_schedule", "linearizing_root", "retry_touches_persistent",
]
