"""The repo's two schedulers: exact per-primitive vs. batched clock-driven.

**Exact** (:class:`Scheduler`): queue algorithms call into
:class:`repro.core.nvram.NVRAM` primitives; each primitive is a *yield
point* (``NVRAM.step_hook``).  Real OS threads run the algorithm code, but
exactly one thread is granted one primitive at a time, in a seed-determined
order.  This gives:

* reproducible interleavings (seeded random / round-robin policies),
* crash injection at an exact global step index (``crash_at``), after which
  every thread observes :class:`ThreadCrashed` at its next primitive -- the
  full-system-crash model of Izraelevitz et al. adopted by the paper (§2).

This is the standard model-checking-style harness for persistency
algorithms -- how we validate durable linearizability without NVRAM
hardware -- but the condition-variable handoff costs milliseconds per op,
capping it at seed-era scales (tens of ops per thread).

**Batched** (:class:`ClockScheduler`): a discrete-event executor with no OS
threads and no yield points.  At each step the thread with the smallest
simulated clock runs its next *whole operation* inline; thread clocks (from
the engine's latency model) drive the interleaving deterministically.  This
is the throughput path behind ``QueueHarness.run_batched`` (thousands of
ops/thread, 1--64 threads) -- but running each op to completion means no
CAS ever fails, so multi-thread contention must be modeled, not observed.

**Contention windows**: ops whose simulated intervals overlap are
*co-scheduled* -- they form the clock window an op contends in.  When a
:class:`repro.core.contention.ContentionModel` is attached, the scheduler
ticks ``NVRAM.epoch`` once per executed op (stamping per-line access
epochs) and, after each op, lets the model charge the CAS retries + helping
work a real interleaving of that window would have executed (see
contention.py for the model).  Crash injection stays exclusive to the exact
scheduler: crash tests use :class:`Scheduler`, benchmarks use
:class:`ClockScheduler`.
"""
from __future__ import annotations

import gc
import heapq
import random
import threading
from typing import Callable, List, Optional

from .nvram import NVRAM, ThreadCrashed


class Scheduler:
    def __init__(self, nvram: NVRAM, seed: int = 0, policy: str = "random",
                 crash_at: Optional[int] = None, max_steps: int = 2_000_000,
                 snapshot_hook: Optional[Callable[[int], None]] = None):
        self.nvram = nvram
        self.rng = random.Random(seed)
        self.policy = policy
        self.crash_at = crash_at
        self.max_steps = max_steps
        # Crash-sweep seam: called as snapshot_hook(s) at every *quiescent
        # boundary* -- every live thread parked at a yield point, s
        # primitives fully executed (including the trailing non-primitive
        # code of the thread that ran primitive s).  The engine state at
        # boundary s is exactly what a crash_at=s run would leave behind,
        # so one hooked run captures every crash point at once.  Called
        # once more after the last primitive (s = total) on crash-free runs.
        self.snapshot_hook = snapshot_hook
        self.steps = 0
        # grants[i] = (tid, primitive kind) of granted primitive i+1 --
        # the sweep classifies crash boundaries (persist-adjacent vs
        # interior) from this record.  Only recorded on hooked (crash-
        # capture) runs: long exact runs (trace fitting, calibration)
        # must not accumulate millions of unused tuples.
        self.grants: List[tuple] = []
        self._record_grants = snapshot_hook is not None
        self.crashed = False
        self._cv = threading.Condition()
        self._waiting: set = set()
        self._done: set = set()
        self._grant: Optional[int] = None
        self._started = 0
        nvram.step_hook = self.step

    # ------------------------------------------------------------ worker side
    def step(self, tid: int, kind: str) -> None:
        with self._cv:
            if self.crashed:
                raise ThreadCrashed()
            self._waiting.add(tid)
            self._cv.notify_all()
            while self._grant != tid:
                if self.crashed:
                    self._waiting.discard(tid)
                    self._cv.notify_all()
                    raise ThreadCrashed()
                self._cv.wait()
            # granted: consume and run one primitive
            self._grant = None
            self._waiting.discard(tid)
            if self._record_grants:
                self.grants.append((tid, kind))
            # trace hook: the primitive about to execute carries this global
            # step index (grants are serialized, so the stamp cannot race)
            tap = getattr(self.nvram, "_tap", None)
            if tap is not None:
                tap.on_sched_step(self.steps)
            self._cv.notify_all()

    # ------------------------------------------------------- coordinator side
    def run(self, workers: List[Callable[[int], None]]) -> bool:
        """Run worker callables (one per thread).  Returns True if a crash
        was injected."""
        n = len(workers)
        threads = []

        def _wrap(tid: int, fn: Callable[[int], None]):
            self.nvram.set_tid(tid)
            try:
                fn(tid)
            except ThreadCrashed:
                pass
            finally:
                with self._cv:
                    self._done.add(tid)
                    self._waiting.discard(tid)
                    self._cv.notify_all()

        for i, fn in enumerate(workers):
            t = threading.Thread(target=_wrap, args=(i, fn), daemon=True)
            threads.append(t)
            t.start()

        with self._cv:
            while len(self._done) < n:
                # wait until every live thread is parked at a yield point
                self._cv.wait_for(
                    lambda: len(self._waiting) + len(self._done) >= n
                    or len(self._done) == n)
                if len(self._done) == n:
                    break
                live = sorted(self._waiting)
                if not live:
                    continue
                if (self.crash_at is not None and self.steps >= self.crash_at) \
                        or self.steps >= self.max_steps:
                    self.crashed = True
                    self._cv.notify_all()
                    self._cv.wait_for(lambda: len(self._done) == n)
                    break
                if self.snapshot_hook is not None:
                    # quiescent boundary: `steps` primitives fully executed,
                    # all live threads parked -- safe to snapshot the engine
                    self.snapshot_hook(self.steps)
                if self.policy == "rr":
                    tid = live[self.steps % len(live)]
                else:
                    tid = self.rng.choice(live)
                self._grant = tid
                self.steps += 1
                self._cv.notify_all()
                # wait for the grant to be consumed
                self._cv.wait_for(lambda: self._grant is None
                                  or len(self._done) == n)

        for t in threads:
            t.join()
        if self.snapshot_hook is not None and not self.crashed:
            # final boundary: every primitive executed, all threads done
            self.snapshot_hook(self.steps)
        self.nvram.step_hook = None
        return self.crashed


class _NullProfiler:
    """No-op stand-in letting the profiled and unprofiled clock-heap
    loops share one body (the hooks cost two empty calls per op on the
    generic path; the columnar/burst hot paths never see them)."""

    __slots__ = ()

    def push(self, name: str) -> None:
        pass

    def pop(self) -> None:
        pass


_NULL_PROF = _NullProfiler()


class ClockScheduler:
    """Batched discrete-event executor: no OS threads, no per-primitive
    yields.

    The exact :class:`Scheduler` above serializes every memory primitive
    through a condition variable between real OS threads -- the right tool
    for model checking crash interleavings, but it caps the harness at tens
    of ops per thread.  For *throughput* runs the interleaving inside one
    queue operation does not change the cost accounting (per-thread latency
    clocks), so this scheduler interleaves at **operation granularity**,
    driven by the simulated clocks themselves: at each step the thread with
    the smallest simulated time executes its next whole operation inline.
    That is a classic discrete-event simulation -- thread clocks stay as
    tightly interleaved as the latency model allows, deterministically
    (ties break by thread id), and the engine's batched cost accumulator is
    drained once per operation instead of once per primitive.

    Sequential accounting is bit-identical to the exact scheduler's (the
    differential tests assert this), which makes thousands of ops per thread
    and 1--64-thread sweeps practical.

    Note: the schedule is fully clock-determined (no randomness) -- varying
    a workload's interleaving across runs is done by varying the *plans*
    (e.g. the mixed5050 generator's seed), not the scheduler.
    """

    def __init__(self, nvram: NVRAM, contention=None, fast=None,
                 pause_gc: bool = True, profile=None, burst=None):
        self.nvram = nvram
        self.contention = contention   # Optional[ContentionModel]
        self.fast = fast               # Optional[opsched.FastPathExecutor]
        self.pause_gc = pause_gc       # False: seed-era GC behavior
        # Optional observation-only phase profiler (duck-typed push/pop,
        # e.g. repro.obs.PhaseProfiler).  When attached, columnar runs
        # take an instrumented per-op loop dispatching the same compiled
        # fns the merged runner splices -- identical Stats/records
        # (tests/test_obs_bit_identity.py), per-op timer cost only when
        # profiling.  None leaves the hot loops untouched.
        self.profile = profile
        # Burst execution (repro.core.burst): True enables it with
        # defaults, a dict passes BurstExecutor options through.  Only
        # engages on columnar runs of burst-eligible queues; everything
        # else silently stays on the merged columnar runner.
        self.burst = burst
        self.burst_exec = None         # BurstExecutor of the last run
        self.ops_run = 0

    def run(self, op_lists: Optional[List[List[Callable[[], None]]]],
            op_kinds: Optional[List[List[str]]] = None,
            op_items: Optional[List[List]] = None,
            make_op: Optional[Callable] = None) -> bool:
        """op_lists[t] is thread t's sequence of zero-argument op thunks;
        op_kinds[t][i] (required when a contention model or fast executor
        is attached) names thunk i's kind ('enq'/'deq') so retries charge
        the right profile; op_items[t][i] is the enqueued item (fast path
        only).  Returns False (this scheduler never injects crashes).

        ``op_lists`` may be None when columnar dispatch will engage (fast
        executor with an attached record store, no contention model,
        tracking off): compiled replays never touch the thunks, so the
        caller skips building ops-count closures up front and instead
        passes ``make_op(t, kind, item) -> thunk``, called only on the
        rare bails.

        With a :class:`repro.core.opsched.FastPathExecutor` attached, each
        op is first offered to the compiled schedule replay; ops outside
        the steady state (empty dequeues, warmup, allocator refills) fall
        back to their real thunk, after which the executor resyncs its
        logical view.  Thread clocks are read back from the engine either
        way, so the schedule (and every Stat) is identical to per-op
        execution -- asserted bit-for-bit by the equivalence suite."""
        nv = self.nvram
        cm = self.contention
        fast = self.fast
        if cm is not None and op_kinds is None:
            raise ValueError("contention modeling needs op_kinds")
        if fast is not None and (op_kinds is None or op_items is None):
            raise ValueError("the fast path needs op_kinds and op_items")
        prof = self.profile
        prev_hook, nv.step_hook = nv.step_hook, None   # no yield points
        # Throughput runs allocate millions of small acyclic objects
        # (op records, event tuples, store-log entries); generational GC
        # passes over the growing live set cost ~30% of wall time for
        # zero reclaim.  Refcounting handles everything we drop.
        gc_was_enabled = self.pause_gc and gc.isenabled()
        if gc_was_enabled:
            gc.disable()
        if prof is not None:
            prof.push("bookkeeping")
        try:
            seed_src = op_lists if op_lists is not None else op_kinds
            cursors = [0] * len(seed_src)
            heap = [(nv.thread_time_ns(t), t) for t, ops in
                    enumerate(seed_src) if ops]
            heapq.heapify(heap)
            timed = (fast is not None and cm is None and fast.timed)
            if (timed and fast.rstore is not None
                    and not nv.contention_tracking):
                return self._run_columnar(heap, cursors, op_lists,
                                          op_kinds, op_items, make_op,
                                          prof)
            if op_lists is None:
                raise ValueError("op_lists omitted but columnar dispatch "
                                 "is unavailable on this run")
            self._heap_loop(heap, cursors, op_lists, op_kinds, op_items,
                            timed,
                            prof if prof is not None else _NULL_PROF)
        finally:
            nv.step_hook = prev_hook
            if gc_was_enabled:
                gc.enable()
            if prof is not None:
                prof.pop()   # bookkeeping
        return False

    def _heap_loop(self, heap, cursors, op_lists, op_kinds, op_items,
                   timed: bool, prof) -> None:
        """The generic clock-heap loop (everything except columnar
        dispatch), shared by the profiled and unprofiled paths -- ``prof``
        is either the attached profiler or the no-op stand-in.  Phases:
        ``heap-loop`` (pop/push + cursor bookkeeping),
        ``interpreted-body`` (op bodies: compiled replay or plain
        thunks), ``bail-real-op`` (fast-path bails incl. resync)."""
        nv = self.nvram
        cm = self.contention
        fast = self.fast
        heappush, heappop = heapq.heappush, heapq.heappop
        prof.push("heap-loop")
        try:
            while heap:
                t_start, t = heappop(heap)
                i = cursors[t]
                if timed:
                    # compiled replay with exact incremental clocks: the
                    # engine is only consulted on bail (real execution)
                    prof.push("interpreted-body")
                    t_end = fast.try_op_timed(t, op_kinds[t][i],
                                              op_items[t][i], t_start)
                    prof.pop()
                    if t_end is None:
                        prof.push("bail-real-op")
                        nv.set_tid(t)
                        op_lists[t][i]()
                        fast.after_real_op(t, op_kinds[t][i])
                        t_end = nv.thread_time_ns(t)
                        prof.pop()
                else:
                    nv.set_tid(t)
                    if cm is not None:
                        nv.epoch += 1     # one clock-window tick per op
                    if fast is not None:
                        kind = op_kinds[t][i]
                        prof.push("interpreted-body")
                        hit = fast.try_op(t, kind, op_items[t][i])
                        prof.pop()
                        if not hit:
                            prof.push("bail-real-op")
                            op_lists[t][i]()
                            fast.after_real_op(t, kind)
                            prof.pop()
                    else:
                        prof.push("interpreted-body")
                        op_lists[t][i]()
                        prof.pop()
                    if cm is not None:
                        t_end = cm.after_op(t, op_kinds[t][i], t_start)
                    else:
                        t_end = nv.thread_time_ns(t)
                self.ops_run += 1
                cursors[t] += 1
                if cursors[t] < len(op_lists[t]):
                    heappush(heap, (t_end, t))
        finally:
            prof.pop()   # heap-loop

    def _run_columnar(self, heap, cursors, op_lists, op_kinds, op_items,
                      make_op, prof) -> bool:
        """Columnar dispatch: the per-kind staged fns append to the
        record store's staging lists; charges and record materialization
        happen in vector bursts at sync points.  Three drivers, all
        bit-identical:

        * the merged ``fast.crunner`` (default, no profiler) -- per-op
          fn bodies spliced into one loop;
        * the burst executor (``burst`` enabled and the queue is
          burst-eligible) -- whole multi-thread bursts as array
          programs, rejected bursts replayed through the merged runner
          in bounded chunks (the ``mispredict-replay`` phase);
        * an instrumented per-op loop (profiler attached, no burst) --
          dispatches the exact fn bodies the runner splices, per-op
          timer cost only when profiling.
        """
        nv = self.nvram
        fast = self.fast
        rs = fast.rstore
        lens = [len(ks) for ks in op_kinds]

        def bail(t, i, t_start, kind):
            # outside the compiled steady state: materialize the staged
            # burst so the engine clock read after the real thunk is
            # exact, run the real thunk, stitch its clocks into the
            # store's per-thread chain
            rs.sync()
            nv.set_tid(t)
            if op_lists is not None:
                op_lists[t][i]()
            else:
                make_op(t, kind, op_items[t][i])()
            fast.after_real_op(t, kind)
            t_end = nv.thread_time_ns(t)
            rs.note_real_clocks(t, t_start, t_end)
            return t_end

        bx = None
        if self.burst:
            from .burst import BurstExecutor, build_burst_program
            bprog = build_burst_program(fast)
            if bprog is not None:
                opts = dict(self.burst) if isinstance(self.burst, dict) \
                    else {}
                bx = BurstExecutor(bprog, fast, op_kinds, op_items, lens,
                                   profile=prof, **opts)
                self.burst_exec = bx
        if bx is not None:
            crunner = fast.crunner
            if prof is not None:
                prof.push("heap-loop")
            try:
                while heap:
                    n = bx.try_burst(heap, cursors)
                    self.ops_run += n
                    if heap and n == 0:
                        # burst rejected here: replay a bounded chunk on
                        # the merged columnar runner, bit-identically
                        if prof is not None:
                            prof.push("mispredict-replay")
                        m = crunner(heap, cursors, op_kinds, op_items,
                                    lens, bail, bx.REPLAY_CHUNK)
                        if prof is not None:
                            prof.pop()
                        self.ops_run += m
                        bx.replayed_ops += m
            finally:
                if prof is not None:
                    prof.pop()   # heap-loop
            return False
        if prof is None:
            self.ops_run += fast.crunner(
                heap, cursors, op_kinds, op_items, lens, bail)
            return False
        # instrumented per-op columnar loop: dispatches the per-kind
        # staged fns -- the exact bodies the merged runner splices, so
        # every append, charge and clock is bit-identical; the merged
        # runner is purely a loop-overhead optimization
        fns = fast.cfns
        fenq, fdeq = fns["enq"], fns["deq"]
        heappush, heappop = heapq.heappush, heapq.heappop
        prof.push("heap-loop")
        try:
            while heap:
                t_start, t = heappop(heap)
                i = cursors[t]
                kind = op_kinds[t][i]
                prof.push("interpreted-body")
                t_end = (fenq if kind == "enq" else fdeq)(
                    t, op_items[t][i], t_start)
                prof.pop()
                if t_end is None:
                    prof.push("bail-real-op")
                    t_end = bail(t, i, t_start, kind)
                    prof.pop()
                self.ops_run += 1
                cursors[t] = i + 1
                if i + 1 < lens[t]:
                    heappush(heap, (t_end, t))
        finally:
            prof.pop()   # heap-loop
        return False
