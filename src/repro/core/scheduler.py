"""Deterministic cooperative scheduler for concurrency + crash testing.

Queue algorithms call into :class:`repro.core.nvram.NVRAM` primitives; each
primitive is a *yield point* (``NVRAM.step_hook``).  The scheduler serializes
primitives: real OS threads run the algorithm code, but exactly one thread is
granted one primitive at a time, in a seed-determined order.  This gives:

* reproducible interleavings (seeded random / round-robin policies),
* crash injection at an exact global step index (``crash_at``), after which
  every thread observes :class:`ThreadCrashed` at its next primitive -- the
  full-system-crash model of Izraelevitz et al. adopted by the paper (§2).

This is the standard model-checking-style harness for persistency algorithms;
it is how we validate durable linearizability without NVRAM hardware.
"""
from __future__ import annotations

import random
import threading
from typing import Callable, List, Optional

from .nvram import NVRAM, ThreadCrashed


class Scheduler:
    def __init__(self, nvram: NVRAM, seed: int = 0, policy: str = "random",
                 crash_at: Optional[int] = None, max_steps: int = 2_000_000):
        self.nvram = nvram
        self.rng = random.Random(seed)
        self.policy = policy
        self.crash_at = crash_at
        self.max_steps = max_steps
        self.steps = 0
        self.crashed = False
        self._cv = threading.Condition()
        self._waiting: set = set()
        self._done: set = set()
        self._grant: Optional[int] = None
        self._started = 0
        nvram.step_hook = self.step

    # ------------------------------------------------------------ worker side
    def step(self, tid: int, kind: str) -> None:
        with self._cv:
            if self.crashed:
                raise ThreadCrashed()
            self._waiting.add(tid)
            self._cv.notify_all()
            while self._grant != tid:
                if self.crashed:
                    self._waiting.discard(tid)
                    self._cv.notify_all()
                    raise ThreadCrashed()
                self._cv.wait()
            # granted: consume and run one primitive
            self._grant = None
            self._waiting.discard(tid)
            self._cv.notify_all()

    # ------------------------------------------------------- coordinator side
    def run(self, workers: List[Callable[[int], None]]) -> bool:
        """Run worker callables (one per thread).  Returns True if a crash
        was injected."""
        n = len(workers)
        threads = []

        def _wrap(tid: int, fn: Callable[[int], None]):
            self.nvram.set_tid(tid)
            try:
                fn(tid)
            except ThreadCrashed:
                pass
            finally:
                with self._cv:
                    self._done.add(tid)
                    self._waiting.discard(tid)
                    self._cv.notify_all()

        for i, fn in enumerate(workers):
            t = threading.Thread(target=_wrap, args=(i, fn), daemon=True)
            threads.append(t)
            t.start()

        with self._cv:
            while len(self._done) < n:
                # wait until every live thread is parked at a yield point
                self._cv.wait_for(
                    lambda: len(self._waiting) + len(self._done) >= n
                    or len(self._done) == n)
                if len(self._done) == n:
                    break
                live = sorted(self._waiting)
                if not live:
                    continue
                if (self.crash_at is not None and self.steps >= self.crash_at) \
                        or self.steps >= self.max_steps:
                    self.crashed = True
                    self._cv.notify_all()
                    self._cv.wait_for(lambda: len(self._done) == n)
                    break
                if self.policy == "rr":
                    tid = live[self.steps % len(live)]
                else:
                    tid = self.rng.choice(live)
                self._grant = tid
                self.steps += 1
                self._cv.notify_all()
                # wait for the grant to be consumed
                self._cv.wait_for(lambda: self._grant is None
                                  or len(self._done) == n)

        for t in threads:
            t.join()
        self.nvram.step_hook = None
        return self.crashed
