"""Deterministic cooperative scheduler for concurrency + crash testing.

Queue algorithms call into :class:`repro.core.nvram.NVRAM` primitives; each
primitive is a *yield point* (``NVRAM.step_hook``).  The scheduler serializes
primitives: real OS threads run the algorithm code, but exactly one thread is
granted one primitive at a time, in a seed-determined order.  This gives:

* reproducible interleavings (seeded random / round-robin policies),
* crash injection at an exact global step index (``crash_at``), after which
  every thread observes :class:`ThreadCrashed` at its next primitive -- the
  full-system-crash model of Izraelevitz et al. adopted by the paper (§2).

This is the standard model-checking-style harness for persistency algorithms;
it is how we validate durable linearizability without NVRAM hardware.
"""
from __future__ import annotations

import heapq
import random
import threading
from typing import Callable, List, Optional

from .nvram import NVRAM, ThreadCrashed


class Scheduler:
    def __init__(self, nvram: NVRAM, seed: int = 0, policy: str = "random",
                 crash_at: Optional[int] = None, max_steps: int = 2_000_000):
        self.nvram = nvram
        self.rng = random.Random(seed)
        self.policy = policy
        self.crash_at = crash_at
        self.max_steps = max_steps
        self.steps = 0
        self.crashed = False
        self._cv = threading.Condition()
        self._waiting: set = set()
        self._done: set = set()
        self._grant: Optional[int] = None
        self._started = 0
        nvram.step_hook = self.step

    # ------------------------------------------------------------ worker side
    def step(self, tid: int, kind: str) -> None:
        with self._cv:
            if self.crashed:
                raise ThreadCrashed()
            self._waiting.add(tid)
            self._cv.notify_all()
            while self._grant != tid:
                if self.crashed:
                    self._waiting.discard(tid)
                    self._cv.notify_all()
                    raise ThreadCrashed()
                self._cv.wait()
            # granted: consume and run one primitive
            self._grant = None
            self._waiting.discard(tid)
            self._cv.notify_all()

    # ------------------------------------------------------- coordinator side
    def run(self, workers: List[Callable[[int], None]]) -> bool:
        """Run worker callables (one per thread).  Returns True if a crash
        was injected."""
        n = len(workers)
        threads = []

        def _wrap(tid: int, fn: Callable[[int], None]):
            self.nvram.set_tid(tid)
            try:
                fn(tid)
            except ThreadCrashed:
                pass
            finally:
                with self._cv:
                    self._done.add(tid)
                    self._waiting.discard(tid)
                    self._cv.notify_all()

        for i, fn in enumerate(workers):
            t = threading.Thread(target=_wrap, args=(i, fn), daemon=True)
            threads.append(t)
            t.start()

        with self._cv:
            while len(self._done) < n:
                # wait until every live thread is parked at a yield point
                self._cv.wait_for(
                    lambda: len(self._waiting) + len(self._done) >= n
                    or len(self._done) == n)
                if len(self._done) == n:
                    break
                live = sorted(self._waiting)
                if not live:
                    continue
                if (self.crash_at is not None and self.steps >= self.crash_at) \
                        or self.steps >= self.max_steps:
                    self.crashed = True
                    self._cv.notify_all()
                    self._cv.wait_for(lambda: len(self._done) == n)
                    break
                if self.policy == "rr":
                    tid = live[self.steps % len(live)]
                else:
                    tid = self.rng.choice(live)
                self._grant = tid
                self.steps += 1
                self._cv.notify_all()
                # wait for the grant to be consumed
                self._cv.wait_for(lambda: self._grant is None
                                  or len(self._done) == n)

        for t in threads:
            t.join()
        self.nvram.step_hook = None
        return self.crashed


class ClockScheduler:
    """Batched discrete-event executor: no OS threads, no per-primitive
    yields.

    The exact :class:`Scheduler` above serializes every memory primitive
    through a condition variable between real OS threads -- the right tool
    for model checking crash interleavings, but it caps the harness at tens
    of ops per thread.  For *throughput* runs the interleaving inside one
    queue operation does not change the cost accounting (per-thread latency
    clocks), so this scheduler interleaves at **operation granularity**,
    driven by the simulated clocks themselves: at each step the thread with
    the smallest simulated time executes its next whole operation inline.
    That is a classic discrete-event simulation -- thread clocks stay as
    tightly interleaved as the latency model allows, deterministically
    (ties break by thread id), and the engine's batched cost accumulator is
    drained once per operation instead of once per primitive.

    Sequential accounting is bit-identical to the exact scheduler's (the
    differential tests assert this), which makes thousands of ops per thread
    and 1--64-thread sweeps practical.

    Note: the schedule is fully clock-determined (no randomness) -- varying
    a workload's interleaving across runs is done by varying the *plans*
    (e.g. the mixed5050 generator's seed), not the scheduler.
    """

    def __init__(self, nvram: NVRAM):
        self.nvram = nvram
        self.ops_run = 0

    def run(self, op_lists: List[List[Callable[[], None]]]) -> bool:
        """op_lists[t] is thread t's sequence of zero-argument op thunks.
        Returns False (this scheduler never injects crashes)."""
        nv = self.nvram
        prev_hook, nv.step_hook = nv.step_hook, None   # no yield points
        try:
            cursors = [0] * len(op_lists)
            heap = [(nv.thread_time_ns(t), t) for t, ops in
                    enumerate(op_lists) if ops]
            heapq.heapify(heap)
            while heap:
                _, t = heapq.heappop(heap)
                nv.set_tid(t)
                op_lists[t][cursors[t]]()
                self.ops_run += 1
                cursors[t] += 1
                if cursors[t] < len(op_lists[t]):
                    heapq.heappush(heap, (nv.thread_time_ns(t), t))
        finally:
            nv.step_hook = prev_hook
        return False
