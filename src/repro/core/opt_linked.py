"""OptLinkedQ -- second amendment of LinkedQ (paper §6.2, §6.3).

LinkedQ transformed to zero post-flush accesses with one fence per op:

* recovery is **reversed**: it walks *backward* ``pred`` links from
  per-thread last-enqueue records instead of forward ``next`` links from a
  flushed head -- forward links live only in the Volatile halves;
* node = Persistent{item, index, pred} + Volatile{copies + next + pptr};
  ``index`` is written *last* so (Assumption 1) a non-stale index certifies
  item/pred; recovery detects stale nodes by nonconsecutive indices;
* per-thread **head index** and **two last-enqueue records** (last and
  penultimate -- the penultimate enqueue's fence guarantees a fully durable
  chain) are written with movnti, never read on the fast path.  The
  penultimate record is written *before* the last one so any crash-time
  prefix of the line still exposes a valid completed candidate;
* recovery sorts candidates by index descending and takes the first from
  which a backward walk of consecutive indices reaches head-index + 1.
"""
from __future__ import annotations

from typing import Any, List, Set, Tuple

from .nvram import LINE_WORDS, NVRAM
from .opsched import (AllocP, AllocV, Cas, Fence, FifoLayout, Flush, L,
                      Movnti, OpSchedule, PersistedAdd, PersistedDiscard,
                      QueueSchedules, Read, Retire, RetireV, SlotSet, Write,
                      WriteLine)
from .queue_base import NULL, QueueAlgorithm
from .ssmem import SSMem, VolatileAlloc

# Persistent half (designated area line)
P_ITEM, P_INDEX, P_PRED = 0, 1, 2
# Volatile half
V_ITEM, V_INDEX, V_NEXT, V_PPTR, V_PREDV = 0, 1, 2, 3, 4
V_WORDS = 5
# per-thread record line: [pen_ptr, pen_idx, last_ptr, last_idx]
R_PEN_PTR, R_PEN_IDX, R_LAST_PTR, R_LAST_IDX = 0, 1, 2, 3


class OptLinkedQueue(QueueAlgorithm):
    NAME = "OptLinkedQ"

    def __init__(self, nvram: NVRAM, mem: SSMem, nthreads: int, on_event=None,
                 _recovering: bool = False, roots=None):
        super().__init__(nvram, mem, nthreads, on_event)
        nv = self.nvram
        self.valloc = VolatileAlloc(nvram, nthreads, V_WORDS, name="optlnq")
        mem.attach_volatile(self.valloc)
        if roots is None:
            hidx = nv.alloc_region(nthreads * LINE_WORDS, "optlnq:headidx")
            # +1 line: the recovery-written last-enqueue record
            le = nv.alloc_region((nthreads + 1) * LINE_WORDS, "optlnq:lastenq")
            roots = [hidx, le]
        self.HEADIDX, self.LASTENQ = roots
        self.roots = roots
        self.HEAD = nv.alloc_region(1, "optlnq:head", persistent=False)
        self.TAIL = nv.alloc_region(1, "optlnq:tail", persistent=False)
        # volatile helpers
        self._persisted: Set[int] = set()
        self._last: List[Tuple[int, int]] = [(NULL, 0)] * nthreads
        if not _recovering:
            for t in range(nthreads):
                nv.movnti(self.HEADIDX + t * LINE_WORDS, 0)
                self._write_record(t, (NULL, 0), (NULL, 0))
            self._write_record(nthreads, (NULL, 0), (NULL, 0))  # recovery slot
            dummy_p = self.mem.alloc(0)
            nv.write_full_line(dummy_p, [None, 0, NULL, 0, 0, 0, 0, 0])
            self.pflush(dummy_p)
            self.pfence()
            self._persisted.add(dummy_p)
            dummy_v = self._new_vnode(0, None, 0, dummy_p, NULL)
            nv.write(self.HEAD, dummy_v)
            nv.write(self.TAIL, dummy_v)

    # ---------------------------------------------------------------- helpers
    def _write_record(self, slot: int, pen: Tuple[int, int],
                      last: Tuple[int, int]) -> None:
        """movnti the per-thread record; penultimate BEFORE last (see module
        docstring -- crash-prefix then always exposes a completed candidate)."""
        nv = self.nvram
        base = self.LASTENQ + slot * LINE_WORDS
        nv.movnti(base + R_PEN_PTR, pen[0])
        nv.movnti(base + R_PEN_IDX, pen[1])
        nv.movnti(base + R_LAST_PTR, last[0])
        nv.movnti(base + R_LAST_IDX, last[1])

    def _new_vnode(self, tid: int, item: Any, idx: int, pptr: int,
                   predv: int) -> int:
        nv = self.nvram
        v = self.valloc.alloc(tid)
        nv.write(v + V_ITEM, item)
        nv.write(v + V_INDEX, idx)
        nv.write(v + V_NEXT, NULL)
        nv.write(v + V_PPTR, pptr)
        nv.write(v + V_PREDV, predv)
        return v

    # ---------------------------------------- steady-state schedule facts
    # Second amendment: retries re-read Volatile halves only (index, pred
    # pointer, next) -- zero flushed_reads (the volatile-only retry body
    # in the schedule proves it), so contended runs keep
    # post_flush_accesses == 0 (property-tested).
    RETRY_SHAPES = {
        "enq": dict(reads=4),
        "deq": dict(reads=4),
    }

    def op_schedule(self):
        """Steady state (§6.2, §6.3): the enqueue's backward flush walk
        covers exactly its own Persistent half (the tail's is already
        durable -- ``tail_persisted`` bails otherwise), then movnti-writes
        the per-thread last-enqueue record (penultimate before last) and
        issues the single fence.  Dequeue mirrors OptUnlinkedQ."""
        enq = OpSchedule("enq", steps=(
            AllocP(),
            PersistedDiscard("new_p"),   # recycled addr: durable-hint evict
            WriteLine(L("new_p"), (None, 0, NULL, 0, 0, 0, 0, 0), item_at=0),
            AllocV(),
            Write(L("new_v", V_ITEM), ("item",)),
            Write(L("new_v", V_INDEX), ("c", 0)),
            Write(L("new_v", V_NEXT), ("c", NULL)),
            Write(L("new_v", V_PPTR), ("sym", "new_p")),
            Write(L("new_v", V_PREDV), ("c", NULL)),
            Read(L("TAIL")),
            Read(L("tail_v", V_NEXT)),
            Read(L("tail_v", V_INDEX)),        # volatile reads only
            Read(L("tail_v", V_PPTR)),
            Write(L("new_p", P_PRED), ("sym", "tail_p")),
            Write(L("new_p", P_INDEX), ("idx",)),     # index LAST
            Write(L("new_v", V_INDEX), ("idx",)),
            Write(L("new_v", V_PREDV), ("sym", "tail_v")),
            Cas(L("tail_v", V_NEXT), ("sym", "new_v"), event="enq"),
            # backward flush walk over the volatile chain: own pnode, then
            # stop at the durable tail (flush reads nothing back)
            Read(L("new_v", V_PPTR)),
            Flush(L("new_p")),
            Read(L("new_v", V_PREDV)),
            Read(L("tail_v", V_PPTR)),
            # per-thread record: penultimate BEFORE last (crash-prefix
            # safety), all movnti -- never read on the fast path
            Movnti(L("LASTENQ", R_PEN_PTR, per_tid=True),
                   ("slot", "_last", 0)),
            Movnti(L("LASTENQ", R_PEN_IDX, per_tid=True),
                   ("slot", "_last", 1)),
            Movnti(L("LASTENQ", R_LAST_PTR, per_tid=True), ("sym", "new_p")),
            Movnti(L("LASTENQ", R_LAST_IDX, per_tid=True), ("idx",)),
            Fence(),                            # the ONE fence
            PersistedAdd("new_p"),
            SlotSet("_last", ("tup", ("sym", "new_p"), ("idx",))),
            Cas(L("TAIL"), ("sym", "new_v"), root=True),
        ), guards=(("tail_persisted",),), retry_from=9)
        deq = OpSchedule("deq", steps=(
            Read(L("HEAD")),
            Read(L("head_v", V_NEXT)),
            Read(L("TAIL")),                    # MSQ guard
            Read(L("next_v", V_ITEM)),
            Read(L("next_v", V_INDEX)),
            Cas(L("HEAD"), ("sym", "next_v"), root=True, event="deq"),
            Movnti(L("HEADIDX", per_tid=True), ("idx",)),
            Fence(),                            # the ONE fence
            Read(L("head_v", V_PPTR)),
            Retire(("sym", "head_p")),
            RetireV(("sym", "head_v")),
        ))
        return QueueSchedules(enq=enq, deq=deq, layout=FifoLayout(
            head_root="HEAD", next_off=V_NEXT, item_off=V_ITEM,
            idx_off=V_INDEX, pptr_off=V_PPTR, volatile=True))

    # --------------------------------------------------------------- enqueue
    def enqueue(self, tid: int, item: Any) -> None:
        nv = self.nvram
        self.mem.op_begin(tid)
        pnode = self.mem.alloc(tid)
        # evict recycled addresses from the durable-hint set at *alloc* time
        # (see linked.py: bounds the backward walk to pending enqueues)
        self._persisted.discard(pnode)
        nv.write_full_line(pnode, [item, 0, NULL, 0, 0, 0, 0, 0])
        vnode = self._new_vnode(tid, item, 0, pnode, NULL)
        while True:
            tailv = nv.read(self.TAIL)
            if nv.read(tailv + V_NEXT) == NULL:
                idx = nv.read(tailv + V_INDEX) + 1       # volatile read
                predp = nv.read(tailv + V_PPTR)          # volatile read
                nv.write(pnode + P_PRED, predp)
                nv.write(pnode + P_INDEX, idx)           # index LAST
                nv.write(vnode + V_INDEX, idx)
                nv.write(vnode + V_PREDV, tailv)
                if nv.cas(tailv + V_NEXT, NULL, vnode):
                    self._ev("enq", item)
                    # backward flush walk over the volatile chain, flushing
                    # Persistent halves only (flush reads nothing back).
                    walked = []
                    pv = vnode
                    while pv != NULL:
                        pp = nv.read(pv + V_PPTR)
                        if pp in self._persisted:
                            break
                        self.pflush(pp)
                        walked.append(pp)
                        pv = nv.read(pv + V_PREDV)
                    self._write_record(tid, self._last[tid], (pnode, idx))
                    self.pfence()                           # the ONE fence
                    self._persisted.update(walked)
                    self._last[tid] = (pnode, idx)
                    nv.cas(self.TAIL, tailv, vnode)
                    return
            else:
                nv.cas(self.TAIL, tailv, nv.read(tailv + V_NEXT))

    # --------------------------------------------------------------- dequeue
    def dequeue(self, tid: int) -> Any:
        nv = self.nvram
        self.mem.op_begin(tid)
        while True:
            headv = nv.read(self.HEAD)
            nxt = nv.read(headv + V_NEXT)
            if nxt == NULL:
                idx = nv.read(headv + V_INDEX)
                nv.movnti(self.HEADIDX + tid * LINE_WORDS, idx)
                self.pfence()
                self._ev("empty")
                return None
            # MSQ guard: head must not overtake tail (reclamation safety)
            tailv = nv.read(self.TAIL)
            if headv == tailv:
                nv.cas(self.TAIL, tailv, nxt)
                continue
            item = nv.read(nxt + V_ITEM)
            idx = nv.read(nxt + V_INDEX)
            if nv.cas(self.HEAD, headv, nxt):
                self._ev("deq", item)
                nv.movnti(self.HEADIDX + tid * LINE_WORDS, idx)
                self.pfence()                               # the ONE fence
                pp = nv.read(headv + V_PPTR)
                self.mem.retire(tid, pp)
                self.mem.retire_volatile(tid, headv)
                return item

    # -------------------------------------------------------------- recovery
    @classmethod
    def recover(cls, nvram: NVRAM, mem: SSMem, nthreads: int, roots,
                on_event=None) -> "OptLinkedQueue":
        q = cls(nvram, mem, nthreads, on_event, _recovering=True, roots=roots)
        nv = nvram
        head_idx = max((nv.pread(q.HEADIDX + t * LINE_WORDS) or 0)
                       for t in range(nthreads))
        # gather candidates: two records per thread + the recovery slot
        cands: List[Tuple[int, int]] = []
        for slot in range(nthreads + 1):
            base = q.LASTENQ + slot * LINE_WORDS
            for (p_off, i_off) in ((R_LAST_PTR, R_LAST_IDX),
                                   (R_PEN_PTR, R_PEN_IDX)):
                ptr = nv.pread(base + p_off) or NULL
                idx = nv.pread(base + i_off) or 0
                if ptr != NULL and idx > head_idx:
                    cands.append((idx, ptr))
        cands.sort(reverse=True)
        chain: List[Tuple[int, int]] = []   # ascending (idx, pnode)
        for (idx, ptr) in cands:
            if nv.pread(ptr + P_INDEX) != idx:
                continue                     # stale node -- next candidate
            walk = [(idx, ptr)]
            cur, curidx, ok = ptr, idx, True
            while curidx > head_idx + 1:
                prev = nv.pread(cur + P_PRED) or NULL
                if prev == NULL or nv.pread(prev + P_INDEX) != curidx - 1:
                    ok = False               # nonconsecutive => stale
                    break
                curidx -= 1
                cur = prev
                walk.append((curidx, cur))
            if ok:
                chain = list(reversed(walk))
                break
        live = {p for (_, p) in chain}
        free = []
        for base, nnodes in mem.area_addrs():
            for i in range(nnodes):
                a = base + i * LINE_WORDS
                if a not in live:
                    free.append(a)
        # dummy Persistent at head_idx
        dummy_p = free.pop() if free else mem.alloc(0)
        nv.pwrite(dummy_p + P_ITEM, None)
        nv.pwrite(dummy_p + P_INDEX, head_idx)
        nv.pwrite(dummy_p + P_PRED, NULL)
        q._persisted.add(dummy_p)
        dummy_v = q._new_vnode(0, None, head_idx, dummy_p, NULL)
        nv.write(q.HEAD, dummy_v)
        prevv = dummy_v
        for (idx, p) in chain:
            v = q._new_vnode(0, nv.pread(p + P_ITEM), idx, p, prevv)
            nv.write(prevv + V_NEXT, v)
            q._persisted.add(p)
            prevv = v
        nv.write(q.TAIL, prevv)
        # reset records: stale slots cleared; the recovery slot republishes
        # the recovered tail as the durable candidate for a future crash.
        for t in range(nthreads):
            base = q.LASTENQ + t * LINE_WORDS
            for off in range(4):
                nv.pwrite(base + off, NULL if off % 2 == 0 else 0)
        rbase = q.LASTENQ + nthreads * LINE_WORDS
        if chain:
            tail_idx, tail_p = chain[-1]
            nv.pwrite(rbase + R_PEN_PTR, tail_p)
            nv.pwrite(rbase + R_PEN_IDX, tail_idx)
            nv.pwrite(rbase + R_LAST_PTR, tail_p)
            nv.pwrite(rbase + R_LAST_IDX, tail_idx)
        else:
            for off in range(4):
                nv.pwrite(rbase + off, NULL if off % 2 == 0 else 0)
        for a in free:
            mem.free_now(0, a)
        nvram.reset_after_recovery()
        return q
