"""IzraelevitzQ / NVTraverseQ -- general-transform baselines (paper §10).

Izraelevitz et al. (DISC'16): any linearizable lock-free object becomes
durably linearizable by persisting (flush + fence) after **every** access to
shared memory -- writes, CASes *and reads*.  Applied to MSQ this yields a
correct but fence-heavy queue; it is the paper's "many fences" baseline.

NVTraverseQ (Friedman et al., PLDI'20) is the same here except that flushes
issued after *reads and CASes* are not followed by their own fence (the next
update's fence covers them), since MSQ has an empty traversal phase.
"""
from __future__ import annotations

from typing import Any

from .nvram import LINE_WORDS, NVRAM
from .opsched import (AllocP, Cas, Fence, FifoLayout, Flush, L, OpSchedule,
                      QueueSchedules, Read, Retire, WriteLine)
from .queue_base import NULL, QueueAlgorithm, alloc_root_lines
from .ssmem import SSMem

ITEM, NEXT = 0, 1


class IzraelevitzQueue(QueueAlgorithm):
    NAME = "IzraelevitzQ"
    FENCE_AFTER_READ = True

    def __init__(self, nvram: NVRAM, mem: SSMem, nthreads: int, on_event=None,
                 _recovering: bool = False, roots=None):
        super().__init__(nvram, mem, nthreads, on_event)
        nv = self.nvram
        if roots is None:
            roots = alloc_root_lines(nv, 2, "izrq:roots")
        self.HEAD, self.TAIL = roots
        self.roots = roots
        if not _recovering:
            dummy = self.mem.alloc(0)
            nv.write_full_line(dummy, [None, NULL, 0, 0, 0, 0, 0, 0])
            nv.write(self.HEAD, dummy)
            nv.write(self.TAIL, dummy)
            self.pflush(dummy)
            self.pflush(self.HEAD)
            self.pflush(self.TAIL)
            self.pfence()

    # ---------------------------------------- steady-state schedule facts
    # The transform persists after EVERY shared access, so a retry replays
    # flush(+fence) per re-read and re-touches the lines those very
    # flushes invalidated -- the fence-heavy baseline is also the
    # retry-heavy one.  NVTraverseQ overrides this with the read/CAS-fail
    # fences elided (FENCE_AFTER_READ=False), mirroring the fast path.
    # Expected counts fit against the exact scheduler (a re-read is
    # post-flush only when no co-scheduled op re-fetched the line first).
    RETRY_SHAPES = {
        "enq": dict(flushed_reads=1.6, flushes=3, fences=3),
        "deq": dict(flushed_reads=3.2, flushes=5, fences=5),
    }

    def op_schedule(self):
        """Steady state: the general transform's persist-per-access
        schedule applied to MSQ (read/CAS-fail fences present iff
        ``FENCE_AFTER_READ``)."""
        far = self.FENCE_AFTER_READ

        def pread(loc):       # _pread: read + flush (+ fence)
            return (Read(loc), Flush(loc)) + ((Fence(),) if far else ())

        enq = OpSchedule("enq", steps=(
            AllocP(),
            WriteLine(L("new_p"), (None, NULL, 0, 0, 0, 0, 0, 0), item_at=0),
            Flush(L("new_p")), Fence(),
        ) + pread(L("TAIL")) + pread(L("tail_p", NEXT)) + (
            Cas(L("tail_p", NEXT), ("sym", "new_p"), event="enq"),
            Flush(L("tail_p", NEXT)), Fence(),
            Cas(L("TAIL"), ("sym", "new_p"), root=True),
            Flush(L("TAIL")), Fence(),
        ), retry_from=4)
        deq = OpSchedule("deq", steps=(
            pread(L("HEAD")) + pread(L("head_p", NEXT))
            + pread(L("TAIL")) + pread(L("next_p", ITEM)) + (
                Cas(L("HEAD"), ("sym", "next_p"), root=True, event="deq"),
                Flush(L("HEAD")), Fence(),
                Retire(("sym", "head_p")),
            )))
        return QueueSchedules(enq=enq, deq=deq, layout=FifoLayout(
            head_root="HEAD", next_off=NEXT, item_off=ITEM))

    # -- transformed accessors ---------------------------------------------
    def _pread(self, addr: int) -> Any:
        v = self.nvram.read(addr)
        self.pflush(addr)
        if self.FENCE_AFTER_READ:
            self.pfence()
        return v

    def _pwrite(self, addr: int, v: Any) -> None:
        self.nvram.write(addr, v)
        self.pflush(addr)
        self.pfence()

    def _pcas(self, addr: int, exp: Any, new: Any, ev=None) -> bool:
        ok = self.nvram.cas(addr, exp, new)
        if ok and ev is not None:
            self._ev(*ev)    # event exactly at the linearizing CAS
        self.pflush(addr)
        if self.FENCE_AFTER_READ or ok:
            self.pfence()
        return ok

    # ------------------------------------------------------------------ ops
    def enqueue(self, tid: int, item: Any) -> None:
        nv = self.nvram
        self.mem.op_begin(tid)
        node = self.mem.alloc(tid)
        nv.write_full_line(node, [item, NULL, 0, 0, 0, 0, 0, 0])
        self.pflush(node)
        self.pfence()
        while True:
            tail = self._pread(self.TAIL)
            nxt = self._pread(tail + NEXT)
            if nxt == NULL:
                if self._pcas(tail + NEXT, NULL, node, ev=("enq", item)):
                    self._pcas(self.TAIL, tail, node)
                    return
            else:
                self._pcas(self.TAIL, tail, nxt)

    def dequeue(self, tid: int) -> Any:
        self.mem.op_begin(tid)
        while True:
            head = self._pread(self.HEAD)
            nxt = self._pread(head + NEXT)
            if nxt == NULL:
                self._ev("empty")
                return None
            # MSQ guard: head must not overtake tail (reclamation safety)
            tail = self._pread(self.TAIL)
            if head == tail:
                self._pcas(self.TAIL, tail, nxt)
                continue
            item = self._pread(nxt + ITEM)
            if self._pcas(self.HEAD, head, nxt, ev=("deq", item)):
                self.mem.retire(tid, head)
                return item

    @classmethod
    def recover(cls, nvram: NVRAM, mem: SSMem, nthreads: int, roots,
                on_event=None):
        q = cls(nvram, mem, nthreads, on_event, _recovering=True, roots=roots)
        head = nvram.pread(q.HEAD) or NULL
        cur = head
        chain = {head}
        while True:
            nxt = nvram.pread(cur + NEXT) or NULL
            if nxt == NULL:
                break
            cur = nxt
            chain.add(cur)
        nvram.pwrite(q.TAIL, cur)
        for base, nnodes in mem.area_addrs():
            for i in range(nnodes):
                a = base + i * LINE_WORDS
                if a not in chain:
                    mem.free_now(0, a)
        nvram.reset_after_recovery()
        return q


class NVTraverseQueue(IzraelevitzQueue):
    NAME = "NVTraverseQ"
    FENCE_AFTER_READ = False

    RETRY_SHAPES = {
        "enq": dict(flushed_reads=2.5, flushes=3, weight=0.8),
        "deq": dict(flushed_reads=4, flushes=5, weight=0.8),
    }
