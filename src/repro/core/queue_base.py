"""Shared scaffolding for the durable queue implementations.

All queues expose the same interface::

    q = SomeQueue(nvram, mem, nthreads, on_event=cb)   # fresh, persisted init
    q.enqueue(tid, item)
    item = q.dequeue(tid)          # None == failing dequeue (empty)
    q2 = SomeQueue.recover(nvram, mem, nthreads, roots, on_event=cb)

``on_event`` receives volatile-linearization events -- ``("enq", item)`` at
the successful link CAS and ``("deq", item)`` at the successful head CAS --
which the harness uses for durable-linearizability checking (the scheduler
serializes primitives, so event order == linearization order).

NULL pointers are address 0 (reserved in the simulator).
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Optional

from .contention import RetryProfile
from .nvram import LINE_WORDS, NVRAM
from .ssmem import SSMem

NULL = 0
Event = Callable[[tuple], None]


class QueueAlgorithm:
    """Base class; concrete queues define NAME and the three operations."""

    NAME = "abstract"

    def __init__(self, nvram: NVRAM, mem: SSMem, nthreads: int,
                 on_event: Optional[Event] = None):
        self.nvram = nvram
        self.mem = mem
        self.nthreads = nthreads
        self.on_event = on_event or (lambda ev: None)

    # -- helpers ------------------------------------------------------------
    def _ev(self, *ev: Any) -> None:
        self.on_event(tuple(ev))

    # -- model-aware persist primitives -------------------------------------
    # All queues route their persistence path through these so the memory
    # model can elide work the platform does not need: under eADR
    # (persist-on-store) CLWB instructions are unnecessary and a tuned
    # implementation simply would not issue them.
    def pflush(self, addr: int) -> None:
        """Flush `addr`'s line iff the platform requires explicit flushes."""
        if self.nvram.model.needs_flush:
            self.nvram.flush(addr)

    def pfence(self) -> None:
        """Persist barrier (SFENCE); always issued -- it orders stores even
        on platforms where it no longer drains flush queues."""
        self.nvram.fence()

    def persist(self, addr: int) -> None:
        """flush + fence ('persisting a location'), model-aware."""
        self.pflush(addr)
        self.pfence()

    # -- contention contract -------------------------------------------------
    def retry_profile(self) -> Dict[str, RetryProfile]:
        """Per-op-kind shape of ONE failed CAS round, for the batched path.

        Concrete queues return ``{'enq': RetryProfile(...), 'deq': ...}``
        describing which root word each kind's linearizing CAS targets and
        the event codes a retry replays -- cached re-reads, re-reads of
        *flushed* content (the post-flush cost a retry re-incurs), and any
        helping-path flush/fence work.  The batched scheduler's
        :class:`repro.core.contention.ContentionModel` charges these per
        modeled CAS failure; the exact scheduler ignores them (its retries
        execute for real).  An empty dict (the default) opts the queue out
        of contention modeling entirely.
        """
        return {}

    def enqueue(self, tid: int, item: Any) -> None:
        raise NotImplementedError

    def dequeue(self, tid: int) -> Any:
        raise NotImplementedError

    def drain(self, tid: int = 0) -> list:
        """Dequeue until empty (testing helper)."""
        out = []
        while True:
            it = self.dequeue(tid)
            if it is None:
                return out
            out.append(it)


def alloc_root_lines(nvram: NVRAM, n: int, name: str, persistent: bool = True) -> list:
    """n root words, each on its own cache line (no false sharing)."""
    base = nvram.alloc_region(n * LINE_WORDS, name=name, persistent=persistent)
    return [base + i * LINE_WORDS for i in range(n)]
