"""Shared scaffolding for the durable queue implementations.

All queues expose the same interface::

    q = SomeQueue(nvram, mem, nthreads, on_event=cb)   # fresh, persisted init
    q.enqueue(tid, item)
    item = q.dequeue(tid)          # None == failing dequeue (empty)
    q2 = SomeQueue.recover(nvram, mem, nthreads, roots, on_event=cb)

``on_event`` receives volatile-linearization events -- ``("enq", item)`` at
the successful link CAS and ``("deq", item)`` at the successful head CAS --
which the harness uses for durable-linearizability checking (the scheduler
serializes primitives, so event order == linearization order).

NULL pointers are address 0 (reserved in the simulator).
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Optional

from .contention import RetryProfile
from .nvram import LINE_WORDS, NVRAM
from .opsched import (QueueSchedules, linearizing_root,
                      retry_touches_persistent)
from .ssmem import SSMem

NULL = 0
Event = Callable[[tuple], None]


class QueueAlgorithm:
    """Base class; concrete queues define NAME and the three operations."""

    NAME = "abstract"

    def __init__(self, nvram: NVRAM, mem: SSMem, nthreads: int,
                 on_event: Optional[Event] = None):
        self.nvram = nvram
        self.mem = mem
        self.nthreads = nthreads
        self.on_event = on_event or (lambda ev: None)

    # -- helpers ------------------------------------------------------------
    def _ev(self, *ev: Any) -> None:
        self.on_event(tuple(ev))

    # -- model-aware persist primitives -------------------------------------
    # All queues route their persistence path through these so the memory
    # model can elide work the platform does not need: under eADR
    # (persist-on-store) CLWB instructions are unnecessary and a tuned
    # implementation simply would not issue them.
    def pflush(self, addr: int) -> None:
        """Flush `addr`'s line iff the platform requires explicit flushes."""
        if self.nvram.model.needs_flush:
            self.nvram.flush(addr)

    def pfence(self) -> None:
        """Persist barrier (SFENCE); always issued -- it orders stores even
        on platforms where it no longer drains flush queues."""
        self.nvram.fence()

    def persist(self, addr: int) -> None:
        """flush + fence ('persisting a location'), model-aware."""
        self.pflush(addr)
        self.pfence()

    # -- steady-state schedule contract --------------------------------------
    # Class-level per-round retry shapes (see RetryProfile): numeric facts
    # only -- the contended root *addresses* are instance-specific and come
    # from op_schedule()'s root-marked CAS, so the numbers stay declarative.
    RETRY_SHAPES: Dict[str, Dict[str, float]] = {}

    def op_schedule(self) -> Optional[QueueSchedules]:
        """The queue's steady-state ops as typed primitive programs.

        Concrete queues return a :class:`repro.core.opsched.QueueSchedules`
        describing the exact reads, writes, CAS, model-aware pflush/pfence,
        movnti and allocator interactions of one successful steady-state
        enqueue and dequeue -- the same facts :meth:`retry_profile` and the
        B2 persist-count tables assert, as one source of truth.  Three
        consumers:

        * the batched scheduler's fast path compiles and replays it
          (:mod:`repro.core.opsched`), bailing to real execution for
          anything the program does not cover;
        * the contention layer locates each kind's CAS root and checks
          whether a retry can touch flushed content at all;
        * the equivalence suite pins the compiled replay bit-identical to
          per-op execution on every memory model.

        ``None`` (the default) opts the queue out of the fast path.
        """
        return None

    # -- contention contract -------------------------------------------------
    def retry_profile(self) -> Dict[str, RetryProfile]:
        """Per-op-kind shape of ONE failed CAS round, for the batched path.

        Returns ``{'enq': RetryProfile(...), 'deq': ...}``: which root word
        each kind's tracked CAS targets and the event codes a retry round
        replays -- cached re-reads, re-reads of *flushed* content (the
        post-flush cost a retry re-incurs), and any helping-path
        flush/fence work.  The batched scheduler's
        :class:`repro.core.contention.ContentionModel` charges these per
        modeled CAS failure; the exact scheduler ignores them (its retries
        execute for real).

        The default implementation combines the class-level
        ``RETRY_SHAPES`` numbers with root addresses resolved from
        :meth:`op_schedule` (the schedule's ``root=True`` CAS), so queues
        declare per-round costs once and never repeat address facts.  An
        empty dict (no shapes, no schedule) opts the queue out of
        contention modeling entirely.
        """
        scheds = self.op_schedule()
        if not self.RETRY_SHAPES or scheds is None:
            return {}
        return {
            kind: RetryProfile(
                root=linearizing_root(self, scheds.of_kind(kind)), **shape)
            for kind, shape in self.RETRY_SHAPES.items()
        }

    def schedule_facts(self) -> Dict[str, Dict[str, Any]]:
        """Contention-relevant facts derived from :meth:`op_schedule`:
        per op kind, the tracked root CAS address and whether a failed-CAS
        retry can touch persistent (flushable) content at all.  The
        :class:`repro.core.contention.ContentionModel` grounds every
        profile (hand-fit or learned) in these instead of trusting
        hand-maintained tables."""
        scheds = self.op_schedule()
        if scheds is None:
            return {}
        return {
            sched.kind: {
                "root": linearizing_root(self, sched),
                "flushable_retry": retry_touches_persistent(self, sched),
            }
            for sched in scheds
        }

    def enqueue(self, tid: int, item: Any) -> None:
        raise NotImplementedError

    def dequeue(self, tid: int) -> Any:
        raise NotImplementedError

    def drain(self, tid: int = 0) -> list:
        """Dequeue until empty (testing helper)."""
        out = []
        while True:
            it = self.dequeue(tid)
            if it is None:
                return out
            out.append(it)


def alloc_root_lines(nvram: NVRAM, n: int, name: str, persistent: bool = True) -> list:
    """n root words, each on its own cache line (no false sharing)."""
    base = nvram.alloc_region(n * LINE_WORDS, name=name, persistent=persistent)
    return [base + i * LINE_WORDS for i in range(n)]
