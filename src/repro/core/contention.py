"""Contention model for the batched (clock-driven) scheduler path.

The exact OS-thread :class:`repro.core.scheduler.Scheduler` interleaves at
primitive granularity, so CAS races at the queue roots *actually happen*
there: a thread reads the tail, another thread links first, the CAS fails,
and the loser retries -- re-reading content the winner just flushed (the
paper's post-flush penalty) and, in the helping designs, persisting the
obstructing link before advancing the tail.  The batched
:class:`repro.core.scheduler.ClockScheduler` runs each operation to
completion inline, so no CAS ever fails and multi-thread sweeps model zero
contention -- understating exactly the flushed-access gap the Second
Amendment targets.

This module closes that gap *above* the cost accumulator: it never changes
how a primitive is accounted (the single-thread differential oracle stays
bit-identical); it only appends extra, pre-classified event codes for the
retries a real interleaving would have executed.

Model
-----
The batched executor pops threads in simulated-clock order, so operation
start times are globally non-decreasing.  An operation that starts at
``t_start`` is *co-scheduled* with every earlier operation whose interval is
still open (``t_end > t_start``) -- that set is the clock window.  Each
queue declares, per op kind, a :class:`RetryProfile`: which root word the
op's linearizing CAS targets (head or tail) and which event codes one failed
CAS round replays (cached re-reads, re-reads of *flushed* content,
helping-path flushes/fences, the failed CAS itself).

For an op whose profile targets root ``w``, let ``k`` be the number of
co-scheduled ops of *other* threads whose traced CASes hit ``w`` (the engine
tags CAS target words; a delta of the per-word CAS count over the op tells
which roots it really hit -- a failing dequeue that never CASes charges
nothing).  The op's CAS **failure probability** at ``w`` is

    ``p = min(retry_scale * profile.weight * k, P_CAP)``

-- under the exact scheduler's uniform interleaving, each co-scheduled
conflicting op lands its linearizing CAS inside this op's read-to-CAS race
window with a roughly constant probability (the window's fraction of the
op), so ``p`` grows linearly in ``k`` until it saturates.  Each failed
round re-opens the window, so retry rounds are geometric and the expected
count is ``E = p / (1 - p)`` -- near zero at 2 threads, steep by 8, exactly
the shape the exact scheduler exhibits.  Expected event counts (``E`` times
the profile's per-round counts, which may themselves be fractional) accrue
in deterministic per-(thread, kind, unit) fractional accumulators (no RNG
-- the batched schedule stays reproducible) and are emitted as whole
events via :meth:`repro.core.nvram.NVRAM.charge_events`, which also
advances the thread's clock so contention feeds back into the schedule
itself.

Staleness is bounded by the engine's per-line access *epochs* (the
scheduler ticks ``NVRAM.epoch`` once per executed op; while a model is
attached -- ``NVRAM.contention_tracking`` -- every touch stamps its line):
each in-flight entry records the root line's ``NVRAM.line_epoch`` at the
time of its CAS, and an entry older than ``window_ops`` epochs is dropped
even if a laggard clock keeps its interval open.

Calibration: ``tests/test_contention_calibration.py`` pins this model
against exact-scheduler ground truth (2--8 threads, all seven queues) on
persist-instruction and flushed-access totals; the default ``retry_scale``
is fit there.  ``retry_scale=0`` (or one thread) reproduces the uncontended
counts exactly -- the property suite asserts bit-equality.
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Any, Dict, List, Mapping, Optional, Tuple, Union

from .memmodel import MemoryModel
from .nvram import (EV_CAS, EV_FENCE, EV_FENCE_LINE, EV_FLUSH, EV_HIT,
                    EV_POSTFLUSH, EV_READ, LINE_WORDS)

# Per-round CAS failure probability contributed by ONE co-scheduled
# conflicting op.  Fit against the exact scheduler (see
# tests/test_contention_calibration.py): across all seven queues the
# read->CAS race window is a similar fraction of an operation, ~0.2.
DEFAULT_RETRY_SCALE = 0.2

# Saturation for the failure probability: E = p/(1-p) must stay finite when
# many threads hammer one root (at P_CAP=0.85 an op retries ~5.7x).
P_CAP = 0.85


@dataclass(frozen=True)
class RetryProfile:
    """Event-code shape of ONE failed CAS round for one op kind.

    Queues return these from :meth:`QueueAlgorithm.retry_profile`.  The
    fields are symbolic -- :class:`ContentionModel` resolves them against
    the active :class:`repro.core.memmodel.MemoryModel` (e.g. a
    ``flushed_reads`` re-read is a post-flush access only under an
    invalidating-flush platform; helping flushes are elided under eADR,
    exactly as :meth:`QueueAlgorithm.pflush` would elide them).
    """

    root: int                 # contended root word (HEAD/TAIL address)
    reads: float = 0.0        # re-reads of still-cached content (hits)
    flushed_reads: float = 0.0  # re-reads of content the algorithm flushes
    cas: float = 1.0          # CAS rounds replayed (the failed attempt)
    flushes: float = 0.0      # helping-path flushes (persist the obstruction)
    fences: float = 0.0       # helping-path fences
    weight: float = 1.0       # race-window fraction relative to the ~0.2 norm
    # Contention decay of the post-flush fraction: a retry's re-read pays
    # the post-flush fetch only if no co-scheduled op re-fetched the
    # invalidated line first, so the effective per-round count shrinks as
    # the window widens.  Two forms:
    #   * a scalar d (the inert hand-profile default 0.0): the parametric
    #     shape flushed_reads / (1 + d * k);
    #   * a tuple shape s: a per-window-size table -- the round's count is
    #     flushed_reads * s[min(k, len(s)) - 1] for window size k >= 1 --
    #     measured directly per traced thread count by the trace fit
    #     (repro.trace.fit), which captures the faster-than-1/(1+dk)
    #     decay the exact scheduler shows at 12-16 threads.
    flushed_decay: Union[float, Tuple[float, ...]] = 0.0
    # Saturation of the expected failed rounds per op.  The geometric
    # E = p/(1-p) caps at P_CAP/(1-P_CAP) (~5.7) once many threads hammer
    # one root, but the exact scheduler saturates lower and per-queue
    # (helping drains the obstruction; the root CAS serializes).  The
    # default keeps the hand-profile behavior; the trace fit measures it.
    max_rounds: float = P_CAP / (1.0 - P_CAP)

    def flushed_scale(self, k: int) -> float:
        """Multiplier on the per-round flushed-read count at window size
        ``k`` (>= 1): the parametric 1/(1+d*k) for a scalar decay, the
        measured per-k table entry for a tuple shape."""
        d = self.flushed_decay
        if isinstance(d, tuple):
            if not d:
                return 1.0
            return d[min(k, len(d)) - 1]
        if d > 0:
            return 1.0 / (1.0 + d * k)
        return 1.0

    def event_units(self, model: MemoryModel
                    ) -> List[Tuple[Tuple[int, ...], float, bool]]:
        """(code-sequence, expected-count, decays) units for one retry round.

        Counts are *expected values per failed round* (a retry takes the
        DurableMSQ helping path only some of the time; a re-read lands on a
        still-invalidated line only when no other op re-fetched it first),
        so they are floats -- the model accrues each unit in a deterministic
        fractional accumulator and emits whole events.  ``decays`` marks
        the flushed-read unit, whose count the model additionally scales by
        ``1 / (1 + flushed_decay * k)`` at charge time.
        """
        # Re-touching a line the algorithm just flushed: the paper's
        # post-flush access under invalidating CLWB; an ordinary hit when
        # flushes retain the line (CXL) or are never issued (eADR).
        flushed_touch = (EV_POSTFLUSH if model.flush_invalidates else EV_HIT)
        units = [
            ((EV_READ, EV_HIT), self.reads, False),
            ((EV_READ, flushed_touch), self.flushed_reads, True),
            ((EV_CAS, EV_HIT), self.cas, False),
        ]
        if model.needs_flush:
            units.append(((EV_FLUSH,), self.flushes, False))
            fence_codes = ((EV_FENCE, EV_FENCE_LINE) if self.flushes
                           else (EV_FENCE,))
            units.append((fence_codes, self.fences, False))
        else:
            # eADR: helping degenerates to the ordering barrier alone
            units.append(((EV_FENCE,), self.fences, False))
        return [(codes, n, dec) for codes, n, dec in units if n > 0]


# RetryProfile numeric fields a learned profile may override (root stays
# instance-bound: addresses are allocation-order specific)
_LEARNED_FIELDS = ("reads", "flushed_reads", "cas", "flushes", "fences",
                   "weight", "flushed_decay", "max_rounds")


@dataclass(frozen=True)
class LearnedRetryProfile:
    """Per-queue retry-profile numbers measured from exact-scheduler traces.

    Produced by :mod:`repro.trace.fit` (least-squares per-round event
    counts + a race-window weight matched to observed CAS failures) and
    consumed here: pass one to :class:`ContentionModel` and
    :meth:`ContentionModel.begin_run` *binds* it against the queue's own
    :meth:`repro.core.queue_base.QueueAlgorithm.retry_profile` -- the
    declared profiles contribute only their ``root`` addresses (which are
    allocation-specific), every numeric field comes from the measurement.

    ``params`` maps op kind -> field -> value for the fields
    ``reads / flushed_reads / cas / flushes / fences / weight``;
    ``source`` carries fit provenance (thread counts, ops, residuals).
    """

    queue: str
    params: Mapping[str, Mapping[str, float]]
    source: Mapping[str, Any] = field(default_factory=dict)

    def bind(self, declared: Dict[str, RetryProfile]
             ) -> Dict[str, RetryProfile]:
        """Graft learned numbers onto the queue's declared roots."""
        def _coerce(f, v):
            # flushed_decay may be a measured per-window-size shape
            # (serialized as a list); everything else is scalar
            if f == "flushed_decay" and isinstance(v, (list, tuple)):
                return tuple(float(x) for x in v)
            return float(v)

        out: Dict[str, RetryProfile] = {}
        for kind, prof in declared.items():
            p = self.params.get(kind)
            if p is None:
                out[kind] = prof      # kind the fit never observed
                continue
            out[kind] = RetryProfile(
                root=prof.root,
                **{f: _coerce(f, p.get(f, getattr(prof, f)))
                   for f in _LEARNED_FIELDS})
        return out


class ContentionModel:
    """Charges CAS-retry costs for co-scheduled ops in the batched path.

    One instance drives one :meth:`QueueHarness.run_batched` call; pass it
    via the harness (``run_batched(plans, contention=ContentionModel())``)
    or let the harness construct the default.  See the module docstring for
    the model; the public knobs:

    ``retry_scale``
        Per-round CAS failure probability contributed by one co-scheduled
        conflicting op (scaled by the profile's ``weight``; 0 disables
        charging entirely -- bit-identical to uncontended).
    ``window_ops``
        Epoch width of the co-schedule window; entries older than this many
        executed ops are dropped regardless of clock overlap.  ``None``
        (default) sizes it to the thread count at :meth:`begin_run`.
    ``profiles``
        An optional :class:`LearnedRetryProfile` (from
        :mod:`repro.trace.fit`): at :meth:`begin_run` its measured numbers
        are bound onto the queue-declared roots, replacing the hand-fit
        per-round counts and weights.
    """

    def __init__(self, retry_scale: float = DEFAULT_RETRY_SCALE,
                 window_ops: Optional[int] = None,
                 profiles: Optional[LearnedRetryProfile] = None):
        if retry_scale < 0:
            raise ValueError("retry_scale must be >= 0")
        self.retry_scale = retry_scale
        self.learned = profiles
        self.window_ops = window_ops
        self._window_ops_fixed = window_ops is not None
        self.retries_charged = 0.0    # sum of expected failed rounds
        self.ops_seen = 0
        self.retries_by_root: Dict[int, float] = {}
        self._nv = None
        self._profiles: Dict[str, RetryProfile] = {}
        self._units: Dict[str, List[Tuple[Tuple[int, ...], float]]] = {}
        self._roots: List[int] = []
        self._last_cas_count: Dict[int, int] = {}
        # per root: open intervals of ops that CASed it: (end_ns, tid, epoch)
        self._inflight: Dict[int, List[Tuple[float, int, int]]] = {}
        # deterministic fractional accumulators, one per (tid, kind, unit)
        self._frac: Dict[Tuple[int, str, int], float] = {}

    # ------------------------------------------------------------ lifecycle
    def begin_run(self, nvram, profiles: Dict[str, RetryProfile],
                  schedules=None) -> None:
        """Bind to an engine + the queue's retry profiles for one run.

        ``schedules`` (the queue's :meth:`repro.core.queue_base.
        QueueAlgorithm.schedule_facts`) grounds the profiles in the
        queue's declared op schedule instead of hand-maintained tables:
        each kind's tracked root address comes from the schedule's root
        CAS, and a kind whose retry loop provably touches no persistent
        line gets its ``flushed_reads`` zeroed -- a volatile-only retry
        cannot re-incur the post-flush penalty, whatever a (learned or
        hand-fit) profile claims.
        """
        if not hasattr(nvram, "charge_events"):
            raise TypeError(
                "contention modeling needs the batched engine "
                "(repro.core.nvram.NVRAM); the reference oracle stays "
                "contention-free by design")
        self._nv = nvram
        nvram.contention_tracking = True   # enable epoch/CAS-tag bookkeeping
        self._profiles = dict(profiles or {})
        if self.learned is not None:
            self._profiles = self.learned.bind(self._profiles)
        if schedules:
            for kind, prof in list(self._profiles.items()):
                facts = schedules.get(kind)
                if facts is None:
                    continue
                changes = {}
                if prof.root != facts["root"]:
                    changes["root"] = facts["root"]
                if not facts["flushable_retry"] and prof.flushed_reads:
                    changes["flushed_reads"] = 0.0
                if changes:
                    self._profiles[kind] = replace(prof, **changes)
        self._units = {k: p.event_units(nvram.model)
                       for k, p in self._profiles.items()}
        self._roots = sorted({p.root for p in self._profiles.values()})
        self._last_cas_count = {w: nvram.cas_count(w) for w in self._roots}
        self._inflight = {w: [] for w in self._roots}
        self._frac = {}
        # reporting counters are per-run too: a reused model must not
        # contaminate its second run's retries_per_op with the first's
        self.retries_charged = 0.0
        self.ops_seen = 0
        self.retries_by_root = {}
        if not self._window_ops_fixed:
            self.window_ops = max(2, getattr(nvram, "nthreads", 2))

    # ------------------------------------------------------------- per - op
    def after_op(self, tid: int, kind: str, t_start: float) -> float:
        """Account one completed op; returns the thread's post-charge clock.

        Called by the ClockScheduler right after the op thunk ran, with the
        simulated time at which the op started (the heap key it was popped
        at).  Charges expected retries for the window, then records this
        op's own CASed roots as open intervals for successors.
        """
        nv = self._nv
        self.ops_seen += 1
        epoch = nv.epoch
        # which registered roots did this op actually CAS? (engine-tagged)
        hit_roots = []
        for w in self._roots:
            c = nv.cas_count(w)
            if c != self._last_cas_count[w]:
                self._last_cas_count[w] = c
                hit_roots.append(w)
        prof = self._profiles.get(kind)
        if prof is not None and prof.root in hit_roots \
                and self.retry_scale > 0:
            w = prof.root
            live = [(e, t, ep) for (e, t, ep) in self._inflight[w]
                    if e > t_start and epoch - ep <= self.window_ops]
            self._inflight[w] = live
            k = sum(1 for (_, t, _) in live if t != tid)
            if k:
                p = min(self.retry_scale * prof.weight * k, P_CAP)
                # geometric retry rounds, saturated at the profile's
                # (possibly trace-measured) per-op ceiling
                expected = min(p / (1.0 - p), prof.max_rounds)
                self.retries_charged += expected
                self.retries_by_root[w] = \
                    self.retries_by_root.get(w, 0.0) + expected
                for u, (codes, per_round, decays) in \
                        enumerate(self._units[kind]):
                    if decays:
                        # wider window => some other op likely re-fetched
                        # the invalidated line first; this round hits it
                        per_round = per_round * prof.flushed_scale(k)
                    key = (tid, kind, u)
                    acc = self._frac.get(key, 0.0) + expected * per_round
                    whole = int(acc)
                    self._frac[key] = acc - whole
                    if whole:
                        nv.charge_events(tid, list(codes), repeat=whole)
        t_end = nv.thread_time_ns(tid)   # includes any charged retries
        for w in hit_roots:
            lst = self._inflight[w]
            if len(lst) >= 4 * self.window_ops:   # keep windows bounded
                lst[:] = [x for x in lst
                          if x[0] > t_start and epoch - x[2] <= self.window_ops]
            # the entry's staleness anchor is the root line's access epoch,
            # stamped by this op's own CAS (engine-tracked)
            lst.append((t_end, tid, nv.line_epoch(w // LINE_WORDS)))
        return t_end

    # ------------------------------------------------------------ reporting
    def retries_per_op(self) -> float:
        return self.retries_charged / self.ops_seen if self.ops_seen else 0.0
