"""Test/benchmark harness: workloads, crash injection, durable-linearizability
checking for the queue family.

:class:`QueueHarness` owns one engine + allocator + queue instance and runs
op plans over it three ways: :meth:`QueueHarness.run_single` (sequential,
the differential-oracle path), :meth:`QueueHarness.run_scheduled` (exact
per-primitive OS-thread scheduler -- crash injection and linearizability
model checking), and :meth:`QueueHarness.run_batched` (clock-driven
op-granularity executor -- the throughput path, optionally with a
:class:`repro.core.contention.ContentionModel` charging CAS-retry/helping
costs for co-scheduled ops).  See docs/architecture.md for how the engines,
schedulers and the contention layer fit together.

The checker implements the paper's correctness criterion (§3.2, §7): a
post-crash recovered state is durably linearizable iff the history with the
crash removed is linearizable.  For a FIFO queue with uniquely-tagged items
and a serialized (scheduler-ordered) event log this reduces to:

* let L  = items in volatile-linearization (link CAS) order,
* let Ec = items whose enqueue *completed* (returned before the crash),
* let Dc = items returned by *completed* successful dequeues,
* the recovered queue R is valid iff there is a way to drop a subset of
  *pending* enqueues' items from L (completed ones may not be dropped) such
  that R equals the remaining sequence minus a removed *prefix*, where the
  removed prefix contains every item of Dc and removes a completed-enqueue
  item only if it is in Dc or its removal is attributable to a pending
  dequeue (at most |pending dequeues| such extra removals).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Tuple, Type, Union

from .contention import ContentionModel
from .memmodel import MemoryModel
from .nvram import NVRAM, Stats
from .opsched import FastPathExecutor
from .records import EventsView, OpRecord, OpsView, RecordStore
from .scheduler import ClockScheduler, Scheduler
from .ssmem import SSMem
from .queue_base import QueueAlgorithm
from .msq import MSQueue
from .durable_msq import DurableMSQueue
from .izraelevitz import IzraelevitzQueue, NVTraverseQueue
from .unlinked import UnlinkedQueue
from .linked import LinkedQueue
from .opt_unlinked import OptUnlinkedQueue
from .opt_linked import OptLinkedQueue

ALL_QUEUES: Dict[str, Type[QueueAlgorithm]] = {
    q.NAME: q for q in (MSQueue, DurableMSQueue, IzraelevitzQueue,
                        NVTraverseQueue, UnlinkedQueue, LinkedQueue,
                        OptUnlinkedQueue, OptLinkedQueue)
}
DURABLE_QUEUES = {k: v for k, v in ALL_QUEUES.items() if k != "MSQ"}


# OpRecord lives in repro.core.records (the columnar store materializes
# them on demand); importing it here keeps the historical
# ``repro.core.harness.OpRecord`` import path working.


@dataclass
class RunResult:
    crashed: bool
    ops: List[OpRecord]          # list (legacy mode) or live OpsView
    events: List[tuple]          # serialized volatile-linearization events
    stats: Stats
    ops_completed: int
    sim_time_ns: float

    def throughput_mops(self) -> float:
        if self.sim_time_ns <= 0:
            return 0.0
        return self.ops_completed / (self.sim_time_ns / 1e9) / 1e6


class QueueHarness:
    """Owns an NVRAM + SSMem + queue instance and runs workloads over it.

    ``model`` selects the persistence platform (a name from
    :data:`repro.core.memmodel.MEMORY_MODELS` or a MemoryModel instance);
    ``nvram_cls`` selects the engine -- the batched array engine
    (:class:`repro.core.nvram.NVRAM`, default) or the sequential reference
    (:class:`repro.core.nvram_ref.ReferenceNVRAM`) used as a differential
    oracle.

    ``records`` selects the op/event bookkeeping: ``"columnar"`` (default)
    routes everything through a :class:`repro.core.records.RecordStore`
    (``self.ops`` / ``self.events`` become live views over its columns;
    compiled fast-path ops stage three scalars each and materialize in
    vector bursts); ``"legacy"`` keeps the original plain Python lists of
    :class:`~repro.core.records.OpRecord` / event tuples as the
    differential reference (``tests/test_columnar_equivalence.py`` pins
    the two bit-identical).
    """

    def __init__(self, queue_cls: Type[QueueAlgorithm], nthreads: int,
                 area_nodes: int = 4096,
                 model: Union[str, MemoryModel, None] = None,
                 nvram_cls: Type = NVRAM, records: str = "columnar"):
        self.queue_cls = queue_cls
        self.nthreads = nthreads
        self.nvram = nvram_cls(nthreads, model=model)
        self.mem = SSMem(self.nvram, nthreads, area_nodes=area_nodes)
        if records == "columnar":
            self._rstore: Optional[RecordStore] = RecordStore(nthreads)
            self._ops = OpsView(self._rstore)
            self._events = EventsView(self._rstore)
        elif records == "legacy":
            self._rstore = None
            self._ops: List[OpRecord] = []
            self._events: List[tuple] = []
        else:
            raise ValueError(
                f"records must be 'columnar' or 'legacy', got {records!r}")
        self.records = records
        self.queue = queue_cls(self.nvram, self.mem, nthreads,
                               on_event=self._events.append)
        self.contention: Optional[ContentionModel] = None   # last run_batched
        self.fast: Optional[FastPathExecutor] = None        # last run_batched
        self.last_scheduler: Optional[Scheduler] = None     # last run_scheduled
        self._trace = None            # active repro.trace recorder, if any

    # ------------------------------------------------------------ record state
    @property
    def ops(self):
        """Op records: a plain list (legacy mode) or a live
        :class:`repro.core.records.OpsView` over the columnar store."""
        return self._ops

    @ops.setter
    def ops(self, value) -> None:
        if self._rstore is not None:
            self._rstore.reset_ops(value)
        else:
            self._ops = value

    @property
    def events(self):
        """Serialized events: a plain list (legacy mode) or a live
        :class:`repro.core.records.EventsView` over the columnar store."""
        return self._events

    @events.setter
    def events(self, value) -> None:
        if self._rstore is not None:
            rs = self._rstore
            rs.clear_events()
            for ev in value:
                rs.append_event(ev)
        else:
            self._events = value

    def _completed_count(self) -> int:
        if self._rstore is not None:
            return self._rstore.completed_count()
        return sum(1 for r in self._ops if r.completed)

    def record_snapshot(self):
        """Cursor snapshot of the op/event history, paired with
        :meth:`NVRAM.snapshot` at crash-sweep boundaries: ``(n_ops,
        n_events)`` in both record modes (the columnar store's cursors ARE
        its snapshot; see :meth:`repro.core.records.RecordStore.snapshot`)."""
        if self._rstore is not None:
            return self._rstore.snapshot()
        return (len(self._ops), len(self._events))

    def record_restore(self, snap) -> None:
        """Truncate the op/event history back to a :meth:`record_snapshot`
        (records only shrink: a snapshot cannot resurrect rows dropped by a
        later restore)."""
        if self._rstore is not None:
            self._rstore.restore(snap)
        else:
            n_ops, n_events = snap
            if n_ops > len(self._ops) or n_events > len(self._events):
                raise ValueError(
                    f"record_restore past live history: {snap!r} vs "
                    f"({len(self._ops)}, {len(self._events)})")
            del self._ops[n_ops:]
            del self._events[n_events:]

    # ------------------------------------------------------------- workloads
    def make_worker(self, tid: int, plan: List[Tuple[str, Any]]):
        """plan: list of ('enq', item) / ('deq', None) steps."""
        def run(_tid: int):
            for kind, item in plan:
                self._make_op(tid, kind, item)()
        return run

    def _trace_begin(self, trace, nthreads: int, seed: Optional[int],
                     scheduler: str) -> None:
        if trace is None:
            return
        trace.attach(self.nvram, meta={
            "queue": self.queue_cls.NAME, "model": self.nvram.model.name,
            "nthreads": nthreads, "seed": seed, "scheduler": scheduler})
        self._trace = trace

    def _trace_end(self, trace) -> None:
        if trace is not None:
            trace.finish(regions=self.nvram.regions)
            self._trace = None

    def run_scheduled(self, plans: List[List[Tuple[str, Any]]], seed: int = 0,
                      crash_at: Optional[int] = None,
                      policy: str = "random", trace=None,
                      snapshot_hook=None) -> RunResult:
        """Exact per-primitive OS-thread scheduler run.  ``trace`` attaches a
        :class:`repro.trace.TraceRecorder` for the duration of the run: the
        engine tap records every primitive (with scheduler step indices) and
        the harness marks op boundaries; Stats are unaffected.

        ``snapshot_hook(step)`` is forwarded to the :class:`Scheduler`: it
        fires at every quiescent boundary (see the scheduler docs) -- the
        crash sweep uses it to capture one :class:`repro.core.nvram.NVRAM`
        snapshot per step.  The scheduler itself stays reachable afterwards
        as ``self.last_scheduler`` (step totals, grant kinds)."""
        sched = Scheduler(self.nvram, seed=seed, policy=policy,
                          crash_at=crash_at, snapshot_hook=snapshot_hook)
        self.last_scheduler = sched
        workers = [self.make_worker(t, plans[t]) for t in range(len(plans))]
        self._trace_begin(trace, len(plans), seed, "exact")
        try:
            crashed = sched.run(workers)
        finally:
            self._trace_end(trace)
        done = self._completed_count()
        return RunResult(crashed=crashed, ops=self.ops, events=self.events,
                         stats=self.nvram.total_stats(), ops_completed=done,
                         sim_time_ns=self.nvram.sim_time_ns())

    def run_single(self, plan: List[Tuple[str, Any]],
                   trace=None) -> RunResult:
        """No scheduler: sequential single-thread execution (tid 0)."""
        self.nvram.set_tid(0)
        w = self.make_worker(0, plan)
        self._trace_begin(trace, 1, None, "single")
        try:
            w(0)
        finally:
            self._trace_end(trace)
        done = self._completed_count()
        return RunResult(crashed=False, ops=self.ops, events=self.events,
                         stats=self.nvram.total_stats(), ops_completed=done,
                         sim_time_ns=self.nvram.sim_time_ns())

    def run_batched(self, plans: List[List[Tuple[str, Any]]],
                    contention: Union[ContentionModel, bool, None] = None,
                    trace=None, compiled: Optional[bool] = None,
                    pause_gc: bool = True, profile=None,
                    burst=None) -> RunResult:
        """Clock-driven op-granularity execution: no OS threads, no yield
        points.  This is the throughput path -- hundreds of thousands of
        ops across 1..64+ threads are practical (the exact scheduler caps
        out around 60 ops/thread).  The schedule is deterministic (see
        ClockScheduler); interleavings vary only through the plans.

        ``compiled`` controls the schedule-compiler fast path
        (:mod:`repro.core.opsched`): by default steady-state ops replay
        their compiled schedules (~10x+ faster per op) and everything else
        bails to real per-primitive execution; Stats are bit-identical
        either way (the fast-path equivalence suite is the gate).  Pass
        ``compiled=False`` to force per-op execution -- the reference
        behavior the equivalence tests compare against.  The fast path is
        disabled automatically when a trace recorder is attached (traces
        record real primitives) or on the reference engine.

        ``contention`` attaches a CAS-contention model to the clock windows:
        pass a configured :class:`repro.core.contention.ContentionModel`, or
        ``True`` for the calibrated default.  Retry/helping costs are charged
        per the queue's :meth:`retry_profile`; with one thread (or
        ``retry_scale=0``) the counts are bit-identical to the uncontended
        run.  Crash injection is not supported here; use
        :meth:`run_scheduled` for crash/linearizability studies.

        ``profile`` attaches an observation-only phase profiler (e.g.
        :class:`repro.obs.PhaseProfiler`): the whole call runs under a
        ``bookkeeping`` phase, with the scheduler loop, op bodies, bails
        and record-charging nested inside (see ``benchmarks/run.py
        profile``).  Stats stay bit-identical; None (the default) leaves
        every hot path untouched.

        ``burst`` opts the run into the burst executor
        (:mod:`repro.core.burst`): whole multi-thread clock-heap bursts
        predicted and applied as array programs, mispredicted bursts
        replayed through the merged columnar runner.  ``True`` uses the
        defaults, a dict passes :class:`~repro.core.burst.BurstExecutor`
        options through (``window``, ``min_ops``, ``max_fixpoint_iters``,
        ``force_mispredict_every``, ``force_reject_every``).  Only
        engages where columnar dispatch does and the queue is
        burst-eligible; results stay bit-identical either way (the burst
        equivalence suite is the gate).  Per-run predictor counters land
        in :attr:`last_burst_stats`."""
        if profile is not None:
            profile.push("bookkeeping")
            if self._rstore is not None:
                self._rstore.profiler = profile
        try:
            return self._run_batched_inner(plans, contention, trace,
                                           compiled, pause_gc, profile,
                                           burst)
        finally:
            if profile is not None:
                if self._rstore is not None:
                    self._rstore.profiler = None
                profile.pop()   # bookkeeping

    def _run_batched_inner(self, plans, contention, trace, compiled,
                           pause_gc, profile, burst=None) -> RunResult:
        if contention is True:
            contention = ContentionModel()
        elif contention is False:
            contention = None
        op_kinds: List[List[str]] = []
        op_items: List[List] = []
        for plan in plans:
            op_kinds.append([kind for kind, _ in plan])
            op_items.append([item for _, item in plan])
        if contention is not None:
            contention.begin_run(self.nvram, self.queue.retry_profile(),
                                 schedules=self.queue.schedule_facts())
        self.contention = contention
        fast = None
        if compiled is None:
            compiled = True
        if compiled and trace is None and isinstance(self.nvram, NVRAM):
            fast = self._make_fast_executor()
        self.fast = fast
        if fast is not None and self._rstore is not None:
            # bind the columnar store's staging lists into the compiled
            # fns; the ClockScheduler then dispatches them directly
            fast.attach_store(self._rstore)
        # columnar dispatch replays every steady-state op compiled and only
        # touches a thunk on bail, so building one closure per planned op
        # up front would dominate the fast path; hand the scheduler the
        # factory instead.  The predicate mirrors ClockScheduler.run's
        # dispatch guard exactly.
        columnar = (fast is not None and fast.rstore is not None
                    and contention is None and fast.timed
                    and not self.nvram.contention_tracking)
        if columnar:
            op_lists = None
        else:
            op_lists = [[self._make_op(t, kind, item)
                         for kind, item in plan]
                        for t, plan in enumerate(plans)]
        sched = ClockScheduler(self.nvram, contention=contention,
                               fast=fast, pause_gc=pause_gc,
                               profile=profile, burst=burst)
        self.last_burst_stats = None
        self._trace_begin(trace, len(plans), None, "batched")
        try:
            sched.run(op_lists, op_kinds=op_kinds, op_items=op_items,
                      make_op=self._make_op)
            if sched.burst_exec is not None:
                self.last_burst_stats = sched.burst_exec.stats()
        finally:
            if fast is not None:
                fast.flush_counts()   # land deferred compiled-op charges
            self._trace_end(trace)
            # don't leave later (uncontended) runs on this engine paying
            # for the per-primitive epoch/CAS-tag stamping
            self.nvram.contention_tracking = False
        done = self._completed_count()
        return RunResult(crashed=False, ops=self.ops, events=self.events,
                         stats=self.nvram.total_stats(), ops_completed=done,
                         sim_time_ns=self.nvram.sim_time_ns())

    def _make_fast_executor(self):
        """Build the compiled-schedule executor for this harness's queue,
        or None when the queue declares no op_schedule()."""
        if self.queue.op_schedule() is None:
            return None
        rs = self._rstore
        if rs is not None:
            def record(tid: int, kind: str, item: Any) -> None:
                rs.add_completed_op(tid, kind, item)
        else:
            def record(tid: int, kind: str, item: Any) -> None:
                self._ops.append(OpRecord(tid=tid, kind=kind, item=item,
                                          completed=True))
        return FastPathExecutor(self.queue, self.nvram, record=record)

    def _make_op(self, tid: int, kind: str, item: Any):
        rs = self._rstore
        if rs is None:
            def op():
                if self._trace is not None:
                    self._trace.begin_op(tid, kind)
                rec = OpRecord(tid=tid, kind=kind, item=item)
                self._ops.append(rec)
                if kind == "enq":
                    self.queue.enqueue(tid, item)
                else:
                    rec.item = self.queue.dequeue(tid)
                rec.completed = True
        elif kind == "enq":
            def op():
                if self._trace is not None:
                    self._trace.begin_op(tid, kind)
                i = rs.begin_op(tid, "enq", item)
                self.queue.enqueue(tid, item)
                rs.complete_op(i)
        else:
            def op():
                if self._trace is not None:
                    self._trace.begin_op(tid, kind)
                i = rs.begin_op(tid, "deq", None)
                rs.complete_op(i, self.queue.dequeue(tid))
        return op

    # --------------------------------------------------------------- recovery
    def crash_and_recover(self, mode: str = "random", seed: int = 0,
                          snapshot=None, choices=None):
        """Full-system crash + recovery on this harness's engine.

        ``snapshot`` (an :class:`repro.core.nvram.EngineSnapshot`) is
        restored first when given -- the crash-sweep path: one scheduled run
        captured with per-step snapshots replaces rerunning the whole
        schedule for every crash point.  ``choices`` (a
        :class:`repro.core.nvram.CrashChoices`) pins the adversarial
        outcome for ``mode='subset'``.
        """
        if snapshot is not None:
            self.nvram.restore(snapshot)
        if choices is not None:
            self.nvram.crash(mode=mode, seed=seed, choices=choices)
        else:
            # the reference oracle's crash() has no `choices` parameter;
            # only the batched engine grows the subset seam
            self.nvram.crash(mode=mode, seed=seed)
        self.events.append(("crash",))
        # allocator state is volatile: recovery rebuilds the free lists from
        # the (persistent) designated areas (paper §9)
        self.mem = SSMem(self.nvram, self.nthreads,
                         area_nodes=self.mem.area_nodes)
        roots = getattr(self.queue, "roots", None)
        self.queue = self.queue_cls.recover(self.nvram, self.mem,
                                            self.nthreads, roots,
                                            on_event=self.events.append)
        return self.queue


# ---------------------------------------------------------------------------
# durable linearizability checking
# ---------------------------------------------------------------------------
def check_durable_linearizability(ops: List[OpRecord], events: List[tuple],
                                  recovered: List[Any]) -> Tuple[bool, str]:
    """Validate the recovered queue contents against the pre-crash history.

    See module docstring for the rule.  Events/ops cover the pre-crash
    execution only (pass the slices up to the ("crash",) marker).
    """
    link_order = [ev[1] for ev in events if ev[0] == "enq"]
    deq_order = [ev[1] for ev in events if ev[0] == "deq"]
    enq_completed = {r.item for r in ops if r.kind == "enq" and r.completed}
    deq_completed = {r.item for r in ops
                     if r.kind == "deq" and r.completed and r.item is not None}
    pending_deqs = sum(1 for r in ops if r.kind == "deq" and not r.completed)

    # sanity: recovered items must come from linked enqueues, no duplicates
    linkset = set(link_order)
    if len(set(recovered)) != len(recovered):
        return False, "duplicate items in recovered queue"
    for it in recovered:
        if it not in linkset:
            return False, f"recovered item {it!r} was never linked"
        if it in deq_completed:
            return False, f"recovered item {it!r} was dequeued (completed)"

    # every completed enqueue must survive unless dequeued
    must_have = [it for it in link_order
                 if it in enq_completed and it not in deq_completed]
    rset = set(recovered)
    # Walk link_order: the removed part must be a prefix (FIFO, Observation 2)
    # of the *kept* sequence; pending enqueues may be dropped anywhere.
    kept = [it for it in link_order if it in rset]
    if kept != recovered:
        return False, (f"recovered order {recovered!r} != link order "
                       f"{kept!r}")
    # removed completed-enqueue items must be explained: either completed
    # dequeues or at most `pending_deqs` pending ones, and removals must form
    # a prefix of the surviving sequence.
    removed_completed = [it for it in must_have if it not in rset]
    extra = [it for it in removed_completed if it not in deq_completed]
    if len(extra) > pending_deqs:
        return False, (f"items {extra!r} vanished without a dequeue")
    # prefix check: in link_order restricted to surviving items (recovered +
    # removed-by-dequeue), all removed items must precede all recovered ones.
    surviving = [it for it in link_order
                 if it in rset or it in deq_completed or it in extra]
    seen_kept = False
    for it in surviving:
        if it in rset:
            seen_kept = True
        elif seen_kept:
            return False, f"non-prefix removal: {it!r} removed after kept item"
    # completed dequeues must have dequeued in FIFO order of linked items
    # (checked against link order restricted to dequeued items)
    deq_link_order = [it for it in link_order if it in set(deq_order)]
    if deq_link_order != deq_order:
        return False, "dequeue order violates FIFO"
    return True, "ok"


def split_at_crash(events: List[tuple]) -> Tuple[List[tuple], List[tuple]]:
    if ("crash",) in events:
        i = events.index(("crash",))
        return events[:i], events[i + 1:]
    return list(events), []   # copy: callers may keep appending to `events`
