"""Vectorized burst execution -- optimistic interleaving prediction.

The merged columnar runner (PR 7) still walks the clock heap one op at a
time: per grant it pops the heap, runs one interpreted compiled-op body
(~45-50 lines of CPython at ~50ns/line) and pushes the thread back.
That per-op body is the ~4.5µs/op floor.  This module breaks it by
executing whole multi-thread bursts as array programs:

* **predict** -- per-op durations of compiled fast-path ops are pure
  functions of the packed outcome key, and in steady state the key per
  (tid, kind) is stable.  Seeding each live thread's next keys from its
  last committed ones, the whole grant order and every clock window of
  the next K ops is computable up front with a segmented ``cumsum`` +
  one ``lexsort`` -- no heap operations at all (the grant sequence of a
  clock heap over per-thread monotone streams is exactly the merge by
  ``(start, tid)``).
* **plan** -- a generated per-queue planner walks the predicted grant
  sequence once and performs the *real* allocator work (free-list pops,
  area-cursor bumps, limbo retires, epoch announces and the 64-op
  ``_try_advance`` boundaries) against the live ``SSMem`` /
  ``VolatileAlloc`` state, after snapshotting it.  Everything else
  about the op bodies is reconstructed vectorized: FIFO tail/head
  chains, per-record indices and dequeue results are prefix shifts and
  gathers over the planned allocation columns.
* **verify** -- the op bodies' line-state and volatile-touch
  transitions are replayed as a vector automaton over the fleet
  lowering's opcode tables (:func:`repro.fleet.lowering.encode_program`
  applied to the ``pin_tid=False`` lowering of the same compiled ops).
  One composite argsort groups every touched line's events in burst
  order; a segmented scan reconstructs each touch's outcome nibble and
  each line's final state *exactly* (the engine's ``TOUCH_CLASS`` /
  ``TOUCH_NEXT`` transition algebra decomposes into "last non-EVERFL
  event" + "any INVAL/EVERFL so far", both O(n) scans).  The
  recomputed keys are compared against the predicted ones.
* **commit** -- on full agreement the burst is committed: staged
  ``RecordStore`` rows (:meth:`~repro.core.records.RecordStore.
  extend_staged`), the generated values-only grant loop for the
  Python-valued stores (:func:`~repro.core.opsched.
  generate_burst_apply_fn`), one scatter each for final line states and
  volatile touch bits, the FIFO splice, and a heap rebuild from the
  committed clocks (the allocator state is already final -- the planner
  mutated the real thing).
* **mispredict** -- any key disagreement discards the speculative
  allocator state (snapshot restore) and either re-predicts with the
  learned keys (bounded fixpoint) or truncates the burst at the first
  disagreeing grant and commits the verified prefix with that grant's
  clock fixed to its true duration.  Structural hazards (empty dequeue,
  allocator exhaustion that would carve a new area/chunk mid-burst) are
  detected *before* planning and truncate the burst conservatively; the
  scheduler replays rejected bursts through the merged columnar runner,
  which handles bails bit-identically.

Bit identity is the contract: every committed burst produces exactly
the staged rows, engine mutations and queue state the merged columnar
runner would have -- gated by the burst equivalence suite across all
queues, models and contention settings.
"""
from __future__ import annotations

import heapq
from collections import deque
from itertools import islice, repeat
from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np

from .nvram import (EV_COLD_DRAM, EV_COLD_NVM, EV_DRAM, EV_HIT,
                    EV_POSTFLUSH, LINE_WORDS, NVRAM)
from .opsched import (K_CASTAG, K_CLASS_P, K_CLASS_V, K_DRAIN, K_DRAINF,
                      K_LINE, K_LOGW, K_NT, K_NTAPPLY, K_PENDW, K_PMEMW,
                      K_STAMP, K_STATE, K_VVAL, _SYMS, _op_value_syms,
                      compile_cached, generate_burst_apply_fn)
from .records import META_KEY_SHIFT

_ST_INVAL, _ST_EVERFL, _ST_RECACHE = 0, 1, 2
_VB = NVRAM._VOLATILE_BASE

# symbol sets a burst-eligible op may reference, per kind: the planner
# can reconstruct exactly these node-locals (allocation columns, FIFO
# tail/head/next chains)
_ENQ_SYMS = frozenset({"new_p", "new_v", "tail_p", "tail_v"})
_DEQ_SYMS = frozenset({"head_p", "head_v", "next_p", "next_v"})
_V_SYMS = frozenset({"new_v", "tail_v", "head_v", "next_v"})


class _KindTables:
    """Static per-(queue, kind, model) burst tables: the opcode rows
    split into persistent-line events and volatile-touch events, with
    per-row address modes and key-nibble shifts."""

    __slots__ = ("kbit", "syms", "n_rows",
                 "p_amode", "p_sym", "p_off", "p_const", "p_pos",
                 "p_c", "p_b", "p_touch", "p_shift",
                 "v_amode", "v_sym", "v_off", "v_const", "v_pos", "v_shift")

    def __init__(self, kbit: int):
        self.kbit = kbit
        self.syms: set = set()


def _build_kind_tables(op, fp, oprog) -> Optional[_KindTables]:
    """Lower one compiled op's fleet micro rows into burst event tables.
    Returns None when the op shape is outside the supported matrix.

    Event algebra (exact on the engine's reachable line states
    {0, 1, 4, 5, 6}): every micro row maps to a low-bits transition code
    ``c`` (1 = leaves the line cached, 2 = flush-invalidated,
    0 = EVERFL-only, transparent) plus a sticky ``b`` bit (the line has
    ever been flushed).  ``K_LINE`` and ``ST_RECACHE`` both reduce to
    the ``TOUCH_NEXT`` transition ``(s & 4) | 1`` -- for ``ST_RECACHE``
    that equivalence needs the row to follow its own op's ``ST_INVAL``
    on the same address (the compiler guarantees it; verified here)."""
    kt = _KindTables(0 if op.kind == "enq" else 1)
    allowed = _ENQ_SYMS if op.kind == "enq" else _DEQ_SYMS
    p_rows: List[tuple] = []     # (c, b, touch, ref, pos, slot)
    v_rows: List[tuple] = []     # (ref, pos, slot)
    n_class = 0
    seen_inval: set = set()
    for pos, m in enumerate(fp.micro):
        tag = m[0]
        ref = m[1]
        if ref.mode == "sym":
            name = _SYMS[ref.sym]
            if name not in allowed:
                return None
            kt.syms.add(name)
        if tag == "class_p":
            p_rows.append((1, 0, 1, ref, pos, n_class))
            n_class += 1
        elif tag == "class_v":
            v_rows.append((ref, pos, n_class))
            n_class += 1
        elif tag == "line":
            p_rows.append((1, 0, 0, ref, pos, -1))
        elif tag == "state":
            mode = m[2]
            if mode == _ST_INVAL:
                p_rows.append((2, 1, 0, ref, pos, -1))
                seen_inval.add(ref)
            elif mode == _ST_EVERFL:
                p_rows.append((0, 1, 0, ref, pos, -1))
            elif mode == _ST_RECACHE:
                if ref not in seen_inval:
                    return None
                p_rows.append((1, 0, 0, ref, pos, -1))
            else:
                return None
        else:
            return None
    if n_class != op.n_class or oprog.n_micro != len(fp.micro):
        return None

    def _pack(ref) -> Tuple[int, Optional[str], int, int]:
        # (amode, sym-name, off, const); v-space consts and per-tid
        # roots are already _VOLATILE_BASE-relative (fleet _lower_addr)
        if ref.mode == "const":
            return 0, None, 0, ref.const
        if ref.mode == "tid":
            return 2, None, 0, ref.const
        return 1, _SYMS[ref.sym], ref.off, 0

    kt.n_rows = len(fp.micro)
    pk = [_pack(r[3]) for r in p_rows]
    kt.p_amode = np.array([p[0] for p in pk], np.int64)
    kt.p_sym = [p[1] for p in pk]
    kt.p_off = np.array([p[2] for p in pk], np.int64)
    kt.p_const = np.array([p[3] for p in pk], np.int64)
    kt.p_pos = np.array([r[4] for r in p_rows], np.int64)
    kt.p_c = np.array([r[0] for r in p_rows], np.int64)
    kt.p_b = np.array([r[1] for r in p_rows], np.int64)
    kt.p_touch = np.array([r[2] for r in p_rows], bool)
    slots = np.array([r[5] for r in p_rows], np.int64)
    kt.p_shift = np.where(slots >= 0, 4 * (n_class - 1 - slots), -1)
    vk = [_pack(r[0]) for r in v_rows]
    kt.v_amode = np.array([p[0] for p in vk], np.int64)
    kt.v_sym = [p[1] for p in vk]
    kt.v_off = np.array([p[2] for p in vk], np.int64)
    kt.v_const = np.array([p[3] for p in vk], np.int64)
    kt.v_pos = np.array([r[1] for r in v_rows], np.int64)
    kt.v_shift = 4 * (n_class - 1 - np.array([r[2] for r in v_rows],
                                             np.int64))
    return kt


# --------------------------------------------------------------------------
# generated planner
# --------------------------------------------------------------------------
def _retire_specs(op) -> Optional[List[Tuple[str, str]]]:
    """aux_specs as [(sym_name, "p"|"v")], or None when unsupported."""
    out: List[Tuple[str, str]] = []
    allowed = _ENQ_SYMS if op.kind == "enq" else _DEQ_SYMS
    for ax in op.aux_specs:
        if ax[0] not in ("retire", "retire_v"):
            return None
        val = ax[1]
        if not (isinstance(val, tuple) and val[0] == "sym"
                and val[1] in allowed):
            return None
        out.append((val[1], "p" if ax[0] == "retire" else "v"))
    return out


def generate_plan_fn(queue, ops: Dict, mem, valloc,
                     retires: Dict[str, List[Tuple[str, str]]]) -> Callable:
    """Generate the burst planner: one pass over the predicted grant
    sequence doing only the sequential allocator work, against the live
    (snapshotted) allocator state.

    ``_plan(n, kb, tids, d0, exist_p, exist_v, h_p, h_v, t_p, t_v,
    badv, e_np, e_nv)`` -- ``badv`` is the grant index whose op_begin
    crosses the 64-op epoch-advance boundary (>= n when none does),
    ``h_*`` / ``t_*`` the current head/tail record fields, ``exist_*``
    the pre-burst FIFO columns, and ``e_np`` / ``e_nv`` output lists
    receiving the allocated node addresses in enqueue order."""
    enq = ops["enq"]
    uses_ss = enq.uses_ssmem
    d_ret = retires["deq"]
    e_ret = retires["enq"]
    any_ret = bool(d_ret or e_ret)
    need_r_p = any(s == "next_p" for s, _ in d_ret) or \
        any(s == "head_p" for s, _ in d_ret)
    need_r_v = any(s == "next_v" for s, _ in d_ret) or \
        any(s == "head_v" for s, _ in d_ret)
    need_h = any(s in ("head_p", "head_v") for s, _ in d_ret)
    need_t = any(s in ("tail_p", "tail_v") for s, _ in e_ret)
    w: List[str] = []
    emit = w.append
    emit("def _plan(n, kb, tids, d0, exist_p, exist_v, h_p, h_v, t_p, t_v,"
         " badv, e_np, e_nv):")
    if uses_ss:
        emit("    ann = mem._announced")
    if any_ret:
        emit("    lb = mem._limbo")
    if uses_ss or any_ret:
        emit("    ep = mem._epoch")
    if enq.allocs_p:
        emit("    mf = mem._free")
        emit("    mcur = mem._cursor")
        emit("    mar = mem._areas")
        emit("    ena = e_np.append")
    if enq.allocs_v:
        emit("    vf = valloc._free")
        emit("    vcur = valloc._cursor")
        emit("    vbase = valloc._base")
        emit(f"    _NW = {valloc.node_words if valloc is not None else LINE_WORDS}")
        emit("    enva = e_nv.append")
    if d_ret:
        emit("    j = 0")
    emit("    g = 0")
    emit("    while g < n:")
    emit("        t = tids[g]")
    if uses_ss:
        emit("        ann[t] = ep")
        emit("        if g == badv:")
        emit("            mem._try_advance()")
        emit("            ep = mem._epoch")
        emit("            badv += 64")
    emit("        if kb[g]:")
    deq_body: List[str] = []
    if d_ret:
        if need_r_p or need_r_v:
            deq_body.append("if j < d0:")
            fields = []
            if need_r_p:
                fields.append("_rp = exist_p[j]")
            if need_r_v:
                fields.append("_rv = exist_v[j]")
            deq_body.append("    " + "; ".join(fields))
            deq_body.append("else:")
            deq_body.append("    _m = j - d0")
            fields = []
            if need_r_p:
                fields.append("_rp = e_np[_m]")
            if need_r_v:
                fields.append("_rv = e_nv[_m]")
            deq_body.append("    " + "; ".join(fields))
        src = {"head_p": "h_p", "head_v": "h_v",
               "next_p": "_rp", "next_v": "_rv"}
        for name, space in d_ret:
            deq_body.append(f"lb[t].append(({src[name]}, ep, {space!r}))")
        if need_h:
            if need_r_p:
                deq_body.append("h_p = _rp")
            if need_r_v:
                deq_body.append("h_v = _rv")
        deq_body.append("j += 1")
    else:
        deq_body.append("pass")
    for line in deq_body:
        emit("            " + line)
    emit("        else:")
    enq_body: List[str] = []
    if enq.allocs_p:
        enq_body += ["_f = mf[t]",
                     "if _f:",
                     "    _x = _f.pop()",
                     "else:",
                     "    _cu = mcur[t]",
                     f"    _x = mar[t][-1] + _cu * {LINE_WORDS}",
                     "    mcur[t] = _cu + 1",
                     "ena(_x)"]
    if enq.allocs_v:
        enq_body += ["_f2 = vf[t]",
                     "if _f2:",
                     "    _y = _f2.pop()",
                     "else:",
                     "    _cv = vcur[t]",
                     "    _y = vbase[t] + _cv * _NW",
                     "    vcur[t] = _cv + 1",
                     "enva(_y)"]
    if e_ret:
        src_e = {"new_p": "_x", "new_v": "_y", "tail_p": "t_p",
                 "tail_v": "t_v"}
        for name, space in e_ret:
            enq_body.append(f"lb[t].append(({src_e[name]}, ep, {space!r}))")
    if need_t:
        if enq.allocs_p:
            enq_body.append("t_p = _x")
        if enq.allocs_v:
            enq_body.append("t_v = _y")
    if not enq_body:
        enq_body.append("pass")
    for line in enq_body:
        emit("            " + line)
    emit("        g += 1")
    src = "\n".join(w)
    env = {"mem": mem, "valloc": valloc}
    exec(compile_cached(src, f"<burst-plan:{type(queue).__name__}>"), env)
    fn = env["_plan"]
    fn.__source__ = src
    return fn


# --------------------------------------------------------------------------
# row-batched value application
# --------------------------------------------------------------------------
# The generated per-grant value loop (generate_burst_apply_fn) is exact
# but sequential CPython.  Every values_only program is *straight-line*
# (each grant executes each store row), so the same effects can also be
# applied row-batched: one fancy scatter (the ``vval`` object ndarray)
# or one C-level ``map(list.__setitem__, ...)`` pass (the ``vis`` /
# ``pmem`` lists) per program row, rows in program order, enqueue rows
# before dequeue rows.  Batching "row-major" instead of "grant-major"
# reorders writes, which is safe exactly when:
#
# * same-row duplicates resolve last-wins in grant order (columns are
#   built in grant order; const-addressed rows collapse to one scalar
#   store of the last grant's value, tid-addressed rows to one store
#   per thread of that thread's last value);
# * cross-row conflicts within a kind only pair an earlier row with a
#   LATER grant: statically, no allocation-addressed row (``new_*``)
#   may follow a chain-addressed row (``tail_*``) on the same plane --
#   a tail address is the previous grant's allocation, so tail rows
#   overwrite new rows, never the reverse;
# * dequeue programs address stores only through constants or the
#   per-tid scratch mode (queue-header regions, disjoint from node
#   areas by region construction), never node symbols;
# * no node address is both consumed/free and re-allocated inside one
#   burst (checked per burst: allocated addresses must be unique and
#   disjoint from the burst's consumed records and the pre-burst
#   tail/head records -- the tail is a retired dummy when the FIFO
#   starts empty);
# * drained lines are clean at burst start and stay clean (checked per
#   burst against the live log; lines this burst itself appends to
#   count as dirty);
# * log appends and line-start counters are per-line aggregations:
#   appends extend in grant order (one appending row per line,
#   enforced statically), counters sum.
#
# Static ineligibility keeps the per-grant loop permanently (``vap`` is
# None); a dynamic hazard falls back for that one burst.
_K_SKIP = frozenset({K_CLASS_P, K_CLASS_V, K_STATE, K_CASTAG, K_STAMP})
_NEW_SYMS = frozenset({"new_p", "new_v"})
_TAIL_SYMS = frozenset({"tail_p", "tail_v"})
_PLANES = ("vis", "pmem", "vval")

_SINK = deque(maxlen=0)
_consume = _SINK.extend        # run a map() at C speed, discard results


class _VecApply:
    """Static row-batched application program for one (queue, model)."""

    __slots__ = ("streams", "drains", "logls", "check_p", "check_v")

    def __init__(self):
        # per kind: [(target, amode, sym, off, const, vtag, vpayload)]
        self.streams: Dict[str, list] = {}
        self.drains: Dict[str, list] = {}   # packed drain-target addrs
        self.logls: frozenset = frozenset()  # lines K_LOGW appends to
        self.check_p = False
        self.check_v = False


def _vec_pack_addr(a) -> Tuple[int, Optional[str], int, int]:
    if a[0] == 0:
        return (0, None, 0, a[1])
    if a[0] == 1:
        return (1, _SYMS[a[1]], a[2], 0)
    return (2, None, 0, a[1] + a[2])


def _vec_pack_val(v):
    tag = v[0]
    if tag == "c":
        return ("c", v[1])
    if tag in ("item", "idx"):
        return (tag, None)
    if tag == "sym":
        return ("sym", v[1])
    return None                  # tup / slot values: per-grant only


def _vec_streams_for(op):
    """Lower one values_only program to row-batched stream specs, or
    None when any row resists batching."""
    streams: list = []
    drains: list = []
    logls: list = []

    def store(target, a, v, k=0) -> bool:
        if v is None:
            return False
        am, sym, off, const = a
        if am == 1:
            off += k
        else:
            const += k
        streams.append((target, am, sym, off, const) + v)
        return True

    for ins in op.prog:
        code = ins[0]
        if code in _K_SKIP:
            continue
        a = _vec_pack_addr(ins[1])
        if code == K_VVAL:
            if not store("vval", a, _vec_pack_val(ins[3])):
                return None
        elif code in (K_PENDW, K_NT):
            if not store("vis", a, _vec_pack_val(ins[3])):
                return None
        elif code == K_PMEMW:
            v = _vec_pack_val(ins[3])
            if not (store("vis", a, v) and store("pmem", a, v)):
                return None
        elif code == K_NTAPPLY:
            if not store("pmem", a, _vec_pack_val(ins[3])):
                return None
        elif code == K_LOGW:
            if a[0] != 0:
                return None      # per-line append order needs a const
            v = _vec_pack_val(ins[3])
            if not (store("vis", a, v) and store("logext", a, v)):
                return None
            logls.append(a[3] // LINE_WORDS)
        elif code == K_LINE:
            if not (ins[4] or ins[5]):
                return None      # materializing line write
            for k in range(LINE_WORDS):
                v = ("item", None) if ins[3] == k else ("c", ins[2][k])
                store("vis", a, v, k)
                if ins[4]:
                    store("pmem", a, v, k)
        elif code == K_DRAIN:
            drains.append(a)
        elif code == K_DRAINF:
            drains.append(a)
            for ent in ins[2]:
                ea = _vec_pack_addr(ent[1])
                if ent[0] == "w":
                    if not store("pmem", ea, _vec_pack_val(ent[3])):
                        return None
                else:
                    for k in range(LINE_WORDS):
                        v = ("item", None) if ent[3] == k else \
                            ("c", ent[2][k])
                        store("pmem", ea, v, k)
            streams.append(("ls", a[0], a[1], a[2], a[3], "c", ins[3]))
        else:
            return None
    return streams, drains, logls


def _fixed_collide(s1, s2, nthreads: int) -> bool:
    """Whether two const/tid-addressed streams can touch one address."""
    am1, c1 = s1[1], s1[4]
    am2, c2 = s2[1], s2[4]
    if am1 == 0 and am2 == 0:
        return c1 == c2
    d = c1 - c2
    if d % LINE_WORDS:
        return False
    t = abs(d) // LINE_WORDS
    return t < nthreads


def _build_vector_apply(ops, nthreads: int) -> Optional[_VecApply]:
    per = {}
    for kind in ("enq", "deq"):
        r = _vec_streams_for(ops[kind])
        if r is None:
            return None
        per[kind] = r
    # dequeues may not address stores through node symbols: the
    # enq-batch-then-deq-batch order is only safe for header writes
    if any(st[1] == 1 and st[0] in _PLANES for st in per["deq"][0]):
        return None
    # within a kind, no allocation-addressed row after a chain row
    for kind in ("enq", "deq"):
        seen_tail = set()
        for st in per[kind][0]:
            if st[1] == 1 and st[0] in _PLANES:
                if st[2] in _TAIL_SYMS:
                    seen_tail.add(st[0])
                elif st[2] in _NEW_SYMS and st[0] in seen_tail:
                    return None
    # const/tid-addressed collisions: forbidden across kinds always,
    # and within a kind unless the two rows address identically (then
    # row order == per-grant order and last-wins is preserved)
    fixed = {k: [st for st in per[k][0]
                 if st[1] != 1 and st[0] in _PLANES]
             for k in ("enq", "deq")}
    for s1 in fixed["enq"]:
        for s2 in fixed["deq"]:
            if s1[0] == s2[0] and _fixed_collide(s1, s2, nthreads):
                return None
    for kind in ("enq", "deq"):
        sts = fixed[kind]
        for i, s1 in enumerate(sts):
            for s2 in sts[i + 1:]:
                if s1[0] == s2[0] and (s1[1], s1[4]) != (s2[1], s2[4]) \
                        and _fixed_collide(s1, s2, nthreads):
                    return None
    # at most one appending row per log line, across both kinds
    all_logls = per["enq"][2] + per["deq"][2]
    if len(all_logls) != len(set(all_logls)):
        return None
    vap = _VecApply()
    for kind in ("enq", "deq"):
        vap.streams[kind] = per[kind][0]
        vap.drains[kind] = per[kind][1]
    vap.logls = frozenset(all_logls)
    syms = {st[2] for k in ("enq", "deq") for st in per[k][0]
            if st[1] == 1 and st[0] in _PLANES}
    vap.check_p = bool(syms & {"new_p", "tail_p"})
    vap.check_v = bool(syms & {"new_v", "tail_v"})
    return vap


# --------------------------------------------------------------------------
# program build + support detection
# --------------------------------------------------------------------------
class BurstProgram:
    """Everything static about bursting one (queue, model): the per-kind
    event tables, the generated planner and values-only apply loop, and
    the feature flags the executor branches on."""

    __slots__ = ("kts", "plan_fn", "apply_fn", "cols", "uses_ssmem",
                 "allocs_p", "allocs_v", "retires", "max_rows",
                 "need_syms", "vap", "vplan")

    def __init__(self):
        self.kts: Dict[str, _KindTables] = {}


def build_burst_program(fast) -> Optional[BurstProgram]:
    """Build (or fetch cached) the burst program for ``fast``'s queue on
    its engine's model; None when the queue/model is outside the burst
    support matrix (the scheduler then stays on the columnar runner)."""
    nv = fast.nv
    cache = fast.q.__dict__.setdefault("_burst_programs", {})
    key = nv.model.name
    ent = cache.get(key)
    if ent is not None and ent[1] is nv:
        return ent[0]
    prog = _build_program(fast)
    cache[key] = (prog, nv)
    return prog


def _build_program(fast) -> Optional[BurstProgram]:
    from repro.fleet.lowering import (FleetLoweringError, encode_program,
                                      lower_op)
    if fast.crunner is None or not fast.timed:
        return None
    q = fast.q
    ops = fast.ops
    mem = getattr(q, "mem", None)
    valloc = getattr(q, "valloc", None)
    if ops["enq"].uses_ssmem != ops["deq"].uses_ssmem:
        return None
    bp = BurstProgram()
    bp.retires = {}
    bp.need_syms = {}
    for kind in ("enq", "deq"):
        op = ops[kind]
        if op.guard_specs:
            return None
        rets = _retire_specs(op)
        if rets is None or (rets and mem is None):
            return None
        bp.retires[kind] = rets
        vcols = _op_value_syms(op)
        allowed = _ENQ_SYMS if kind == "enq" else _DEQ_SYMS
        if not vcols <= allowed:
            return None
        try:
            fp = lower_op(op, frozenset(), pin_tid=False)
            oprog = encode_program(fp, ())
        except FleetLoweringError:
            return None
        kt = _build_kind_tables(op, fp, oprog)
        if kt is None:
            return None
        bp.kts[kind] = kt
        bp.need_syms[kind] = kt.syms | vcols | {s for s, _ in rets}
    enq = ops["enq"]
    if (enq.allocs_p or enq.uses_ssmem) and mem is None:
        return None
    if enq.allocs_v and valloc is None:
        return None
    # volatile record fields only exist when the enqueue allocates them
    if (bp.need_syms["deq"] & _V_SYMS or "tail_v" in bp.need_syms["enq"]) \
            and not enq.allocs_v:
        return None
    bp.uses_ssmem = enq.uses_ssmem
    bp.allocs_p = enq.allocs_p
    bp.allocs_v = enq.allocs_v
    bp.max_rows = max(kt.n_rows for kt in bp.kts.values()) or 1
    bp.apply_fn = generate_burst_apply_fn(q, ops, fast.nv)
    bp.cols = bp.apply_fn.__cols__
    bp.plan_fn = generate_plan_fn(q, ops, mem, valloc, bp.retires)
    bp.vap = _build_vector_apply(ops, fast.nv.nthreads)
    # the vectorized planner covers pure-enqueue bursts; enqueues that
    # retire records need the sequential planner's limbo walk
    bp.vplan = not bp.retires["enq"]
    return bp


# --------------------------------------------------------------------------
# the vector automaton
# --------------------------------------------------------------------------
def _p_automaton(lv: np.ndarray, lines: np.ndarray, seq: np.ndarray,
                 c: np.ndarray, b: np.ndarray, span: int):
    """Replay all persistent-line events of a burst at once.

    ``lv`` is a live uint8 view of the engine's packed ``_lstate``; the
    events are (line, global seq, c-code, flushed-bit).  Returns (touch
    outcome nibble per event in input order, per-touched-line final
    lines, final states), or None when an initial line state falls
    outside the reachable set {0, 1, 4, 5, 6}."""
    n = lines.size
    order = np.argsort(lines * span + seq)
    ls_ = lines[order]
    c_ = c[order]
    b_ = b[order]
    start = np.empty(n, dtype=bool)
    start[0] = True
    start[1:] = ls_[1:] != ls_[:-1]
    gstart = np.nonzero(start)[0]
    grp = np.cumsum(start) - 1
    gs = gstart[grp]
    glines = ls_[gstart]
    init_g = lv[glines].astype(np.int64)
    # the engine only reaches {0,1,4,5,6}: FINVAL is always set together
    # with EVERFL and never alongside CACHED -- anything else means the
    # decomposition below doesn't apply, so the burst bails out
    if ((((init_g & 3) == 3) | ((init_g & 6) == 2)) | (init_g > 7)).any():
        return None
    init = init_g[grp]
    idx = np.arange(n, dtype=np.int64)
    nz = np.where(c_ != 0, idx, -1)
    m = np.maximum.accumulate(nz)
    m_strict = np.empty(n, np.int64)
    m_strict[0] = -1
    m_strict[1:] = m[:-1]
    has_p = m_strict >= gs
    pc = c_[np.where(has_p, m_strict, 0)]
    low = np.where(has_p, np.where(pc == 2, 2, 1), init & 3)
    cb = np.cumsum(b_)
    cb_strict = cb - b_
    bit4 = ((cb_strict - cb_strict[gs]) > 0) | ((init & 4) != 0)
    nib = np.where(low & 1, EV_HIT,
                   np.where(low & 2, EV_POSTFLUSH,
                            np.where(bit4, EV_COLD_NVM, EV_COLD_DRAM)))
    out = np.empty(n, np.int64)
    out[order] = nib
    ge = np.empty(gstart.size, np.int64)
    ge[:-1] = gstart[1:] - 1
    ge[-1] = n - 1
    has_c = m[ge] >= gstart
    pcf = c_[np.where(has_c, m[ge], 0)]
    lowf = np.where(has_c, np.where(pcf == 2, 2, 1), init_g & 3)
    b4f = ((cb[ge] - cb_strict[gstart]) > 0) | ((init_g & 4) != 0)
    fin = lowf | (b4f.astype(np.int64) << 2)
    return out, glines, fin


def _v_automaton(vtv: np.ndarray, vis: np.ndarray, seq: np.ndarray,
                 span: int, scratch: Optional[np.ndarray] = None
                 ) -> np.ndarray:
    """Volatile-touch nibbles (EV_DRAM on the burst's first touch of an
    untouched word, EV_HIT otherwise) per event in input order.

    With ``scratch`` (an int64 array covering the volatile word space)
    the events are promised already seq-ordered -- the first occurrence
    of each address is found with three linear passes instead of a
    sort: a reversed fancy write leaves each address holding the index
    of its *first* occurrence (duplicate-index assignment is last-wins).
    """
    n = vis.size
    if scratch is not None:
        idx = np.arange(n, dtype=np.int64)
        scratch[vis[::-1]] = idx[::-1]
        first = scratch[vis] == idx
        return np.where(first, EV_DRAM - vtv[vis].astype(np.int64),
                        EV_HIT)
    order = np.argsort(vis * span + seq)
    vs_ = vis[order]
    start = np.empty(n, dtype=bool)
    start[0] = True
    start[1:] = vs_[1:] != vs_[:-1]
    nib = np.where(start, EV_DRAM - vtv[vs_].astype(np.int64), EV_HIT)
    out = np.empty(n, np.int64)
    out[order] = nib
    return out


def predict_grants(dur: np.ndarray, seg_len_a: np.ndarray,
                   seg_t0_a: np.ndarray, pool_tid: np.ndarray,
                   more: np.ndarray):
    """Pure clock-heap prediction over per-op durations.

    ``dur`` holds each pooled op's predicted latency, segmented by
    thread (``seg_len_a`` ops per segment, thread clocks starting at
    ``seg_t0_a``, thread ids repeated in ``pool_tid``).  Returns
    ``(order, g_tid, g_start, g_end, N)``: the permutation sorting the
    pool into clock-heap grant order, the per-grant clock windows, and
    the count ``N`` of leading grants that remain valid given ``more``
    (per-segment flag: the thread has unpooled ops and re-enters the
    heap at its last pooled end; grants are valid only while they sort
    strictly before the earliest such re-entry point).

    This is exactly the order ``ClockScheduler``'s ``(time, tid)`` heap
    would produce: every latency is a multiple of 0.5ns, so the float
    cumsums are exact and association-free (the same invariant the
    per-op incremental clocks rely on), and the lexsort's tid tiebreak
    matches the heap's tuple comparison.
    """
    cs = np.cumsum(dur)
    seg_start = np.concatenate(([0], np.cumsum(seg_len_a)[:-1]))
    offs = np.repeat(cs[seg_start] - dur[seg_start], seg_len_a)
    t0_rep = np.repeat(seg_t0_a, seg_len_a)
    ends = t0_rep + (cs - offs)
    starts = ends - dur
    order = np.lexsort((pool_tid, starts))
    g_tid = pool_tid[order]
    g_start = starts[order]
    g_end = ends[order]
    N = int(dur.size)
    if more.any():
        seg_last = seg_start + seg_len_a - 1
        ce = ends[seg_last[more]]
        ct = pool_tid[seg_start][more]
        cut_e = ce.min()
        cut_t = int(ct[ce == cut_e].min())
        keep = (g_start < cut_e) | ((g_start == cut_e) & (g_tid < cut_t))
        N = int(keep.sum())
    return order, g_tid, g_start, g_end, N


# --------------------------------------------------------------------------
# the executor
# --------------------------------------------------------------------------
class BurstExecutor:
    """Drives burst prediction/commit for one batched columnar run.

    Created by :class:`repro.core.scheduler.ClockScheduler` when the run
    is dispatched columnar and ``burst`` is enabled; shares the
    scheduler's live ``heap`` / ``cursors``.  :meth:`try_burst` returns
    the number of ops committed (0 = this burst could not be predicted;
    the scheduler then replays a bounded chunk through the merged
    columnar runner)."""

    #: ops replayed per merged-runner chunk after a burst rejection
    REPLAY_CHUNK = 256

    def __init__(self, prog: BurstProgram, fast, op_kinds, op_items, lens,
                 profile=None, window: int = 8192, min_ops: int = 33,
                 max_fixpoint_iters: int = 3,
                 force_mispredict_every: int = 0,
                 force_reject_every: int = 0):
        self.prog = prog
        self.fast = fast
        self.nv = fast.nv
        self.mem = getattr(fast.q, "mem", None)
        self.valloc = getattr(fast.q, "valloc", None)
        self.fifo = fast.fifo
        self.dbox = fast._dbox
        self.rs = fast.rstore
        self.op_kinds = op_kinds
        self.op_items = op_items
        self.lens = lens
        self.profile = profile
        self.window = window
        self.min_ops = min_ops
        self.max_iters = max(1, max_fixpoint_iters)
        self.force_mispredict_every = force_mispredict_every
        self.force_reject_every = force_reject_every
        nthreads = self.nv.nthreads
        self._seed = np.full((nthreads, 2), -1, dtype=np.int64)
        self._kb: List[Optional[np.ndarray]] = [None] * nthreads
        self._it: List[Optional[np.ndarray]] = [None] * nthreads
        self._ns_vec = self.nv._ns_vec
        self._vscr: Optional[np.ndarray] = None
        # counters (read by benchmarks/tests; replayed_ops is driver-fed)
        self.n_bursts = 0
        self.n_commits = 0
        self.n_mispredicts = 0
        self.n_truncations = 0
        self.n_rejects = 0
        self.ops_bursted = 0
        self.replayed_ops = 0
        self.n_vec_plans = 0      # bursts planned by _vector_plan
        self.n_vec_applies = 0    # commits applied row-batched

    def stats(self) -> Dict[str, int]:
        return {"bursts": self.n_bursts, "commits": self.n_commits,
                "mispredicts": self.n_mispredicts,
                "truncations": self.n_truncations,
                "rejects": self.n_rejects,
                "ops_bursted": self.ops_bursted,
                "replayed_ops": self.replayed_ops,
                "vec_plans": self.n_vec_plans,
                "vec_applies": self.n_vec_applies}

    # -- per-thread static columns ---------------------------------------
    def _thread_cols(self, t: int):
        kb = self._kb[t]
        if kb is None:
            kinds = self.op_kinds[t]
            n = len(kinds)
            c = kinds.count("deq") if isinstance(kinds, list) else -1
            if c == 0:
                kb = np.zeros(n, np.int64)
            elif c == n:
                kb = np.ones(n, np.int64)
            else:
                kb = (np.array(kinds, dtype="U3") == "deq") \
                    .astype(np.int64)
            items = np.empty(n, dtype=object)
            items[:] = self.op_items[t]
            self._kb[t] = kb
            self._it[t] = items
        return kb, self._it[t]

    def _harvest_seeds(self) -> None:
        """Seed per-(tid, kind) keys from the staged rows the columnar
        runner (or prior bursts) already produced."""
        sm = self.rs._sm
        if not len(sm):
            return
        m = np.frombuffer(sm, dtype=np.int64)
        combo = ((m >> 1) & 0xFF) * 2 + (m & 1)
        uniq, ridx = np.unique(combo[::-1], return_index=True)
        last = m.size - 1 - ridx
        self._seed[uniq // 2, uniq % 2] = m[last] >> META_KEY_SHIFT

    # -- speculative allocator state -------------------------------------
    def _snapshot(self):
        mem, valloc = self.mem, self.valloc
        ms = vs = None
        if mem is not None:
            ms = ({t: list(l) for t, l in mem._free.items()},
                  dict(mem._cursor), dict(mem._announced),
                  {t: list(l) for t, l in mem._limbo.items()},
                  mem._epoch, mem._ops_since_adv)
        if valloc is not None:
            vs = ({t: list(l) for t, l in valloc._free.items()},
                  dict(valloc._cursor))
        return ms, vs

    def _restore(self, snap) -> None:
        ms, vs = snap
        mem, valloc = self.mem, self.valloc
        if ms is not None:
            free, cursor, ann, limbo, epoch, osa = ms
            for t, l in free.items():
                mem._free[t][:] = l
            mem._cursor.update(cursor)
            mem._announced.clear()
            mem._announced.update(ann)
            for t, l in limbo.items():
                mem._limbo[t][:] = l
            mem._epoch = epoch
            mem._ops_since_adv = osa
        if vs is not None:
            vfree, vcursor = vs
            for t, l in vfree.items():
                valloc._free[t][:] = l
            valloc._cursor.update(vcursor)

    # -- core -------------------------------------------------------------
    def try_burst(self, heap, cursors) -> int:
        nv = self.nv
        if nv.crashed:
            return 0
        pending = nv._pending
        for _, t in heap:
            if pending.get(t):
                return 0
        return self._try_burst_inner(heap, cursors)

    def _try_burst_inner(self, heap, cursors) -> int:
        self.n_bursts += 1
        prof = self.profile
        lens = self.lens
        wper = max(16, self.window // len(heap))
        if prof is not None:
            prof.push("burst-predict")
        # ---- pool: per live thread, up to wper pending ops -------------
        seg_tid: List[int] = []
        seg_t0: List[float] = []
        seg_len: List[int] = []
        kb_parts = []
        it_parts = []
        for t0, t in heap:
            c = cursors[t]
            k = min(lens[t] - c, wper)
            kb, items = self._thread_cols(t)
            kb_parts.append(kb[c:c + k])
            it_parts.append(items[c:c + k])
            seg_tid.append(t)
            seg_t0.append(t0)
            seg_len.append(k)
        P = int(sum(seg_len))
        if P < self.min_ops:
            if prof is not None:
                prof.pop()
            self.n_rejects += 1
            return 0
        pool_kb = np.concatenate(kb_parts)
        pool_tid = np.repeat(np.array(seg_tid, np.int64),
                             np.array(seg_len, np.int64))
        it_pool = np.concatenate(it_parts)
        seg_len_a = np.array(seg_len, np.int64)
        seg_t0_a = np.array(seg_t0, np.float64)
        keys_pool = self._seed[pool_tid, pool_kb]
        if np.any(keys_pool < 0):
            # only the (tid, kind) pairs actually pooled need seeds: an
            # enqueue-only phase must not re-harvest forever for the
            # dequeue seeds it will never use
            self._harvest_seeds()
            keys_pool = self._seed[pool_tid, pool_kb]
        if prof is not None:
            prof.pop()
        snap = None
        force_trunc = (self.force_mispredict_every
                       and self.n_bursts % self.force_mispredict_every == 0)
        force_reject = (self.force_reject_every
                        and self.n_bursts % self.force_reject_every == 0)
        try:
            for it in range(self.max_iters):
                if prof is not None:
                    prof.push("burst-predict")
                plan = self._predict(pool_kb, pool_tid, keys_pool,
                                     seg_len_a, seg_t0_a, cursors,
                                     from_seed=(it == 0))
                if prof is not None:
                    prof.pop()
                if plan is None:
                    break
                order_idx, g_tid, g_kb, g_start, g_end, N = plan
                if N < self.min_ops:
                    break
                if snap is None:
                    snap = self._snapshot()
                if prof is not None:
                    prof.push("burst-verify")
                state = self._plan_and_classify(g_tid, g_kb, order_idx,
                                                it_pool, N)
                if state is None:
                    if prof is not None:
                        prof.pop()
                    self._restore(snap)
                    break
                autokeys = state["keys"]
                predicted = keys_pool[order_idx[:N]]
                mis = np.nonzero(autokeys != predicted)[0]
                bad = int(mis[0]) if mis.size else -1
                if prof is not None:
                    prof.pop()
                if force_trunc:
                    bad = 0
                if bad < 0:
                    if force_reject:
                        self._restore(snap)
                        self.n_rejects += 1
                        return 0
                    return self._commit(heap, cursors, state, g_tid, g_kb,
                                        g_start, g_end, N, autokeys,
                                        fixed_last=False)
                # mispredict: discard the speculative allocator state
                self._restore(snap)
                self.n_mispredicts += 1
                if it < self.max_iters - 1 and not force_trunc:
                    # learn the observed keys, re-predict the interleave
                    keys_pool[order_idx[:N]] = autokeys
                    continue
                # truncate at the first disagreeing grant and commit the
                # verified prefix, that grant's clock fixed to its true
                # duration
                N2 = bad + 1
                if prof is not None:
                    prof.push("burst-verify")
                state2 = self._plan_and_classify(g_tid, g_kb, order_idx,
                                                 it_pool, N2)
                ok = state2 is not None and np.array_equal(
                    state2["keys"][:bad], predicted[:bad])
                if prof is not None:
                    prof.pop()
                if not ok:
                    if state2 is not None:
                        self._restore(snap)
                    self.n_rejects += 1
                    return 0
                self.n_truncations += 1
                return self._commit(heap, cursors, state2, g_tid, g_kb,
                                    g_start, g_end, N2, state2["keys"],
                                    fixed_last=True)
        except Exception:
            if snap is not None:
                self._restore(snap)
            raise
        self.n_rejects += 1
        return 0

    def _vscratch(self, n: int) -> np.ndarray:
        s = self._vscr
        if s is None or s.size < n:
            s = self._vscr = np.empty(n, np.int64)
        return s

    # -- prediction -------------------------------------------------------
    def _dur_key_vec(self, kind: str, keys_arr) -> np.ndarray:
        op = self.fast.ops[kind]
        tc = op._tcache
        uk, inv = np.unique(keys_arr, return_inverse=True)
        ud = np.empty(uk.size, np.float64)
        for j, k in enumerate(uk.tolist()):
            if k < 0:
                ud[j] = 1.0         # unseeded: placeholder pace (iter 1)
                continue
            d = tc.get(k)
            if d is None:
                d = op.time_for_key(k, self._ns_vec)
            ud[j] = d
        return ud[inv]

    def _durations(self, kb_arr, keys_arr, tid_arr=None) -> np.ndarray:
        if tid_arr is not None:
            # keys straight from the per-(tid, kind) seed table: one
            # duration per table cell, gathered per op
            dtab = np.empty(self._seed.shape, np.float64)
            for kbit, kind in ((0, "enq"), (1, "deq")):
                dtab[:, kbit] = self._dur_key_vec(kind,
                                                  self._seed[:, kbit])
            return dtab[tid_arr, kb_arr]
        dur = np.empty(kb_arr.size, np.float64)
        for kbit, kind in ((0, "enq"), (1, "deq")):
            sel = np.nonzero(kb_arr == kbit)[0]
            if sel.size:
                dur[sel] = self._dur_key_vec(kind, keys_arr[sel])
        return dur

    def _predict(self, pool_kb, pool_tid, keys_pool, seg_len_a, seg_t0_a,
                 cursors, from_seed: bool = False):
        """Durations from predicted keys -> per-thread clock windows ->
        global grant order -> hazard truncation.  Pure numpy."""
        dur = self._durations(pool_kb, keys_pool,
                              pool_tid if from_seed else None)
        seg_start = np.concatenate(([0], np.cumsum(seg_len_a)[:-1]))
        first_tid = pool_tid[seg_start]
        lens_a = np.array([self.lens[t] for t in first_tid], np.int64)
        cur_a = np.array([cursors[t] for t in first_tid], np.int64)
        more = (lens_a - cur_a) > seg_len_a
        order, g_tid, g_start, g_end, N = predict_grants(
            dur, seg_len_a, seg_t0_a, pool_tid, more)
        g_kb = pool_kb[order]
        if N == 0:
            return None
        # empty-dequeue hazard: truncate before the first dequeue that
        # would find the FIFO empty (the columnar runner bails there)
        d0 = len(self.fifo)
        isq = g_kb[:N] == 1
        eb = np.cumsum(~isq) - ~isq
        jseq = np.cumsum(isq) - isq
        hzi = np.nonzero(isq & (jseq >= d0 + eb))[0]
        if hzi.size:
            N = int(hzi[0])
            if N == 0:
                return None
        # allocator-exhaustion hazard: conservatively require that each
        # thread's enqueue demand fits its current free list + area/chunk
        # headroom (epoch advances only ever ADD supply), so the planner
        # never needs a mid-burst _new_area / chunk carve
        N = self._alloc_cut(g_tid, g_kb, N)
        if N == 0:
            return None
        return order, g_tid, g_kb, g_start, g_end, N

    def _alloc_cut(self, g_tid, g_kb, N: int) -> int:
        prog = self.prog
        if not (prog.allocs_p or prog.allocs_v):
            return N
        enq_sel = g_kb[:N] == 0
        if not enq_sel.any():
            return N
        demand = np.bincount(g_tid[:N][enq_sel],
                             minlength=self.nv.nthreads)
        mem, valloc = self.mem, self.valloc
        for t in np.nonzero(demand)[0].tolist():
            need = int(demand[t])
            sups = []
            if prog.allocs_p:
                sup = len(mem._free[t])
                if mem._areas[t]:
                    sup += mem.area_nodes - mem._cursor[t]
                sups.append(sup)
            if prog.allocs_v:
                sup = len(valloc._free[t])
                if valloc._base[t] is not None:
                    sup += valloc.chunk_nodes - valloc._cursor[t]
                sups.append(sup)
            sup = min(sups)
            if need > sup:
                pos = np.nonzero((g_tid[:N] == t) & (g_kb[:N] == 0))[0]
                if pos.size > sup:
                    N = int(pos[sup])
        return N

    # -- vectorized planner (pure-enqueue bursts) -------------------------
    def _vector_plan(self, tidN, N: int, badv: int, e_np, e_nv,
                     arrs_e) -> bool:
        """Plan an all-enqueue burst without the per-grant loop.

        When every participating thread's free list is empty (and, with
        epochs in play, no limbo entry exists anywhere) the sequential
        planner reduces to per-thread cursor bumps -- a stable sort by
        tid plus a within-thread ordinal -- and the epoch walk to one
        advance test per 64-op boundary.  Mutates the allocator state
        exactly as the generated planner would; returns False leaving
        it untouched (the caller then runs the sequential planner)."""
        prog = self.prog
        mem, valloc = self.mem, self.valloc
        counts = np.bincount(tidN, minlength=self.nv.nthreads)
        active = np.nonzero(counts)[0]
        act_l = active.tolist()
        if (prog.allocs_p or prog.uses_ssmem) and \
                any(map(bool, mem._limbo.values())):
            return False
        if prog.allocs_p:
            free, areas = mem._free, mem._areas
            if any(free[t] or not areas[t] for t in act_l):
                return False
        if prog.allocs_v:
            vfree, vbase = valloc._free, valloc._base
            if any(vfree[t] or vbase[t] is None for t in act_l):
                return False
        order = np.argsort(tidN, kind="stable")
        cnt_a = counts[active]
        starts = np.concatenate(([0], np.cumsum(cnt_a)[:-1]))
        within = np.arange(N, dtype=np.int64) - np.repeat(starts, cnt_a)
        if prog.allocs_p:
            cur = mem._cursor
            base = np.fromiter(
                (areas[t][-1] + cur[t] * LINE_WORDS for t in act_l),
                np.int64, active.size)
            vals = np.repeat(base, cnt_a) + LINE_WORDS * within
            out = np.empty(N, np.int64)
            out[order] = vals
            arrs_e["new_p"] = out
            e_np.extend(out.tolist())
            for i, t in enumerate(act_l):
                cur[t] += int(cnt_a[i])
        if prog.allocs_v:
            nw = valloc.node_words
            vcur = valloc._cursor
            base = np.fromiter(
                (vbase[t] + vcur[t] * nw for t in act_l),
                np.int64, active.size)
            vals = np.repeat(base, cnt_a) + nw * within
            out = np.empty(N, np.int64)
            out[order] = vals
            arrs_e["new_v"] = out
            e_nv.extend(out.tolist())
            for i, t in enumerate(act_l):
                vcur[t] += int(cnt_a[i])
        if prog.uses_ssmem:
            # each grant announces the epoch current at its turn; the
            # boundary grant announces first, then tests the advance.
            # With no limbo anywhere _try_advance is only the test.
            ann = mem._announced
            nt = mem.nthreads
            ann_arr = np.fromiter(ann.values(), np.int64, nt)
            ep = mem._epoch
            prev, b = 0, badv
            while b < N:
                ann_arr[tidN[prev:b + 1]] = ep
                if int(ann_arr.min()) >= ep:
                    ep += 1
                prev = b + 1
                b += 64
            if prev < N:
                ann_arr[tidN[prev:N]] = ep
            mem._epoch = ep
            ann.update(enumerate(ann_arr.tolist()))
        return True

    # -- plan + classify --------------------------------------------------
    def _plan_and_classify(self, g_tid, g_kb, order_idx, it_pool,
                           N: int) -> Optional[dict]:
        prog = self.prog
        mem = self.mem
        fifo = self.fifo
        kbN = g_kb[:N]
        tidN = g_tid[:N]
        sel_e = np.nonzero(kbN == 0)[0]
        sel_d = np.nonzero(kbN == 1)[0]
        ne = int(sel_e.size)
        nd = int(sel_d.size)
        d0 = len(fifo)
        # current tail/head records (the columnar fns' _t / dbox[0])
        trec = fifo[-1] if fifo else self.dbox[0]
        drec = self.dbox[0]
        t0_p, t0_v, t0_idx = trec[0], trec[1], (trec[3] or 0)
        exist = list(islice(fifo, min(nd, d0)))
        exist_p = [r[0] for r in exist]
        exist_v = [r[1] for r in exist]
        exist_it = [r[2] for r in exist]
        exist_ix = [r[3] for r in exist]
        # epoch-advance boundary (grant whose op_begin advances)
        badv = N + 1
        if prog.uses_ssmem:
            badv = 63 - mem._ops_since_adv
        e_np: List[int] = []
        e_nv: List[Any] = []
        cols_e: Dict[str, list] = {}
        cols_d: Dict[str, list] = {}
        arrs_e: Dict[str, np.ndarray] = {}
        arrs_d: Dict[str, np.ndarray] = {}
        kb_l = tid_l = None     # lazily materialized (sequential paths)
        planned = nd == 0 and prog.vplan and \
            self._vector_plan(tidN, N, badv, e_np, e_nv, arrs_e)
        if planned:
            self.n_vec_plans += 1
        else:
            kb_l = kbN.tolist()
            tid_l = tidN.tolist()
            prog.plan_fn(N, kb_l, tid_l, d0, exist_p, exist_v,
                         drec[0], drec[1], t0_p, t0_v, badv, e_np, e_nv)
        if prog.uses_ssmem:
            # counter after N check-then-increment steps from its
            # pre-burst value (reset at each boundary grant)
            if badv >= N:
                mem._ops_since_adv += N
            else:
                last_b = badv + 64 * ((N - 1 - badv) // 64)
                mem._ops_since_adv = N - 1 - last_b
        if not prog.allocs_p:
            e_np = [0] * ne
        if not prog.allocs_v:
            e_nv = [None] * ne
        # ---- vectorized node-local columns -----------------------------
        need_e = prog.need_syms["enq"]
        need_d = prog.need_syms["deq"]

        def _col_e(name: str, lst: list) -> None:
            cols_e[name] = lst
            if name not in arrs_e:     # the vector planner pre-stashes
                arrs_e[name] = np.fromiter(lst, np.int64, ne) if ne else \
                    np.empty(0, np.int64)

        def _col_d(name: str, lst: list) -> None:
            cols_d[name] = lst
            arrs_d[name] = np.fromiter(lst, np.int64, nd) if nd else \
                np.empty(0, np.int64)

        if "new_p" in need_e:
            _col_e("new_p", e_np)
        if "new_v" in need_e:
            _col_e("new_v", e_nv)
        if "tail_p" in need_e:
            a = arrs_e.get("new_p")
            if a is not None and ne:
                t = np.empty(ne, np.int64)
                t[0] = t0_p
                t[1:] = a[:-1]
                arrs_e["tail_p"] = t
            _col_e("tail_p", [t0_p] + e_np[:-1])
        if "tail_v" in need_e:
            a = arrs_e.get("new_v")
            if a is not None and ne:
                t = np.empty(ne, np.int64)
                t[0] = t0_v
                t[1:] = a[:-1]
                arrs_e["tail_v"] = t
            _col_e("tail_v", [t0_v] + e_nv[:-1])
        e_idx = list(range(t0_idx + 1, t0_idx + 1 + ne))
        # consumed-record chains (source enqueues always precede their
        # dequeue in grant order -- guaranteed by the hazard cut)
        if nd:
            cat_p = exist_p + e_np
            cat_v = exist_v + e_nv
            d_idx = (exist_ix + e_idx)[:nd]
        else:
            cat_p = cat_v = []
            d_idx = []
        if "next_p" in need_d:
            _col_d("next_p", cat_p[:nd])
        if "head_p" in need_d:
            _col_d("head_p", ([drec[0]] + cat_p[:nd - 1]) if nd else [])
        if "next_v" in need_d:
            _col_d("next_v", cat_v[:nd])
        if "head_v" in need_d:
            _col_d("head_v", ([drec[1]] + cat_v[:nd - 1]) if nd else [])
        # items in grant order; dequeue results from the consumed chain
        items_o = it_pool[order_idx[:N]]
        if nd:
            e_items = items_o[sel_e]
            d_items = items_o[sel_d]
            d_res = (exist_it + e_items.tolist())[:nd]
        else:
            e_items = items_o
            d_items = items_o[:0]
            d_res = []
        keys = self._classify(tidN, sel_e, sel_d, arrs_e, arrs_d)
        if keys is None:
            return None
        autokeys, p_fin, v_idx = keys
        return {"keys": autokeys, "p_fin": p_fin, "v_idx": v_idx,
                "sel_e": sel_e, "sel_d": sel_d, "ne": ne, "nd": nd,
                "e_np": e_np, "e_nv": e_nv, "e_idx": e_idx,
                "d_idx": d_idx, "cols_e": cols_e, "cols_d": cols_d,
                "arrs_e": arrs_e, "arrs_d": arrs_d,
                "cons_p": cat_p[:nd] + [t0_p],
                "cons_v": cat_v[:nd] + [t0_v],
                "e_items": e_items, "d_items": d_items, "d_res": d_res,
                "kb_l": kb_l, "tid_l": tid_l}

    def _classify(self, tidN, sel_e, sel_d, arrs_e, arrs_d):
        prog = self.prog
        nv = self.nv
        N = tidN.size
        maxr = prog.max_rows
        span = N * maxr + 1
        p_lines, p_seq, p_c, p_b = [], [], [], []
        p_chunks = []
        v_vis, v_seq = [], []
        v_chunks = []
        off_p = off_v = 0
        for kind, sel, arrs in (("enq", sel_e, arrs_e),
                                ("deq", sel_d, arrs_d)):
            kt = prog.kts[kind]
            ng = sel.size
            if ng == 0:
                continue
            tids_k = tidN[sel]
            R = kt.p_amode.size
            if R:
                A = np.empty((ng, R), np.int64)
                for r in range(R):
                    am = kt.p_amode[r]
                    if am == 0:
                        A[:, r] = kt.p_const[r] // LINE_WORDS
                    elif am == 1:
                        A[:, r] = (arrs[kt.p_sym[r]] + kt.p_off[r]) \
                            // LINE_WORDS
                    else:
                        A[:, r] = (kt.p_const[r]
                                   + tids_k * LINE_WORDS) // LINE_WORDS
                seq = sel[:, None] * maxr + kt.p_pos[None, :]
                p_lines.append(A.ravel())
                p_seq.append(seq.ravel())
                p_c.append(np.broadcast_to(kt.p_c, (ng, R)).ravel())
                p_b.append(np.broadcast_to(kt.p_b, (ng, R)).ravel())
                p_chunks.append((kind, sel, ng, R, off_p))
                off_p += ng * R
            Rv = kt.v_amode.size
            if Rv:
                V = np.empty((ng, Rv), np.int64)
                for r in range(Rv):
                    am = kt.v_amode[r]
                    if am == 0:
                        V[:, r] = kt.v_const[r] + kt.v_off[r]
                    elif am == 1:
                        V[:, r] = arrs[kt.v_sym[r]] + kt.v_off[r] - _VB
                    else:
                        V[:, r] = kt.v_const[r] + tids_k * LINE_WORDS
                # V.ravel() is already seq-ordered (grants ascending,
                # rows in program order); seq is only materialized when
                # two kinds must be merged
                v_vis.append(V.ravel())
                v_seq.append((sel, kt.v_pos))
                v_chunks.append((kind, sel, ng, Rv, off_v))
                off_v += ng * Rv
        keys = np.zeros(N, np.int64)
        p_fin = None
        v_idx = None
        if off_p:
            lv = np.frombuffer(nv._lstate, dtype=np.uint8)
            res = _p_automaton(lv, np.concatenate(p_lines),
                               np.concatenate(p_seq),
                               np.concatenate(p_c),
                               np.concatenate(p_b), span)
            if res is None:
                return None
            out_p, glines, gfin = res
            p_fin = (glines, gfin)
            for kind, sel, ng, R, off in p_chunks:
                kt = prog.kts[kind]
                o2 = out_p[off:off + ng * R]
                contrib = np.zeros(ng, np.int64)
                for r in np.nonzero(kt.p_touch)[0].tolist():
                    contrib += o2[r::R] << kt.p_shift[r]
                keys[sel] += contrib
        if off_v:
            vtv = np.frombuffer(nv._vtouched, dtype=np.uint8)
            if len(v_vis) == 1:
                vis_all = v_vis[0]
                out_v = _v_automaton(vtv, vis_all, None, span,
                                     scratch=self._vscratch(vtv.size))
            else:
                vis_all = np.concatenate(v_vis)
                seq_all = np.concatenate(
                    [(s[:, None] * maxr + pos[None, :]).ravel()
                     for s, pos in v_seq])
                out_v = _v_automaton(vtv, vis_all, seq_all, span)
            v_idx = vis_all
            for kind, sel, ng, Rv, off in v_chunks:
                kt = prog.kts[kind]
                o2 = out_v[off:off + ng * Rv]
                contrib = np.zeros(ng, np.int64)
                for r in range(Rv):
                    contrib += o2[r::Rv] << kt.v_shift[r]
                keys[sel] += contrib
        return keys, p_fin, v_idx

    # -- row-batched value application ------------------------------------
    def _vec_hazards(self, state, tidN) -> bool:
        """Per-burst dynamic safety of the row-batched apply."""
        vap = self.prog.vap
        ne, nd = state["ne"], state["nd"]
        # freshness: no node address both consumed/free and allocated
        # inside the burst (row batching would misorder their writes)
        for check, col, ecol, cons in (
                (vap.check_p, "new_p", "e_np", "cons_p"),
                (vap.check_v, "new_v", "e_nv", "cons_v")):
            if not (check and ne):
                continue
            a = state["arrs_e"].get(col)
            if a is None:
                a = np.fromiter(state[ecol], np.int64, ne)
            u = np.unique(a)
            if u.size != a.size:
                return False
            if np.isin(np.asarray(state[cons], np.int64), u).any():
                return False
        # drains must only meet clean lines: none already dirty, none
        # this burst appends to
        if (ne and vap.drains["enq"]) or (nd and vap.drains["deq"]):
            hazard = {ln for ln, lst in self.nv._log.items() if lst}
            hazard |= vap.logls
            if hazard:
                hz = np.fromiter(hazard, np.int64, len(hazard))
                arrs_all = {"enq": state["arrs_e"], "deq": state["arrs_d"]}
                for kind, sel in (("enq", state["sel_e"]),
                                  ("deq", state["sel_d"])):
                    if not sel.size:
                        continue
                    arrs = arrs_all[kind]
                    tids_k = None
                    for am, sym, off, const in vap.drains[kind]:
                        if am == 0:
                            if const // LINE_WORDS in hazard:
                                return False
                            continue
                        if am == 1:
                            lines = (arrs[sym] + off) // LINE_WORDS
                        else:
                            if tids_k is None:
                                tids_k = tidN[sel]
                            lines = (const + tids_k * LINE_WORDS) \
                                // LINE_WORDS
                        if np.isin(lines, hz).any():
                            return False
        return True

    def _apply_vector(self, state, tidN) -> bool:
        """Apply the burst's value stores row-batched (see the module
        section above); False falls back to the per-grant loop."""
        if not self._vec_hazards(state, tidN):
            return False
        vap = self.prog.vap
        nv = self.nv
        vis, pmem, vval = nv._vis, nv._pmem, nv._vval
        log, ls_obj = nv._log, nv._log_start
        ls_lines, ls_tots, ls_scalar = [], [], {}
        for kind in ("enq", "deq"):
            if kind == "enq":
                sel, arrs, cols = state["sel_e"], state["arrs_e"], \
                    state["cols_e"]
                items_arr, idx_list = state["e_items"], state["e_idx"]
            else:
                sel, arrs, cols = state["sel_d"], state["arrs_d"], \
                    state["cols_d"]
                items_arr, idx_list = state["d_items"], state["d_idx"]
            n_k = int(sel.size)
            if not n_k or not vap.streams[kind]:
                continue
            tids_k = None
            items_list = state.get("e_items_l") if kind == "enq" else None
            obj_cache: dict = {}
            tl = [None]        # lazily computed (ut, lastpos)

            def _list_vals(vt, vp):
                nonlocal items_list
                if vt == "c":
                    return repeat(vp)
                if vt == "item":
                    if items_list is None:
                        items_list = items_arr.tolist()
                    return items_list
                return idx_list if vt == "idx" else cols[vp]

            def _obj_col(vt, vp):
                col = obj_cache.get((vt, vp))
                if col is None:
                    if vt == "item":
                        col = items_arr
                    else:
                        col = np.empty(n_k, dtype=object)
                        if vt == "c":
                            col.fill(vp)
                        else:
                            col[:] = idx_list if vt == "idx" else cols[vp]
                    obj_cache[(vt, vp)] = col
                return col

            def _last_val(vt, vp):
                if vt == "c":
                    return vp
                if vt == "item":
                    return items_arr[-1]
                return (idx_list if vt == "idx" else cols[vp])[-1]

            def _tid_last():
                nonlocal tids_k
                if tl[0] is None:
                    if tids_k is None:
                        tids_k = tidN[sel]
                    ut, rti = np.unique(tids_k[::-1], return_index=True)
                    tl[0] = (ut, n_k - 1 - rti)
                return tl[0]

            for target, am, sym, off, const, vt, vp in vap.streams[kind]:
                if target == "ls":
                    if am == 0:
                        ln = const // LINE_WORDS
                        ls_scalar[ln] = ls_scalar.get(ln, 0) + vp * n_k
                    else:
                        if am == 1:
                            lines = (arrs[sym] + off) // LINE_WORDS
                        else:
                            if tids_k is None:
                                tids_k = tidN[sel]
                            lines = (const + tids_k * LINE_WORDS) \
                                // LINE_WORDS
                        ls_lines.append(lines)
                        ls_tots.append(np.full(n_k, vp, np.int64))
                elif target == "logext":
                    ln = const // LINE_WORDS
                    if vt == "c":
                        ents = [(const, vp)] * n_k
                    else:
                        ents = list(zip(repeat(const), _list_vals(vt, vp)))
                    lg = log.get(ln)
                    if lg is None:
                        log[ln] = ents
                    else:
                        lg.extend(ents)
                elif target == "vval":
                    if am == 0:
                        vval[const - _VB] = _last_val(vt, vp)
                    elif am == 1:
                        vval[arrs[sym] + off - _VB] = _obj_col(vt, vp)
                    else:
                        ut, lastpos = _tid_last()
                        vval[const + ut * LINE_WORDS - _VB] = \
                            _obj_col(vt, vp)[lastpos]
                else:
                    plane = vis if target == "vis" else pmem
                    if am == 0:
                        plane[const] = _last_val(vt, vp)
                    elif am == 1:
                        _consume(map(plane.__setitem__,
                                     (arrs[sym] + off).tolist(),
                                     _list_vals(vt, vp)))
                    else:
                        ut, lastpos = _tid_last()
                        _consume(map(
                            plane.__setitem__,
                            (const + ut * LINE_WORDS).tolist(),
                            _obj_col(vt, vp)[lastpos].tolist()))
        for ln, add in ls_scalar.items():
            ls_obj[ln] += add
        if ls_lines:
            lines = np.concatenate(ls_lines)
            u, inv = np.unique(lines, return_inverse=True)
            sums = np.bincount(inv, weights=np.concatenate(ls_tots))
            ul = u.tolist()
            new = (np.fromiter(map(ls_obj.__getitem__, ul),
                               np.int64, u.size)
                   + sums.astype(np.int64)).tolist()
            _consume(map(ls_obj.__setitem__, ul, new))
        return True

    # -- commit -----------------------------------------------------------
    def _commit(self, heap, cursors, state, g_tid, g_kb, g_start, g_end,
                N: int, autokeys, fixed_last: bool) -> int:
        prof = self.profile
        if prof is not None:
            prof.push("burst-vector-apply")
        try:
            return self._commit_inner(heap, cursors, state, g_tid, g_kb,
                                      g_start, g_end, N, autokeys,
                                      fixed_last)
        finally:
            if prof is not None:
                prof.pop()

    def _commit_inner(self, heap, cursors, state, g_tid, g_kb, g_start,
                      g_end, N: int, autokeys, fixed_last: bool) -> int:
        nv = self.nv
        prog = self.prog
        fifo = self.fifo
        tidN = g_tid[:N]
        kbN = g_kb[:N]
        ends = g_end[:N]
        if fixed_last:
            kind = "deq" if int(kbN[N - 1]) else "enq"
            op = self.fast.ops[kind]
            k = int(autokeys[N - 1])
            d = op._tcache.get(k)
            if d is None:
                d = op.time_for_key(k, self._ns_vec)
            ends = ends.copy()
            ends[N - 1] = g_start[N - 1] + d
        sel_e, sel_d = state["sel_e"], state["sel_d"]
        ne, nd = state["ne"], state["nd"]
        # staged record rows (materialized + charged at the next sync)
        metas = (autokeys << META_KEY_SHIFT) | (tidN << 1) | kbN
        e_items_l = state["e_items"].tolist()
        state["e_items_l"] = e_items_l
        if nd:
            si = np.empty(N, dtype=object)
            si[sel_e] = state["e_items"]
            dr = np.empty(nd, dtype=object)
            dr[:] = state["d_res"]
            si[sel_d] = dr
        else:
            si = state["e_items"]
        self.rs.extend_staged(metas.tobytes(), si, ends.tobytes())
        # value stores: row-batched when the static program and this
        # burst's hazards allow it, else the sequential per-grant loop
        if prog.vap is not None and self._apply_vector(state, tidN):
            self.n_vec_applies += 1
        else:
            cols = prog.cols
            kb_l = state["kb_l"]
            if kb_l is None:
                kb_l, state["tid_l"] = kbN.tolist(), tidN.tolist()
            args = [N, kb_l, state["tid_l"], e_items_l, state["e_idx"]]
            args += [state["cols_e"][s] for s in cols["enq"]]
            args += [state["d_items"].tolist(), state["d_idx"]]
            args += [state["cols_d"][s] for s in cols["deq"]]
            prog.apply_fn(*args)
        # line-state / volatile-touch finals, one scatter each
        if state["p_fin"] is not None:
            glines, gfin = state["p_fin"]
            lv = np.frombuffer(nv._lstate, dtype=np.uint8)
            lv[glines] = gfin.astype(np.uint8)
        if state["v_idx"] is not None:
            vtv = np.frombuffer(nv._vtouched, dtype=np.uint8)
            vtv[state["v_idx"]] = 1
        # FIFO splice: append the burst's records, consume nd from the
        # left, the last consumed record becomes the dummy
        if ne:
            fifo.extend(zip(state["e_np"], state["e_nv"],
                            e_items_l, state["e_idx"]))
        if nd:
            popleft = fifo.popleft
            for _ in range(nd):
                last = popleft()
            self.dbox[0] = last
        # cursors + per-(tid, kind) seeds + heap rebuild
        counts = np.bincount(tidN, minlength=self.nv.nthreads)
        combo = tidN * 2 + kbN
        uniq, ridx = np.unique(combo[::-1], return_index=True)
        lastpos = N - 1 - ridx
        self._seed[uniq // 2, uniq % 2] = autokeys[lastpos]
        rev_t = tidN[::-1]
        ut, rti = np.unique(rev_t, return_index=True)
        last_end = dict(zip(ut.tolist(), ends[N - 1 - rti].tolist()))
        lens = self.lens
        newheap = []
        for t0, t in heap:
            k = int(counts[t])
            if k:
                c = cursors[t] + k
                cursors[t] = c
                if c < lens[t]:
                    newheap.append((last_end[t], t))
            else:
                newheap.append((t0, t))
        heap[:] = newheap
        heapq.heapify(heap)
        self.n_commits += 1
        self.ops_bursted += N
        return N
