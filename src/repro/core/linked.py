"""LinkedQ -- first amendment, design #2 (paper §5.2).

Also one blocking fence per operation, via a completely different scheme:

* nodes carry an ``initialized`` validity flag; enqueue writes content first,
  flag second (same line, so Assumption 1 orders them in NVRAM without a
  fence); recovery trusts a node only if the flag is set in NVRAM;
* the flag must be *clear in NVRAM* before a node is reused.  Instead of an
  extra fence at allocation, a dequeuer clears the flag of its previously
  retired node and **piggybacks** the flag's flush on the fence its next
  successful dequeue performs anyway, returning the node to ssmem only after
  that fence;
* a backward ``pred`` link lets an enqueuer persist exactly the chain suffix
  that might not be durable yet: walk back flushing nodes until a node known
  persisted (volatile hint set), then issue the single fence;
* recovery walks the persisted ``next`` chain from the persisted head while
  ``initialized`` is set.
"""
from __future__ import annotations

from typing import Any, Set

from .nvram import LINE_WORDS, NVRAM
from .opsched import (AllocP, Cas, Fence, FifoLayout, Flush, L, OpSchedule,
                      PersistedAdd, PersistedDiscard, QueueSchedules, Read,
                      Retire, SlotSet, Write, WriteLine)
from .queue_base import NULL, QueueAlgorithm, alloc_root_lines
from .ssmem import SSMem

# persistent node layout (one cache line)
ITEM, NEXT, INIT, PRED = 0, 1, 2, 3


class LinkedQueue(QueueAlgorithm):
    NAME = "LinkedQ"

    def __init__(self, nvram: NVRAM, mem: SSMem, nthreads: int, on_event=None,
                 _recovering: bool = False, roots=None):
        super().__init__(nvram, mem, nthreads, on_event)
        nv = self.nvram
        if roots is None:
            roots = alloc_root_lines(nv, 2, "linkedq:roots")
        self.HEAD, self.TAIL = roots
        self.roots = roots
        # volatile helper state
        self._persisted: Set[int] = set()    # nodes known durable (hint)
        self._to_flush = [NULL] * nthreads   # flag cleared, flush pending
        if not _recovering:
            dummy = self.mem.alloc(0)
            nv.write_full_line(dummy, [None, NULL, 0, NULL, 0, 0, 0, 0])
            nv.write(self.HEAD, dummy)
            nv.write(self.TAIL, dummy)
            self.pflush(dummy)
            self.pflush(self.HEAD)
            self.pfence()
            self._persisted.add(dummy)

    # ---------------------------------------- steady-state schedule facts
    # Retries issue no flushes, so no new invalidations: the lines the
    # backward walk flushed are re-fetched once in the base accounting
    # and retries re-read them as hits (exact-scheduler flushed-access
    # totals stay flat).  LinkedQ's post-flush cost lives in the walk
    # itself, not in the CAS loop.
    RETRY_SHAPES = {
        "enq": dict(reads=2),
        "deq": dict(reads=4),
    }

    def op_schedule(self):
        """Steady state (§5.2): one fence per op.  The enqueue's backward
        walk covers exactly the new node plus the (already-durable) tail --
        a longer not-yet-durable suffix means a pending enqueue is still in
        flight, which op-granularity execution excludes; the
        ``tail_persisted`` guard bails to real execution otherwise.  The
        dequeue piggybacks the previously-retired node's flag flush on its
        own fence (``_to_flush`` slot; NULL on a thread's first dequeue --
        warmup bails)."""
        enq = OpSchedule("enq", steps=(
            AllocP(),
            PersistedDiscard("new_p"),      # recycled addr no longer durable
            WriteLine(L("new_p"), (None, NULL, 0, NULL, 0, 0, 0, 0),
                      item_at=0),
            Read(L("TAIL")),
            Read(L("tail_p", NEXT)),
            Write(L("new_p", PRED), ("sym", "tail_p")),
            Write(L("new_p", INIT), ("c", 1)),     # after content: Asm. 1
            Cas(L("tail_p", NEXT), ("sym", "new_p"), event="enq"),
            # backward-walk persist: the suffix [new node, durable tail]
            Read(L("new_p", PRED)),
            Flush(L("new_p")),
            Read(L("tail_p", PRED)),
            Flush(L("tail_p")),
            Fence(),                               # the ONE fence
            PersistedAdd("new_p", "tail_p"),
            Cas(L("TAIL"), ("sym", "new_p"), root=True),
        ), guards=(("tail_persisted",),), retry_from=3)
        deq = OpSchedule("deq", steps=(
            Read(L("HEAD")),
            Read(L("head_p", NEXT)),
            Read(L("TAIL")),                       # MSQ guard
            Read(L("next_p", ITEM)),
            Cas(L("HEAD"), ("sym", "next_p"), root=True, event="deq"),
            # piggyback protocol: clear the current head's flag now, flush
            # the previously retired node, one fence covers both
            Write(L("head_p", INIT), ("c", 0)),
            Flush(L("prev")),
            Flush(L("HEAD")),
            Fence(),                               # the ONE fence
            Retire(("sym", "prev")),
            SlotSet("_to_flush", ("sym", "head_p")),
        ), guards=(("slot_nonnull", "_to_flush"),))
        return QueueSchedules(enq=enq, deq=deq, layout=FifoLayout(
            head_root="HEAD", next_off=NEXT, item_off=ITEM))

    # --------------------------------------------------------------- enqueue
    def enqueue(self, tid: int, item: Any) -> None:
        nv = self.nvram
        self.mem.op_begin(tid)
        node = self.mem.alloc(tid)
        # a recycled address is no longer durable in its new incarnation;
        # evicting here (not at retire) keeps every non-persisted node on a
        # pred chain part of a *pending* enqueue, bounding backward walks.
        self._persisted.discard(node)
        # content first; `initialized` is set only after item/pred are written
        # (ssmem guarantees the flag is already clear in NVRAM on reuse).
        nv.write_full_line(node, [item, NULL, 0, NULL, 0, 0, 0, 0])
        while True:
            tail = nv.read(self.TAIL)
            if nv.read(tail + NEXT) == NULL:
                nv.write(node + PRED, tail)
                nv.write(node + INIT, 1)          # after content: Assumption 1
                if nv.cas(tail + NEXT, NULL, node):
                    self._ev("enq", item)
                    # Backward-walk persist: flush the not-yet-durable suffix
                    # INCLUDING the first durable node -- its line holds the
                    # next-pointer onto the suffix, which recovery follows.
                    # (Reads of pred on flushed lines are LinkedQ's post-flush
                    # cost, measured and eliminated by the 2nd amendment.)
                    walked = []
                    p = node
                    while True:
                        pred = nv.read(p + PRED)
                        self.pflush(p)
                        walked.append(p)
                        if p in self._persisted or pred == NULL:
                            break
                        p = pred
                    self.pfence()                     # the ONE fence
                    self._persisted.update(walked)
                    nv.cas(self.TAIL, tail, node)
                    return
            else:
                nv.cas(self.TAIL, tail, nv.read(tail + NEXT))

    # --------------------------------------------------------------- dequeue
    def dequeue(self, tid: int) -> Any:
        nv = self.nvram
        self.mem.op_begin(tid)
        while True:
            head = nv.read(self.HEAD)
            nxt = nv.read(head + NEXT)
            if nxt == NULL:
                self.pflush(self.HEAD)
                self.pfence()
                self._ev("empty")
                return None
            # MSQ guard: head must not overtake tail (reclamation safety)
            tail = nv.read(self.TAIL)
            if head == tail:
                nv.cas(self.TAIL, tail, nxt)
                continue
            item = nv.read(nxt + ITEM)
            if nv.cas(self.HEAD, head, nxt):
                self._ev("deq", item)
                # piggyback protocol (§5.2): clear the *current* retired
                # node's flag now; flush the *previous* one and let this
                # operation's single fence cover both the head and that flush;
                # only then hand the previous node back to ssmem.
                nv.write(head + INIT, 0)
                prev = self._to_flush[tid]
                if prev != NULL:
                    self.pflush(prev)
                self.pflush(self.HEAD)
                self.pfence()                         # the ONE fence
                if prev != NULL:
                    self.mem.retire(tid, prev)
                self._to_flush[tid] = head
                return item

    # -------------------------------------------------------------- recovery
    @classmethod
    def recover(cls, nvram: NVRAM, mem: SSMem, nthreads: int, roots,
                on_event=None) -> "LinkedQueue":
        q = cls(nvram, mem, nthreads, on_event, _recovering=True, roots=roots)
        head = nvram.pread(q.HEAD) or NULL
        assert head != NULL
        # resurrect the path of consecutive initialized nodes from the head
        chain = [head]
        cur = head
        while True:
            nxt = nvram.pread(cur + NEXT) or NULL
            if nxt == NULL or not nvram.pread(nxt + INIT):
                break
            chain.append(nxt)
            cur = nxt
        nvram.pwrite(cur + NEXT, NULL)   # cut any stale suffix
        nvram.pwrite(q.TAIL, cur)
        nvram.pwrite(q.HEAD, head)
        chain_set = set(chain)
        for base, nnodes in mem.area_addrs():
            for i in range(nnodes):
                a = base + i * LINE_WORDS
                if a not in chain_set:
                    nvram.pwrite(a + INIT, 0)   # clear before reuse
                    mem.free_now(0, a)
        q._persisted.update(chain)
        nvram.reset_after_recovery()
        return q
