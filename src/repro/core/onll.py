"""ONLL with cache-line-aligned logs -- the paper's §2.1 upper bound.

Cohen et al.'s universal construction achieves the fence lower bound (one
per update); the paper's observation is that aligning each per-thread log
entry to its own cache line *also* achieves **zero post-flush accesses**,
proving the two optima compose for any object with a deterministic
sequential specification.

Components (faithful to §2.1):
* a shared **volatile execution trace** with a persistent-prefix marker
  (never flushed, not used by recovery);
* **per-thread persistent logs**; an update appends the trace suffix that is
  not yet marked persistent to its own log -- one record per cache line,
  full-line writes, flushed and fenced ONCE -- then advances the marker.
  Log lines are written once and never read again (recovery reads the
  persistent image directly), hence zero post-flush accesses.
* recovery: collect all log records from all threads, order by trace
  sequence number, deduplicate, replay into the object's sequential spec.

The object is pluggable: ``apply(state, op) -> (state', response)``.
"""
from __future__ import annotations

from typing import Any, Callable, List, Tuple

from .nvram import LINE_WORDS, NVRAM

LOG_LINES = 8192   # per-thread log capacity (records)


class ONLL:
    NAME = "ONLL"

    def __init__(self, nvram: NVRAM, nthreads: int,
                 apply_fn: Callable[[Any, Any], Tuple[Any, Any]],
                 init_state: Any, _recovering: bool = False, roots=None):
        self.nvram = nvram
        self.nthreads = nthreads
        self.apply_fn = apply_fn
        self.init_state = init_state
        nv = nvram
        if roots is None:
            roots = [nv.alloc_region(LOG_LINES * LINE_WORDS, f"onll:log:t{t}")
                     for t in range(nthreads)]
        self.logs = roots
        self.roots = roots
        self._log_pos = [0] * nthreads          # volatile cursors
        # volatile execution trace: list of (seq, op); marker = persisted len
        self.TRACE_LEN = nv.alloc_region(1, "onll:tracelen", persistent=False)
        self.MARKER = nv.alloc_region(1, "onll:marker", persistent=False)
        self._trace: List[Tuple[int, Any]] = []
        if not _recovering:
            nv.write(self.TRACE_LEN, 0)
            nv.write(self.MARKER, 0)

    # ------------------------------------------------------------------- ops
    def update(self, tid: int, op: Any) -> Any:
        nv = self.nvram
        # 1. append to the shared volatile trace (CAS-reserve a slot)
        while True:
            n = nv.read(self.TRACE_LEN)
            if nv.cas(self.TRACE_LEN, n, n + 1):
                seq = n
                self._trace.append((seq, op))   # python list: volatile body
                break
        # 2. copy the not-yet-persistent suffix into my log, one record per
        #    cache line (the paper's alignment amendment), flush each line
        marker = nv.read(self.MARKER)
        suffix = [e for e in self._trace if e[0] >= marker and e[0] <= seq]
        for (s, o) in suffix:
            line_addr = self.logs[tid] + self._log_pos[tid] * LINE_WORDS
            assert self._log_pos[tid] < LOG_LINES, "log full"
            nv.write_full_line(line_addr, [1, s, o, 0, 0, 0, 0, 0])
            if nv.model.needs_flush:
                nv.flush(line_addr)
            self._log_pos[tid] += 1
        nv.fence()                               # the ONE fence
        # 3. advance the persistent-prefix marker (volatile, monotone)
        while True:
            m = nv.read(self.MARKER)
            if m >= seq + 1 or nv.cas(self.MARKER, m, seq + 1):
                break
        # response from replaying the trace prefix (volatile computation)
        state = self.init_state
        resp = None
        for (s, o) in sorted(self._trace):
            state, r = self.apply_fn(state, o)
            if s == seq:
                resp = r
        return resp

    def read_state(self) -> Any:
        """Read-only operation: zero fences (the lower bound's read side)."""
        nv = self.nvram
        marker = nv.read(self.MARKER)
        state = self.init_state
        for (s, o) in sorted(self._trace):
            if s < marker:
                state, _ = self.apply_fn(state, o)
        return state

    # -------------------------------------------------------------- recovery
    @classmethod
    def recover(cls, nvram: NVRAM, nthreads: int, apply_fn, init_state,
                roots) -> Tuple["ONLL", Any]:
        obj = cls(nvram, nthreads, apply_fn, init_state,
                  _recovering=True, roots=roots)
        records = {}
        for t in range(nthreads):
            pos = 0
            for i in range(LOG_LINES):
                a = roots[t] + i * LINE_WORDS
                if not nvram.pread(a):          # valid-flag word
                    break
                seq, op = nvram.pread(a + 1), nvram.pread(a + 2)
                records[seq] = op
                pos = i + 1
            obj._log_pos[t] = pos                # append after old records
        state = init_state
        replayed = []
        for seq in sorted(records):
            if seq != len(replayed):
                break                            # stop at the first gap
            state, _ = obj.apply_fn(state, records[seq])
            replayed.append((seq, records[seq]))
        obj._trace = replayed
        nvram.write(obj.TRACE_LEN, len(replayed))
        nvram.write(obj.MARKER, len(replayed))
        nvram.reset_after_recovery()
        return obj, state
