"""Simulated byte-addressable NVRAM -- batched, columnar cost engine.

This is the substrate for the faithful reproduction of
"Durable Queues: The Second Amendment" (Sela & Petrank, SPAA'21).

The model (paper §2):

* Memory is word-granular (one object slot per word), grouped into cache
  lines of ``LINE_WORDS`` words.  A word models an 8-byte slot; a double-width
  (16-byte) CAS target is modeled as a tuple stored in a single word slot.
* Two levels: a volatile cache and a persistent backing store.  Stores go to
  the cache; they reach the persistent store via explicit ``flush``
  (CLWB-like) + ``fence`` (SFENCE-like), via ``movnti`` (non-temporal store,
  bypassing the cache) + ``fence``, or -- at a crash -- via the adversarial
  application of a *prefix* of the line's outstanding stores (Assumption 1:
  cache lines evict atomically, so persistent content is always a prefix of
  the stores to that line).
* Platform behaviour (does a flush invalidate?  is a visible store already
  durable?) and all latencies come from a pluggable
  :class:`repro.core.memmodel.MemoryModel`.  Under the default
  ``optane-clwb`` model a flush **invalidates** the line (Cascade Lake CLWB,
  paper §1/§2) and the next access is counted as a **post-flush access** --
  the paper's key cost metric.

Engine representation (this file is the fast path; the original dict engine
survives as :class:`repro.core.nvram_ref.ReferenceNVRAM`, the oracle the
differential tests compare against):

* flat Python lists hold the coherent view and the persistent image
  (persistent and volatile address spaces are each dense; scalar list
  indexing beats numpy object arrays by ~2x per access, which matters on
  the compiled fast path);
* per-line flush state is ONE packed ``_lstate`` bytearray -- bit 0 cached,
  bit 1 flush-invalidated, bit 2 ever-flushed -- so an access classifies
  and transitions with two byte-table lookups (``TOUCH_CLASS`` /
  ``TOUCH_NEXT``) instead of three array reads and two writes, and bulk
  transitions (crash wipe, allocator-area init) are single
  ``bytes.translate`` passes;
* per-line *dirty prefixes* (the unapplied store logs that give Assumption-1
  crash semantics) are kept per line and only touched by stores, fences and
  crashes -- never by loads;
* cost accounting is **batched**: every primitive appends one small event
  code to a buffer; the buffer is reduced with ``numpy.bincount`` into a
  ``(nthreads, N_EV)`` counter matrix only when statistics are requested.
  Per-thread simulated time is the dot product of that matrix with the
  model's latency vector, so multi-thread throughput is
  ``ops / max(thread_clock)`` -- reproducing the paper's Fig. 2 *orderings*
  without real NVRAM hardware.

Every mutable container above is **identity-stable**: growth, restore and
crash mutate the existing list/bytearray/dict in place instead of rebinding
the attribute.  The compiled fast path (``repro.core.opsched``) binds these
containers into generated functions as defaults, and the columnar record
store batches whole bursts of ops into one ``charge_counts`` pass -- both
depend on the bindings staying live across snapshot/restore/crash.

Latency constants (ns) follow published Optane DC characterization
[van Renen et al., DaMoN'19; Yang et al., FAST'20].
"""
from __future__ import annotations

import random
import threading
from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional, Tuple, Union

import numpy as np

from .memmodel import MemoryModel, get_memory_model

LINE_WORDS = 8  # 64-byte line / 8-byte words

NS = float

# ------------------------------------------------------------- event codes
# Each primitive logs one or two of these; counts x latency vector = time.
(EV_READ, EV_WRITE, EV_CAS, EV_FLUSH, EV_FENCE, EV_FENCE_LINE, EV_MOVNTI,
 EV_HIT, EV_DRAM, EV_COLD_DRAM, EV_COLD_NVM, EV_POSTFLUSH) = range(12)
N_EV = 12

# ------------------------------------------------- packed line-state bits
# One byte per line in NVRAM._lstate.  Reachable values are
# {0, 1, 4, 5, 6}: cached and flush-invalidated are mutually exclusive,
# and a line can only be invalidated by a flush (which also sets everfl).
LS_CACHED, LS_FINVAL, LS_EVERFL = 1, 2, 4

# Byte tables: packed state -> accounting outcome / post-access state for
# a fetching access (read/write/CAS RFO).  Shared with the codegen backend
# in repro.core.opsched, which inlines the same two lookups per line step.
TOUCH_CLASS = [
    EV_HIT if s & LS_CACHED else
    EV_POSTFLUSH if s & LS_FINVAL else
    EV_COLD_NVM if s & LS_EVERFL else
    EV_COLD_DRAM
    for s in range(256)]
TOUCH_NEXT = [s if s & LS_CACHED else (s & LS_EVERFL) | LS_CACHED
              for s in range(256)]

# bytes.translate tables for bulk line-state transitions
_T_EVERFL_ONLY = bytes(s & LS_EVERFL for s in range(256))      # crash wipe
_T_RECACHE = bytes((s & LS_EVERFL) | LS_CACHED for s in range(256))

# -------------------------------------------------- trace primitive codes
# Consumed by the opt-in trace tap (repro.trace.recorder.TraceRecorder).
# These are observation codes, deliberately separate from the EV_* cost
# codes: the tap sits BESIDE the cost accumulator and never feeds it.
(TR_READ, TR_WRITE, TR_WRITE_LINE, TR_CAS, TR_FLUSH, TR_FENCE,
 TR_MOVNTI) = range(7)

# Line flush-state at the moment of an access, classified BEFORE the access
# mutates cache metadata.  TS_INVALIDATED on a fetching primitive is exactly
# the engine's post-flush access (the paper's key cost).
(TS_VOLATILE, TS_CACHED, TS_COLD_DRAM, TS_COLD_NVM, TS_INVALIDATED) = range(5)


def _latency_vector(m: MemoryModel) -> np.ndarray:
    v = np.zeros(N_EV, dtype=np.float64)
    v[EV_FLUSH] = m.flush_issue_ns
    v[EV_FENCE] = m.fence_base_ns
    v[EV_FENCE_LINE] = m.fence_per_line_ns
    v[EV_MOVNTI] = m.movnti_ns
    v[EV_HIT] = m.cache_hit_ns
    v[EV_DRAM] = m.dram_miss_ns
    v[EV_COLD_DRAM] = m.dram_miss_ns
    v[EV_COLD_NVM] = m.nvram_read_ns
    v[EV_POSTFLUSH] = m.nvram_read_ns
    return v


class ThreadCrashed(Exception):
    """Raised inside a simulated thread when a crash is injected."""


@dataclass(frozen=True)
class CrashChoices:
    """Explicit adversarial crash outcome, applied by ``crash(mode='subset')``.

    The 'random' crash mode draws three kinds of decisions; this pins each
    one so the crash sweep can *enumerate* the full outcome space at a
    boundary instead of sampling it:

    * ``flush_survivors`` -- the set of ``(tid, pending-index)`` flush
      entries that reach NVRAM;
    * ``nt_prefix`` -- per ``(tid, line)``, how many of the thread's
      pending NT stores to that line persist (a *prefix*: WC buffers drain
      in issue order and the line evicts atomically);
    * ``log_prefix`` -- per line, how many of its unapplied stores persist
      (a prefix, Assumption 1).

    Prefixes are clamped to what actually remains once the surviving
    flushes have been applied, so enumerating against the pre-crash log
    lengths over-covers harmlessly (duplicate outcomes, never missed ones).
    """
    flush_survivors: frozenset = frozenset()   # {(tid, pending_index)}
    nt_prefix: tuple = ()                      # (((tid, line), k), ...)
    log_prefix: tuple = ()                     # ((line, k), ...)


class EngineSnapshot:
    """Frozen copy of an engine's *memory* state -- never its accounting.

    Captured by :meth:`NVRAM.snapshot`, reapplied by :meth:`NVRAM.restore`.
    The event buffer and counter matrix are deliberately excluded: Stats
    are monotonic instruments of work *performed*, and restoring memory
    state must not rewind or perturb them (the crash-sweep tests assert a
    snapshot/restore round-trip leaves Stats bit-identical).  Op-record
    cursors live one layer up, in :class:`repro.core.records.RecordStore`
    (snapshotted alongside this by the crash capture seam).

    ``volatile=False`` captures a crash-sufficient snapshot only (the
    persistent image, store logs, pending-persist sets and line history --
    ``lstate`` is masked down to the ever-flushed bit): restoring one is
    only meaningful when immediately followed by :meth:`NVRAM.crash`,
    which discards volatile state anyway.  The crash sweep takes one such
    snapshot per scheduler step, so the smaller footprint matters.
    """

    __slots__ = ("nthreads", "brk", "vbrk", "regions", "pmem", "log",
                 "log_start", "pending", "lstate", "crashed", "has_volatile",
                 "vis", "vval", "vtouched")

    def __init__(self, nv: "NVRAM", volatile: bool = True):
        self.nthreads = nv.nthreads
        self.brk = nv._brk
        self.vbrk = nv._vbrk
        self.regions = tuple(nv.regions)
        self.pmem = nv._pmem[:nv._brk]          # list slice == copy
        self.log = {ln: list(entries) for ln, entries in nv._log.items()
                    if entries}
        nl = -(-nv._brk // LINE_WORDS)
        self.log_start = nv._log_start[:nl]      # list slice == copy
        self.pending = {t: list(pl) for t, pl in nv._pending.items()}
        self.crashed = nv.crashed
        self.has_volatile = volatile
        if volatile:
            self.lstate = bytes(nv._lstate[:nl])
            self.vis = nv._vis[:nv._brk]
            vused = nv._vbrk - NVRAM._VOLATILE_BASE
            self.vval = nv._vval[:vused].copy()   # ndarray slice is a view
            self.vtouched = bytes(nv._vtouched[:vused])
        else:
            # crash-sufficient: only the ever-flushed history matters
            self.lstate = bytes(nv._lstate[:nl]).translate(_T_EVERFL_ONLY)


@dataclass
class Stats:
    """Per-thread persistence/cost counters (paper metrics)."""
    reads: int = 0
    writes: int = 0
    cas: int = 0
    flushes: int = 0
    fences: int = 0
    movntis: int = 0
    post_flush_accesses: int = 0   # accesses to a line invalidated by CLWB
    cold_misses: int = 0
    time_ns: NS = 0.0

    def snapshot(self) -> "Stats":
        return Stats(**self.__dict__)

    def minus(self, other: "Stats") -> "Stats":
        return Stats(**{k: getattr(self, k) - getattr(other, k)
                        for k in self.__dict__})

    def add(self, other: "Stats") -> None:
        for k in self.__dict__:
            setattr(self, k, getattr(self, k) + getattr(other, k))


class NVRAM:
    """Two-level (cache + persistent) memory simulator, columnar state."""

    _VOLATILE_BASE = 1 << 40   # volatile addresses live far above

    def __init__(self, nthreads: int = 1,
                 step_hook: Optional[Callable[[int, str], None]] = None,
                 model: Union[str, MemoryModel, None] = None):
        self.nthreads = nthreads
        self.step_hook = step_hook          # scheduler yield point
        self.model = get_memory_model(model)
        self._ns_vec = _latency_vector(self.model)
        # --- persistent space (dense, addr is the index) ------------------
        # All containers below are identity-stable: grown/cleared in place,
        # never rebound (compiled fast-path functions hold them as bound
        # defaults across snapshot/restore/crash).
        cap = 1024
        self._pcap = cap
        # the persistent planes stay plain lists: the compiled per-op
        # paths do dozens of scalar/slice accesses per op and lists are
        # measurably faster there (ndarray slice-assign alone costs ~2.5x).
        # The burst executor batches its p-plane stores with C-level
        # map(list.__setitem__) passes instead of fancy indexing.
        self._pmem: List[Any] = [None] * cap        # persistent image
        self._vis: List[Any] = [None] * cap         # coherent (cached) view
        # packed per-line flush state (LS_CACHED|LS_FINVAL|LS_EVERFL bits)
        self._lstate = bytearray(cap // LINE_WORDS)
        # per-line dirty prefix: unapplied stores (crash Assumption 1)
        self._log: Dict[int, List[Tuple[int, Any]]] = {}
        # absolute log position already persisted, indexed by line.  Stays
        # a plain list: the per-op paths do scalar `+=` on it (ndarray
        # scalar read-modify-write is ~3x slower and leaks np.int64 into
        # downstream arithmetic); the burst path batches its updates with
        # one C-level map(__setitem__) pass instead.
        self._log_start: List[int] = [0] * (cap // LINE_WORDS)
        # pending persists per thread: ('flush', line, upto) | ('nt', addr, v)
        self._pending: Dict[int, List[Tuple]] = {t: [] for t in range(nthreads)}
        # --- volatile space (dense above _VOLATILE_BASE) ------------------
        vcap = 1024
        self._vcap = vcap
        # the volatile value plane IS an object ndarray: the volatile-only
        # fast paths touch it a handful of times per op (cheap either
        # way), and it is exactly where the burst executor's vectorized
        # apply lands whole bursts of stores as one fancy-indexed scatter.
        # np.empty(object) initializes to None.
        self._vval = np.empty(vcap, dtype=object)
        self._vtouched = bytearray(vcap)
        # --- address-space management (address 0 is reserved as NULL) -----
        self._brk = LINE_WORDS
        self._vbrk = self._VOLATILE_BASE
        self.regions: List[Tuple[str, int, int, bool]] = []
        # --- contention bookkeeping (read by repro.core.contention; never
        # consulted by the cost accounting itself).  Tag/epoch stamping is
        # gated on contention_tracking (set by ContentionModel.begin_run) so
        # uncontended runs and the exact scheduler pay nothing for it.
        self.contention_tracking = False
        self.epoch = 0                        # clock-window tick (scheduler)
        self._line_epoch: Dict[int, int] = {}   # line -> last access epoch
        self._cas_words: Dict[int, int] = {}    # CAS target word -> attempts
        # --- trace tap (read-only observer; see repro.trace) --------------
        # When attached, every primitive reports (tid, TR_* code, addr,
        # TS_* pre-access line state, aux) to the tap.  The tap never
        # touches the event buffer or counters, so Stats are bit-identical
        # with and without it; when None the cost is one predicate per
        # primitive.
        self._tap = None
        # Benchmarking escape hatch: False forces allocator-area zeroing
        # back onto the per-primitive path (the seed behavior), so the
        # fastpath smoke can report an honest fully-per-op baseline.
        self.enable_bulk_init = True
        # --- batched cost accumulator -------------------------------------
        self._ebuf: List[int] = []            # packed tid * N_EV + code
        self._counts = np.zeros((nthreads, N_EV), dtype=np.int64)
        self._tls = threading.local()
        self.crashed = False
        # recovery-work tallies (crash-sweep reporting axis; not Stats)
        self.pread_count = 0
        self.pwrite_count = 0
        self._lock = threading.Lock()   # guards structural mutation (alloc)

    # ------------------------------------------------------------ thread id
    def set_tid(self, tid: int) -> None:
        self._tls.tid = tid

    @property
    def tid(self) -> int:
        return getattr(self._tls, "tid", 0)

    def _step(self, kind: str) -> None:
        if self.step_hook is not None:
            self.step_hook(self.tid, kind)

    # ------------------------------------------------------------ trace tap
    def set_trace_tap(self, tap) -> None:
        """Attach/detach (None) a trace observer (repro.trace recorder).

        The tap receives ``on_prim(tid, prim, addr, state, aux)`` per
        primitive -- a pure observation seam above/beside the cost
        accumulator; attaching one cannot perturb Stats.
        """
        self._tap = tap

    def _line_state(self, addr: int) -> int:
        """TS_* classification of `addr`'s line, pre-access (tap only)."""
        if addr >= self._VOLATILE_BASE:
            return TS_VOLATILE
        s = self._lstate[addr // LINE_WORDS]
        if s & LS_CACHED:
            return TS_CACHED
        if s & LS_FINVAL:
            return TS_INVALIDATED
        if s & LS_EVERFL:
            return TS_COLD_NVM
        return TS_COLD_DRAM

    # --------------------------------------------------------- address space
    def _grow_p(self, need: int) -> None:
        cap = self._pcap
        while cap < need:
            cap *= 2
        add = cap - self._pcap
        self._pmem.extend([None] * add)
        self._vis.extend([None] * add)
        self._lstate.extend(bytes(add // LINE_WORDS))
        self._log_start.extend([0] * (add // LINE_WORDS))
        self._pcap = cap

    def _grow_v(self, need: int) -> None:
        cap = self._vcap
        while cap < need:
            cap *= 2
        add = cap - self._vcap
        # in-place growth: ndarray.resize keeps the array object itself
        # (the compiled fast path holds it as a bound default); the new
        # cells must be re-initialized to None (resize zero-fills)
        self._vval.resize(cap, refcheck=False)
        self._vval[self._vcap:] = None
        self._vtouched.extend(bytes(add))
        self._vcap = cap

    def alloc_region(self, nwords: int, name: str = "region",
                     persistent: bool = True) -> int:
        """Allocate a line-aligned region; returns base address."""
        with self._lock:
            if persistent:
                base = (self._brk + LINE_WORDS - 1) // LINE_WORDS * LINE_WORDS
                self._brk = base + nwords
                if self._brk > self._pcap:
                    self._grow_p(self._brk)
            else:
                base = (self._vbrk + LINE_WORDS - 1) // LINE_WORDS * LINE_WORDS
                self._vbrk = base + nwords
                if self._vbrk - self._VOLATILE_BASE > self._vcap:
                    self._grow_v(self._vbrk - self._VOLATILE_BASE)
            self.regions.append((name, base, nwords, persistent))
            return base

    def is_persistent_addr(self, addr: int) -> bool:
        return addr < self._VOLATILE_BASE

    @staticmethod
    def line_of(addr: int) -> int:
        return addr // LINE_WORDS

    # ------------------------------------------------------- cache mechanics
    def _touch(self, line: int, tid: int) -> None:
        """Account for bringing `line` into cache (persistent space)."""
        if self.contention_tracking:
            self._line_epoch[line] = self.epoch
        s = self._lstate[line]
        self._ebuf.append(tid * N_EV + TOUCH_CLASS[s])
        self._lstate[line] = TOUCH_NEXT[s]

    # ------------------------------------------------------------ primitives
    def read(self, addr: int) -> Any:
        self._step("read")
        tid = self.tid
        if self._tap is not None:
            self._tap.on_prim(tid, TR_READ, addr, self._line_state(addr), -1)
        self._ebuf.append(tid * N_EV + EV_READ)
        if addr >= self._VOLATILE_BASE:
            i = addr - self._VOLATILE_BASE
            if self._vtouched[i]:
                self._ebuf.append(tid * N_EV + EV_HIT)
            else:
                self._ebuf.append(tid * N_EV + EV_DRAM)
                self._vtouched[i] = 1
            return self._vval[i]
        self._touch(addr // LINE_WORDS, tid)
        return self._vis[addr]

    def write(self, addr: int, value: Any) -> None:
        self._step("write")
        tid = self.tid
        if self._tap is not None:
            self._tap.on_prim(tid, TR_WRITE, addr, self._line_state(addr), -1)
        self._ebuf.append(tid * N_EV + EV_WRITE)
        if addr >= self._VOLATILE_BASE:
            i = addr - self._VOLATILE_BASE
            if self._vtouched[i]:
                self._ebuf.append(tid * N_EV + EV_HIT)
            else:
                self._ebuf.append(tid * N_EV + EV_DRAM)
                self._vtouched[i] = 1
            self._vval[i] = value
            return
        line = addr // LINE_WORDS
        self._touch(line, tid)              # write-allocate (RFO)
        self._vis[addr] = value
        if self.model.persist_on_store:
            self._pmem[addr] = value        # visible => durable: no log
        else:
            self._log.setdefault(line, []).append((addr, value))

    def write_full_line(self, base_addr: int, values: List[Any]) -> None:
        """Full-line store without read-for-ownership (models allocator /
        node initialization via fast-string or full-line NT stores -- no
        fetch, hence *not* a post-flush access).  Used only when every word
        of the line is overwritten."""
        self._step("write")
        tid = self.tid
        if self._tap is not None:
            # no fetch: the pre-state is recorded but a full-line store is
            # never a post-flush access (analysis treats it as non-fetching)
            self._tap.on_prim(tid, TR_WRITE_LINE, base_addr,
                              self._line_state(base_addr), -1)
        self._ebuf.append(tid * N_EV + EV_WRITE)
        self._ebuf.append(tid * N_EV + EV_HIT)
        assert base_addr % LINE_WORDS == 0 and len(values) <= LINE_WORDS
        if base_addr >= self._VOLATILE_BASE:
            i = base_addr - self._VOLATILE_BASE
            for k, v in enumerate(values):
                self._vval[i + k] = v
                self._vtouched[i + k] = 1
            return
        line = base_addr // LINE_WORDS
        self._lstate[line] = (self._lstate[line] & LS_EVERFL) | LS_CACHED
        if self.model.persist_on_store:
            for k, v in enumerate(values):
                self._vis[base_addr + k] = v
                self._pmem[base_addr + k] = v
            return
        log = self._log.setdefault(line, [])
        for k, v in enumerate(values):
            self._vis[base_addr + k] = v
            log.append((base_addr + k, v))

    def cas(self, addr: int, expected: Any, new: Any) -> bool:
        """Atomic compare-and-swap (one scheduler step).  Double-width CAS is
        modeled by storing a tuple at a single word address (paper §5.1.2)."""
        self._step("cas")
        tid = self.tid
        tap = self._tap
        state = self._line_state(addr) if tap is not None else 0
        self._ebuf.append(tid * N_EV + EV_CAS)
        # tag the CAS target word + stamp its line's access epoch (contention
        # bookkeeping; persistent-space lines are stamped inside _touch)
        if self.contention_tracking:
            self._cas_words[addr] = self._cas_words.get(addr, 0) + 1
            if addr >= self._VOLATILE_BASE:
                self._line_epoch[addr // LINE_WORDS] = self.epoch
        if addr >= self._VOLATILE_BASE:
            i = addr - self._VOLATILE_BASE
            if self._vtouched[i]:
                self._ebuf.append(tid * N_EV + EV_HIT)
            else:
                self._ebuf.append(tid * N_EV + EV_DRAM)
                self._vtouched[i] = 1
            ok = self._vval[i] == expected
            if ok:
                self._vval[i] = new
        else:
            line = addr // LINE_WORDS
            self._touch(line, tid)
            ok = self._vis[addr] == expected
            if ok:
                self._vis[addr] = new
                if self.model.persist_on_store:
                    self._pmem[addr] = new
                else:
                    self._log.setdefault(line, []).append((addr, new))
        if tap is not None:
            tap.on_prim(tid, TR_CAS, addr, state, 1 if ok else 0)
        return bool(ok)

    def flush(self, addr: int) -> None:
        """Asynchronous CLWB: schedule write-back of the whole containing
        line; under an invalidating model (Cascade Lake) also evict it."""
        self._step("flush")
        tid = self.tid
        if self._tap is not None:
            self._tap.on_prim(tid, TR_FLUSH, addr, self._line_state(addr), -1)
        self._ebuf.append(tid * N_EV + EV_FLUSH)
        assert addr < self._VOLATILE_BASE, "flushing volatile memory"
        line = addr // LINE_WORDS
        upto_abs = self._log_start[line] + len(self._log.get(line, ()))
        self._pending[tid].append(("flush", line, upto_abs))
        if self.model.flush_invalidates:
            self._lstate[line] = LS_FINVAL | LS_EVERFL
        else:
            self._lstate[line] |= LS_EVERFL

    def movnti(self, addr: int, value: Any) -> None:
        """Non-temporal store: straight to the memory write queue; does not
        touch or pollute the cache (paper §6.3).  Needs a fence to complete.
        NT stores are globally visible immediately (x86 coherence)."""
        self._step("movnti")
        tid = self.tid
        if self._tap is not None:
            self._tap.on_prim(tid, TR_MOVNTI, addr, self._line_state(addr), -1)
        self._ebuf.append(tid * N_EV + EV_MOVNTI)
        assert addr < self._VOLATILE_BASE
        self._vis[addr] = value
        self._pending[tid].append(("nt", addr, value))

    def fence(self) -> None:
        """SFENCE: block until all of this thread's outstanding flushes and
        NT stores are persistent."""
        self._step("fence")
        tid = self.tid
        if self._tap is not None:
            # aux = outstanding persist entries this fence will drain
            self._tap.on_prim(tid, TR_FENCE, -1, -1, len(self._pending[tid]))
        self._ebuf.append(tid * N_EV + EV_FENCE)
        pend = self._pending[tid]
        if pend:
            # drain cost scales with distinct lines: WC buffers combine NT
            # stores to one line, and flush entries of a line coalesce
            lines = {(e[1] if e[0] == "flush" else e[1] // LINE_WORDS)
                     for e in pend}
            self._counts[tid, EV_FENCE_LINE] += len(lines)
            for ent in pend:
                self._apply_persist(ent)
            pend.clear()

    def persist(self, addr: int) -> None:
        """flush + fence convenience (the paper's 'persisting a location')."""
        self.flush(addr)
        self.fence()

    # --------------------------------------------------------------- persist
    def _apply_persist(self, ent: Tuple) -> None:
        if ent[0] == "flush":
            _, line, upto_abs = ent
            log = self._log.get(line, [])
            start = self._log_start[line]
            count = upto_abs - start
            if count <= 0:
                return          # already applied by a later/earlier fence
            count = min(count, len(log))
            for (a, v) in log[:count]:
                self._pmem[a] = v
            del log[:count]
            self._log_start[line] = start + count
        else:
            _, addr, v = ent
            self._pmem[addr] = v

    # ------------------------------------------------------ snapshot/restore
    def snapshot(self, volatile: bool = True) -> EngineSnapshot:
        """Capture this engine's memory state (see :class:`EngineSnapshot`).

        Pure observation: nothing is appended to the event buffer and no
        counter moves, so taking a snapshot cannot perturb Stats.  With
        ``volatile=False`` only the crash-relevant state is copied (the
        persistent image, per-line store logs, pending-persist sets and
        ever-flushed history) -- restore such a snapshot only to crash() it.
        """
        return EngineSnapshot(self, volatile=volatile)

    def restore(self, snap: EngineSnapshot) -> None:
        """Reapply a snapshot's memory state in place.

        The address space (break pointers + region table) rewinds to the
        snapshot's, so regions allocated afterwards are forgotten -- their
        addresses will be handed out again and rewritten before any read
        (the allocators zero or fully initialize before use).  Cost
        accounting is untouched: Stats remain whatever the engine has
        accumulated, because restore models *state transplantation*, not
        un-executing work.  Every container is refilled in place (the
        compiled fast path holds them as bound defaults).
        """
        if snap.nthreads != self.nthreads:
            raise ValueError(
                f"snapshot taken with nthreads={snap.nthreads}, "
                f"engine has {self.nthreads}")
        if snap.brk > self._pcap:
            self._grow_p(snap.brk)
        vused = snap.vbrk - self._VOLATILE_BASE
        if vused > self._vcap:
            self._grow_v(vused)
        self._brk = snap.brk
        self._vbrk = snap.vbrk
        self.regions = list(snap.regions)
        self._pmem[:snap.brk] = snap.pmem
        nl = len(snap.log_start)
        ls = self._log_start
        ls[:] = [0] * len(ls)
        ls[:nl] = snap.log_start
        self._log.clear()
        for ln, entries in snap.log.items():
            self._log[ln] = list(entries)
        for t, pl in snap.pending.items():
            self._pending[t][:] = pl
        self.crashed = snap.crashed
        st = self._lstate
        st[:] = bytes(len(st))
        st[:nl] = snap.lstate          # full bits, or everfl-only (crash-
        vt = self._vtouched            # sufficient snapshot)
        if snap.has_volatile:
            self._vis[:snap.brk] = snap.vis
            self._vval[:vused] = snap.vval
            vt[:] = bytes(len(vt))
            vt[:vused] = snap.vtouched
        else:
            # crash-only snapshot: give the volatile level a post-crash-like
            # default (coherent view = persistent image, cold caches) so a
            # restore is well-defined even before crash() wipes it for real
            self._vis[:snap.brk] = snap.pmem
            vt[:] = bytes(len(vt))
        # contention bookkeeping is a per-run measurement aid, not memory
        # state: clear it rather than time-travel it
        self._line_epoch.clear()
        self._cas_words.clear()

    # ----------------------------------------------------------------- crash
    def crash(self, mode: str = "random", seed: int = 0,
              choices: Optional[CrashChoices] = None) -> None:
        """Full-system crash (paper §2 failure model).

        mode='min'    -- nothing beyond fenced state survives (pending flushes
                         and NT stores are dropped; un-flushed stores lost).
        mode='random' -- each pending flush/NT store independently survives;
                         additionally each line persists a random *prefix* of
                         its remaining stores (implicit eviction, Assumption 1).
        mode='max'    -- everything reaches NVRAM (all stores applied).
        mode='subset' -- the outcome pinned by ``choices`` (a
                         :class:`CrashChoices`): the crash sweep uses this to
                         exhaustively enumerate every adversarial outcome at
                         a boundary when the pending set is small.
        Under a persist-on-store model (eADR) every visible store is durable,
        so all modes behave like 'max'.  Volatile memory (cache + DRAM space)
        is wiped regardless.
        """
        rng = random.Random(seed)
        self.crashed = True
        if mode == "max" or self.model.persist_on_store:
            for plist in self._pending.values():
                for ent in plist:
                    self._apply_persist(ent)
            for line, log in self._log.items():
                for (a, v) in log:
                    self._pmem[a] = v
        elif mode == "random":
            for plist in self._pending.values():
                # flush entries may survive independently: applying a later
                # flush of a line subsumes earlier ones (prefix-safe).
                for ent in plist:
                    if ent[0] == "flush" and rng.random() < 0.5:
                        self._apply_persist(ent)
                # NT stores to the same line combine in the WC buffer and the
                # line evicts atomically (Assumption 1): per line, a *prefix*
                # of the thread's NT stores survives, in issue order.
                nt_by_line: Dict[int, List[Tuple]] = {}
                for ent in plist:
                    if ent[0] == "nt":
                        nt_by_line.setdefault(ent[1] // LINE_WORDS,
                                              []).append(ent)
                for line, ents in nt_by_line.items():
                    k = rng.randint(0, len(ents))
                    for ent in ents[:k]:
                        self._apply_persist(ent)
            for line, log in list(self._log.items()):
                if log:
                    k = rng.randint(0, len(log))  # prefix (Assumption 1)
                    for (a, v) in log[:k]:
                        self._pmem[a] = v
        elif mode == "subset":
            # same decision structure as 'random', but every draw is pinned
            # by `choices`; prefixes clamp to what remains after the chosen
            # flushes applied (see CrashChoices)
            ch = choices if choices is not None else CrashChoices()
            nt_pref = dict(ch.nt_prefix)
            for t in sorted(self._pending):
                plist = self._pending[t]
                nt_by_line: Dict[int, List[Tuple]] = {}
                for i, ent in enumerate(plist):
                    if ent[0] == "flush":
                        if (t, i) in ch.flush_survivors:
                            self._apply_persist(ent)
                    else:
                        nt_by_line.setdefault(ent[1] // LINE_WORDS,
                                              []).append(ent)
                for line, ents in nt_by_line.items():
                    k = min(nt_pref.get((t, line), 0), len(ents))
                    for ent in ents[:k]:
                        self._apply_persist(ent)
            log_pref = dict(ch.log_prefix)
            for line, log in list(self._log.items()):
                k = min(log_pref.get(line, 0), len(log))
                for (a, v) in log[:k]:
                    self._pmem[a] = v
        elif mode == "min":
            pass
        else:
            raise ValueError(mode)
        # volatile state is gone: the coherent view collapses onto the
        # persistent image, DRAM space and all cache metadata are wiped
        # (in place: the compiled fast path holds these containers)
        for plist in self._pending.values():
            plist.clear()
        self._log.clear()
        self._log_start[:] = [0] * len(self._log_start)
        self._vis[:] = self._pmem
        self._lstate[:] = self._lstate.translate(_T_EVERFL_ONLY)
        self._vval[:] = [None] * len(self._vval)
        self._vtouched[:] = bytes(len(self._vtouched))

    # ------------------------------------------------------ recovery access
    def pread(self, addr: int) -> Any:
        """Recovery-time direct read of the persistent image (not on the
        fast path; costs are accounted separately by the harness).  The
        plain `pread_count` tally feeds the crash sweep's recovery-work
        axis; it is not part of Stats."""
        self.pread_count += 1
        return self._pmem[addr]

    def pwrite(self, addr: int, value: Any) -> None:
        """Recovery-time direct persistent write (recovery persists its
        reconstruction before normal operation resumes)."""
        self.pwrite_count += 1
        self._pmem[addr] = value
        self._vis[addr] = value

    def reset_after_recovery(self) -> None:
        """Recovery is complete: resume normal (cached) operation."""
        self.crashed = False

    # --------------------------------------------------------- state export
    def line_state_arrays(self, nlines: int) -> Tuple[np.ndarray, np.ndarray,
                                                      np.ndarray]:
        """Unpack the first `nlines` of ``_lstate`` into (cached, finval,
        everfl) ``uint8`` arrays -- the fleet state exporter's layout
        (:mod:`repro.fleet.state` tiles these across instances)."""
        s = np.frombuffer(bytes(self._lstate[:nlines]), dtype=np.uint8)
        return ((s & LS_CACHED).astype(np.uint8),
                ((s & LS_FINVAL) >> 1).astype(np.uint8),
                ((s & LS_EVERFL) >> 2).astype(np.uint8))

    def vtouched_array(self, nwords: int) -> np.ndarray:
        """First `nwords` of the volatile touched map as a ``uint8`` copy."""
        return np.frombuffer(bytes(self._vtouched[:nwords]),
                             dtype=np.uint8).copy()

    # ---------------------------------------------------- contention seam
    # The contention layer (repro.core.contention) lives ABOVE this cost
    # accumulator: it reads the tags/epochs below and feeds extra event
    # codes through charge_events -- it never alters how a primitive is
    # accounted, so single-thread runs stay bit-identical to the oracle.
    def cas_count(self, addr: int) -> int:
        """How many CAS attempts have targeted `addr` (tagged in cas())."""
        return self._cas_words.get(addr, 0)

    def cas_targets(self) -> Dict[int, int]:
        """All tagged CAS target words with their attempt counts."""
        return dict(self._cas_words)

    def line_epoch(self, line: int) -> int:
        """Last clock-window epoch at which `line` was accessed (-1 never).

        Epochs are ticked by the batched scheduler (one per executed op);
        under the exact scheduler they stay 0 and this bookkeeping is inert.
        """
        return self._line_epoch.get(line, -1)

    def charge_events(self, tid: int, codes: List[int],
                      repeat: int = 1) -> None:
        """Append pre-classified event codes to thread `tid`'s account.

        `codes` are EV_* values (one retry round's shape); they flow into
        the same bincount reduction as real primitives, so charged retries
        advance the thread's simulated clock and all Stats counters.
        """
        buf = self._ebuf
        base = tid * N_EV
        for _ in range(repeat):
            for c in codes:
                buf.append(base + c)

    # ------------------------------------------------- compiled-op seam
    # The schedule compiler (repro.core.opsched) replays a queue op's
    # event shape as ONE pre-reduced count vector instead of dozens of
    # event-buffer appends.  Charging goes straight into the counter
    # matrix -- the same destination the bincount reduction feeds -- so
    # compiled and per-primitive execution produce identical counts and
    # identical (dot-product) thread clocks.  The columnar record store
    # (repro.core.records.RecordStore) batches a whole burst of compiled
    # ops into a handful of charge_counts calls (one per distinct
    # (outcome-key, tid, kind) triple).
    def charge_counts(self, tid: int, vec: np.ndarray) -> None:
        """Add one compiled op's (N_EV,) event-count vector to `tid`."""
        self._counts[tid] += vec

    def bulk_line_init(self, base: int, nlines: int) -> None:
        """Vectorized allocator-area init: the exact accounting + state
        effects of, per line, ``write_full_line(a, [0]*LINE_WORDS)`` (+
        ``flush(a)`` when the model needs flushes) followed by ONE
        ``fence()`` -- the ssmem designated-area zeroing schedule (paper
        §5.1.3).  Event counts, line state, the persistent image and the
        per-line ``_log_start`` positions come out bit-identical to the
        per-primitive sequence; only the Python-loop overhead (tens of
        milliseconds per 4096-node area) is removed.

        Callers (``SSMem._new_area``) must only use this when no
        scheduler step hook and no trace tap are attached: the compiled
        form has no per-primitive yield points to report.
        """
        assert self.step_hook is None and self._tap is None
        tid = self.tid
        lo, hi = base, base + nlines * LINE_WORDS
        line0 = base // LINE_WORDS
        self._drain()
        c = self._counts[tid]
        c[EV_WRITE] += nlines          # one full-line store per line
        c[EV_HIT] += nlines
        zeros = [0] * (hi - lo)
        self._vis[lo:hi] = zeros
        self._pmem[lo:hi] = zeros
        seg = slice(line0, line0 + nlines)
        if self.model.persist_on_store:
            # eADR: stores persist on visibility; pflush is elided and the
            # fence drains nothing
            self._lstate[seg] = self._lstate[seg].translate(_T_RECACHE)
            c[EV_FENCE] += 1
            return
        # flush-based platforms: every line is flushed once, then one
        # fence drains all nlines distinct lines
        c[EV_FLUSH] += nlines
        c[EV_FENCE] += 1
        c[EV_FENCE_LINE] += nlines
        if self.model.flush_invalidates:
            self._lstate[seg] = bytes([LS_FINVAL | LS_EVERFL]) * nlines
        else:
            self._lstate[seg] = bytes([LS_CACHED | LS_EVERFL]) * nlines
        # the LINE_WORDS zero-stores per line were logged and drained by
        # the fence: logs end empty with the start cursor advanced (past
        # any pre-existing unapplied entries too -- the zeros overwrote
        # whatever values those would have applied)
        ls = self._log_start
        log = self._log
        for ln in range(line0, line0 + nlines):
            pre = log.get(ln)
            ls[ln] += LINE_WORDS + (len(pre) if pre else 0)
            if pre:
                pre.clear()

    # ------------------------------------------------------------- reporting
    def _drain(self) -> None:
        """Reduce the event buffer into the counter matrix (vectorized)."""
        if self._ebuf:
            cnt = np.bincount(np.asarray(self._ebuf, dtype=np.int64),
                              minlength=self.nthreads * N_EV)
            self._counts += cnt.reshape(self.nthreads, N_EV)
            self._ebuf.clear()

    def _stats_of(self, c: np.ndarray) -> Stats:
        return Stats(
            reads=int(c[EV_READ]), writes=int(c[EV_WRITE]),
            cas=int(c[EV_CAS]), flushes=int(c[EV_FLUSH]),
            fences=int(c[EV_FENCE]), movntis=int(c[EV_MOVNTI]),
            post_flush_accesses=int(c[EV_POSTFLUSH]),
            cold_misses=int(c[EV_COLD_DRAM] + c[EV_COLD_NVM]),
            time_ns=float(c @ self._ns_vec))

    @property
    def stats(self) -> Dict[int, Stats]:
        """Per-thread Stats, materialized on demand from the counter matrix."""
        self._drain()
        return {t: self._stats_of(self._counts[t])
                for t in range(self.nthreads)}

    def total_stats(self) -> Stats:
        self._drain()
        return self._stats_of(self._counts.sum(axis=0))

    def thread_time_ns(self, tid: int) -> float:
        """Simulated clock of one thread (drains the event buffer)."""
        self._drain()
        return float(self._counts[tid] @ self._ns_vec)

    def thread_times_ns(self) -> np.ndarray:
        """All per-thread clocks at once (vectorized)."""
        self._drain()
        return self._counts @ self._ns_vec

    def sim_time_ns(self) -> NS:
        """Makespan across per-thread clocks."""
        times = self.thread_times_ns()
        return float(times.max()) if len(times) else 0.0
