"""Steady-state op-schedule IR, compiler and fast-path executor.

The paper's central objects -- per-op persist schedules and post-flush
access counts (§5-§6) -- are *fixed primitive sequences* in steady state:
once a queue is warm, every successful enqueue/dequeue replays the same
reads, writes, CAS, flush and fence primitives against a small, predictable
set of cache lines.  The per-op throughput path
(:class:`repro.core.scheduler.ClockScheduler`) nevertheless re-executes
those primitives one Python call at a time (~50-100µs/op).  This module
removes that overhead without changing a single count:

* **IR** (:class:`OpSchedule` built from :class:`L` locations and the step
  constructors below): each queue's :meth:`~repro.core.queue_base.
  QueueAlgorithm.op_schedule` declares its steady-state enqueue/dequeue as
  a typed primitive program -- the same facts its ``retry_profile()`` and
  the B2 persist-count tables assert, now as one machine-readable source
  of truth.  The contention layer derives each op kind's CAS *root* and
  whether a retry can touch flushed content directly from this program
  (:func:`linearizing_root`, :func:`retry_touches_persistent`).

* **Compiler** (:func:`compile_schedule`): partial-evaluates a schedule
  against a :class:`repro.core.memmodel.MemoryModel` and one queue
  instance.  Model-elided work disappears (``pflush`` under eADR), line
  touches whose outcome is decidable intra-op fold into a fixed event
  vector (a re-read after an invalidating flush *is* a post-flush access),
  and only genuinely state-dependent classifications survive as runtime
  steps.  The result is one pre-reduced ``(N_EV,)`` count vector plus a
  short effect program over the engine's raw arrays.

* **Executor** (:class:`FastPathExecutor`): replays compiled ops for the
  scheduler.  Logical FIFO contents are maintained in O(1) Python (a
  deque of ``(pnode, vnode, item, index)`` records), memory effects are
  applied through the same ``_vis``/``_pmem``/store-log structures the
  primitives would touch, and the whole op's events are charged through
  :meth:`repro.core.nvram.NVRAM.charge_counts` in one vector add.  Any
  op outside the compiled steady state -- empty dequeues, first-op
  sentinel warmup (per-thread retire/flush slots still NULL), allocator
  area refills, leftover unfenced persists, crash-adjacent engines --
  **bails** to real per-primitive execution; the executor then resyncs
  its logical view from engine memory.

Equivalence is the gate, not an aspiration: ``tests/
test_fastpath_equivalence.py`` asserts fast-path Stats (every counter
*and* ``time_ns``) are bit-identical to per-op ClockScheduler execution
for all 8 queues x 3 memory models x contention off/on/learned, and
``tests/test_fastpath_bailout.py`` covers the bail conditions.
"""
from __future__ import annotations

import heapq
from collections import deque
from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np

from .nvram import (EV_CAS, EV_DRAM, EV_FENCE, EV_FENCE_LINE, EV_FLUSH,
                    EV_HIT, EV_MOVNTI, EV_POSTFLUSH, EV_READ, EV_WRITE,
                    LINE_WORDS, LS_CACHED, LS_EVERFL, LS_FINVAL, N_EV,
                    NVRAM, TOUCH_CLASS, TOUCH_NEXT)
from .records import MAX_STAGED_NCLASS, MAX_STAGED_THREADS, META_KEY_SHIFT

NULL = 0

# Codegen cache: the generated sources are pure functions of (queue
# class, op schedule, model), so identical text recurs across harness
# constructions -- re-``exec`` of the cached code object into fresh
# globals is ~100x cheaper than re-``compile``.
_CODE_CACHE: Dict[Tuple[str, str], Any] = {}


def compile_cached(src: str, name: str):
    key = (name, src)
    code = _CODE_CACHE.get(key)
    if code is None:
        code = _CODE_CACHE[key] = compile(src, name, "exec")
    return code


# --------------------------------------------------------------------------
# locations and value expressions (queue-facing, address-free)
# --------------------------------------------------------------------------
# Environment symbols an op binds at runtime.  ``*_p`` addresses live in
# persistent space, ``*_v`` in volatile space; ``prev`` is the per-thread
# slot value bound by a ``slot_nonnull`` guard (always a persistent node).
_SYMS = ("new_p", "new_v", "tail_p", "tail_v", "head_p", "head_v",
         "next_p", "next_v", "prev")
(E_NEW_P, E_NEW_V, E_TAIL_P, E_TAIL_V, E_HEAD_P, E_HEAD_V,
 E_NEXT_P, E_NEXT_V, E_PREV) = range(len(_SYMS))
_SYM_INDEX = {s: i for i, s in enumerate(_SYMS)}
_VOLATILE_SYMS = {"new_v", "tail_v", "head_v", "next_v"}


@dataclass(frozen=True)
class L:
    """A symbolic address: an UPPERCASE queue root attribute (``HEAD``,
    ``TAIL``, ``HEADIDX``...) or a lowercase env symbol, plus a word
    offset.  ``per_tid`` addresses the calling thread's line within a
    per-thread root region (``base + tid * LINE_WORDS + off``)."""
    base: str
    off: int = 0
    per_tid: bool = False

    @property
    def is_root(self) -> bool:
        return self.base[0].isupper()


# Value expressions -- tiny tagged tuples, compiled to closures:
#   ("c", x)            literal
#   ("item",)           the op's item
#   ("idx",)            the op's index (enq: tail index + 1; deq: next's)
#   ("sym", name)       an env symbol's address *as a value* (pointer store)
#   ("tup", e1, e2)     a pair (double-width CAS payloads)
#   ("slot", attr, i)   element i of the per-thread tuple ``q.attr[tid]``
Val = tuple


# --------------------------------------------------------------------------
# IR steps
# --------------------------------------------------------------------------
@dataclass(frozen=True)
class Step:
    op: str                       # step constructor name below
    loc: Optional[L] = None
    val: Optional[Val] = None
    tpl: Optional[tuple] = None   # WriteLine template
    item_at: Optional[int] = None
    root: bool = False            # Cas: tracked contention root
    event: Optional[str] = None   # Cas: linearization event kind
    attr: Optional[str] = None    # slot / persisted-set steps
    syms: tuple = ()              # persisted_add symbols


def AllocP() -> Step:
    """Allocate a persistent node from ssmem into ``new_p`` (bails to the
    real path when the allocator would have to carve a new area)."""
    return Step("alloc_p")


def AllocV() -> Step:
    """Allocate a volatile node from the queue's ``valloc`` into ``new_v``."""
    return Step("alloc_v")


def Read(loc: L) -> Step:
    return Step("read", loc=loc)


def Write(loc: L, val: Val) -> Step:
    return Step("write", loc=loc, val=val)


def WriteLine(loc: L, tpl: tuple, item_at: Optional[int] = None) -> Step:
    """Full-line store without RFO (``NVRAM.write_full_line``)."""
    return Step("write_line", loc=loc, tpl=tpl, item_at=item_at)


def Cas(loc: L, val: Val, root: bool = False,
        event: Optional[str] = None) -> Step:
    """A CAS that always succeeds in steady state.  ``root=True`` marks the
    op's contention-tracked root CAS (exactly one per schedule);
    ``event`` emits the volatile-linearization event at this CAS."""
    return Step("cas", loc=loc, val=val, root=root, event=event)


def Flush(loc: L) -> Step:
    """Model-aware ``pflush`` (elided when the platform needs no flushes)."""
    return Step("flush", loc=loc)


def Fence() -> Step:
    return Step("fence")


def Movnti(loc: L, val: Val) -> Step:
    return Step("movnti", loc=loc, val=val)


def Retire(val: Val) -> Step:
    return Step("retire", val=val)


def RetireV(val: Val) -> Step:
    return Step("retire_v", val=val)


def SlotSet(attr: str, val: Val) -> Step:
    """``q.<attr>[tid] = value`` (volatile per-thread helper state)."""
    return Step("slot_set", attr=attr, val=val)


def PersistedDiscard(sym: str) -> Step:
    return Step("persisted_discard", attr=sym)


def PersistedAdd(*syms: str) -> Step:
    return Step("persisted_add", syms=syms)


@dataclass(frozen=True)
class OpSchedule:
    """One op kind's steady-state primitive program.

    ``guards`` are extra bail conditions beyond the built-in ones:
      ``("slot_nonnull", attr)``  -- ``q.attr[tid] != NULL`` (binds ``prev``)
      ``("tail_persisted",)``     -- the tail node's persistent half is in
                                     ``q._persisted`` (bounds backward walks)
    ``retry_from`` indexes the first step of the CAS-retry loop body; the
    contention layer inspects ``steps[retry_from:]`` to decide whether a
    failed-CAS retry can touch flushed (persistent) content at all.
    """
    kind: str
    steps: Tuple[Step, ...]
    guards: Tuple[tuple, ...] = ()
    uses_ssmem: bool = True
    retry_from: int = 0


@dataclass(frozen=True)
class FifoLayout:
    """How to walk the queue's logical FIFO straight out of engine memory
    (bootstrap + post-bail resync).  ``head_root`` names the root attr
    whose value is the current dummy node."""
    head_root: str
    next_off: int = 1
    item_off: int = 0
    idx_off: Optional[int] = None
    pptr_off: Optional[int] = None    # volatile layouts: ptr to pnode
    volatile: bool = False
    head_is_tuple: bool = False


@dataclass(frozen=True)
class QueueSchedules:
    enq: OpSchedule
    deq: OpSchedule
    layout: FifoLayout

    def __iter__(self):
        yield from (self.enq, self.deq)

    def of_kind(self, kind: str) -> OpSchedule:
        return self.enq if kind == "enq" else self.deq


# --------------------------------------------------------------------------
# schedule-derived contention facts
# --------------------------------------------------------------------------
def _loc_is_volatile(queue, loc: L) -> bool:
    if loc.is_root:
        return getattr(queue, loc.base) >= NVRAM._VOLATILE_BASE
    return loc.base in _VOLATILE_SYMS


def linearizing_root(queue, sched: OpSchedule) -> int:
    """Resolve the op's contention-tracked root word address: the target
    of the schedule's unique ``Cas(..., root=True)`` step."""
    roots = [s for s in sched.steps if s.op == "cas" and s.root]
    if len(roots) != 1:
        raise ValueError(
            f"{type(queue).__name__}/{sched.kind}: expected exactly one "
            f"root CAS, found {len(roots)}")
    loc = roots[0].loc
    if not loc.is_root:
        raise ValueError(f"root CAS must target a fixed root, got {loc}")
    base = getattr(queue, loc.base)
    return base + loc.off   # per_tid roots are not CAS targets


def retry_touches_persistent(queue, sched: OpSchedule) -> bool:
    """Does the CAS-retry loop body fetch any persistent-space line?

    A retry round can only re-incur the paper's post-flush penalty if the
    re-executed reads/CASes touch persistent memory at all; the
    second-amendment queues' loop bodies are volatile-only, which is
    exactly why their contended ``post_flush_accesses`` stay zero.  The
    contention model uses this to zero out ``flushed_reads`` claims the
    schedule cannot support.
    """
    for s in sched.steps[sched.retry_from:]:
        if s.op in ("read", "cas") and not _loc_is_volatile(queue, s.loc):
            return True
    return False


# --------------------------------------------------------------------------
# compiler
# --------------------------------------------------------------------------
# runtime opcodes.  K_PENDW / K_DRAINF are the compiler's drain fusion:
# a write whose line is provably drained by a fence later in the same op
# skips store-log materialization entirely (K_PENDW applies only the
# coherent-view store), and the fence's K_DRAINF applies the persistent
# image directly -- pre-existing log entries (recycled lines) take the
# generic order-preserving branch at runtime.
(K_CLASS_P, K_CLASS_V, K_STATE, K_VVAL, K_LOGW, K_PMEMW, K_LINE, K_DRAIN,
 K_NT, K_NTAPPLY, K_CASTAG, K_STAMP, K_PENDW, K_DRAINF) = range(14)

# K_STATE modes
ST_INVAL = 0     # invalidating flush: cached=0, finval=1, everfl=1
ST_EVERFL = 1    # retaining flush: everfl=1, cache state untouched
ST_RECACHE = 2   # post-flush re-touch: cached=1, finval=0


def _compile_addr(queue, loc: L):
    """Location -> runtime address descriptor (mode, a, b)."""
    if loc.is_root:
        base = getattr(queue, loc.base)
        if loc.per_tid:
            return (2, base, loc.off)
        return (0, base + loc.off)
    return (1, _SYM_INDEX[loc.base], loc.off)


def _compile_val(queue, val: Val):
    """Value expression -> closure(env, item, idx, tid)."""
    tag = val[0]
    if tag == "c":
        x = val[1]
        return lambda env, item, idx, tid: x
    if tag == "item":
        return lambda env, item, idx, tid: item
    if tag == "idx":
        return lambda env, item, idx, tid: idx
    if tag == "sym":
        i = _SYM_INDEX[val[1]]
        return lambda env, item, idx, tid: env[i]
    if tag == "tup":
        f1 = _compile_val(queue, val[1])
        f2 = _compile_val(queue, val[2])
        return lambda env, item, idx, tid: (f1(env, item, idx, tid),
                                            f2(env, item, idx, tid))
    if tag == "slot":
        attr, i = val[1], val[2]
        slots = getattr(queue, attr)
        return lambda env, item, idx, tid: slots[tid][i]
    raise ValueError(f"unknown value expr {val!r}")


class CompiledOp:
    """One (queue, kind, model) schedule lowered to a count vector + a
    short effect program over the engine arrays.

    ``prog`` is the backend-neutral opcode list; ``guard_specs`` /
    ``aux_specs`` keep the declarative forms so the codegen backend can
    translate them without re-walking the schedule.  ``n_class`` counts
    the dynamic classification points (each contributes one 4-bit outcome
    nibble to the codegen backend's cache key)."""

    __slots__ = ("kind", "base_counts", "prog", "aux", "event_kind",
                 "uses_ssmem", "allocs_p", "allocs_v", "guards",
                 "guard_specs", "aux_specs", "n_class", "_veccache",
                 "_tcache", "_deferred")

    def __init__(self, kind):
        self.kind = kind
        self.base_counts = np.zeros(N_EV, dtype=np.int64)
        self.prog: List[tuple] = []
        self.aux: List[tuple] = []
        self.event_kind: Optional[str] = None
        self.uses_ssmem = True
        self.allocs_p = False
        self.allocs_v = False
        self.guards: List[Callable] = []
        self.guard_specs: Tuple[tuple, ...] = ()
        self.aux_specs: List[tuple] = []
        self.n_class = 0
        self._veccache: Dict[Any, np.ndarray] = {}
        self._tcache: Dict[int, float] = {}    # key -> op time delta (ns)
        self._deferred: Dict[tuple, int] = {}  # (tid, key) -> pending ops

    def counts_for(self, dyn: tuple) -> np.ndarray:
        vec = self._veccache.get(dyn)
        if vec is None:
            vec = self.base_counts.copy()
            for c in dyn:
                vec[c] += 1
            self._veccache[dyn] = vec
        return vec

    def counts_for_key(self, key: int) -> np.ndarray:
        """Codegen-backend variant: outcomes packed as 4-bit nibbles."""
        vec = self._veccache.get(key)
        if vec is None:
            vec = self.base_counts.copy()
            k = key
            for _ in range(self.n_class):
                vec[k & 15] += 1
                k >>= 4
            self._veccache[key] = vec
        return vec

    def time_for_key(self, key: int, ns_vec: np.ndarray) -> float:
        """Simulated time one op with outcome `key` advances the thread's
        clock by.  Exact-float territory: every model latency is a
        multiple of 0.5ns, so clock += delta reproduces the engine's
        counts-dot-latency reduction bit for bit (the executor checks the
        invariant before enabling incremental clocks)."""
        t = self._tcache.get(key)
        if t is None:
            t = float(self.counts_for_key(key) @ ns_vec)
            self._tcache[key] = t
        return t


class ScheduleError(ValueError):
    """A schedule the compiler cannot prove equivalent (authoring bug)."""


def compile_schedule(queue, sched: OpSchedule, model) -> CompiledOp:
    """Lower one op schedule against a memory model + queue instance."""
    op = CompiledOp(sched.kind)
    op.uses_ssmem = sched.uses_ssmem
    base = op.base_counts
    prog = op.prog
    # symbolic cache state: line key -> None (unknown) | 'cached' | 'inv'
    # line keys: (base, off // LINE_WORDS, per_tid); node symbols are
    # line-aligned and distinct symbols never alias intra-op
    pstate: Dict[tuple, Optional[str]] = {}
    vstate: Dict[tuple, bool] = {}          # volatile word touched intra-op
    flushed_since_fence: List[tuple] = []   # (line key, addr desc)
    flushed_pending_keys: set = set()
    nt_since_fence: List[tuple] = []        # (line key, addr desc, valfn)
    # positions of each line's last write/flush since the last fence: the
    # compiled fence drains a flushed line's FULL log, which is exact iff
    # no write to it lands after its last pre-fence flush
    seq = [0]
    last_write: Dict[tuple, int] = {}
    last_flush: Dict[tuple, int] = {}
    # prog indices of since-fence writes per line key (drain fusion)
    writes_map: Dict[tuple, List[int]] = {}

    def lkey(loc: L) -> tuple:
        return (loc.base, loc.off // LINE_WORDS, loc.per_tid)

    def addr(loc: L):
        return _compile_addr(queue, loc)

    def stamp(loc: L) -> None:
        # contention epoch stamp for a statically-classified touch (the
        # engine stamps on EVERY touch; one stamp per line per op is
        # equivalent -- the epoch does not change intra-op)
        prog.append((K_STAMP, addr(loc)))

    def touch_p(loc: L) -> None:
        k = lkey(loc)
        st = pstate.get(k)
        if st is None:
            prog.append((K_CLASS_P, addr(loc)))
            pstate[k] = "cached"
        elif st == "cached":
            base[EV_HIT] += 1
            stamp(loc)
        else:   # invalidated by an intra-op flush: the paper's penalty
            base[EV_POSTFLUSH] += 1
            prog.append((K_STATE, addr(loc), ST_RECACHE))
            pstate[k] = "cached"
            stamp(loc)

    def touch_v(loc: L) -> None:
        k = (loc.base, loc.off)
        if vstate.get(k):
            base[EV_HIT] += 1
        else:
            prog.append((K_CLASS_V, addr(loc)))
            vstate[k] = True

    def write_effect(loc: L, valfn, spec: Val) -> None:
        if _loc_is_volatile(queue, loc):
            prog.append((K_VVAL, addr(loc), valfn, spec))
            return
        seq[0] += 1
        last_write[lkey(loc)] = seq[0]
        if model.persist_on_store:
            prog.append((K_PMEMW, addr(loc), valfn, spec))
        else:
            prog.append((K_LOGW, addr(loc), valfn, spec))
            writes_map.setdefault(lkey(loc), []).append(len(prog) - 1)

    for si, s in enumerate(sched.steps):
        kind = s.op
        if kind == "alloc_p":
            op.allocs_p = True
        elif kind == "alloc_v":
            op.allocs_v = True
        elif kind == "read":
            base[EV_READ] += 1
            if _loc_is_volatile(queue, s.loc):
                touch_v(s.loc)
            else:
                touch_p(s.loc)
        elif kind == "write":
            base[EV_WRITE] += 1
            valfn = _compile_val(queue, s.val)
            if _loc_is_volatile(queue, s.loc):
                touch_v(s.loc)
            else:
                touch_p(s.loc)
            write_effect(s.loc, valfn, s.val)
        elif kind == "write_line":
            if _loc_is_volatile(queue, s.loc):
                raise ScheduleError("write_line is persistent-only in "
                                    "the queue schedules")
            base[EV_WRITE] += 1
            base[EV_HIT] += 1
            k = lkey(s.loc)
            seq[0] += 1
            last_write[k] = seq[0]
            prog.append((K_LINE, addr(s.loc), tuple(s.tpl), s.item_at,
                         bool(model.persist_on_store), False))
            if not model.persist_on_store:
                writes_map.setdefault(k, []).append(len(prog) - 1)
            pstate[k] = "cached"
        elif kind == "cas":
            base[EV_CAS] += 1
            valfn = _compile_val(queue, s.val)
            vol = _loc_is_volatile(queue, s.loc)
            if vol:
                touch_v(s.loc)
            else:
                touch_p(s.loc)
            write_effect(s.loc, valfn, s.val)
            prog.append((K_CASTAG, addr(s.loc), vol))
            if s.event is not None:
                if op.event_kind is not None:
                    raise ScheduleError("one linearization event per op")
                op.event_kind = s.event
        elif kind == "flush":
            if not model.needs_flush:
                continue          # pflush elided by the platform
            if _loc_is_volatile(queue, s.loc):
                raise ScheduleError("flushing volatile memory")
            base[EV_FLUSH] += 1
            k = lkey(s.loc)
            seq[0] += 1
            last_flush[k] = seq[0]
            if k not in flushed_pending_keys:
                flushed_since_fence.append((k, addr(s.loc)))
                flushed_pending_keys.add(k)
            if model.flush_invalidates:
                prog.append((K_STATE, addr(s.loc), ST_INVAL))
                pstate[k] = "inv"
            else:
                prog.append((K_STATE, addr(s.loc), ST_EVERFL))
        elif kind == "movnti":
            base[EV_MOVNTI] += 1
            if _loc_is_volatile(queue, s.loc):
                raise ScheduleError("movnti targets persistent memory")
            valfn = _compile_val(queue, s.val)
            prog.append((K_NT, addr(s.loc), valfn, s.val))
            nt_since_fence.append((lkey(s.loc), addr(s.loc), valfn, s.val))
        elif kind == "fence":
            base[EV_FENCE] += 1
            for k in flushed_pending_keys:
                if last_write.get(k, -1) > last_flush[k]:
                    raise ScheduleError(
                        f"{sched.kind}: write to {k} after its last flush "
                        "before the fence -- the compiled drain would "
                        "over-apply it")
            lines = {k for k, _ in flushed_since_fence}
            lines |= {k for k, _, _, _ in nt_since_fence}
            base[EV_FENCE_LINE] += len(lines)
            for k, a in flushed_since_fence:
                idxs = writes_map.get(k)
                if not idxs:
                    prog.append((K_DRAIN, a))
                    continue
                # drain fusion: this op's own writes to the line skip log
                # materialization; the fence applies them to the
                # persistent image directly (a pre-existing log -- e.g. a
                # recycled line -- takes the generic branch at runtime)
                deferred, total = [], 0
                for i in sorted(idxs):
                    ins = prog[i]
                    if ins[0] == K_LOGW:
                        prog[i] = (K_PENDW, ins[1], ins[2], ins[3])
                        deferred.append(("w", ins[1], ins[2], ins[3]))
                        total += 1
                    else:   # K_LINE
                        prog[i] = (K_LINE, ins[1], ins[2], ins[3], ins[4],
                                   True)
                        deferred.append(("line", ins[1], ins[2], ins[3]))
                        total += LINE_WORDS
                prog.append((K_DRAINF, a, tuple(deferred), total))
            for _, a, valfn, spec in nt_since_fence:
                prog.append((K_NTAPPLY, a, valfn, spec))
            flushed_since_fence = []
            flushed_pending_keys = set()
            nt_since_fence = []
            last_write.clear()
            last_flush.clear()
            writes_map.clear()
        elif kind == "retire":
            op.aux.append(("retire", _compile_val(queue, s.val)))
            op.aux_specs.append(("retire", s.val))
        elif kind == "retire_v":
            op.aux.append(("retire_v", _compile_val(queue, s.val)))
            op.aux_specs.append(("retire_v", s.val))
        elif kind == "slot_set":
            op.aux.append(("slot", getattr(queue, s.attr),
                           _compile_val(queue, s.val)))
            op.aux_specs.append(("slot", s.attr, s.val))
        elif kind == "persisted_discard":
            op.aux.append(("pdiscard", _SYM_INDEX[s.attr]))
            op.aux_specs.append(("pdiscard", s.attr))
        elif kind == "persisted_add":
            op.aux.append(("padd", tuple(_SYM_INDEX[x] for x in s.syms)))
            op.aux_specs.append(("padd", s.syms))
        else:
            raise ScheduleError(f"unknown step {kind!r}")
    if flushed_since_fence or nt_since_fence:
        raise ScheduleError(
            f"{sched.kind}: schedule ends with unfenced persists -- the "
            "next op's PendingEmpty bail guard would never hold")
    op.n_class = sum(1 for ins in prog if ins[0] in (K_CLASS_P, K_CLASS_V))
    if op.n_class > 15:
        raise ScheduleError("more than 15 dynamic classification points "
                            "per op (nibble key overflow)")
    op.guard_specs = tuple(sched.guards)
    # guards
    for g in sched.guards:
        if g[0] == "slot_nonnull":
            slots = getattr(queue, g[1])

            def _g_slot(ex, tid, _slots=slots):
                v = _slots[tid]
                if v == NULL:
                    return False
                ex.env[E_PREV] = v
                return True
            op.guards.append(_g_slot)
        elif g[0] == "tail_persisted":
            pers = queue._persisted

            def _g_pers(ex, tid, _pers=pers):
                t = ex.fifo[-1] if ex.fifo else ex.dummy
                return t[0] in _pers
            op.guards.append(_g_pers)
        else:
            raise ScheduleError(f"unknown guard {g!r}")
    return op


# --------------------------------------------------------------------------
# codegen backend
# --------------------------------------------------------------------------
# The interpreter above is the readable reference backend; this lowers the
# same CompiledOp program to one specialized Python function per (queue,
# kind, model) -- straight-line code over hoisted engine arrays with every
# address/constant baked in.  Both backends execute the identical opcode
# list, and the equivalence suite pins both against real per-op execution.

def _addr_src(a) -> str:
    if a[0] == 0:
        return str(a[1])
    if a[0] == 1:
        name = _SYMS[a[1]]
        return name if a[2] == 0 else f"({name} + {a[2]})"
    return f"({a[1] + a[2]} + tid * {LINE_WORDS})"


def _line_src(a) -> str:
    if a[0] == 0:
        return str(a[1] // LINE_WORDS)
    s = _addr_src(a)
    # bare names need no parens -- keeps the rendering canonical so the
    # CSE pass unifies this with the K_LINE/K_LOGW spellings
    if s.isidentifier():
        return f"{s} // {LINE_WORDS}"
    return f"({s}) // {LINE_WORDS}"


def _val_src(v: Val) -> str:
    tag = v[0]
    if tag == "c":
        return repr(v[1])
    if tag == "item":
        return "item"
    if tag == "idx":
        return "idx"
    if tag == "sym":
        return v[1]
    if tag == "tup":
        return f"({_val_src(v[1])}, {_val_src(v[2])})"
    if tag == "slot":
        return f"q.{v[1]}[tid][{v[2]}]"
    raise ScheduleError(f"unknown value expr {v!r}")


_VB = NVRAM._VOLATILE_BASE


def _emit_prog(emit, op: CompiledOp, tracking: bool,
               values_only: bool = False) -> None:
    """Emit the effect-program body shared by both codegen variants.

    Line-state transitions go through the engine's packed ``_lstate``
    byte array: dynamic touches read one byte and apply the
    ``TOUCH_CLASS``/``TOUCH_NEXT`` tables (bound as ``_CT``/``_NS``),
    static transitions write the packed constant directly.  ``tracking``
    emits the contention-epoch taps (legacy variant only; the columnar
    variant is dispatched exclusively with tracking off).

    ``values_only`` keeps just the value-carrying effects (vis/pmem/vval
    stores, log appends and drains) and drops everything the burst
    executor computes vectorized instead: outcome-key accounting and
    every ``lstate``/``vtouched`` read or write.  The burst automaton
    over the fleet lowering's opcode rows covers exactly the dropped
    transitions, so running this body per grant followed by the
    vectorized line-state scatter reproduces the full-body mutations.

    Address, line-number and volatile-index expressions are pure within
    one op body (they only read ``tid``/``item`` and the node locals
    fixed up front), so repeats are hoisted into ``_c<n>`` locals --
    common-subexpression elimination at the source level.  Value
    expressions stay inline: they may read mutable queue state."""
    cse: dict = {}

    def ref(expr: str) -> str:
        """Hoist a pure expression into a local, once per op body."""
        if expr.isidentifier() or expr.lstrip("-").isdigit():
            return expr
        v = cse.get(expr)
        if v is None:
            v = f"_c{len(cse)}"
            emit(f"    {v} = {expr}")
            cse[expr] = v
        return v

    def vals_ref(vals: List[str]) -> str:
        """One shared list object per distinct line-literal (the writers
        only ever copy out of it, never mutate it)."""
        return ref(f"[{', '.join(vals)}]")

    def line_of(a: str) -> str:
        """Line number of an already-rendered address expression."""
        if a.lstrip("-").isdigit():
            return str(int(a) // LINE_WORDS)
        return ref(f"{a} // {LINE_WORDS}")

    prog = op.prog
    for pc, ins in enumerate(prog):
        code = ins[0]
        if code in (K_CLASS_P, K_CLASS_V, K_STATE) and values_only:
            continue
        if code == K_CLASS_P:
            ln = ref(_line_src(ins[1]))
            if tracking:
                emit("    if tk:")
                emit(f"        le[{ln}] = ep")
            emit(f"    key = key << 4 | _CT[(_s := lstate[{ln}])]")
            emit(f"    lstate[{ln}] = _NS[_s]")
        elif code == K_CLASS_V:
            # branchless: untouched -> EV_DRAM (8), touched -> EV_HIT (7)
            vi = ref(f"{_addr_src(ins[1])} - {_VB}")
            emit(f"    key = key << 4 | ({EV_DRAM} - vtouched[{vi}])")
            emit(f"    vtouched[{vi}] = 1")
        elif code == K_STATE:
            mode = ins[2]
            ln = ref(_line_src(ins[1]))
            if mode == ST_INVAL:
                emit(f"    lstate[{ln}] = {LS_FINVAL | LS_EVERFL}")
            elif mode == ST_EVERFL:
                emit(f"    lstate[{ln}] |= {LS_EVERFL}")
            else:
                # ST_RECACHE provably follows this op's own ST_INVAL on
                # the same line, so the packed state is a constant
                emit(f"    lstate[{ln}] = {LS_CACHED | LS_EVERFL}")
        elif code == K_VVAL:
            vi = ref(f"{_addr_src(ins[1])} - {_VB}")
            emit(f"    vval[{vi}] = {_val_src(ins[3])}")
        elif code == K_LOGW:
            a = ref(_addr_src(ins[1]))
            ln = line_of(a)
            emit(f"    _v = {_val_src(ins[3])}")
            emit(f"    vis[{a}] = _v")
            emit(f"    _lg = log.get({ln})")
            emit("    if _lg is None:")
            emit(f"        log[{ln}] = [({a}, _v)]")
            emit("    else:")
            emit(f"        _lg.append(({a}, _v))")
        elif code == K_PMEMW:
            a = ref(_addr_src(ins[1]))
            emit(f"    _v = {_val_src(ins[3])}")
            emit(f"    vis[{a}] = _v")
            emit(f"    pmem[{a}] = _v")
        elif code == K_LINE:
            vals = [repr(x) for x in ins[2]]
            if ins[3] is not None:
                vals[ins[3]] = "item"
            a = ref(_addr_src(ins[1]))
            ln = line_of(a)
            vl = vals_ref(vals)
            emit(f"    vis[{a}:{a} + {LINE_WORDS}] = {vl}")
            if ins[4]:              # eADR: visible => durable
                emit(f"    pmem[{a}:{a} + {LINE_WORDS}] = {vl}")
            elif not ins[5]:        # materialize unless drain-fused
                emit(f"    _lg = log.get({ln})")
                emit(f"    _ents = list(zip(range({a}, {a} + "
                     f"{LINE_WORDS}), {vl}))")
                emit("    if _lg is None:")
                emit(f"        log[{ln}] = _ents")
                emit("    else:")
                emit("        _lg.extend(_ents)")
            # dead-store elimination: skip the cached-bit write when the
            # very next instruction overwrites this same line's state with
            # a constant (ST_INVAL/ST_RECACHE); nothing reads it between
            nxt = prog[pc + 1] if pc + 1 < len(prog) else None
            if values_only:
                pass
            elif not (nxt is not None and nxt[0] == K_STATE
                      and nxt[2] in (ST_INVAL, ST_RECACHE)
                      and nxt[1] == ins[1]):
                emit(f"    lstate[{ln}] = lstate[{ln}] & {LS_EVERFL} | "
                     f"{LS_CACHED}")
        elif code == K_PENDW:
            emit(f"    vis[{ref(_addr_src(ins[1]))}] = {_val_src(ins[3])}")
        elif code == K_DRAIN:
            ln = ref(_line_src(ins[1]))
            emit(f"    _lg = log.get({ln})")
            emit("    if _lg:")
            emit("        for _wa, _wv in _lg:")
            emit("            pmem[_wa] = _wv")
            emit(f"        ls[{ln}] += len(_lg)")
            emit("        _lg.clear()")
        elif code == K_DRAINF:
            ln = ref(_line_src(ins[1]))
            emit(f"    _lg = log.get({ln})")
            emit("    if _lg:")
            emit("        for _wa, _wv in _lg:")
            emit("            pmem[_wa] = _wv")
            emit("        _n0 = len(_lg)")
            emit("        _lg.clear()")
            emit("    else:")
            emit("        _n0 = 0")
            for ent in ins[2]:
                if ent[0] == "w":
                    emit(f"    pmem[{ref(_addr_src(ent[1]))}] = "
                         f"{_val_src(ent[3])}")
                else:
                    vals = [repr(x) for x in ent[2]]
                    if ent[3] is not None:
                        vals[ent[3]] = "item"
                    a = ref(_addr_src(ent[1]))
                    emit(f"    pmem[{a}:{a} + {LINE_WORDS}] = "
                         f"{vals_ref(vals)}")
            emit(f"    ls[{ln}] += _n0 + {ins[3]}")
        elif code == K_NT:
            emit(f"    vis[{ref(_addr_src(ins[1]))}] = {_val_src(ins[3])}")
        elif code == K_NTAPPLY:
            emit(f"    pmem[{ref(_addr_src(ins[1]))}] = {_val_src(ins[3])}")
        elif code == K_CASTAG:
            if tracking:
                # inside `if tk:` -- must not hoist into the taken path
                emit("    if tk:")
                emit(f"        _a = {_addr_src(ins[1])}")
                emit("        cw[_a] = cw.get(_a, 0) + 1")
                if ins[2]:
                    emit(f"        le[_a // {LINE_WORDS}] = ep")
        else:   # K_STAMP
            if tracking:
                emit("    if tk:")
                emit(f"        le[{_line_src(ins[1])}] = ep")


def _emit_aux(emit, op: CompiledOp) -> None:
    for ax in op.aux_specs:
        t0 = ax[0]
        if t0 == "retire":
            # inlined SSMem.retire: limbo-append under the current epoch
            emit(f"    mem._limbo[tid].append(({_val_src(ax[1])}, "
                 "mem._epoch, 'p'))")
        elif t0 == "retire_v":
            emit(f"    mem._limbo[tid].append(({_val_src(ax[1])}, "
                 "mem._epoch, 'v'))")
        elif t0 == "slot":
            emit(f"    q.{ax[1]}[tid] = {_val_src(ax[2])}")
        elif t0 == "pdiscard":
            emit(f"    q._persisted.discard({ax[1]})")
        else:   # padd
            for s in ax[1]:
                emit(f"    q._persisted.add({s})")


def generate_fast_fn(queue, op: CompiledOp) -> Callable:
    """Translate one CompiledOp into a specialized fast-op function
    ``fn(ex, tid, item) -> time-delta | None`` via source generation
    (the legacy-record variant: per-op ``ex.record`` callback + deferred
    per-(tid, key) charge dict)."""
    w: List[str] = []
    emit = w.append
    kind = op.kind
    emit("def _fast_op(ex, tid, item):")
    emit("    nv = ex.nv")
    emit("    if nv.crashed or nv._pending[tid]:")
    emit("        return None")
    emit("    fifo = ex.fifo")
    emit("    q = ex.q")
    if kind == "deq":
        emit("    if not fifo:")
        emit("        return None")
    else:
        emit("    _t = fifo[-1] if fifo else ex.dummy")
    for g in op.guard_specs:
        if g[0] == "slot_nonnull":
            emit(f"    prev = q.{g[1]}[tid]")
            emit("    if prev == 0:")
            emit("        return None")
        else:   # tail_persisted
            emit("    if _t[0] not in q._persisted:")
            emit("        return None")
    if op.uses_ssmem:
        emit("    mem = q.mem")
    if op.allocs_p:
        emit("    if not mem._free[tid] and (not mem._areas[tid]")
        emit("            or mem._cursor[tid] >= mem.area_nodes):")
        emit("        return None")
    if op.uses_ssmem:
        emit("    mem.op_begin(tid)")
    if kind == "enq":
        emit("    tail_p = _t[0]")
        emit("    tail_v = _t[1]")
        emit("    idx = (_t[3] or 0) + 1")
    else:
        emit("    _d = ex.dummy")
        emit("    _n = fifo[0]")
        emit("    head_p = _d[0]")
        emit("    head_v = _d[1]")
        emit("    next_p = _n[0]")
        emit("    next_v = _n[1]")
        emit("    idx = _n[3]")
        emit("    result = _n[2]")
    if op.allocs_p:
        emit("    new_p = mem.alloc(tid)")
    if op.allocs_v:
        emit("    new_v = q.valloc.alloc(tid)")
    # hoist exactly the engine structures the program touches
    codes = {ins[0] for ins in op.prog}
    if codes & {K_CLASS_P, K_STATE, K_LINE}:
        emit("    lstate = nv._lstate")
    if codes & {K_CLASS_V}:
        emit("    vtouched = nv._vtouched")
    if codes & {K_VVAL}:
        emit("    vval = nv._vval")
    if codes & {K_LOGW, K_PMEMW, K_LINE, K_NT, K_PENDW}:
        emit("    vis = nv._vis")
    if codes & {K_PMEMW, K_DRAIN, K_DRAINF, K_NTAPPLY} or \
            (K_LINE in codes and any(ins[0] == K_LINE and ins[4]
                                     for ins in op.prog)):
        emit("    pmem = nv._pmem")
    if codes & {K_LOGW, K_DRAIN, K_DRAINF} or \
            (K_LINE in codes and any(ins[0] == K_LINE and not ins[4]
                                     for ins in op.prog)):
        emit("    log = nv._log")
    if codes & {K_DRAIN, K_DRAINF}:
        emit("    ls = nv._log_start")
    if codes & {K_CLASS_P, K_CASTAG, K_STAMP}:
        emit("    tk = nv.contention_tracking")
        emit("    if tk:")
        emit("        le = nv._line_epoch")
        emit("        ep = nv.epoch")
        if K_CASTAG in codes:
            emit("        cw = nv._cas_words")
    emit("    key = 0")
    _emit_prog(emit, op, tracking=True)
    # defer the count charge (flushed in bulk by the executor) and return
    # the op's exact clock advance -- see CompiledOp.time_for_key
    emit("    _k = (tid, key)")
    emit("    _n = _dc.get(_k)")
    emit("    _dc[_k] = 1 if _n is None else _n + 1")
    emit("    _t = _tc.get(key)")
    emit("    if _t is None:")
    emit("        _t = _op.time_for_key(key, nv._ns_vec)")
    if kind == "enq":
        np_src = "new_p" if op.allocs_p else "0"
        nv_src = "new_v" if op.allocs_v else "None"
        emit(f"    fifo.append(({np_src}, {nv_src}, item, idx))")
    else:
        emit("    ex.dummy = fifo.popleft()")
    _emit_aux(emit, op)
    res = "item" if kind == "enq" else "result"
    if op.event_kind is not None:
        emit(f"    q.on_event(({op.event_kind!r}, {res}))")
    emit(f"    ex.record(tid, {kind!r}, {res})")
    emit("    ex.fast_ops += 1")
    emit("    return _t")
    src = "\n".join(w)
    g = {"_op": op, "_vc": op._veccache, "_dc": op._deferred,
         "_tc": op._tcache, "_CT": TOUCH_CLASS, "_NS": TOUCH_NEXT}
    exec(compile_cached(src, f"<opsched:{type(queue).__name__}.{kind}>"), g)
    fn = g["_fast_op"]
    fn.__source__ = src
    return fn


def generate_columnar_fn(queue, op: CompiledOp, nvram: NVRAM, fifo: deque,
                         dbox: list) -> Callable:
    """Translate one CompiledOp into the columnar-record fast-op variant
    ``fn(tid, item, t_start) -> post-op clock | None``.

    The per-op tail is three plain-list appends into the attached
    :class:`repro.core.records.RecordStore` staging buffers (one packed
    ``key << META_KEY_SHIFT | tid << 1 | kind`` word, the op's item, the
    post-op clock); the whole burst is materialized and charged in one
    vector pass at :meth:`~repro.core.records.RecordStore.sync`.  Every
    engine container the body touches is bound as a keyword-only default
    (the engine's identity-stability contract makes that safe across
    crash/restore); ``sm``/``si``/``st`` start as ``None`` placeholders
    and are rebound by ``FastPathExecutor.attach_store``.  Only generated
    when the outcome key fits the staging word (``n_class <=
    MAX_STAGED_NCLASS``, ``nthreads <= MAX_STAGED_THREADS``); dispatched
    by :class:`repro.core.scheduler.ClockScheduler` only with no
    contention model and tracking off, so the epoch/CAS taps compile to
    nothing."""
    w: List[str] = []
    emit = w.append
    kind = op.kind
    codes = {ins[0] for ins in op.prog}
    params = [("nv", "_NV"), ("pending", "_PENDING"), ("fifo", "_FIFO"),
              ("dbox", "_DBOX"), ("q", "_Q")]
    if op.uses_ssmem:
        params.append(("mem", "_MEM"))
    if op.allocs_v:
        params.append(("valloc", "_VALLOC"))
    if codes & {K_CLASS_P, K_STATE, K_LINE}:
        params.append(("lstate", "_LSTATE"))
    if K_CLASS_V in codes:
        params.append(("vtouched", "_VTOUCHED"))
    if K_VVAL in codes:
        params.append(("vval", "_VVAL"))
    if codes & {K_LOGW, K_PMEMW, K_LINE, K_NT, K_PENDW}:
        params.append(("vis", "_VIS"))
    if codes & {K_PMEMW, K_DRAIN, K_DRAINF, K_NTAPPLY} or \
            any(ins[0] == K_LINE and ins[4] for ins in op.prog):
        params.append(("pmem", "_PMEM"))
    if codes & {K_LOGW, K_DRAIN, K_DRAINF} or \
            any(ins[0] == K_LINE and not ins[4] for ins in op.prog):
        params.append(("log", "_LOG"))
    if codes & {K_DRAIN, K_DRAINF}:
        params.append(("ls", "_LS"))
    if K_CLASS_P in codes:
        params += [("_CT", "_TCT"), ("_NS", "_TNS")]
    params += [("_tc", "_tc"), ("_op", "_op"),
               ("sm", "None"), ("si", "None"), ("st", "None")]
    # plain positional defaults, not keyword-only: CPython resolves them
    # from the code object's defaults tuple with no per-call dict lookups
    # (measurably cheaper at this call rate); attach_store rebinds the
    # trailing sm/si/st slots through fn.__defaults__
    sig = ", ".join(f"{n}={d}" for n, d in params)
    emit(f"def _fast_op(tid, item, t_start, {sig}):")
    emit("    if nv.crashed or pending[tid]:")
    emit("        return None")
    if kind == "deq":
        emit("    if not fifo:")
        emit("        return None")
    else:
        emit("    _t = fifo[-1] if fifo else dbox[0]")
    for g in op.guard_specs:
        if g[0] == "slot_nonnull":
            emit(f"    prev = q.{g[1]}[tid]")
            emit("    if prev == 0:")
            emit("        return None")
        else:   # tail_persisted
            emit("    if _t[0] not in q._persisted:")
            emit("        return None")
    if op.allocs_p:
        # _mf is the per-thread free list OBJECT (never rebound by ssmem,
        # only popped/appended), so reading it before op_begin is safe:
        # an epoch advance inside op_begin refills this same list
        emit("    _mf = mem._free[tid]")
        emit("    if not _mf and (not mem._areas[tid]")
        emit("            or mem._cursor[tid] >= mem.area_nodes):")
        emit("        return None")
    if op.uses_ssmem:
        # inlined SSMem.op_begin: announce under the CURRENT epoch, then
        # bump the shared op counter; the 64th op resets it and runs the
        # (rare) epoch advance.  check-then-increment here is the same
        # automaton as op_begin's increment-then-check -- state 63 maps
        # to a reset + advance either way
        emit("    mem._announced[tid] = mem._epoch")
        emit("    if mem._ops_since_adv >= 63:")
        emit("        mem._ops_since_adv = 0")
        emit("        mem._try_advance()")
        emit("    else:")
        emit("        mem._ops_since_adv += 1")
    if kind == "enq":
        emit("    tail_p = _t[0]")
        emit("    tail_v = _t[1]")
        emit("    idx = (_t[3] or 0) + 1")
    else:
        emit("    _d = dbox[0]")
        emit("    _n = fifo[0]")
        emit("    head_p = _d[0]")
        emit("    head_v = _d[1]")
        emit("    next_p = _n[0]")
        emit("    next_v = _n[1]")
        emit("    idx = _n[3]")
        emit("    result = _n[2]")
    if op.allocs_p:
        # inlined SSMem.alloc fast paths.  The pop must be decided AFTER
        # op_begin: its epoch advance can refill _mf, and the real alloc
        # would see that refill.  The bump branch never needs _new_area --
        # the guard above proved area space exists when _mf was empty, and
        # the advance only ever grows _mf
        emit("    if _mf:")
        emit("        new_p = _mf.pop()")
        emit("    else:")
        emit("        _cu = mem._cursor[tid]")
        emit(f"        new_p = mem._areas[tid][-1] + _cu * {LINE_WORDS}")
        emit("        mem._cursor[tid] = _cu + 1")
    if op.allocs_v:
        # inlined VolatileAlloc.alloc fast path (free-list pop); the
        # chunk-refill slow path stays an out-of-line call
        emit("    _vf = valloc._free[tid]")
        emit("    if _vf:")
        emit("        new_v = _vf.pop()")
        emit("    else:")
        emit("        new_v = valloc.alloc(tid)")
    emit("    key = 0")
    _emit_prog(emit, op, tracking=False)
    emit("    _t2 = _tc.get(key)")
    emit("    if _t2 is None:")
    emit("        _t2 = _op.time_for_key(key, nv._ns_vec)")
    if kind == "enq":
        np_src = "new_p" if op.allocs_p else "0"
        nv_src = "new_v" if op.allocs_v else "None"
        emit(f"    fifo.append(({np_src}, {nv_src}, item, idx))")
    else:
        emit("    dbox[0] = fifo.popleft()")
    _emit_aux(emit, op)
    emit("    _te = t_start + _t2")
    kbit = 0 if kind == "enq" else 1
    emit(f"    sm.append(key << {META_KEY_SHIFT} | tid << 1 | {kbit})")
    emit("    si.append(item)" if kind == "enq" else "    si.append(result)")
    emit("    st.append(_te)")
    emit("    return _te")
    src = "\n".join(w)
    g = {"_op": op, "_tc": op._tcache, "_TCT": TOUCH_CLASS,
         "_TNS": TOUCH_NEXT, "_NV": nvram, "_PENDING": nvram._pending,
         "_FIFO": fifo, "_DBOX": dbox, "_Q": queue,
         "_MEM": getattr(queue, "mem", None),
         "_VALLOC": getattr(queue, "valloc", None),
         "_LSTATE": nvram._lstate,
         "_VTOUCHED": nvram._vtouched, "_VVAL": nvram._vval,
         "_VIS": nvram._vis, "_PMEM": nvram._pmem, "_LOG": nvram._log,
         "_LS": nvram._log_start}
    exec(compile_cached(
        src, f"<opsched-col:{type(queue).__name__}.{kind}>"), g)
    fn = g["_fast_op"]
    fn.__source__ = src
    fn.__params__ = params      # (name, global-name) pairs, in order
    return fn


def generate_columnar_runner(cfns: dict, queue) -> Callable:
    """Merge the two columnar fast-op bodies into ONE generated function
    that owns the whole clock-heap loop.

    The per-op call frames (scheduler -> fast-op) are the last fixed cost
    once the bodies themselves are lean, so the runner splices the
    generated enq/deq sources inline -- each body's early ``return None``
    bails become breaks out of a one-shot ``while True`` block, landing in
    a shared bail arm that defers to the scheduler-provided ``bail``
    callback (staged-burst sync + real thunk + clock stitch).  Bit
    identity is untouched: the spliced text IS the fast-op bodies, only
    the calling convention changed.  ``sm``/``si``/``st`` stay the last
    three positional defaults so ``FastPathExecutor.attach_store`` rebinds
    the runner exactly like the fns it was spliced from.
    """
    fenq, fdeq = cfns["enq"], cfns["deq"]
    # merged bound-parameter spec: enq's engine params, deq-only extras,
    # the per-op caches disambiguated (_tc/_op -> _tcd/_opd for deq),
    # staging buffers last
    env: dict = {}
    params: List[Tuple[str, str]] = []
    seen = set()
    renames_deq = {"_tc": "_tcd", "_op": "_opd"}
    for fn, renames in ((fenq, {}), (fdeq, renames_deq)):
        vals = dict(zip([n for n, _ in fn.__params__], fn.__defaults__))
        for name, gname in fn.__params__:
            if name in ("sm", "si", "st"):
                continue
            tgt = renames.get(name, name)
            if tgt in seen:
                continue
            seen.add(tgt)
            g_tgt = "_G" + tgt
            params.append((tgt, g_tgt))
            env[g_tgt] = vals[name]
    params += [("sm", "None"), ("si", "None"), ("st", "None")]

    def splice(fn, renames) -> List[str]:
        out = []
        for line in fn.__source__.splitlines()[1:]:
            stripped = line.strip()
            pad = " " * (len(line) - len(line.lstrip())) + " " * 12
            for old, new in renames.items():
                line = line.replace(f"{old}.", f"{new}.")
            if stripped == "return None":
                out.append(pad + "_te = None")
                out.append(pad + "break")
            elif stripped == "return _te":
                out.append(pad + "break")
            else:
                out.append(" " * 12 + line)
        return out

    sig = ", ".join(f"{n}={d}" for n, d in params)
    w: List[str] = []
    emit = w.append
    emit(f"def _runner(heap, cursors, op_kinds, op_items, lens, bail, "
         f"nops=-1, heappop=_HPOP, heappush=_HPUSH, {sig}):")
    emit("    ops_run = 0")
    emit("    while heap and ops_run != nops:")
    emit("        t_start, tid = heappop(heap)")
    emit("        _i = cursors[tid]")
    emit("        if op_kinds[tid][_i] == 'enq':")
    emit("            item = op_items[tid][_i]")
    emit("            _te = None")
    emit("            while True:")
    w.extend(splice(fenq, {}))
    emit("                break")
    emit("            if _te is None:")
    emit("                _te = bail(tid, _i, t_start, 'enq')")
    emit("        else:")
    emit("            item = op_items[tid][_i]")
    emit("            _te = None")
    emit("            while True:")
    w.extend(splice(fdeq, renames_deq))
    emit("                break")
    emit("            if _te is None:")
    emit("                _te = bail(tid, _i, t_start, 'deq')")
    emit("        cursors[tid] = _i + 1")
    emit("        ops_run += 1")
    emit("        if _i + 1 < lens[tid]:")
    emit("            heappush(heap, (_te, tid))")
    emit("    return ops_run")
    src = "\n".join(w)
    env["_HPOP"] = heapq.heappop
    env["_HPUSH"] = heapq.heappush
    exec(compile_cached(src, f"<opsched-runner:{type(queue).__name__}>"),
         env)
    runner = env["_runner"]
    runner.__source__ = src
    return runner


def _op_value_syms(op: CompiledOp) -> set:
    """Node-local symbol names the op's *value* effects read (addresses
    and value expressions of the stores kept by ``values_only``)."""
    used: set = set()

    def _val(v) -> None:
        tag = v[0]
        if tag == "sym":
            used.add(v[1])
        elif tag == "tup":
            _val(v[1])
            _val(v[2])

    def _addr(a) -> None:
        if a is not None and a[0] == 1:
            used.add(_SYMS[a[1]])

    for ins in op.prog:
        code = ins[0]
        if code in (K_CLASS_P, K_CLASS_V, K_STATE, K_CASTAG, K_STAMP):
            continue
        _addr(ins[1])
        if code == K_DRAINF:
            for ent in ins[2]:
                _addr(ent[1])
                if ent[0] == "w":
                    _val(ent[3])
        elif code in (K_VVAL, K_LOGW, K_PMEMW, K_PENDW, K_NT, K_NTAPPLY):
            _val(ins[3])
    return used


def generate_burst_apply_fn(queue, ops: Dict[str, CompiledOp],
                            nvram: NVRAM) -> Callable:
    """Generate the burst executor's merged per-grant value loop.

    The burst path splits each compiled op body in two: everything that
    feeds the outcome key (line-state and volatile-touch transitions) is
    replayed vectorized from the fleet lowering's opcode rows, while the
    value-carrying stores -- which may move arbitrary Python payloads
    through ``vis``/``pmem``/``vval`` and the per-line write logs --
    still need sequential grant-order execution because the engine's
    value containers are plain Python lists.  This emits that sequential
    half as ONE loop over the whole burst: per grant it binds the
    planner-computed node locals from column lists and runs the
    ``values_only`` rendering of the enq or deq body (see
    :func:`_emit_prog`).  Drain branches (``K_DRAIN``/``K_DRAINF``) stay
    exact without any prediction precisely because this loop runs in
    grant order: each drain sees the log contents its predecessors left.

    Signature of the generated fn::

        _burst_apply(n, kb, tids, e_items, e_idxs, <e_syms...>,
                     d_items, d_idxs, <d_syms...>)

    ``kb`` is the per-grant kind bit (1 = deq); each kind's node-local
    columns are *per-kind* lists (indexed by separate enq/deq cursors, so
    the planner never pads the other kind's rows).  The sym column order
    per kind is published as ``fn.__cols__``.  Engine containers ride as
    positional defaults like the columnar fns.
    """
    nv = nvram
    cols = {k: sorted(_op_value_syms(ops[k])) for k in ("enq", "deq")}
    w: List[str] = []
    emit = w.append
    sig = ", ".join(
        [f"e_items, e_idxs"] + [f"e_{s}" for s in cols["enq"]] +
        [f"d_items, d_idxs"] + [f"d_{s}" for s in cols["deq"]])
    emit(f"def _burst_apply(n, kb, tids, {sig}, "
         "vis=_VIS, pmem=_PMEM, vval=_VVAL, log=_LOG, ls=_LS):")
    emit("    g = 0")
    emit("    ge = 0")
    emit("    gd = 0")
    emit("    while g < n:")
    emit("        tid = tids[g]")
    emit("        if kb[g]:")
    for kind, pfx, cur in (("deq", "d", "gd"), ("enq", "e", "ge")):
        op = ops[kind]
        body: List[str] = [f"    item = {pfx}_items[{cur}]",
                           f"    idx = {pfx}_idxs[{cur}]"]
        for s in cols[kind]:
            body.append(f"    {s} = {pfx}_{s}[{cur}]")
        _emit_prog(body.append, op, tracking=False, values_only=True)
        body.append(f"    {cur} += 1")
        w.extend("        " + line for line in body)
        if kind == "deq":
            emit("        else:")
    emit("        g += 1")
    src = "\n".join(w)
    env = {"_VIS": nv._vis, "_PMEM": nv._pmem, "_VVAL": nv._vval,
           "_LOG": nv._log, "_LS": nv._log_start}
    exec(compile_cached(src, f"<burst-apply:{type(queue).__name__}>"), env)
    fn = env["_burst_apply"]
    fn.__source__ = src
    fn.__cols__ = cols
    return fn


# --------------------------------------------------------------------------
# executor
# --------------------------------------------------------------------------
def _peek(nv: NVRAM, addr: int):
    """Raw, unaccounted read of the engine's coherent view (bootstrap and
    resync only -- never on a costed path)."""
    if addr >= NVRAM._VOLATILE_BASE:
        return nv._vval[addr - NVRAM._VOLATILE_BASE]
    return nv._vis[addr]


class FastPathExecutor:
    """Replays compiled steady-state schedules for one batched run.

    Owned by :meth:`repro.core.harness.QueueHarness.run_batched`; driven by
    :class:`repro.core.scheduler.ClockScheduler`.  ``record(tid, kind,
    item)`` is the harness's op-record callback (mirrors the per-op
    thunk's ``OpRecord`` bookkeeping).
    """

    def __init__(self, queue, nvram: NVRAM,
                 record: Optional[Callable[[int, str, Any], None]] = None,
                 backend: str = "codegen"):
        schedules = queue.op_schedule()
        if schedules is None:
            raise ScheduleError(f"{type(queue).__name__} declares no "
                                "op_schedule()")
        self.q = queue
        self.nv = nvram
        self.record = record or (lambda tid, kind, item: None)
        self.layout = schedules.layout
        self.backend = backend
        cache = queue.__dict__.setdefault("_compiled_schedules", {})
        key = nvram.model.name
        ent = cache.get(key)
        # columnar fns bind this engine's containers as keyword defaults,
        # so a cache entry is only valid against the engine it was
        # generated for; regenerate on an engine swap
        if ent is None or ent[3] is not nvram:
            ops = {k: compile_schedule(queue, schedules.of_kind(k),
                                       nvram.model)
                   for k in ("enq", "deq")}
            fns = {k: generate_fast_fn(queue, op) for k, op in ops.items()}
            fifo: deque = deque()
            dbox: list = [None]
            cfns = None
            crunner = None
            if (nvram.nthreads <= MAX_STAGED_THREADS
                    and all(o.n_class <= MAX_STAGED_NCLASS
                            for o in ops.values())):
                cfns = {k: generate_columnar_fn(queue, op, nvram, fifo,
                                                dbox)
                        for k, op in ops.items()}
                crunner = generate_columnar_runner(cfns, queue)
            ent = (ops, fns, cfns, nvram, fifo, dbox, crunner)
            cache[key] = ent
        (self.ops, self._fns, self.cfns, _, self.fifo, self._dbox,
         self.crunner) = ent
        self.env: List[Any] = [NULL] * len(_SYMS)
        self.rstore = None        # columnar RecordStore (attach_store)
        self.fast_ops = 0         # compiled replays
        self.bailed_ops = 0       # fell back to real execution
        # incremental clocks are exact (hence heap-order identical to the
        # engine's counts-dot-latency reduction) iff every latency is a
        # multiple of 0.5ns, so float sums never round
        ns2 = nvram._ns_vec * 2.0
        self.timed = bool(np.all(ns2 == np.round(ns2)))
        if backend == "codegen":
            self.try_op = self._codegen_op
        else:
            self.try_op_timed = self._interp_timed
        self._bootstrap()

    # the logical dummy node lives in a one-slot box shared with the
    # columnar fns (bound as their ``dbox`` default)
    @property
    def dummy(self) -> Optional[tuple]:
        return self._dbox[0]

    @dummy.setter
    def dummy(self, rec: Optional[tuple]) -> None:
        self._dbox[0] = rec

    def attach_store(self, store) -> bool:
        """Wire a :class:`repro.core.records.RecordStore` into this run:
        rebind the columnar fns' staging-list defaults and hand the store
        the engine + compiled ops it charges staged bursts against.
        Returns False (store not attached) when columnar dispatch is
        unavailable -- non-codegen backend, inexact latencies, or an
        outcome key that does not fit the staging word."""
        self.rstore = None
        if (store is None or self.cfns is None
                or self.backend != "codegen" or not self.timed):
            return False
        fns = list(self.cfns.values())
        if self.crunner is not None:
            fns.append(self.crunner)
        for fn in fns:
            # sm/si/st are the last three positional defaults by
            # construction (generate_columnar_fn and the merged runner
            # both append them last)
            fn.__defaults__ = fn.__defaults__[:-3] + (
                store._sm, store._si, store._st)
        store.attach_engine(
            self.nv, (self.ops["enq"], self.ops["deq"]),
            (self.ops["enq"].event_kind, self.ops["deq"].event_kind),
            executor=self)
        self.rstore = store
        return True

    def _codegen_op(self, tid: int, kind: str, item: Any) -> bool:
        """Codegen backend, eager mode (used under a contention model):
        run the generated function, then flush its deferred charge so the
        model's ``after_op`` reads up-to-date engine counts."""
        fn = self._fns.get(kind)
        if fn is None:
            return False
        if fn(self, tid, item) is None:
            return False
        self.flush_counts()
        return True

    def try_op_timed(self, tid: int, kind: str, item: Any,
                     t_start: float) -> Optional[float]:
        """Codegen backend, deferred mode: execute one compiled op and
        return the thread's post-op clock (``t_start`` + the op's exact
        time delta), or None on bail (with pending charges flushed so the
        real thunk and its engine-side clock read are exact)."""
        fn = self._fns.get(kind)
        if fn is not None:
            d = fn(self, tid, item)
            if d is not None:
                return t_start + d
        self.flush_counts()
        return None

    def _interp_timed(self, tid: int, kind: str, item: Any,
                      t_start: float) -> Optional[float]:
        if self.try_op(tid, kind, item):
            return self.nv.thread_time_ns(tid)
        return None

    def flush_counts(self) -> None:
        """Apply all deferred compiled-op charges to the engine counters
        through the charge seam (a handful of vector adds per run), and
        materialize any staged columnar burst."""
        charge = self.nv.charge_counts
        for op in self.ops.values():
            dc = op._deferred
            if dc:
                for (tid, key), n in dc.items():
                    vec = op.counts_for_key(key)
                    charge(tid, vec if n == 1 else vec * n)
                dc.clear()
        if self.rstore is not None:
            self.rstore.flush()

    # ------------------------------------------------------------ logical view
    def _read_record(self, addr: int) -> tuple:
        nv, lay = self.nv, self.layout
        item = _peek(nv, addr + lay.item_off)
        idx = _peek(nv, addr + lay.idx_off) if lay.idx_off is not None else 0
        if lay.volatile:
            p = (_peek(nv, addr + lay.pptr_off)
                 if lay.pptr_off is not None else NULL)
            return (p, addr, item, idx or 0)
        return (addr, None, item, idx or 0)

    def _next_addr(self, rec: tuple) -> int:
        lay = self.layout
        base = rec[1] if lay.volatile else rec[0]
        return _peek(self.nv, base + lay.next_off) or NULL

    def _bootstrap(self) -> None:
        """Build the logical FIFO by walking engine memory from the head
        root -- the state any prefill/recovery left behind."""
        lay = self.layout
        head = getattr(self.q, lay.head_root)
        hv = _peek(self.nv, head)
        if lay.head_is_tuple:
            hv, hidx = hv
            self.dummy = self._read_record(hv)
            self.dummy = (self.dummy[0], self.dummy[1], self.dummy[2], hidx)
        else:
            self.dummy = self._read_record(hv)
        self.fifo.clear()
        rec = self.dummy
        while True:
            nxt = self._next_addr(rec)
            if nxt == NULL:
                break
            rec = self._read_record(nxt)
            self.fifo.append(rec)

    def after_real_op(self, tid: int, kind: str) -> None:
        """Resync the logical view after a bailed (real) op: a real
        enqueue appended exactly one node after the old logical tail; a
        real dequeue consumed the head (or observed empty)."""
        self.bailed_ops += 1
        if kind == "enq":
            tail = self.fifo[-1] if self.fifo else self.dummy
            nxt = self._next_addr(tail)
            if nxt != NULL:
                self.fifo.append(self._read_record(nxt))
        elif self.fifo:
            self.dummy = self.fifo.popleft()

    # ---------------------------------------------------------------- fast op
    def try_op(self, tid: int, kind: str, item: Any) -> bool:
        """Execute one op through the compiled fast path.  Returns False
        (without any side effect) when a bail guard fires; the caller then
        runs the real per-primitive thunk."""
        op = self.ops.get(kind)
        nv = self.nv
        if op is None or nv.crashed or nv._pending[tid]:
            return False
        fifo = self.fifo
        if kind == "deq" and not fifo:
            return False          # empty dequeue: a different schedule
        for g in op.guards:
            if not g(self, tid):
                return False
        q = self.q
        mem = q.mem if op.uses_ssmem else None
        if op.allocs_p:
            # an area refill mid-op is hundreds of primitives of zeroing:
            # real execution territory
            if not mem._free[tid] and (not mem._areas[tid] or
                                       mem._cursor[tid] >= mem.area_nodes):
                return False
        if mem is not None:
            mem.op_begin(tid)
        env = self.env
        if kind == "enq":
            t = fifo[-1] if fifo else self.dummy
            env[E_TAIL_P], env[E_TAIL_V] = t[0], t[1]
            idx = (t[3] or 0) + 1
            result = item
        else:
            d, n = self.dummy, fifo[0]
            env[E_HEAD_P], env[E_HEAD_V] = d[0], d[1]
            env[E_NEXT_P], env[E_NEXT_V] = n[0], n[1]
            idx = n[3]
            result = n[2]
        if op.allocs_p:
            env[E_NEW_P] = mem.alloc(tid)
        if op.allocs_v:
            env[E_NEW_V] = q.valloc.alloc(tid)

        # ---- effect program ------------------------------------------
        vis, pmem = nv._vis, nv._pmem
        lstate = nv._lstate
        vval, vtouched = nv._vval, nv._vtouched
        log, log_start = nv._log, nv._log_start
        tracking = nv.contention_tracking
        epoch = nv.epoch
        line_epoch = nv._line_epoch
        VB = NVRAM._VOLATILE_BASE
        dyn: List[int] = []
        for ins in op.prog:
            code = ins[0]
            a = ins[1]
            m = a[0]
            if m == 0:
                ad = a[1]
            elif m == 1:
                ad = env[a[1]] + a[2]
            else:
                ad = a[1] + tid * LINE_WORDS + a[2]
            if code == K_CLASS_P:
                ln = ad // LINE_WORDS
                if tracking:
                    line_epoch[ln] = epoch
                s = lstate[ln]
                dyn.append(TOUCH_CLASS[s])
                lstate[ln] = TOUCH_NEXT[s]
            elif code == K_CLASS_V:
                i = ad - VB
                if vtouched[i]:
                    dyn.append(EV_HIT)
                else:
                    dyn.append(EV_DRAM)
                    vtouched[i] = 1
            elif code == K_LOGW:
                v = ins[2](env, item, idx, tid)
                vis[ad] = v
                ln = ad // LINE_WORDS
                lg = log.get(ln)
                if lg is None:
                    log[ln] = [(ad, v)]
                else:
                    lg.append((ad, v))
            elif code == K_VVAL:
                vval[ad - VB] = ins[2](env, item, idx, tid)
            elif code == K_PMEMW:
                v = ins[2](env, item, idx, tid)
                vis[ad] = v
                pmem[ad] = v
            elif code == K_STATE:
                ln = ad // LINE_WORDS
                mode = ins[2]
                if mode == ST_INVAL:
                    lstate[ln] = LS_FINVAL | LS_EVERFL
                elif mode == ST_EVERFL:
                    lstate[ln] |= LS_EVERFL
                else:
                    lstate[ln] = (lstate[ln] & LS_EVERFL) | LS_CACHED
            elif code == K_LINE:
                vals = list(ins[2])
                if ins[3] is not None:
                    vals[ins[3]] = item
                hi = ad + LINE_WORDS
                vis[ad:hi] = vals
                ln = ad // LINE_WORDS
                if ins[4]:                      # eADR: durable on store
                    pmem[ad:hi] = vals
                elif not ins[5]:                # materialize unless fused
                    lg = log.get(ln)
                    ents = list(zip(range(ad, hi), vals))
                    if lg is None:
                        log[ln] = ents
                    else:
                        lg.extend(ents)
                lstate[ln] = (lstate[ln] & LS_EVERFL) | LS_CACHED
            elif code == K_PENDW:
                # fused-drain write: coherent view now, persistent image
                # at the covering fence's K_DRAINF
                vis[ad] = ins[2](env, item, idx, tid)
            elif code == K_DRAIN:
                ln = ad // LINE_WORDS
                lg = log.get(ln)
                if lg:
                    for (wa, wv) in lg:
                        pmem[wa] = wv
                    log_start[ln] += len(lg)
                    lg.clear()
            elif code == K_DRAINF:
                ln = ad // LINE_WORDS
                lg = log.get(ln)
                if lg:     # pre-existing entries (recycled line): oldest first
                    for (wa, wv) in lg:
                        pmem[wa] = wv
                    n0 = len(lg)
                    lg.clear()
                else:
                    n0 = 0
                for ent in ins[2]:
                    a2d = ent[1]
                    m2 = a2d[0]
                    if m2 == 0:
                        a2 = a2d[1]
                    elif m2 == 1:
                        a2 = env[a2d[1]] + a2d[2]
                    else:
                        a2 = a2d[1] + tid * LINE_WORDS + a2d[2]
                    if ent[0] == "w":
                        pmem[a2] = ent[2](env, item, idx, tid)
                    else:
                        vals = list(ent[2])
                        if ent[3] is not None:
                            vals[ent[3]] = item
                        pmem[a2:a2 + LINE_WORDS] = vals
                log_start[ln] += n0 + ins[3]
            elif code == K_NT:
                vis[ad] = ins[2](env, item, idx, tid)
            elif code == K_NTAPPLY:
                pmem[ad] = ins[2](env, item, idx, tid)
            elif code == K_CASTAG:
                if tracking:
                    cw = nv._cas_words
                    cw[ad] = cw.get(ad, 0) + 1
                    if ins[2]:                  # volatile CAS target
                        line_epoch[ad // LINE_WORDS] = epoch
            else:   # K_STAMP
                if tracking:
                    line_epoch[ad // LINE_WORDS] = epoch

        # ---- charge the whole op in one vector add -------------------
        nv.charge_counts(tid, op.counts_for(tuple(dyn)))

        # ---- logical FIFO + aux --------------------------------------
        if kind == "enq":
            fifo.append((env[E_NEW_P] if op.allocs_p else NULL,
                         env[E_NEW_V] if op.allocs_v else None, item, idx))
        else:
            self.dummy = fifo.popleft()
        for ax in op.aux:
            t0 = ax[0]
            if t0 == "retire":
                mem.retire(tid, ax[1](env, item, idx, tid))
            elif t0 == "retire_v":
                mem.retire_volatile(tid, ax[1](env, item, idx, tid))
            elif t0 == "slot":
                ax[1][tid] = ax[2](env, item, idx, tid)
            elif t0 == "pdiscard":
                q._persisted.discard(env[ax[1]])
            else:   # padd
                q._persisted.update(env[i] for i in ax[1])
        if op.event_kind is not None:
            q.on_event((op.event_kind, result))
        self.record(tid, kind, result)
        self.fast_ops += 1
        return True
