"""Sequential reference NVRAM engine (the seed per-word dict simulator).

This is the original, deliberately-simple engine: one Python object per word,
per-line store logs scanned on every read, dataclass counters bumped per
primitive.  It is kept verbatim-in-spirit as the *oracle* for the batched
array engine in :mod:`repro.core.nvram` -- the differential tests assert that
both engines produce identical persist accounting (fences/op,
post-flush-accesses/op) for every queue.  Do not optimize this file; its
value is being obviously correct, not fast.

Semantics (paper §2) are documented in :mod:`repro.core.nvram`; latencies and
platform behaviour come from a pluggable :class:`repro.core.memmodel.MemoryModel`.
"""
from __future__ import annotations

import random
import threading
from typing import Any, Callable, Dict, List, Optional, Tuple, Union

from .memmodel import MemoryModel, get_memory_model
from .nvram import LINE_WORDS, Stats


class ReferenceNVRAM:
    """Word-granular two-level (cache + persistent) memory simulator."""

    def __init__(self, nthreads: int = 1,
                 step_hook: Optional[Callable[[int, str], None]] = None,
                 model: Union[str, MemoryModel, None] = None):
        self.nthreads = nthreads
        self.step_hook = step_hook          # scheduler yield point
        self.model = get_memory_model(model)
        # persistent backing store: committed NVRAM state
        self._pmem: Dict[int, Any] = {}
        # per-line log of *unapplied* stores; _log_start[line] is the
        # absolute index (since line creation) of _log[line][0] -- pending
        # flush entries carry absolute indices so they stay valid however
        # other threads' fences interleave.
        self._log: Dict[int, List[Tuple[int, Any]]] = {}
        self._log_start: Dict[int, int] = {}
        # cache metadata (persistent space only)
        self._cached: Dict[int, bool] = {}
        self._flush_invalid: Dict[int, bool] = {}
        self._ever_flushed: Dict[int, bool] = {}
        # pending persists per thread: ('flush', line, upto) | ('nt', addr, v)
        self._pending: Dict[int, List[Tuple]] = {t: [] for t in range(nthreads)}
        # coherent overlay: last store (regular, CAS or NT) per address, in
        # program order -- mirrors the batched engine's _vis array, so a
        # write after an NT store to the same address is not shadowed by the
        # stale pending NT value
        self._coh: Dict[int, Any] = {}
        # volatile (DRAM) space: wiped at crash
        self._vmem: Dict[int, Any] = {}
        self._vtouched: set = set()
        # address-space management (address 0 is reserved as NULL)
        self._brk = LINE_WORDS
        self.regions: List[Tuple[str, int, int, bool]] = []
        self._volatile_base = 1 << 40  # volatile addresses live far above
        self._vbrk = self._volatile_base
        self.stats: Dict[int, Stats] = {t: Stats() for t in range(nthreads)}
        self._tls = threading.local()
        self.crashed = False
        self._lock = threading.Lock()   # guards structural mutation (alloc)

    # ------------------------------------------------------------ thread id
    def set_tid(self, tid: int) -> None:
        self._tls.tid = tid

    @property
    def tid(self) -> int:
        return getattr(self._tls, "tid", 0)

    def _step(self, kind: str) -> None:
        if self.step_hook is not None:
            self.step_hook(self.tid, kind)

    # --------------------------------------------------------- address space
    def alloc_region(self, nwords: int, name: str = "region",
                     persistent: bool = True) -> int:
        """Allocate a line-aligned region; returns base address."""
        with self._lock:
            if persistent:
                base = (self._brk + LINE_WORDS - 1) // LINE_WORDS * LINE_WORDS
                self._brk = base + nwords
            else:
                base = (self._vbrk + LINE_WORDS - 1) // LINE_WORDS * LINE_WORDS
                self._vbrk = base + nwords
            self.regions.append((name, base, nwords, persistent))
            return base

    def is_persistent_addr(self, addr: int) -> bool:
        return addr < self._volatile_base

    @staticmethod
    def line_of(addr: int) -> int:
        return addr // LINE_WORDS

    # ------------------------------------------------------- cache mechanics
    def _touch(self, line: int, for_write: bool) -> None:
        """Account for bringing `line` into cache (persistent space)."""
        st = self.stats[self.tid]
        m = self.model
        if self._cached.get(line, False):
            st.time_ns += m.cache_hit_ns
            return
        if self._flush_invalid.get(line, False):
            # the paper's penalty: reading back explicitly flushed content
            st.post_flush_accesses += 1
            st.time_ns += m.nvram_read_ns
        else:
            st.cold_misses += 1
            st.time_ns += m.nvram_read_ns if self._ever_flushed.get(line, False) \
                else m.dram_miss_ns
        self._cached[line] = True
        self._flush_invalid[line] = False

    def _visible(self, addr: int) -> Any:
        """Coherent view: the last store to the address in program order
        (regular, CAS or NT -- x86 stores are coherent before persistence),
        falling back to the persistent image."""
        if addr in self._coh:
            return self._coh[addr]
        return self._pmem.get(addr)

    # ------------------------------------------------------------ primitives
    def read(self, addr: int) -> Any:
        self._step("read")
        st = self.stats[self.tid]
        st.reads += 1
        if not self.is_persistent_addr(addr):
            st.time_ns += self.model.cache_hit_ns if addr in self._vtouched \
                else self.model.dram_miss_ns
            self._vtouched.add(addr)
            return self._vmem.get(addr)
        self._touch(self.line_of(addr), for_write=False)
        return self._visible(addr)

    def write(self, addr: int, value: Any) -> None:
        self._step("write")
        st = self.stats[self.tid]
        st.writes += 1
        if not self.is_persistent_addr(addr):
            st.time_ns += self.model.cache_hit_ns if addr in self._vtouched \
                else self.model.dram_miss_ns
            self._vtouched.add(addr)
            self._vmem[addr] = value
            return
        line = self.line_of(addr)
        self._touch(line, for_write=True)   # write-allocate (RFO)
        self._coh[addr] = value
        if self.model.persist_on_store:
            self._pmem[addr] = value        # visible => durable: no log
        else:
            self._log.setdefault(line, []).append((addr, value))

    def write_full_line(self, base_addr: int, values: List[Any]) -> None:
        """Full-line store without read-for-ownership (models allocator /
        node initialization via fast-string or full-line NT stores -- no
        fetch, hence *not* a post-flush access).  Used only when every word
        of the line is overwritten."""
        self._step("write")
        st = self.stats[self.tid]
        st.writes += 1
        line = self.line_of(base_addr)
        assert base_addr % LINE_WORDS == 0 and len(values) <= LINE_WORDS
        if not self.is_persistent_addr(base_addr):
            for i, v in enumerate(values):
                self._vmem[base_addr + i] = v
                self._vtouched.add(base_addr + i)
            st.time_ns += self.model.cache_hit_ns
            return
        st.time_ns += self.model.cache_hit_ns
        self._cached[line] = True
        self._flush_invalid[line] = False
        if self.model.persist_on_store:
            for i, v in enumerate(values):
                self._coh[base_addr + i] = v
                self._pmem[base_addr + i] = v
            return
        log = self._log.setdefault(line, [])
        for i, v in enumerate(values):
            self._coh[base_addr + i] = v
            log.append((base_addr + i, v))

    def cas(self, addr: int, expected: Any, new: Any) -> bool:
        """Atomic compare-and-swap (one scheduler step).  Double-width CAS is
        modeled by storing a tuple at a single word address (paper §5.1.2)."""
        self._step("cas")
        st = self.stats[self.tid]
        st.cas += 1
        if not self.is_persistent_addr(addr):
            st.time_ns += self.model.cache_hit_ns if addr in self._vtouched \
                else self.model.dram_miss_ns
            self._vtouched.add(addr)
            cur = self._vmem.get(addr)
            if cur == expected:
                self._vmem[addr] = new
                return True
            return False
        line = self.line_of(addr)
        self._touch(line, for_write=True)
        cur = self._visible(addr)
        if cur == expected:
            self._coh[addr] = new
            if self.model.persist_on_store:
                self._pmem[addr] = new
            else:
                self._log.setdefault(line, []).append((addr, new))
            return True
        return False

    def flush(self, addr: int) -> None:
        """Asynchronous CLWB: schedule write-back of the whole containing
        line; under an invalidating model (Cascade Lake) also evict it."""
        self._step("flush")
        st = self.stats[self.tid]
        st.flushes += 1
        st.time_ns += self.model.flush_issue_ns
        assert self.is_persistent_addr(addr), "flushing volatile memory"
        line = self.line_of(addr)
        upto_abs = self._log_start.get(line, 0) + len(self._log.get(line, ()))
        self._pending[self.tid].append(("flush", line, upto_abs))
        if self.model.flush_invalidates:
            self._cached[line] = False
            self._flush_invalid[line] = True
        self._ever_flushed[line] = True

    def movnti(self, addr: int, value: Any) -> None:
        """Non-temporal store: straight to the memory write queue; does not
        touch or pollute the cache (paper §6.3).  Needs a fence to complete."""
        self._step("movnti")
        st = self.stats[self.tid]
        st.movntis += 1
        st.time_ns += self.model.movnti_ns
        assert self.is_persistent_addr(addr)
        self._coh[addr] = value
        self._pending[self.tid].append(("nt", addr, value))

    def fence(self) -> None:
        """SFENCE: block until all of this thread's outstanding flushes and
        NT stores are persistent."""
        self._step("fence")
        st = self.stats[self.tid]
        st.fences += 1
        pend = self._pending[self.tid]
        # drain cost scales with distinct lines: WC buffers combine NT
        # stores to one line, and multiple flush entries of a line coalesce
        lines = {(e[1] if e[0] == "flush" else self.line_of(e[1]))
                 for e in pend}
        st.time_ns += self.model.fence_base_ns \
            + self.model.fence_per_line_ns * len(lines)
        for ent in pend:
            self._apply_persist(ent)
        pend.clear()

    def persist(self, addr: int) -> None:
        """flush + fence convenience (the paper's 'persisting a location')."""
        self.flush(addr)
        self.fence()

    # --------------------------------------------------------------- persist
    def _apply_persist(self, ent: Tuple) -> None:
        if ent[0] == "flush":
            _, line, upto_abs = ent
            log = self._log.get(line, [])
            start = self._log_start.get(line, 0)
            count = upto_abs - start
            if count <= 0:
                return          # already applied by a later/earlier fence
            count = min(count, len(log))
            for (a, v) in log[:count]:
                self._pmem[a] = v
            del log[:count]
            self._log_start[line] = start + count
        else:
            _, addr, v = ent
            self._pmem[addr] = v

    # ----------------------------------------------------------------- crash
    def crash(self, mode: str = "random", seed: int = 0) -> None:
        """Full-system crash (paper §2 failure model).

        mode='min'    -- nothing beyond fenced state survives (pending flushes
                         and NT stores are dropped; un-flushed stores lost).
        mode='random' -- each pending flush/NT store independently survives;
                         additionally each line persists a random *prefix* of
                         its remaining stores (implicit eviction, Assumption 1).
        mode='max'    -- everything reaches NVRAM (all stores applied).
        Under a persist-on-store model (eADR) every visible store is durable,
        so all modes behave like 'max'.  Volatile memory is wiped regardless.
        """
        rng = random.Random(seed)
        self.crashed = True
        if mode == "max" or self.model.persist_on_store:
            for plist in self._pending.values():
                for ent in plist:
                    self._apply_persist(ent)
            for line, log in self._log.items():
                for (a, v) in log:
                    self._pmem[a] = v
        elif mode == "random":
            for plist in self._pending.values():
                # flush entries may survive independently: applying a later
                # flush of a line subsumes earlier ones (prefix-safe).
                for ent in plist:
                    if ent[0] == "flush" and rng.random() < 0.5:
                        self._apply_persist(ent)
                # NT stores to the same line combine in the WC buffer and the
                # line evicts atomically (Assumption 1): per line, a *prefix*
                # of the thread's NT stores survives, in issue order.
                nt_by_line: Dict[int, List[Tuple]] = {}
                for ent in plist:
                    if ent[0] == "nt":
                        nt_by_line.setdefault(self.line_of(ent[1]), []).append(ent)
                for line, ents in nt_by_line.items():
                    k = rng.randint(0, len(ents))
                    for ent in ents[:k]:
                        self._apply_persist(ent)
            for line, log in list(self._log.items()):
                if log:
                    k = rng.randint(0, len(log))  # prefix (Assumption 1)
                    for (a, v) in log[:k]:
                        self._pmem[a] = v
        elif mode == "min":
            pass
        else:
            raise ValueError(mode)
        # volatile state is gone
        for plist in self._pending.values():
            plist.clear()
        self._log.clear()
        self._log_start.clear()
        self._coh.clear()
        self._cached.clear()
        self._flush_invalid.clear()
        self._vmem.clear()
        self._vtouched.clear()

    # ------------------------------------------------------ recovery access
    def pread(self, addr: int) -> Any:
        """Recovery-time direct read of the persistent image (not on the
        fast path; costs are accounted separately by the harness)."""
        return self._pmem.get(addr)

    def pwrite(self, addr: int, value: Any) -> None:
        """Recovery-time direct persistent write (recovery persists its
        reconstruction before normal operation resumes)."""
        self._pmem[addr] = value

    def reset_after_recovery(self) -> None:
        """Recovery is complete: resume normal (cached) operation."""
        self.crashed = False

    # ------------------------------------------------------------- reporting
    def total_stats(self) -> Stats:
        tot = Stats()
        for s in self.stats.values():
            tot.add(s)
        return tot

    def thread_time_ns(self, tid: int) -> float:
        return self.stats[tid].time_ns

    def sim_time_ns(self) -> float:
        """Makespan across per-thread clocks."""
        return max((s.time_ns for s in self.stats.values()), default=0.0)
