"""Columnar op-record / event store -- the batched engine's bookkeeping.

Per-op Python bookkeeping (an ``OpRecord`` object + a list append + an
event-tuple append per op) was the ~10µs/op floor under the compiled fast
path once the memory simulation itself got cheap.  This module replaces it
with a **columnar store**: preallocated numpy columns + cursors for op
records (tid, kind, per-thread seq, start/end clock, per-op event-count
vector, item, completed) and for linearization events (interned kind code +
payload), with two write paths:

* **staged** (the compiled fast path): each generated op function appends
  one packed integer ``key << 9 | tid << 1 | kind`` plus the item and the
  post-op clock to three staging buffers (two typed ``array`` buffers + an
  item list) -- ~3 appends per op, no objects.  :meth:`RecordStore.sync`
  then materializes a whole burst in one vector pass: the typed buffers
  convert to numpy through the buffer protocol (a memcpy, not a
  per-element walk), then column scatter, per-thread seq/clock chains,
  event rows, and the engine charge -- one
  :meth:`repro.core.nvram.NVRAM.charge_counts` call per distinct
  (outcome-key, tid, kind) triple instead of per op.

* **direct** (real per-primitive execution, recovery, the exact
  scheduler): :meth:`begin_op` / :meth:`complete_op` /
  :meth:`append_event` append single rows under a lock, flushing any
  staged burst first so global order is preserved.

Capacity is preallocated and **auto-grows by doubling, preserving
contents**; a ``max_records`` bound makes exhaustion an explicit
:class:`RecordCapacityError` -- never a silent truncation.  Cursors
snapshot/restore with memory state (:meth:`snapshot` / :meth:`restore`),
the seam the crash sweep rides.

The legacy list-of-``OpRecord`` path survives behind
``QueueHarness(records="legacy")`` as the differential reference; the
equivalence suite (``tests/test_columnar_equivalence.py``) pins both
representations bit-identical.  :class:`OpsView` / :class:`EventsView`
give the store the mutable-list surface the rest of the repo programs
against (``harness.ops`` / ``harness.events``).
"""
from __future__ import annotations

import threading
from array import array
from dataclasses import dataclass
from typing import Any, List, Optional, Tuple

import numpy as np

from .nvram import N_EV

KIND_NAMES = ("enq", "deq")
KIND_CODES = {"enq": 0, "deq": 1}

# staging-word layout: key << META_KEY_SHIFT | tid << 1 | kind-bit.
# tid must fit 8 bits and key must leave the int64 sign bit clear, hence
# the executor only stages when nthreads <= 256 and n_class <= MAX_NCLASS
# (4 bits per classification nibble: 9 + 4*13 = 61 bits).
META_KEY_SHIFT = 9
MAX_STAGED_NCLASS = 13
MAX_STAGED_THREADS = 256

_UNSET = object()


class RecordCapacityError(RuntimeError):
    """The store needs more rows than its explicit ``max_records`` bound.

    Raised instead of dropping records: capacity exhaustion must never
    silently truncate an op history the linearizability checker reads.
    """


@dataclass
class OpRecord:
    tid: int
    kind: str            # 'enq' | 'deq'
    item: Any = None     # for enq: item; for deq: returned item (or None)
    completed: bool = False


def _grown(a: np.ndarray, cap: int) -> np.ndarray:
    out = np.zeros((cap,) + a.shape[1:], dtype=a.dtype) \
        if a.dtype != object else np.empty((cap,) + a.shape[1:], dtype=object)
    out[:len(a)] = a
    return out


class RecordStore:
    """Preallocated op/event columns + cursors (see module docstring)."""

    def __init__(self, nthreads: int, op_capacity: int = 1024,
                 event_capacity: int = 1024,
                 max_records: Optional[int] = None):
        self.nthreads = nthreads
        self.max_records = max_records
        op_capacity = max(1, min(op_capacity, max_records or op_capacity))
        event_capacity = max(1, event_capacity)
        # ---- op columns (row = one enqueue/dequeue) ----------------------
        self.tid = np.zeros(op_capacity, dtype=np.int32)
        self.kind = np.zeros(op_capacity, dtype=np.uint8)      # KIND_CODES
        self.seq = np.zeros(op_capacity, dtype=np.int64)       # per-thread
        self.t_start = np.zeros(op_capacity, dtype=np.float64)
        self.t_end = np.zeros(op_capacity, dtype=np.float64)
        self.completed = np.zeros(op_capacity, dtype=np.uint8)
        # per-op event-count vector: populated for compiled (staged) ops --
        # base counts + dynamic outcomes; direct rows account through the
        # engine's event buffer instead and stay zero here
        self.ev = np.zeros((op_capacity, N_EV), dtype=np.int64)
        self.items = np.empty(op_capacity, dtype=object)
        self.n_ops = 0
        # ---- event columns (row = one serialized event tuple) ------------
        self.ev_code = np.zeros(event_capacity, dtype=np.int32)
        # 1 = (name,);  2 = (name, payload);  -1 = payload is the raw tuple
        self.ev_arity = np.zeros(event_capacity, dtype=np.int8)
        self.ev_payload = np.empty(event_capacity, dtype=object)
        self.n_events = 0
        # event-kind interning
        self._codes: dict = {}
        self._names: List[str] = []
        # ---- staging (compiled fast path; identity-stable buffers bound
        # into the generated op functions as positional defaults).  The
        # meta/clock buffers are typed arrays so sync() converts them to
        # numpy via the buffer protocol instead of walking Python ints ----
        self._sm = array("q")         # packed key/tid/kind words
        self._si: List[Any] = []      # op items (enq item / deq result)
        self._st = array("d")         # post-op thread clocks
        # burst item chunks: (stream position, object ndarray) -- whole
        # bursts stay as arrays so sync() block-copies them instead of
        # converting a giant Python list element-wise
        self._si_chunks: List[Tuple[int, Any]] = []
        # ---- per-thread chain carries ------------------------------------
        self._nextseq = np.zeros(nthreads, dtype=np.int64)
        self._last_tend = np.zeros(nthreads, dtype=np.float64)
        # ---- charge seam (attach_engine) ---------------------------------
        self._nv = None               # engine staged charges land on
        self._cops: Tuple = (None, None)   # CompiledOp per kind bit
        self._evk: Tuple[int, int] = (-1, -1)  # event code per kind bit
        self._ex = None               # executor whose fast_ops we advance
        self.version = 0              # bumped on any mutation (view caches)
        self._lock = threading.Lock()
        # optional observation-only phase profiler (duck-typed push/pop,
        # e.g. repro.obs.PhaseProfiler): when attached, sync() charges its
        # vector pass to the "record-charging" phase.  Never affects the
        # records or engine charges themselves.
        self.profiler = None

    # ------------------------------------------------------------- capacity
    def _ensure_ops(self, need: int) -> None:
        # the bound check comes before the capacity short-circuit: a
        # max_records below the preallocated capacity must still trip
        if self.max_records is not None and need > self.max_records:
            raise RecordCapacityError(
                f"op-record store needs {need} rows but max_records="
                f"{self.max_records}")
        cap = len(self.tid)
        if need <= cap:
            return
        while cap < need:
            cap *= 2
        if self.max_records is not None:
            cap = min(cap, self.max_records)
        self.tid = _grown(self.tid, cap)
        self.kind = _grown(self.kind, cap)
        self.seq = _grown(self.seq, cap)
        self.t_start = _grown(self.t_start, cap)
        self.t_end = _grown(self.t_end, cap)
        self.completed = _grown(self.completed, cap)
        self.ev = _grown(self.ev, cap)
        self.items = _grown(self.items, cap)

    def _ensure_events(self, need: int) -> None:
        if self.max_records is not None and need > self.max_records:
            raise RecordCapacityError(
                f"event store needs {need} rows but max_records="
                f"{self.max_records}")
        cap = len(self.ev_code)
        if need <= cap:
            return
        while cap < need:
            cap *= 2
        if self.max_records is not None:
            cap = min(cap, self.max_records)
        self.ev_code = _grown(self.ev_code, cap)
        self.ev_arity = _grown(self.ev_arity, cap)
        self.ev_payload = _grown(self.ev_payload, cap)

    # ------------------------------------------------------------ interning
    def _intern(self, name: str) -> int:
        c = self._codes.get(name)
        if c is None:
            c = len(self._names)
            self._codes[name] = c
            self._names.append(name)
        return c

    # ----------------------------------------------------------- charge seam
    def attach_engine(self, nv, cops: Tuple, event_kinds: Tuple[
            Optional[str], Optional[str]], executor=None) -> None:
        """Bind the engine + compiled ops staged bursts resolve against.

        ``cops`` is (enq CompiledOp, deq CompiledOp) -- their
        ``counts_for_key`` caches turn packed outcome keys back into event
        vectors; ``event_kinds`` the linearization-event kind per op kind
        (None = the op emits no event).  Called by
        ``FastPathExecutor.attach_store`` at the start of every batched
        run; also re-seeds the per-thread clock chain from the engine's
        current thread clocks.
        """
        if self.nthreads > MAX_STAGED_THREADS:
            raise ValueError(
                f"staged records support at most {MAX_STAGED_THREADS} "
                f"threads, got {self.nthreads}")
        self.flush()
        self._nv = nv
        self._cops = cops
        self._ex = executor
        self._evk = tuple(-1 if k is None else self._intern(k)
                          for k in event_kinds)
        self._last_tend[:] = nv.thread_times_ns()

    # ------------------------------------------------------------- staging
    def extend_staged(self, metas: bytes, items, tends: bytes) -> None:
        """Append a whole committed burst to the staging arrays in one
        bulk copy -- ``metas`` / ``tends`` are the packed int64 meta
        words and float64 post-op clocks as raw bytes, ``items`` the
        per-op payloads (an object ndarray, kept whole as a chunk, or a
        plain list).  The rows are materialized and charged by the next
        :meth:`sync`, exactly as per-op staged rows are; used by the
        burst executor (:mod:`repro.core.burst`)."""
        if isinstance(items, np.ndarray):
            self._si_chunks.append((len(self._sm), items))
        else:
            self._si.extend(items)
        self._sm.frombytes(metas)
        self._st.frombytes(tends)

    def sync(self) -> None:
        """Materialize the staged burst into the columns and charge the
        engine -- one vector pass, one ``charge_counts`` per distinct
        (outcome-key, tid, kind) triple.  Caller holds the lock or is the
        single-threaded batched scheduler."""
        if not self._sm:
            return
        prof = self.profiler
        if prof is None:
            return self._sync_impl()
        prof.push("record-charging")
        try:
            self._sync_impl()
        finally:
            prof.pop()

    def _sync_impl(self) -> None:
        sm = self._sm
        n = len(sm)
        c = self.n_ops
        self._ensure_ops(c + n)
        m = np.frombuffer(sm, dtype=np.int64).copy()
        kb = (m & 1).astype(np.uint8)
        tids = ((m >> 1) & 0xFF).astype(np.int64)
        sl = slice(c, c + n)
        self.tid[sl] = tids
        self.kind[sl] = kb
        self.completed[sl] = 1
        icol = self.items[sl]
        if self._si_chunks:
            li = cur = 0
            si = self._si
            for pos, chunk in self._si_chunks:
                if pos > cur:
                    icol[cur:pos] = si[li:li + pos - cur]
                    li += pos - cur
                    cur = pos
                k = len(chunk)
                icol[cur:cur + k] = chunk
                cur += k
            if cur < n:
                icol[cur:] = si[li:]
        else:
            icol[:] = self._si
        te = np.frombuffer(self._st, dtype=np.float64).copy()
        self.t_end[sl] = te
        # per-thread seq numbers + start-clock chain: a thread's clock only
        # advances inside ops, so op i's start clock is op i-1's end clock
        # (the carry bridges bursts and real-execution ops)
        order = np.argsort(tids.astype(np.uint8), kind="stable")
        ts_ = tids[order]
        gstart = np.empty(n, dtype=bool)
        gstart[0] = True
        gstart[1:] = ts_[1:] != ts_[:-1]
        starts = np.nonzero(gstart)[0]
        gtids = ts_[starts]
        cnt = np.empty(starts.size, np.int64)
        cnt[:-1] = starts[1:] - starts[:-1]
        cnt[-1] = n - starts[-1]
        within = np.arange(n, dtype=np.int64) - np.repeat(starts, cnt)
        seq_s = np.repeat(self._nextseq[gtids], cnt) + within
        self._nextseq[gtids] += cnt
        te_s = te[order]
        ts_chain = np.empty(n, dtype=np.float64)
        ts_chain[1:] = te_s[:-1]
        ts_chain[starts] = self._last_tend[gtids]
        self._last_tend[gtids] = te_s[starts + cnt - 1]
        self.seq[sl][order] = seq_s
        self.t_start[sl][order] = ts_chain
        # event-count columns + engine charge, one pass per distinct word
        uniq, inv, counts = np.unique(m, return_inverse=True,
                                      return_counts=True)
        vecs = np.empty((uniq.size, N_EV), dtype=np.int64)
        nv = self._nv
        cops = self._cops
        for j in range(uniq.size):
            meta = int(uniq[j])
            vec = cops[meta & 1].counts_for_key(meta >> META_KEY_SHIFT)
            vecs[j] = vec
            nv.charge_counts((meta >> 1) & 0xFF, vec * int(counts[j]))
        self.ev[sl] = vecs[inv]
        # linearization events: compiled ops of a kind either always emit
        # (event kind, item) or never emit -- derived, not recorded
        e0, e1 = self._evk
        if e0 >= 0 or e1 >= 0:
            codes = np.where(kb == 1, e1, e0).astype(np.int32)
            mask = codes >= 0
            ne = int(mask.sum())
            if ne:
                ec = self.n_events
                self._ensure_events(ec + ne)
                esl = slice(ec, ec + ne)
                self.ev_code[esl] = codes[mask]
                self.ev_arity[esl] = 2
                self.ev_payload[esl] = self.items[sl][mask]
                self.n_events = ec + ne
        self.n_ops = c + n
        if self._ex is not None:
            self._ex.fast_ops += n
        del sm[:]
        del self._si[:]
        del self._st[:]
        self._si_chunks.clear()
        self.version += 1

    def flush(self) -> None:
        """Thread-safe sync (the harness's end-of-run seam)."""
        with self._lock:
            self.sync()

    # --------------------------------------------------------- direct rows
    def begin_op(self, tid: int, kind: str, item: Any = None,
                 completed: bool = False) -> int:
        """Append one op row (real per-primitive execution path); returns
        its row index for :meth:`complete_op`.  Flushes any staged burst
        first so rows land in global execution order."""
        with self._lock:
            self.sync()
            i = self.n_ops
            self._ensure_ops(i + 1)
            self.tid[i] = tid
            self.kind[i] = KIND_CODES[kind]
            self.seq[i] = self._nextseq[tid]
            self._nextseq[tid] += 1
            self.t_start[i] = self.t_end[i] = self._last_tend[tid]
            self.completed[i] = 1 if completed else 0
            self.ev[i] = 0
            self.items[i] = item
            self.n_ops = i + 1
            self.version += 1
            return i

    def complete_op(self, i: int, item: Any = _UNSET) -> None:
        with self._lock:
            self.completed[i] = 1
            if item is not _UNSET:
                self.items[i] = item
            self.version += 1

    def add_completed_op(self, tid: int, kind: str, item: Any) -> int:
        """One-shot completed row (the eager fast-path record callback)."""
        return self.begin_op(tid, kind, item, completed=True)

    def note_real_clocks(self, tid: int, t_start: float,
                         t_end: float) -> None:
        """Fix up the clock columns of the row a just-bailed real op
        appended (always the latest row) and re-seed the thread's chain."""
        i = self.n_ops - 1
        self.t_start[i] = t_start
        self.t_end[i] = t_end
        self._last_tend[tid] = t_end

    def append_event(self, ev: tuple) -> None:
        """Append one serialized event (``q.on_event`` / crash markers)."""
        with self._lock:
            self.sync()
            i = self.n_events
            self._ensure_events(i + 1)
            if (type(ev) is tuple and 1 <= len(ev) <= 2
                    and isinstance(ev[0], str)):
                self.ev_code[i] = self._intern(ev[0])
                self.ev_arity[i] = len(ev)
                self.ev_payload[i] = ev[1] if len(ev) == 2 else None
            else:
                # arbitrary event shape: store verbatim
                self.ev_code[i] = self._intern("<raw>")
                self.ev_arity[i] = -1
                self.ev_payload[i] = ev
            self.n_events = i + 1
            self.version += 1

    # ---------------------------------------------------------- observation
    def op_count(self) -> int:
        with self._lock:
            self.sync()
            return self.n_ops

    def event_count(self) -> int:
        with self._lock:
            self.sync()
            return self.n_events

    def completed_count(self) -> int:
        with self._lock:
            self.sync()
            return int(self.completed[:self.n_ops].sum())

    def op_record(self, i: int) -> OpRecord:
        return OpRecord(tid=int(self.tid[i]), kind=KIND_NAMES[self.kind[i]],
                        item=self.items[i], completed=bool(self.completed[i]))

    def op_records(self) -> List[OpRecord]:
        with self._lock:
            self.sync()
            kn = KIND_NAMES
            tid, kind = self.tid, self.kind
            items, comp = self.items, self.completed
            return [OpRecord(tid=int(tid[i]), kind=kn[kind[i]],
                             item=items[i], completed=bool(comp[i]))
                    for i in range(self.n_ops)]

    def event_tuples(self) -> List[tuple]:
        with self._lock:
            self.sync()
            names = self._names
            out = []
            for i in range(self.n_events):
                a = self.ev_arity[i]
                if a == 2:
                    out.append((names[self.ev_code[i]], self.ev_payload[i]))
                elif a == 1:
                    out.append((names[self.ev_code[i]],))
                else:
                    out.append(self.ev_payload[i])
            return out

    # ------------------------------------------------------ snapshot/restore
    def snapshot(self) -> Tuple[int, int]:
        """(op cursor, event cursor) -- taken alongside an
        :class:`repro.core.nvram.EngineSnapshot` so the crash seam can
        rewind records with memory state."""
        with self._lock:
            self.sync()
            return (self.n_ops, self.n_events)

    def restore(self, snap: Tuple[int, int]) -> None:
        """Truncate back to a snapshot's cursors (contents up to the
        cursors are untouched; per-thread chain carries are recomputed
        from the surviving rows)."""
        oc, ec = snap
        with self._lock:
            self.sync()
            if oc > self.n_ops or ec > self.n_events or oc < 0 or ec < 0:
                raise ValueError(
                    f"record snapshot ({oc}, {ec}) does not fit store with "
                    f"({self.n_ops}, {self.n_events}) rows")
            self.items[oc:self.n_ops] = None
            self.ev_payload[ec:self.n_events] = None
            self.n_ops = oc
            self.n_events = ec
            tids = self.tid[:oc]
            self._nextseq[:] = np.bincount(tids, minlength=self.nthreads
                                           )[:self.nthreads]
            self._last_tend[:] = 0.0
            for t in range(self.nthreads):
                idx = np.nonzero(tids == t)[0]
                if idx.size:
                    self._last_tend[t] = self.t_end[idx[-1]]
            self.version += 1

    # ----------------------------------------------------------- mutation
    def clear_ops(self) -> None:
        with self._lock:
            self.sync()
            self.items[:self.n_ops] = None
            self.n_ops = 0
            self._nextseq[:] = 0
            self._last_tend[:] = 0.0
            self.version += 1

    def clear_events(self) -> None:
        with self._lock:
            self.sync()
            self.ev_payload[:self.n_events] = None
            self.n_events = 0
            self.version += 1

    def reset_ops(self, records) -> None:
        """Replace op contents wholesale (``harness.ops = [...]``)."""
        self.clear_ops()
        for r in records:
            self.begin_op(r.tid, r.kind, r.item, completed=r.completed)


class _ViewBase:
    """Mutable list-like surface over one of the store's record families.

    Materialization is cached against the store's version counter, so
    repeated reads (equality checks, membership, slicing) cost one list
    build per mutation epoch."""

    __slots__ = ("_s", "_cache", "_cver")

    def __init__(self, store: RecordStore):
        self._s = store
        self._cache: Optional[list] = None
        self._cver = -1

    def _materialize(self) -> list:
        raise NotImplementedError

    def _list(self) -> list:
        if self._cver != self._s.version:
            self._cache = self._materialize()
            self._cver = self._s.version
        return self._cache

    def __iter__(self):
        return iter(self._list())

    def __getitem__(self, i):
        return self._list()[i]

    def __contains__(self, x):
        return x in self._list()

    def index(self, x, *args):
        return self._list().index(x, *args)

    def count(self, x):
        return self._list().count(x)

    def __eq__(self, other):
        if isinstance(other, _ViewBase):
            other = other._list()
        if isinstance(other, list):
            return self._list() == other
        return NotImplemented

    def __ne__(self, other):
        r = self.__eq__(other)
        return r if r is NotImplemented else not r

    def __repr__(self):
        return repr(self._list())

    def __delitem__(self, key):
        if not (isinstance(key, slice) and key.start in (None, 0)
                and key.stop is None and key.step is None):
            raise TypeError("record views support full-slice deletion only "
                            "(del view[:])")
        self.clear()

    def extend(self, it):
        for x in it:
            self.append(x)

    # views are truthy iff non-empty, like lists
    def __bool__(self):
        return len(self) > 0


class OpsView(_ViewBase):
    """``harness.ops`` surface: a live list of :class:`OpRecord`."""

    __slots__ = ()

    def __len__(self):
        return self._s.op_count()

    def _materialize(self) -> list:
        return self._s.op_records()

    def append(self, rec: OpRecord) -> None:
        self._s.begin_op(rec.tid, rec.kind, rec.item,
                         completed=rec.completed)

    def clear(self) -> None:
        self._s.clear_ops()


class EventsView(_ViewBase):
    """``harness.events`` surface: a live list of event tuples."""

    __slots__ = ()

    def __len__(self):
        return self._s.event_count()

    def _materialize(self) -> list:
        return self._s.event_tuples()

    def append(self, ev: tuple) -> None:
        self._s.append_event(ev)

    def clear(self) -> None:
        self._s.clear_events()
