"""Pluggable persistence memory models for the NVRAM cost engine.

The paper's measurements assume one platform: Optane DC behind CLWB on
Cascade Lake, where a flush *invalidates* the cache line and the next access
pays NVRAM read latency (the post-flush penalty, the paper's key metric).
Related work evaluates the same designs under different persistence regimes:

* Fatourou et al. ("Highly-Efficient Persistent FIFO Queues") target
  platforms where flushed lines *stay cached*, so post-flush accesses cost a
  cache hit;
* eADR platforms (Ice Lake SP + battery-backed caches) make the cache part of
  the persistence domain: a store is durable once globally visible, flushes
  are unnecessary and fences only order stores;
* CXL-attached memory trades flush-invalidation for a longer read/fence round
  trip through the CXL.mem link.

A :class:`MemoryModel` bundles the latency constants and the behavioural
flags that distinguish these regimes.  Both NVRAM engines (the batched array
engine and the sequential reference), the queue-level persist helpers
(:meth:`repro.core.queue_base.QueueAlgorithm.pflush`) and the contention
layer's retry-cost resolver
(:meth:`repro.core.contention.RetryProfile.event_units` -- a retry's
re-read of flushed content is a post-flush access only under an
invalidating-flush platform) all consult it, which turns "which persistence
platform?" into a benchmark sweep axis.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Union


@dataclass(frozen=True)
class MemoryModel:
    """Latencies (ns) + behaviour flags of one persistence platform."""

    name: str
    # latencies
    cache_hit_ns: float = 1.5      # L1/L2 blend
    dram_miss_ns: float = 80.0     # volatile-region / never-flushed miss
    nvram_read_ns: float = 300.0   # persistent-media random read
    flush_issue_ns: float = 20.0   # CLWB issue (asynchronous)
    fence_base_ns: float = 100.0   # SFENCE drain, base
    fence_per_line_ns: float = 60.0  # per outstanding flushed line / NT line
    movnti_ns: float = 30.0        # non-temporal store issue (asynchronous)
    # behaviour
    flush_invalidates: bool = True   # CLWB evicts the line (Cascade Lake)
    needs_flush: bool = True         # algorithms must issue flushes at all
    persist_on_store: bool = False   # visible => durable (eADR)

    def describe(self) -> str:
        inv = "invalidating" if self.flush_invalidates else "retaining"
        dom = "cache-persistent" if self.persist_on_store else "flush-based"
        return (f"{self.name}: {dom}, {inv} flushes, "
                f"read {self.nvram_read_ns:.0f}ns, "
                f"fence {self.fence_base_ns:.0f}ns")


# Optane DC + CLWB on Cascade Lake: the paper's platform and the seed
# engine's historical behaviour (constants from van Renen'19 / Yang'20).
OPTANE_CLWB = MemoryModel(name="optane-clwb")

# eADR (battery-backed caches in the persistence domain): flushes are
# unnecessary and free, nothing is ever invalidated, stores persist once
# visible; SFENCE degenerates to a store-ordering barrier.
EADR = MemoryModel(
    name="eadr",
    flush_issue_ns=0.0,
    fence_base_ns=30.0,
    fence_per_line_ns=0.0,
    flush_invalidates=False,
    needs_flush=False,
    persist_on_store=True,
)

# CXL-attached persistent memory: flushes write back through the link but
# leave the line cached (no post-flush re-fetch penalty); reads and fence
# drains pay the longer CXL.mem round trip instead.
CXL_MEM = MemoryModel(
    name="cxl",
    nvram_read_ns=450.0,
    flush_issue_ns=25.0,
    fence_base_ns=200.0,
    fence_per_line_ns=90.0,
    flush_invalidates=False,
)

MEMORY_MODELS: Dict[str, MemoryModel] = {
    m.name: m for m in (OPTANE_CLWB, EADR, CXL_MEM)
}


def get_memory_model(model: Union[str, MemoryModel, None]) -> MemoryModel:
    """Resolve a model name (or pass a MemoryModel through; None = Optane)."""
    if model is None:
        return OPTANE_CLWB
    if isinstance(model, MemoryModel):
        return model
    try:
        return MEMORY_MODELS[model]
    except KeyError:
        raise ValueError(
            f"unknown memory model {model!r}; "
            f"known: {sorted(MEMORY_MODELS)}") from None
