"""OptUnlinkedQ -- second amendment of UnlinkedQ (paper §6.1, §6.3).

UnlinkedQ with **zero accesses to flushed content** while keeping the single
fence per operation:

* the global persisted head index becomes **per-thread head indices**, each
  on its own cache line, written with **non-temporal stores** (movnti) so the
  flushed-and-invalidated line is never fetched back; recovery takes the max;
* each node is split into a **Persistent** half (item, index, linked --
  flushed once by the enqueuer, then only ever read by recovery) and a
  **Volatile** half (item, index, next, pptr -- serves every fast-path read);
* the queue's head and tail point at Volatile halves, so dequeues CAS and
  read purely volatile memory; the only persistent-memory work in a dequeue
  is one movnti + one fence.
"""
from __future__ import annotations

from typing import Any, List, Tuple

from .nvram import LINE_WORDS, NVRAM
from .opsched import (AllocP, AllocV, Cas, Fence, FifoLayout, Flush, L,
                      Movnti, OpSchedule, QueueSchedules, Read, Retire,
                      RetireV, Write, WriteLine)
from .queue_base import NULL, QueueAlgorithm
from .ssmem import SSMem, VolatileAlloc

# Persistent half (designated areas, one line)
P_ITEM, P_INDEX, P_LINKED = 0, 1, 2
# Volatile half
V_ITEM, V_INDEX, V_NEXT, V_PPTR = 0, 1, 2, 3
V_WORDS = 4


class OptUnlinkedQueue(QueueAlgorithm):
    NAME = "OptUnlinkedQ"

    def __init__(self, nvram: NVRAM, mem: SSMem, nthreads: int, on_event=None,
                 _recovering: bool = False, roots=None):
        super().__init__(nvram, mem, nthreads, on_event)
        nv = self.nvram
        self.valloc = VolatileAlloc(nvram, nthreads, V_WORDS, name="optunlq")
        mem.attach_volatile(self.valloc)
        if roots is None:
            # per-thread head-index slots, one line each, + a root line id
            hidx = nv.alloc_region(nthreads * LINE_WORDS, "optunlq:headidx")
            roots = [hidx]
            self.HEADIDX = hidx
        else:
            self.HEADIDX = roots[0]
        self.roots = roots
        # head/tail are volatile pointers to Volatile halves
        self.HEAD = nv.alloc_region(1, "optunlq:head", persistent=False)
        self.TAIL = nv.alloc_region(1, "optunlq:tail", persistent=False)
        if not _recovering:
            for t in range(nthreads):
                nv.movnti(self.HEADIDX + t * LINE_WORDS, 0)
            self.pfence()
            dummy_p = self.mem.alloc(0)
            nv.write_full_line(dummy_p, [None, 0, 0, 0, 0, 0, 0, 0])
            self.pflush(dummy_p)
            self.pfence()
            dummy_v = self._new_vnode(0, None, 0, dummy_p)
            nv.write(self.HEAD, dummy_v)
            nv.write(self.TAIL, dummy_v)

    def _new_vnode(self, tid: int, item: Any, idx: int, pptr: int) -> int:
        nv = self.nvram
        v = self.valloc.alloc(tid)
        nv.write(v + V_ITEM, item)
        nv.write(v + V_INDEX, idx)
        nv.write(v + V_NEXT, NULL)
        nv.write(v + V_PPTR, pptr)
        return v

    # ---------------------------------------- steady-state schedule facts
    # Second amendment: the fast path reads/CASes Volatile halves only, so
    # a retry is pure cached work -- zero flushed_reads (the schedule's
    # volatile-only retry body *proves* it: the contention model zeroes
    # any flushed-read claim).  Contended runs must preserve
    # post_flush_accesses == 0 (property-tested).
    RETRY_SHAPES = {
        "enq": dict(reads=3),
        "deq": dict(reads=4),
    }

    def op_schedule(self):
        """Steady state (§6.1, §6.3): enqueue flushes its Persistent half
        once (never read back); dequeue's only persistent-memory work is
        one movnti + one fence.  Zero accesses to flushed content."""
        enq = OpSchedule("enq", steps=(
            AllocP(),
            # linked unset before a meaningful index is visible (§5.1.1)
            WriteLine(L("new_p"), (None, 0, 0, 0, 0, 0, 0, 0), item_at=0),
            AllocV(),
            Write(L("new_v", V_ITEM), ("item",)),
            Write(L("new_v", V_INDEX), ("c", 0)),
            Write(L("new_v", V_NEXT), ("c", NULL)),
            Write(L("new_v", V_PPTR), ("sym", "new_p")),
            Read(L("TAIL")),
            Read(L("tail_v", V_NEXT)),
            Read(L("tail_v", V_INDEX)),       # VOLATILE tail: no post-flush
            Write(L("new_p", P_INDEX), ("idx",)),
            Write(L("new_v", V_INDEX), ("idx",)),
            Cas(L("tail_v", V_NEXT), ("sym", "new_v"), event="enq"),
            Write(L("new_p", P_LINKED), ("c", 1)),
            Flush(L("new_p")), Fence(),       # flushed once, never read
            Cas(L("TAIL"), ("sym", "new_v"), root=True),
        ), retry_from=7)
        deq = OpSchedule("deq", steps=(
            Read(L("HEAD")),
            Read(L("head_v", V_NEXT)),
            Read(L("TAIL")),                  # MSQ guard
            Read(L("next_v", V_ITEM)),
            Read(L("next_v", V_INDEX)),
            Cas(L("HEAD"), ("sym", "next_v"), root=True, event="deq"),
            # persist this thread's head index: movnti, never read back
            Movnti(L("HEADIDX", per_tid=True), ("idx",)),
            Fence(),                          # the ONE fence
            Read(L("head_v", V_PPTR)),
            Retire(("sym", "head_p")),        # both halves, epoch-protected
            RetireV(("sym", "head_v")),
        ))
        return QueueSchedules(enq=enq, deq=deq, layout=FifoLayout(
            head_root="HEAD", next_off=V_NEXT, item_off=V_ITEM,
            idx_off=V_INDEX, pptr_off=V_PPTR, volatile=True))

    # --------------------------------------------------------------- enqueue
    def enqueue(self, tid: int, item: Any) -> None:
        nv = self.nvram
        self.mem.op_begin(tid)
        pnode = self.mem.alloc(tid)
        # linked unset before a meaningful index is visible (§5.1.1 order);
        # full-line init avoids fetching a previously flushed line.
        nv.write_full_line(pnode, [item, 0, 0, 0, 0, 0, 0, 0])
        vnode = self._new_vnode(tid, item, 0, pnode)
        while True:
            tailv = nv.read(self.TAIL)
            if nv.read(tailv + V_NEXT) == NULL:
                # index read from the VOLATILE tail -- no post-flush access
                idx = nv.read(tailv + V_INDEX) + 1
                nv.write(pnode + P_INDEX, idx)
                nv.write(vnode + V_INDEX, idx)
                if nv.cas(tailv + V_NEXT, NULL, vnode):
                    self._ev("enq", item)
                    nv.write(pnode + P_LINKED, 1)
                    self.pflush(pnode)                  # flushed once, never read
                    self.pfence()                       # the ONE fence
                    nv.cas(self.TAIL, tailv, vnode)
                    return
            else:
                nv.cas(self.TAIL, tailv, nv.read(tailv + V_NEXT))

    # --------------------------------------------------------------- dequeue
    def dequeue(self, tid: int) -> Any:
        nv = self.nvram
        self.mem.op_begin(tid)
        while True:
            headv = nv.read(self.HEAD)
            nxt = nv.read(headv + V_NEXT)
            if nxt == NULL:
                # persist this thread's view of the head index (§6.3: movnti,
                # never read back) so prior dequeues that emptied the queue
                # are durable before we report empty.
                idx = nv.read(headv + V_INDEX)
                nv.movnti(self.HEADIDX + tid * LINE_WORDS, idx)
                self.pfence()
                self._ev("empty")
                return None
            # MSQ guard: head must not overtake tail (reclamation safety)
            tailv = nv.read(self.TAIL)
            if headv == tailv:
                nv.cas(self.TAIL, tailv, nxt)
                continue
            item = nv.read(nxt + V_ITEM)
            idx = nv.read(nxt + V_INDEX)
            if nv.cas(self.HEAD, headv, nxt):
                self._ev("deq", item)
                nv.movnti(self.HEADIDX + tid * LINE_WORDS, idx)
                self.pfence()                           # the ONE fence
                # retire both halves of the old dummy (epoch-protected)
                self.mem.retire(tid, nv.read(headv + V_PPTR))
                self.mem.retire_volatile(tid, headv)
                return item

    # -------------------------------------------------------------- recovery
    @classmethod
    def recover(cls, nvram: NVRAM, mem: SSMem, nthreads: int, roots,
                on_event=None) -> "OptUnlinkedQueue":
        q = cls(nvram, mem, nthreads, on_event, _recovering=True, roots=roots)
        nv = nvram
        head_idx = max((nv.pread(q.HEADIDX + t * LINE_WORDS) or 0)
                       for t in range(nthreads))
        live: List[Tuple[int, int]] = []
        free: List[int] = []
        for base, nnodes in mem.area_addrs():
            for i in range(nnodes):
                a = base + i * LINE_WORDS
                if a == q.HEADIDX:   # head-index region is not an area
                    continue
                linked = nv.pread(a + P_LINKED)
                idx = nv.pread(a + P_INDEX) or 0
                if linked and idx > head_idx:
                    live.append((idx, a))
                else:
                    free.append(a)
        live.sort()
        # dummy Persistent with the recovered head index (§6.1)
        dummy_p = free.pop() if free else mem.alloc(0)
        nv.pwrite(dummy_p + P_ITEM, None)
        nv.pwrite(dummy_p + P_INDEX, head_idx)
        nv.pwrite(dummy_p + P_LINKED, 0)
        # per-thread indices stand as-is (max is unchanged); build Volatile twins
        dummy_v = q._new_vnode(0, None, head_idx, dummy_p)
        nv.write(q.HEAD, dummy_v)
        prev = dummy_v
        for idx, a in live:
            v = q._new_vnode(0, nv.pread(a + P_ITEM), idx, a)
            nv.write(prev + V_NEXT, v)
            prev = v
        nv.write(q.TAIL, prev)
        for a in free:
            mem.free_now(0, a)
        nvram.reset_after_recovery()
        return q
