"""Sharding rules: logical parameter/activation layouts -> NamedSharding.

Mesh axes (launch/mesh.py):
  single-pod:  ("data", "model")           = (16, 16)   -- 256 chips
  multi-pod:   ("pod", "data", "model")    = (2, 16, 16) -- 512 chips

Strategy (MaxText-style 2D sharding + ZeRO):
  * batch: sharded over ("pod", "data");
  * parameters: tensor-parallel over "model" on the contracting/expert axis,
    FSDP over "data" on the other axis (GSPMD inserts the all-gathers);
    pods hold replicas (gradient all-reduce over "pod" -- hierarchical DP);
  * optimizer state (AdamW m/v): additionally sharded over "pod" (ZeRO-1
    across pods) -- states are only touched at the update, so the extra
    gather cost is off the critical path;
  * activations (residual stream): batch-sharded + sequence-sharded over
    "model" between layers (Megatron-style sequence parallelism) for long
    sequences, controlled by ``seq_shard``.

Rules are matched on parameter-tree paths; stacked (scanned) layers have a
leading period axis which is never sharded.
"""
from __future__ import annotations

import re
from typing import Any, Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

PyTree = Any

# (regex on param path, spec WITHOUT the stacked leading axis)
# fsdp == data axis; tp == model axis
_PARAM_RULES = [
    (r"embed$", ("tp", "fsdp")),
    (r"lm_head$", ("fsdp", "tp")),
    (r"final_norm$", (None,)),
    (r"norm1$|norm2$|q_norm$|k_norm$", (None,)),
    # attention
    (r"mixer/w[qkv]$", ("fsdp", "tp")),
    (r"mixer/wo$", ("tp", "fsdp")),
    # mamba
    (r"mixer/in_proj$", ("fsdp", "tp")),
    (r"mixer/conv_w$", (None, "tp")),
    (r"mixer/x_proj$", ("tp", None)),
    (r"mixer/dt_proj$", (None, "tp")),
    (r"mixer/dt_bias$", ("tp",)),
    (r"mixer/A_log$", ("tp", None)),
    (r"mixer/D$", ("tp",)),
    (r"mixer/out_proj$", ("tp", "fsdp")),
    # moe first: experts over the model axis (EP == TP axis) -- these MUST
    # precede the dense-ffn rules, which also match "ffn/w1" etc.
    (r"ffn/router$", ("fsdp", None)),
    (r"ffn/(w1|w3)$__moe", ("tp", "fsdp", None)),
    (r"ffn/w2$__moe", ("tp", None, "fsdp")),
    # dense ffn
    (r"ffn/w1$|ffn/w3$", ("fsdp", "tp")),
    (r"ffn/w2$", ("tp", "fsdp")),
    (r"ffn/sh_w1$|ffn/sh_w3$", ("fsdp", "tp")),
    (r"ffn/sh_w2$", ("tp", "fsdp")),
]


def _path_str(path) -> str:
    parts = []
    for p in path:
        if isinstance(p, jax.tree_util.DictKey):
            parts.append(str(p.key))
        elif isinstance(p, jax.tree_util.SequenceKey):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def _axis(mesh: Mesh, logical: Optional[str], params_over_pod: bool,
          fsdp: bool = True):
    if logical is None:
        return None
    if logical == "tp":
        return "model"
    if logical == "fsdp":
        if not fsdp:
            return None     # replicate over data: no per-layer weight
                            # all-gathers (wins for models whose TP shard
                            # already fits HBM -- see EXPERIMENTS.md §Perf)
        if params_over_pod and "pod" in mesh.axis_names:
            return ("pod", "data")
        return "data"
    raise ValueError(logical)


def spec_for_param(mesh: Mesh, path, leaf, *, stacked_depth: int,
                   is_moe: bool, params_over_pod: bool = False,
                   fsdp: bool = True) -> P:
    s = _path_str(path)
    for pat, logical in _PARAM_RULES:
        pat_re, suffix = (pat.split("$__")[0] + "$", "__moe") \
            if pat.endswith("__moe") else (pat, "")
        if suffix and not is_moe:
            continue
        if re.search(pat_re, s):
            axes = tuple(_axis(mesh, a, params_over_pod, fsdp)
                         for a in logical)
            lead = (None,) * stacked_depth
            full = lead + axes
            if len(full) < leaf.ndim:
                full = full + (None,) * (leaf.ndim - len(full))
            return P(*full[:leaf.ndim])
    return P()   # replicate by default (small tensors)


def _is_moe_param(path_str: str) -> bool:
    # moe expert weights have a leading E dim; identified by rank at caller
    return False


def param_shardings(mesh: Mesh, params: PyTree,
                    params_over_pod: bool = False,
                    fsdp: bool = True) -> PyTree:
    """NamedSharding tree matching `params` (works on ShapeDtypeStructs)."""
    def one(path, leaf):
        s = _path_str(path)
        in_stack = s.startswith("stack/")
        # moe expert tensors: ffn/w{1,2,3} with an expert axis => rank 3 body
        body_rank = leaf.ndim - (1 if in_stack else 0)
        is_moe = bool(re.search(r"ffn/(w1|w2|w3)$", s)) and body_rank == 3
        return NamedSharding(mesh, spec_for_param(
            mesh, path, leaf, stacked_depth=1 if in_stack else 0,
            is_moe=is_moe, params_over_pod=params_over_pod, fsdp=fsdp))
    return jax.tree_util.tree_map_with_path(one, params)


def opt_state_shardings(mesh: Mesh, opt_state: PyTree, params: PyTree) -> PyTree:
    """m/v inherit parameter shardings ZeRO-extended over the pod axis;
    int8-quantized states (dicts of q/s) are sharded on the flat block dim."""
    pshard = param_shardings(mesh, params, params_over_pod=True)

    def map_mv(ps, leaf_tree):
        if not isinstance(leaf_tree, dict):
            return ps      # fp32 state mirrors the parameter layout
        # int8-quantized {q: (nblk, BLOCK), s: (nblk, 1)}: shard the flat
        # block dim across (pod, data) -- pure ZeRO layout; small tensors
        # whose block count doesn't divide the axes stay replicated
        ax = ("pod", "data") if "pod" in mesh.axis_names else "data"
        return {k: NamedSharding(mesh, P(_fit(mesh, v.shape[0], ax, "data"),
                                         None))
                for k, v in leaf_tree.items()}

    m = jax.tree.map(map_mv, pshard, opt_state["m"],
                     is_leaf=lambda x: isinstance(x, NamedSharding))
    v = jax.tree.map(map_mv, pshard, opt_state["v"],
                     is_leaf=lambda x: isinstance(x, NamedSharding))
    return {"step": NamedSharding(mesh, P()), "m": m, "v": v}


def _axes_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        return mesh.shape[axes]
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def _fit(mesh: Mesh, dim: int, *candidates):
    """First candidate axis (or axis tuple) that divides `dim`, else None."""
    for c in candidates:
        if c is None:
            continue
        if dim % _axes_size(mesh, c) == 0:
            return c
    return None


def _batch_axes(mesh: Mesh):
    return ("pod", "data") if "pod" in mesh.axis_names else "data"


def batch_shardings(mesh: Mesh, batch: PyTree) -> PyTree:
    bx = _batch_axes(mesh)

    def one(leaf):
        ax = _fit(mesh, leaf.shape[0], bx, "data")
        spec = (ax,) + (None,) * (leaf.ndim - 1)
        return NamedSharding(mesh, P(*spec))
    return jax.tree.map(one, batch)


def cache_shardings(mesh: Mesh, cache: PyTree) -> PyTree:
    """KV/SSM caches, divisibility-aware.

    k/v (L?, B, S, KV, hd): batch over (pod,data) when divisible, sequence
    over 'model' (kv-head counts are usually < 16, so heads stay local and
    attention contracts over the sharded S with a psum); when B=1
    (long-context) the sequence absorbs every mesh axis.
    h (L?, B, din, ds) / conv (L?, B, k, din): d_inner over 'model'."""
    bx = _batch_axes(mesh)
    all_ax = tuple(mesh.axis_names)

    def one(path, leaf):
        s = _path_str(path)
        stacked = s.startswith("stack/")
        lead = (None,) if stacked else ()
        name = s.rsplit("/", 1)[-1]
        nd = leaf.ndim - len(lead)
        dims = leaf.shape[len(lead):]
        if name in ("k", "v") and nd == 4:
            b_ax = _fit(mesh, dims[0], bx, "data")
            if b_ax is None:
                s_ax = _fit(mesh, dims[1], all_ax, ("data", "model"), "model")
            else:
                s_ax = _fit(mesh, dims[1], "model")
            spec = lead + (b_ax, s_ax, None, None)
        elif name == "h" and nd == 3:
            b_ax = _fit(mesh, dims[0], bx, "data")
            d_ax = _fit(mesh, dims[1],
                        ("data", "model") if b_ax is None else "model",
                        "model")
            spec = lead + (b_ax, d_ax, None)
        elif name == "conv" and nd == 3:
            b_ax = _fit(mesh, dims[0], bx, "data")
            d_ax = _fit(mesh, dims[2],
                        ("data", "model") if b_ax is None else "model",
                        "model")
            spec = lead + (b_ax, None, d_ax)
        else:
            b_ax = _fit(mesh, dims[0], bx, "data")
            spec = lead + (b_ax,) + (None,) * (nd - 1)
        return NamedSharding(mesh, P(*spec[:leaf.ndim]))
    return jax.tree_util.tree_map_with_path(one, cache)


def activation_constrainer(mesh: Mesh, seq_shard: bool = False):
    """Activation constraints for the model code.

    kind="residual": batch-shard (optionally sequence-shard) the stream.
    kind="moe_xe":   dispatch buffer (E, C, d) pinned to (model, data, None)
                     so the expert einsum gathers *weights* (FSDP-style, MBs)
                     instead of replicating the token buffer (GBs)."""
    bx = _batch_axes(mesh)

    def cons(x, kind: str = "residual"):
        if kind == "moe_xe" and x.ndim == 3:
            e_ax = _fit(mesh, x.shape[0], "model")
            c_ax = _fit(mesh, x.shape[1], bx, "data")
            return jax.lax.with_sharding_constraint(
                x, NamedSharding(mesh, P(e_ax, c_ax, None)))
        if kind == "moe_ye" and x.ndim == 4:
            c_ax = _fit(mesh, x.shape[0], bx, "data")
            return jax.lax.with_sharding_constraint(
                x, NamedSharding(mesh, P(c_ax, None, None, None)))
        if kind == "residual" and x.ndim == 3:
            b_ax = _fit(mesh, x.shape[0], bx, "data")
            s_ax = _fit(mesh, x.shape[1], "model") if seq_shard else None
            return jax.lax.with_sharding_constraint(
                x, NamedSharding(mesh, P(b_ax, s_ax, None)))
        return x
    return cons
