"""Distributed-optimization helpers: gradient compression with error
feedback, and microbatch compute/comm overlap accounting.

``compress_grads``/``decompress_grads`` implement bf16 (or int8 blockwise)
gradient compression with an error-feedback accumulator (Karimireddy et al.
-- the residual of the quantization is added back into the next step's
gradient), halving/quartering the all-reduce payload.  Pure functions:
numerics are unit-tested on CPU; at scale the compressed tensors are what
the pod-axis all-reduce moves.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

PyTree = Any
_BLOCK = 256


def init_error_feedback(params: PyTree) -> PyTree:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compress_grads(grads: PyTree, error: PyTree,
                   method: str = "bf16") -> Tuple[PyTree, PyTree]:
    """Returns (compressed, new_error).  compressed is what goes on the
    wire; new_error is the quantization residual to re-inject next step."""
    def one(g, e):
        g = g.astype(jnp.float32) + e
        if method == "bf16":
            c = g.astype(jnp.bfloat16)
            back = c.astype(jnp.float32)
        elif method == "int8":
            flat = g.reshape(-1)
            pad = (-flat.shape[0]) % _BLOCK
            fp = jnp.pad(flat, (0, pad)).reshape(-1, _BLOCK)
            scale = jnp.max(jnp.abs(fp), axis=1, keepdims=True) / 127.0
            q = jnp.round(fp / jnp.maximum(scale, 1e-12)).astype(jnp.int8)
            c = {"q": q, "s": scale, "shape": g.shape}
            back = (q.astype(jnp.float32) * scale).reshape(-1)[
                :flat.shape[0]].reshape(g.shape)
        else:
            raise ValueError(method)
        return c, g - back
    flat, tdef = jax.tree.flatten(grads)
    flat_e = tdef.flatten_up_to(error)
    pairs = [one(g, e) for g, e in zip(flat, flat_e)]
    return tdef.unflatten([p[0] for p in pairs]), \
        tdef.unflatten([p[1] for p in pairs])


def decompress_grads(compressed: PyTree) -> PyTree:
    def one(c):
        if isinstance(c, dict) and "q" in c:
            flat = (c["q"].astype(jnp.float32) * c["s"]).reshape(-1)
            n = 1
            for s in c["shape"]:
                n *= s
            return flat[:n].reshape(c["shape"])
        return c.astype(jnp.float32)
    return jax.tree.map(one, compressed,
                        is_leaf=lambda x: isinstance(x, dict) and "q" in x)


def compressed_bytes(compressed: PyTree) -> int:
    tot = 0
    for leaf in jax.tree.leaves(compressed):
        tot += leaf.size * leaf.dtype.itemsize
    return tot
