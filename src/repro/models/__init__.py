from .config import ModelConfig, ShapeConfig, SHAPES
from .model import (forward, init_cache, init_params, loss_fn, serve_step)

__all__ = ["ModelConfig", "ShapeConfig", "SHAPES", "forward", "init_cache",
           "init_params", "loss_fn", "serve_step"]
