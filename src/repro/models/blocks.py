"""Layer composition: (mixer, ffn) sub-layer pairs with pre-RMSNorm."""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from .attention import (attention_block, decode_attention_block,
                        init_attention, init_attn_cache)
from .common import act_fn, dense_init, rms_norm
from .config import LayerSpec, ModelConfig
from .mamba import (init_mamba, init_mamba_cache, mamba_block,
                    mamba_decode_step)
from .moe import init_moe, moe_ffn


def init_dense_ffn(cfg: ModelConfig, key, d_ff: int) -> dict:
    d = cfg.d_model
    dt = jnp.dtype(cfg.param_dtype)
    k1, k2, k3 = jax.random.split(key, 3)
    gated = cfg.act in ("swiglu", "geglu")
    p = {"w1": dense_init(k1, (d, d_ff), dt),
         "w2": dense_init(k2, (d_ff, d), dt)}
    if gated:
        p["w3"] = dense_init(k3, (d, d_ff), dt)
    return p


def dense_ffn(cfg: ModelConfig, params, x: jax.Array) -> jax.Array:
    act = act_fn(cfg.act)
    h = x @ params["w1"]
    if cfg.act in ("swiglu", "geglu"):
        h = act(h) * (x @ params["w3"])
    else:
        h = act(h)
    return h @ params["w2"]


def init_layer(cfg: ModelConfig, spec: LayerSpec, key) -> dict:
    mixer, ffn = spec
    k1, k2 = jax.random.split(key)
    dt = jnp.dtype(cfg.param_dtype)
    p = {"norm1": jnp.ones((cfg.d_model,), dt)}
    p["mixer"] = (init_attention(cfg, k1) if mixer == "attn"
                  else init_mamba(cfg, k1))
    if ffn != "none":
        p["norm2"] = jnp.ones((cfg.d_model,), dt)
        if ffn == "moe":
            p["ffn"] = init_moe(cfg, k2)
        elif ffn == "dense_first":
            p["ffn"] = init_dense_ffn(cfg, k2, cfg.dense_ff_first)
        else:
            p["ffn"] = init_dense_ffn(cfg, k2, cfg.d_ff)
    return p


def apply_layer(cfg: ModelConfig, spec: LayerSpec, params, x, positions,
                use_pallas: bool = False, cons=None) -> jax.Array:
    mixer, ffn = spec
    h = rms_norm(x, params["norm1"], cfg.norm_eps)
    if mixer == "attn":
        h = attention_block(cfg, params["mixer"], h, positions, use_pallas)
    else:
        h = mamba_block(cfg, params["mixer"], h, use_pallas)
    x = x + h
    if ffn != "none":
        h = rms_norm(x, params["norm2"], cfg.norm_eps)
        if ffn == "moe":
            h = moe_ffn(cfg, params["ffn"], h, cons)
        else:
            h = dense_ffn(cfg, params["ffn"], h)
        x = x + h
    return x


# ------------------------------------------------------------------ decode --
def init_layer_cache(cfg: ModelConfig, spec: LayerSpec, batch: int,
                     max_len: int) -> dict:
    mixer, _ = spec
    if mixer == "attn":
        return init_attn_cache(cfg, batch, max_len)
    return init_mamba_cache(cfg, batch)


def apply_layer_decode(cfg: ModelConfig, spec: LayerSpec, params, x, cache,
                       position, use_pallas: bool = False, cons=None
                       ) -> Tuple[jax.Array, dict]:
    mixer, ffn = spec
    h = rms_norm(x, params["norm1"], cfg.norm_eps)
    if mixer == "attn":
        h, cache = decode_attention_block(cfg, params["mixer"], h, cache,
                                          position, use_pallas)
    else:
        h, cache = mamba_decode_step(cfg, params["mixer"], h, cache)
    x = x + h
    if ffn != "none":
        h = rms_norm(x, params["norm2"], cfg.norm_eps)
        if ffn == "moe":
            h = moe_ffn(cfg, params["ffn"], h, cons)
        else:
            h = dense_ffn(cfg, params["ffn"], h)
        x = x + h
    return x, cache
