"""Shared building blocks: norms, rotary embeddings, activations, init."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps)).astype(dt) * scale


def act_fn(name: str):
    if name in ("swiglu", "geglu"):
        inner = jax.nn.silu if name == "swiglu" else jax.nn.gelu
        return inner
    if name == "sq_relu":
        return lambda x: jnp.square(jax.nn.relu(x))
    raise ValueError(name)


# ----------------------------------------------------------------- rotary --
def rope_freqs(head_dim: int, theta: float) -> np.ndarray:
    return 1.0 / (theta ** (np.arange(0, head_dim, 2) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    freqs = jnp.asarray(rope_freqs(hd, theta), dtype=jnp.float32)
    ang = positions[..., :, None].astype(jnp.float32) * freqs   # (..., S, hd/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    cos = cos[..., :, None, :]      # broadcast over heads
    sin = sin[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x: jax.Array, positions: jax.Array, theta: float,
                sections=None) -> jax.Array:
    """Qwen2-VL multimodal RoPE: the rotary dims are partitioned into
    (temporal, height, width) sections, each rotated by its own position id.
    positions: (..., 3, S) -- for pure text all three ids coincide.
    x: (..., S, H, hd)."""
    hd = x.shape[-1]
    half = hd // 2
    if sections is None:
        # Qwen2-VL proportions (16,24,24)/64, scaled to the head dim
        s1 = half // 4
        s2 = (half - s1 + 1) // 2
        sections = (s1, s2, half - s1 - s2)
    assert sum(sections) == half, (sections, hd)
    freqs = jnp.asarray(rope_freqs(hd, theta), dtype=jnp.float32)  # (half,)
    # positions (..., 3, S) -> per-frequency positions (..., S, half).
    # Static concat (NOT a gather): SPMD-partitions cleanly; a fancy-index
    # here triggered involuntary full rematerialization in GSPMD.
    p = jnp.moveaxis(positions, -2, -1)       # (..., S, 3)
    per_freq = jnp.concatenate(
        [jnp.broadcast_to(p[..., i:i + 1], p.shape[:-1] + (s,))
         for i, s in enumerate(sections)], axis=-1)   # (..., S, half)
    ang = per_freq.astype(jnp.float32) * freqs
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ------------------------------------------------------------------- init --
def dense_init(key, shape, dtype, scale: float = 1.0):
    fan_in = shape[0] if len(shape) >= 2 else 1
    std = scale / np.sqrt(fan_in)
    return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)


def keygen(key):
    while True:
        key, sub = jax.random.split(key)
        yield sub
