"""Mixture-of-Experts FFN with sort-based capacity dispatch.

Design goals:
* compiled FLOPs track *active* experts (top-k routing with capacity C =
  ceil(T·k/E · capacity_factor)), so the roofline's MODEL_FLOPS/HLO_FLOPs
  ratio stays honest -- no dense all-experts einsum;
* expert-parallel shardable: expert weights carry a leading E dim that the
  sharding rules place on the 'model' mesh axis; dispatch/combine are
  gather/scatters that GSPMD turns into all-to-alls;
* fine-grained MoE (DeepSeekMoE): optional always-on shared experts.

Dispatch: tokens' (token, expert) assignments are sorted by expert id
(stable argsort), positions within each expert computed from the sorted
order; tokens beyond capacity are dropped (standard GShard/Switch
semantics -- tests use a high capacity factor to validate equivalence
against the dense reference).
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from .common import act_fn, dense_init
from .config import ModelConfig


def init_moe(cfg: ModelConfig, key) -> dict:
    d, dff, E, S = cfg.d_model, cfg.d_ff, cfg.n_experts, cfg.n_shared_experts
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 7)
    gated = cfg.act in ("swiglu", "geglu")
    p = {
        "router": dense_init(ks[0], (d, E), dt),
        "w1": dense_init(ks[1], (E, d, dff), dt),
        "w2": dense_init(ks[2], (E, dff, d), dt),
    }
    if gated:
        p["w3"] = dense_init(ks[3], (E, d, dff), dt)
    if S:
        p["sh_w1"] = dense_init(ks[4], (d, S * dff), dt)
        p["sh_w2"] = dense_init(ks[5], (S * dff, d), dt)
        if gated:
            p["sh_w3"] = dense_init(ks[6], (d, S * dff), dt)
    return p


def _moe_chunks(T: int) -> int:
    """Token chunks for locality: sorts/dispatch run per chunk, so with the
    chunk axis batch-sharded the routing never leaves the device; only the
    (chunk,E)->(E,chunk) transpose for the expert einsum moves tokens --
    exactly the canonical expert-parallel all-to-all."""
    for nc in (32, 16, 8, 4, 2, 1):
        if T % nc == 0 and T // nc >= 16:
            return nc
    return 1


def moe_ffn(cfg: ModelConfig, params, x: jax.Array,
            cons=None) -> jax.Array:
    """x: (B, S, d) -> (B, S, d)."""
    cons = cons or (lambda t, kind=None: t)
    import math
    B, S, d = x.shape
    E, k = cfg.n_experts, cfg.top_k
    T = B * S
    nc = _moe_chunks(T)
    tc = T // nc                                # tokens per chunk
    xt = x.reshape(nc, tc, d)
    act = act_fn(cfg.act)
    gated = cfg.act in ("swiglu", "geglu")
    cap = int(max(1, math.ceil(tc * k / E * cfg.capacity_factor)))

    # ---- routing + chunk-local sort-based capacity dispatch ---------------
    def route_chunk(xc):
        logits = (xc @ params["router"]).astype(jnp.float32)    # (tc, E)
        gates = jax.nn.softmax(logits, axis=-1)
        top_g, top_e = jax.lax.top_k(gates, k)                  # (tc, k)
        top_g = top_g / jnp.maximum(top_g.sum(-1, keepdims=True), 1e-9)
        flat_e = top_e.reshape(-1)                              # (tc*k,)
        order = jnp.argsort(flat_e, stable=True)
        sorted_e = flat_e[order]
        idx = jnp.arange(tc * k)
        seg_start = jnp.searchsorted(sorted_e, jnp.arange(E))
        pos_in_e = idx - seg_start[sorted_e]
        keep = pos_in_e < cap
        slot = sorted_e * cap + pos_in_e
        token_of = order // k
        buf = jnp.zeros((E * cap, d), x.dtype)
        # out-of-bounds slot for dropped tokens => the write is discarded
        buf = buf.at[jnp.where(keep, slot, E * cap)].set(
            xc[token_of], mode="drop")
        gate_of = top_g.reshape(-1)[order]
        return buf.reshape(E, cap, d), (slot, keep, token_of, gate_of)

    xe, combine_info = jax.vmap(route_chunk)(xt)   # xe: (nc, E, cap, d)

    # ---- expert computation (active FLOPs; EP all-to-all at the transpose)
    xe = jnp.swapaxes(xe, 0, 1).reshape(E, nc * cap, d)
    xe = cons(xe, "moe_xe")   # pin (E@model, C@data): see sharding.py
    h = jnp.einsum("ecd,edf->ecf", xe, params["w1"])
    if gated:
        h = act(h) * jnp.einsum("ecd,edf->ecf", xe, params["w3"])
    else:
        h = act(h)
    ye = jnp.einsum("ecf,efd->ecd", h, params["w2"])    # (E, nc*cap, d)
    ye = jnp.swapaxes(ye.reshape(E, nc, cap, d), 0, 1)  # (nc, E, cap, d)
    # NOTE(perf, measured): constraining ye back to chunk-local here
    # (cons(ye, "moe_ye")) converts the combine's fp32 masked psums into a
    # bf16 all-gather, but the gather volume exceeds the psum saving
    # (313+236 GB vs 486+19 GB/device on deepseek train_4k) -- refuted,
    # see EXPERIMENTS.md §Perf iteration log.  The canonical fix is a
    # shard_map all-to-all combine (future work, napkin floor ~3.5s).

    # ---- chunk-local combine ----------------------------------------------
    def combine_chunk(ye_c, info):
        slot, keep, token_of, gate_of = info
        yflat = ye_c.reshape(E * cap, d)
        contrib = yflat[jnp.where(keep, slot, 0)] * keep[:, None]
        contrib = contrib * gate_of[:, None].astype(x.dtype)
        return jax.ops.segment_sum(contrib, token_of, num_segments=tc)

    y = jax.vmap(combine_chunk)(ye, combine_info).reshape(T, d)
    xt = xt.reshape(T, d)

    # ---- shared experts (always on) ---------------------------------------
    if cfg.n_shared_experts:
        hs = xt @ params["sh_w1"]
        if gated:
            hs = act(hs) * (xt @ params["sh_w3"])
        else:
            hs = act(hs)
        y = y + hs @ params["sh_w2"]
    return y.reshape(B, S, d).astype(x.dtype)


def moe_ffn_dense_reference(cfg: ModelConfig, params, x: jax.Array) -> jax.Array:
    """Oracle: evaluate every expert densely, weight by top-k gates.
    Used by tests (equivalence when capacity is not binding)."""
    B, S, d = x.shape
    E, k = cfg.n_experts, cfg.top_k
    xt = x.reshape(-1, d)
    act = act_fn(cfg.act)
    gated = cfg.act in ("swiglu", "geglu")
    logits = (xt @ params["router"]).astype(jnp.float32)
    gates = jax.nn.softmax(logits, axis=-1)
    top_g, top_e = jax.lax.top_k(gates, k)
    top_g = top_g / jnp.maximum(top_g.sum(-1, keepdims=True), 1e-9)
    w = jnp.zeros_like(gates).at[jnp.arange(xt.shape[0])[:, None], top_e].set(top_g)
    h = jnp.einsum("td,edf->tef", xt, params["w1"])
    if gated:
        h = act(h) * jnp.einsum("td,edf->tef", xt, params["w3"])
    else:
        h = act(h)
    ye = jnp.einsum("tef,efd->ted", h, params["w2"])
    y = jnp.einsum("ted,te->td", ye, w.astype(x.dtype))
    if cfg.n_shared_experts:
        hs = xt @ params["sh_w1"]
        if gated:
            hs = act(hs) * (xt @ params["sh_w3"])
        else:
            hs = act(hs)
        y = y + hs @ params["sh_w2"]
    return y.reshape(B, S, d).astype(x.dtype)
