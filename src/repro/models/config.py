"""Model configuration for the assigned architecture zoo.

A model is a decoder-only stack described as:
  * optional ``prefix`` layers (unstacked, e.g. DeepSeekMoE's dense layer 0),
  * a repeated ``pattern`` of sub-layer specs scanned ``n_periods`` times
    (jax.lax.scan over stacked params keeps HLO size / compile time bounded),
  * embeddings + final norm + LM head.

Each pattern element is a (mixer, ffn) pair:
  mixer ∈ {"attn", "mamba"};  ffn ∈ {"dense", "moe", "none"}.
"""
from __future__ import annotations

import dataclasses
import math
from typing import List, Optional, Tuple

LayerSpec = Tuple[str, str]     # (mixer, ffn)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int                   # dense FFN hidden (per-expert hidden for MoE)
    vocab: int
    d_head: Optional[int] = None
    act: str = "swiglu"         # swiglu | sq_relu | geglu
    rope: str = "rope"          # rope | mrope | none
    rope_theta: float = 1e4
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    attn_bias: bool = False
    qk_norm: bool = False
    # --- MoE ---
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    moe_every: int = 1          # apply MoE every k-th layer (jamba: 2)
    dense_ff_first: int = 0     # DeepSeekMoE: dense FFN width for layer 0
    capacity_factor: float = 1.25
    # --- SSM (mamba-1) ---
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    dt_rank: int = 0
    # --- hybrid ---
    attn_every: int = 0         # jamba: one attn layer per 8 (at position 4)
    attn_position: int = 4
    # --- frontend stub (vlm/audio): inputs may be precomputed embeddings ---
    embed_stub: bool = False
    # perf knobs (hillclimb levers; see EXPERIMENTS.md §Perf)
    attn_unroll_q: bool = False   # unroll q-blocks, skip masked KV blocks
    # --- numerics ---
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    # sub-quadratic? (drives long_500k applicability)
    sub_quadratic: bool = False

    # ------------------------------------------------------------ derived --
    @property
    def head_dim(self) -> int:
        return self.d_head if self.d_head else self.d_model // self.n_heads

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def dt_rank_(self) -> int:
        return self.dt_rank or max(1, math.ceil(self.d_model / 16))

    # ------------------------------------------------------- layer pattern --
    def layer_pattern(self) -> Tuple[List[LayerSpec], int, List[LayerSpec]]:
        """Returns (prefix_specs, n_periods, period_pattern)."""
        if self.family == "ssm":
            return [], self.n_layers, [("mamba", "none")]
        if self.family == "hybrid":
            period = self.attn_every or 8
            pat: List[LayerSpec] = []
            for i in range(period):
                mixer = "attn" if i == self.attn_position else "mamba"
                ffn = "moe" if (self.n_experts and i % self.moe_every == 1) \
                    else "dense"
                pat.append((mixer, ffn))
            assert self.n_layers % period == 0
            return [], self.n_layers // period, pat
        if self.family == "moe":
            if self.dense_ff_first:
                return ([("attn", "dense_first")], self.n_layers - 1,
                        [("attn", "moe")])
            return [], self.n_layers, [("attn", "moe")]
        # dense / vlm / audio
        return [], self.n_layers, [("attn", "dense")]

    def n_params(self) -> int:
        """Total parameter count (used for MODEL_FLOPS = 6·N·D)."""
        d, dff, V = self.d_model, self.d_ff, self.vocab
        hd, H, KV = self.head_dim, self.n_heads, self.n_kv_heads
        prefix, periods, pat = self.layer_pattern()
        total = V * d * (1 if self.tie_embeddings else 2)
        gated = self.act in ("swiglu", "geglu")

        def ffn_params(kind: str) -> int:
            if kind == "none":
                return 0
            if kind == "dense":
                return d * dff * (3 if gated else 2)
            if kind == "dense_first":
                return d * self.dense_ff_first * (3 if gated else 2)
            per_exp = d * dff * (3 if gated else 2)
            return (self.n_experts + self.n_shared_experts) * per_exp \
                + d * self.n_experts    # router

        def mixer_params(kind: str) -> int:
            if kind == "attn":
                return d * hd * (H + 2 * KV) + H * hd * d
            din, ds, dtr = self.d_inner, self.ssm_state, self.dt_rank_
            return (d * 2 * din            # in_proj
                    + din * self.ssm_conv  # conv
                    + din * (dtr + 2 * ds) # x_proj (dt, B, C)
                    + dtr * din + din      # dt_proj, dt_bias
                    + din * ds + din       # A_log, D
                    + din * d)             # out_proj

        def norms(ff: str) -> int:
            return d if ff == "none" else 2 * d

        for (mx, ff) in prefix:
            total += mixer_params(mx) + ffn_params(ff) + norms(ff)
        for (mx, ff) in pat:
            total += periods * (mixer_params(mx) + ffn_params(ff) + norms(ff))
        total += d   # final norm
        return total

    def n_active_params(self) -> int:
        """Active parameters per token (MoE: top_k + shared experts only)."""
        if not self.n_experts:
            return self.n_params()
        d, dff = self.d_model, self.d_ff
        gated = self.act in ("swiglu", "geglu")
        per_exp = d * dff * (3 if gated else 2)
        inactive = (self.n_experts - self.top_k) * per_exp
        _, periods, pat = self.layer_pattern()
        n_moe_layers = periods * sum(1 for (_, f) in pat if f == "moe")
        return self.n_params() - n_moe_layers * inactive


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str          # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}
