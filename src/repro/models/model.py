"""CausalLM: init / forward / loss / prefill / decode over the layer stack.

Layers are grouped as (optional unstacked prefix) + (pattern × n_periods)
with ``jax.lax.scan`` over stacked period parameters -- HLO stays one period
big regardless of depth, which keeps 80+ dry-run compiles tractable.
Remat (``jax.checkpoint``) wraps the scan body; the policy is configurable
for the perf loop.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from .blocks import (apply_layer, apply_layer_decode, init_layer,
                     init_layer_cache)
from .common import dense_init, rms_norm
from .config import ModelConfig

PyTree = Any


def init_params(cfg: ModelConfig, key) -> PyTree:
    prefix, periods, pattern = cfg.layer_pattern()
    dt = jnp.dtype(cfg.param_dtype)
    keys = jax.random.split(key, 4)
    params: Dict[str, PyTree] = {
        "embed": dense_init(keys[0], (cfg.vocab, cfg.d_model), dt, scale=1.0),
        "final_norm": jnp.ones((cfg.d_model,), dt),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(keys[1], (cfg.d_model, cfg.vocab), dt)
    params["prefix"] = [
        init_layer(cfg, spec, k)
        for spec, k in zip(prefix, jax.random.split(keys[2], max(len(prefix), 1)))
    ] if prefix else []

    def init_period(k):
        sub = jax.random.split(k, len(pattern))
        return {f"sub{i}": init_layer(cfg, spec, sub[i])
                for i, spec in enumerate(pattern)}

    params["stack"] = jax.vmap(init_period)(jax.random.split(keys[3], periods))
    return params


def _lm_head(cfg: ModelConfig, params, x: jax.Array) -> jax.Array:
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    w = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    return x @ w


def forward(cfg: ModelConfig, params, batch: Dict[str, jax.Array],
            use_pallas: bool = False,
            remat_policy: str = "nothing",
            constrain=None) -> jax.Array:
    """Returns logits (B, S, V).  ``constrain`` is an optional callable
    applied to the residual stream at layer-group boundaries (the sharding
    layer injects `with_sharding_constraint` here)."""
    prefix, periods, pattern = cfg.layer_pattern()
    if "embeds" in batch:
        x = batch["embeds"]
        B, S = x.shape[:2]
    else:
        tokens = batch["tokens"]
        B, S = tokens.shape
        x = params["embed"][tokens]
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    cons = constrain or (lambda t, kind=None: t)
    x = cons(x)
    for spec, lp in zip(prefix, params.get("prefix", [])):
        x = cons(apply_layer(cfg, spec, lp, x, positions, use_pallas,
                             cons))

    def body(carry, period_params):
        h = carry
        for i, spec in enumerate(pattern):
            h = apply_layer(cfg, spec, period_params[f"sub{i}"], h,
                            positions, use_pallas, cons)
        return cons(h), None

    if remat_policy == "nothing":
        body = jax.checkpoint(body)
    elif remat_policy == "dots":
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    x, _ = jax.lax.scan(body, x, params["stack"])
    return _lm_head(cfg, params, x)


def loss_fn(cfg: ModelConfig, params, batch, use_pallas: bool = False,
            remat_policy: str = "nothing", constrain=None) -> jax.Array:
    logits = forward(cfg, params, batch, use_pallas, remat_policy, constrain)
    labels = batch["labels"]
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)


# ------------------------------------------------------------------ decode --
def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> PyTree:
    prefix, periods, pattern = cfg.layer_pattern()
    cache: Dict[str, PyTree] = {
        "prefix": [init_layer_cache(cfg, spec, batch, max_len)
                   for spec in prefix],
    }

    def one_period(_):
        return {f"sub{i}": init_layer_cache(cfg, spec, batch, max_len)
                for i, spec in enumerate(pattern)}

    # stack per-period caches along a leading axis for the scan
    per = one_period(None)
    cache["stack"] = jax.tree.map(
        lambda leaf: jnp.broadcast_to(leaf, (periods,) + leaf.shape).copy()
        if periods else leaf, per)
    return cache


def serve_step(cfg: ModelConfig, params, cache: PyTree,
               batch: Dict[str, jax.Array], position: jax.Array,
               use_pallas: bool = False,
               constrain=None) -> Tuple[jax.Array, PyTree]:
    """One decode step: batch has "tokens" (B,1) (or "embeds" (B,1,d));
    position (B,) is the write index.  Returns (logits (B,V), new cache)."""
    prefix, periods, pattern = cfg.layer_pattern()
    if "embeds" in batch:
        x = batch["embeds"]
    else:
        x = params["embed"][batch["tokens"]]
    cons = constrain or (lambda t, kind=None: t)
    x = cons(x)
    new_prefix = []
    for spec, lp, lc in zip(prefix, params.get("prefix", []),
                            cache.get("prefix", [])):
        x, c = apply_layer_decode(cfg, spec, lp, x, lc, position, use_pallas,
                                  cons)
        new_prefix.append(c)

    def body(carry, xs):
        h = carry
        period_params, period_cache = xs
        new_cache = {}
        for i, spec in enumerate(pattern):
            h, c = apply_layer_decode(cfg, spec, period_params[f"sub{i}"], h,
                                      period_cache[f"sub{i}"], position,
                                      use_pallas, cons)
            new_cache[f"sub{i}"] = c
        return cons(h), new_cache

    x, new_stack = jax.lax.scan(body, x, (params["stack"], cache["stack"]))
    logits = _lm_head(cfg, params, x)[:, 0]
    return logits, {"prefix": new_prefix, "stack": new_stack}
