"""Mamba-1 block (selective state-space model), pure JAX.

Faithful to Gu & Dao (arXiv:2312.00752): in_proj -> (x, z); causal depthwise
conv (k=4) + SiLU on x; data-dependent (Δ, B, C); selective scan
h_t = exp(Δ_t A) h_{t-1} + Δ_t B_t x_t ; y_t = C_t h_t + D x_t; out = y·SiLU(z).

Two scan paths:
* ``chunked`` -- parallel within chunks via associative scan over the
  (decay, increment) monoid, sequential lax.scan across chunks.  This is the
  pure-JAX oracle of the ``repro.kernels.ssm_scan`` Pallas kernel and the
  dry-run path (memory O(B·chunk·d_inner·d_state));
* ``recurrent`` -- one-step state update used by decode (O(1) per token).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from .common import dense_init
from .config import ModelConfig


def init_mamba(cfg: ModelConfig, key) -> dict:
    d, din, ds, dtr = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.dt_rank_
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 6)
    return {
        "in_proj": dense_init(ks[0], (d, 2 * din), dt),
        "conv_w": dense_init(ks[1], (cfg.ssm_conv, din), dt, scale=1.0),
        "x_proj": dense_init(ks[2], (din, dtr + 2 * ds), dt),
        "dt_proj": dense_init(ks[3], (dtr, din), dt),
        "dt_bias": jnp.zeros((din,), dt),
        # A initialized to -[1..ds] (S4D-real); stored as log
        "A_log": jnp.broadcast_to(
            jnp.log(jnp.arange(1, ds + 1, dtype=jnp.float32)), (din, ds)
        ).astype(jnp.float32),
        "D": jnp.ones((din,), jnp.float32),
        "out_proj": dense_init(ks[4], (din, d), dt),
    }


def _ssm_inputs(cfg: ModelConfig, params, xc: jax.Array):
    """xc: (B, S, din) post-conv activations -> (dt, B_t, C_t)."""
    ds, dtr = cfg.ssm_state, cfg.dt_rank_
    proj = xc @ params["x_proj"]                   # (B,S,dtr+2ds)
    dt_in, Bt, Ct = jnp.split(proj, [dtr, dtr + ds], axis=-1)
    dt = jax.nn.softplus(dt_in @ params["dt_proj"]
                         + params["dt_bias"]).astype(jnp.float32)  # (B,S,din)
    return dt, Bt.astype(jnp.float32), Ct.astype(jnp.float32)


def _causal_conv(cfg: ModelConfig, params, x: jax.Array) -> jax.Array:
    """Depthwise causal conv, kernel (k, din); x: (B, S, din)."""
    k = cfg.ssm_conv
    pad = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    w = params["conv_w"]                            # (k, din)
    out = sum(pad[:, i:i + x.shape[1], :] * w[i] for i in range(k))
    return out


def selective_scan_chunked(dt, Bt, Ct, x, A, chunk: int = 128,
                           h0=None) -> Tuple[jax.Array, jax.Array]:
    """dt, x: (B,S,din); Bt,Ct: (B,S,ds); A: (din,ds).
    Returns (y (B,S,din), h_final (B,din,ds))."""
    Bsz, S, din = x.shape
    ds = Bt.shape[-1]
    if S % chunk != 0:
        padlen = chunk - S % chunk
        dt = jnp.pad(dt, ((0, 0), (0, padlen), (0, 0)))
        x = jnp.pad(x, ((0, 0), (0, padlen), (0, 0)))
        Bt = jnp.pad(Bt, ((0, 0), (0, padlen), (0, 0)))
        Ct = jnp.pad(Ct, ((0, 0), (0, padlen), (0, 0)))
    Sp = dt.shape[1]
    nc = Sp // chunk
    # per-step decay a_t = exp(dt*A): (B,S,din,ds); increment b_t = dt*B*x
    dtc = dt.reshape(Bsz, nc, chunk, din)
    xc = x.reshape(Bsz, nc, chunk, din)
    Btc = Bt.reshape(Bsz, nc, chunk, ds)
    Ctc = Ct.reshape(Bsz, nc, chunk, ds)
    if h0 is None:
        h0 = jnp.zeros((Bsz, din, ds), jnp.float32)

    def chunk_step(h, args):
        dti, xi, Bi, Ci = args     # (B,chunk,din) / (B,chunk,ds)
        a = jnp.exp(dti[..., None] * A)                        # (B,c,din,ds)
        b = (dti * xi)[..., None] * Bi[:, :, None, :]          # (B,c,din,ds)

        def combine(u, v):
            (a1, b1), (a2, b2) = u, v
            return a1 * a2, b1 * a2 + b2

        a_cum, b_cum = jax.lax.associative_scan(combine, (a, b), axis=1)
        hs = a_cum * h[:, None] + b_cum                        # (B,c,din,ds)
        y = jnp.einsum("bcds,bcs->bcd", hs, Ci)
        return hs[:, -1], y

    # checkpoint each chunk: backward recomputes the intra-chunk associative
    # scan from the carried boundary state instead of saving (B,c,din,ds)
    # intermediates for every chunk -- O(S/chunk) memory, not O(S).
    h_fin, ys = jax.lax.scan(
        jax.checkpoint(chunk_step), h0,
        (jnp.moveaxis(dtc, 1, 0), jnp.moveaxis(xc, 1, 0),
         jnp.moveaxis(Btc, 1, 0), jnp.moveaxis(Ctc, 1, 0)))
    y = jnp.moveaxis(ys, 0, 1).reshape(Bsz, Sp, din)[:, :S]
    return y, h_fin


def mamba_block(cfg: ModelConfig, params, x: jax.Array,
                use_pallas: bool = False) -> jax.Array:
    """Full-sequence (train/prefill) mamba sub-layer. x: (B,S,d)."""
    B, S, _ = x.shape
    din = cfg.d_inner
    xz = x @ params["in_proj"]
    xi, z = jnp.split(xz, 2, axis=-1)
    xi = jax.nn.silu(_causal_conv(cfg, params, xi))
    dt, Bt, Ct = _ssm_inputs(cfg, params, xi)
    A = -jnp.exp(params["A_log"])
    if use_pallas:
        from repro.kernels.ssm_scan.ops import ssm_scan
        y, _ = ssm_scan(dt, Bt, Ct, xi.astype(jnp.float32), A)
    else:
        y, _ = selective_scan_chunked(dt, Bt, Ct, xi.astype(jnp.float32), A)
    y = y + params["D"] * xi.astype(jnp.float32)
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    return y @ params["out_proj"]


# ------------------------------------------------------------------ decode --
def init_mamba_cache(cfg: ModelConfig, batch: int) -> dict:
    return {
        "h": jnp.zeros((batch, cfg.d_inner, cfg.ssm_state), jnp.float32),
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, cfg.d_inner),
                          jnp.dtype(cfg.compute_dtype)),
    }


def mamba_decode_step(cfg: ModelConfig, params, x: jax.Array,
                      cache: dict) -> Tuple[jax.Array, dict]:
    """x: (B, 1, d) -> (B, 1, d); O(1) state update."""
    B = x.shape[0]
    xz = x[:, 0] @ params["in_proj"]
    xi, z = jnp.split(xz, 2, axis=-1)                 # (B, din)
    # conv over [cache window, new token]
    win = jnp.concatenate([cache["conv"], xi[:, None].astype(cache["conv"].dtype)],
                          axis=1)                     # (B, k, din)
    w = params["conv_w"]                              # (k, din)
    xc = jax.nn.silu(jnp.einsum("bkd,kd->bd", win, w))
    dt, Bt, Ct = _ssm_inputs(cfg, params, xc[:, None])
    dt, Bt, Ct = dt[:, 0], Bt[:, 0], Ct[:, 0]         # (B,din),(B,ds),(B,ds)
    A = -jnp.exp(params["A_log"])
    a = jnp.exp(dt[..., None] * A)                    # (B,din,ds)
    h = a * cache["h"] + (dt * xc.astype(jnp.float32))[..., None] * Bt[:, None, :]
    y = jnp.einsum("bds,bs->bd", h, Ct) + params["D"] * xc.astype(jnp.float32)
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    out = (y @ params["out_proj"])[:, None]
    return out, {"h": h, "conv": win[:, 1:]}
