"""GQA attention: training/prefill (chunked flash-style) and decode paths.

The chunked path is the pure-JAX oracle of the Pallas flash kernel in
``repro.kernels.flash_attention`` (online softmax over KV blocks; memory
O(S·block) instead of O(S²)), and is what the dry-run lowers for long
sequences.  ``use_pallas`` switches the hot spot to the TPU kernel.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from .common import apply_mrope, apply_rope, dense_init, rms_norm
from .config import ModelConfig

NEG_INF = -1e30


def init_attention(cfg: ModelConfig, key) -> dict:
    d, hd, H, KV = cfg.d_model, cfg.head_dim, cfg.n_heads, cfg.n_kv_heads
    dt = jnp.dtype(cfg.param_dtype)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p = {
        "wq": dense_init(k1, (d, H * hd), dt),
        "wk": dense_init(k2, (d, KV * hd), dt),
        "wv": dense_init(k3, (d, KV * hd), dt),
        "wo": dense_init(k4, (H * hd, d), dt),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), dt)
        p["k_norm"] = jnp.ones((hd,), dt)
    return p


def _rope(cfg: ModelConfig, x, positions):
    if cfg.rope == "none":
        return x
    if cfg.rope == "mrope":
        # text-only stub: all three section position ids coincide
        pos3 = jnp.broadcast_to(positions[..., None, :],
                                positions.shape[:-1] + (3, positions.shape[-1]))
        return apply_mrope(x, pos3, cfg.rope_theta)
    return apply_rope(x, positions, cfg.rope_theta)


def _qkv(cfg: ModelConfig, params, x, positions):
    B, S, _ = x.shape
    hd, H, KV = cfg.head_dim, cfg.n_heads, cfg.n_kv_heads
    q = (x @ params["wq"]).reshape(B, S, H, hd)
    k = (x @ params["wk"]).reshape(B, S, KV, hd)
    v = (x @ params["wv"]).reshape(B, S, KV, hd)
    if cfg.qk_norm:
        q = rms_norm(q, params["q_norm"], cfg.norm_eps)
        k = rms_norm(k, params["k_norm"], cfg.norm_eps)
    q = _rope(cfg, q, positions)
    k = _rope(cfg, k, positions)
    return q, k, v


def causal_attention_reference(q, k, v, n_kv_groups: int) -> jax.Array:
    """O(S²) einsum attention -- oracle + short-sequence path.
    q: (B,S,H,hd); k,v: (B,S,KV,hd); H = KV * n_kv_groups."""
    B, S, H, hd = q.shape
    KV = k.shape[2]
    qg = q.reshape(B, S, KV, n_kv_groups, hd)
    scores = jnp.einsum("bskgh,btkh->bkgst", qg, k) / jnp.sqrt(hd)
    mask = jnp.tril(jnp.ones((S, S), bool))
    scores = jnp.where(mask, scores.astype(jnp.float32), NEG_INF)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgst,btkh->bskgh", w.astype(v.dtype), v)
    return out.reshape(B, S, H, hd)


def causal_attention_chunked(q, k, v, n_kv_groups: int,
                             block: int = 1024,
                             unroll_q: bool = False) -> jax.Array:
    """Flash-style chunked causal attention (online softmax over KV blocks).

    Memory O(B·S·block) -- this is what makes 32k prefill fit.  Processes Q
    in blocks via scan; for each Q block, scans KV blocks up to the diagonal
    using a lax.scan with running (max, sum, acc)."""
    B, S, H, hd = q.shape
    KV = k.shape[2]
    if S <= 2 * block:
        return causal_attention_reference(q, k, v, n_kv_groups)
    assert S % block == 0
    nb = S // block
    qg = q.reshape(B, nb, block, KV, n_kv_groups, hd)
    kb = k.reshape(B, nb, block, KV, hd)
    vb = v.reshape(B, nb, block, KV, hd)
    scale = 1.0 / jnp.sqrt(hd)

    def q_block_impl(qi, q_i, n_kv_blocks):
        # q_i: (B, block, KV, G, hd); attend to kv blocks 0..qi
        def kv_step(carry, j):
            m, l, acc = carry
            k_j = jax.lax.dynamic_index_in_dim(kb, j, axis=1, keepdims=False)
            v_j = jax.lax.dynamic_index_in_dim(vb, j, axis=1, keepdims=False)
            s = jnp.einsum("bqkgh,btkh->bkgqt", q_i, k_j) * scale
            s = s.astype(jnp.float32)
            # masking: full blocks below diagonal; triangular on diagonal
            q_pos = qi * block + jnp.arange(block)
            t_pos = j * block + jnp.arange(block)
            mask = q_pos[:, None] >= t_pos[None, :]
            s = jnp.where(mask, s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            alpha = jnp.exp(m - m_new)
            p = jnp.exp(s - m_new[..., None])
            l_new = l * alpha + p.sum(axis=-1)
            acc_new = acc * alpha[..., None] + jnp.einsum(
                "bkgqt,btkh->bkgqh", p.astype(v_j.dtype), v_j)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, KV, n_kv_groups, block), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, KV, n_kv_groups, block), jnp.float32)
        a0 = jnp.zeros((B, KV, n_kv_groups, block, hd), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            jax.checkpoint(kv_step), (m0, l0, a0), jnp.arange(n_kv_blocks),
            unroll=False)
        # kv blocks beyond the diagonal contribute nothing (masked to -inf),
        # but scanning them wastes FLOPs; they are masked fully so l is safe.
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return out  # (B, KV, G, block, hd)

    q_block = jax.checkpoint(
        lambda qi, q_i: q_block_impl(qi, q_i, nb))
    q_block_bounded = jax.checkpoint(
        q_block_impl, static_argnums=(2,))

    if unroll_q:
        # python-unrolled q blocks with STATIC per-block KV extents: the
        # scan for q-block qi only covers kv blocks 0..qi -- no masked-block
        # MXU waste, and the HLO keeps known trip counts (honest accounting)
        outs = jnp.stack([q_block_bounded(qi, qg[:, qi], qi + 1)
                          for qi in range(nb)])
    else:
        outs = jax.lax.map(lambda i: q_block(i, qg[:, i]), jnp.arange(nb))
    # (nb, B, KV, G, block, hd) -> (B, S, H, hd)
    out = jnp.moveaxis(outs, 0, 1)                    # (B, nb, KV, G, blk, hd)
    out = jnp.moveaxis(out, -2, 2)                    # (B, nb, blk, KV, G, hd)
    return out.reshape(B, S, H, hd).astype(q.dtype)


def attention_block(cfg: ModelConfig, params, x, positions,
                    use_pallas: bool = False) -> jax.Array:
    """Full training/prefill attention sub-layer (no cache)."""
    B, S, _ = x.shape
    G = cfg.n_heads // cfg.n_kv_heads
    q, k, v = _qkv(cfg, params, x, positions)
    if use_pallas:
        from repro.kernels.flash_attention.ops import flash_attention
        out = flash_attention(q, k, v, causal=True)
    else:
        out = causal_attention_chunked(q, k, v, G,
                                       unroll_q=cfg.attn_unroll_q)
    out = out.reshape(B, S, cfg.n_heads * cfg.head_dim)
    return out @ params["wo"]


# ------------------------------------------------------------------ decode --
def init_attn_cache(cfg: ModelConfig, batch: int, max_len: int) -> dict:
    dt = jnp.dtype(cfg.compute_dtype)
    kv, hd = cfg.n_kv_heads, cfg.head_dim
    return {
        "k": jnp.zeros((batch, max_len, kv, hd), dt),
        "v": jnp.zeros((batch, max_len, kv, hd), dt),
    }


def decode_attention_block(cfg: ModelConfig, params, x, cache: dict,
                           position: jax.Array,
                           use_pallas: bool = False) -> Tuple[jax.Array, dict]:
    """One-token decode: x (B, 1, d); cache holds max_len KV; position (B,)
    is the index of the new token.  Returns (out (B,1,d), new cache)."""
    B = x.shape[0]
    hd, H, KV = cfg.head_dim, cfg.n_heads, cfg.n_kv_heads
    G = H // KV
    q, k, v = _qkv(cfg, params, x, position[:, None])
    # write the new kv at `position`
    upd = jax.vmap(lambda c, u, p: jax.lax.dynamic_update_slice_in_dim(
        c, u, p, axis=0))
    ck = upd(cache["k"], k[:, 0:1].astype(cache["k"].dtype), position)
    cv = upd(cache["v"], v[:, 0:1].astype(cache["v"].dtype), position)
    if use_pallas:
        from repro.kernels.decode_attention.ops import decode_attention
        out = decode_attention(q[:, 0], ck, cv, position + 1)
    else:
        S = ck.shape[1]
        qg = q.reshape(B, 1, KV, G, hd)
        s = jnp.einsum("bqkgh,btkh->bkgqt", qg, ck) / jnp.sqrt(hd)
        valid = jnp.arange(S)[None, :] <= position[:, None]      # (B, S)
        s = jnp.where(valid[:, None, None, None, :],
                      s.astype(jnp.float32), NEG_INF)
        w = jax.nn.softmax(s, axis=-1).astype(cv.dtype)
        out = jnp.einsum("bkgqt,btkh->bqkgh", w, cv)[:, 0]   # (B, KV, G, hd)
    out = out.reshape(B, 1, H * hd)
    return out @ params["wo"], {"k": ck, "v": cv}
