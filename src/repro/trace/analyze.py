"""Trace analysis: per-op cost decomposition, CAS contention windows, and
post-flush access attribution.

Everything here consumes the columnar :class:`repro.trace.recorder.Trace`
stream and produces the quantities the paper's arguments (and our fitted
contention profiles) are built from:

* :func:`op_table` -- one row per recorded operation with its step
  interval and per-class primitive counts (cached re-reads vs accesses to
  flushed content vs CAS attempts/failures vs persist work);
* :func:`modal_cas_roots` -- which fixed word each op kind's CAS loop
  hammers (the queue's HEAD/TAIL roots, recovered from the trace itself);
* :func:`conflict_windows` -- for each op, how many earlier-started,
  still-open ops of other threads CASed the same root: the ``k`` the
  batched contention model derives its failure probability from;
* :func:`cas_failure_stats` -- per-target-word attempt/failure counts;
* :func:`post_flush_sites` / :func:`post_flush_per_op` -- the paper-§8
  attribution: *which program sites re-read flushed content*, keyed by
  (op kind, engine region, primitive).  The second-amendment queues show
  zero rows here; their baselines do not -- that ordering is asserted in
  ``tests/test_trace_fit.py``.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.nvram import (TR_CAS, TR_FENCE, TR_FLUSH, TR_MOVNTI, TR_READ,
                              TR_WRITE, TR_WRITE_LINE, TS_CACHED,
                              TS_INVALIDATED)
from .recorder import FETCHING_PRIMS, Trace

PRIM_NAMES = {TR_READ: "read", TR_WRITE: "write",
              TR_WRITE_LINE: "write_line", TR_CAS: "cas", TR_FLUSH: "flush",
              TR_FENCE: "fence", TR_MOVNTI: "movnti"}


@dataclass
class OpTable:
    """Per-operation aggregation of a trace (parallel arrays, one row/op)."""

    kinds: List[str]               # op-kind code table (meta['kinds'])
    tid: np.ndarray
    seq: np.ndarray                # per-thread op sequence number
    kind: np.ndarray               # code into `kinds`
    start: np.ndarray              # first primitive's step
    end: np.ndarray                # last primitive's step
    reads_hit: np.ndarray          # fetches of still-cached lines
    reads_flushed: np.ndarray      # fetches of flush-invalidated lines
    cas: np.ndarray                # CAS attempts
    cas_failed: np.ndarray         # CAS attempts that failed
    flushes: np.ndarray
    fences: np.ndarray
    movntis: np.ndarray

    def __len__(self) -> int:
        return len(self.tid)

    def of_kind(self, kind: str) -> np.ndarray:
        """Boolean row mask selecting ops of `kind`."""
        code = self.kinds.index(kind) if kind in self.kinds else -1
        return self.kind == code


def op_table(trace: Trace) -> OpTable:
    """Aggregate the primitive stream into one row per operation."""
    c = trace.columns
    in_op = c["op_seq"] >= 0
    tid, seq = c["tid"][in_op], c["op_seq"][in_op]
    nthreads = int(trace.meta.get("nthreads", int(tid.max()) + 1 if
                                  len(tid) else 1))
    max_seq = int(seq.max()) + 1 if len(seq) else 0
    key = tid * max(max_seq, 1) + seq
    uniq, inverse = np.unique(key, return_inverse=True)
    n = len(uniq)

    def _count(mask: np.ndarray) -> np.ndarray:
        return np.bincount(inverse, weights=mask[in_op].astype(np.float64),
                           minlength=n).astype(np.int64)

    prim, state, aux = c["prim"], c["state"], c["aux"]
    fetch = np.isin(prim, FETCHING_PRIMS)
    start = np.full(n, np.iinfo(np.int64).max, dtype=np.int64)
    end = np.zeros(n, dtype=np.int64)
    np.minimum.at(start, inverse, c["step"][in_op])
    np.maximum.at(end, inverse, c["step"][in_op])
    kind = np.zeros(n, dtype=np.int64)
    kind[inverse] = c["op_kind"][in_op]     # constant within an op
    assert nthreads > 0
    return OpTable(
        kinds=list(trace.meta.get("kinds", [])),
        tid=(uniq // max(max_seq, 1)), seq=(uniq % max(max_seq, 1)),
        kind=kind, start=start, end=end,
        reads_hit=_count(fetch & (state == TS_CACHED)),
        reads_flushed=_count(fetch & (state == TS_INVALIDATED)),
        cas=_count(prim == TR_CAS),
        cas_failed=_count((prim == TR_CAS) & (aux == 0)),
        flushes=_count(prim == TR_FLUSH),
        fences=_count(prim == TR_FENCE),
        movntis=_count(prim == TR_MOVNTI),
    )


def modal_cas_roots(trace: Trace,
                    table: Optional[OpTable] = None) -> Dict[str, int]:
    """Per op kind, the CAS target word hit most often: the queue's root.

    A CAS loop retries against one fixed word (TAIL for enqueues, HEAD for
    dequeues) while its other CAS targets (node link words) vary per op, so
    the modal target identifies the contended root without needing the
    queue instance's addresses.
    """
    c = trace.columns
    out: Dict[str, int] = {}
    kinds = trace.meta.get("kinds", [])
    for code, kind in enumerate(kinds):
        mask = (c["prim"] == TR_CAS) & (c["op_kind"] == code)
        if not mask.any():
            continue
        addrs, counts = np.unique(c["addr"][mask], return_counts=True)
        out[kind] = int(addrs[np.argmax(counts)])
    return out


def conflict_windows(trace: Trace, table: Optional[OpTable] = None,
                     roots: Optional[Dict[str, int]] = None) -> np.ndarray:
    """Per op: the number of co-scheduled conflicting ops, ``k``.

    Mirrors the batched model's window rule
    (:class:`repro.core.contention.ContentionModel`): op *i* conflicts with
    every op *j* of another thread that CASed the same root, started no
    later than *i*, and whose interval was still open at *i*'s start
    (``end_j > start_i``).  Ops that never CASed their kind's root get 0.
    """
    t = table if table is not None else op_table(trace)
    roots = roots if roots is not None else modal_cas_roots(trace, t)
    c = trace.columns
    n = len(t)
    k = np.zeros(n, dtype=np.int64)
    # per-op set of CASed roots, as a boolean per (op, root)
    root_addrs = sorted(set(roots.values()))
    hit = {w: np.zeros(n, dtype=bool) for w in root_addrs}
    in_op = c["op_seq"] >= 0
    max_seq = int(t.seq.max()) + 1 if n else 1
    key_of_row = c["tid"][in_op] * max(max_seq, 1) + c["op_seq"][in_op]
    uniq = t.tid * max(max_seq, 1) + t.seq
    order = np.argsort(uniq)
    for w in root_addrs:
        m = (c["prim"][in_op] == TR_CAS) & (c["addr"][in_op] == w)
        rows = np.searchsorted(uniq[order], key_of_row[m])
        hit[w][order[rows]] = True
    for i in range(n):
        kind = t.kinds[t.kind[i]] if 0 <= t.kind[i] < len(t.kinds) else None
        w = roots.get(kind)
        if w is None or not hit[w][i]:
            continue
        overlap = (hit[w] & (t.tid != t.tid[i])
                   & (t.start <= t.start[i]) & (t.end > t.start[i]))
        k[i] = int(overlap.sum())
    return k


@dataclass(frozen=True)
class CasSiteStat:
    """CAS attempt/failure totals for one target word."""
    addr: int
    region: str
    attempts: int
    failures: int

    @property
    def failure_rate(self) -> float:
        return self.failures / self.attempts if self.attempts else 0.0


def cas_failure_stats(trace: Trace) -> List[CasSiteStat]:
    """Per-target-word CAS statistics, most-contended first."""
    c = trace.columns
    mask = c["prim"] == TR_CAS
    addrs = c["addr"][mask]
    fails = (c["aux"][mask] == 0)
    out = []
    for w in np.unique(addrs):
        m = addrs == w
        out.append(CasSiteStat(addr=int(w), region=trace.region_of(int(w)),
                               attempts=int(m.sum()),
                               failures=int(fails[m].sum())))
    out.sort(key=lambda s: (-s.failures, -s.attempts, s.addr))
    return out


@dataclass(frozen=True)
class SiteStat:
    """Post-flush accesses attributed to one program site."""
    op_kind: str       # 'enq' / 'deq' / '(outside-op)'
    region: str        # engine region name (queue roots, ssmem area, ...)
    prim: str          # read / write / cas
    count: int
    per_op: float      # count / ops recorded for that kind


def post_flush_sites(trace: Trace) -> List[SiteStat]:
    """The §8 attribution: which sites re-read flushed content, how often.

    A site is (op kind, engine region, primitive): e.g. DurableMSQ
    dequeues re-fetching the flushed HEAD root line show up as
    ``('deq', 'durablemsq:roots', 'read')``.  Sorted by count descending;
    an empty list is the second-amendment signature.
    """
    c = trace.columns
    mask = trace.post_flush_mask()
    kinds = trace.meta.get("kinds", [])
    ops_by_code: Dict[int, int] = {}
    in_op = c["op_seq"] >= 0
    if in_op.any():
        max_seq = int(c["op_seq"][in_op].max()) + 1
        key = c["tid"][in_op] * max_seq + c["op_seq"][in_op]
        uniq_key, first = np.unique(key, return_index=True)
        op_kind_per_op = c["op_kind"][in_op][first]
        for code in np.unique(op_kind_per_op):
            ops_by_code[int(code)] = int((op_kind_per_op == code).sum())
    counts: Dict[Tuple[str, str, str], int] = {}
    for idx in np.flatnonzero(mask):
        code = int(c["op_kind"][idx])
        kind = kinds[code] if 0 <= code < len(kinds) else "(outside-op)"
        site = (kind, trace.region_of(int(c["addr"][idx])),
                PRIM_NAMES.get(int(c["prim"][idx]), "?"))
        counts[site] = counts.get(site, 0) + 1
    out = []
    for (kind, region, prim), cnt in counts.items():
        code = kinds.index(kind) if kind in kinds else -1
        nops = ops_by_code.get(code, 0)
        out.append(SiteStat(op_kind=kind, region=region, prim=prim,
                            count=cnt, per_op=cnt / nops if nops else 0.0))
    out.sort(key=lambda s: (-s.count, s.op_kind, s.region, s.prim))
    return out


def post_flush_per_op(trace: Trace) -> Dict[str, float]:
    """Post-flush accesses per recorded op: one entry per kind + 'all'."""
    t = op_table(trace)
    out: Dict[str, float] = {}
    total_ops = len(t)
    for kind in t.kinds:
        m = t.of_kind(kind)
        nops = int(m.sum())
        out[kind] = float(t.reads_flushed[m].sum()) / nops if nops else 0.0
    out["all"] = (float(t.reads_flushed.sum()) / total_ops
                  if total_ops else 0.0)
    return out
