"""Exact-scheduler trace capture: a low-overhead tap on the NVRAM primitives.

:class:`TraceRecorder` attaches to the batched engine's opt-in tap seam
(:meth:`repro.core.nvram.NVRAM.set_trace_tap`) and records one row per
memory primitive into growable columnar numpy arrays -- the stream the
paper's cost arguments are *about*: which thread touched which cache line,
in which flush state, under which operation, and how its CASes fared.

The tap sits beside the engine's cost accumulator: it only observes, so a
recorded run's :class:`repro.core.nvram.Stats` are bit-identical to an
unrecorded one (property-tested), and the differential oracle
(``repro.core.nvram_ref``) is untouched.  Under the exact
:class:`repro.core.scheduler.Scheduler` each row additionally carries the
scheduler's global step index (grants are serialized, so step order ==
primitive order); under ``run_single`` the recorder numbers primitives
itself.

Columns (all ``int64``, one row per primitive):

=========  =============================================================
``step``   global order: exact-scheduler step index, else a running count
``tid``    executing simulated thread
``op_seq`` per-thread operation sequence number (-1 outside any op,
           e.g. queue construction or prefill)
``op_kind`` index into ``meta['kinds']`` ('enq'/'deq'; -1 outside ops)
``prim``   primitive kind: TR_READ/TR_WRITE/TR_WRITE_LINE/TR_CAS/
           TR_FLUSH/TR_FENCE/TR_MOVNTI (repro.core.nvram)
``addr``   word address (-1 for fences)
``line``   cache line number (addr // LINE_WORDS; -1 for fences)
``state``  TS_* pre-access flush state of the line; TS_INVALIDATED on a
           fetching primitive (read/write/CAS) is a post-flush access
``aux``    CAS outcome (1 success / 0 failure), fence pending-entry
           count; -1 otherwise
=========  =============================================================
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

import numpy as np

from repro.core.nvram import LINE_WORDS, TR_CAS, TR_READ, TR_WRITE

# fetching primitives: these bring the line into cache, so TS_INVALIDATED
# pre-state means the access pays the paper's post-flush penalty
FETCHING_PRIMS = (TR_READ, TR_WRITE, TR_CAS)

COLUMNS = ("step", "tid", "op_seq", "op_kind", "prim", "addr", "line",
           "state", "aux")


@dataclass
class Trace:
    """One captured run: columnar event stream + provenance metadata.

    ``meta`` carries ``schema`` (version), ``queue``, ``model``,
    ``nthreads``, ``seed``, ``scheduler``, ``kinds`` (op-kind code table)
    and ``regions`` (the engine's named address regions, for mapping
    addresses back to program sites).  No wall-clock or host state is ever
    recorded: the same seed produces a byte-identical trace.
    """

    meta: Dict[str, Any]
    columns: Dict[str, np.ndarray] = field(default_factory=dict)

    def __len__(self) -> int:
        return len(self.columns["step"]) if self.columns else 0

    def __getattr__(self, name: str) -> np.ndarray:
        cols = self.__dict__.get("columns") or {}
        if name in cols:
            return cols[name]
        raise AttributeError(name)

    # ----------------------------------------------------------- utilities
    def kind_code(self, kind: str) -> int:
        """Code of op kind `kind` in this trace (-1 if never recorded)."""
        kinds = self.meta.get("kinds", [])
        return kinds.index(kind) if kind in kinds else -1

    def region_of(self, addr: int) -> str:
        """Name of the engine region containing `addr` ('?' if unmapped)."""
        for name, base, nwords, _persistent in self.meta.get("regions", []):
            if base <= addr < base + nwords:
                return name
        return "?"

    def post_flush_mask(self) -> np.ndarray:
        """Rows that are post-flush accesses (fetch of an invalidated line).

        Sums to the engine's ``Stats.post_flush_accesses`` for the recorded
        window -- the trace and the cost accumulator classify identically.
        """
        from repro.core.nvram import TS_INVALIDATED
        return (np.isin(self.columns["prim"], FETCHING_PRIMS)
                & (self.columns["state"] == TS_INVALIDATED))


class TraceRecorder:
    """Columnar recorder implementing the engine tap protocol.

    Use via the harness::

        rec = TraceRecorder()
        h.run_scheduled(plans, seed=1, trace=rec)
        trace = rec.trace          # repro.trace.Trace

    or attach/detach manually with :meth:`attach` / :meth:`finish`.
    One recorder captures one run.
    """

    def __init__(self, capacity: int = 4096):
        self._cap = max(int(capacity), 16)
        self._n = 0
        self._cols = {c: np.empty(self._cap, dtype=np.int64)
                      for c in COLUMNS}
        self._nv = None
        self._meta: Dict[str, Any] = {}
        self._kinds: List[str] = []
        self._kind_code: Dict[str, int] = {}
        # per-thread current (op_seq, op_kind); -1 outside any op
        self._cur_seq: Dict[int, int] = {}
        self._cur_kind: Dict[int, int] = {}
        self._op_count: Dict[int, int] = {}
        self._count = 0          # fallback primitive numbering
        self._sched_step = -1    # pending exact-scheduler step index
        self.trace: Optional[Trace] = None

    # ------------------------------------------------------------ lifecycle
    def attach(self, nvram, meta: Optional[Dict[str, Any]] = None) -> None:
        if self._nv is not None:
            raise RuntimeError("recorder already attached")
        if self.trace is not None:
            raise RuntimeError(
                "recorder already used: one recorder captures one run "
                "(a second attach would concatenate streams); create a "
                "fresh TraceRecorder")
        if not hasattr(nvram, "set_trace_tap"):
            raise TypeError(
                "trace capture needs the batched engine "
                "(repro.core.nvram.NVRAM); the reference oracle has no tap "
                "seam by design")
        self._nv = nvram
        self._meta = dict(meta or {})
        nvram.set_trace_tap(self)

    def finish(self, regions=None) -> Trace:
        """Detach from the engine and freeze the recorded stream."""
        if self._nv is not None:
            self._nv.set_trace_tap(None)
            self._nv = None
        meta = dict(self._meta)
        meta["schema"] = 1
        meta["kinds"] = list(self._kinds)
        meta["regions"] = [list(r) for r in (regions or [])]
        meta["ops_recorded"] = dict(sorted(self._op_count.items()))
        cols = {c: self._cols[c][:self._n].copy() for c in COLUMNS}
        self.trace = Trace(meta=meta, columns=cols)
        return self.trace

    # --------------------------------------------------------- tap protocol
    def on_sched_step(self, step: int) -> None:
        """Exact scheduler: the next primitive carries global index `step`."""
        self._sched_step = step

    def begin_op(self, tid: int, kind: str) -> None:
        """Harness hook: thread `tid` starts its next `kind` operation."""
        code = self._kind_code.get(kind)
        if code is None:
            code = len(self._kinds)
            self._kinds.append(kind)
            self._kind_code[kind] = code
        self._cur_seq[tid] = self._op_count.get(tid, 0)
        self._op_count[tid] = self._cur_seq[tid] + 1
        self._cur_kind[tid] = code

    def on_prim(self, tid: int, prim: int, addr: int, state: int,
                aux: int) -> None:
        n = self._n
        if n == self._cap:
            self._grow()
        self._count += 1
        step = self._sched_step
        if step >= 0:
            self._sched_step = -1
        else:
            step = self._count
        c = self._cols
        c["step"][n] = step
        c["tid"][n] = tid
        c["op_seq"][n] = self._cur_seq.get(tid, -1)
        c["op_kind"][n] = self._cur_kind.get(tid, -1)
        c["prim"][n] = prim
        c["addr"][n] = addr
        c["line"][n] = addr // LINE_WORDS if addr >= 0 else -1
        c["state"][n] = state
        c["aux"][n] = aux
        self._n = n + 1

    # ------------------------------------------------------------ internals
    def _grow(self) -> None:
        self._cap *= 2
        for k, arr in self._cols.items():
            grown = np.empty(self._cap, dtype=np.int64)
            grown[:self._n] = arr
            self._cols[k] = grown
