"""Trace subsystem: exact-scheduler capture, attribution, learned profiles.

Four modules over the :mod:`repro.core` engine's opt-in tap seam:

* :mod:`repro.trace.recorder` -- columnar capture of the primitive stream
  (:class:`TraceRecorder` / :class:`Trace`);
* :mod:`repro.trace.store` -- versioned ``.npz`` persistence
  (:func:`save_trace` / :func:`load_trace`);
* :mod:`repro.trace.analyze` -- per-op decomposition, CAS contention
  windows, and post-flush access attribution (the paper's §8 discussion);
* :mod:`repro.trace.fit` -- least-squares fitting of
  :class:`repro.core.contention.LearnedRetryProfile` from traces, behind
  the ``--contention learned`` benchmark axis.
"""
from .recorder import COLUMNS, FETCHING_PRIMS, Trace, TraceRecorder
from .store import SCHEMA_VERSION, TraceSchemaError, load_trace, save_trace
from .analyze import (CasSiteStat, OpTable, SiteStat, cas_failure_stats,
                      conflict_windows, modal_cas_roots, op_table,
                      post_flush_per_op, post_flush_sites)
from .fit import (PROFILE_SCHEMA, capture_trace, fit_all, fit_profiles,
                  load_profiles, make_pairs_plans, save_profiles)

__all__ = [
    "COLUMNS", "FETCHING_PRIMS", "Trace", "TraceRecorder",
    "SCHEMA_VERSION", "TraceSchemaError", "load_trace", "save_trace",
    "CasSiteStat", "OpTable", "SiteStat", "cas_failure_stats",
    "conflict_windows", "modal_cas_roots", "op_table",
    "post_flush_per_op", "post_flush_sites",
    "PROFILE_SCHEMA", "capture_trace", "fit_all", "fit_profiles",
    "load_profiles", "make_pairs_plans", "save_profiles",
]
