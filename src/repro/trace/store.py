"""Versioned on-disk format for captured traces (`.npz` + JSON metadata).

A trace file is a ``numpy.savez_compressed`` archive holding the nine
int64 columns of :class:`repro.trace.recorder.Trace` plus one ``meta``
entry: the UTF-8 JSON encoding of the trace metadata, which carries the
``schema`` version.  Loading validates the schema version, the column set,
dtypes and equal lengths, and raises :class:`TraceSchemaError` on any
mismatch -- a trace produced by a future incompatible recorder fails
loudly instead of mis-parsing.

Determinism: ``save_trace`` writes only the recorded arrays and metadata
(no timestamps, hostnames or absolute paths), so identical traces produce
byte-identical files -- the property the determinism tests pin.
"""
from __future__ import annotations

import io
import json
import os
from typing import Union

import numpy as np

from .recorder import COLUMNS, Trace

SCHEMA_VERSION = 1


class TraceSchemaError(ValueError):
    """The file is not a trace of a schema version this code understands."""


def save_trace(path: Union[str, "os.PathLike"], trace: Trace) -> None:
    """Write `trace` to `path` (conventionally ``*.trace.npz``)."""
    meta = dict(trace.meta)
    meta["schema"] = meta.get("schema", SCHEMA_VERSION)
    payload = {c: np.ascontiguousarray(trace.columns[c], dtype=np.int64)
               for c in COLUMNS}
    payload["meta"] = np.frombuffer(
        json.dumps(meta, sort_keys=True).encode("utf-8"), dtype=np.uint8)
    # write through a buffer so partial writes never leave a torn file
    buf = io.BytesIO()
    np.savez_compressed(buf, **payload)
    with open(path, "wb") as f:
        f.write(buf.getvalue())


def load_trace(path: Union[str, "os.PathLike"]) -> Trace:
    """Load a trace saved by :func:`save_trace`, validating the schema."""
    try:
        with np.load(path) as npz:
            names = set(npz.files)
            if "meta" not in names:
                raise TraceSchemaError(f"{path}: no trace metadata entry")
            try:
                meta = json.loads(bytes(npz["meta"]).decode("utf-8"))
            except (UnicodeDecodeError, json.JSONDecodeError) as e:
                raise TraceSchemaError(f"{path}: unparseable metadata: {e}")
            version = meta.get("schema")
            if version != SCHEMA_VERSION:
                raise TraceSchemaError(
                    f"{path}: trace schema {version!r}, this reader "
                    f"understands {SCHEMA_VERSION}")
            missing = [c for c in COLUMNS if c not in names]
            if missing:
                raise TraceSchemaError(f"{path}: missing columns {missing}")
            cols = {c: npz[c] for c in COLUMNS}
    except (OSError, ValueError) as e:
        if isinstance(e, TraceSchemaError):
            raise
        raise TraceSchemaError(f"{path}: not a readable trace archive: {e}")
    lengths = {c: len(a) for c, a in cols.items()}
    if len(set(lengths.values())) != 1:
        raise TraceSchemaError(f"{path}: ragged columns {lengths}")
    for c, a in cols.items():
        if a.dtype != np.int64:
            raise TraceSchemaError(
                f"{path}: column {c} has dtype {a.dtype}, expected int64")
        cols[c] = a.copy()   # detach from the npz mmap
    # JSON round-trips region tuples as lists; normalize to tuples
    meta["regions"] = [tuple(r) for r in meta.get("regions", [])]
    meta["ops_recorded"] = {int(k): v for k, v in
                            meta.get("ops_recorded", {}).items()}
    return Trace(meta=meta, columns=cols)
