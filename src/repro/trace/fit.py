"""Learn contention profiles from exact-scheduler traces.

PR 2's :class:`repro.core.contention.ContentionModel` charged CAS retries
from hand-fit :meth:`retry_profile` constants.  This module replaces them
with a measurement pipeline:

1. :func:`capture_trace` runs a queue under the exact per-primitive
   scheduler with a :class:`repro.trace.recorder.TraceRecorder` attached --
   CAS failures, helping paths and post-flush re-reads *actually execute*
   and land in the trace;
2. :func:`fit_profiles` turns traces at several thread counts into a
   :class:`repro.core.contention.LearnedRetryProfile`:

   * **per-round event counts** by least squares *across thread counts*:
     for each op kind and event class (cached re-reads, re-reads of
     flushed content, CAS attempts, helping flushes/fences), regress each
     trace's per-op **excess** over an uncontended batched run of the same
     workload -- the quantity the contention model must supply -- against
     that trace's observed failed-CAS rounds per op, through the origin;
     the slope is the cost of one retry round.  Fitting excesses (rather
     than individual ops or raw totals) matters twice over: per-op
     structural growth with thread count (longer walks, more empty checks)
     cancels out, and a metric that is globally conserved under retries --
     e.g. UnlinkedQ's post-flush count, where a retry that re-fetches an
     invalidated line merely *absorbs* a fetch another op would have paid
     -- shows zero excess, exactly the zero charge it should get;
   * **race-window weight** by matching retries against the batched model
     itself: starting from a grid least squares of ``E = p/(1-p)``,
     ``p = scale*w*k`` (with ``k`` from
     :func:`repro.trace.analyze.conflict_windows`, the trace-side mirror
     of the clock window) against observed failed rounds, the refinement
     replays the same workload through the batched
     :class:`repro.core.contention.ContentionModel` and searches the
     weight that minimizes the squared gap between charged and traced
     retries per op, per thread count -- closing any gap between
     trace-side and clock-side window statistics;

3. :func:`save_profiles` / :func:`load_profiles` round-trip the learned
   profiles as versioned JSON -- ``benchmarks/profiles/learned.json`` is
   the checked-in artifact the ``--contention learned`` benchmark axis
   reads.

Every number the batched model charges under ``--contention learned``
comes from this pipeline; no hand-tuned per-queue constants remain.
"""
from __future__ import annotations

import json
from typing import Any, Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import ALL_QUEUES, ContentionModel, LearnedRetryProfile, \
    QueueHarness
from repro.core.contention import DEFAULT_RETRY_SCALE, P_CAP
from .analyze import conflict_windows, modal_cas_roots, op_table
from .recorder import Trace, TraceRecorder
from .store import save_trace

PROFILE_SCHEMA = 1
# numeric fields of a learned profile, in serialization order
PARAM_FIELDS = ("reads", "flushed_reads", "cas", "flushes", "fences",
                "weight", "flushed_decay", "max_rounds")
# headroom over the largest traced failed-round rate when measuring the
# per-op retry saturation (max_rounds): thread counts past the traced
# range still grow a little before the queue's true ceiling
_MAX_ROUNDS_HEADROOM = 1.25
# contention-decay grid for the flushed-read fit (see RetryProfile
# .flushed_decay): effective per-round count = F / (1 + delta * k)
_DELTA_GRID = np.arange(0.0, 2.001, 0.05)
# minimum mean excess flushed reads per trace before a measured per-k
# decay shape replaces the jointly-fit parametric curve (thin signals
# produce noise-dominated, upward-biased ratio tables)
_SHAPE_MIN_EVENTS = 8.0
# the smallest measured per-round ratio must sit at or below this for a
# shape to count as "measured decay" (an all-flat table is clamp noise)
_SHAPE_MAX_FLAT = 0.8
# weight grid for the least-squares search (step 0.005, deterministic)
_W_GRID = np.linspace(0.0, 4.0, 801)


# --------------------------------------------------------------- workloads
def make_pairs_plans(nthreads: int, ops_per_thread: int
                     ) -> Tuple[List[list], int]:
    """The calibration workload: per-thread enqueue/dequeue pairs over a
    10-item prefill (mirrors ``benchmarks.workloads.make_plans('pairs')``,
    re-stated here so ``repro.trace`` does not depend on ``benchmarks``)."""
    plans = []
    for t in range(nthreads):
        p = []
        for i in range(ops_per_thread // 2):
            p.append(("enq", (t, i)))
            p.append(("deq", None))
        plans.append(p)
    return plans, 10


# ----------------------------------------------------------------- capture
def capture_trace(queue_name: str, nthreads: int, ops_per_thread: int,
                  seed: int = 1, model: str = "optane-clwb",
                  area_nodes: int = 1024) -> Trace:
    """One exact-scheduler run of the pairs workload, traced."""
    h = QueueHarness(ALL_QUEUES[queue_name], nthreads=nthreads,
                     area_nodes=area_nodes, model=model)
    plans, prefill = make_pairs_plans(nthreads, ops_per_thread)
    for i in range(prefill):
        h.queue.enqueue(0, ("pre", i))
    rec = TraceRecorder()
    h.run_scheduled(plans, seed=seed, trace=rec)
    trace = rec.trace
    trace.meta["workload"] = "pairs"
    trace.meta["ops_per_thread"] = ops_per_thread
    return trace


# --------------------------------------------------------------- regression
def _nnls(A: np.ndarray, b: np.ndarray) -> np.ndarray:
    """Tiny non-negative least squares (active-set elimination): solve
    ``min |Ax - b|`` with ``x >= 0`` by dropping the most negative
    coordinate until the unconstrained solution is feasible."""
    n = A.shape[1]
    active = list(range(n))
    x = np.zeros(n)
    while active:
        sol, *_ = np.linalg.lstsq(A[:, active], b, rcond=None)
        if (sol >= -1e-12).all():
            x[active] = np.maximum(sol, 0.0)
            break
        active.pop(int(np.argmin(sol)))
    return x


# weight of the cross-kind conservation equation in the per-class fit: the
# *total* excess must be matched even when retries merely shift events
# between kinds (one kind's excess offsets another's deficit)
_CONSERVATION_WEIGHT = 3.0


def _fit_weight(k: np.ndarray, rounds: np.ndarray,
                retry_scale: float) -> float:
    """Grid least squares of E(k; w) = p/(1-p), p = min(scale*w*k, P_CAP),
    against observed failed rounds."""
    if not len(k) or float(k.max()) <= 0:
        return 1.0
    best_w, best_sse = 1.0, float("inf")
    for w in _W_GRID:
        p = np.minimum(retry_scale * w * k, P_CAP)
        sse = float(np.sum((p / (1.0 - p) - rounds) ** 2))
        if sse < best_sse - 1e-12:
            best_w, best_sse = float(w), sse
    return best_w


# event classes regressed per retry round, keyed by RetryProfile field
_CLASS_COLS = {"reads": "reads_hit", "flushed_reads": "reads_flushed",
               "cas": "cas", "flushes": "flushes", "fences": "fences"}


def _baseline_per_op(queue_name: str, nthreads: int, ops_per_thread: int,
                     model: str) -> Dict[str, Dict[str, float]]:
    """Per-kind per-op class means of an *uncontended batched* run of the
    same workload: the contention-free baseline the excess is taken over
    (the tap works under the clock scheduler too -- the recorder numbers
    primitives itself)."""
    h = QueueHarness(ALL_QUEUES[queue_name], nthreads=nthreads,
                     area_nodes=1024, model=model)
    plans, prefill = make_pairs_plans(nthreads, ops_per_thread)
    for i in range(prefill):
        h.queue.enqueue(0, ("pre", i))
    rec = TraceRecorder()
    h.run_batched(plans, trace=rec)
    table = op_table(rec.trace)
    out: Dict[str, Dict[str, float]] = {}
    for kind in table.kinds:
        m = table.of_kind(kind)
        if m.any():
            out[kind] = {f: float(getattr(table, col)[m].mean())
                         for f, col in _CLASS_COLS.items()}
    return out


def _per_trace_stats(traces: Sequence[Trace]) -> Dict[str, List[dict]]:
    """Per (kind, trace) aggregates: mean failed rounds, per-op class
    excess over the uncontended batched baseline, and pooled per-op
    (k, rounds) samples for the initial weight fit.

    Returns kind -> list of one dict per trace with keys ``nthreads``,
    ``rounds`` (mean failed CAS rounds/op), ``excess`` (class -> mean/op
    above baseline), ``k`` and ``rounds_i`` (per-op arrays).
    """
    out: Dict[str, List[dict]] = {}
    for trace in traces:
        table = op_table(trace)
        roots = modal_cas_roots(trace, table)
        k = conflict_windows(trace, table, roots)
        base = _baseline_per_op(
            trace.meta["queue"], int(trace.meta.get("nthreads", 1)),
            int(trace.meta.get("ops_per_thread") or 0) or
            int(np.ceil(len(table) / max(int(trace.meta.get(
                "nthreads", 1)), 1))),
            trace.meta.get("model", "optane-clwb"))
        for kind in table.kinds:
            m = table.of_kind(kind)
            if not m.any():
                continue
            rounds_i = table.cas_failed[m].astype(np.float64)
            k_i = k[m].astype(np.float64)
            # window size where the failures actually happened (weighted by
            # failed rounds): the k the decay term should see
            k_eff = (float((k_i * rounds_i).sum() / rounds_i.sum())
                     if rounds_i.sum() > 0
                     else (float(k_i.mean()) if len(k_i) else 0.0))
            kbase = base.get(kind, {f: 0.0 for f in _CLASS_COLS})
            out.setdefault(kind, []).append({
                "nthreads": int(trace.meta.get("nthreads", 1)),
                "ops_per_thread": trace.meta.get("ops_per_thread"),
                "nops": int(m.sum()),
                "k_eff": k_eff,
                "rounds": float(rounds_i.mean()),
                "excess": {f: float(getattr(table, col)[m].mean())
                           - kbase.get(f, 0.0)
                           for f, col in _CLASS_COLS.items()},
                "k": k[m].astype(np.float64),
                "rounds_i": rounds_i,
            })
    return out


# ------------------------------------------------------------- refinement
def _charged_per_op(queue_name: str, nthreads: int, ops_per_thread: int,
                    learned: LearnedRetryProfile, model: str,
                    retry_scale: float) -> Dict[str, float]:
    """Replay the pairs workload through the batched contention model and
    report the charged expected retries per op, per kind."""
    h = QueueHarness(ALL_QUEUES[queue_name], nthreads=nthreads,
                     area_nodes=1024, model=model)
    plans, prefill = make_pairs_plans(nthreads, ops_per_thread)
    for i in range(prefill):
        h.queue.enqueue(0, ("pre", i))
    cm = ContentionModel(retry_scale=retry_scale, profiles=learned)
    h.run_batched(plans, contention=cm)
    roots = {kind: prof.root
             for kind, prof in h.queue.retry_profile().items()}
    nops = nthreads * (ops_per_thread // 2)    # pairs: half enq, half deq
    return {kind: cm.retries_by_root.get(root, 0.0) / max(nops, 1)
            for kind, root in roots.items()}


def _search_weight(queue_name: str, kind: str, cells: Sequence[Tuple[int,
                   int]], params: Dict[str, Dict[str, float]],
                   target: Dict[str, Dict[int, float]], mem_model: str,
                   retry_scale: float) -> float:
    """Coarse-then-fine grid search of `kind`'s weight, minimizing the
    squared gap between the batched model's charged retries per op and the
    traced failed rounds per op, across the traced thread counts.

    This is measurement all the way down: each candidate weight is
    *evaluated by running the batched model*, so whatever the clock-window
    statistics do at a given thread count is priced in, not approximated.
    """
    def sse(w: float) -> float:
        trial = {k: dict(v) for k, v in params.items()}
        trial[kind]["weight"] = w
        learned = LearnedRetryProfile(queue=queue_name, params=trial)
        err = 0.0
        for nthreads, ops in cells:
            got = _charged_per_op(queue_name, nthreads, ops, learned,
                                  mem_model, retry_scale)
            want = target.get(kind, {}).get(nthreads, 0.0)
            # relative residuals: the calibration tolerance is relative
            # per thread count, so a small-count cell must not be
            # sacrificed to a large-count one; the floor keeps near-zero
            # cells (e.g. 2 threads, no observed failure) from dominating
            err += ((got.get(kind, 0.0) - want) / max(want, 0.5)) ** 2
        return err

    coarse = np.arange(0.0, 3.01, 0.25)
    best_w = min(coarse, key=sse)
    fine = np.arange(max(best_w - 0.2, 0.0), best_w + 0.21, 0.05)
    return float(min(fine, key=sse))


# ------------------------------------------------------------------- fit
def fit_profiles(queue_name: str, traces: Sequence[Trace],
                 retry_scale: float = DEFAULT_RETRY_SCALE,
                 refine: bool = True,
                 refine_sweeps: int = 2) -> LearnedRetryProfile:
    """Fit a :class:`LearnedRetryProfile` for one queue from its traces.

    `traces` should cover several thread counts (both fits need varying
    contention levels).  With ``refine=True`` each kind's weight is tuned
    against the batched model itself (see :func:`_search_weight`); without
    it the weight comes from the trace-side window statistics alone.
    """
    if not traces:
        raise ValueError("fit_profiles needs at least one trace")
    stats = _per_trace_stats(traces)
    kinds = sorted(stats)
    params: Dict[str, Dict[str, float]] = {k: {} for k in kinds}
    target: Dict[str, Dict[int, float]] = {}
    # the joint fit below pairs stats[kind][i] rows across kinds by trace
    # index; a trace missing a kind (e.g. a producers-only capture) would
    # silently mis-align the regression, so reject it up front
    short = {k: len(rows) for k, rows in stats.items()
             if len(rows) != len(traces)}
    if short:
        raise ValueError(
            f"every trace must contain ops of every kind; {short} "
            f"(rows per kind) vs {len(traces)} traces -- fit from "
            "mixed-kind workloads like 'pairs'")
    # per-class joint fit across kinds: per-kind excess rows apportion the
    # cost, a heavier cross-kind conservation row pins the total (a
    # negative excess in one kind nets off another's positive one)
    ntraces = len(traces)

    def class_system(field: str, delta: float = 0.0
                     ) -> Tuple[np.ndarray, np.ndarray]:
        A, b = [], []
        for i in range(ntraces):
            x = {k: stats[k][i]["rounds"]
                 / (1.0 + delta * stats[k][i]["k_eff"]) for k in kinds}
            for ki, kind in enumerate(kinds):
                row = np.zeros(len(kinds))
                row[ki] = x[kind]
                A.append(row)
                b.append(stats[kind][i]["excess"][field])
            ntot = sum(stats[k][i]["nops"] for k in kinds)
            frac = [stats[k][i]["nops"] / max(ntot, 1) for k in kinds]
            A.append(_CONSERVATION_WEIGHT * np.array(
                [x[k] * frac[ki] for ki, k in enumerate(kinds)]))
            b.append(_CONSERVATION_WEIGHT * sum(
                stats[k][i]["excess"][field] * frac[ki]
                for ki, k in enumerate(kinds)))
        return np.asarray(A), np.asarray(b)

    for f in _CLASS_COLS:
        if f == "flushed_reads":
            continue
        A, b = class_system(f)
        sol = _nnls(A, b)
        for ki, kind in enumerate(kinds):
            params[kind][f] = float(sol[ki])
    # flushed reads: jointly fit the per-round count AND its contention
    # decay (the post-flush fraction shrinks as more co-scheduled ops
    # re-fetch the invalidated line first) over a delta grid
    best = None
    for delta in _DELTA_GRID:
        A, b = class_system("flushed_reads", delta)
        sol = _nnls(A, b)
        sse = float(((A @ sol - b) ** 2).sum())
        if best is None or sse < best[0] - 1e-12:
            best = (sse, float(delta), sol)
    _, delta, sol = best
    for ki, kind in enumerate(kinds):
        params[kind]["flushed_reads"] = float(sol[ki])
        params[kind]["flushed_decay"] = delta if sol[ki] > 0 else 0.0
    # Per-window-size decay shape: instead of forcing the measured decay
    # through the parametric 1/(1+delta*k), read the per-round flushed
    # fraction off each traced thread count directly and tabulate it by
    # integer window size (RetryProfile.flushed_decay accepts the tuple;
    # the scalar stays as the inert default and the parametric fallback).
    # The exact scheduler's 12-16-thread runs decay faster than 1/(1+dk)
    # -- threads re-fetch invalidated lines almost immediately -- and the
    # table captures that, which is what pushes the wide-thread envelope.
    for ki, kind in enumerate(kinds):
        fr = params[kind]["flushed_reads"]
        if fr <= 0 or not params[kind]["flushed_decay"]:
            continue
        usable = [r for r in stats[kind]
                  if r["rounds"] > 1e-9 and r["k_eff"] > 0]
        pts = [(r["k_eff"],
                min(max(r["excess"]["flushed_reads"], 0.0)
                    / (r["rounds"] * fr), 1.0))
               for r in usable]
        if len(pts) < 2:
            continue
        # The per-point ratios bypass the joint (cross-kind conservation)
        # system, so they are only trustworthy when the traces actually
        # contain a measurable number of excess flushed reads; with a thin
        # signal the clamped ratios bias high and the parametric scalar
        # (fit jointly) extrapolates better.
        mean_events = float(np.mean(
            [max(r["excess"]["flushed_reads"], 0.0) * r["nops"]
             for r in usable]))
        if mean_events < _SHAPE_MIN_EVENTS:
            continue
        # and the measured region must actually exhibit decay: a table
        # that is flat (clamped at 1) over every traced window size and
        # only "decays" in the extrapolated tail contradicts the joint
        # fit's delta and merely re-inflates small-k charges
        if min(f for _, f in pts) > _SHAPE_MAX_FLAT:
            continue
        pts.sort()
        ks = np.array([p[0] for p in pts])
        fs = np.array([p[1] for p in pts])
        kmax = int(np.ceil(ks.max())) + 8     # cover past the traced range
        grid = np.arange(1, kmax + 1, dtype=float)
        shape = np.interp(grid, ks, fs)
        # beyond the last measured window size, continue the fitted
        # parametric decay anchored at the measured boundary
        kb = float(ks.max())
        fb = float(fs[-1])
        beyond = grid > kb
        shape[beyond] = fb * (1.0 + delta * kb) / (1.0 + delta * grid[beyond])
        shape = np.minimum.accumulate(np.clip(shape, 0.0, 1.0))
        table = tuple(round(float(x), 6) for x in shape)

        def _sse(fn):
            return sum(
                (fr * fn(r["k_eff"]) * r["rounds"]
                 - max(r["excess"]["flushed_reads"], 0.0)) ** 2
                for r in stats[kind] if r["rounds"] > 1e-9)

        def _tab(k, _t=table):
            return _t[max(1, min(int(round(k)), len(_t))) - 1]

        # adopt the table only where it explains the measurements at
        # least as well as the scalar curve it replaces
        if _sse(_tab) <= _sse(lambda k: 1.0 / (1.0 + delta * k)) + 1e-12:
            params[kind]["flushed_decay"] = table
    for kind, rows in stats.items():
        k_pool = np.concatenate([r["k"] for r in rows])
        r_pool = np.concatenate([r["rounds_i"] for r in rows])
        params[kind]["weight"] = _fit_weight(k_pool, r_pool, retry_scale)
        target[kind] = {r["nthreads"]: r["rounds"] for r in rows}
        # measured retry saturation: the exact scheduler's failed-round
        # rate plateaus well below the geometric cap (helping drains the
        # obstruction), and the weight search below needs the ceiling in
        # place to fit the unsaturated cells
        r_max = max(target[kind].values(), default=0.0)
        params[kind]["max_rounds"] = (_MAX_ROUNDS_HEADROOM * r_max
                                      if r_max > 0
                                      else P_CAP / (1.0 - P_CAP))
    cells = sorted({(r["nthreads"], r["ops_per_thread"])
                    for rows in stats.values() for r in rows
                    if r["nthreads"] > 1 and r["ops_per_thread"]})
    if refine and cells:
        mem_model = traces[0].meta.get("model", "optane-clwb")
        for _ in range(refine_sweeps):
            for kind in sorted(params):
                params[kind]["weight"] = _search_weight(
                    queue_name, kind, cells, params, target, mem_model,
                    retry_scale)
    source: Dict[str, Any] = {
        "traces": [{"nthreads": t.meta.get("nthreads"),
                    "seed": t.meta.get("seed"),
                    "ops_per_thread": t.meta.get("ops_per_thread"),
                    "model": t.meta.get("model"),
                    "events": len(t)} for t in traces],
        "retry_scale": retry_scale,
        "target_rounds_per_op": {
            kind: {str(t): round(v, 4) for t, v in sorted(d.items())}
            for kind, d in sorted(target.items())},
    }
    return LearnedRetryProfile(queue=queue_name, params=params,
                               source=source)


def fit_all(queue_names: Iterable[str],
            thread_counts: Sequence[int] = (2, 4, 8, 12),
            ops_per_thread: int = 24, seed: int = 1,
            model: str = "optane-clwb",
            trace_dir: Optional[str] = None,
            log=None) -> Dict[str, LearnedRetryProfile]:
    """Capture traces and fit profiles for several queues.

    With `trace_dir`, each captured trace is also saved there as
    ``<queue>_t<threads>_s<seed>.trace.npz``.
    """
    say = log or (lambda msg: None)
    out: Dict[str, LearnedRetryProfile] = {}
    for name in queue_names:
        traces = []
        for nthreads in thread_counts:
            say(f"# tracing {name} at {nthreads} threads "
                f"({ops_per_thread} ops/thread, exact scheduler)...")
            trace = capture_trace(name, nthreads, ops_per_thread,
                                  seed=seed, model=model)
            traces.append(trace)
            if trace_dir:
                import os
                os.makedirs(trace_dir, exist_ok=True)
                save_trace(os.path.join(
                    trace_dir, f"{name}_t{nthreads}_s{seed}.trace.npz"),
                    trace)
        out[name] = fit_profiles(name, traces, refine=True)
        say(f"# fitted {name}: " + json.dumps(
            {k: {f: ([round(float(x), 3) for x in v]
                     if isinstance(v, (list, tuple)) else round(v, 3))
                 for f, v in p.items()}
             for k, p in out[name].params.items()}))
    return out


# ----------------------------------------------------------- serialization
def save_profiles(path: str, profiles: Dict[str, LearnedRetryProfile],
                  retry_scale: float = DEFAULT_RETRY_SCALE) -> None:
    """Write learned profiles as versioned, diff-friendly JSON."""
    def _ser(v):
        # flushed_decay may be a per-window-size shape (tuple -> JSON list)
        if isinstance(v, (list, tuple)):
            return [round(float(x), 6) for x in v]
        return round(float(v), 6)

    doc = {
        "schema": PROFILE_SCHEMA,
        "retry_scale": retry_scale,
        "generator": "python benchmarks/run.py fit-profiles",
        "queues": {
            name: {
                "params": {kind: {f: _ser(p[f]) for f in PARAM_FIELDS}
                           for kind, p in sorted(lp.params.items())},
                "source": lp.source,
            } for name, lp in sorted(profiles.items())
        },
    }
    with open(path, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
        f.write("\n")


def load_profiles(path: str) -> Dict[str, LearnedRetryProfile]:
    """Load profiles written by :func:`save_profiles` (schema-checked)."""
    with open(path) as f:
        doc = json.load(f)
    if doc.get("schema") != PROFILE_SCHEMA:
        raise ValueError(
            f"{path}: profile schema {doc.get('schema')!r}, this reader "
            f"understands {PROFILE_SCHEMA}")
    def _de(v):
        if isinstance(v, list):
            return tuple(float(x) for x in v)
        return float(v)

    out: Dict[str, LearnedRetryProfile] = {}
    for name, entry in doc.get("queues", {}).items():
        params = {}
        for kind, p in entry.get("params", {}).items():
            missing = [f for f in PARAM_FIELDS if f not in p]
            if missing:
                raise ValueError(
                    f"{path}: {name}/{kind} missing fields {missing}")
            params[kind] = {f: _de(p[f]) for f in PARAM_FIELDS}
        out[name] = LearnedRetryProfile(queue=name, params=params,
                                        source=entry.get("source", {}))
    return out
