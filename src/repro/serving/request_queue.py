"""Durable inference request/response queues.

Requests are durably enqueued (append + one fence -- can group-commit a
burst under a single fence); a response is durable when its record lands in
the response WAL (one fence per batch of responses).  Crash recovery
replays: pending = requests-prefix minus responded ids.  In-flight requests
at crash time are simply re-served (at-least-once serving with
idempotent request ids -- the standard contract)."""
from __future__ import annotations

import json
import os
from typing import List

from repro.persist.wal import WriteAheadLog


class DurableRequestQueue:
    def __init__(self, directory: str):
        self.dir = directory
        os.makedirs(directory, exist_ok=True)
        self.req_wal = WriteAheadLog(os.path.join(directory, "requests.wal"))
        self.resp_wal = WriteAheadLog(os.path.join(directory, "responses.wal"))
        self._pending: List[dict] = []
        self._responded: set = set()

    # ----------------------------------------------------------------- client
    def submit(self, requests: List[dict]) -> None:
        """Durable enqueue; one fence for the whole burst."""
        for r in requests:
            assert "id" in r
            self.req_wal.append(json.dumps(r).encode())
            self._pending.append(r)
        self.req_wal.fence()

    # ----------------------------------------------------------------- server
    def take_batch(self, n: int) -> List[dict]:
        batch = self._pending[:n]
        self._pending = self._pending[n:]
        return batch

    def commit_responses(self, responses: List[dict]) -> None:
        """Durable response publication; one fence per batch."""
        for r in responses:
            self.resp_wal.append(json.dumps(r).encode())
            self._responded.add(r["id"])
        self.resp_wal.fence()

    def pending_count(self) -> int:
        return len(self._pending)

    # --------------------------------------------------------------- recovery
    def recover(self) -> int:
        reqs = [json.loads(p.decode()) for p in WriteAheadLog.replay(
            os.path.join(self.dir, "requests.wal"))]
        resps = [json.loads(p.decode()) for p in WriteAheadLog.replay(
            os.path.join(self.dir, "responses.wal"))]
        self._responded = {r["id"] for r in resps}
        self._pending = [r for r in reqs if r["id"] not in self._responded]
        return len(self._pending)

    def responses(self) -> List[dict]:
        return [json.loads(p.decode()) for p in WriteAheadLog.replay(
            os.path.join(self.dir, "responses.wal"))]

    def close(self) -> None:
        self.req_wal.close()
        self.resp_wal.close()
