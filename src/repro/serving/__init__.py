from .engine import ServeEngine
from .request_queue import DurableRequestQueue

__all__ = ["DurableRequestQueue", "ServeEngine"]
