"""Batched serving engine: durable request queue -> prefill+decode loop.

Serves a (reduced-config) CausalLM: takes a batch of prompts, builds the KV
cache by teacher-forcing the prompt tokens through ``serve_step`` (token at
a time -- the cache path is the thing under test), then greedy-decodes
``max_new`` tokens, and durably commits the responses with one fence."""
from __future__ import annotations

from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import init_cache, init_params, serve_step
from repro.models.config import ModelConfig

from .request_queue import DurableRequestQueue


class ServeEngine:
    def __init__(self, cfg: ModelConfig, queue: DurableRequestQueue,
                 params=None, seed: int = 0, max_len: int = 64):
        self.cfg = cfg
        self.queue = queue
        self.max_len = max_len
        self.params = params if params is not None \
            else init_params(cfg, jax.random.PRNGKey(seed))
        self._step = jax.jit(
            lambda p, c, b, q: serve_step(cfg, p, c, b, q))

    def _greedy(self, prompts: np.ndarray, max_new: int) -> np.ndarray:
        B, P = prompts.shape
        cache = init_cache(self.cfg, B, self.max_len)
        tok = jnp.asarray(prompts[:, 0:1], jnp.int32)
        outs = []
        for t in range(P + max_new - 1):
            pos = jnp.full((B,), t, jnp.int32)
            logits, cache = self._step(self.params, cache,
                                       {"tokens": tok}, pos)
            nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
            if t + 1 < P:
                tok = jnp.asarray(prompts[:, t + 1:t + 2], jnp.int32)
            else:
                tok = nxt
                outs.append(np.asarray(nxt)[:, 0])
        return np.stack(outs, axis=1)   # (B, max_new)

    def serve_once(self, batch_size: int = 4, max_new: int = 8) -> List[dict]:
        batch = self.queue.take_batch(batch_size)
        if not batch:
            return []
        P = max(len(r["prompt"]) for r in batch)
        prompts = np.zeros((len(batch), P), np.int32)
        for i, r in enumerate(batch):
            p = np.asarray(r["prompt"], np.int32)
            prompts[i, :len(p)] = p
        gen = self._greedy(prompts, max_new)
        responses = [{"id": r["id"], "tokens": gen[i].tolist()}
                     for i, r in enumerate(batch)]
        self.queue.commit_responses(responses)   # ONE fence for the batch
        return responses

    def run(self, batch_size: int = 4, max_new: int = 8) -> int:
        n = 0
        while self.queue.pending_count():
            n += len(self.serve_once(batch_size, max_new))
        return n
