from .cursors import CursorFile
from .wal import WalStats, WriteAheadLog

__all__ = ["CursorFile", "WalStats", "WriteAheadLog"]
