"""Per-worker monotone cursors -- OptUnlinkedQ/OptLinkedQ's per-thread head
index and double last-enqueue record, at file granularity.

Each worker owns a slot file that is only ever *written* on the fast path
(the movnti analogue: no read-modify-write, no readback).  Writes alternate
between two fixed slots so a torn write can only destroy the slot being
written -- the other still holds the penultimate durable value, exactly the
paper's two-record trick (§6.2).  Recovery takes the max valid value; across
workers the global cursor is the max over per-worker cursors (§6.1).
"""
from __future__ import annotations

import os
import struct
import zlib
from typing import List, Optional

_REC = struct.Struct("<QQI")    # value, seq, crc
_SLOT = 64                      # one "cache line" per slot


class CursorFile:
    def __init__(self, path: str):
        self.path = path
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        self._f = open(path, "r+b" if os.path.exists(path) else "w+b")
        if os.path.getsize(path) < 2 * _SLOT:
            self._f.write(b"\0" * (2 * _SLOT))
            self._f.flush()
        self._seq = 0
        self.fences = 0

    def advance(self, value: int, fence: bool = True) -> None:
        """Publish a new cursor value (write-only; never reads back)."""
        self._seq += 1
        body = struct.pack("<QQ", value, self._seq)
        crc = zlib.crc32(body) & 0xFFFFFFFF
        rec = _REC.pack(value, self._seq, crc)
        self._f.seek((self._seq % 2) * _SLOT)
        self._f.write(rec)
        self._f.flush()
        if fence:
            os.fsync(self._f.fileno())
            self.fences += 1

    def fence(self) -> None:
        os.fsync(self._f.fileno())
        self.fences += 1

    def close(self) -> None:
        self._f.close()

    # ------------------------------------------------------------- recovery
    @staticmethod
    def recover(path: str) -> Optional[int]:
        if not os.path.exists(path):
            return None
        with open(path, "rb") as f:
            data = f.read()
        best = None
        for i in range(2):
            chunk = data[i * _SLOT: i * _SLOT + _REC.size]
            if len(chunk) < _REC.size:
                continue
            value, seq, crc = _REC.unpack(chunk)
            body = struct.pack("<QQ", value, seq)
            if (zlib.crc32(body) & 0xFFFFFFFF) == crc and seq > 0:
                if best is None or value > best:
                    best = value
        return best

    @staticmethod
    def recover_max(paths: List[str]) -> Optional[int]:
        """Global cursor = max across per-worker cursors (paper §6.1)."""
        vals = [CursorFile.recover(p) for p in paths]
        vals = [v for v in vals if v is not None]
        return max(vals) if vals else None
