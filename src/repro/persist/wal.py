"""Write-once append log -- the paper's §2.1 logging discipline at file
granularity.

Design rules carried over from the durable queues:
* records are framed (magic, length, crc32, payload) and **write-once**:
  the fast path never reads anything it wrote (zero post-flush accesses);
* ``append`` buffers + ``flush`` issues the OS write (the CLWB analogue);
  ``fence`` fsyncs -- the ONE blocking persist; group commit batches any
  number of appends under a single fence, exactly like the queues piggyback
  flushes on one SFENCE;
* recovery replays the longest valid *prefix* (a torn/corrupt tail record is
  treated as absent -- the file-level Assumption 1).
"""
from __future__ import annotations

import os
import struct
import zlib
from dataclasses import dataclass
from typing import List

_MAGIC = 0x5151A5A5     # 'QQ' durable-queue homage
_HDR = struct.Struct("<III")   # magic, length, crc32


@dataclass
class WalStats:
    appends: int = 0
    flushes: int = 0
    fences: int = 0
    bytes_written: int = 0
    reads_after_write: int = 0   # must stay 0 on the fast path


class WriteAheadLog:
    def __init__(self, path: str):
        self.path = path
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        self._f = open(path, "ab", buffering=1024 * 1024)
        self.stats = WalStats()

    # ------------------------------------------------------------ fast path
    def append(self, payload: bytes) -> None:
        crc = zlib.crc32(payload) & 0xFFFFFFFF
        self._f.write(_HDR.pack(_MAGIC, len(payload), crc))
        self._f.write(payload)
        self.stats.appends += 1
        self.stats.bytes_written += _HDR.size + len(payload)

    def flush(self) -> None:
        """Asynchronous write-back (CLWB analogue)."""
        self._f.flush()
        self.stats.flushes += 1

    def fence(self) -> None:
        """The ONE blocking persist: everything appended so far is durable."""
        self._f.flush()
        os.fsync(self._f.fileno())
        self.stats.fences += 1

    def append_durable(self, payload: bytes) -> None:
        """Single logical update = append + flush + fence."""
        self.append(payload)
        self.fence()

    def close(self) -> None:
        self._f.close()

    # ------------------------------------------------------------- recovery
    @staticmethod
    def replay(path: str) -> List[bytes]:
        """Longest valid prefix of records (recovery-only read path)."""
        out: List[bytes] = []
        if not os.path.exists(path):
            return out
        with open(path, "rb") as f:
            data = f.read()
        off = 0
        while off + _HDR.size <= len(data):
            magic, length, crc = _HDR.unpack_from(data, off)
            if magic != _MAGIC or off + _HDR.size + length > len(data):
                break
            payload = data[off + _HDR.size: off + _HDR.size + length]
            if (zlib.crc32(payload) & 0xFFFFFFFF) != crc:
                break   # torn tail: stop at the persisted prefix
            out.append(payload)
            off += _HDR.size + length
        return out
