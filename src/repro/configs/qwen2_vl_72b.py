"""qwen2-vl-72b [vlm] -- M-RoPE, dynamic resolution (backbone only).

80L d_model=8192 64H (GQA kv=8) d_ff=29568 vocab=152064.  The vision
frontend is a stub: ``input_specs`` supplies precomputed patch embeddings.
[arXiv:2409.12191; hf Qwen/Qwen2-VL-72B]
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-72b", family="vlm",
    n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=29568, vocab=152064,
    rope="mrope", rope_theta=1e6,
    embed_stub=True, attn_bias=True,
)
