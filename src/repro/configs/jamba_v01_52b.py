"""jamba-v0.1-52b [hybrid] -- Mamba+attention 1:7 interleave with MoE.

32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=65536, MoE 16 experts
top-2, MoE every other layer, attention at position 4 of each 8-layer
block, ssm_state=16.  [arXiv:2403.19887; hf ai21labs/Jamba-v0.1]
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="jamba-v0.1-52b", family="hybrid",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=14336, vocab=65536,
    n_experts=16, top_k=2, moe_every=2,
    ssm_state=16, ssm_conv=4, ssm_expand=2,
    attn_every=8, attn_position=4,
    sub_quadratic=True,
)
