"""musicgen-medium [audio] -- decoder-only over EnCodec tokens.

48L d_model=1536 24H (kv=24 => MHA) d_ff=6144 vocab=2048.  The EnCodec
frontend is a stub: ``input_specs`` supplies precomputed frame embeddings.
[arXiv:2306.05284; hf facebook/musicgen-medium]
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium", family="audio",
    n_layers=48, d_model=1536, n_heads=24, n_kv_heads=24,
    d_ff=6144, vocab=2048,
    embed_stub=True,
)
