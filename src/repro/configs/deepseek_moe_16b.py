"""deepseek-moe-16b [moe] -- fine-grained MoE, 2 shared + 64 routed top-6.

28L d_model=2048 16H (kv=16 => MHA) d_ff=1408 (per expert) vocab=102400;
layer 0 uses a dense FFN (width 10944) per the paper.
[arXiv:2401.06066; hf deepseek-ai/deepseek-moe-16b-base]
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-moe-16b", family="moe",
    n_layers=28, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=1408, vocab=102400,
    n_experts=64, n_shared_experts=2, top_k=6,
    dense_ff_first=10944,
)
