"""falcon-mamba-7b [ssm] -- attention-free mamba-1 architecture.

64L d_model=4096 vocab=65024 ssm_state=16 (d_inner=8192, conv=4, expand=2).
[arXiv:2410.05355; unverified]
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="falcon-mamba-7b", family="ssm",
    n_layers=64, d_model=4096, n_heads=1, n_kv_heads=1,
    d_ff=0, vocab=65024,
    ssm_state=16, ssm_conv=4, ssm_expand=2,
    rope="none", sub_quadratic=True,
)
