"""Architecture registry: ``get_config(arch_id)`` + reduced smoke variants.

Reduced configs keep the *family shape* (same pattern: GQA ratios, MoE
expert structure, hybrid interleave) at toy width/depth so one train step
runs on a single CPU device in seconds.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List

from repro.models.config import ModelConfig, SHAPES, ShapeConfig

from .jamba_v01_52b import CONFIG as _jamba
from .command_r_plus_104b import CONFIG as _commandr
from .yi_6b import CONFIG as _yi
from .phi4_mini_3_8b import CONFIG as _phi4
from .nemotron_4_340b import CONFIG as _nemotron
from .falcon_mamba_7b import CONFIG as _falconmamba
from .qwen2_vl_72b import CONFIG as _qwen2vl
from .musicgen_medium import CONFIG as _musicgen
from .deepseek_moe_16b import CONFIG as _deepseek
from .dbrx_132b import CONFIG as _dbrx

ARCHS: Dict[str, ModelConfig] = {c.name: c for c in [
    _jamba, _commandr, _yi, _phi4, _nemotron, _falconmamba,
    _qwen2vl, _musicgen, _deepseek, _dbrx,
]}

# short aliases for --arch
ALIASES = {
    "jamba": "jamba-v0.1-52b",
    "command-r-plus": "command-r-plus-104b",
    "yi": "yi-6b",
    "phi4-mini": "phi4-mini-3.8b",
    "nemotron": "nemotron-4-340b",
    "falcon-mamba": "falcon-mamba-7b",
    "qwen2-vl": "qwen2-vl-72b",
    "musicgen": "musicgen-medium",
    "deepseek-moe": "deepseek-moe-16b",
    "dbrx": "dbrx-132b",
}


def get_config(arch: str) -> ModelConfig:
    arch = ALIASES.get(arch, arch)
    return ARCHS[arch]


def reduced_config(arch: str) -> ModelConfig:
    """Toy-size config of the same family for CPU smoke tests."""
    cfg = get_config(arch)
    period = cfg.attn_every or 0
    n_layers = period if cfg.family == "hybrid" else 2
    if cfg.dense_ff_first:
        n_layers = 3
    heads = 4
    kv = max(1, round(heads * cfg.n_kv_heads / cfg.n_heads)) \
        if cfg.n_heads else 1
    return dataclasses.replace(
        cfg,
        name=cfg.name + "-smoke",
        n_layers=n_layers,
        d_model=64,
        n_heads=heads, n_kv_heads=kv, d_head=16,
        d_ff=0 if cfg.family == "ssm" else 96,
        vocab=512,
        n_experts=min(cfg.n_experts, 8),
        n_shared_experts=min(cfg.n_shared_experts, 1),
        top_k=min(cfg.top_k, 2),
        dense_ff_first=128 if cfg.dense_ff_first else 0,
        dt_rank=8 if cfg.ssm_state else 0,
        # drop-free routing so decode (T=1) and teacher-forced forward agree
        capacity_factor=16.0,
        param_dtype="float32", compute_dtype="float32",
    )


def applicable_shapes(arch: str) -> List[ShapeConfig]:
    """The assigned shape set, honoring the long_500k sub-quadratic skip."""
    cfg = get_config(arch)
    shapes = [SHAPES["train_4k"], SHAPES["prefill_32k"], SHAPES["decode_32k"]]
    if cfg.sub_quadratic:
        shapes.append(SHAPES["long_500k"])
    return shapes


__all__ = ["ARCHS", "ALIASES", "get_config", "reduced_config",
           "applicable_shapes", "SHAPES"]
