"""Sharded AdamW with optional block-wise int8 state quantization.

The int8 mode (per-128-block absmax scales, bitsandbytes-style) cuts
optimizer memory 4x -- what lets the 340B config fit a 16GB/chip pod slice
under ZeRO-1 sharding.  Pure JAX; states inherit the parameter shardings
plus extra data-axis sharding from the sharding rules.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp

PyTree = Any
BLOCK = 128


class AdamWConfig(NamedTuple):
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    state_dtype: str = "float32"      # float32 | int8
    warmup_steps: int = 100
    total_steps: int = 10_000


def _q8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Block-wise int8 quantization along the flattened last axis."""
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % BLOCK
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    q = jnp.round(blocks / jnp.maximum(scale, 1e-12)).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def _dq8(q: jax.Array, scale: jax.Array, shape) -> jax.Array:
    flat = (q.astype(jnp.float32) * scale).reshape(-1)
    n = 1
    for s in shape:
        n *= s
    return flat[:n].reshape(shape)


def _sched(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (0.1 + 0.9 * cos)


def init_opt_state(cfg: AdamWConfig, params: PyTree) -> PyTree:
    def zero_like(p):
        if cfg.state_dtype == "int8":
            q, s = _q8(jnp.zeros_like(p, jnp.float32))
            return {"q": q, "s": s}
        return jnp.zeros_like(p, jnp.float32)
    return {
        "step": jnp.zeros((), jnp.int32),
        "m": jax.tree.map(zero_like, params),
        "v": jax.tree.map(zero_like, params),
    }


def adamw_update(cfg: AdamWConfig, params: PyTree, grads: PyTree,
                 state: PyTree) -> Tuple[PyTree, PyTree, dict]:
    step = state["step"] + 1
    # global-norm clip
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                         for g in jax.tree.leaves(grads)))
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))
    lr = _sched(cfg, step)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        if cfg.state_dtype == "int8":
            m_f = _dq8(m["q"], m["s"], p.shape)
            v_f = _dq8(v["q"], v["s"], p.shape)
        else:
            m_f, v_f = m, v
        m_f = cfg.b1 * m_f + (1 - cfg.b1) * g
        v_f = cfg.b2 * v_f + (1 - cfg.b2) * jnp.square(g)
        mhat = m_f / (1 - cfg.b1 ** step.astype(jnp.float32))
        vhat = v_f / (1 - cfg.b2 ** step.astype(jnp.float32))
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) \
            + cfg.weight_decay * p.astype(jnp.float32)
        p_new = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        if cfg.state_dtype == "int8":
            mq, ms = _q8(m_f)
            vq, vs = _q8(v_f)
            return p_new, {"q": mq, "s": ms}, {"q": vq, "s": vs}
        return p_new, m_f, v_f

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(state["m"])
    flat_v = tdef.flatten_up_to(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, {"step": step, "m": new_m, "v": new_v}, metrics
