"""Production mesh construction (assignment-mandated shapes).

A FUNCTION, not a module-level constant: importing this module must never
touch jax device state (tests run with 1 CPU device; only dryrun.py forces
512 host devices).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh():
    """Tiny (2,2) mesh over available devices (subprocess tests force >=4
    host devices); falls back to (1,1) on a single device."""
    n = len(jax.devices())
    if n >= 4:
        return jax.make_mesh((2, 2), ("data", "model"))
    return jax.make_mesh((1, 1), ("data", "model"))
