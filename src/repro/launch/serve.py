"""Serving driver: durable request queue + batched greedy decoding.

  PYTHONPATH=src python -m repro.launch.serve --arch musicgen-medium \
      --requests 12 --dir /tmp/serve1
"""
from __future__ import annotations

import argparse

import numpy as np

from repro.configs import reduced_config
from repro.serving import DurableRequestQueue, ServeEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--dir", default="/tmp/repro_serve")
    args = ap.parse_args()

    cfg = reduced_config(args.arch)
    q = DurableRequestQueue(args.dir)
    q.recover()
    rng = np.random.RandomState(0)
    reqs = [{"id": f"r{i}", "prompt": rng.randint(
        0, cfg.vocab, (4,)).tolist()} for i in range(args.requests)]
    q.submit(reqs)
    eng = ServeEngine(cfg, q)
    n = eng.run(batch_size=args.batch, max_new=args.max_new)
    print(f"served {n} requests; responses durable in {args.dir}")


if __name__ == "__main__":
    main()
