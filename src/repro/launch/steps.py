"""Step functions + abstract input specs for every (arch × shape) cell.

``input_specs`` returns ShapeDtypeStruct stand-ins (no allocation), which is
what the multi-pod dry-run lowers against.  The same step functions back the
real train/serve drivers on concrete arrays.
"""
from __future__ import annotations

import math
from typing import Any, Callable, Dict

import jax
import jax.numpy as jnp

from repro.models import (forward, init_cache, init_params, loss_fn,
                          serve_step)
from repro.models.config import ModelConfig, ShapeConfig
from repro.optim import AdamWConfig, adamw_update, init_opt_state

PyTree = Any


def opt_config(cfg: ModelConfig) -> AdamWConfig:
    """int8-quantized AdamW state for the largest models (>=200B params) --
    the 4x optimizer-memory cut that fits 340B on a 16GB/chip pod slice."""
    big = cfg.n_params() > 200e9
    return AdamWConfig(state_dtype="int8" if big else "float32")


def accum_steps(cfg: ModelConfig, shape: ShapeConfig, n_data_shards: int,
                seq_shard: bool, budget_bytes: float = 2.5e9) -> int:
    """Gradient-accumulation factor bounding per-chip saved-activation
    memory: scan carries are (B/dp/accum, S[, /tp], D) bf16 x n_periods.
    SSM/hybrid configs additionally bound the selective-scan transient,
    (B_mb, chunk, d_inner, ds) fp32 blocks, which dwarfs the carry."""
    _, periods, _ = cfg.layer_pattern()
    per_seq = shape.seq_len * cfg.d_model * 2
    if seq_shard:
        per_seq = per_seq / 16
    b_shard = max(1, shape.global_batch // n_data_shards)
    total = b_shard * per_seq * periods
    accum = max(1, int(math.ceil(total / budget_bytes)))
    if cfg.ssm_state:
        # keep ~3 live (B_mb, 128, din, ds) fp32 scan blocks under budget
        per_b = 3 * 128 * cfg.d_inner * cfg.ssm_state * 4
        accum = max(accum, int(math.ceil(b_shard * per_b / budget_bytes)))
    # accum must divide the per-shard batch
    while b_shard % accum and accum < b_shard:
        accum += 1
    return min(accum, b_shard)


# -------------------------------------------------------------- input specs --
def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, jax.ShapeDtypeStruct]:
    B, S = shape.global_batch, shape.seq_len
    tok = jnp.int32
    if shape.kind == "train":
        if cfg.embed_stub:
            return {"embeds": jax.ShapeDtypeStruct((B, S, cfg.d_model),
                                                   jnp.bfloat16),
                    "labels": jax.ShapeDtypeStruct((B, S), tok)}
        return {"tokens": jax.ShapeDtypeStruct((B, S), tok),
                "labels": jax.ShapeDtypeStruct((B, S), tok)}
    if shape.kind == "prefill":
        if cfg.embed_stub:
            return {"embeds": jax.ShapeDtypeStruct((B, S, cfg.d_model),
                                                   jnp.bfloat16)}
        return {"tokens": jax.ShapeDtypeStruct((B, S), tok)}
    # decode: one new token against a cache of S
    if cfg.embed_stub:
        batch = {"embeds": jax.ShapeDtypeStruct((B, 1, cfg.d_model),
                                                jnp.bfloat16)}
    else:
        batch = {"tokens": jax.ShapeDtypeStruct((B, 1), tok)}
    batch["position"] = jax.ShapeDtypeStruct((B,), jnp.int32)
    return batch


def abstract_params(cfg: ModelConfig) -> PyTree:
    return jax.eval_shape(lambda k: init_params(cfg, k),
                          jax.ShapeDtypeStruct((2,), jnp.uint32))


def abstract_opt_state(cfg: ModelConfig, params: PyTree) -> PyTree:
    ocfg = opt_config(cfg)
    return jax.eval_shape(lambda p: init_opt_state(ocfg, p), params)


def abstract_cache(cfg: ModelConfig, shape: ShapeConfig) -> PyTree:
    return jax.eval_shape(
        lambda: init_cache(cfg, shape.global_batch, shape.seq_len))


# ------------------------------------------------------------------- steps --
def make_train_step(cfg: ModelConfig, accum: int = 1,
                    use_pallas: bool = False,
                    remat_policy: str = "nothing",
                    constrain=None,
                    accum_dtype=jnp.float32,
                    grad_shardings=None) -> Callable:
    """``grad_shardings``: optional NamedSharding tree for the gradient
    accumulator.  Sharding it over the data axis turns the per-microbatch
    gradient all-reduce into a reduce-scatter (ZeRO-style accumulation);
    the full reduction then happens ONCE at the optimizer update."""
    ocfg = opt_config(cfg)

    def _constrain_grads(g):
        if grad_shardings is None:
            return g
        return jax.tree.map(jax.lax.with_sharding_constraint, g,
                            grad_shardings)

    def train_step(params, opt_state, batch):
        def one_loss(p, mb):
            return loss_fn(cfg, p, mb, use_pallas, remat_policy, constrain)

        if accum == 1:
            loss, grads = jax.value_and_grad(one_loss)(params, batch)
            grads = _constrain_grads(grads)
        else:
            def split(x):
                return x.reshape((accum, x.shape[0] // accum) + x.shape[1:])
            mbs = jax.tree.map(split, batch)

            def acc_step(carry, mb):
                gsum, lsum = carry
                l, g = jax.value_and_grad(one_loss)(params, mb)
                gsum = jax.tree.map(
                    lambda a, b: a + b.astype(accum_dtype), gsum, g)
                return (_constrain_grads(gsum), lsum + l), None

            zeros = _constrain_grads(jax.tree.map(
                lambda p: jnp.zeros(p.shape, accum_dtype), params))
            (gsum, lsum), _ = jax.lax.scan(acc_step, (zeros, 0.0), mbs)
            grads = jax.tree.map(lambda g: g / accum, gsum)
            loss = lsum / accum
        new_params, new_opt, metrics = adamw_update(ocfg, params, grads,
                                                    opt_state)
        metrics["loss"] = loss
        return new_params, new_opt, metrics

    return train_step


def make_prefill_step(cfg: ModelConfig, use_pallas: bool = False,
                      constrain=None) -> Callable:
    def prefill_step(params, batch):
        logits = forward(cfg, params, batch, use_pallas,
                         remat_policy="none_inference", constrain=constrain)
        return logits[:, -1]
    return prefill_step


def make_serve_step(cfg: ModelConfig, use_pallas: bool = False,
                    constrain=None) -> Callable:
    def step(params, cache, batch):
        position = batch["position"]
        toks = {k: v for k, v in batch.items() if k != "position"}
        return serve_step(cfg, params, cache, toks, position, use_pallas,
                          constrain=constrain)
    return step
