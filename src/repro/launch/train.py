"""End-to-end training driver: durable data queue -> train_step -> durable
checkpoints, with crash-restart.

This is example (b)'s engine and the integration point of the paper's
technique: the data queue, the per-worker cursors and the checkpointer all
follow the one-fence / zero-post-flush-read discipline (see DESIGN.md §3).

Usage (reduced config trains a ~small model on CPU; full configs are for
the cluster):
  PYTHONPATH=src python -m repro.launch.train --arch yi-6b --reduced \
      --steps 50 --ckpt-dir /tmp/run1 [--crash-at 23]

``--crash-at N`` aborts the process abruptly after step N (os._exit), so a
subsequent identical invocation exercises real recovery.
"""
from __future__ import annotations

import argparse
import functools
import os
import sys
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced_config
from repro.data import DurableShardQueue, TokenSource
from repro.checkpoint import DurableCheckpointer
from repro.launch.steps import make_train_step, opt_config
from repro.optim import init_opt_state
from repro.models import init_params


def train(arch: str, steps: int = 50, batch: int = 4, seq_len: int = 64,
          ckpt_dir: str = "/tmp/repro_train", ckpt_every: int = 10,
          crash_at: Optional[int] = None, reduced: bool = True,
          log=functools.partial(print, flush=True)) -> dict:
    cfg = reduced_config(arch) if reduced else get_config(arch)
    ocfg = opt_config(cfg)
    source = TokenSource(cfg.vocab, seq_len, batch)
    queue = DurableShardQueue(os.path.join(ckpt_dir, "data"))
    ckpt = DurableCheckpointer(os.path.join(ckpt_dir, "ckpt"),
                               background=False)

    # ---- recovery: model+optimizer state and the data cursor move together
    queue.recover()
    start_step = 0
    restored = ckpt.restore_latest()
    if restored is not None:
        start_step, shards, meta = restored
        params, opt_state = shards[0]["params"], shards[0]["opt"]
        params = jax.tree.map(jnp.asarray, params)
        opt_state = jax.tree.map(jnp.asarray, opt_state)
        log(f"[recovery] resumed from step {start_step} "
            f"(data cursor {meta.get('data_cursor')})")
    else:
        params = init_params(cfg, jax.random.PRNGKey(0))
        opt_state = init_opt_state(ocfg, params)

    # keep the queue topped up (producer role; one fence per burst)
    have = len(queue._shards)
    if have < steps + 1:
        queue.enqueue_shards([{"shard": i} for i in range(have, steps + 8)])

    step_fn = jax.jit(make_train_step(cfg))
    losses = []
    consumed = []
    for step in range(start_step, steps):
        shard = queue.next_shard()
        assert shard is not None
        b = source.batch_for(shard["shard"])
        batch_j = {k: jnp.asarray(v) for k, v in b.items()}
        if cfg.embed_stub:
            emb = np.asarray(
                np.random.RandomState(shard["shard"]).randn(
                    batch, seq_len, cfg.d_model), np.float32) * 0.02
            batch_j = {"embeds": jnp.asarray(emb),
                       "labels": batch_j["labels"]}
        params, opt_state, metrics = step_fn(params, opt_state, batch_j)
        losses.append(float(metrics["loss"]))
        consumed.append(shard["shard"])
        if (step + 1) % ckpt_every == 0 or step + 1 == steps:
            ckpt.save(step + 1,
                      {0: {"params": params, "opt": opt_state}},
                      meta={"data_cursor": shard["_queue_index"] + 1,
                            "arch": cfg.name})
            ckpt.wait()
            # data-consumption durability rides the checkpoint commit
            queue.commit_consumed(shard["_queue_index"])
            log(f"step {step + 1}: loss={losses[-1]:.4f} [checkpointed]")
        else:
            log(f"step {step + 1}: loss={losses[-1]:.4f}")
        if crash_at is not None and step + 1 >= crash_at:
            log(f"[crash injection] abrupt exit after step {step + 1}")
            sys.stdout.flush()
            os._exit(42)
    queue.close()
    return {"losses": losses, "consumed": consumed,
            "final_step": steps, "params": params}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b")
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train")
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--crash-at", type=int, default=None)
    ap.add_argument("--full", action="store_true",
                    help="full-size config (cluster scale)")
    args = ap.parse_args()
    out = train(args.arch, args.steps, args.batch, args.seq_len,
                args.ckpt_dir, args.ckpt_every, args.crash_at,
                reduced=not args.full)
    print(f"done: {out['final_step']} steps, "
          f"loss {out['losses'][0]:.3f} -> {out['losses'][-1]:.3f}")


if __name__ == "__main__":
    main()
