"""Analytic per-device cost model for the roofline's memory term.

Why analytic: the CPU-backend compiled HLO contains copy-insertion
artifacts and materialized fp32 intermediates that a TPU compilation keeps
in VMEM/registers, so byte counts walked from that HLO over-estimate TPU
HBM traffic by >10x (measured; see EXPERIMENTS.md §Dry-run).  FLOPs and
collective payloads parse exactly, so §Roofline uses:

    compute term    <- HLO walker  (exact, trip-count aware)
    memory term     <- THIS model  (documented per-component formulas)
    collective term <- HLO walker  (exact payload bytes x trip counts)

All results are bytes PER DEVICE PER STEP.  Components are returned
separately so EXPERIMENTS.md can show the breakdown.
"""
from __future__ import annotations

from typing import Dict

from repro.models.config import ModelConfig, ShapeConfig

BF16 = 2
F32 = 4


def _layer_act_io(cfg: ModelConfig, spec, tokens_dev: float) -> float:
    """HBM bytes moved by one layer's activations for one forward pass.
    Counts reads+writes of matmul/norm boundary tensors at bf16; block
    internals (attention probabilities, gate products) stay on chip."""
    d = cfg.d_model
    mixer, ffn = spec
    io = 0.0
    # pre-norm read+write, residual add read+write (x2 sublayers)
    io += 4 * d * BF16 * (1 if ffn == "none" else 2)
    if mixer == "attn":
        H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
        io += (d + H * hd) * 2 * BF16          # q proj in/out
        io += (d + 2 * KV * hd) * BF16         # kv proj out (input shared)
        io += (H * hd + d) * 2 * BF16          # out proj in/out
        io += 2 * (H + 2 * KV) * hd * BF16     # flash attn reads q,k,v + out
    else:
        din, ds, dtr = cfg.d_inner, cfg.ssm_state, cfg.dt_rank_
        io += (d + 2 * din) * 2 * BF16         # in_proj
        io += 4 * din * BF16                   # conv + silu r/w
        io += (din + dtr + 2 * ds) * 2 * BF16  # x_proj
        io += (din * 2) * F32 * 2              # scan in/out (fp32)
        io += (din + d) * 2 * BF16             # out_proj
    if ffn in ("dense", "dense_first"):
        dff = cfg.dense_ff_first if ffn == "dense_first" else cfg.d_ff
        gated = cfg.act in ("swiglu", "geglu")
        io += (d + dff * (2 if gated else 1)) * 2 * BF16   # up (w1[,w3])
        io += (dff + d) * 2 * BF16                         # down
    elif ffn == "moe":
        dff = cfg.d_ff
        k = cfg.top_k
        gated = cfg.act in ("swiglu", "geglu")
        io += (d + cfg.n_experts) * 2 * F32                # router
        io += 2 * k * d * BF16 * 2                         # dispatch+combine
        io += k * (d + dff * (2 if gated else 1)) * 2 * BF16
        io += k * (dff + d) * 2 * BF16
        sh = cfg.n_shared_experts
        if sh:
            io += sh * ((d + dff * (2 if gated else 1)) + (dff + d)) * 2 * BF16
    return io * tokens_dev


def analytic_bytes(cfg: ModelConfig, shape: ShapeConfig, n_chips: int,
                   tp: int = 16, accum: int = 1) -> Dict[str, float]:
    """Per-device HBM bytes for one step of this (arch x shape) cell."""
    prefix, periods, pattern = cfg.layer_pattern()
    N = cfg.n_params()
    layers = list(prefix) + list(pattern) * periods
    dp = n_chips // tp
    out: Dict[str, float] = {}

    if shape.kind == "train":
        tokens_dev = shape.seq_len * shape.global_batch / dp
        passes = 3.0           # fwd + remat-recompute + bwd activation IO
        out["weights"] = 3.0 * N * BF16 / tp      # read fwd/remat/bwd (gathered per TP shard)
        out["grads"] = 2.0 * N * BF16 / tp        # write + reduce read
        state_b = 1 if cfg.n_params() > 2e11 else F32    # int8 vs fp32 m,v
        out["optimizer"] = N * (2 * 2 * state_b + 2 * BF16 + F32) / n_chips
        out["activations"] = passes * sum(
            _layer_act_io(cfg, s, tokens_dev) for s in layers)
        out["logits"] = tokens_dev * cfg.vocab / tp * F32 * 3
        out["embed"] = tokens_dev * cfg.d_model * BF16 * 3
    elif shape.kind == "prefill":
        tokens_dev = shape.seq_len * shape.global_batch / dp
        out["weights"] = N * BF16 / tp
        out["activations"] = sum(_layer_act_io(cfg, s, tokens_dev)
                                 for s in layers)
        out["logits"] = tokens_dev * cfg.vocab / tp * BF16
        out["embed"] = tokens_dev * cfg.d_model * BF16
    else:   # decode: one token per sequence against a seq_len cache
        bdev = max(1.0, shape.global_batch / dp)
        out["weights"] = N * BF16 / tp            # every weight read once
        kv_layers = sum(1 for (m, _) in layers if m == "attn")
        ssm_layers = len(layers) - kv_layers
        cache_per_seq = (kv_layers * 2 * shape.seq_len * cfg.n_kv_heads
                         * cfg.head_dim * BF16
                         + ssm_layers * (cfg.d_inner * cfg.ssm_state * F32 * 2
                                         if cfg.ssm_state else 0))
        # the whole cache is read once per decoded token, sharded over chips
        out["kv_cache"] = cache_per_seq * shape.global_batch / n_chips
        out["activations"] = sum(_layer_act_io(cfg, s, bdev) for s in layers)
        out["logits"] = bdev * cfg.vocab / tp * BF16
    out["total"] = sum(out.values())
    return out


def model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """MODEL_FLOPS = 6*N_active*D for train; 2*N_active*D forward-only for
    prefill; 2*N_active per token for decode (assignment convention)."""
    D = shape.seq_len * shape.global_batch
    Na = cfg.n_active_params()
    if shape.kind == "train":
        return 6.0 * Na * D
    if shape.kind == "prefill":
        return 2.0 * Na * D
    return 2.0 * Na * shape.global_batch   # one new token per sequence
