"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

MUST set XLA_FLAGS before any jax import (jax locks the device count on
first init) -- hence the first two lines.

For each cell this driver:
  1. builds the production mesh (16×16 single-pod / 2×16×16 multi-pod),
  2. constructs abstract params/opt/cache (ShapeDtypeStruct, no allocation),
  3. jit-lowers the step function with in/out shardings and compiles,
  4. records memory_analysis() (proves it fits), cost_analysis() (FLOPs,
     bytes) and the collective-transfer bytes parsed from the optimized HLO
     (all-gather / all-reduce / reduce-scatter / all-to-all /
     collective-permute operand sizes),
  5. appends a JSON line to --out (benchmarks/roofline.py consumes it).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch yi-6b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all --multi-pod both
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
if not os.environ.get("REPRO_DRYRUN_FULL_OPT"):
    # the dry-run needs lowering/partitioning/compilation to SUCCEED and the
    # compiled artifact to be analyzable; LLVM optimization effort on the CPU
    # stand-in backend is irrelevant to that and costs 2-3x compile time.
    os.environ["XLA_FLAGS"] += (" --xla_llvm_disable_expensive_passes=true"
                                " --xla_backend_optimization_level=0")

# ruff: noqa: E402
import argparse
import json
import re
import sys
import time
import traceback
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, applicable_shapes, get_config
from repro.distributed.sharding import (activation_constrainer,
                                        batch_shardings, cache_shardings,
                                        opt_state_shardings, param_shardings)
from repro.launch.mesh import make_production_mesh
from repro.launch.analytic import analytic_bytes, model_flops
from repro.launch.hlo_analysis import analyze as hlo_analyze
from repro.launch.steps import (abstract_cache, abstract_opt_state,
                                abstract_params, accum_steps, input_specs,
                                make_prefill_step, make_serve_step,
                                make_train_step)
from repro.models.config import SHAPES

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                  "collective-permute")


# --------------------------------------------------------- HLO text parsing --
_SHAPE_RE = re.compile(r"(f32|bf16|f16|s32|u32|s8|u8|pred|s64|u64|f64)"
                       r"\[([0-9,]*)\]")
_BYTES = {"f64": 8, "s64": 8, "u64": 8, "f32": 4, "s32": 4, "u32": 4,
          "bf16": 2, "f16": 2, "s8": 1, "u8": 1, "pred": 1}


def _first_shape_bytes(line: str) -> int:
    """Bytes of the op's result shape(s) -- for collectives the result size
    equals the transferred payload (per participating device)."""
    total = 0
    # result may be a tuple: take every shape before ' = ' ... simpler: take
    # all shapes on the LHS (before the op name) -- the '=' splits it.
    lhs = line.split("=")[0] if "=" in line else line
    for m in _SHAPE_RE.finditer(lhs):
        dtype, dims = m.group(1), m.group(2)
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _BYTES[dtype]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum result-shape bytes of every collective op in the optimized HLO.
    Ops inside while-loop bodies are multiplied by an estimated trip count
    when XLA annotates it; otherwise counted once (documented in
    EXPERIMENTS.md)."""
    out = {k: 0 for k in COLLECTIVE_OPS}
    out["count"] = 0
    for line in hlo_text.splitlines():
        ls = line.strip()
        for op in COLLECTIVE_OPS:
            # match op invocations like '%x = bf16[..] all-gather(...'
            if re.search(rf"= [a-z0-9\[\],() ]*{op}", ls) or \
               re.search(rf"{op}-start", ls):
                out[op] += _first_shape_bytes(ls)
                out["count"] += 1
                break
    return out


def _cost_get(cost: dict, key: str) -> float:
    return float(cost.get(key, 0.0) or 0.0)


# ------------------------------------------------------------------ lowering --
def build_cell(arch: str, shape_name: str, mesh, *, seq_shard: bool = True,
               use_pallas: bool = False, remat_policy: str = "nothing",
               accum_override=None, fsdp: bool = True,
               unroll_attn: bool = False):
    import dataclasses
    cfg = get_config(arch)
    if unroll_attn:
        cfg = dataclasses.replace(cfg, attn_unroll_q=True)
    shape = SHAPES[shape_name]
    cons = activation_constrainer(mesh, seq_shard=seq_shard and
                                  shape.kind != "decode")
    specs = input_specs(cfg, shape)
    params = abstract_params(cfg)
    pshard = param_shardings(mesh, params, fsdp=fsdp)
    bshard = batch_shardings(mesh, {k: v for k, v in specs.items()})

    n_data = 1
    for ax in ("pod", "data"):
        if ax in mesh.axis_names:
            n_data *= mesh.shape[ax]

    if shape.kind == "train":
        accum = accum_override or accum_steps(cfg, shape, n_data, seq_shard)
        # when params are DP-replicated, still accumulate grads SHARDED over
        # data (ZeRO grads): per-microbatch reduce-scatter, one reduction
        grad_sh = param_shardings(mesh, params, fsdp=True) if not fsdp \
            else None
        step = make_train_step(
            cfg, accum=accum, use_pallas=use_pallas,
            remat_policy=remat_policy, constrain=cons,
            accum_dtype=jnp.bfloat16 if cfg.n_params() > 2e11 else jnp.float32,
            grad_shardings=grad_sh)
        opt = abstract_opt_state(cfg, params)
        oshard = opt_state_shardings(mesh, opt, params)
        jitted = jax.jit(step,
                         in_shardings=(pshard, oshard, bshard),
                         out_shardings=(pshard, oshard, None))
        args = (params, opt, specs)
        meta = {"accum": accum}
    elif shape.kind == "prefill":
        step = make_prefill_step(cfg, use_pallas=use_pallas, constrain=cons)
        jitted = jax.jit(step, in_shardings=(pshard, bshard),
                         out_shardings=None)
        args = (params, specs)
        meta = {}
    else:
        step = make_serve_step(cfg, use_pallas=use_pallas, constrain=cons)
        cache = abstract_cache(cfg, shape)
        cshard = cache_shardings(mesh, cache)
        jitted = jax.jit(step, in_shardings=(pshard, cshard, bshard),
                         out_shardings=(None, cshard))
        args = (params, cache, specs)
        meta = {}
    return jitted, args, meta


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             **kw) -> Dict[str, Any]:
    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    rec: Dict[str, Any] = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_chips": 512 if multi_pod else 256,
        "opts": {k: v for k, v in kw.items()},
    }
    try:
        with mesh:
            jitted, args, meta = build_cell(arch, shape_name, mesh, **kw)
            lowered = jitted.lower(*args)
            compiled = lowered.compile()
            mem = compiled.memory_analysis()
            cost = compiled.cost_analysis()
            hlo = compiled.as_text()
        rec.update(meta)
        rec["ok"] = True
        rec["compile_s"] = round(time.time() - t0, 1)
        # raw XLA numbers (per device, while-bodies counted ONCE -- kept for
        # reference; see EXPERIMENTS.md §Dry-run for the discrepancy note)
        rec["xla_flops_raw"] = _cost_get(cost, "flops")
        rec["xla_bytes_raw"] = _cost_get(cost, "bytes accessed")
        # trip-count-aware per-device costs (the §Roofline source of truth)
        costs = hlo_analyze(hlo)
        rec["flops_per_device"] = costs.flops
        rec["bytes_per_device"] = costs.bytes
        rec["collective_bytes_per_device"] = costs.coll_bytes
        rec["collective_count"] = costs.coll_count
        rec["memory"] = {
            "argument_size": getattr(mem, "argument_size_in_bytes", 0),
            "output_size": getattr(mem, "output_size_in_bytes", 0),
            "temp_size": getattr(mem, "temp_size_in_bytes", 0),
            "generated_code_size": getattr(mem, "generated_code_size_in_bytes", 0),
        }
        cfg = get_config(arch)
        rec["n_params"] = cfg.n_params()
        rec["n_active_params"] = cfg.n_active_params()
        rec["model_flops"] = model_flops(cfg, SHAPES[shape_name])
        rec["analytic_bytes_per_device"] = analytic_bytes(
            cfg, SHAPES[shape_name], rec["n_chips"],
            accum=rec.get("accum", 1))
    except Exception as e:   # noqa: BLE001 -- report, don't die mid-sweep
        rec["ok"] = False
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
    return rec


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--multi-pod", choices=["no", "yes", "both"], default="no")
    ap.add_argument("--out", default="benchmarks/dryrun_results.jsonl")
    ap.add_argument("--no-seq-shard", action="store_true")
    ap.add_argument("--use-pallas", action="store_true")
    ap.add_argument("--remat", default="nothing")
    ap.add_argument("--accum", type=int, default=None)
    ap.add_argument("--no-fsdp", action="store_true",
                    help="replicate params over the data axis (DP)")
    ap.add_argument("--unroll-attn", action="store_true")
    args = ap.parse_args()

    # smallest-first so the roofline table fills up front under a time budget
    archs = sorted(ARCHS, key=lambda a: ARCHS[a].n_params()) \
        if args.arch == "all" else [args.arch]
    pods = {"no": [False], "yes": [True], "both": [False, True]}[args.multi_pod]
    failures = 0
    with open(args.out, "a") as f:
        for arch in archs:
            shapes = ([s.name for s in applicable_shapes(arch)]
                      if args.shape == "all" else [args.shape])
            for shape in shapes:
                for mp in pods:
                    rec = run_cell(arch, shape, mp,
                                   seq_shard=not args.no_seq_shard,
                                   use_pallas=args.use_pallas,
                                   remat_policy=args.remat,
                                   accum_override=args.accum,
                                   fsdp=not args.no_fsdp,
                                   unroll_attn=args.unroll_attn)
                    tb = rec.pop("traceback", None)
                    line = json.dumps(rec)
                    f.write(line + "\n")
                    f.flush()
                    status = "OK " if rec["ok"] else "FAIL"
                    print(f"[{status}] {arch} × {shape} × {rec['mesh']} "
                          f"({rec.get('compile_s', '-')}s)", flush=True)
                    if not rec["ok"]:
                        failures += 1
                        print(rec["error"], flush=True)
                        if tb:
                            print(tb, flush=True)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
