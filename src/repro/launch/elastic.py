"""Elastic scaling + straggler mitigation planning (pure logic, fully
testable without hardware).

``plan_remesh`` decides the new (pod, data, model) factorization when hosts
fail, preferring to shrink the data axis (cheapest resharding: optimizer
shards re-gather along data only; TP layout untouched).  ``ReshardPlan``
spells out which collective moves what -- the launcher executes it with a
checkpoint-restore into the new mesh (parameters are layout-portable because
checkpoints store unsharded logical tensors per shard group).

``StragglerPolicy`` implements deadline-based gradient skipping: a step's
all-reduce proceeds with the contributions that arrived by the deadline and
rescales by the participation fraction (bounded staleness, standard at
1000-node scale); hosts that miss repeatedly are evicted -> plan_remesh.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple


@dataclasses.dataclass
class ReshardPlan:
    old_mesh: Tuple[int, ...]
    new_mesh: Tuple[int, ...]
    axis_names: Tuple[str, ...]
    moves: List[str]
    restart_from_checkpoint: bool


def factorize_mesh(n_chips: int, model_parallel: int,
                   chips_per_pod: int = 256) -> Optional[Tuple[int, int, int]]:
    """(pods, data, model) for n_chips, keeping TP intact."""
    if n_chips % model_parallel:
        return None
    rest = n_chips // model_parallel
    pods = max(1, n_chips // chips_per_pod)
    while pods > 1 and rest % pods:
        pods -= 1
    data = rest // pods
    if data < 1:
        return None
    return (pods, data, model_parallel)


def plan_remesh(n_healthy: int, old: Tuple[int, int, int],
                chips_per_host: int = 4) -> ReshardPlan:
    """Choose the largest usable mesh after failures.

    TP ('model') is pinned (changing it would re-layout every weight);
    the data axis absorbs the loss; pods collapse when a whole pod is gone.
    """
    pods_o, data_o, model_o = old
    usable = (n_healthy * chips_per_host // model_o) * model_o
    best = None
    for pods in range(pods_o, 0, -1):
        per_pod = usable // pods
        data = per_pod // model_o
        if data >= 1:
            best = (pods, data, model_o)
            break
    assert best is not None, "not enough healthy chips for one TP group"
    moves = []
    if best[1] != data_o:
        moves.append(
            f"re-partition optimizer state (ZeRO shards): data {data_o} -> "
            f"{best[1]} (all-gather m/v along old data axis, re-scatter)")
        moves.append("rebalance data-queue cursors: max() over worker "
                     "cursors stays valid (paper §6.1 recovery rule)")
    if best[0] != pods_o:
        moves.append(f"pod replicas {pods_o} -> {best[0]}: drop pod-axis "
                     "gradient all-reduce groups; no tensor movement")
    return ReshardPlan(old_mesh=old, new_mesh=best,
                       axis_names=("pod", "data", "model"), moves=moves,
                       restart_from_checkpoint=True)


@dataclasses.dataclass
class StragglerPolicy:
    deadline_ms: float = 500.0
    min_participation: float = 0.75
    evict_after_misses: int = 3

    def step_outcome(self, arrival_ms: List[float]) -> dict:
        """Given per-host gradient arrival times, decide the step."""
        on_time = [t for t in arrival_ms if t <= self.deadline_ms]
        frac = len(on_time) / max(len(arrival_ms), 1)
        if frac >= self.min_participation:
            return {"action": "proceed", "participation": frac,
                    "grad_scale": 1.0 / max(frac, 1e-6)}
        return {"action": "wait_full", "participation": frac,
                "grad_scale": 1.0}

    def track_misses(self, miss_counts: dict, arrival_ms: dict) -> List[str]:
        evict = []
        for host, t in arrival_ms.items():
            if t > self.deadline_ms:
                miss_counts[host] = miss_counts.get(host, 0) + 1
                if miss_counts[host] >= self.evict_after_misses:
                    evict.append(host)
            else:
                miss_counts[host] = 0
        return evict
