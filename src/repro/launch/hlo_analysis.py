"""Trip-count-aware cost analysis of compiled (optimized) HLO text.

XLA's ``compiled.cost_analysis()`` counts while-loop bodies ONCE, which
under-reports a scanned-layer transformer by orders of magnitude (verified
experimentally -- see EXPERIMENTS.md §Dry-run).  The optimized HLO, however,
annotates every loop with ``backend_config={"known_trip_count":{"n":...}}``.

This module parses the HLO text into computations, walks the call graph
(fusion ``calls=``, while ``body=/condition=``, conditional branches) and
multiplies dot-FLOPs, approximate HBM bytes and collective payload bytes by
the loop trip counts.  All values are PER DEVICE (shapes in partitioned HLO
are per-shard).

It is deliberately an *executed-cost* model: masked/wasted compute (e.g.
fully-masked attention blocks the chunked scan still multiplies) is counted,
which is exactly what the MODEL_FLOPS/HLO_FLOPS ratio in §Roofline is meant
to expose.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_BYTES = {"f64": 8, "s64": 8, "u64": 8, "f32": 4, "s32": 4, "u32": 4,
          "bf16": 2, "f16": 2, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
          "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1, "token": 0, "opaque": 0,
          "s4": 1, "u4": 1}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\((.*?)\)\s*->")
_OP_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*)$")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALLS_RE = re.compile(r"calls=%?([\w.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_LHS_C_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_LHS_B_RE = re.compile(r"lhs_batch_dims=\{([0-9,]*)\}")

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_SKIP_OPS = {"parameter", "constant", "get-tuple-element", "tuple", "bitcast",
             "after-all", "add-dependency", "iota", "partition-id",
             "replica-id", "bitcast-convert"}


def _shape_bytes(type_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _BYTES[dt]
    return total


def _shape_dims(type_str: str) -> List[int]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


def _split_type_op(rhs: str) -> Tuple[str, str, str]:
    """rhs like 'bf16[8,128]{1,0} dot(%a, %b), ...' or
    '(f32[2]{0}, s32[]) while(%t), ...' -> (type_str, opcode, rest)."""
    if rhs.startswith("("):
        depth = 0
        for i, ch in enumerate(rhs):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    break
        type_str = rhs[:i + 1]
        rest = rhs[i + 1:].lstrip()
    else:
        sp = rhs.find(" ")
        type_str = rhs[:sp]
        rest = rhs[sp + 1:]
    m = re.match(r"([a-z][\w\-]*)\(", rest)
    opcode = m.group(1) if m else ""
    return type_str, opcode, rest


@dataclass
class OpInfo:
    name: str
    opcode: str
    type_str: str
    line: str


@dataclass
class Computation:
    name: str
    ops: List[OpInfo] = field(default_factory=list)
    symbols: Dict[str, str] = field(default_factory=dict)   # op -> type_str


@dataclass
class Costs:
    flops: float = 0.0
    bytes: float = 0.0
    coll_bytes: Dict[str, float] = field(default_factory=dict)
    coll_count: float = 0.0

    def add(self, other: "Costs", mult: float = 1.0) -> None:
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        self.coll_count += other.coll_count * mult
        for k, v in other.coll_bytes.items():
            self.coll_bytes[k] = self.coll_bytes.get(k, 0.0) + v * mult

    def total_collective_bytes(self) -> float:
        return sum(self.coll_bytes.values())


def parse_computations(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for raw in text.splitlines():
        line = raw.rstrip()
        if cur is None:
            m = _COMP_HDR.match(line.strip())
            if m and line.rstrip().endswith("{"):
                cur = Computation(m.group(1))
                # parameters appear in the header with types
                for pm in re.finditer(r"%?([\w.\-]+):\s*([a-z0-9]+\[[0-9,]*\]"
                                      r"(?:\{[^}]*\})?)", m.group(2)):
                    cur.symbols[pm.group(1)] = pm.group(2)
            continue
        if line.strip() == "}":
            comps[cur.name] = cur
            cur = None
            continue
        m = _OP_RE.match(line)
        if not m:
            continue
        name, rhs = m.group(1), m.group(2)
        try:
            type_str, opcode, _ = _split_type_op(rhs)
        except Exception:   # noqa: BLE001
            continue
        cur.symbols[name] = type_str
        cur.ops.append(OpInfo(name, opcode, type_str, line))
    return comps


def _dot_flops(op: OpInfo, comp: Computation) -> float:
    out_dims = _shape_dims(op.type_str)
    n_out = 1
    for d in out_dims:
        n_out *= d
    # lhs operand: first %name inside dot(...).  Newer XLA prints operand
    # types inline ("dot(f32[256,256] %a, ...)"), so take the first %-token
    # rather than the first word after the paren; the inline type is also a
    # fallback source for the lhs dims when the symbol table misses.
    contract = 1
    lhs_dims: List[int] = []
    call = re.search(r"\bdot\((.*?)\)", op.line)
    if call:
        args = call.group(1)
        nm = re.search(r"%([\w.\-]+)", args)
        if nm:
            lhs_dims = _shape_dims(comp.symbols.get(nm.group(1), ""))
        if not lhs_dims:
            # first inline shape in the operand list is the lhs type
            lhs_dims = _shape_dims(args)
    cm = _LHS_C_RE.search(op.line)
    if cm and lhs_dims:
        for ci in cm.group(1).split(","):
            if ci and int(ci) < len(lhs_dims):
                contract *= lhs_dims[int(ci)]
    return 2.0 * n_out * contract


def _operands(op: OpInfo) -> List[str]:
    call = re.search(r"\b[a-z][\w\-]*\((.*?)\)", op.line)
    if not call:
        return []
    return [a.group(1) for a in re.finditer(r"%([\w.\-]+)", call.group(1))]


_SLICING = ("dynamic-slice", "slice", "gather", "dynamic-update-slice")


def _operand_bytes(op: OpInfo, comp: Computation) -> float:
    """Approximate HBM traffic of a top-level op: result + operands, with
    slicing ops charged for the transferred window, not the whole buffer."""
    res = float(_shape_bytes(op.type_str))
    if op.opcode in ("dynamic-slice", "slice", "gather"):
        return 2.0 * res     # read window + write result
    if op.opcode == "dynamic-update-slice":
        ops_ = _operands(op)
        upd = _shape_bytes(comp.symbols.get(ops_[1], "")) if len(ops_) > 1 else 0
        return 2.0 * upd     # read + write the updated window (aliased buf)
    total = res
    for a in _operands(op):
        total += _shape_bytes(comp.symbols.get(a, ""))
    return total


def _fusion_bytes(op: OpInfo, comp: Computation,
                  comps: Dict[str, "Computation"]) -> float:
    """HBM traffic of a fusion: root write + parameter reads, where a
    parameter consumed ONLY through slicing ops is charged per-window, and a
    dynamic-update-slice root is charged for the written window (the output
    buffer is aliased in place)."""
    cm = _CALLS_RE.search(op.line)
    fcomp = comps.get(cm.group(1)) if cm else None
    total = float(_shape_bytes(op.type_str))
    if fcomp is not None and fcomp.ops:
        root = fcomp.ops[-1]
        if root.opcode == "dynamic-update-slice":
            ops_ = _operands(root)
            if len(ops_) > 1:
                total = float(_shape_bytes(fcomp.symbols.get(ops_[1], "")))
    args = _operands(op)
    if fcomp is None:
        for a in args:
            total += _shape_bytes(comp.symbols.get(a, ""))
        return total
    # map parameter index -> internal name
    params: Dict[int, str] = {}
    for fop in fcomp.ops:
        pm = re.search(r"parameter\((\d+)\)", fop.line)
        if pm and fop.opcode == "parameter":
            params[int(pm.group(1))] = fop.name
    for i, a in enumerate(args):
        full = _shape_bytes(comp.symbols.get(a, ""))
        pname = params.get(i)
        if pname is None:
            total += full
            continue
        uses = [fop for fop in fcomp.ops
                if re.search(r"%" + re.escape(pname) + r"\b", fop.line)
                and fop.name != pname]
        if uses and all(u.opcode in _SLICING for u in uses):
            window = sum(
                _shape_bytes(u.type_str) if u.opcode != "dynamic-update-slice"
                else 2 * _shape_bytes(fcomp.symbols.get(_operands(u)[1], ""))
                for u in uses)
            total += min(window, full)
        else:
            total += full
    return total


def analyze(text: str) -> Costs:
    comps = parse_computations(text)
    memo: Dict[str, Costs] = {}
    flops_memo: Dict[str, float] = {}

    entry_m = re.search(r"^ENTRY\s+%?([\w.\-]+)", text, re.M)
    entry = entry_m.group(1) if entry_m else next(iter(comps))

    def flops_of(name: str) -> float:
        """dot-FLOPs of a computation including nested fusion calls -- used
        for fusion bodies, whose internals stay in registers (no bytes)."""
        if name in flops_memo:
            return flops_memo[name]
        flops_memo[name] = 0.0
        comp = comps.get(name)
        if comp is None:
            return 0.0
        f = 0.0
        for op in comp.ops:
            if op.opcode == "dot":
                f += _dot_flops(op, comp)
            elif op.opcode in ("fusion", "call"):
                cm = _CALLS_RE.search(op.line)
                if cm:
                    f += flops_of(cm.group(1))
        flops_memo[name] = f
        return f

    def walk(name: str) -> Costs:
        if name in memo:
            return memo[name]
        memo[name] = Costs()     # break cycles defensively
        comp = comps.get(name)
        if comp is None:
            return memo[name]
        c = Costs()
        for op in comp.ops:
            if op.opcode in _SKIP_OPS:
                continue
            if op.opcode == "while":
                tm = _TRIP_RE.search(op.line)
                trip = int(tm.group(1)) if tm else 1
                bm = _BODY_RE.search(op.line)
                cm = _COND_RE.search(op.line)
                if bm:
                    c.add(walk(bm.group(1)), trip)
                if cm:
                    c.add(walk(cm.group(1)), trip + 1)
                continue
            if op.opcode == "conditional":
                brm = _BRANCHES_RE.search(op.line)
                if brm:
                    branch_costs = [walk(b.strip().lstrip("%"))
                                    for b in brm.group(1).split(",") if b.strip()]
                    if branch_costs:
                        best = max(branch_costs, key=lambda x: x.flops + x.bytes)
                        c.add(best)
                continue
            is_coll = None
            for coll in COLLECTIVES:
                if op.opcode.startswith(coll):
                    is_coll = coll
                    break
            if is_coll and not op.opcode.endswith("-done"):
                payload = _shape_bytes(op.type_str)
                c.coll_bytes[is_coll] = c.coll_bytes.get(is_coll, 0.0) + payload
                c.coll_count += 1
                c.bytes += payload
                continue
            if op.opcode == "dot":
                c.flops += _dot_flops(op, comp)
                c.bytes += _operand_bytes(op, comp)
                continue
            if op.opcode == "fusion":
                cm = _CALLS_RE.search(op.line)
                if cm:
                    c.flops += flops_of(cm.group(1))
                c.bytes += _fusion_bytes(op, comp, comps)
                continue
            if op.opcode in ("call", "async-start"):
                cm = _CALLS_RE.search(op.line)
                if cm:
                    c.add(walk(cm.group(1)))
                continue
            # reduce/map/sort appliers are per-element micro-computations;
            # their flops are negligible next to dots -- count bytes only.
            c.bytes += _operand_bytes(op, comp)
        memo[name] = c
        return c

    return walk(entry)


def analyze_compiled(compiled) -> Costs:
    return analyze(compiled.as_text())
