"""Exhaustive crash sweep: durable linearizability at EVERY boundary.

For one queue, :func:`sweep_queue`:

1. captures a standard exact-scheduler run once (:mod:`repro.crash.capture`);
2. for every crash step ``1..total`` applies the adversarial crash modes --
   ``min`` / ``random`` / ``max`` (paper §2 failure model) plus the
   ``subset`` mode, which *enumerates* every combination of surviving
   pending flushes, NT-store prefixes and per-line store-log prefixes
   whenever that outcome space is small enough (``subset_cap``);
3. runs the queue's recovery from each crashed image, drains it, and checks
   the result against the pre-crash history with
   :func:`repro.core.check_durable_linearizability`;
4. classifies each boundary (persist-adjacent vs interior; see
   :data:`repro.crash.capture.PERSIST_KINDS`) and tallies coverage, plus a
   recovery-work axis (persistent reads/writes + wall time per recovery).

Every violation becomes a one-command repro artifact
(:mod:`repro.crash.artifact`; ``python -m repro.crash repro <file>``).
"""
from __future__ import annotations

import itertools
import time
from dataclasses import dataclass, field
from typing import Dict, Iterator, List, Optional, Tuple

from repro.core import (DURABLE_QUEUES, CrashChoices, QueueHarness,
                        check_durable_linearizability)
from repro.core.nvram import LINE_WORDS

from .capture import Boundary, Capture, capture_run

#: the three sampled adversarial modes (the paper's failure model corners
#: plus a seeded draw); `subset` is driven separately by its outcome space
DEFAULT_MODES = ("min", "random", "max")


def standard_plans(nthreads: int = 3, per_thread: int = 6,
                   tag=None) -> List[list]:
    """The standard crash workload (same shape as tests/test_crash_recovery):
    each thread enqueues `per_thread` items, dequeuing after every odd one."""
    plans = []
    for t in range(nthreads):
        p = []
        for i in range(per_thread):
            item = (t, i) if tag is None else (tag, t, i)
            p.append(("enq", item))
            if i % 2 == 1:
                p.append(("deq", None))
        plans.append(p)
    return plans


@dataclass
class ChoiceSpace:
    """The adversarial outcome space at one boundary (from its snapshot).

    ``combos`` counts what the subset mode enumerates: every subset of the
    pending flush entries x every per-(thread, line) NT-store prefix --
    the *persist decisions*, where durability bugs hide -- crossed with
    the implicit-eviction axis.  By default that axis contributes only its
    two corners (no unapplied store survives / every line's full log
    survives): the interior per-line eviction prefixes form a product that
    is too large to enumerate across a whole sweep and is sampled by the
    'random' mode instead.  With ``exhaustive_log=True`` the axis is the
    full per-line prefix product -- every line independently persists any
    prefix of its unapplied stores -- which small cells (few threads, tiny
    designated areas) can afford to exhaust completely.
    """
    flush_entries: List[Tuple[int, int]]          # (tid, pending index)
    nt_groups: Dict[Tuple[int, int], int]         # (tid, line) -> #NT stores
    log_lines: Dict[int, int]                     # line -> #unapplied stores
    exhaustive_log: bool = False
    combos: int = 1

    def __post_init__(self):
        n = 2 ** len(self.flush_entries)
        for c in self.nt_groups.values():
            n *= c + 1
        if self.log_lines:
            if self.exhaustive_log:
                for c in self.log_lines.values():
                    n *= c + 1
            else:
                n *= 2
        self.combos = n


def choice_space(boundary: Boundary,
                 exhaustive_log: bool = False) -> ChoiceSpace:
    """Enumerate the crash-outcome axes recorded in a boundary snapshot."""
    snap = boundary.snap
    flush_entries: List[Tuple[int, int]] = []
    nt_groups: Dict[Tuple[int, int], int] = {}
    for t, plist in sorted(snap.pending.items()):
        for i, ent in enumerate(plist):
            if ent[0] == "flush":
                flush_entries.append((t, i))
            else:
                key = (t, ent[1] // LINE_WORDS)
                nt_groups[key] = nt_groups.get(key, 0) + 1
    log_lines = {line: len(log) for line, log in snap.log.items() if log}
    return ChoiceSpace(flush_entries, nt_groups, log_lines, exhaustive_log)


def _log_choices(space: ChoiceSpace) -> List[tuple]:
    """The implicit-eviction axis: per-line applied-store prefixes.

    Corners mode yields the empty and the full prefix; exhaustive mode
    yields the whole product (every line independently keeps 0..n of its
    unapplied stores, in store order -- Assumption 1 eviction atomicity).
    ``k == 0`` entries are dropped: an absent line already means 'nothing
    survives', so keeping them would double-count outcomes.
    """
    if not space.log_lines:
        return [()]
    lines = sorted(space.log_lines)
    if not space.exhaustive_log:
        return [(), tuple((ln, space.log_lines[ln]) for ln in lines)]
    return [tuple((ln, k) for ln, k in zip(lines, ks) if k)
            for ks in itertools.product(
                *[range(space.log_lines[ln] + 1) for ln in lines])]


def enumerate_choices(space: ChoiceSpace) -> Iterator[CrashChoices]:
    """All crash outcomes of `space` (see :class:`ChoiceSpace` for what
    'all' means), as CrashChoices for mode='subset'."""
    nt_keys = sorted(space.nt_groups)
    log_choices = _log_choices(space)
    for bits in itertools.product((False, True),
                                  repeat=len(space.flush_entries)):
        survivors = frozenset(e for e, keep in zip(space.flush_entries, bits)
                              if keep)
        for nt_ks in itertools.product(
                *[range(space.nt_groups[k] + 1) for k in nt_keys]):
            for log_prefix in log_choices:
                yield CrashChoices(
                    flush_survivors=survivors,
                    nt_prefix=tuple(zip(nt_keys, nt_ks)),
                    log_prefix=log_prefix)


@dataclass
class SweepResult:
    queue: str
    seed: int
    nthreads: int
    per_thread: int
    model: str
    total_steps: int
    rows: List[dict] = field(default_factory=list)
    failures: List[dict] = field(default_factory=list)   # repro artifacts
    wall_s: float = 0.0

    def coverage(self) -> dict:
        """Coverage summary: which boundaries were exercised and how."""
        steps = {r["crash_step"] for r in self.rows}
        persist = {r["crash_step"] for r in self.rows
                   if r["boundary"] == "persist-adjacent"}
        subset_rows = [r for r in self.rows if r["mode"] == "subset"]
        checks = sum((r["subset_combos"] if r["mode"] == "subset" else 1)
                     for r in self.rows)
        rec_us = sum(r["recovery_us"] for r in self.rows)
        return {
            "boundaries": len(steps),
            "persist_adjacent": len(persist),
            "interior": len(steps) - len(persist),
            "subset_enumerated": sum(1 for r in subset_rows
                                     if r["subset_combos"]),
            "subset_skipped": sum(1 for r in subset_rows
                                  if not r["subset_combos"]),
            "crashes_checked": checks,
            "recovery_us_total": rec_us,
            "failures": len(self.failures),
        }


class _NullProfiler:
    """No-op phase profiler so the sweep's inner loop has one shape."""

    def push(self, name):
        pass

    def pop(self):
        pass


_NULL_PROF = _NullProfiler()


def _check_point(harness: QueueHarness, capture: Capture, step: int,
                 mode: str, crash_seed: int,
                 choices: Optional[CrashChoices] = None, prof=_NULL_PROF):
    """Restore boundary `step`, crash with `mode`, recover, drain, check.
    Returns (ok, why, recovered, preads, pwrites, wall_us)."""
    b = capture.boundaries[step]
    nv = harness.nvram
    prof.push("restore")
    nv.restore(b.snap)
    # the checker reads the Capture's frozen history, not the live record
    # state; truncate it so ~thousands of recoveries don't accumulate dead
    # crash-marker/drain events.  Clearing is the cursor restore's
    # degenerate case (record_restore((0, 0))): record cursors only shrink,
    # and the sweep walks steps forward, so rewinding to b.rec_snap after an
    # earlier step already truncated below it would be invalid.  Both record
    # modes clear in place -- the columnar store resets its cursors, the
    # legacy lists empty without rebinding (the queue's on_event stays bound
    # to the same ops/events objects either way).
    del harness.events[:]
    del harness.ops[:]
    p0, w0 = nv.pread_count, nv.pwrite_count
    prof.pop()
    prof.push("recover")
    t0 = time.perf_counter()
    harness.crash_and_recover(mode=mode, seed=crash_seed, choices=choices)
    recovered = harness.queue.drain(0)
    wall_us = (time.perf_counter() - t0) * 1e6
    prof.pop()
    prof.push("check")
    ok, why = check_durable_linearizability(
        capture.pre_crash_ops(step), capture.pre_crash_events(step),
        recovered)
    prof.pop()
    return (ok, why, recovered,
            nv.pread_count - p0, nv.pwrite_count - w0, wall_us)


def sweep_queue(name: str, nthreads: int = 3, per_thread: int = 6,
                seed: int = 3, policy: str = "random",
                model: str = "optane-clwb", area_nodes: int = 64,
                modes: Tuple[str, ...] = DEFAULT_MODES, subset: bool = True,
                subset_cap: int = 64, steps: Optional[range] = None,
                exhaustive_log: bool = False, log=None,
                profile=None) -> SweepResult:
    """Sweep every crash point of the standard workload for one queue.

    ``subset_cap`` bounds the per-boundary exhaustive enumeration: when a
    boundary's outcome space is larger (e.g. mid allocator-area zeroing,
    with hundreds of pending flushes) the subset row records
    ``subset_combos=0`` and the boundary is still covered by the three
    sampled modes.  ``steps`` restricts the crash points (default: all of
    ``1..total_steps``).  ``exhaustive_log=True`` widens the subset mode's
    implicit-eviction axis from the two corners to every interior per-line
    store-prefix (see :class:`ChoiceSpace`); affordable only on small
    cells -- pair it with a tiny workload and ``area_nodes`` small enough
    that mid-area-zeroing boundaries fit under ``subset_cap``.

    ``profile`` attaches an observation-only phase profiler (phases:
    ``capture`` -- the hooked exact run, then per crash point
    ``restore``/``recover``/``check``); rows and Stats are unchanged.
    """
    if name not in DURABLE_QUEUES:
        raise ValueError(f"unknown durable queue {name!r} "
                         f"(have {sorted(DURABLE_QUEUES)})")
    prof = profile if profile is not None else _NULL_PROF
    t_start = time.perf_counter()
    prof.push("capture")
    harness = QueueHarness(DURABLE_QUEUES[name], nthreads=nthreads,
                           area_nodes=area_nodes, model=model)
    plans = standard_plans(nthreads, per_thread)
    capture = capture_run(harness, plans, seed=seed, policy=policy)
    prof.pop()
    result = SweepResult(queue=name, seed=seed, nthreads=nthreads,
                         per_thread=per_thread, model=model,
                         total_steps=capture.total_steps)
    sweep_steps = steps if steps is not None \
        else range(1, capture.total_steps + 1)

    def base_row(step: int, space: ChoiceSpace) -> dict:
        return {
            "queue": name, "seed": seed, "nthreads": nthreads,
            "per_thread": per_thread, "model": model, "crash_step": step,
            "boundary": capture.boundary_class(step),
            "prim_before": capture.kinds[step - 1] if step >= 1 else "",
            "prim_after": (capture.kinds[step]
                           if step < capture.total_steps else ""),
            "pending_flush": len(space.flush_entries),
            "pending_nt": sum(space.nt_groups.values()),
            "log_words": sum(space.log_lines.values()),
        }

    def record_failure(row: dict, why: str, recovered: list,
                       choices: Optional[CrashChoices]) -> None:
        from .artifact import failure_artifact
        result.failures.append(failure_artifact(
            capture=capture, crash_step=row["crash_step"], mode=row["mode"],
            crash_seed=seed, choices=choices, why=why, recovered=recovered))
        if log:
            log(f"FAIL {name} step={row['crash_step']} mode={row['mode']}: "
                f"{why}")

    for step in sweep_steps:
        b = capture.boundaries[step]
        space = choice_space(b, exhaustive_log=exhaustive_log)
        for mode in modes:
            row = base_row(step, space)
            ok, why, recovered, pr, pw, us = _check_point(
                harness, capture, step, mode, crash_seed=seed, prof=prof)
            row.update(mode=mode, subset_combos=None, ok=ok,
                       recovered_len=len(recovered), recovery_preads=pr,
                       recovery_pwrites=pw, recovery_us=us)
            result.rows.append(row)
            if not ok:
                record_failure(row, why, recovered, None)
        if subset:
            row = base_row(step, space)
            row.update(mode="subset", subset_combos=0, ok=True,
                       recovered_len=0, recovery_preads=0,
                       recovery_pwrites=0, recovery_us=0.0)
            if space.combos <= subset_cap:
                for choices in enumerate_choices(space):
                    ok, why, recovered, pr, pw, us = _check_point(
                        harness, capture, step, "subset", crash_seed=seed,
                        choices=choices, prof=prof)
                    row["subset_combos"] += 1
                    row["recovered_len"] = max(row["recovered_len"],
                                               len(recovered))
                    row["recovery_preads"] += pr
                    row["recovery_pwrites"] += pw
                    row["recovery_us"] += us
                    if not ok:
                        row["ok"] = False
                        record_failure(row, why, recovered, choices)
            result.rows.append(row)
    result.wall_s = time.perf_counter() - t_start
    return result


def sweep_queues(names: List[str], log=None, **kwargs) -> List[SweepResult]:
    """Sweep several queues; kwargs are forwarded to :func:`sweep_queue`."""
    out = []
    for name in names:
        r = sweep_queue(name, log=log, **kwargs)
        if log:
            cov = r.coverage()
            log(f"{name}: {cov['boundaries']} boundaries "
                f"({cov['persist_adjacent']} persist-adjacent), "
                f"{cov['crashes_checked']} crashes checked, "
                f"{cov['failures']} failures, {r.wall_s:.1f}s")
        out.append(r)
    return out
