"""Snapshot capture: ONE exact-scheduler run, every crash point preserved.

The classic way to test a crash point is to rerun the whole schedule from
scratch with ``crash_at=s`` -- ~milliseconds per primitive on the OS-thread
scheduler, so checking *every* boundary of even a small workload costs
hours.  This module replaces that with a single hooked run:

* the exact :class:`repro.core.Scheduler` calls ``snapshot_hook(s)`` at
  every quiescent boundary (all live threads parked at yield points, ``s``
  primitives fully executed);
* at each boundary we take an :class:`repro.core.nvram.EngineSnapshot`
  (crash-sufficient by default: persistent image + store logs + pending
  persist sets) and record the harness-side history cursor (how many ops
  exist, which completed, how many linearization events happened);
* because the scheduler is seed-deterministic, the first ``s`` primitives
  of a ``crash_at=s`` rerun are *identical* to the hooked run's prefix --
  so restoring boundary ``s``'s snapshot and crashing reproduces the rerun
  exactly (asserted by ``tests/test_crash_sweep.py``).

The recorded op/event cursors let :meth:`Capture.pre_crash_ops` and
:meth:`Capture.pre_crash_events` rebuild the pre-crash history that the
durable-linearizability checker needs, without rerunning anything.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, List, Tuple

from repro.core.harness import OpRecord, QueueHarness

#: scheduler primitive kinds whose adjacency makes a boundary
#: "persist-adjacent" (the crash sweep's coverage classification):
#: boundaries right before/after explicit persist work are where
#: crash-recovery bugs hide (NVTraverse; Zuriel et al.).
PERSIST_KINDS = frozenset({"flush", "fence", "movnti"})


@dataclass
class Boundary:
    """State at one crash point: after `step` primitives executed."""
    step: int
    snap: Any                      # EngineSnapshot (crash-sufficient)
    ops_len: int                   # harness.ops existing at this boundary
    events_len: int                # linearization events so far
    completed: Tuple[bool, ...]    # per existing op: returned before crash?
    items: Tuple[Any, ...]         # per existing op: item (deq result if done)
    #: record-history cursors (QueueHarness.record_snapshot) taken at the
    #: same quiescent instant as `snap` -- restoring both rewinds the engine
    #: AND the op/event history to this boundary together
    rec_snap: Any = None


@dataclass
class Capture:
    """A full run plus everything needed to crash it anywhere."""
    queue_name: str
    nthreads: int
    seed: int
    policy: str
    model: str
    area_nodes: int
    plans: List[list]
    total_steps: int
    kinds: List[str]               # kinds[i] = primitive i+1's kind
    boundaries: List[Boundary]     # index s -> boundary after s primitives
    ops: List[OpRecord] = field(default_factory=list)    # final (crash-free)
    events: List[tuple] = field(default_factory=list)    # frozen event log

    def pre_crash_ops(self, step: int) -> List[OpRecord]:
        """The op history a crash_at=`step` run would have produced."""
        b = self.boundaries[step]
        return [OpRecord(tid=self.ops[i].tid, kind=self.ops[i].kind,
                         item=b.items[i], completed=b.completed[i])
                for i in range(b.ops_len)]

    def pre_crash_events(self, step: int) -> List[tuple]:
        """The linearization-event prefix visible at crash point `step`."""
        return self.events[:self.boundaries[step].events_len]

    def boundary_class(self, step: int) -> str:
        """'persist-adjacent' if the primitive just executed or the next
        one due is persist work (flush/fence/movnti), else 'interior'."""
        before = self.kinds[step - 1] if step >= 1 else None
        after = self.kinds[step] if step < self.total_steps else None
        return ("persist-adjacent"
                if before in PERSIST_KINDS or after in PERSIST_KINDS
                else "interior")


def capture_run(harness: QueueHarness, plans: List[list], seed: int = 0,
                policy: str = "random",
                volatile_snapshots: bool = False) -> Capture:
    """Run `plans` to completion on `harness`'s exact scheduler, capturing
    a boundary record at every step.  Returns the :class:`Capture`; the
    harness is left in its end-of-run state (sweeps restore over it).

    ``volatile_snapshots=True`` captures full snapshots (volatile state
    included) -- only needed when a restored boundary is *resumed* rather
    than crashed; the sweep never needs it.
    """
    nv = harness.nvram
    boundaries: List[Boundary] = []

    def hook(step: int) -> None:
        boundaries.append(Boundary(
            step=step,
            snap=nv.snapshot(volatile=volatile_snapshots),
            ops_len=len(harness.ops),
            events_len=len(harness.events),
            completed=tuple(r.completed for r in harness.ops),
            items=tuple(r.item for r in harness.ops),
            rec_snap=harness.record_snapshot()))

    res = harness.run_scheduled([list(p) for p in plans], seed=seed,
                                policy=policy, snapshot_hook=hook)
    sched = harness.last_scheduler
    assert not res.crashed, "capture runs must be crash-free"
    assert len(boundaries) == sched.steps + 1, \
        f"expected {sched.steps + 1} boundaries, got {len(boundaries)}"
    return Capture(
        queue_name=harness.queue_cls.NAME, nthreads=len(plans), seed=seed,
        policy=policy, model=nv.model.name, area_nodes=harness.mem.area_nodes,
        plans=[list(p) for p in plans], total_steps=sched.steps,
        kinds=[k for _, k in sched.grants], boundaries=boundaries,
        ops=list(res.ops), events=list(harness.events))
