"""Failure-repro artifacts: a sweep violation as one JSON file.

A crash-sweep failure is fully determined by (queue, workload shape,
scheduler seed/policy, memory model, crash step, crash mode, crash seed,
subset choices) -- everything else is deterministic.  :func:`failure_artifact`
packs exactly that, :func:`save_artifact` / :func:`load_artifact` round-trip
it, and :func:`reproduce` replays it either way:

* ``method='snapshot'`` -- the sweep's own path (capture once, restore the
  boundary, crash);
* ``method='rerun'``    -- the classic independent path (rerun the whole
  schedule from scratch with ``crash_at=step``), confirming the snapshot
  seam itself is not the bug.

One command::

    python -m repro.crash repro <file> [--method rerun]

exits nonzero iff the durable-linearizability violation still reproduces.
CI uploads these files from failing sweep shards.
"""
from __future__ import annotations

import json
from typing import Optional, Tuple

from repro.core import (DURABLE_QUEUES, CrashChoices, QueueHarness,
                        check_durable_linearizability, split_at_crash)

ARTIFACT_VERSION = 1


def _choices_to_json(choices: Optional[CrashChoices]):
    if choices is None:
        return None
    return {
        "flush_survivors": sorted(list(e) for e in choices.flush_survivors),
        "nt_prefix": [[list(k), v] for k, v in choices.nt_prefix],
        "log_prefix": [list(kv) for kv in choices.log_prefix],
    }


def _choices_from_json(data) -> Optional[CrashChoices]:
    if data is None:
        return None
    return CrashChoices(
        flush_survivors=frozenset(tuple(e) for e in data["flush_survivors"]),
        nt_prefix=tuple((tuple(k), v) for k, v in data["nt_prefix"]),
        log_prefix=tuple((line, k) for line, k in data["log_prefix"]))


def failure_artifact(capture, crash_step: int, mode: str, crash_seed: int,
                     choices: Optional[CrashChoices], why: str,
                     recovered: list) -> dict:
    """Build the repro dict for one violation found by the sweep."""
    per_thread = sum(1 for kind, _ in capture.plans[0] if kind == "enq")
    return {
        "version": ARTIFACT_VERSION,
        "queue": capture.queue_name,
        "nthreads": capture.nthreads,
        "per_thread": per_thread,
        "seed": capture.seed,
        "policy": capture.policy,
        "model": capture.model,
        "area_nodes": capture.area_nodes,
        "crash_step": crash_step,
        "mode": mode,
        "crash_seed": crash_seed,
        "choices": _choices_to_json(choices),
        "why": why,
        "recovered": [repr(it) for it in recovered],
    }


def save_artifact(path: str, art: dict) -> None:
    with open(path, "w") as f:
        json.dump(art, f, indent=2, sort_keys=True)
        f.write("\n")


def load_artifact(path: str) -> dict:
    with open(path) as f:
        art = json.load(f)
    if art.get("version") != ARTIFACT_VERSION:
        raise ValueError(f"artifact version {art.get('version')!r} "
                         f"(this code reads {ARTIFACT_VERSION})")
    return art


def reproduce(art: dict, method: str = "snapshot",
              log=None) -> Tuple[bool, str, list]:
    """Replay an artifact.  Returns (ok, why, recovered): ``ok=False``
    means the durable-linearizability violation reproduced."""
    from .sweep import _check_point, standard_plans
    from .capture import capture_run

    name = art["queue"]
    plans = standard_plans(art["nthreads"], art["per_thread"])
    choices = _choices_from_json(art["choices"])
    h = QueueHarness(DURABLE_QUEUES[name], nthreads=art["nthreads"],
                     area_nodes=art["area_nodes"], model=art["model"])
    if method == "snapshot":
        cap = capture_run(h, plans, seed=art["seed"], policy=art["policy"])
        ok, why, recovered, _pr, _pw, _us = _check_point(
            h, cap, art["crash_step"], art["mode"],
            crash_seed=art["crash_seed"], choices=choices)
    elif method == "rerun":
        res = h.run_scheduled(plans, seed=art["seed"], policy=art["policy"],
                              crash_at=art["crash_step"])
        pre_events, _ = split_at_crash(h.events)
        pre_ops = list(res.ops)
        h.crash_and_recover(mode=art["mode"], seed=art["crash_seed"],
                            choices=choices)
        recovered = h.queue.drain(0)
        ok, why = check_durable_linearizability(pre_ops, pre_events,
                                                recovered)
    else:
        raise ValueError(f"method {method!r} (snapshot|rerun)")
    if log:
        verdict = "violation REPRODUCED" if not ok else "no violation"
        log(f"{name} step={art['crash_step']} mode={art['mode']} "
            f"[{method}]: {verdict} ({why}); recovered={recovered!r}")
    return ok, why, recovered
