"""CLI for the crash-sweep subsystem.

::

    python -m repro.crash sweep [--queues A,B | --shard k/n] [--out CSV]
                                [--artifacts-dir DIR] ...
    python -m repro.crash repro <artifact.json> [--method snapshot|rerun]

``sweep`` exits nonzero iff any crash point violates durable
linearizability (writing one repro artifact per violation); ``repro``
exits nonzero iff the artifact's violation still reproduces.  CI runs the
sweep as a sharded blocking matrix job and uploads the artifacts of
failing shards (`.github/workflows/ci.yml`).
"""
from __future__ import annotations

import argparse
import csv
import os
import sys
from typing import List

from repro.core import DURABLE_QUEUES

from .artifact import load_artifact, reproduce, save_artifact
from .sweep import DEFAULT_MODES, sweep_queue

CSV_FIELDS = [
    "queue", "seed", "nthreads", "per_thread", "model", "crash_step",
    "mode", "boundary", "prim_before", "prim_after", "pending_flush",
    "pending_nt", "log_words", "subset_combos", "ok", "recovered_len",
    "recovery_preads", "recovery_pwrites", "recovery_us",
]


def _shard(names: List[str], spec: str) -> List[str]:
    """'k/n' -> every n-th queue starting at k (round-robin by sorted name,
    so shards stay balanced as queues are added)."""
    k, n = (int(x) for x in spec.split("/", 1))
    if not (0 <= k < n):
        raise ValueError(f"shard {spec!r}: need 0 <= k < n")
    return names[k::n]


def sweep_main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.crash sweep",
        description="Exhaustive crash sweep: check durable linearizability "
                    "at every scheduler step (snapshot/restore path).")
    ap.add_argument("--queues", default=",".join(sorted(DURABLE_QUEUES)),
                    help="comma-separated queue names "
                         "(default: all durable queues)")
    ap.add_argument("--shard", default=None, metavar="K/N",
                    help="run shard K of N over the sorted queue list "
                         "(CI matrix axis); applied after --queues")
    ap.add_argument("--threads", type=int, default=3)
    ap.add_argument("--ops", type=int, default=6,
                    help="enqueues per thread (a dequeue follows every "
                         "other one; default 6 = the standard workload)")
    ap.add_argument("--seed", type=int, default=3)
    ap.add_argument("--policy", default="random", choices=["random", "rr"])
    ap.add_argument("--model", default="optane-clwb")
    ap.add_argument("--area-nodes", type=int, default=64,
                    help="allocator designated-area size (smaller = "
                         "smaller snapshots + faster recovery scans)")
    ap.add_argument("--modes", default=",".join(DEFAULT_MODES))
    ap.add_argument("--no-subset", action="store_true",
                    help="skip the exhaustive flush-subset enumeration")
    ap.add_argument("--subset-cap", type=int, default=64,
                    help="max outcome combos to enumerate per boundary "
                         "(larger spaces fall back to the sampled modes)")
    ap.add_argument("--out", default=None,
                    help="write the per-crash-point coverage/recovery-cost "
                         "CSV here (a versioned run manifest is written "
                         "alongside it, see docs/observability.md)")
    ap.add_argument("--artifacts-dir", default=None,
                    help="write one repro JSON per violation here")
    args = ap.parse_args(argv)

    names = [q for q in args.queues.split(",") if q]
    unknown = [q for q in names if q not in DURABLE_QUEUES]
    if unknown:
        ap.error(f"unknown queue(s) {unknown}; have {sorted(DURABLE_QUEUES)}")
    if args.shard:
        names = _shard(sorted(names), args.shard)
        print(f"# shard {args.shard}: {','.join(names) or '(empty)'}")

    from repro.obs import PhaseProfiler, build_manifest, manifest_path_for, \
        write_manifest

    all_rows, n_failures = [], 0
    headline, wall_total = {}, 0.0
    checked_total = rec_us_total = 0
    profile = PhaseProfiler()
    print("name,us_per_call,derived")
    for name in names:
        r = sweep_queue(name, nthreads=args.threads, per_thread=args.ops,
                        seed=args.seed, policy=args.policy, model=args.model,
                        area_nodes=args.area_nodes,
                        modes=tuple(args.modes.split(",")),
                        subset=not args.no_subset,
                        subset_cap=args.subset_cap, log=print,
                        profile=profile)
        cov = r.coverage()
        all_rows.extend(r.rows)
        wall_total += r.wall_s
        checked_total += cov["crashes_checked"]
        rec_us_total += cov["recovery_us_total"]
        if cov["recovery_us_total"] > 0:
            headline[f"crash-sweep/{name}/recoveries_per_s"] = (
                cov["crashes_checked"] * 1e6 / cov["recovery_us_total"])
        us_per_recovery = (cov["recovery_us_total"]
                           / max(cov["crashes_checked"], 1))
        print(f"crash/{name},{us_per_recovery:.3f},"
              f"boundaries={cov['boundaries']};"
              f"persist_adjacent={cov['persist_adjacent']};"
              f"interior={cov['interior']};"
              f"crashes={cov['crashes_checked']};"
              f"subset_enumerated={cov['subset_enumerated']};"
              f"subset_skipped={cov['subset_skipped']};"
              f"failures={cov['failures']};wall_s={r.wall_s:.1f}")
        n_failures += len(r.failures)
        if r.failures and args.artifacts_dir:
            os.makedirs(args.artifacts_dir, exist_ok=True)
            # sequence number: one step can yield several subset-mode
            # violations (distinct CrashChoices) -- each gets its own file
            for i, art in enumerate(r.failures):
                path = os.path.join(
                    args.artifacts_dir,
                    f"{art['queue']}_{i:04d}_step{art['crash_step']}_"
                    f"{art['mode']}.json")
                save_artifact(path, art)
                print(f"# wrote repro artifact {path} "
                      f"(python -m repro.crash repro {path})")

    if args.out and all_rows:
        with open(args.out, "w", newline="") as f:
            w = csv.DictWriter(f, fieldnames=CSV_FIELDS)
            w.writeheader()
            w.writerows(all_rows)
        print(f"# wrote {len(all_rows)} rows to {args.out}")
    if args.out:
        if rec_us_total > 0:
            headline["crash-sweep/recoveries_per_s"] = (
                checked_total * 1e6 / rec_us_total)
        man = build_manifest(
            subcommand="crash-sweep", config=vars(args),
            metrics=[{"queue": n.split("/", 2)[1],
                      "recoveries_per_s": v}
                     for n, v in headline.items()
                     if n.count("/") == 2],
            headline=headline, phases=profile.as_dict(), wall_s=wall_total)
        mpath = write_manifest(man, manifest_path_for(args.out))
        print(f"# wrote manifest {mpath}")
    if n_failures:
        print(f"# {n_failures} durable-linearizability violation(s)",
              file=sys.stderr)
        return 1
    return 0


def repro_main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.crash repro",
        description="Replay a crash-sweep failure artifact.  Exits nonzero "
                    "iff the violation still reproduces.")
    ap.add_argument("artifact", help="path to the repro JSON")
    ap.add_argument("--method", default="snapshot",
                    choices=["snapshot", "rerun"],
                    help="snapshot: the sweep's fast path; rerun: "
                         "independent rerun-from-scratch with crash_at")
    args = ap.parse_args(argv)
    art = load_artifact(args.artifact)
    ok, _why, _recovered = reproduce(art, method=args.method, log=print)
    return 1 if not ok else 0


def main(argv=None) -> int:
    argv = sys.argv[1:] if argv is None else list(argv)
    if not argv or argv[0] not in ("sweep", "repro"):
        print(__doc__.strip(), file=sys.stderr)
        return 2
    if argv[0] == "sweep":
        return sweep_main(argv[1:])
    return repro_main(argv[1:])


if __name__ == "__main__":
    sys.exit(main())
