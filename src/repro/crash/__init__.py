"""Exhaustive crash-sweep subsystem (paper §2 failure model, §7 claim).

Checks durable linearizability at **every** scheduler step for the durable
queues, fast enough for CI: one exact-scheduler run is captured with a
per-step engine snapshot (:mod:`repro.crash.capture`), then each crash
point is replayed by restore + crash + recover instead of rerunning the
whole schedule (:mod:`repro.crash.sweep`).  Failures become one-command
repro artifacts (:mod:`repro.crash.artifact`)::

    python -m repro.crash sweep --queues OptUnlinkedQ
    python -m repro.crash repro crash_artifacts/OptUnlinkedQ_step120_min.json

See docs/architecture.md (crash subsystem) and docs/benchmarking.md
(crash-sweep CSV schema).
"""
from .capture import PERSIST_KINDS, Boundary, Capture, capture_run
from .sweep import (DEFAULT_MODES, ChoiceSpace, SweepResult, choice_space,
                    enumerate_choices, standard_plans, sweep_queue,
                    sweep_queues)
from .artifact import (ARTIFACT_VERSION, failure_artifact, load_artifact,
                       reproduce, save_artifact)

__all__ = [
    "PERSIST_KINDS", "Boundary", "Capture", "capture_run",
    "DEFAULT_MODES", "ChoiceSpace", "SweepResult", "choice_space",
    "enumerate_choices", "standard_plans", "sweep_queue", "sweep_queues",
    "ARTIFACT_VERSION", "failure_artifact", "load_artifact", "reproduce",
    "save_artifact",
]
