"""Units for the dry-run analysis stack: HLO walker exactness, analytic
model sanity, roofline-term math."""
import jax
import jax.numpy as jnp
import pytest

from repro.launch.hlo_analysis import analyze
from repro.launch.analytic import analytic_bytes, model_flops
from repro.models.config import SHAPES
from repro.configs import get_config


def test_walker_counts_scan_trips_exactly():
    n = 256
    x = jax.ShapeDtypeStruct((n, n), jnp.float32)

    def scanned(x):
        return jax.lax.scan(lambda c, _: (c @ c, None), x, None, length=8)[0]

    def unrolled(x):
        for _ in range(8):
            x = x @ x
        return x

    ref = 8 * 2 * n ** 3
    for f in (scanned, unrolled):
        c = jax.jit(f).lower(x).compile()
        got = analyze(c.as_text()).flops
        assert abs(got - ref) / ref < 1e-6, (f.__name__, got, ref)


def test_walker_vs_xla_raw_discrepancy():
    """Documents WHY we do not use compiled.cost_analysis() directly."""
    n = 128
    x = jax.ShapeDtypeStruct((n, n), jnp.float32)

    def scanned(x):
        return jax.lax.scan(lambda c, _: (c @ c, None), x, None, length=16)[0]

    c = jax.jit(scanned).lower(x).compile()
    ca = c.cost_analysis()
    if isinstance(ca, (list, tuple)):   # older jax returns [dict]
        ca = ca[0]
    xla_flops = float(ca.get("flops", 0))
    walker = analyze(c.as_text()).flops
    assert walker > 10 * xla_flops   # XLA counts the body once


def test_walker_nested_scans_multiply():
    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)

    def nested(x):
        def outer(c, _):
            def inner(c2, _):
                return c2 @ c2, None
            c, _ = jax.lax.scan(inner, c, None, length=3)
            return c, None
        return jax.lax.scan(outer, x, None, length=5)[0]

    c = jax.jit(nested).lower(x).compile()
    got = analyze(c.as_text()).flops
    ref = 15 * 2 * 64 ** 3
    assert abs(got - ref) / ref < 1e-6


def test_analytic_decode_dominated_by_cache_and_weights():
    cfg = get_config("command-r-plus-104b")
    b = analytic_bytes(cfg, SHAPES["decode_32k"], 256)
    assert b["kv_cache"] > 0 and b["weights"] > 0
    assert b["kv_cache"] + b["weights"] > 0.8 * b["total"]


def test_analytic_train_scales_with_tokens():
    cfg = get_config("yi-6b")
    t4k = analytic_bytes(cfg, SHAPES["train_4k"], 256)
    pf = analytic_bytes(cfg, SHAPES["prefill_32k"], 256)
    # same total token count (1M): prefill (1 pass) < train (3 passes + opt)
    assert pf["total"] < t4k["total"]


def test_model_flops_conventions():
    cfg = get_config("yi-6b")
    tr = model_flops(cfg, SHAPES["train_4k"])
    pf = model_flops(cfg, SHAPES["prefill_32k"])
    de = model_flops(cfg, SHAPES["decode_32k"])
    D = 4096 * 256
    assert abs(tr - 6 * cfg.n_active_params() * D) / tr < 1e-9
    assert pf == pytest.approx(2 * cfg.n_active_params() * D, rel=1e-9)
    assert de == pytest.approx(2 * cfg.n_active_params() * 128, rel=1e-9)


def test_moe_model_flops_use_active_params():
    cfg = get_config("deepseek-moe-16b")
    assert cfg.n_active_params() < 0.25 * cfg.n_params()
    tr = model_flops(cfg, SHAPES["train_4k"])
    assert tr == pytest.approx(6 * cfg.n_active_params() * 4096 * 256,
                               rel=1e-9)


def test_roofline_terms_shape():
    from benchmarks.roofline import roofline_terms
    cell = {
        "ok": True, "flops_per_device": 1e14,
        "analytic_bytes_per_device": {"total": 1e12},
        "collective_bytes_per_device": {"all-gather": 1e11},
        "model_flops": 1e16, "n_chips": 256,
    }
    t = roofline_terms(cell)
    # memory = 1.22 ms < collective = 2.0 ms
    assert t["bottleneck"] == "collective"
    assert t["compute_ms"] == pytest.approx(1e14 / 197e12 * 1e3)
    assert 0 < t["roofline_fraction"] < 1
