"""Hypothesis property tests: durable linearizability under randomized
workloads, interleavings, crash points and crash modes -- for every durable
queue. These are the system's core invariants:

  P1. no loss: completed enqueues survive a crash unless dequeued;
  P2. no duplication / invention: recovered items are exactly linked items;
  P3. FIFO: recovered order = link order; removals form a prefix;
  P4. one fence per update op for the four new queues;
  P5. zero post-flush accesses for the second-amendment queues.
"""
import pytest

pytest.importorskip(
    "hypothesis",
    reason="hypothesis is an optional dev dependency (installed in CI)")
from hypothesis import HealthCheck, given, settings, strategies as st  # noqa: E402

from repro.core import (ALL_QUEUES, DURABLE_QUEUES, QueueHarness,
                        check_durable_linearizability, split_at_crash)

QNAMES = sorted(DURABLE_QUEUES)


def _build_plans(opseq, nthreads):
    plans = [[] for _ in range(nthreads)]
    counters = [0] * nthreads
    for (t, is_enq) in opseq:
        t = t % nthreads
        if is_enq:
            plans[t].append(("enq", (t, counters[t])))
            counters[t] += 1
        else:
            plans[t].append(("deq", None))
    return plans


op_strategy = st.lists(
    st.tuples(st.integers(0, 2), st.booleans()), min_size=4, max_size=40)


@settings(max_examples=30, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(name=st.sampled_from(QNAMES), opseq=op_strategy,
       seed=st.integers(0, 10_000), crash_frac=st.floats(0.05, 0.95),
       mode=st.sampled_from(["min", "random", "max"]))
def test_durable_linearizability_property(name, opseq, seed, crash_frac, mode):
    nthreads = 3
    plans = _build_plans(opseq, nthreads)
    # discover total steps, then crash somewhere inside
    probe = QueueHarness(DURABLE_QUEUES[name], nthreads, area_nodes=128)
    from repro.core.scheduler import Scheduler
    sched = Scheduler(probe.nvram, seed=seed)
    sched.run([probe.make_worker(t, p) for t, p in enumerate(plans)])
    total = max(sched.steps, 2)

    h = QueueHarness(DURABLE_QUEUES[name], nthreads, area_nodes=128)
    res = h.run_scheduled(plans, seed=seed,
                          crash_at=max(1, int(total * crash_frac)))
    pre_events, _ = split_at_crash(h.events)
    pre_ops = list(res.ops)
    h.crash_and_recover(mode=mode, seed=seed)
    recovered = h.queue.drain(0)
    ok, why = check_durable_linearizability(pre_ops, pre_events, recovered)
    assert ok, f"{name}: {why} (recovered={recovered!r})"


@settings(max_examples=20, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(name=st.sampled_from(["UnlinkedQ", "LinkedQ", "OptUnlinkedQ",
                             "OptLinkedQ"]),
       n_ops=st.integers(2, 60))
def test_fence_lower_bound_property(name, n_ops):
    """P4: exactly one fence per completed update op (single-threaded, so no
    helping-induced extras; allocator-area fences amortize to <= 2 extra)."""
    h = QueueHarness(ALL_QUEUES[name], nthreads=1, area_nodes=4096)
    base = h.nvram.total_stats()
    for i in range(n_ops):
        if i % 3 == 2:
            h.queue.dequeue(0)
        else:
            h.queue.enqueue(0, i)
    d = h.nvram.total_stats().minus(base)
    assert n_ops <= d.fences <= n_ops + 2


@settings(max_examples=20, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(name=st.sampled_from(["OptUnlinkedQ", "OptLinkedQ"]),
       opseq=op_strategy, seed=st.integers(0, 10_000))
def test_zero_post_flush_property(name, opseq, seed):
    """P5 under arbitrary concurrent interleavings."""
    nthreads = 3
    h = QueueHarness(ALL_QUEUES[name], nthreads, area_nodes=128)
    res = h.run_scheduled(_build_plans(opseq, nthreads), seed=seed)
    assert res.stats.post_flush_accesses == 0


@settings(max_examples=15, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(opseq=op_strategy, seed=st.integers(0, 1000))
def test_queues_agree_with_each_other(opseq, seed):
    """All queues must produce the identical dequeue results under the SAME
    deterministic schedule seed... they take different step counts, so we
    compare against the sequential-spec outcome per thread plan instead:
    single-threaded runs of the same plan must agree exactly."""
    plan = _build_plans(opseq, 1)[0]
    outs = {}
    for name in QNAMES:
        h = QueueHarness(DURABLE_QUEUES[name], 1, area_nodes=128)
        got = []
        for kind, item in plan:
            if kind == "enq":
                h.queue.enqueue(0, item)
                got.append(("enq", item))
            else:
                got.append(("deq", h.queue.dequeue(0)))
        outs[name] = got
    vals = list(outs.values())
    for name, v in outs.items():
        assert v == vals[0], f"{name} diverges from {QNAMES[0]}"
