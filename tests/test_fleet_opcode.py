"""Opcode-table gates: encoding round-trips and the compile-time bound.

Two contracts from the opcode stepper PR:

* ``encode_program`` emits a fixed-width int32 table that decodes back to
  the source :class:`repro.fleet.lowering.FleetProgram`'s effect entries
  exactly (validated at every encode; tampered or malformed tables are
  rejected) -- for all 8 queues x 3 memory models;
* the opcode-interpreting chunk fn's jaxpr does **not** grow with
  schedule depth (the unrolled stepper's does -- that asymmetry is the
  whole reason the opcode backend exists).
"""
import dataclasses

import numpy as np
import pytest

from repro.core.harness import ALL_QUEUES
from repro.fleet.lowering import (OPC_NOP, OPC_SLOT, OPCODE_COLUMNS,
                                  FleetLoweringError, FleetPrograms,
                                  OpcodeProgram, decode_opcodes,
                                  encode_program, validate_opcodes)
from repro.fleet.state import build_template, replicate

MODELS = ["optane-clwb", "eadr", "cxl"]


def _all_templates(ops=32):
    for q in ALL_QUEUES:
        for m in MODELS:
            yield build_template(q, m, ops=ops)


def test_encode_round_trips_all_queues_and_models():
    """Every lowered program encodes, and the decode reproduces its
    micro/aux entries (normal form: line -> recache, padd expanded)."""
    n = 0
    for t in _all_templates():
        for prog in t.programs:
            opc = encode_program(prog, t.dims.slot_attrs)
            assert opc.table.dtype == np.int32
            assert opc.table.shape[1] == OPCODE_COLUMNS
            assert 0 <= opc.n_micro <= opc.n_rows
            # encode_program already validates; decode once more here so
            # the test fails loudly if validation is ever weakened
            micro, aux = decode_opcodes(opc, t.dims.slot_attrs)
            assert len(micro) >= len([i for i in prog.micro])
            n += 1
    assert n == len(ALL_QUEUES) * len(MODELS) * 2


def test_nop_padding_is_inert_and_monotonic():
    t = build_template("DurableMSQ", "optane-clwb", ops=16)
    opc = encode_program(t.programs.enq, t.dims.slot_attrs)
    padded = opc.padded(opc.n_rows + 5)
    assert padded.n_rows == opc.n_rows + 5
    assert (padded.table[opc.n_rows:, 0] == OPC_NOP).all()
    assert decode_opcodes(padded, t.dims.slot_attrs) == \
        decode_opcodes(opc, t.dims.slot_attrs)
    with pytest.raises(ValueError):
        opc.padded(opc.n_rows - 1)


def test_validate_rejects_tampered_table():
    """Flipping any row's opcode must fail the round-trip validation."""
    t = build_template("OptLinkedQ", "optane-clwb", ops=16)
    prog = t.programs.enq
    opc = encode_program(prog, t.dims.slot_attrs)
    bad = opc.table.copy()
    bad[0, 0] = OPC_NOP if bad[0, 0] != OPC_NOP else OPC_SLOT
    with pytest.raises(FleetLoweringError):
        validate_opcodes(prog, OpcodeProgram(table=bad, n_micro=opc.n_micro),
                         t.dims.slot_attrs)


def test_validate_rejects_wrong_shape_and_region():
    t = build_template("DurableMSQ", "optane-clwb", ops=16)
    prog = t.programs.enq
    opc = encode_program(prog, t.dims.slot_attrs)
    with pytest.raises(FleetLoweringError):
        validate_opcodes(prog, OpcodeProgram(
            table=opc.table.astype(np.int64), n_micro=opc.n_micro),
            t.dims.slot_attrs)
    # a micro row pushed into the aux region is a structural error
    with pytest.raises(FleetLoweringError):
        decode_opcodes(OpcodeProgram(table=opc.table, n_micro=0),
                       t.dims.slot_attrs)


def test_encode_rejects_slot_outside_layout():
    """An aux slot store whose attribute is missing from the fleet-wide
    guard-slot layout cannot be encoded."""
    hit = False
    for t in _all_templates(ops=16):
        for prog in t.programs:
            if any(ax[0] == "slot" for ax in prog.aux):
                with pytest.raises(FleetLoweringError):
                    encode_program(prog, ())
                hit = True
    assert hit, "no queue with a guarded slot store? layout changed"


# ---- compile-time bound ---------------------------------------------------

jax = pytest.importorskip("jax", reason="trace-size tests need jax")


def _count_eqns(obj):
    """Total equations in a (closed) jaxpr, recursing into sub-jaxprs
    carried by scan/while/cond/pjit params."""
    if hasattr(obj, "jaxpr"):
        return _count_eqns(obj.jaxpr)
    total = len(obj.eqns)
    for eqn in obj.eqns:
        for v in eqn.params.values():
            for sub in (v if isinstance(v, (list, tuple)) else (v,)):
                if hasattr(sub, "jaxpr") or hasattr(sub, "eqns"):
                    total += _count_eqns(sub)
    return total


def _state_dict(template, n):
    from repro.fleet import jaxexec
    state = replicate(template.row, template.dims, n)
    st = {f: getattr(state, f) for f in jaxexec._ARRAY_FIELDS}
    for f in jaxexec._SCALAR_FIELDS:
        st[f] = getattr(state, f)
    st["counts"] = state.counts.astype(np.int32)
    for attr, arr in state.slots.items():
        st["slot_" + attr] = arr
    return st


def _deepen(programs, k):
    """A synthetic deep schedule: the same programs with k copies of the
    micro sequence (still encodable and traceable -- semantics don't
    matter here, trace size does)."""
    return FleetPrograms(
        enq=dataclasses.replace(programs.enq, micro=programs.enq.micro * k),
        deq=dataclasses.replace(programs.deq, micro=programs.deq.micro * k))


def test_opcode_trace_size_independent_of_schedule_depth():
    """The acceptance bound: 8x deeper schedules leave the opcode chunk
    fn's jaxpr equation count unchanged, while the unrolled chunk fn's
    grows -- and on the deep variant the opcode trace is the smaller."""
    from repro.fleet.jaxexec import make_chunk_fn, make_opcode_chunk_fn

    t = build_template("DurableMSQ", "optane-clwb", ops=16)
    st = _state_dict(t, 4)
    kcols = np.zeros((4, 8), dtype=np.uint8)
    oi = np.arange(8, dtype=np.int32)
    deep = _deepen(t.programs, 8)

    def eqns(make, programs):
        fn = make(jax, programs, t.dims)
        return _count_eqns(jax.make_jaxpr(fn)(st, kcols, oi))

    opcode_shallow = eqns(make_opcode_chunk_fn, t.programs)
    opcode_deep = eqns(make_opcode_chunk_fn, deep)
    unrolled_shallow = eqns(make_chunk_fn, t.programs)
    unrolled_deep = eqns(make_chunk_fn, deep)

    assert opcode_shallow == opcode_deep, (
        f"opcode trace scaled with depth: {opcode_shallow} -> {opcode_deep}")
    assert unrolled_deep > unrolled_shallow
    assert opcode_deep < unrolled_deep
