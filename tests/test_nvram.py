"""Unit tests for the simulated NVRAM memory model (paper §2 semantics)."""
from repro.core import NVRAM


def test_write_not_durable_without_flush():
    nv = NVRAM(1)
    a = nv.alloc_region(8, "r")
    nv.write(a, 42)
    nv.crash(mode="min")
    assert nv.pread(a) is None


def test_flush_fence_makes_durable():
    nv = NVRAM(1)
    a = nv.alloc_region(8, "r")
    nv.write(a, 42)
    nv.flush(a)
    nv.fence()
    nv.crash(mode="min")
    assert nv.pread(a) == 42


def test_flush_without_fence_may_be_dropped():
    nv = NVRAM(1)
    a = nv.alloc_region(8, "r")
    nv.write(a, 42)
    nv.flush(a)
    nv.crash(mode="min")          # adversarial: pending flush dropped
    assert nv.pread(a) is None


def test_assumption1_prefix_of_same_line_stores():
    """Persistent content of a line is always a prefix of its stores."""
    for seed in range(40):
        nv = NVRAM(1)
        a = nv.alloc_region(8, "r")
        for i in range(4):
            nv.write(a + i, ("v", i))
        nv.crash(mode="random", seed=seed)
        vals = [nv.pread(a + i) for i in range(4)]
        # must be a prefix: once None is seen, the rest are None
        seen_none = False
        for v, i in zip(vals, range(4)):
            if v is None:
                seen_none = True
            else:
                assert not seen_none, f"non-prefix survival: {vals}"
                assert v == ("v", i)


def test_clwb_invalidates_and_post_flush_access_is_counted():
    nv = NVRAM(1)
    a = nv.alloc_region(8, "r")
    nv.write(a, 1)
    assert nv.total_stats().post_flush_accesses == 0
    nv.flush(a)
    nv.fence()
    assert nv.read(a) == 1        # miss: line was invalidated by CLWB
    assert nv.total_stats().post_flush_accesses == 1
    assert nv.read(a) == 1        # now cached again
    assert nv.total_stats().post_flush_accesses == 1


def test_movnti_bypasses_cache():
    nv = NVRAM(1)
    a = nv.alloc_region(8, "r")
    nv.write(a, "old")
    nv.flush(a)
    nv.fence()
    before = nv.total_stats().post_flush_accesses
    nv.movnti(a, "new")           # no fetch of the invalidated line
    nv.fence()
    assert nv.total_stats().post_flush_accesses == before
    nv.crash(mode="min")
    assert nv.pread(a) == "new"


def test_movnti_needs_fence():
    nv = NVRAM(1)
    a = nv.alloc_region(8, "r")
    nv.movnti(a, 7)
    nv.crash(mode="min")
    assert nv.pread(a) is None


def test_nt_store_prefix_on_crash():
    """NT stores to one line survive as a prefix in issue order."""
    for seed in range(30):
        nv = NVRAM(1)
        a = nv.alloc_region(8, "r")
        for i in range(4):
            nv.movnti(a + i, i)
        nv.crash(mode="random", seed=seed)
        vals = [nv.pread(a + i) for i in range(4)]
        seen_none = False
        for v in vals:
            if v is None:
                seen_none = True
            else:
                assert not seen_none, f"NT stores tore: {vals}"


def test_cas_semantics():
    nv = NVRAM(1)
    a = nv.alloc_region(8, "r")
    nv.write(a, 5)
    assert not nv.cas(a, 4, 9)
    assert nv.read(a) == 5
    assert nv.cas(a, 5, 9)
    assert nv.read(a) == 9


def test_volatile_space_wiped_on_crash():
    nv = NVRAM(1)
    a = nv.alloc_region(8, "v", persistent=False)
    nv.write(a, 42)
    assert nv.read(a) == 42
    nv.crash(mode="max")
    assert nv.read(a) is None


def test_interleaved_flush_fence_absolute_indices():
    """Regression: stale pending flush entries must stay valid when other
    fences apply and trim the same line's log (the compaction bug)."""
    nv = NVRAM(2)
    a = nv.alloc_region(8, "r")
    nv.set_tid(0)
    nv.write(a, 1)
    nv.flush(a)             # t0 pending: stores [1]
    nv.set_tid(1)
    nv.write(a, 2)
    nv.flush(a)
    nv.fence()              # t1 persists prefix [1,2]
    nv.set_tid(0)
    nv.write(a, 3)
    nv.fence()              # t0's stale entry must not clobber store 3
    assert nv.read(a) == 3
    nv.flush(a)
    nv.fence()
    nv.crash(mode="min")
    assert nv.pread(a) == 3


def test_time_accounting_post_flush_expensive():
    nv = NVRAM(1)
    a = nv.alloc_region(8, "r")
    nv.write(a, 1)
    t0 = nv.total_stats().time_ns
    nv.read(a)                      # cache hit
    hit_cost = nv.total_stats().time_ns - t0
    nv.flush(a)
    nv.fence()
    t1 = nv.total_stats().time_ns
    nv.read(a)                      # NVRAM-latency miss
    miss_cost = nv.total_stats().time_ns - t1
    assert miss_cost > 50 * hit_cost
