"""Property tests for the columnar record store (repro.core.records).

Three families of invariants, each run both as a seeded-random sweep
(always on; no optional deps) and as a hypothesis property when the
optional dev dependency is installed (CI):

  R1. capacity: columns auto-grow by doubling and growth PRESERVES
      contents -- every row written before a grow reads back identically
      after it; per-thread seq numbers stay contiguous across grows;
  R2. exhaustion is loud: with an explicit ``max_records`` bound the
      store raises :class:`RecordCapacityError` instead of dropping
      rows, and the rows already stored survive the failed append --
      never a silent truncation of the history the linearizability
      checker reads;
  R3. interleaving: arbitrary interleaves of staged-burst charges
      (``run_batched``), direct rows, reads (which force a staging
      sync), cursor snapshots and restores leave the columnar history
      bit-identical to the legacy list path driven by the same sequence.
"""
import random

import pytest

from repro.core import ALL_QUEUES, QueueHarness
from repro.core.records import (OpRecord, RecordCapacityError, RecordStore)

try:
    from hypothesis import given, settings, strategies as st
    _HAS_HYPOTHESIS = True
except ImportError:
    _HAS_HYPOTHESIS = False


# ------------------------------------------------------------ R1: auto-grow

def _fill_and_check_grow(n_rows, nthreads, op_capacity):
    rs = RecordStore(nthreads=nthreads, op_capacity=op_capacity,
                     event_capacity=op_capacity)
    expect = []
    for i in range(n_rows):
        tid = i % nthreads
        kind = "enq" if i % 3 else "deq"
        rs.begin_op(tid, kind, item=("it", i), completed=bool(i % 2))
        rs.append_event(("ev", i))
        expect.append(OpRecord(tid=tid, kind=kind, item=("it", i),
                               completed=bool(i % 2)))
    assert len(rs.tid) >= n_rows > op_capacity, "growth never triggered"
    assert rs.op_records() == expect
    assert rs.event_tuples() == [("ev", i) for i in range(n_rows)]
    # per-thread seqs must be 0..k-1 in row order despite the grows
    seen = [0] * nthreads
    for i in range(n_rows):
        t = int(rs.tid[i])
        assert int(rs.seq[i]) == seen[t]
        seen[t] += 1


def test_auto_grow_preserves_contents_seeded():
    rng = random.Random(11)
    for _ in range(8):
        _fill_and_check_grow(n_rows=rng.randint(10, 400),
                             nthreads=rng.randint(1, 8),
                             op_capacity=rng.choice([1, 2, 3, 8]))


# --------------------------------------------------------- R2: loud overflow

def _check_overflow(max_records, extra):
    rs = RecordStore(nthreads=2, op_capacity=1, event_capacity=1,
                     max_records=max_records)
    for i in range(max_records):
        rs.begin_op(i % 2, "enq", item=i, completed=True)
        rs.append_event(("enq", i))
    before_ops = rs.op_records()
    before_evs = rs.event_tuples()
    for _ in range(extra):
        with pytest.raises(RecordCapacityError):
            rs.begin_op(0, "enq", item="overflow")
        with pytest.raises(RecordCapacityError):
            rs.append_event(("enq", "overflow"))
    # the failed appends changed nothing: no truncation, no partial rows
    assert rs.op_records() == before_ops
    assert rs.event_tuples() == before_evs
    assert rs.snapshot() == (max_records, max_records)


def test_capacity_exhaustion_is_explicit_seeded():
    rng = random.Random(23)
    for _ in range(6):
        _check_overflow(max_records=rng.randint(1, 64),
                        extra=rng.randint(1, 3))


def test_staged_burst_overflow_is_explicit():
    """Exhaustion must be loud on the staged (compiled fast) path too:
    the burst fails before any row is scattered, so the history keeps
    exactly the rows that fit -- nothing silently dropped mid-burst."""
    h = QueueHarness(ALL_QUEUES["DurableMSQ"], nthreads=2,
                     model="optane-clwb")
    rs = h._rstore
    rs.max_records = 10
    plans = [[("enq", (t, i)) for i in range(20)] for t in range(2)]
    with pytest.raises(RecordCapacityError):
        h.run_batched(plans)
        len(h.ops)   # force the staged burst to materialize
    assert rs.n_ops <= 10


# -------------------------------------------------------- R3: interleaving

_QNAME = "DurableMSQ"


def _interleave_trial(steps, nthreads=2):
    """Drive a columnar and a legacy harness through the same random
    sequence of bursts / direct rows / reads / snapshot / restore and
    assert the record state never diverges."""
    pair = [QueueHarness(ALL_QUEUES[_QNAME], nthreads=nthreads,
                         model="optane-clwb", records=mode)
            for mode in ("columnar", "legacy")]
    snaps = []
    counter = [0]

    def burst(rng_seed):
        rng = random.Random(rng_seed)
        plans = []
        for t in range(nthreads):
            plan = []
            for _ in range(rng.randint(1, 5)):
                if rng.random() < 0.5:
                    plan.append(("enq", ("b", counter[0])))
                    counter[0] += 1
                else:
                    plan.append(("deq", None))
            plans.append(plan)
        for h in pair:
            h.run_batched([list(p) for p in plans])

    def direct(rng_seed):
        rng = random.Random(rng_seed)
        item = ("d", counter[0])
        counter[0] += 1
        tid = rng.randrange(nthreads)
        for h in pair:
            h.ops.append(OpRecord(tid=tid, kind="enq", item=item,
                                  completed=True))
            h.events.append(("enq", item))

    def snap(_):
        snaps.append(pair[0].record_snapshot())
        assert pair[1].record_snapshot() == snaps[-1]

    def restore(rng_seed):
        if not snaps:
            return
        rng = random.Random(rng_seed)
        k = rng.randrange(len(snaps))
        s = snaps[k]
        del snaps[k + 1:]     # later snapshots die with the rewind
        for h in pair:
            h.record_restore(s)

    actions = [burst, burst, direct, snap, restore]
    for i, pick in enumerate(steps):
        actions[pick % len(actions)](i * 7919)
        h_col, h_leg = pair
        assert list(h_col.ops) == list(h_leg.ops), f"step {i}"
        assert list(h_col.events) == list(h_leg.events), f"step {i}"
        assert h_col._completed_count() == h_leg._completed_count()


def test_interleaved_burst_snapshot_restore_seeded():
    rng = random.Random(7)
    for _ in range(4):
        _interleave_trial([rng.randrange(100) for _ in
                           range(rng.randint(4, 12))])


if _HAS_HYPOTHESIS:
    @settings(max_examples=20, deadline=None)
    @given(n_rows=st.integers(2, 300), nthreads=st.integers(1, 8),
           cap=st.sampled_from([1, 2, 3, 8]))
    def test_auto_grow_preserves_contents_property(n_rows, nthreads, cap):
        if n_rows <= cap:
            n_rows = cap + 1
        _fill_and_check_grow(n_rows, nthreads, cap)

    @settings(max_examples=20, deadline=None)
    @given(max_records=st.integers(1, 64), extra=st.integers(1, 3))
    def test_capacity_exhaustion_is_explicit_property(max_records, extra):
        _check_overflow(max_records, extra)

    @settings(max_examples=10, deadline=None)
    @given(steps=st.lists(st.integers(0, 99), min_size=3, max_size=12))
    def test_interleaved_burst_snapshot_restore_property(steps):
        _interleave_trial(steps)
else:
    @pytest.mark.skip(reason="hypothesis not installed (optional dev dep)")
    def test_records_property_sweep():
        pass
