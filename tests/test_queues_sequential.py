"""Sequential FIFO semantics + persist-op accounting for every queue."""
import pytest

from repro.core import ALL_QUEUES, DURABLE_QUEUES, QueueHarness


@pytest.mark.parametrize("name", sorted(ALL_QUEUES))
def test_fifo_order_single_thread(name):
    h = QueueHarness(ALL_QUEUES[name], nthreads=1, area_nodes=64)
    q = h.queue
    n = 50
    for i in range(n):
        q.enqueue(0, ("t0", i))
    out = [q.dequeue(0) for _ in range(n)]
    assert out == [("t0", i) for i in range(n)]
    assert q.dequeue(0) is None


@pytest.mark.parametrize("name", sorted(ALL_QUEUES))
def test_interleaved_enq_deq(name):
    h = QueueHarness(ALL_QUEUES[name], nthreads=1, area_nodes=64)
    q = h.queue
    model = []
    import random
    rng = random.Random(7)
    for i in range(300):
        if rng.random() < 0.55:
            q.enqueue(0, i)
            model.append(i)
        else:
            got = q.dequeue(0)
            want = model.pop(0) if model else None
            assert got == want
    assert q.drain(0) == model


@pytest.mark.parametrize("name", sorted(DURABLE_QUEUES))
def test_empty_dequeue_returns_none(name):
    h = QueueHarness(DURABLE_QUEUES[name], nthreads=1, area_nodes=64)
    assert h.queue.dequeue(0) is None
    h.queue.enqueue(0, "x")
    assert h.queue.dequeue(0) == "x"
    assert h.queue.dequeue(0) is None


def test_node_reuse_through_ssmem():
    """Allocator must recycle retired nodes (epochs advance)."""
    h = QueueHarness(ALL_QUEUES["OptUnlinkedQ"], nthreads=1, area_nodes=64)
    q = h.queue
    # way more ops than area_nodes: must not exhaust if reuse works
    for i in range(1000):
        q.enqueue(0, i)
        assert q.dequeue(0) == i
    areas = h.mem.area_addrs()
    assert len(areas) <= 4, f"allocator leaked: {len(areas)} areas"
