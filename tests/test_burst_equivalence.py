"""The burst executor's equivalence gate.

``run_batched(burst=...)`` runs whole multi-thread stretches of the
clock-heap schedule as one array program: predict the interleave from
per-(tid, kind) outcome seeds, plan allocations, classify line touches
with the vector automatons, verify the predicted keys, then commit
memory effects and staged records in bulk -- with misprediction falling
back to fixpoint re-prediction, prefix truncation, or rejection (the
scheduler then replays a bounded chunk through the merged columnar
runner).  The acceptance criterion is the same one every execution tier
in this repo carries: **bit identity**.  For all 8 queues x 3 memory
models x contention off/on/learned, a burst run must produce exactly
the per-thread Stats (every counter AND the float ``time_ns``), op
records, linearization events and final queue contents as the columnar
runner with bursts disabled.

Forced-misprediction knobs (``force_mispredict_every`` /
``force_reject_every``) pin the bail paths: truncated commits and
rejected bursts must leave no trace beyond the ops they legitimately
committed.  The vectorized planner and row-batched apply fast paths
assert engagement on the workloads they were built for (enqueue-only
bursts), so a silent fallback cannot masquerade as coverage.
"""
import pytest

from repro.core import ALL_QUEUES, MEMORY_MODELS, QueueHarness
from benchmarks.workloads import make_plans, resolve_contention

QUEUES8 = sorted(ALL_QUEUES)
BURST = {"window": 512, "min_ops": 8}


def _run(qname, model, contention="off", workload="mixed5050",
         nthreads=4, ops=48, area_nodes=256, seed=0, burst=None):
    h = QueueHarness(ALL_QUEUES[qname], nthreads=nthreads,
                     area_nodes=area_nodes, model=model)
    plans, wl_prefill = make_plans(workload, nthreads, ops, seed=seed)
    for i in range(wl_prefill):
        h.queue.enqueue(0, ("pre", i))
    _, cmodel = resolve_contention(contention, qname)
    res = h.run_batched(plans, contention=cmodel, burst=burst)
    return h, res


def assert_bit_identical(qname, model, contention="off", burst=BURST,
                         **kw):
    h_ref, r_ref = _run(qname, model, contention, burst=None, **kw)
    h_b, r_b = _run(qname, model, contention, burst=burst, **kw)
    s_ref, s_b = h_ref.nvram.stats, h_b.nvram.stats
    for t in s_ref:
        assert s_ref[t] == s_b[t], (
            f"{qname}/{model}/{contention}: thread {t} Stats diverge\n"
            f"  columnar: {s_ref[t]}\n  burst:    {s_b[t]}")
    assert list(r_b.ops) == list(r_ref.ops)
    assert list(r_b.events) == list(r_ref.events)
    assert r_b.ops_completed == r_ref.ops_completed
    assert r_b.sim_time_ns == r_ref.sim_time_ns
    assert h_b.queue.drain(0) == h_ref.queue.drain(0)
    return h_b


@pytest.mark.parametrize("model", sorted(MEMORY_MODELS))
@pytest.mark.parametrize("qname", QUEUES8)
def test_burst_bit_identical_all_models(qname, model):
    """The core gate: 8 queues x 3 models, mixed workload."""
    assert_bit_identical(qname, model)


@pytest.mark.parametrize("contention", ["on", "learned"])
@pytest.mark.parametrize("qname", QUEUES8)
def test_burst_bit_identical_contended(qname, contention):
    """Contended dispatch bypasses bursts entirely (prediction only
    covers the uncontended steady state); the burst=on run must still
    be bit-identical through the generic path."""
    assert_bit_identical(qname, "optane-clwb", contention)


@pytest.mark.parametrize("qname", QUEUES8)
def test_burst_commits_engage_uncontended(qname):
    """Burst-capable queues must actually commit bursts on the mixed
    workload -- equivalence through a silent never-burst fallback would
    test nothing.  Queues whose programs cannot compile are the
    documented exception and must report zero attempts."""
    h = assert_bit_identical(qname, "optane-clwb", ops=96)
    st = h.last_burst_stats or {}
    if st.get("bursts", 0):
        assert st["ops_bursted"] > 0 or st["rejects"] > 0


def test_burst_vector_fast_paths_engage():
    """Enqueue-only bursts must take both vector fast paths: the
    sequential-planner bypass and the row-batched value apply."""
    h = assert_bit_identical("MSQ", "optane-clwb", workload="producers",
                             nthreads=4, ops=96)
    st = h.last_burst_stats or {}
    assert st.get("vec_plans", 0) > 0, "vectorized planner never engaged"
    assert st.get("vec_applies", 0) > 0, "row-batched apply never engaged"
    assert st.get("ops_bursted", 0) > 0


@pytest.mark.parametrize("qname", ["MSQ", "DurableMSQ", "OptUnlinkedQ"])
def test_burst_bit_identical_forced_mispredict(qname):
    """Forced truncations exercise the mispredict bail: every other
    burst commits only its verified prefix, with the disagreeing
    grant's clock fixed to its true duration."""
    h = assert_bit_identical(
        qname, "optane-clwb", ops=96,
        burst={"window": 512, "min_ops": 8, "force_mispredict_every": 2})
    st = h.last_burst_stats or {}
    if st.get("bursts", 0):
        # a forced truncation either commits a verified prefix or, when
        # the prefix is below min_ops, rejects the burst outright
        assert st.get("mispredicts", 0) + st.get("rejects", 0) > 0, \
            "forcing never fired"


@pytest.mark.parametrize("qname", ["MSQ", "DurableMSQ"])
def test_burst_bit_identical_forced_reject(qname):
    """Forced rejections exercise the full bail: the scheduler replays
    the rejected stretch through the merged columnar runner."""
    h = assert_bit_identical(
        qname, "optane-clwb", ops=96,
        burst={"window": 512, "min_ops": 8, "force_reject_every": 2})
    st = h.last_burst_stats or {}
    if st.get("bursts", 0):
        assert st.get("rejects", 0) > 0, "forcing never fired"
        assert st.get("replayed_ops", 0) > 0, "rejection never replayed"


@pytest.mark.parametrize("workload", ["producers", "consumers", "pairs",
                                      "prodcons"])
def test_burst_bit_identical_workload_shapes(workload):
    """Workload shapes stress different burst paths: enqueue-only
    (vector plan), dequeue-only (consumed-chain resolution), and the
    mixed shapes that route through the sequential planner."""
    assert_bit_identical("DurableMSQ", "optane-clwb", workload=workload,
                         nthreads=4, ops=64)


def test_burst_single_thread_and_tiny_windows():
    """Degenerate shapes: one live thread, and windows below min_ops
    (every burst rejected) must both stay bit-identical."""
    assert_bit_identical("MSQ", "optane-clwb", nthreads=1, ops=40)
    assert_bit_identical("MSQ", "optane-clwb",
                         burst={"window": 4, "min_ops": 64})
