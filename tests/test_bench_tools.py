"""CLI round-trip smokes for the dry-run artifact tools
(``benchmarks/merge_results.py`` and ``benchmarks/roofline.py``) -- the
entry points themselves, not just the library functions
(``tests/test_roofline_tools.py`` covers the math).  Both are registered
in ``tests/test_docs_refs.py`` CLI_SOURCES so their flags stay real.
"""
import json

from benchmarks.merge_results import main as merge_main
from benchmarks.merge_results import merge
from benchmarks.roofline import main as roofline_main


def _cell(arch, shape, mesh, ok=True, **kw):
    c = {"arch": arch, "shape": shape, "mesh": mesh, "ok": ok,
         "flops_per_device": 1e14,
         "analytic_bytes_per_device": {"total": 1e12},
         "collective_bytes_per_device": {"all-gather": 1e11},
         "model_flops": 1e16, "n_chips": 256}
    c.update(kw)
    return c


def _write_jsonl(path, cells):
    with open(path, "w") as f:
        for c in cells:
            f.write(json.dumps(c) + "\n")


def test_merge_last_wins_ok_preferred(tmp_path):
    a = tmp_path / "a.jsonl"
    b = tmp_path / "b.jsonl"
    out = tmp_path / "merged.jsonl"
    _write_jsonl(a, [_cell("x", "s", "m", ok=True, run=1),
                     _cell("y", "s", "m", ok=False, run=1)])
    _write_jsonl(b, [_cell("x", "s", "m", ok=False, run=2),   # loses: not ok
                     _cell("y", "s", "m", ok=True, run=2)])   # wins
    best = merge([str(a), str(b), str(tmp_path / "missing.jsonl")], str(out))
    assert best[("x", "s", "m")]["run"] == 1
    assert best[("y", "s", "m")]["run"] == 2
    # file order preserved: first-seen key order
    lines = [json.loads(line) for line in out.read_text().splitlines()]
    assert [r["arch"] for r in lines] == ["x", "y"]


def test_merge_cli_round_trip(tmp_path, capsys):
    src = tmp_path / "dryrun_results_0.jsonl"
    out = tmp_path / "merged.jsonl"
    _write_jsonl(src, [_cell("a", "s", "m"), _cell("b", "s", "m", ok=False)])
    rc = merge_main([str(src), "--out", str(out)])
    assert rc == 0
    assert "merged 2 cells (1 ok)" in capsys.readouterr().out
    assert len(out.read_text().splitlines()) == 2


def test_roofline_cli_round_trip(tmp_path, capsys):
    src = tmp_path / "cells.jsonl"
    md = tmp_path / "roofline.md"
    _write_jsonl(src, [_cell("tpu", "train_4k", "2x2", ok=True),
                       _cell("tpu", "decode", "2x2", ok=False,
                             error="boom")])
    rc = roofline_main([str(src)])
    assert rc == 0
    stdout = capsys.readouterr().out
    assert "| arch |" in stdout and "FAIL: boom" in stdout
    # --out writes the same table to a file instead
    assert roofline_main([str(src), "--out", str(md)]) == 0
    assert "FAIL: boom" in md.read_text()
    assert md.read_text().strip() in stdout.strip() or \
        stdout.strip() in md.read_text().strip()
