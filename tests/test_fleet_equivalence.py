"""Fleet executor equivalence gate (tier 1).

The fleet's contract: per-instance Stats -- every event counter and the
derived ``time_ns`` -- are **bit-identical** to running the same instance
plan on an independent ``QueueHarness.run_batched`` harness.  These tests
enforce it for the numpy reference backend across all 8 queues x 3 memory
models, for the bail/rejoin protocol (drained queues forcing empty-dequeue
bails), for the epoch-reclamation path (runs long enough to free and reuse
retired nodes), and -- when jax is installed -- for the jax backend against
the same gate.
"""
import numpy as np
import pytest

from repro.core.harness import ALL_QUEUES
from repro.core.nvram import N_EV
from repro.fleet import (FleetConfig, build_template, check_instances,
                         fleet_kinds, run_fleet)
from repro.fleet.state import export_instance, make_instance_harness

MODELS = ["optane-clwb", "eadr", "cxl"]


def _assert_all_ok(res, sample):
    rows = check_instances(res, sample=sample)
    bad = [r for r in rows if not r["ok"]]
    assert not bad, (
        f"{len(bad)}/{len(rows)} sampled instances diverged; first: "
        f"instance {bad[0]['instance']}\n fleet {bad[0]['fleet']}\n "
        f"ref   {bad[0]['ref']}")
    return rows


@pytest.mark.parametrize("model", MODELS)
@pytest.mark.parametrize("queue", list(ALL_QUEUES))
def test_fleet_matches_run_batched(queue, model):
    """All 8 queues x 3 models: >= 8 sampled instances bit-identical."""
    cfg = FleetConfig(queue=queue, model=model, instances=9, ops=80,
                      chunk=32, backend="numpy", seed=3)
    res = run_fleet(cfg)
    rows = _assert_all_ok(res, sample=8)
    assert len(rows) == 8
    # fleet aggregate == sum of per-instance counts by construction;
    # sanity-check the aggregate is populated and self-consistent
    agg = res.aggregate()
    assert agg.fences > 0 or queue == "MSQ"
    assert res.counts.shape == (9, N_EV)


@pytest.mark.parametrize("queue", ["MSQ", "DurableMSQ", "LinkedQ",
                                   "NVTraverseQ", "OptUnlinkedQ"])
def test_bail_rejoin_exact(queue):
    """Deq-heavy unclamped plans drain queues: instances bail out of the
    vector program, replay on real harnesses, rejoin -- still exact."""
    rng = np.random.default_rng(5)
    cfg = FleetConfig(queue=queue, model="cxl", instances=6, ops=60,
                      chunk=20, backend="numpy", prefill=3, seed=2)
    kinds = (rng.random((cfg.ops, cfg.instances)) < 0.65).astype(np.uint8)
    res = run_fleet(cfg, kinds=kinds)
    assert res.bails > 0, "plans were meant to force empty-dequeue bails"
    _assert_all_ok(res, sample=6)


def test_epoch_reclamation_exact():
    """400 ops cross several 64-op epoch advances: retired nodes move
    through limbo to the free stacks and are reallocated -- still exact."""
    for queue in ("UnlinkedQ", "OptLinkedQ"):
        cfg = FleetConfig(queue=queue, model="optane-clwb", instances=4,
                          ops=400, chunk=64, backend="numpy", seed=7)
        res = run_fleet(cfg)
        assert res.bails == 0
        _assert_all_ok(res, sample=4)


def test_batched_instances_match_unbatched():
    """Splitting the fleet into state batches must not change any counts."""
    base = FleetConfig(queue="DurableMSQ", model="eadr", instances=10,
                       ops=48, chunk=16, backend="numpy", seed=11)
    r1 = run_fleet(base)
    r2 = run_fleet(FleetConfig(**{**base.__dict__, "batch": 3}))
    assert np.array_equal(r1.counts, r2.counts)


def test_fleet_kinds_deterministic_and_clamped():
    k1 = fleet_kinds(50, 64, seed=9, prefill=5)
    k2 = fleet_kinds(50, 64, seed=9, prefill=5)
    assert np.array_equal(k1, k2)
    assert k1.shape == (64, 50)
    # clamped: running length never goes negative
    length = np.full(50, 5)
    for c in range(64):
        length += np.where(k1[c] == 1, -1, 1)
        assert (length >= 0).all()


def test_template_round_trip():
    """export_instance on a fresh harness reproduces the template row."""
    t = build_template("LinkedQ", "optane-clwb", ops=32)
    h = make_instance_harness(ALL_QUEUES["LinkedQ"], "optane-clwb",
                              area_nodes=t.harness.mem.area_nodes)
    row = export_instance(h, t.dims)
    assert row is not None
    for key, val in t.row.items():
        if key == "slots":
            assert val == row["slots"]
        elif isinstance(val, np.ndarray):
            assert np.array_equal(val, row[key]), key
        else:
            assert val == row[key], key


jax = pytest.importorskip("jax", reason="jax backend tests need jax")


@pytest.mark.parametrize("queue", ["DurableMSQ", "UnlinkedQ", "OptLinkedQ"])
def test_jax_backend_matches_run_batched(queue):
    """The jax backend passes the same bit-identity gate (reduced cells;
    the full matrix runs on the numpy reference above and the two backends
    share the run_batched oracle)."""
    cfg = FleetConfig(queue=queue, model="optane-clwb", instances=9, ops=64,
                      chunk=32, backend="jax", seed=3)
    res = run_fleet(cfg)
    assert res.backend == "jax"
    _assert_all_ok(res, sample=8)


def test_jax_bail_rejoin_exact():
    rng = np.random.default_rng(5)
    cfg = FleetConfig(queue="LinkedQ", model="cxl", instances=6, ops=60,
                      chunk=20, backend="jax", prefill=3, seed=2)
    kinds = (rng.random((cfg.ops, cfg.instances)) < 0.65).astype(np.uint8)
    res = run_fleet(cfg, kinds=kinds)
    assert res.bails > 0
    _assert_all_ok(res, sample=6)


def test_jax_matches_numpy_counts():
    """Backend cross-check: identical counts arrays, not just sampled."""
    for queue in ("MSQ", "OptUnlinkedQ"):
        base = dict(queue=queue, model="eadr", instances=8, ops=48,
                    chunk=24, seed=13)
        rn = run_fleet(FleetConfig(backend="numpy", **base))
        rj = run_fleet(FleetConfig(backend="jax", **base))
        assert np.array_equal(rn.counts, rj.counts)


# ---- opcode-interpreting backends: the full matrix -----------------------
# The unrolled jax stepper shares its trace with the numpy reference line
# by line, so reduced cells suffice above.  The jax-opcode and pallas
# backends interpret the encoded opcode *tables* instead -- a second
# program representation -- so they carry the full 8 queues x 3 models
# bit-identity gate themselves.

@pytest.mark.parametrize("model", MODELS)
@pytest.mark.parametrize("queue", list(ALL_QUEUES))
@pytest.mark.parametrize("backend", ["jax-opcode", "pallas"])
def test_opcode_backends_match_run_batched(backend, queue, model):
    cfg = FleetConfig(queue=queue, model=model, instances=5, ops=48,
                      chunk=24, backend=backend, seed=3)
    res = run_fleet(cfg)
    assert res.backend == backend
    _assert_all_ok(res, sample=5)


@pytest.mark.parametrize("backend", ["jax-opcode", "pallas"])
def test_opcode_backend_bail_rejoin_exact(backend):
    rng = np.random.default_rng(5)
    cfg = FleetConfig(queue="LinkedQ", model="cxl", instances=6, ops=60,
                      chunk=20, backend=backend, prefill=3, seed=2)
    kinds = (rng.random((cfg.ops, cfg.instances)) < 0.65).astype(np.uint8)
    res = run_fleet(cfg, kinds=kinds)
    assert res.bails > 0
    _assert_all_ok(res, sample=6)


@pytest.mark.parametrize("backend", ["jax-opcode", "pallas"])
def test_opcode_backend_matches_numpy_counts(backend):
    """Full counts arrays equal to the numpy reference, not just sampled
    (epoch reclamation included: 200 ops cross three advances)."""
    base = dict(queue="OptLinkedQ", model="optane-clwb", instances=5,
                ops=200, chunk=50, seed=7)
    rn = run_fleet(FleetConfig(backend="numpy", **base))
    rb = run_fleet(FleetConfig(backend=backend, **base))
    assert rn.bails == rb.bails == 0
    assert np.array_equal(rn.counts, rb.counts)
