"""The paper's two headline metrics, asserted exactly.

* fences per update operation: 1 for the four new queues (the Cohen et al.
  lower bound), more for DurableMSQ, many for IzraelevitzQ;
* post-flush accesses: **zero** for the second-amendment queues
  (OptUnlinkedQ / OptLinkedQ), nonzero for everything else durable.
"""
import pytest

from repro.core import ALL_QUEUES, QueueHarness

OPTIMAL = ["UnlinkedQ", "LinkedQ", "OptUnlinkedQ", "OptLinkedQ"]
ZERO_POST_FLUSH = ["OptUnlinkedQ", "OptLinkedQ"]


def _run_ops(name, n_ops=200, area_nodes=1024):
    h = QueueHarness(ALL_QUEUES[name], nthreads=1, area_nodes=area_nodes)
    q = h.queue
    base = h.nvram.total_stats()
    for i in range(n_ops // 2):
        q.enqueue(0, i)
    for i in range(n_ops // 2):
        assert q.dequeue(0) == i
    delta = h.nvram.total_stats().minus(base)
    return h, delta


@pytest.mark.parametrize("name", OPTIMAL)
def test_one_fence_per_op(name):
    n_ops = 200
    h, d = _run_ops(name, n_ops)
    # allocator area setup adds one amortized fence; allow tiny slack
    assert d.fences <= n_ops + 2, f"{name}: {d.fences} fences for {n_ops} ops"
    assert d.fences >= n_ops, f"{name}: missing fences ({d.fences})"


def test_durable_msq_more_fences():
    n_ops = 200
    _, d = _run_ops("DurableMSQ", n_ops)
    # 2 per enqueue + 1 per dequeue = 1.5/op
    assert d.fences >= int(1.5 * n_ops)


def test_izraelevitz_many_fences():
    n_ops = 100
    _, d = _run_ops("IzraelevitzQ", n_ops)
    assert d.fences >= 4 * n_ops   # one per shared access


@pytest.mark.parametrize("name", ZERO_POST_FLUSH)
def test_zero_post_flush_accesses(name):
    _, d = _run_ops(name, n_ops=400, area_nodes=64)  # force node reuse too
    assert d.post_flush_accesses == 0, (
        f"{name}: {d.post_flush_accesses} accesses to flushed content")


@pytest.mark.parametrize("name", ["UnlinkedQ", "LinkedQ", "DurableMSQ"])
def test_first_amendment_has_post_flush_accesses(name):
    _, d = _run_ops(name, n_ops=200)
    assert d.post_flush_accesses > 0, (
        f"{name} unexpectedly avoids flushed content -- metric broken?")


@pytest.mark.parametrize("name", ZERO_POST_FLUSH)
def test_zero_post_flush_multithreaded(name):
    h = QueueHarness(ALL_QUEUES[name], nthreads=4, area_nodes=256)
    plans = [[("enq", (t, i)) for i in range(30)] + [("deq", None)] * 30
             for t in range(4)]
    res = h.run_scheduled(plans, seed=11)
    assert not res.crashed
    assert res.stats.post_flush_accesses == 0


def test_opt_faster_than_durable_msq_simulated():
    """The paper's bottom line: the second amendment wins on simulated time."""
    _, d_opt = _run_ops("OptUnlinkedQ", 400)
    _, d_dur = _run_ops("DurableMSQ", 400)
    assert d_opt.time_ns < d_dur.time_ns, (
        f"OptUnlinkedQ {d_opt.time_ns:.0f}ns !< DurableMSQ {d_dur.time_ns:.0f}ns")
