"""Durable linearizability under crash injection (paper §7).

Strategy: run multi-threaded workloads under the deterministic scheduler,
inject a full-system crash at a chosen global step, apply the adversarial
crash semantics (Assumption-1 per-line prefixes; pending flushes/NT stores
survive or not), run the queue's recovery, drain the recovered queue and
check the result against the pre-crash event log with the checker of
``repro.core.harness``.
"""
import pytest

from repro.core import (DURABLE_QUEUES, QueueHarness,
                        check_durable_linearizability, split_at_crash)


def _plans(nthreads, per_thread, tag=None):
    plans = []
    for t in range(nthreads):
        p = []
        for i in range(per_thread):
            item = (t, i) if tag is None else (tag, t, i)
            p.append(("enq", item))
            if i % 2 == 1:
                p.append(("deq", None))
        plans.append(p)
    return plans


def _crash_run(name, crash_at, mode, seed, nthreads=3, per_thread=6):
    h = QueueHarness(DURABLE_QUEUES[name], nthreads=nthreads, area_nodes=256)
    res = h.run_scheduled(_plans(nthreads, per_thread), seed=seed,
                          crash_at=crash_at)
    pre_events, _ = split_at_crash(h.events)
    pre_ops = list(res.ops)
    h.crash_and_recover(mode=mode, seed=seed)
    recovered = h.queue.drain(0)
    ok, why = check_durable_linearizability(pre_ops, pre_events, recovered)
    assert ok, (f"{name} crash_at={crash_at} mode={mode} seed={seed}: {why}\n"
                f"recovered={recovered!r}")
    return h, res


def _count_steps(name, seed, nthreads=3, per_thread=6):
    h = QueueHarness(DURABLE_QUEUES[name], nthreads=nthreads, area_nodes=256)
    from repro.core.scheduler import Scheduler
    sched = Scheduler(h.nvram, seed=seed)
    sched.run([h.make_worker(t, p)
               for t, p in enumerate(_plans(nthreads, per_thread))])
    return sched.steps


@pytest.mark.parametrize("name", sorted(DURABLE_QUEUES))
@pytest.mark.parametrize("mode", ["min", "random", "max"])
def test_crash_sweep(name, mode):
    """Crash at a spread of global steps; every recovery must be durably
    linearizable."""
    seed = 3
    total = _count_steps(name, seed)
    points = sorted(set([1, 2, 3, 5, 8, 13, total // 7, total // 3,
                         total // 2, 2 * total // 3, total - 2]))
    for crash_at in points:
        if crash_at <= 0:
            continue
        _crash_run(name, crash_at, mode, seed)


@pytest.mark.parametrize("name", sorted(DURABLE_QUEUES))
def test_crash_many_seeds(name):
    for seed in range(8):
        total = _count_steps(name, seed)
        crash_at = (seed * 37 + 11) % max(total - 1, 1) + 1
        _crash_run(name, crash_at, "random", seed)


@pytest.mark.parametrize("name", sorted(DURABLE_QUEUES))
def test_recovered_queue_still_works(name):
    h, _ = _crash_run(name, crash_at=40, mode="random", seed=1)
    q = h.queue
    for i in range(20):
        q.enqueue(0, ("post", i))
    assert [q.dequeue(0) for _ in range(20)] == [("post", i) for i in range(20)]
    assert q.dequeue(0) is None


@pytest.mark.parametrize("name", sorted(DURABLE_QUEUES))
def test_double_crash(name):
    """Crash, recover, run more ops, crash again, recover again."""
    h = QueueHarness(DURABLE_QUEUES[name], nthreads=2, area_nodes=256)
    res = h.run_scheduled(_plans(2, 4, tag="e1"), seed=5, crash_at=30)
    h.crash_and_recover(mode="random", seed=5)
    # second epoch of operations
    h.ops = []
    h.events.clear()
    res2 = h.run_scheduled(_plans(2, 4, tag="e2"), seed=6, crash_at=25)
    pre_events, _ = split_at_crash(h.events)
    pre_ops = list(res2.ops)
    h.crash_and_recover(mode="random", seed=7)
    recovered = h.queue.drain(0)
    # validate only epoch-2 semantics: epoch-1 leftovers form a prefix
    epoch2_items = {it for p in _plans(2, 4, tag="e2")
                    for (k, it) in p if k == "enq"}
    rec2 = [it for it in recovered if it in epoch2_items]
    leftovers = [it for it in recovered if it not in epoch2_items]
    assert leftovers == recovered[:len(leftovers)], \
        "epoch-1 leftovers must form a FIFO prefix"
    # restrict the history to epoch-2 items (epoch-1 leftovers flowing
    # through epoch-2 dequeues are legal but out of scope for the checker)
    pre_events = [ev for ev in pre_events
                  if len(ev) < 2 or ev[1] in epoch2_items]
    ok, why = check_durable_linearizability(pre_ops, pre_events, rec2)
    assert ok, f"{name} second crash: {why} (recovered={recovered!r})"


@pytest.mark.parametrize("name", ["OptUnlinkedQ", "OptLinkedQ"])
def test_crash_during_heavy_reuse(name):
    """Small areas force node recycling before the crash."""
    h = QueueHarness(DURABLE_QUEUES[name], nthreads=2, area_nodes=16)
    plans = []
    for t in range(2):
        p = []
        for i in range(30):
            p.append(("enq", (t, i)))
            p.append(("deq", None))
        plans.append(p)
    res = h.run_scheduled(plans, seed=9, crash_at=900)
    pre_events, _ = split_at_crash(h.events)
    h.crash_and_recover(mode="random", seed=2)
    recovered = h.queue.drain(0)
    ok, why = check_durable_linearizability(list(res.ops), pre_events,
                                            recovered)
    assert ok, f"{name}: {why} (recovered={recovered!r})"
