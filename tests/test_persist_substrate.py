"""WAL / cursor / checkpoint substrate: the paper's guidelines at file
granularity, including torn-write (crash-prefix) recovery."""
import os

import numpy as np

from repro.persist import CursorFile, WriteAheadLog
from repro.checkpoint import DurableCheckpointer


def test_wal_roundtrip(tmp_path):
    p = str(tmp_path / "a.wal")
    w = WriteAheadLog(p)
    for i in range(10):
        w.append(f"rec{i}".encode())
    w.fence()
    w.close()
    assert [r.decode() for r in WriteAheadLog.replay(p)] == \
        [f"rec{i}" for i in range(10)]


def test_wal_group_commit_one_fence(tmp_path):
    w = WriteAheadLog(str(tmp_path / "a.wal"))
    for i in range(100):
        w.append(b"x" * 50)
    w.fence()
    assert w.stats.fences == 1
    assert w.stats.appends == 100
    assert w.stats.reads_after_write == 0


def test_wal_torn_tail_is_prefix(tmp_path):
    p = str(tmp_path / "a.wal")
    w = WriteAheadLog(p)
    for i in range(5):
        w.append(f"rec{i}".encode())
    w.fence()
    w.close()
    # simulate a torn tail: truncate mid-record
    size = os.path.getsize(p)
    with open(p, "r+b") as f:
        f.truncate(size - 3)
    got = [r.decode() for r in WriteAheadLog.replay(p)]
    assert got == [f"rec{i}" for i in range(4)]   # longest valid prefix


def test_wal_corrupt_middle_stops_prefix(tmp_path):
    p = str(tmp_path / "a.wal")
    w = WriteAheadLog(p)
    for i in range(5):
        w.append(f"rec{i}".encode())
    w.fence()
    w.close()
    with open(p, "r+b") as f:
        f.seek(20)
        f.write(b"\xff\xff")
    got = WriteAheadLog.replay(p)
    assert len(got) < 5


def test_cursor_monotone_recovery(tmp_path):
    p = str(tmp_path / "c.bin")
    c = CursorFile(p)
    for v in (3, 7, 11):
        c.advance(v)
    c.close()
    assert CursorFile.recover(p) == 11


def test_cursor_torn_write_falls_back(tmp_path):
    """Destroying the most recent slot must expose the penultimate value
    (the paper's two-record trick)."""
    p = str(tmp_path / "c.bin")
    c = CursorFile(p)
    c.advance(5)
    c.advance(9)
    c.close()
    # seq=2 went to slot 0; corrupt it
    with open(p, "r+b") as f:
        f.seek(0)
        f.write(b"\xde\xad\xbe\xef")
    v = CursorFile.recover(p)
    assert v == 5


def test_cursor_max_across_workers(tmp_path):
    paths = []
    for w, v in enumerate((4, 9, 2)):
        p = str(tmp_path / f"c{w}.bin")
        c = CursorFile(p)
        c.advance(v)
        c.close()
        paths.append(p)
    assert CursorFile.recover_max(paths) == 9


# ------------------------------------------------------------- checkpointer
def _tree(step):
    return {"w": np.full((4, 4), float(step)), "b": np.arange(3.0) + step,
            "nested": [{"x": np.ones((2,)) * step}]}


def test_checkpoint_save_restore(tmp_path):
    ck = DurableCheckpointer(str(tmp_path), background=False)
    ck.save(10, {0: _tree(10)}, meta={"data_cursor": 3})
    step, shards, meta = ck.restore_latest()
    assert step == 10 and meta["data_cursor"] == 3
    np.testing.assert_array_equal(shards[0]["w"], _tree(10)["w"])
    assert shards[0]["nested"][0]["x"][0] == 10


def test_checkpoint_latest_wins_and_gc(tmp_path):
    ck = DurableCheckpointer(str(tmp_path), keep=2, background=False)
    for s in (10, 20, 30):
        ck.save(s, {0: _tree(s)})
    step, shards, _ = ck.restore_latest()
    assert step == 30
    steps = [s for s, _ in ck.scan()]
    assert steps == [20, 30]     # keep=2


def test_checkpoint_uncommitted_ignored(tmp_path):
    """A crash mid-save leaves shards without COMMIT: recovery must ignore
    it (the un-`linked` node rule)."""
    ck = DurableCheckpointer(str(tmp_path), background=False)
    ck.save(10, {0: _tree(10)})
    # simulate crash during save of step 20: shard written, no COMMIT
    ck._write_shard(20, 0, _tree(20))
    step, shards, _ = ck.restore_latest()
    assert step == 10
    assert shards[0]["w"][0, 0] == 10.0


def test_checkpoint_torn_commit_ignored(tmp_path):
    ck = DurableCheckpointer(str(tmp_path), background=False)
    ck.save(10, {0: _tree(10)})
    ck._write_shard(20, 0, _tree(20))
    with open(os.path.join(str(tmp_path), "step_00000020", "COMMIT"),
              "wb") as f:
        f.write(b"\x01\x02garbage")
    step, _, _ = ck.restore_latest()
    assert step == 10


def test_checkpoint_one_commit_fence_per_save(tmp_path):
    ck = DurableCheckpointer(str(tmp_path), background=False)
    ck.save(1, {0: _tree(1), 1: _tree(2), 2: _tree(3)})   # 3 shards
    assert ck.commit_fences == 1


def test_checkpoint_background_async(tmp_path):
    ck = DurableCheckpointer(str(tmp_path), background=True)
    ck.save(5, {0: _tree(5)})
    ck.wait()
    assert ck.restore_latest()[0] == 5
