"""Queue-enumeration drift guards.

``repro.core.DURABLE_QUEUES`` is the single source of truth for "which
queues exist"; the benchmark CLI and the crash-sweep sharding must derive
from it so a newly added queue cannot silently drop out of benchmarks,
contention profiles or the durability gate.
"""
import inspect

from repro.core import ALL_QUEUES, DURABLE_QUEUES


def test_benchmark_durable_list_derives_from_registry():
    from benchmarks.run import DURABLE
    assert DURABLE == list(DURABLE_QUEUES), (
        "benchmarks/run.py DURABLE drifted from repro.core.DURABLE_QUEUES; "
        "derive it, don't copy it")
    # no hand-maintained queue-name literals left in the module source
    src = inspect.getsource(inspect.getmodule(__import__("benchmarks.run",
                                                         fromlist=["run"])))
    assert 'DURABLE = list(DURABLE_QUEUES)' in src


def test_crash_sweep_shards_cover_registry():
    """The CI matrix shards by sorted queue name over the same registry:
    every durable queue lands in exactly one shard and no shard is empty."""
    from repro.crash.__main__ import _shard

    names = sorted(DURABLE_QUEUES)
    shards = [_shard(names, f"{k}/4") for k in range(4)]
    assert sorted(q for s in shards for q in s) == names
    assert all(shards), "a CI crash-sweep shard would run empty"


def test_crash_sweep_default_derives_from_registry():
    import repro.crash.__main__ as crash_main

    src = inspect.getsource(crash_main)
    assert '",".join(sorted(DURABLE_QUEUES))' in src, (
        "crash-sweep --queues default no longer derives from "
        "repro.core.DURABLE_QUEUES")


def test_learned_profiles_cover_every_queue():
    """The learned-contention axis must cover all 8 queues (MSQ included:
    the volatile baseline gets a measured profile too)."""
    from benchmarks.workloads import load_learned_profiles

    profiles = load_learned_profiles()
    missing = set(ALL_QUEUES) - set(profiles)
    assert not missing, (
        f"benchmarks/profiles/learned.json is missing {sorted(missing)}; "
        "re-run `python benchmarks/run.py fit-profiles`")
