"""Run-manifest schema and the perf-trajectory gate.

Covers the three pieces of observability plumbing that CI leans on:
``repro.obs.manifest`` (build/validate/write/load round trip, the
``x.csv -> x.manifest.json`` naming convention), ``PhaseProfiler``
accounting (nesting hands off at a shared timestamp, so phase totals sum
to covered wall exactly), and ``benchmarks/bench_history.py`` (fold run
manifests into a ``BENCH_<pr>.json`` snapshot; compare flags an injected
30% regression, tolerates an 8% wobble, warns in between, and is
direction-aware for higher-is-better cells).
"""
import json
import time

import pytest

import benchmarks.bench_history as bh
from repro.obs import (ManifestError, PhaseProfiler, build_manifest,
                       load_manifest, manifest_path_for, validate_manifest,
                       write_manifest)
from repro.obs.manifest import MANIFEST_SCHEMA


def _manifest(headline=None, **kw):
    return build_manifest(
        "fastpath-smoke", config={"threads": 4, "ops": 100},
        metrics=[{"queue": "DurableMSQ", "us_per_op": 4.7}],
        headline=headline or {"fastpath/DurableMSQ/compiled_us_per_op": 4.7},
        wall_s=1.25, **kw)


# ---------------------------------------------------------------- manifest

def test_manifest_round_trip(tmp_path):
    man = _manifest(phases={"heap-loop": {"ns": 1000, "count": 3}})
    assert man["schema"] == MANIFEST_SCHEMA
    assert man["git"] and "sha" in man["git"]
    assert man["env"]["python"]
    out = tmp_path / "smoke.manifest.json"
    write_manifest(man, out)
    back = load_manifest(out)
    assert back["headline"] == man["headline"]
    assert back["phases"]["heap-loop"]["count"] == 3
    assert back["wall_s"] == 1.25


def test_manifest_path_convention(tmp_path):
    assert str(manifest_path_for("out/fleet.csv")).endswith(
        "out/fleet.manifest.json")
    assert str(manifest_path_for(tmp_path / "x.csv")) == str(
        tmp_path / "x.manifest.json")


def test_manifest_extra_merges_top_level():
    man = _manifest(extra={"post_flush_attribution": {"OptUnlinkedQ": {}}})
    assert man["post_flush_attribution"] == {"OptUnlinkedQ": {}}


@pytest.mark.parametrize("mutate", [
    lambda m: m.pop("schema"),
    lambda m: m.__setitem__("schema", "bogus/v9"),
    lambda m: m.__setitem__("headline", {"k": "not-a-number"}),
    lambda m: m.__setitem__("metrics", "not-a-list"),
    lambda m: m.pop("subcommand"),
])
def test_manifest_validation_rejects_corruption(mutate):
    man = _manifest()
    mutate(man)
    with pytest.raises(ManifestError):
        validate_manifest(man)


def test_load_manifest_rejects_corrupt_file(tmp_path):
    path = tmp_path / "bad.manifest.json"
    man = _manifest()
    man["headline"] = {"cell": [1, 2]}
    path.write_text(json.dumps(man))
    with pytest.raises(ManifestError):
        load_manifest(path)


# ---------------------------------------------------------------- profiler

def test_profiler_nesting_sums_to_covered_wall():
    prof = PhaseProfiler()
    t0 = time.perf_counter_ns()
    prof.push("outer")
    time.sleep(0.002)
    prof.push("inner")
    time.sleep(0.002)
    prof.pop()
    time.sleep(0.002)
    prof.pop()
    wall = time.perf_counter_ns() - t0
    # handoff at a shared timestamp: no gaps, no double counting
    assert prof.total_ns() <= wall
    assert prof.total_ns() >= 0.95 * wall
    assert prof.counts == {"outer": 1, "inner": 1}
    assert prof.totals["inner"] >= 1_500_000  # ~2ms
    assert prof._stack == []


def test_profiler_us_per_op_and_merge():
    a, b = PhaseProfiler(), PhaseProfiler()
    a.totals = {"heap-loop": 4_000}
    a.counts = {"heap-loop": 2}
    b.totals = {"heap-loop": 2_000, "bookkeeping": 1_000}
    b.counts = {"heap-loop": 1, "bookkeeping": 1}
    a.merge(b)
    assert a.totals == {"heap-loop": 6_000, "bookkeeping": 1_000}
    assert a.counts == {"heap-loop": 3, "bookkeeping": 1}
    per = a.us_per_op(7)
    assert per["heap-loop"] == pytest.approx(6.0 / 7)
    assert a.as_dict()["bookkeeping"] == {"ns": 1_000, "count": 1}


# ------------------------------------------------------------ bench_history

def _write_manifest(tmp_path, name, headline):
    man = _manifest(headline=headline)
    path = tmp_path / name
    write_manifest(man, path)
    return str(path)


BASE = {
    "fastpath/DurableMSQ/compiled_us_per_op": 5.0,
    "fastpath/DurableMSQ/speedup_vs_cap": 60.0,
    "crash-sweep/recoveries_per_s": 2000.0,
}


def test_fold_snapshot_round_trip(tmp_path):
    m1 = _write_manifest(tmp_path, "a.manifest.json", dict(BASE))
    m2 = _write_manifest(tmp_path, "b.manifest.json",
                         {"fleet/m/off/Q/wall_us_per_op": 0.8})
    snap, warnings = bh.fold([m1, m2], pr=8)
    assert not warnings
    assert snap["schema"] == bh.SNAPSHOT_SCHEMA and snap["pr"] == 8
    assert len(snap["cells"]) == 4
    out = tmp_path / "BENCH_8.json"
    out.write_text(json.dumps(snap))
    assert bh.load_snapshot(str(out))["cells"] == snap["cells"]
    with pytest.raises(ManifestError):
        bh.validate_snapshot({**snap, "cells": {"k": "oops"}})


def _compare(tmp_path, scale_us, scale_rate=1.0, **kw):
    """Fold BASE, then compare a manifest whose us/op cells are scaled by
    ``scale_us`` and whose rate cells are scaled by ``scale_rate``."""
    base = _write_manifest(tmp_path, "base.manifest.json", dict(BASE))
    snap, _ = bh.fold([base], pr=8)
    cur = {k: v * (scale_us if k.endswith("_us_per_op") else scale_rate)
           for k, v in BASE.items()}
    man = _write_manifest(tmp_path, "cur.manifest.json", cur)
    return bh.compare(snap, [man], **kw)


def test_compare_flags_30pct_regression(tmp_path):
    res = _compare(tmp_path, scale_us=1.30)
    assert res["fails"] == 1
    status = {k: s for s, k, *_ in res["rows"]}
    assert status["fastpath/DurableMSQ/compiled_us_per_op"] == "FAIL"
    # unchanged cells stay green
    assert status["crash-sweep/recoveries_per_s"] == "ok"


def test_compare_tolerates_8pct_wobble(tmp_path):
    res = _compare(tmp_path, scale_us=1.08, scale_rate=0.93)
    assert res["fails"] == 0 and res["warns"] == 0


def test_compare_warns_between_thresholds(tmp_path):
    res = _compare(tmp_path, scale_us=1.12)
    assert res["fails"] == 0 and res["warns"] == 1


def test_compare_direction_aware(tmp_path):
    # recoveries_per_s and speedup_vs_cap are higher-is-better: a 40% DROP
    # is the regression; us/op improving must never trip the gate
    res = _compare(tmp_path, scale_us=0.5, scale_rate=0.6)
    failing = {k for s, k, *_ in res["rows"] if s == "FAIL"}
    assert failing == {"crash-sweep/recoveries_per_s",
                       "fastpath/DurableMSQ/speedup_vs_cap"}
    assert bh.is_higher_better("fleet/m/off/Q/wall_us_per_op") is False
    assert bh.is_higher_better("x/speedup_same_scale") is True


def test_compare_ignores_unshared_cells(tmp_path):
    base = _write_manifest(tmp_path, "base.manifest.json", dict(BASE))
    snap, _ = bh.fold([base], pr=8)
    man = _write_manifest(tmp_path, "new.manifest.json",
                          {"fleet/new/cell_us_per_op": 99.0})
    res = bh.compare(snap, [man])
    assert res["rows"] == [] and res["fails"] == 0
    assert res["only_current"] == ["fleet/new/cell_us_per_op"]
    assert set(res["only_base"]) == set(BASE)


def test_bench_history_cli_smoke(tmp_path, capsys):
    m = _write_manifest(tmp_path, "s.manifest.json", dict(BASE))
    snap_path = tmp_path / "BENCH_8.json"
    assert bh.main(["fold", "--pr", "8", "--out", str(snap_path), m]) == 0
    assert bh.main(["compare", "--baseline", str(snap_path), m]) == 0
    slow = {k: v * 2 if k.endswith("_us_per_op") else v
            for k, v in BASE.items()}
    m_slow = _write_manifest(tmp_path, "slow.manifest.json", slow)
    assert bh.main(["compare", "--baseline", str(snap_path), m_slow]) == 1
    out = capsys.readouterr().out
    assert "FAIL fastpath/DurableMSQ/compiled_us_per_op" in out
    assert bh.main(["show", str(snap_path)]) == 0


def test_compare_summary_writes_delta_table(tmp_path):
    """``compare --summary`` appends the GFM delta table CI shows in the
    job summary: one row per shared cell with a status mark, plus
    gone/new rows for unshared cells."""
    base = _write_manifest(tmp_path, "base.manifest.json", dict(BASE))
    snap_path = tmp_path / "BENCH_8.json"
    assert bh.main(["fold", "--pr", "8", "--out", str(snap_path), base]) == 0
    cur = {k: (v * 1.5 if k.endswith("_us_per_op") and "compiled" in k else v)
           for k, v in BASE.items()}
    del cur["crash-sweep/recoveries_per_s"]
    cur["fleet/m/off/Q/pallas_wall_us_per_op"] = 1.0
    m = _write_manifest(tmp_path, "cur.manifest.json", cur)
    summary = tmp_path / "summary.md"
    rc = bh.main(["compare", "--baseline", str(snap_path),
                  "--summary", str(summary), m])
    assert rc == 1  # the 50% regression still fails the gate
    text = summary.read_text()
    assert text.startswith("### Perf trajectory vs `BENCH_8.json` (PR 8)")
    assert "| ❌ FAIL | `fastpath/DurableMSQ/compiled_us_per_op` |" in text
    assert "| ✅ ok | `fastpath/DurableMSQ/speedup_vs_cap` |" in text
    assert "| gone | `crash-sweep/recoveries_per_s` |" in text
    assert "| new | `fleet/m/off/Q/pallas_wall_us_per_op` |" in text
    assert "2 cells compared: 1 fail, 0 warn" in text
    # appends (CI reuses $GITHUB_STEP_SUMMARY across steps)
    assert bh.main(["compare", "--baseline", str(snap_path),
                    "--summary", str(summary), base]) == 0
    assert summary.read_text().count("### Perf trajectory") == 2


def test_committed_bench_8_snapshot_is_valid():
    """The committed trajectory bootstrap: BENCH_8.json exists, validates,
    and carries the three cell families the gate is built around."""
    path = bh.latest_snapshot_path()
    assert path is not None, "no committed BENCH_*.json under benchmarks/history/"
    snap = bh.load_snapshot(path)
    cells = snap["cells"]
    assert any(k.startswith("fastpath/") and k.endswith("_us_per_op")
               for k in cells)
    assert any(k.startswith("fleet/") and k.endswith("wall_us_per_op")
               for k in cells)
    assert "crash-sweep/recoveries_per_s" in cells
