"""Property tests for the burst predictor (repro.core.burst).

The burst executor's whole optimism rests on one pure function:
:func:`repro.core.burst.predict_grants` takes per-op duration tables and
claims to reproduce, in one vectorized pass, the exact grant order and
clock windows the per-op ``ClockScheduler`` ``(time, tid)`` heap would
produce.  These tests pit it against a literal ``heapq`` replay:

  P1. full pools: with every thread's ops pooled, the predicted
      (tid, start, end) sequence equals the heap replay bit-for-bit --
      including ties, which the heap breaks by tid;
  P2. windowed pools: when threads hold back unpooled ops, the valid
      prefix ``N`` of the prediction still matches the replay of the
      *full* schedule exactly (the cutoff never admits a grant the
      re-entering thread would have displaced);
  P3. tie-breaking, directed: identical clocks and identical durations
      degenerate to round-robin by thread id.

Durations and start clocks are multiples of 0.5ns, the invariant the
engine's latency tables guarantee and the predictor's exactness
argument relies on.  Run as a seeded-random sweep (always on; no
optional deps) and as hypothesis properties when the optional dev
dependency is installed (CI).
"""
import heapq
import random

import numpy as np

from repro.core.burst import predict_grants

try:
    from hypothesis import given, settings, strategies as st
    _HAS_HYPOTHESIS = True
except ImportError:
    _HAS_HYPOTHESIS = False


# ----------------------------------------------------------- heap reference

def _heap_replay(tids, t0s, durs):
    """Literal ClockScheduler heap: pop the earliest (time, tid), run
    that thread's next op, push it back at its new clock."""
    heap = [(t0, t) for t, t0 in zip(tids, t0s)]
    heapq.heapify(heap)
    cursor = dict.fromkeys(tids, 0)
    grants = []
    while heap:
        t0, t = heapq.heappop(heap)
        d = durs[t][cursor[t]]
        cursor[t] += 1
        grants.append((t, t0, t0 + d))
        if cursor[t] < len(durs[t]):
            heapq.heappush(heap, (t0 + d, t))
    return grants


def _predict(tids, t0s, durs, pooled):
    """Run predict_grants over the pooled prefix of each schedule."""
    dur = np.concatenate([np.asarray(durs[t][:pooled[t]], np.float64)
                          for t in tids])
    seg_len = np.array([pooled[t] for t in tids], np.int64)
    seg_t0 = np.array(t0s, np.float64)
    pool_tid = np.repeat(np.array(tids, np.int64), seg_len)
    more = np.array([pooled[t] < len(durs[t]) for t in tids], bool)
    return predict_grants(dur, seg_len, seg_t0, pool_tid, more)


def _check(tids, t0s, durs, pooled):
    order, g_tid, g_start, g_end, N = _predict(tids, t0s, durs, pooled)
    ref = _heap_replay(tids, t0s, durs)
    total = sum(pooled[t] for t in tids)
    if all(pooled[t] == len(durs[t]) for t in tids):
        assert N == total, "full pools must not be truncated"
    assert 0 <= N <= total
    for i in range(N):
        rt, rs, re = ref[i]
        assert int(g_tid[i]) == rt, f"grant {i}: tid {g_tid[i]} != {rt}"
        # bit-exact clock windows, not approximate ones: the engine's
        # verification compares keys derived from this interleave
        assert float(g_start[i]) == rs, f"grant {i}: start mismatch"
        assert float(g_end[i]) == re, f"grant {i}: end mismatch"


# --------------------------------------------------------------- P1/P2 sweep

def _random_case(rng, max_threads=8, max_ops=40):
    nthreads = rng.randint(2, max_threads)
    tids = list(range(nthreads))
    # coarse palettes make collisions (= heap ties) common
    t0s = [rng.choice([0.0, 0.5, 1.0, 2.5]) for _ in tids]
    palette = [0.5, 0.5, 1.0, 1.5, 2.0, 3.5]
    durs = {t: [rng.choice(palette)
                for _ in range(rng.randint(1, max_ops))] for t in tids}
    return tids, t0s, durs


def test_full_pool_matches_heap_seeded():
    rng = random.Random(1302)
    for _ in range(150):
        tids, t0s, durs = _random_case(rng)
        pooled = {t: len(durs[t]) for t in tids}
        _check(tids, t0s, durs, pooled)


def test_windowed_pool_matches_heap_seeded():
    rng = random.Random(4177)
    for _ in range(150):
        tids, t0s, durs = _random_case(rng)
        pooled = {t: rng.randint(1, len(durs[t])) for t in tids}
        _check(tids, t0s, durs, pooled)


# ------------------------------------------------------------- P3: directed

def test_identical_durations_round_robin():
    tids = [0, 1, 2, 3]
    t0s = [0.0, 0.0, 0.0, 0.0]
    durs = {t: [1.0] * 5 for t in tids}
    pooled = {t: 5 for t in tids}
    order, g_tid, g_start, g_end, N = _predict(tids, t0s, durs, pooled)
    assert N == 20
    assert g_tid.tolist() == [0, 1, 2, 3] * 5
    assert g_start.tolist() == [float(r) for r in range(5)
                                for _ in range(4)]
    _check(tids, t0s, durs, pooled)


def test_tie_at_cutoff_keeps_lower_tids():
    # thread 2 holds back an op and re-enters at clock 1.0; grants AT
    # 1.0 survive only for tids below it, exactly like the heap's tuple
    # comparison would order them
    tids = [0, 1, 2, 3]
    t0s = [1.0, 1.0, 0.0, 1.0]
    durs = {0: [1.0], 1: [1.0], 2: [1.0, 1.0], 3: [1.0]}
    pooled = {0: 1, 1: 1, 2: 1, 3: 1}
    order, g_tid, g_start, g_end, N = _predict(tids, t0s, durs, pooled)
    ref = _heap_replay(tids, t0s, durs)
    assert [int(x) for x in g_tid[:N]] == [t for t, _, _ in ref[:N]]
    assert N == 3          # grant of tid 2 at 0.0, then 0 and 1 at 1.0
    _check(tids, t0s, durs, pooled)


# ------------------------------------------------- hypothesis (optional dep)

if _HAS_HYPOTHESIS:
    _halves = st.integers(min_value=1, max_value=7).map(lambda k: k * 0.5)
    _sched = st.lists(_halves, min_size=1, max_size=25)

    @settings(max_examples=200, deadline=None)
    @given(st.data())
    def test_full_pool_matches_heap_hypothesis(data):
        nthreads = data.draw(st.integers(2, 8))
        tids = list(range(nthreads))
        t0s = [data.draw(_halves) - 0.5 for _ in tids]
        durs = {t: data.draw(_sched) for t in tids}
        pooled = {t: len(durs[t]) for t in tids}
        _check(tids, t0s, durs, pooled)

    @settings(max_examples=200, deadline=None)
    @given(st.data())
    def test_windowed_pool_matches_heap_hypothesis(data):
        nthreads = data.draw(st.integers(2, 8))
        tids = list(range(nthreads))
        t0s = [data.draw(_halves) - 0.5 for _ in tids]
        durs = {t: data.draw(_sched) for t in tids}
        pooled = {t: data.draw(st.integers(1, len(durs[t])))
                  for t in tids}
        _check(tids, t0s, durs, pooled)
