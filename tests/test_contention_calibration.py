"""Calibration: the batched contention model vs exact-scheduler ground truth.

The acceptance criterion for the contention layer: batched multi-thread
persist-instruction totals (flushes + fences) and flushed-access totals
(post-flush accesses) must land within tolerance of what the exact
per-primitive OS-thread scheduler -- where CAS failures, retries and
helping actually execute -- produces at 2--8 threads, for all seven
durable queues: **15%** for the hand-fit ``retry_profile()`` constants
(``--contention on``), **10%** for the trace-learned profiles
(``--contention learned``, fit by ``repro.trace.fit`` -- see
``python benchmarks/run.py fit-profiles``).  The 12/16-thread extension
of the learned envelope lives in the slow-marked part of
``tests/test_trace_fit.py`` (16%, with multi-seed exact ground truth
for the fence-heavy worst cells).

The exact scheduler is the ground truth because its retries are real: a
thread that loses the link CAS re-reads the tail, takes the helping path,
and re-touches flushed lines exactly as the algorithm dictates.  The
contention model replays those costs statistically (see
repro.core.contention); the hand profiles were fit against these very
runs, and the learned profiles are regression-fit against traces of them.

Small absolute floors keep the relative tolerance meaningful where ground
truth is tiny (the second-amendment queues have zero post-flush accesses on
both sides, which must stay exactly zero -- see the property suite).
"""
import pytest

from repro.core import ALL_QUEUES, QueueHarness
from benchmarks.workloads import make_plans, resolve_contention

DURABLE7 = ["DurableMSQ", "IzraelevitzQ", "NVTraverseQ", "UnlinkedQ",
            "LinkedQ", "OptUnlinkedQ", "OptLinkedQ"]

TOLERANCES = {"on": 0.15, "learned": 0.10}
PF_FLOOR = 30        # absolute floor for the post-flush denominator
OPS_PER_THREAD = 24  # exact-scheduler runs are ~ms/op; keep runs small

# Deliberately NOT marked slow: this suite IS the PR's acceptance gate for
# the contention model, so CI must run it.  The ~2 min it costs is the
# price of exact-scheduler ground truth (computed once per cell and shared
# by both model variants); shrink OPS_PER_THREAD before slow-marking it.

_exact_cache = {}


def _counts(name, nthreads, engine, contention="on", seed=1):
    """(persist_instructions, post_flush_accesses) for one run."""
    if engine == "exact" and (name, nthreads) in _exact_cache:
        return _exact_cache[(name, nthreads)]
    h = QueueHarness(ALL_QUEUES[name], nthreads=nthreads, area_nodes=1024)
    plans, prefill = make_plans("pairs", nthreads, OPS_PER_THREAD)
    for i in range(prefill):
        h.queue.enqueue(0, ("pre", i))
    base = h.nvram.total_stats()
    if engine == "exact":
        res = h.run_scheduled(plans, seed=seed)
    else:
        _, cmodel = resolve_contention(contention, name)
        res = h.run_batched(plans, contention=cmodel)
    assert res.ops_completed == nthreads * OPS_PER_THREAD
    d = h.nvram.total_stats().minus(base)
    out = (d.flushes + d.fences, d.post_flush_accesses)
    if engine == "exact":
        _exact_cache[(name, nthreads)] = out
    return out


@pytest.mark.parametrize("name", DURABLE7)
@pytest.mark.parametrize("contention", ["on", "learned"])
def test_contended_batched_matches_exact_scheduler(name, contention):
    tol = TOLERANCES[contention]
    for nthreads in (2, 4, 8):
        persist_e, pf_e = _counts(name, nthreads, "exact")
        persist_b, pf_b = _counts(name, nthreads, "batched", contention)
        assert abs(persist_b - persist_e) <= tol * max(persist_e, 1), (
            f"{name} t{nthreads} [{contention}]: persist instructions "
            f"batched={persist_b} exact={persist_e} (> {tol:.0%} off)")
        assert abs(pf_b - pf_e) <= tol * max(pf_e, PF_FLOOR), (
            f"{name} t{nthreads} [{contention}]: flushed accesses "
            f"batched={pf_b} exact={pf_e} (> {tol:.0%} off)")


def test_contention_charges_grow_with_threads():
    """The modeled retry load must scale with the co-schedule width:
    more threads on one root => more charged retries per op."""
    per_op = []
    for nthreads in (2, 4, 8):
        h = QueueHarness(ALL_QUEUES["DurableMSQ"], nthreads=nthreads,
                         area_nodes=1024)
        plans, prefill = make_plans("pairs", nthreads, 40)
        for i in range(prefill):
            h.queue.enqueue(0, ("pre", i))
        h.run_batched(plans, contention=True)
        per_op.append(h.contention.retries_per_op())
    assert per_op[0] < per_op[1] < per_op[2]
    assert per_op[2] > 0.1


def test_contention_feeds_back_into_sim_time():
    """Charged retries advance the per-thread clocks, so a contended run's
    simulated makespan must exceed the uncontended one's."""
    def span(contention):
        h = QueueHarness(ALL_QUEUES["IzraelevitzQ"], nthreads=8,
                         area_nodes=1024)
        plans, prefill = make_plans("pairs", 8, 40)
        for i in range(prefill):
            h.queue.enqueue(0, ("pre", i))
        return h.run_batched(plans, contention=contention).sim_time_ns
    assert span(True) > span(None) * 1.05
