"""Calibration: the batched contention model vs exact-scheduler ground truth.

The acceptance criterion for the contention layer: batched multi-thread
persist-instruction totals (flushes + fences) and flushed-access totals
(post-flush accesses) must land within 15% of what the exact per-primitive
OS-thread scheduler -- where CAS failures, retries and helping actually
execute -- produces at 2--8 threads, for all seven durable queues.

The exact scheduler is the ground truth because its retries are real: a
thread that loses the link CAS re-reads the tail, takes the helping path,
and re-touches flushed lines exactly as the algorithm dictates.  The
contention model replays those costs statistically (see
repro.core.contention); its default ``retry_scale`` and the per-queue
``retry_profile()`` expected counts were fit against these very runs.

Small absolute floors keep the relative tolerance meaningful where ground
truth is tiny (the second-amendment queues have zero post-flush accesses on
both sides, which must stay exactly zero -- see the property suite).
"""
import pytest

from repro.core import ALL_QUEUES, QueueHarness
from benchmarks.workloads import make_plans

DURABLE7 = ["DurableMSQ", "IzraelevitzQ", "NVTraverseQ", "UnlinkedQ",
            "LinkedQ", "OptUnlinkedQ", "OptLinkedQ"]

TOLERANCE = 0.15
PF_FLOOR = 30        # absolute floor for the post-flush denominator
OPS_PER_THREAD = 24  # exact-scheduler runs are ~ms/op; keep runs small

# Deliberately NOT marked slow: this suite IS the PR's acceptance gate for
# the contention model, so CI must run it.  The ~2 min it costs is the
# price of exact-scheduler ground truth; shrink OPS_PER_THREAD before
# slow-marking it.


def _counts(name, nthreads, engine, seed=1):
    """(persist_instructions, post_flush_accesses) for one run."""
    h = QueueHarness(ALL_QUEUES[name], nthreads=nthreads, area_nodes=1024)
    plans, prefill = make_plans("pairs", nthreads, OPS_PER_THREAD)
    for i in range(prefill):
        h.queue.enqueue(0, ("pre", i))
    base = h.nvram.total_stats()
    if engine == "exact":
        res = h.run_scheduled(plans, seed=seed)
    else:
        res = h.run_batched(plans, contention=True)
    assert res.ops_completed == nthreads * OPS_PER_THREAD
    d = h.nvram.total_stats().minus(base)
    return d.flushes + d.fences, d.post_flush_accesses


@pytest.mark.parametrize("name", DURABLE7)
def test_contended_batched_matches_exact_scheduler(name):
    for nthreads in (2, 4, 8):
        persist_e, pf_e = _counts(name, nthreads, "exact")
        persist_b, pf_b = _counts(name, nthreads, "batched")
        assert abs(persist_b - persist_e) <= TOLERANCE * max(persist_e, 1), (
            f"{name} t{nthreads}: persist instructions batched={persist_b} "
            f"exact={persist_e} (> {TOLERANCE:.0%} off)")
        assert abs(pf_b - pf_e) <= TOLERANCE * max(pf_e, PF_FLOOR), (
            f"{name} t{nthreads}: flushed accesses batched={pf_b} "
            f"exact={pf_e} (> {TOLERANCE:.0%} off)")


def test_contention_charges_grow_with_threads():
    """The modeled retry load must scale with the co-schedule width:
    more threads on one root => more charged retries per op."""
    per_op = []
    for nthreads in (2, 4, 8):
        h = QueueHarness(ALL_QUEUES["DurableMSQ"], nthreads=nthreads,
                         area_nodes=1024)
        plans, prefill = make_plans("pairs", nthreads, 40)
        for i in range(prefill):
            h.queue.enqueue(0, ("pre", i))
        h.run_batched(plans, contention=True)
        per_op.append(h.contention.retries_per_op())
    assert per_op[0] < per_op[1] < per_op[2]
    assert per_op[2] > 0.1


def test_contention_feeds_back_into_sim_time():
    """Charged retries advance the per-thread clocks, so a contended run's
    simulated makespan must exceed the uncontended one's."""
    def span(contention):
        h = QueueHarness(ALL_QUEUES["IzraelevitzQ"], nthreads=8,
                         area_nodes=1024)
        plans, prefill = make_plans("pairs", 8, 40)
        for i in range(prefill):
            h.queue.enqueue(0, ("pre", i))
        return h.run_batched(plans, contention=contention).sim_time_ns
    assert span(True) > span(None) * 1.05
