"""Sharding-rule unit tests (regression: the MoE/dense rule-order bug)."""
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.distributed.sharding import param_shardings
from repro.launch.mesh import make_test_mesh
from repro.launch.steps import abstract_params


@pytest.fixture(scope="module")
def mesh():
    return make_test_mesh()


def _spec(shardings, *path):
    node = shardings
    for p in path:
        node = node[p]
    return node.spec


def test_moe_expert_weights_sharded_over_model(mesh):
    """Regression: dense ffn/w1 rule must NOT shadow the MoE rule -- expert
    dim goes on 'model' (EP), d on 'data' (FSDP)."""
    params = abstract_params(get_config("dbrx-132b"))
    sh = param_shardings(mesh, params)
    spec = _spec(sh, "stack", "sub0", "ffn", "w1")
    assert spec == P(None, "model", "data", None), spec
    spec2 = _spec(sh, "stack", "sub0", "ffn", "w2")
    assert spec2 == P(None, "model", None, "data"), spec2


def test_dense_ffn_weights_tp_sharded(mesh):
    params = abstract_params(get_config("yi-6b"))
    sh = param_shardings(mesh, params)
    assert _spec(sh, "stack", "sub0", "ffn", "w1") == P(None, "data", "model")
    assert _spec(sh, "stack", "sub0", "ffn", "w2") == P(None, "model", "data")
    assert _spec(sh, "stack", "sub0", "mixer", "wq") == P(None, "data", "model")
    assert _spec(sh, "stack", "sub0", "mixer", "wo") == P(None, "model", "data")


def test_norms_replicated(mesh):
    params = abstract_params(get_config("yi-6b"))
    sh = param_shardings(mesh, params)
    assert _spec(sh, "final_norm") == P(None)
    # stacked: leading period axis + the replicated feature dim
    assert _spec(sh, "stack", "sub0", "norm1") == P(None, None)


def test_no_fsdp_replicates_data_axis(mesh):
    params = abstract_params(get_config("yi-6b"))
    sh = param_shardings(mesh, params, fsdp=False)
    assert _spec(sh, "stack", "sub0", "ffn", "w1") == P(None, None, "model")
    assert _spec(sh, "embed") == P("model", None)


def test_mamba_weights(mesh):
    params = abstract_params(get_config("falcon-mamba-7b"))
    sh = param_shardings(mesh, params)
    assert _spec(sh, "stack", "sub0", "mixer", "in_proj") == \
        P(None, "data", "model")
    assert _spec(sh, "stack", "sub0", "mixer", "out_proj") == \
        P(None, "model", "data")
    assert _spec(sh, "stack", "sub0", "mixer", "A_log") == \
        P(None, "model", None)
