"""The crash-sweep subsystem (repro.crash): snapshot/restore crash engine,
exhaustive durable-linearizability sweeps, repro artifacts.

Four guarantees are pinned here:

* **snapshot == rerun**: restoring a per-step engine snapshot and crashing
  produces *exactly* the state a rerun-from-scratch ``crash_at=step`` run
  would crash into -- the whole sweep stands on this equivalence;
* **observation-only seam**: a snapshot/restore round-trip at every
  scheduler boundary leaves engine Stats bit-identical to an untouched run
  (mirroring the trace-tap guarantee);
* **exhaustive sweep passes**: every crash step x {min, random, max} plus
  the enumerated flush-subset outcomes is durably linearizable for all 7
  durable queues (reduced size in tier-1; the full standard workload in
  the slow suite and, sharded and blocking, in CI);
* **recovery idempotence**: recovering twice from the same crash image
  drains the same queue as recovering once.
"""
import pytest

from repro.core import (DURABLE_QUEUES, NVRAM, QueueHarness,
                        check_durable_linearizability, split_at_crash)
from repro.crash import (capture_run, choice_space, enumerate_choices,
                         failure_artifact, load_artifact, reproduce,
                         save_artifact, standard_plans, sweep_queue)
from repro.crash.capture import PERSIST_KINDS

STAT_FIELDS = ["reads", "writes", "cas", "flushes", "fences", "movntis",
               "post_flush_accesses", "cold_misses", "time_ns"]


def _harness(name, nthreads=3, area_nodes=64):
    return QueueHarness(DURABLE_QUEUES[name], nthreads=nthreads,
                        area_nodes=area_nodes)


# ---------------------------------------------------------------------------
# the load-bearing equivalence: snapshot path == rerun-from-scratch
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("name", ["DurableMSQ", "OptUnlinkedQ", "LinkedQ"])
def test_snapshot_crash_equals_rerun_from_scratch(name):
    """Restoring boundary s and crashing == rerunning with crash_at=s:
    same pre-crash history metadata, same recovered queue."""
    h = _harness(name)
    plans = standard_plans(3, 6)
    cap = capture_run(h, plans, seed=3)
    total = cap.total_steps
    for crash_at in [2, 7, total // 4, total // 2, 2 * total // 3, total - 1]:
        b = cap.boundaries[crash_at]
        h.nvram.restore(b.snap)
        h.crash_and_recover(mode="random", seed=11)
        rec_snap = h.queue.drain(0)
        # independent classic path
        h2 = _harness(name)
        r2 = h2.run_scheduled(standard_plans(3, 6), seed=3,
                              crash_at=crash_at)
        pre_events, _ = split_at_crash(h2.events)
        h2.crash_and_recover(mode="random", seed=11)
        rec_rerun = h2.queue.drain(0)
        assert rec_snap == rec_rerun, f"step {crash_at}"
        assert b.ops_len == len(r2.ops)
        assert b.completed == tuple(r.completed for r in r2.ops)
        assert b.items == tuple(r.item for r in r2.ops)
        assert cap.pre_crash_events(crash_at) == pre_events
        ok, why = check_durable_linearizability(
            cap.pre_crash_ops(crash_at), cap.pre_crash_events(crash_at),
            rec_snap)
        assert ok, f"step {crash_at}: {why}"


def test_capture_boundaries_and_kinds():
    h = _harness("DurableMSQ")
    cap = capture_run(h, standard_plans(2, 4), seed=1)
    assert len(cap.boundaries) == cap.total_steps + 1
    assert [b.step for b in cap.boundaries] == list(range(cap.total_steps + 1))
    assert len(cap.kinds) == cap.total_steps
    assert set(cap.kinds) <= {"read", "write", "cas", "flush", "fence",
                              "movnti"}
    # classification: a boundary adjacent to persist work is persist-adjacent
    for s in range(1, cap.total_steps + 1):
        cls = cap.boundary_class(s)
        adjacent = (cap.kinds[s - 1] in PERSIST_KINDS
                    or (s < cap.total_steps and cap.kinds[s] in PERSIST_KINDS))
        assert cls == ("persist-adjacent" if adjacent else "interior")
    # both classes occur on a real schedule
    classes = {cap.boundary_class(s) for s in range(1, cap.total_steps + 1)}
    assert classes == {"persist-adjacent", "interior"}


# ---------------------------------------------------------------------------
# observation-only: snapshot/restore round-trip cannot perturb Stats
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("name", ["DurableMSQ", "OptUnlinkedQ"])
def test_snapshot_roundtrip_stats_bit_identical(name):
    """A full snapshot + in-place restore at EVERY scheduler boundary must
    leave per-thread Stats (including time_ns) bit-identical to an
    untouched run, and not change the execution's outcome."""
    plans = standard_plans(3, 5)
    h_plain = _harness(name)
    h_plain.run_scheduled(standard_plans(3, 5), seed=2)

    h_rt = _harness(name)

    def roundtrip(step):
        h_rt.nvram.restore(h_rt.nvram.snapshot(volatile=True))

    h_rt.run_scheduled(plans, seed=2, snapshot_hook=roundtrip)

    sp, sr = h_plain.nvram.stats, h_rt.nvram.stats
    for t in range(3):
        for f in STAT_FIELDS:
            assert getattr(sp[t], f) == getattr(sr[t], f), \
                f"thread {t}: {f} perturbed by snapshot/restore round-trip"
    assert [r.item for r in h_plain.ops] == [r.item for r in h_rt.ops]
    assert h_plain.events == h_rt.events
    assert h_plain.queue.drain(0) == h_rt.queue.drain(0)


# ---------------------------------------------------------------------------
# the sweep itself
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("name", sorted(DURABLE_QUEUES))
def test_sweep_every_boundary_reduced(name):
    """Tier-1: every crash step x {min,random,max} + enumerated subsets on
    a reduced workload (2 threads) is durably linearizable."""
    r = sweep_queue(name, nthreads=2, per_thread=4, seed=1, area_nodes=32,
                    subset_cap=32)
    assert not r.failures, r.failures[0]
    cov = r.coverage()
    assert cov["boundaries"] == r.total_steps, \
        "sweep must visit every crash step"
    assert cov["persist_adjacent"] + cov["interior"] == cov["boundaries"]
    assert cov["persist_adjacent"] > 0 and cov["interior"] > 0
    assert cov["subset_enumerated"] > 0, \
        "no boundary had a small enough outcome space to enumerate?"
    assert cov["crashes_checked"] >= 3 * r.total_steps
    # recovery-work axis is populated
    assert all(row["recovery_preads"] >= 0 for row in r.rows)
    assert any(row["recovery_preads"] > 0 for row in r.rows)


@pytest.mark.slow
@pytest.mark.parametrize("name", sorted(DURABLE_QUEUES))
def test_sweep_full_standard_workload(name):
    """Acceptance: the full sweep (standard 3-thread workload, every step,
    all modes + subsets) passes and stays well inside the 90s budget.
    CI also runs this sharded and blocking via `run.py crash-sweep`."""
    r = sweep_queue(name)
    assert not r.failures, r.failures[0]
    assert r.coverage()["boundaries"] == r.total_steps
    assert r.wall_s < 90, f"sweep took {r.wall_s:.1f}s"


# ---------------------------------------------------------------------------
# recovery idempotence
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("name", sorted(DURABLE_QUEUES))
def test_recovery_idempotence(name):
    """Recovering twice from the same crash image == recovering once; and
    the snapshot path is deterministic (same snapshot + seed -> same
    drain)."""
    h = _harness(name)
    cap = capture_run(h, standard_plans(3, 6), seed=3)
    for step in (cap.total_steps // 3, cap.total_steps // 2,
                 cap.total_steps - 1):
        b = cap.boundaries[step]
        h.nvram.restore(b.snap)
        h.crash_and_recover(mode="random", seed=5)
        once = h.queue.drain(0)
        # same crash image, recover, then crash AGAIN (harshest mode: only
        # what recovery persisted survives) and recover a second time
        h.nvram.restore(b.snap)
        h.crash_and_recover(mode="random", seed=5)
        h.crash_and_recover(mode="min")
        twice = h.queue.drain(0)
        assert once == twice, \
            f"{name} step {step}: double recovery diverged"
        # determinism of the sweep's replay
        h.nvram.restore(b.snap)
        h.crash_and_recover(mode="random", seed=5)
        again = h.queue.drain(0)
        assert once == again


# ---------------------------------------------------------------------------
# the subset mode at engine level
# ---------------------------------------------------------------------------
def test_subset_mode_enumerates_pending_outcomes():
    """With one pending flush and unapplied stores, the enumerated subset
    outcomes must include both the 'nothing survived' and 'everything
    survived' corners, each matching the corresponding sampled mode."""
    def scenario():
        nv = NVRAM(1)
        a = nv.alloc_region(16, "r")
        nv.write(a, "x1")
        nv.flush(a)             # pending flush covering the first store
        nv.write(a + 1, "x2")   # unapplied store behind the flush point
        nv.write(a + 8, "y1")   # second line, never flushed
        return nv, a

    nv, a = scenario()
    snap = nv.snapshot(volatile=False)

    class FakeBoundary:
        pass

    fb = FakeBoundary()
    fb.snap = snap
    space = choice_space(fb)
    assert len(space.flush_entries) == 1
    # all three stores are still unapplied (a flush only *schedules* the
    # write-back; nothing leaves the log until a fence or crash applies it)
    assert sum(space.log_lines.values()) == 3
    choices = list(enumerate_choices(space))
    assert len(choices) == space.combos == 4    # 2 flush-subsets x 2 corners

    outcomes = set()
    for ch in choices:
        nv.restore(snap)
        nv.crash(mode="subset", choices=ch)
        outcomes.add((nv.pread(a), nv.pread(a + 1), nv.pread(a + 8)))
    # min corner: nothing persisted; max corner: everything did
    nv.restore(snap)
    nv.crash(mode="min")
    assert (nv.pread(a), nv.pread(a + 1), nv.pread(a + 8)) in outcomes
    nv.restore(snap)
    nv.crash(mode="max")
    assert (nv.pread(a), nv.pread(a + 1), nv.pread(a + 8)) in outcomes
    assert ("x1", None, None) in outcomes       # flush survived alone
    assert len(outcomes) >= 3


def test_subset_mode_exhausts_interior_eviction_prefixes():
    """``exhaustive_log`` widens the implicit-eviction axis from its two
    corners to every per-line store prefix: interior outcomes -- a strict
    prefix of one line surviving alongside another line's full log -- are
    reachable only there, and dropping ``k == 0`` entries keeps the
    enumeration duplicate-free."""
    nv = NVRAM(1)
    a = nv.alloc_region(16, "r")
    nv.write(a, "x1")
    nv.flush(a)             # pending flush covering the first store
    nv.write(a + 1, "x2")   # second store on the same line, behind the flush
    nv.write(a + 8, "y1")   # second line, never flushed
    snap = nv.snapshot(volatile=False)

    class FakeBoundary:
        pass

    fb = FakeBoundary()
    fb.snap = snap
    corners = choice_space(fb)
    full = choice_space(fb, exhaustive_log=True)
    # 2 flush-subsets x (2+1) prefixes of line a x (1+1) prefixes of line a+8
    assert corners.combos == 4
    assert full.combos == 12
    choices = list(enumerate_choices(full))
    assert len(choices) == full.combos
    assert len({(c.flush_survivors, c.nt_prefix, c.log_prefix)
                for c in choices}) == full.combos, "duplicate outcomes"

    outcomes = set()
    for ch in choices:
        nv.restore(snap)
        nv.crash(mode="subset", choices=ch)
        outcomes.add((nv.pread(a), nv.pread(a + 1), nv.pread(a + 8)))
    # the corner outcomes are still covered...
    for mode in ("min", "max"):
        nv.restore(snap)
        nv.crash(mode=mode)
        assert (nv.pread(a), nv.pread(a + 1), nv.pread(a + 8)) in outcomes
    # ...and the interior prefixes appear: one line's strict prefix
    # combined with the other line's survival, unreachable from corners
    assert (None, None, "y1") in outcomes
    assert ("x1", None, "y1") in outcomes


def _exhaustive_cell_sweeps(per_thread, subset_cap):
    """The satellite cell: DurableMSQ x optane-clwb, 2 threads, 2-node
    designated areas -- small enough that EVERY boundary's outcome space,
    including mid-area-zeroing ones (several pending zero-flushes plus
    8-word line logs), fits under the cap.  Returns (corners, exhaustive)
    sweep results over the identical capture."""
    kw = dict(nthreads=2, per_thread=per_thread, seed=1, area_nodes=2,
              subset_cap=subset_cap)
    return (sweep_queue("DurableMSQ", **kw),
            sweep_queue("DurableMSQ", exhaustive_log=True, **kw))


def _assert_exhaustive_cell(r_corner, r_ex):
    assert not r_corner.failures, r_corner.failures[0]
    assert not r_ex.failures, r_ex.failures[0]
    cov_c, cov_e = r_corner.coverage(), r_ex.coverage()
    # truly exhaustive: no boundary's subset space overflowed the cap
    assert cov_e["subset_skipped"] == 0
    assert cov_e["subset_enumerated"] == r_ex.total_steps
    # the interior prefixes are a strict superset of the corner outcomes
    assert cov_e["crashes_checked"] > cov_c["crashes_checked"]
    sub_e = {r["crash_step"]: r for r in r_ex.rows if r["mode"] == "subset"}
    sub_c = {r["crash_step"]: r for r in r_corner.rows
             if r["mode"] == "subset"}
    assert all(sub_e[s]["subset_combos"] >= sub_c[s]["subset_combos"]
               for s in sub_e)
    # at least one boundary with a multi-entry line log was widened beyond
    # its two eviction corners...
    assert any(r["log_words"] >= 2
               and r["subset_combos"] > sub_c[s]["subset_combos"]
               for s, r in sub_e.items())
    # ...and the mid-area-zeroing boundaries (>= 2 pending zero-flushes
    # from one thread's area init) were exhausted, not skipped
    mid_zero = [r for r in sub_e.values() if r["pending_flush"] >= 2]
    assert mid_zero, "no mid-area-zeroing boundary in the capture?"
    assert all(r["subset_combos"] > 0 for r in mid_zero)


def test_sweep_exhaustive_interior_prefixes_reduced():
    """Tier-1 cell: every boundary of a tiny DurableMSQ run, with the full
    per-line eviction-prefix product and all mid-area-zeroing boundaries
    enumerated (~6.5k crash images in under a second)."""
    _assert_exhaustive_cell(*_exhaustive_cell_sweeps(per_thread=2,
                                                     subset_cap=2048))


@pytest.mark.slow
def test_sweep_exhaustive_interior_prefixes_full_cell():
    """The full satellite cell (per_thread=4: ~29k crash images, ~5s):
    exhaustive interior eviction prefixes and mid-area-zeroing boundaries
    for DurableMSQ x optane-clwb."""
    _assert_exhaustive_cell(*_exhaustive_cell_sweeps(per_thread=4,
                                                     subset_cap=32768))


def test_restore_rewinds_address_space():
    """Regions allocated after a snapshot are forgotten by restore, so
    repeated recoveries cannot leak address space across crash points."""
    nv = NVRAM(1)
    nv.alloc_region(16, "base")
    snap = nv.snapshot()
    brk, nregions = nv._brk, len(nv.regions)
    nv.alloc_region(4096, "post-snapshot")
    nv.restore(snap)
    assert nv._brk == brk and len(nv.regions) == nregions


# ---------------------------------------------------------------------------
# failure-repro artifacts
# ---------------------------------------------------------------------------
def test_artifact_roundtrip_and_repro_both_methods(tmp_path):
    """An artifact round-trips through JSON and replays through both the
    snapshot path and the independent rerun path, agreeing on the
    recovered queue."""
    h = _harness("DurableMSQ")
    cap = capture_run(h, standard_plans(3, 6), seed=3)
    step = cap.total_steps // 2
    art = failure_artifact(cap, crash_step=step, mode="random", crash_seed=3,
                           choices=None, why="synthetic (healthy point)",
                           recovered=[("t", 0)])
    path = tmp_path / "repro.json"
    save_artifact(str(path), art)
    loaded = load_artifact(str(path))
    assert loaded == art

    ok_s, _, rec_s = reproduce(loaded, method="snapshot")
    ok_r, _, rec_r = reproduce(loaded, method="rerun")
    assert ok_s and ok_r, "healthy crash point must not report a violation"
    assert rec_s == rec_r, "snapshot and rerun repro paths diverged"


def test_artifact_subset_choices_roundtrip(tmp_path):
    """Subset-mode artifacts carry their CrashChoices through JSON."""
    from repro.crash.artifact import _choices_from_json, _choices_to_json
    from repro.core import CrashChoices
    ch = CrashChoices(flush_survivors=frozenset({(0, 1), (2, 0)}),
                      nt_prefix=(((1, 5), 2),),
                      log_prefix=((7, 3), (9, 1)))
    assert _choices_from_json(_choices_to_json(ch)) == ch
    assert _choices_to_json(None) is None
    assert _choices_from_json(None) is None


def test_cli_shard_partitions_queues():
    from repro.crash.__main__ import _shard
    names = sorted(DURABLE_QUEUES)
    shards = [_shard(names, f"{k}/4") for k in range(4)]
    assert sorted(q for s in shards for q in s) == names
    assert all(s for s in shards), "4-way sharding must keep shards busy"
