"""The trace subsystem: capture determinism, storage schema, attribution,
and the learned contention profiles.

Four property groups:

* **Inertness** -- recording must never change what it observes: a traced
  run's Stats are bit-identical to an untraced one (the tap sits beside
  the cost accumulator), and the trace's own post-flush classification
  sums to the engine's counter.
* **Determinism** -- the exact scheduler is seed-deterministic, the
  recorder adds no ambient state, and the store writes no timestamps:
  same seed => byte-identical trace file.
* **Storage** -- `.npz` round-trips preserve columns and metadata;
  wrong-version or malformed files are rejected loudly.
* **Section 8 attribution + learned profiles** -- trace-derived post-flush
  attribution reproduces the paper's qualitative ordering (second
  amendment queues strictly below their baselines, at zero), and the
  checked-in `benchmarks/profiles/learned.json` is complete, measured
  (no hand constants), and calibrates the batched model within 10% of
  exact at 2-8 threads -- extended to 12/16 threads (16%, multi-seed
  ground truth for the worst cells) in the slow-marked test.
"""
import json

import numpy as np
import pytest

from repro.core import ALL_QUEUES, QueueHarness
from repro.trace import (TraceRecorder, TraceSchemaError, capture_trace,
                         load_trace, post_flush_per_op, post_flush_sites,
                         save_trace)
from repro.trace.fit import (PARAM_FIELDS, fit_profiles, load_profiles,
                             make_pairs_plans)
from benchmarks.workloads import LEARNED_PROFILES_PATH, resolve_contention

STAT_FIELDS = ["reads", "writes", "cas", "flushes", "fences", "movntis",
               "post_flush_accesses", "cold_misses", "time_ns"]

DURABLE7 = ["DurableMSQ", "IzraelevitzQ", "NVTraverseQ", "UnlinkedQ",
            "LinkedQ", "OptUnlinkedQ", "OptLinkedQ"]


def _run_traced(name, nthreads, ops, seed, trace=None):
    h = QueueHarness(ALL_QUEUES[name], nthreads=nthreads, area_nodes=512)
    plans, prefill = make_pairs_plans(nthreads, ops)
    for i in range(prefill):
        h.queue.enqueue(0, ("pre", i))
    base = h.nvram.total_stats()
    res = h.run_scheduled(plans, seed=seed, trace=trace)
    assert res.ops_completed == nthreads * ops
    return h.nvram.total_stats().minus(base)


# ------------------------------------------------------------- inertness
@pytest.mark.parametrize("name", ["DurableMSQ", "OptUnlinkedQ"])
def test_recorder_off_vs_on_stats_bit_identical(name):
    """Attaching a recorder must not perturb any Stats field: the tap only
    observes.  (The differential oracle suite covers the untraced engine;
    this pins the traced one against it.)"""
    plain = _run_traced(name, 2, 12, seed=5)
    traced = _run_traced(name, 2, 12, seed=5, trace=TraceRecorder())
    for f in STAT_FIELDS:
        assert getattr(traced, f) == getattr(plain, f), (
            f"{name}: tracing perturbed {f}: "
            f"{getattr(traced, f)} != {getattr(plain, f)}")


def test_trace_post_flush_classification_matches_engine():
    """The trace's pre-access line states reproduce the engine's post-flush
    accounting exactly: sum(post_flush_mask) == Stats.post_flush_accesses."""
    rec = TraceRecorder()
    d = _run_traced("DurableMSQ", 2, 12, seed=5, trace=rec)
    assert d.post_flush_accesses > 0
    assert int(rec.trace.post_flush_mask().sum()) == d.post_flush_accesses


# ----------------------------------------------------------- determinism
def test_same_seed_byte_identical_trace(tmp_path):
    paths = []
    for i in (0, 1):
        trace = capture_trace("DurableMSQ", 2, 8, seed=7)
        p = tmp_path / f"t{i}.trace.npz"
        save_trace(p, trace)
        paths.append(p)
    assert paths[0].read_bytes() == paths[1].read_bytes(), \
        "same seed must produce a byte-identical trace file"


def test_different_seed_different_interleaving(tmp_path):
    a = capture_trace("DurableMSQ", 3, 8, seed=1)
    b = capture_trace("DurableMSQ", 3, 8, seed=2)
    assert (len(a) != len(b)
            or not np.array_equal(a.columns["tid"], b.columns["tid"]))


# --------------------------------------------------------------- storage
def test_store_roundtrip_preserves_schema(tmp_path):
    trace = capture_trace("UnlinkedQ", 2, 8, seed=3)
    p = tmp_path / "u.trace.npz"
    save_trace(p, trace)
    back = load_trace(p)
    assert back.meta["schema"] == 1
    assert back.meta["queue"] == "UnlinkedQ"
    assert back.meta["kinds"] == trace.meta["kinds"]
    for c in trace.columns:
        assert np.array_equal(back.columns[c], trace.columns[c]), c
    # region map survives (site attribution needs it)
    assert any(n.startswith("unlinkedq:") for n, *_ in back.meta["regions"])


def test_store_rejects_wrong_version(tmp_path):
    trace = capture_trace("UnlinkedQ", 2, 6, seed=3)
    trace.meta["schema"] = 999
    p = tmp_path / "bad_version.trace.npz"
    save_trace(p, trace)
    with pytest.raises(TraceSchemaError, match="schema"):
        load_trace(p)


def test_store_rejects_malformed_files(tmp_path):
    not_a_trace = tmp_path / "junk.npz"
    np.savez(not_a_trace, step=np.arange(3))
    with pytest.raises(TraceSchemaError):
        load_trace(not_a_trace)
    garbage = tmp_path / "garbage.npz"
    garbage.write_bytes(b"this is not an npz archive")
    with pytest.raises(TraceSchemaError):
        load_trace(garbage)


# --------------------------------------------- section 8 attribution
def test_paper_s8_opt_queues_strictly_fewer_post_flush_accesses():
    """Trace-derived attribution reproduces the paper's qualitative
    ordering: each second-amendment queue shows strictly fewer post-flush
    accesses per op than its non-opt counterpart -- and in fact zero, with
    an empty site list, while every baseline attributes at least one
    concrete (op kind, region, primitive) site."""
    per_op = {}
    sites = {}
    for name in ("UnlinkedQ", "OptUnlinkedQ", "LinkedQ", "OptLinkedQ",
                 "DurableMSQ"):
        trace = capture_trace(name, 3, 12, seed=2)
        per_op[name] = post_flush_per_op(trace)["all"]
        sites[name] = post_flush_sites(trace)
    for opt, base in (("OptUnlinkedQ", "UnlinkedQ"),
                      ("OptLinkedQ", "LinkedQ"),
                      ("OptUnlinkedQ", "DurableMSQ")):
        assert per_op[opt] < per_op[base], (
            f"{opt} ({per_op[opt]:.2f}/op) not strictly below "
            f"{base} ({per_op[base]:.2f}/op)")
        assert per_op[opt] == 0.0, f"{opt} must attribute zero"
        assert sites[opt] == [], f"{opt} must have no post-flush sites"
        assert sites[base], f"{base} must attribute at least one site"
    # the attribution names real program sites: DurableMSQ's dequeues
    # re-read the flushed HEAD root line (module docstring claim)
    msq_sites = {(s.op_kind, s.region, s.prim)
                 for s in sites["DurableMSQ"]}
    assert ("deq", "durablemsq:roots", "read") in msq_sites


# ------------------------------------------------------ learned profiles
def test_checked_in_profiles_are_complete_and_measured():
    """benchmarks/profiles/learned.json: schema-checked, all EIGHT queues
    (MSQ's volatile baseline included), every numeric field present,
    provenance recorded, and the second amendment invariant is *measured*
    (flushed_reads == 0 for opt queues, so contended runs keep
    post_flush_accesses == 0).  ``flushed_decay`` may be a measured
    per-window-size shape (a list of multipliers in [0, 1], k = 1..K)."""
    profiles = load_profiles(LEARNED_PROFILES_PATH)
    # exactly the queue registry: no queue missing, no stale orphan entry
    assert set(profiles) == set(ALL_QUEUES)
    assert set(ALL_QUEUES) == set(DURABLE7) | {"MSQ"}
    for name, lp in profiles.items():
        assert set(lp.params) == {"enq", "deq"}, name
        for kind, p in lp.params.items():
            for f in PARAM_FIELDS:
                v = p[f]
                if f == "flushed_decay" and isinstance(v, (list, tuple)):
                    arr = np.asarray(v, dtype=float)
                    assert len(arr) >= 2, (name, kind, "degenerate shape")
                    assert np.isfinite(arr).all(), (name, kind)
                    assert ((arr >= 0) & (arr <= 1)).all(), (name, kind)
                    # a shape is a decay: monotone non-increasing in k
                    assert (np.diff(arr) <= 1e-12).all(), (name, kind)
                    continue
                assert np.isfinite(v) and v >= 0, (name, kind, f)
        assert lp.source.get("traces"), f"{name}: no fit provenance"
    for name in ("OptUnlinkedQ", "OptLinkedQ"):
        for kind in ("enq", "deq"):
            assert profiles[name].params[kind]["flushed_reads"] == 0.0
    # raw JSON stays versioned + diff-reviewable
    with open(LEARNED_PROFILES_PATH) as f:
        doc = json.load(f)
    assert doc["schema"] == 1 and "retry_scale" in doc


def test_learned_profiles_preserve_second_amendment_under_contention():
    """Contended batched runs with learned profiles keep the paper's
    headline invariant: zero post-flush accesses for the opt queues."""
    for name in ("OptUnlinkedQ", "OptLinkedQ"):
        h = QueueHarness(ALL_QUEUES[name], nthreads=8, area_nodes=512)
        plans, prefill = make_pairs_plans(8, 24)
        for i in range(prefill):
            h.queue.enqueue(0, ("pre", i))
        _, cm = resolve_contention("learned", name)
        res = h.run_batched(plans, contention=cm)
        assert res.stats.post_flush_accesses == 0
        assert cm.retries_charged > 0   # and not because nothing happened


def test_fit_pipeline_end_to_end_small():
    """fit_profiles on small fresh traces: produces finite non-negative
    params for both kinds and records the observed retry targets."""
    traces = [capture_trace("DurableMSQ", t, 8, seed=4) for t in (2, 3)]
    lp = fit_profiles("DurableMSQ", traces, refine=False)
    assert set(lp.params) == {"enq", "deq"}
    for kind, p in lp.params.items():
        assert set(p) == set(PARAM_FIELDS)
        for f, v in p.items():
            assert np.isfinite(v) and v >= 0, (kind, f, v)
    assert lp.source["target_rounds_per_op"]


# ------------------------------------------------- 12/16-thread envelope
def _counts(name, nthreads, engine, ops, contention=None, seed=1):
    h = QueueHarness(ALL_QUEUES[name], nthreads=nthreads, area_nodes=1024)
    plans, prefill = make_pairs_plans(nthreads, ops)
    for i in range(prefill):
        h.queue.enqueue(0, ("pre", i))
    base = h.nvram.total_stats()
    if engine == "exact":
        h.run_scheduled(plans, seed=seed)
    else:
        _, cm = resolve_contention(contention, name)
        h.run_batched(plans, contention=cm)
    d = h.nvram.total_stats().minus(base)
    return d.flushes + d.fences, d.post_flush_accesses


#: the fence-heavy transforms are the calibration's worst cells (their
#: flushed-access totals carry the most scheduling variance), so their
#: ground truth is averaged over several exact seeds; the other queues'
#: single-seed errors sit at or under ~4%, seed-to-seed spread included.
FENCE_HEAVY_WORST = {"IzraelevitzQ", "NVTraverseQ"}
GROUND_TRUTH_SEEDS = (1, 2, 3)


@pytest.mark.slow
@pytest.mark.parametrize("name", DURABLE7)
def test_learned_calibration_extends_to_12_and_16_threads(name):
    """Past the exact scheduler's practical reach, the learned model stays
    within 16% of exact ground truth (12 ops/thread) on persist-instruction
    and flushed-access totals at 12 and 16 threads.

    Ground truth is *multi-seed* where it matters: the fence-heavy
    transforms (IzraelevitzQ, NVTraverseQ) -- whose flushed-access totals
    are the envelope's worst cells -- are averaged over three exact seeds,
    which pins their model error at ~14-15% (vs up to ~17% against any
    single seed).  Every other queue's cells sit at or under ~6% with
    negligible seed spread, so one seed suffices there.  Both engines are
    deterministic, so 16% is a real gate, not a noise margin; the prior
    20% bound only existed to absorb single-seed sampling of the worst
    cells.

    Slow: each exact 16-thread sample costs ~15-20 s of per-primitive
    OS-thread scheduling; CI runs this suite in a non-blocking job.
    """
    TOL, PF_FLOOR, OPS = 0.16, 30, 12
    seeds = GROUND_TRUTH_SEEDS if name in FENCE_HEAVY_WORST else (1,)
    for nthreads in (12, 16):
        exact = [_counts(name, nthreads, "exact", OPS, seed=s)
                 for s in seeds]
        persist_e = sum(p for p, _ in exact) / len(exact)
        pf_e = sum(f for _, f in exact) / len(exact)
        persist_b, pf_b = _counts(name, nthreads, "batched", OPS, "learned")
        assert abs(persist_b - persist_e) <= TOL * max(persist_e, 1), (
            f"{name} t{nthreads}: persist batched={persist_b} "
            f"exact={persist_e:.1f} over seeds {seeds} (> {TOL:.0%} off)")
        assert abs(pf_b - pf_e) <= TOL * max(pf_e, PF_FLOOR), (
            f"{name} t{nthreads}: flushed accesses batched={pf_b} "
            f"exact={pf_e:.1f} over seeds {seeds} (> {TOL:.0%} off)")
