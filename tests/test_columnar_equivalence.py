"""The columnar op-record engine's equivalence gate.

``QueueHarness`` now keeps op records and linearization events in a
columnar :class:`repro.core.records.RecordStore` (numpy columns +
cursors) instead of per-op Python objects; the compiled fast path stages
whole bursts and charges the engine in one vector pass.  The acceptance
criterion mirrors the fast path's own gate: **bit identity**.  For all 8
queues x 3 memory models x contention off/on/learned, a columnar-record
run must produce exactly the per-thread Stats (every counter AND the
float ``time_ns``), the same op records, the same linearization events
and the same final queue contents as the legacy list-of-``OpRecord``
path (``records="legacy"``), which survives precisely as this suite's
differential reference.

The second half pins the crash seam: record cursors snapshot/restore
with memory state (``QueueHarness.record_snapshot`` /
``record_restore``), including round-trips through non-zero cursors.
"""
import pytest

from repro.core import ALL_QUEUES, MEMORY_MODELS, QueueHarness
from repro.core.records import RecordStore
from benchmarks.workloads import make_plans, resolve_contention

QUEUES8 = sorted(ALL_QUEUES)


def _run(qname, records, model, contention="off", workload="mixed5050",
         nthreads=3, ops=40, area_nodes=256, seed=0, compiled=None):
    h = QueueHarness(ALL_QUEUES[qname], nthreads=nthreads,
                     area_nodes=area_nodes, model=model, records=records)
    plans, wl_prefill = make_plans(workload, nthreads, ops, seed=seed)
    for i in range(wl_prefill):
        h.queue.enqueue(0, ("pre", i))
    _, cmodel = resolve_contention(contention, qname)
    res = h.run_batched(plans, contention=cmodel, compiled=compiled)
    return h, res


def assert_bit_identical(qname, model, contention, **kw):
    h_leg, r_leg = _run(qname, "legacy", model, contention, **kw)
    h_col, r_col = _run(qname, "columnar", model, contention, **kw)
    s_leg, s_col = h_leg.nvram.stats, h_col.nvram.stats
    for t in s_leg:
        assert s_leg[t] == s_col[t], (
            f"{qname}/{model}/{contention}: thread {t} Stats diverge\n"
            f"  legacy:   {s_leg[t]}\n  columnar: {s_col[t]}")
    assert list(r_col.ops) == list(r_leg.ops)
    assert list(r_col.events) == list(r_leg.events)
    assert r_col.ops_completed == r_leg.ops_completed
    assert r_col.sim_time_ns == r_leg.sim_time_ns
    assert h_col.queue.drain(0) == h_leg.queue.drain(0)
    return h_col


@pytest.mark.parametrize("model", sorted(MEMORY_MODELS))
@pytest.mark.parametrize("qname", QUEUES8)
def test_columnar_bit_identical_all_models(qname, model):
    """The core gate: 8 queues x 3 models, mixed workload, contention off."""
    h = assert_bit_identical(qname, model, "off")
    assert h._rstore is not None, "columnar mode lost its store"
    assert h.fast is not None and h.fast.fast_ops > 0, \
        "fast path never engaged -- the staged-burst path went untested"


@pytest.mark.parametrize("contention", ["on", "learned"])
@pytest.mark.parametrize("qname", QUEUES8)
def test_columnar_bit_identical_contended(qname, contention):
    """Contended runs fall back to the generic scheduler loop (the staged
    dispatch is uncontended-only); records flow through the eager direct
    path and must still match legacy bit for bit."""
    assert_bit_identical(qname, "optane-clwb", contention)


@pytest.mark.parametrize("qname", ["DurableMSQ", "OptUnlinkedQ", "LinkedQ"])
def test_columnar_bit_identical_uncompiled(qname):
    """compiled=False exercises the per-op direct-row path end to end."""
    assert_bit_identical(qname, "optane-clwb", "off", compiled=False)


@pytest.mark.parametrize("qname", ["DurableMSQ", "NVTraverseQ"])
def test_columnar_matches_legacy_on_exact_scheduler(qname):
    """The exact per-primitive scheduler (crash harness) writes records
    through begin_op/complete_op; both record modes must agree there too,
    including incomplete ops cut off by a crash."""
    def scheduled(records, crash_at):
        h = QueueHarness(ALL_QUEUES[qname], nthreads=3, area_nodes=64,
                         model="optane-clwb", records=records)
        plans = [[("enq", (t, i)) for i in range(4)] + [("deq", None)]
                 for t in range(3)]
        h.run_scheduled(plans, seed=5, crash_at=crash_at)
        return h
    for crash_at in (None, 37):
        h_leg = scheduled("legacy", crash_at)
        h_col = scheduled("columnar", crash_at)
        assert list(h_col.ops) == list(h_leg.ops), f"crash_at={crash_at}"
        assert list(h_col.events) == list(h_leg.events)
        for t in h_leg.nvram.stats:
            assert h_col.nvram.stats[t] == h_leg.nvram.stats[t]


# --------------------------------------------------- snapshot/restore seam

def test_record_snapshot_restore_roundtrip_nonzero_cursors():
    """Cursors snapshot with memory state and restore rewinds the record
    history exactly -- through non-zero cursors, not just the empty store."""
    h, _ = _run("DurableMSQ", "columnar", "optane-clwb", nthreads=2, ops=20)
    snap = h.record_snapshot()
    n_ops, n_events = snap
    assert n_ops > 0 and n_events > 0, "seam test needs non-zero cursors"
    ops_before = list(h.ops)
    events_before = list(h.events)
    plans, _ = make_plans("mixed5050", 2, 10, seed=3)
    h.run_batched(plans)
    assert len(h.ops) > n_ops and len(h.events) > n_events
    h.record_restore(snap)
    assert h.record_snapshot() == snap
    assert list(h.ops) == ops_before
    assert list(h.events) == events_before


def test_record_snapshot_restore_roundtrip_legacy_mode():
    """The seam is mode-agnostic: legacy lists truncate the same way."""
    h, _ = _run("DurableMSQ", "legacy", "optane-clwb", nthreads=2, ops=20)
    snap = h.record_snapshot()
    assert snap[0] > 0 and snap[1] > 0
    ops_before, events_before = list(h.ops), list(h.events)
    plans, _ = make_plans("mixed5050", 2, 10, seed=3)
    h.run_batched(plans)
    h.record_restore(snap)
    assert list(h.ops) == ops_before and list(h.events) == events_before
    with pytest.raises(ValueError):
        h.record_restore((snap[0] + 10 ** 6, snap[1]))


def test_store_restore_recomputes_thread_chains():
    """After a cursor restore, per-thread seq numbers and the start-clock
    chain continue from the surviving rows, not from stale carries."""
    rs = RecordStore(nthreads=2)
    for i in range(6):
        rs.begin_op(i % 2, "enq", item=i, completed=True)
    snap = rs.snapshot()
    assert snap == (6, 0)
    for i in range(4):
        rs.begin_op(0, "deq", item=None, completed=True)
    rs.restore(snap)
    assert rs.snapshot() == snap
    # thread 0 had rows 0,2,4 -> seqs 0,1,2; the next row continues at 3
    i = rs.begin_op(0, "enq", item=99, completed=True)
    assert rs.seq[i] == 3
    assert [r.item for r in rs.op_records()] == [0, 1, 2, 3, 4, 5, 99]


def test_capture_boundaries_carry_record_cursors():
    """The crash sweep's Boundary pairs each EngineSnapshot with the
    record cursors taken at the same quiescent instant."""
    from repro.crash.capture import capture_run
    from repro.crash.sweep import standard_plans
    h = QueueHarness(ALL_QUEUES["DurableMSQ"], nthreads=2, area_nodes=64,
                     model="optane-clwb")
    cap = capture_run(h, standard_plans(2, 3), seed=1)
    assert cap.boundaries, "capture produced no boundaries"
    for b in cap.boundaries:
        assert b.rec_snap == (b.ops_len, b.events_len)
    assert cap.boundaries[-1].rec_snap[0] == len(cap.ops)
