"""Per-architecture smoke tests: reduced config, one forward + train step +
decode step on CPU; assert shapes and no NaNs (assignment requirement)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, reduced_config
from repro.models import forward, init_cache, init_params, loss_fn, serve_step
from repro.optim import AdamWConfig, adamw_update, init_opt_state

ARCH_IDS = sorted(ARCHS)


def _batch(cfg, B=2, S=32, key=0):
    rng = np.random.RandomState(key)
    tokens = rng.randint(0, cfg.vocab, (B, S)).astype(np.int32)
    labels = np.roll(tokens, -1, axis=1)
    if cfg.embed_stub:
        embeds = rng.randn(B, S, cfg.d_model).astype(np.float32) * 0.02
        return {"embeds": jnp.asarray(embeds), "labels": jnp.asarray(labels)}
    return {"tokens": jnp.asarray(tokens), "labels": jnp.asarray(labels)}


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_no_nan(arch):
    cfg = reduced_config(arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg)
    logits = jax.jit(lambda p, b: forward(cfg, p, b))(params, batch)
    assert logits.shape == (2, 32, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits))), "NaN/Inf in logits"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_one_train_step(arch):
    cfg = reduced_config(arch)
    params = init_params(cfg, jax.random.PRNGKey(1))
    ocfg = AdamWConfig(lr=1e-3)
    opt = init_opt_state(ocfg, params)
    batch = _batch(cfg)

    @jax.jit
    def step(p, o, b):
        loss, grads = jax.value_and_grad(
            lambda pp: loss_fn(cfg, pp, b))(p)
        p2, o2, met = adamw_update(ocfg, p, grads, o)
        return p2, o2, loss, met

    p2, o2, loss, met = step(params, opt, batch)
    assert bool(jnp.isfinite(loss)), "loss is NaN"
    assert float(loss) > 0
    assert bool(jnp.isfinite(met["grad_norm"]))
    # params actually changed
    delta = sum(float(jnp.sum(jnp.abs(a.astype(jnp.float32)
                                      - b.astype(jnp.float32))))
                for a, b in zip(jax.tree.leaves(p2), jax.tree.leaves(params))
                if a.dtype in (jnp.float32, jnp.bfloat16))
    assert delta > 0
    # second step reduces... at least runs and stays finite
    p3, o3, loss2, _ = step(p2, o2, batch)
    assert bool(jnp.isfinite(loss2))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_step(arch):
    cfg = reduced_config(arch)
    params = init_params(cfg, jax.random.PRNGKey(2))
    B, S_max = 2, 16
    cache = init_cache(cfg, B, S_max)
    if cfg.embed_stub:
        batch = {"embeds": jnp.zeros((B, 1, cfg.d_model), jnp.float32)}
    else:
        batch = {"tokens": jnp.zeros((B, 1), jnp.int32)}
    pos = jnp.zeros((B,), jnp.int32)
    logits, cache2 = jax.jit(
        lambda p, c, b, q: serve_step(cfg, p, c, b, q))(params, cache, batch, pos)
    assert logits.shape == (B, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))
    # structure preserved
    assert jax.tree.structure(cache) == jax.tree.structure(cache2)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_matches_forward(arch):
    """Greedy decode over a short prompt must match teacher-forced forward
    logits position by position (cache correctness)."""
    cfg = reduced_config(arch)
    params = init_params(cfg, jax.random.PRNGKey(3))
    B, S = 1, 8
    batch = _batch(cfg, B=B, S=S, key=7)
    full = forward(cfg, params, batch)            # (B,S,V)
    cache = init_cache(cfg, B, S)
    outs = []
    for t in range(S):
        if cfg.embed_stub:
            step_in = {"embeds": batch["embeds"][:, t:t + 1]}
        else:
            step_in = {"tokens": batch["tokens"][:, t:t + 1]}
        pos = jnp.full((B,), t, jnp.int32)
        lg, cache = serve_step(cfg, params, cache, step_in, pos)
        outs.append(lg)
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(full, np.float32),
                               np.asarray(dec, np.float32),
                               rtol=2e-2, atol=2e-2)


def test_param_counts_match_formula():
    for arch in ARCH_IDS:
        cfg = reduced_config(arch)
        params = init_params(cfg, jax.random.PRNGKey(0))
        actual = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(params))
        expected = cfg.n_params()
        assert actual == expected, (
            f"{arch}: counted {actual} != formula {expected}")
