"""Per-kernel allclose validation vs the pure-jnp oracles (interpret=True),
sweeping shapes and dtypes as required by the assignment."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.kernel import flash_attention_kernel
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.kernels.decode_attention.kernel import decode_attention_kernel
from repro.kernels.decode_attention.ref import decode_attention_ref
from repro.kernels.ssm_scan.kernel import ssm_scan_kernel
from repro.kernels.ssm_scan.ref import ssm_scan_ref


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------- flash attention
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,S,H,KV,hd,bq,bk", [
    (2, 256, 4, 4, 64, 128, 128),     # MHA
    (2, 512, 8, 2, 64, 128, 256),     # GQA 4:1, rectangular blocks
    (1, 1024, 8, 1, 128, 256, 256),   # MQA, MXU-width head
    (3, 384, 6, 2, 32, 128, 128),     # non-pow2 batch/heads
])
def test_flash_attention_shapes(B, S, H, KV, hd, bq, bk, dtype):
    rng = np.random.RandomState(hash((B, S, H)) % 2**31)
    q = jnp.asarray(rng.randn(B, S, H, hd), dtype)
    k = jnp.asarray(rng.randn(B, S, KV, hd), dtype)
    v = jnp.asarray(rng.randn(B, S, KV, hd), dtype)
    out = flash_attention_kernel(q, k, v, causal=True, block_q=bq,
                                 block_k=bk, interpret=True)
    ref = flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


def test_flash_attention_noncausal():
    rng = np.random.RandomState(0)
    q = jnp.asarray(rng.randn(2, 256, 4, 64), jnp.float32)
    k = jnp.asarray(rng.randn(2, 256, 2, 64), jnp.float32)
    v = jnp.asarray(rng.randn(2, 256, 2, 64), jnp.float32)
    out = flash_attention_kernel(q, k, v, causal=False, block_q=128,
                                 block_k=128, interpret=True)
    ref = flash_attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_flash_attention_matches_model_path():
    """The model's chunked-XLA attention and the kernel must agree."""
    from repro.models.attention import causal_attention_chunked
    rng = np.random.RandomState(1)
    B, S, H, KV, hd = 2, 512, 8, 2, 64
    q = jnp.asarray(rng.randn(B, S, H, hd), jnp.float32)
    k = jnp.asarray(rng.randn(B, S, KV, hd), jnp.float32)
    v = jnp.asarray(rng.randn(B, S, KV, hd), jnp.float32)
    out_k = flash_attention_kernel(q, k, v, causal=True, interpret=True)
    out_x = causal_attention_chunked(q, k, v, H // KV, block=128)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_x),
                               rtol=2e-4, atol=2e-4)


# ----------------------------------------------------------- decode attention
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,S,H,KV,hd,ns,bk", [
    (2, 1024, 8, 2, 64, 4, 128),
    (4, 512, 4, 4, 64, 2, 256),
    (1, 2048, 8, 1, 128, 8, 256),
])
def test_decode_attention_shapes(B, S, H, KV, hd, ns, bk, dtype):
    rng = np.random.RandomState(hash((B, S)) % 2**31)
    q = jnp.asarray(rng.randn(B, H, hd), dtype)
    k = jnp.asarray(rng.randn(B, S, KV, hd), dtype)
    v = jnp.asarray(rng.randn(B, S, KV, hd), dtype)
    lengths = jnp.asarray(rng.randint(1, S + 1, (B,)), jnp.int32)
    out = decode_attention_kernel(q, k, v, lengths, n_splits=ns, block_k=bk,
                                  interpret=True)
    ref = decode_attention_ref(q, k, v, lengths)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


def test_decode_attention_edge_lengths():
    """length=1 and length=S must both be exact (split masking edges)."""
    rng = np.random.RandomState(3)
    B, S, H, KV, hd = 2, 512, 4, 2, 64
    q = jnp.asarray(rng.randn(B, H, hd), jnp.float32)
    k = jnp.asarray(rng.randn(B, S, KV, hd), jnp.float32)
    v = jnp.asarray(rng.randn(B, S, KV, hd), jnp.float32)
    for lens in ([1, S], [S, 1], [137, 255]):
        lengths = jnp.asarray(lens, jnp.int32)
        out = decode_attention_kernel(q, k, v, lengths, n_splits=4,
                                      block_k=128, interpret=True)
        ref = decode_attention_ref(q, k, v, lengths)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-5, atol=2e-5)


# ------------------------------------------------------------------ ssm scan
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,S,din,ds,bd,chunk", [
    (2, 128, 64, 16, 32, 64),
    (1, 256, 128, 16, 128, 128),
    (3, 64, 96, 8, 48, 32),
])
def test_ssm_scan_shapes(B, S, din, ds, bd, chunk, dtype):
    rng = np.random.RandomState(hash((B, S, din)) % 2**31)
    dt = jnp.asarray(np.abs(rng.randn(B, S, din)) * 0.1, dtype)
    x = jnp.asarray(rng.randn(B, S, din), dtype)
    Bt = jnp.asarray(rng.randn(B, S, ds), dtype)
    Ct = jnp.asarray(rng.randn(B, S, ds), dtype)
    A = -jnp.asarray(np.abs(rng.randn(din, ds)) + 0.1, jnp.float32)
    y, h = ssm_scan_kernel(dt, Bt, Ct, x, A, block_d=bd, chunk=chunk,
                           interpret=True)
    y_ref, h_ref = ssm_scan_ref(dt, Bt, Ct, x, A)
    tol = dict(rtol=5e-2, atol=5e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), **tol)
    np.testing.assert_allclose(np.asarray(h), np.asarray(h_ref), **tol)


def test_ssm_scan_matches_model_chunked_path():
    from repro.models.mamba import selective_scan_chunked
    rng = np.random.RandomState(5)
    B, S, din, ds = 2, 128, 64, 16
    dt = jnp.asarray(np.abs(rng.randn(B, S, din)) * 0.1, jnp.float32)
    x = jnp.asarray(rng.randn(B, S, din), jnp.float32)
    Bt = jnp.asarray(rng.randn(B, S, ds), jnp.float32)
    Ct = jnp.asarray(rng.randn(B, S, ds), jnp.float32)
    A = -jnp.asarray(np.abs(rng.randn(din, ds)) + 0.1, jnp.float32)
    y_k, h_k = ssm_scan_kernel(dt, Bt, Ct, x, A, block_d=32, chunk=32,
                               interpret=True)
    y_x, h_x = selective_scan_chunked(dt, Bt, Ct, x, A, chunk=32)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_x),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(h_k), np.asarray(h_x),
                               rtol=1e-4, atol=1e-4)


# -------------------------------------------------- hypothesis property sweep
# hypothesis is an optional dev dependency (installed in CI): only the
# property sweep is skipped without it, not the shape/dtype tests above.
try:
    from hypothesis import given, settings, strategies as st
    _HAS_HYPOTHESIS = True
except ImportError:
    _HAS_HYPOTHESIS = False

if _HAS_HYPOTHESIS:
    @settings(max_examples=10, deadline=None)
    @given(s_blocks=st.integers(2, 6), h=st.sampled_from([2, 4, 8]),
           kv=st.sampled_from([1, 2]), seed=st.integers(0, 999))
    def test_flash_attention_property(s_blocks, h, kv, seed):
        if h % kv:
            kv = 1
        rng = np.random.RandomState(seed)
        B, S, hd = 1, 128 * s_blocks, 32
        q = jnp.asarray(rng.randn(B, S, h, hd), jnp.float32)
        k = jnp.asarray(rng.randn(B, S, kv, hd), jnp.float32)
        v = jnp.asarray(rng.randn(B, S, kv, hd), jnp.float32)
        out = flash_attention_kernel(q, k, v, causal=True, block_q=128,
                                     block_k=128, interpret=True)
        ref = flash_attention_ref(q, k, v, causal=True)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=3e-5, atol=3e-5)
else:
    @pytest.mark.skip(reason="hypothesis not installed (optional dev dep)")
    def test_flash_attention_property():
        pass
