"""Concurrent (scheduler-interleaved) correctness + lock-freedom checks."""
import pytest

from repro.core import ALL_QUEUES, QueueHarness


def _mixed_plans(nthreads, per_thread):
    plans = []
    for t in range(nthreads):
        p = []
        for i in range(per_thread):
            p.append(("enq", (t, i)))
            p.append(("deq", None))
        plans.append(p)
    return plans


@pytest.mark.parametrize("name", sorted(ALL_QUEUES))
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_concurrent_no_loss_no_dup(name, seed):
    """Across arbitrary interleavings: every enqueued item is dequeued
    exactly once (after draining), FIFO per linearization order."""
    nthreads = 3
    h = QueueHarness(ALL_QUEUES[name], nthreads=nthreads, area_nodes=512)
    plans = _mixed_plans(nthreads, 10)
    res = h.run_scheduled(plans, seed=seed)
    assert not res.crashed
    rest = h.queue.drain(0)
    got = [r.item for r in res.ops if r.kind == "deq" and r.item is not None]
    enqueued = [r.item for r in res.ops if r.kind == "enq"]
    assert sorted(got + rest) == sorted(enqueued)
    # dequeue order must follow link (volatile linearization) order
    link_order = [ev[1] for ev in res.events if ev[0] == "enq"]
    deq_order = [ev[1] for ev in res.events if ev[0] == "deq"]
    deq_set = set(deq_order)
    assert [x for x in link_order if x in deq_set] == deq_order


@pytest.mark.parametrize("name", ["OptUnlinkedQ", "OptLinkedQ"])
def test_heavy_contention(name, seed=5):
    nthreads = 6
    h = QueueHarness(ALL_QUEUES[name], nthreads=nthreads, area_nodes=512)
    plans = _mixed_plans(nthreads, 8)
    res = h.run_scheduled(plans, seed=seed)
    assert res.ops_completed == sum(len(p) for p in plans)
    assert res.stats.post_flush_accesses == 0


@pytest.mark.parametrize("name", sorted(ALL_QUEUES))
def test_lock_freedom_bounded_steps(name):
    """System-wide progress: all ops complete within a bounded number of
    scheduler steps even under adversarial random scheduling (§8)."""
    nthreads = 4
    h = QueueHarness(ALL_QUEUES[name], nthreads=nthreads, area_nodes=512)
    plans = _mixed_plans(nthreads, 5)
    total_ops = sum(len(p) for p in plans)
    # generous bound: if something livelocks/deadlocks, max_steps triggers
    from repro.core.scheduler import Scheduler
    sched = Scheduler(h.nvram, seed=13, policy="random", max_steps=400_000)
    workers = [h.make_worker(t, plans[t]) for t in range(nthreads)]
    crashed = sched.run(workers)
    assert not crashed, "hit step bound: no progress (lock-freedom violated?)"
    assert sum(1 for r in h.ops if r.completed) == total_ops
