"""ONLL (§2.1): one fence per update AND zero post-flush accesses, for an
arbitrary object -- the paper's theoretical upper bound, executable."""
from repro.core import NVRAM, ONLL


def queue_spec(state, op):
    """Deterministic sequential FIFO spec: state is a tuple."""
    kind, arg = op
    if kind == "enq":
        return state + (arg,), None
    if not state:
        return state, None
    return state[1:], state[0]


def counter_spec(state, op):
    return state + op, state + op


def test_onll_sequential_queue():
    nv = NVRAM(1)
    o = ONLL(nv, 1, queue_spec, ())
    for i in range(5):
        o.update(0, ("enq", i))
    assert o.read_state() == (0, 1, 2, 3, 4)
    assert o.update(0, ("deq", None)) == 0
    assert o.read_state() == (1, 2, 3, 4)


def test_onll_one_fence_zero_post_flush():
    nv = NVRAM(1)
    o = ONLL(nv, 1, counter_spec, 0)
    base = nv.total_stats()
    n = 50
    for i in range(n):
        o.update(0, 1)
    d = nv.total_stats().minus(base)
    assert d.fences == n, f"{d.fences} fences for {n} updates"
    assert d.post_flush_accesses == 0


def test_onll_crash_recovery():
    nv = NVRAM(1)
    o = ONLL(nv, 1, queue_spec, ())
    for i in range(6):
        o.update(0, ("enq", i))
    o.update(0, ("deq", None))
    nv.crash(mode="min")    # everything was fenced per-update
    o2, state = ONLL.recover(nv, 1, queue_spec, (), o.roots)
    assert state == (1, 2, 3, 4, 5)
    # object continues to work after recovery
    o2.update(0, ("enq", 99))
    assert o2.read_state() == (1, 2, 3, 4, 5, 99)


def test_onll_crash_mid_random_prefix():
    for seed in range(10):
        nv = NVRAM(1)
        o = ONLL(nv, 1, counter_spec, 0)
        for i in range(10):
            o.update(0, 1)
        # one more update, unfenced at crash time: simulate by crashing with
        # random pending application
        nv.crash(mode="random", seed=seed)
        _, state = ONLL.recover(nv, 1, counter_spec, 0, o.roots)
        assert state in (10, 11)   # pending update may or may not survive
