"""Fast-path bail-out coverage: everything outside the compiled steady
state must fall back to real per-primitive execution with Stats still
bit-exact against the per-op path.

The named non-steady-state cases from the schedule-compiler design:

* **empty-dequeue bursts** -- a dequeue on an empty queue runs a different
  primitive program (flush/fence the head, report empty) and, for
  NVTraverseQ, even leaves unfenced flushes pending into the next op;
* **first-op sentinel warmup** -- per-thread retire/flush slots
  (``node_to_retire`` / ``_to_flush``) are still NULL, and the very first
  ops run against cold roots;
* **allocator area refills** -- ``SSMem.alloc`` mid-op carves and zeroes a
  whole designated area (hundreds of primitives).
"""
import random

import pytest

from repro.core import ALL_QUEUES, QueueHarness

DURABLE7 = sorted(q for q in ALL_QUEUES if q != "MSQ")


def _run_pair(qname, plans, prefill=0, area_nodes=64, model="optane-clwb",
              nthreads=None):
    nthreads = nthreads if nthreads is not None else len(plans)
    out = []
    for compiled in (False, True):
        h = QueueHarness(ALL_QUEUES[qname], nthreads=nthreads,
                         area_nodes=area_nodes, model=model)
        for i in range(prefill):
            h.queue.enqueue(0, ("pre", i))
        res = h.run_batched([list(p) for p in plans], compiled=compiled)
        out.append((h, res))
    return out


def assert_pair_bit_exact(qname, plans, **kw):
    (h_ref, r_ref), (h_fast, r_fast) = _run_pair(qname, plans, **kw)
    s_ref, s_fast = h_ref.nvram.stats, h_fast.nvram.stats
    for t in s_ref:
        assert s_ref[t] == s_fast[t], (
            f"{qname}: thread {t}\n  per-op: {s_ref[t]}\n"
            f"  fast:   {s_fast[t]}")
    assert r_ref.events == r_fast.events
    assert r_ref.ops == r_fast.ops
    assert h_ref.queue.drain(0) == h_fast.queue.drain(0)
    return h_fast


@pytest.mark.parametrize("qname", DURABLE7)
def test_empty_dequeue_bursts_bail(qname):
    """Drain past empty repeatedly: every empty dequeue must execute for
    real (the compiled schedule covers successful dequeues only)."""
    plans = [[("deq", None)] * 12 + [("enq", (t, i)) for i in range(3)]
             + [("deq", None)] * 8 for t in range(3)]
    h = assert_pair_bit_exact(qname, plans, prefill=4)
    assert h.fast.bailed_ops > 0, "no op bailed -- the burst missed empty"


@pytest.mark.parametrize("qname", DURABLE7)
def test_sentinel_warmup_bails_then_settles(qname):
    """From a completely fresh queue (no prefill, cold slots) the first
    ops may bail; the run must still be bit-exact and the tail of the run
    must reach the fast path."""
    plans = [[("enq", (t, i)) for i in range(6)]
             + [("deq", None), ("enq", ("x", t)), ("deq", None)]
             for t in range(2)]
    h = assert_pair_bit_exact(qname, plans, prefill=0)
    assert h.fast.fast_ops > 0


@pytest.mark.parametrize("qname", ["DurableMSQ", "UnlinkedQ", "OptLinkedQ"])
def test_area_refill_bails_midrun(qname):
    """A tiny designated area forces refills mid-run; the enqueue that
    would carve a new area must run for real (zeroing schedule included)
    and the logical view must resync."""
    plans = [[("enq", (t, i)) for i in range(40)] for t in range(2)]
    h = assert_pair_bit_exact(qname, plans, prefill=0, area_nodes=8)
    assert h.fast.bailed_ops >= 2    # at least one refill per thread


def test_random_plans_bit_exact_property():
    """Property-style sweep: random interleavings of enq/deq (hitting
    empty, warmup and refill bails unpredictably) stay bit-exact across
    queues, models and seeds."""
    try:
        from hypothesis import given, settings, strategies as st
    except ImportError:
        pytest.skip("hypothesis not installed")

    @settings(max_examples=20, deadline=None)
    @given(st.integers(0, 2 ** 31 - 1), st.sampled_from(DURABLE7),
           st.sampled_from(["optane-clwb", "eadr", "cxl"]))
    def prop(seed, qname, model):
        rng = random.Random(seed)
        plans = []
        for t in range(rng.randint(1, 3)):
            plan = []
            for i in range(rng.randint(5, 25)):
                if rng.random() < 0.55:
                    plan.append(("enq", (t, i)))
                else:
                    plan.append(("deq", None))
            plans.append(plan)
        assert_pair_bit_exact(qname, plans, prefill=rng.randint(0, 4),
                              area_nodes=rng.choice([8, 64]), model=model)

    prop()


@pytest.mark.parametrize("qname", ["NVTraverseQ"])
def test_pending_persists_from_bailed_op_block_fast_path(qname):
    """NVTraverseQ's empty dequeue leaves unfenced flushes pending; the
    next op on that thread must bail too (PendingEmpty guard) so the real
    fence drains them with the correct line count."""
    plans = [[("deq", None), ("enq", ("a", 1)), ("deq", None)]]
    h = assert_pair_bit_exact(qname, plans, prefill=0)
    # first deq (empty) bails; the following enq sees pending flushes and
    # must bail as well
    assert h.fast.bailed_ops >= 2
